#!/usr/bin/env python
"""Per-model capture-report artifact: sites harvested/dispatched/fallback.

Harvests every demo config (dense / MoE / SSM — the conformance trio of
``repro.capture.demo_configs``) plus any archs named on the command line,
at each trace point (train / prefill / decode), abstractly — no parameter
allocation, no kernel execution — and writes one JSON document per model
with the full per-site breakdown (spec name, extents, dtype, dispatch
status, fallback reason).  CI uploads the output directory as the
``capture-report`` artifact so dispatch-coverage regressions are diffable
between runs.

Usage:
  python scripts/capture_report.py --out capture-report [--arch qwen3-8b ...]
      [--batch 2] [--seq 64] [--smoke]

Exit code is non-zero if any demo config dispatches zero sites at the
train trace point (the conformance floor).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_REPO, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def main() -> int:
    ap = argparse.ArgumentParser(description="capture-report artifact")
    ap.add_argument("--out", default="capture-report",
                    help="output directory for the per-model JSON files")
    ap.add_argument("--arch", action="append", default=[],
                    help="extra arch ids to harvest (repeatable)")
    ap.add_argument("--smoke", action="store_true",
                    help="use smoke() for the extra --arch configs")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    args = ap.parse_args()

    from repro import capture
    from repro.configs import get_config

    configs = dict(capture.demo_configs())
    for arch in args.arch:
        cfg = get_config(arch)
        configs[arch] = cfg.smoke() if args.smoke else cfg

    batch = args.batch or capture.DEMO_BATCH
    seq = args.seq or capture.DEMO_SEQ
    os.makedirs(args.out, exist_ok=True)

    failures = []
    index = {}
    for name, cfg in sorted(configs.items()):
        doc = {"config": name, "arch_id": cfg.arch_id, "kinds": {}}
        for kind in ("train", "prefill", "decode"):
            try:
                _, rep = capture.model_capture(
                    cfg, batch=batch, seq=seq, kind=kind, interpret=True,
                )
            except Exception as e:  # noqa: BLE001 — report, don't die
                doc["kinds"][kind] = {"error": f"{type(e).__name__}: {e}"}
                continue
            doc["kinds"][kind] = rep.as_dict()
            print(f"[capture-report] {name}/{kind}: {rep.summary()}")
            if kind == "train" and name in ("dense", "moe", "ssm"):
                if rep.dispatched < 1:
                    failures.append(f"{name}/train dispatched 0 sites")
        path = os.path.join(args.out, f"{name}.json")
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        index[name] = {
            kind: {
                k: v for k, v in d.items()
                if k in ("harvested", "dispatched", "fallback", "error")
            }
            for kind, d in doc["kinds"].items()
        }

    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump(index, f, indent=2, sort_keys=True)
        f.write("\n")

    if failures:
        print("FAIL:\n  " + "\n  ".join(failures))
        return 1
    print(f"capture-report written to {args.out}/ "
          f"({len(configs)} model(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
