#!/usr/bin/env python
"""CI smoke bench: run kernel_bench --smoke through the generator + search.

Executes ``python -m benchmarks.kernel_bench --smoke`` with PYTHONPATH set,
parses every CSV row, prints a one-line-per-row status table, and exits
non-zero if ANY row failed:

  * a required row is missing from the output,
  * a row carries ``error=`` in its derived column (a bench section raised
    — kernel_bench guards sections so one failure cannot hide another),
  * a ``max_err`` is NaN or above tolerance (NaN previously compared False
    against the threshold and slipped through — the exit-0-on-failure bug),
  * the searched schedule measured slower than ``default_schedule``
    (``search.vs_default`` must report ``not_slower=True``).

Usage: python scripts/bench_smoke.py
"""

from __future__ import annotations

import math
import os
import re
import subprocess
import sys

TOL = 1e-3
REQUIRED = [
    "kernel.gen.matmul",
    "kernel.gen.vs_handwritten",
    "kernel.gen.batched",
    "kernel.gen.chain",
    "kernel.gen.transposed",
    "search.matmul",
    "search.vs_default",
]


def check_row(name: str, derived: str) -> str:
    """'' if the row is healthy, else a failure reason."""
    if "error=" in derived:
        return derived[derived.index("error=") :]
    m = re.search(r"max_err=([^;,\s]+)", derived)
    if m:
        try:
            err = float(m.group(1))
        except ValueError:
            return f"unparseable max_err {m.group(1)!r}"
        if math.isnan(err):
            return "max_err is NaN"
        if err > TOL:
            return f"max_err {err:.3g} > {TOL}"
    if name == "search.vs_default" and "not_slower=True" not in derived:
        return "searched schedule slower than default_schedule"
    return ""


def main() -> int:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.kernel_bench", "--smoke"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=1800,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)

    rows = {}
    for line in proc.stdout.splitlines():
        m = re.match(r"([\w.]+),([^,]*),(.*)", line)
        if m and m.group(1) != "name":
            rows[m.group(1)] = m.group(3)

    failures = []
    print()
    print(f"{'row':32s} {'status':6s} detail")
    for name in sorted(set(rows) | set(REQUIRED)):
        if name not in rows:
            status, detail = "MISS", "required row absent from bench output"
            failures.append(f"{name}: {detail}")
        else:
            reason = check_row(name, rows[name])
            if reason:
                status, detail = "FAIL", reason
                failures.append(f"{name}: {reason}")
            else:
                status, detail = "ok", rows[name][:60]
        print(f"{name:32s} {status:6s} {detail}")

    if proc.returncode != 0:
        failures.append(f"kernel_bench exited {proc.returncode}")
    if failures:
        print(f"\nFAIL ({len(failures)}):\n  " + "\n  ".join(failures))
        return 1
    print(f"\nOK: {len(rows)} rows, {len(REQUIRED)} required, all healthy")
    return 0


if __name__ == "__main__":
    sys.exit(main())
