#!/usr/bin/env python
"""CI smoke bench: run kernel_bench --smoke through generator+search+grad.

Executes ``python -m benchmarks.kernel_bench --smoke`` with PYTHONPATH set,
parses every CSV row, prints a one-line-per-row status table, and exits
non-zero if ANY row failed:

  * a required row is missing from the output,
  * a row carries ``error=`` in its derived column (a bench section raised
    — kernel_bench guards sections so one failure cannot hide another),
  * a ``max_err`` is NaN or above tolerance (NaN previously compared False
    against the threshold and slipped through — the exit-0-on-failure bug),
  * the searched schedule measured slower than ``default_schedule``
    (``search.vs_default`` must report ``not_slower=True``),
  * the backward GEMMs failed to pick up searched plans by derived-spec
    key (``grad.plandb`` must report ``ok=True``),
  * whole-model capture dispatched zero sites on any demo config
    (``capture.sites.*`` must report ``dispatched>=1``),
  * observability instrumentation measurably slowed the hot dispatch path
    (``obs.overhead`` must report ``ratio=`` <= ``OBS_OVERHEAD_MAX``; the
    obs.* rows additionally land in ``BENCH_obs.json``).

On success (and only then) the parsed rows are written to
``BENCH_pr3.json`` at the repo root — per-row seconds, GFLOP/s (from the
``flops=`` fields kernel_bench emits) and max_err — the machine-readable
perf trajectory later PRs diff against.

With ``--attn`` the bench subprocess runs only the fused-family sections
(``kernel_bench --smoke --attn``) and the ``attn.fused`` /
``moe.grouped`` rows become required: ``attn.fused`` must report
``not_slower=True`` (the analytic HBM claim — the fused kernel never
round-trips the score tensor) and ``moe.grouped`` must report
``ok=True`` (the ragged kernel matches the per-group dot loop).  Rows
land in ``BENCH_attn.json`` — the attn-smoke CI job's artifact.

With ``--quant`` the bench subprocess runs only the int8/fp8 quant-tier
sections (``kernel_bench --smoke --quant``) and the ``quant.*`` rows
become required: every quant row must report ``ok=True`` (bounded
kernel-vs-dequantized-oracle error through the searched ladder), and
``quant.int8`` / ``quant.fp8`` must additionally report
``not_slower=True`` — the analytic one-pass HBM floor of the quantized
contraction is below the bf16 floor at the matched shape, which with
matched ``flops=`` is exactly the "quant GFLOP/s >= bf16" gate in
``BENCH_quant.json`` — the quant-smoke CI job's artifact.

With ``--mesh`` the bench subprocess runs under a forced 8-device CPU mesh
(``--xla_force_host_platform_device_count=8``) and the ``mesh.*`` rows
become required: ``mesh.search`` and ``mesh.ring`` must report ``ok=True``
and ``mesh.vs_psum`` must report ``not_slower=True`` — the searched
sharded schedule is never slower than the naive plain-psum lowering of
the same subdivision (structural: the naive baseline is part of the
measured set).  This is the mesh-smoke CI job's entry point; the parsed
rows then land in ``BENCH_mesh.json`` instead of the single-device
baseline file.

Usage: python scripts/bench_smoke.py [--mesh | --serve | --attn | --quant]
"""

from __future__ import annotations

import json
import math
import os
import re
import subprocess
import sys

TOL = 1e-3
#: observability must be free enough to stay on by default: obs-on vs
#: obs-off timing of the same memoized dense dispatch (min-over-repeats)
OBS_OVERHEAD_MAX = 1.02
BENCH_JSON = "BENCH_pr3.json"
BENCH_MESH_JSON = "BENCH_mesh.json"
BENCH_OBS_JSON = "BENCH_obs.json"
BENCH_SERVE_JSON = "BENCH_serve.json"
BENCH_ATTN_JSON = "BENCH_attn.json"
BENCH_QUANT_JSON = "BENCH_quant.json"
REQUIRED = [
    "kernel.gen.matmul",
    "kernel.gen.vs_handwritten",
    "kernel.gen.batched",
    "kernel.gen.chain",
    "kernel.gen.transposed",
    "search.matmul",
    "search.vs_default",
    "grad.dense.fwd",
    "grad.dense.bwd",
    "grad.dense_act.bwd",
    "grad.plandb",
    "capture.sites.dense",
    "capture.sites.moe",
    "capture.sites.ssm",
    "capture.step",
    "obs.overhead",
]
#: required only under --mesh (the bench emits them only multi-device)
REQUIRED_MESH = [
    "mesh.search",
    "mesh.vs_psum",
    "mesh.ring",
]
#: the --serve run replaces kernel_bench with serve_bench entirely: the
#: continuous-batching engine must not be slower than the fixed-slot
#: baseline AND must produce byte-identical per-request greedy outputs
REQUIRED_SERVE = [
    "serve.continuous.tok_per_s",
    "serve.fixed.tok_per_s",
    "serve.p50",
    "serve.p99",
    "serve.vs_fixed",
    "serve.differential",
]
#: the --attn run gates the fused families (ISSUE 8): the fused attention
#: kernel's analytic HBM claim vs the unfused two-GEMM+softmax program,
#: and the ragged grouped kernel's correctness vs the per-group dot loop
REQUIRED_ATTN = [
    "attn.fused",
    "moe.grouped",
]
#: the --quant run gates the int8/fp8 tier (ISSUE 10): the searched
#: quantized kernels' bounded error vs the dequantized f64 oracle, and
#: the analytic HBM claim that 1-byte operands beat bf16 at the matched
#: shape (== the "quant GFLOP/s >= bf16" gate under matched flops)
REQUIRED_QUANT = [
    "quant.bf16",
    "quant.int8",
    "quant.fp8",
    "quant.dense",
]


def check_row(name: str, derived: str) -> str:
    """'' if the row is healthy, else a failure reason."""
    if "error=" in derived:
        return derived[derived.index("error=") :]
    m = re.search(r"max_err=([^;,\s]+)", derived)
    if m:
        try:
            err = float(m.group(1))
        except ValueError:
            return f"unparseable max_err {m.group(1)!r}"
        if math.isnan(err):
            return "max_err is NaN"
        if err > TOL:
            return f"max_err {err:.3g} > {TOL}"
    if name == "search.vs_default" and "not_slower=True" not in derived:
        return "searched schedule slower than default_schedule"
    if name == "grad.plandb" and "ok=True" not in derived:
        return "backward GEMMs did not hit searched plans by derived key"
    if name.startswith("mesh.") and "ok=True" not in derived:
        return "mesh row unhealthy (ok=True missing)"
    if name == "mesh.vs_psum" and "not_slower=True" not in derived:
        return "searched sharded schedule slower than naive psum lowering"
    if name == "serve.vs_fixed" and "not_slower=True" not in derived:
        return "continuous batching slower than the fixed-slot baseline"
    if name == "serve.differential" and "ok=True" not in derived:
        return "continuous/fixed per-request outputs diverged"
    if name == "attn.fused" and "not_slower=True" not in derived:
        return ("fused attention claims more HBM traffic than the "
                "unfused two-GEMM+softmax program")
    if name == "moe.grouped" and "ok=True" not in derived:
        return "grouped kernel diverged from the per-group dot loop"
    if name.startswith("quant.") and "ok=True" not in derived:
        return "quant row unhealthy (ok=True missing)"
    if name in ("quant.int8", "quant.fp8") and "not_slower=True" not in derived:
        return ("quantized tier claims no HBM advantage over bf16 at "
                "the matched shape")
    if name.startswith("capture.sites."):
        m = re.search(r"dispatched=(\d+)", derived)
        if not m:
            return "capture row missing dispatched= counter"
        if int(m.group(1)) < 1:
            return "whole-model capture dispatched zero sites"
    if name == "obs.overhead":
        m = re.search(r"ratio=([^;,\s]+)", derived)
        if not m:
            return "obs row missing ratio= field"
        try:
            ratio = float(m.group(1))
        except ValueError:
            return f"unparseable obs ratio {m.group(1)!r}"
        if math.isnan(ratio) or ratio > OBS_OVERHEAD_MAX:
            return (f"obs-on/obs-off ratio {ratio:.4g} > "
                    f"{OBS_OVERHEAD_MAX} — instrumentation too hot")
    return ""


def _field(derived: str, key: str):
    m = re.search(rf"{key}=([^;,\s]+)", derived)
    if not m:
        return None
    try:
        val = float(m.group(1))
    except ValueError:
        return None
    # non-finite values must not reach the JSON baseline (bare NaN is not
    # valid strict JSON and would poison later-PR diffs)
    return val if math.isfinite(val) else None


def write_bench_json(
    repo: str, rows: dict, out_name: str = BENCH_JSON,
    source: str = "kernel_bench --smoke",
) -> str:
    """Persist the parsed rows as the PR's perf baseline.

    ``rows`` maps name -> (seconds, derived).  GFLOP/s comes from the
    ``flops=`` field where a row carries one; rows without arithmetic
    (plan-DB bookkeeping, vs_* comparisons) report null.  The default
    target is the single-device baseline (``BENCH_pr3.json``); the
    ``--mesh`` run writes ``BENCH_mesh.json`` so forced-mesh timings
    never overwrite the single-device trajectory.
    """
    out = {}
    for name in sorted(rows):
        seconds, derived = rows[name]
        flops = _field(derived, "flops")
        gflops = (
            flops / seconds / 1e9
            if flops and seconds and seconds > 0 else None
        )
        out[name] = {
            "seconds": seconds if math.isfinite(seconds) else None,
            "gflops": None if gflops is None else round(gflops, 4),
            "max_err": _field(derived, "max_err"),
        }
    path = os.path.join(repo, out_name)
    with open(path, "w") as f:
        json.dump(
            {
                "schema": 1,
                "source": f"scripts/bench_smoke.py ({source})",
                "rows": out,
            },
            f, indent=1, sort_keys=True, allow_nan=False,
        )
        f.write("\n")
    return path


def write_obs_json(repo: str, rows: dict) -> str:
    """Persist the obs.* rows (overhead gate evidence) to BENCH_obs.json.

    Unlike the perf baseline, the interesting numbers here are the
    obs-on/obs-off ``ratio`` and the obs-off ``baseline_s`` — the record
    that observability stayed within ``OBS_OVERHEAD_MAX`` on this commit.
    """
    out = {}
    for name in sorted(rows):
        seconds, derived = rows[name]
        out[name] = {
            "seconds_on": seconds if math.isfinite(seconds) else None,
            "seconds_off": _field(derived, "baseline_s"),
            "ratio": _field(derived, "ratio"),
        }
    path = os.path.join(repo, BENCH_OBS_JSON)
    with open(path, "w") as f:
        json.dump(
            {
                "schema": 1,
                "source": "scripts/bench_smoke.py (kernel_bench --smoke)",
                "gate_max_ratio": OBS_OVERHEAD_MAX,
                "rows": out,
            },
            f, indent=1, sort_keys=True, allow_nan=False,
        )
        f.write("\n")
    return path


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--mesh", action="store_true",
        help="force an 8-device CPU mesh for the bench subprocess and "
             "gate on the mesh.* rows (sharded search + ring collective)",
    )
    ap.add_argument(
        "--serve", action="store_true",
        help="run benchmarks.serve_bench instead of kernel_bench and "
             "gate on the serve.* rows (continuous vs fixed-slot)",
    )
    ap.add_argument(
        "--attn", action="store_true",
        help="run only kernel_bench's fused attention + grouped-GEMM "
             "sections and gate on the attn.fused / moe.grouped rows",
    )
    ap.add_argument(
        "--quant", action="store_true",
        help="run only kernel_bench's int8/fp8 quant-tier sections and "
             "gate on the quant.* rows (searched ladder error bounds + "
             "analytic HBM advantage over bf16)",
    )
    args = ap.parse_args()
    if sum((args.mesh, args.serve, args.attn, args.quant)) > 1:
        ap.error(
            "--mesh/--serve/--attn/--quant are separate CI jobs; pick one"
        )

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    required = list(REQUIRED)
    bench_json = BENCH_JSON
    bench_module = "benchmarks.kernel_bench"
    bench_flags = ["--smoke"]
    if args.attn:
        required = list(REQUIRED_ATTN)
        bench_json = BENCH_ATTN_JSON
        bench_flags.append("--attn")
    if args.quant:
        required = list(REQUIRED_QUANT)
        bench_json = BENCH_QUANT_JSON
        bench_flags.append("--quant")
    if args.mesh:
        flags = env.get("XLA_FLAGS", "")
        env["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8"
        ).strip()
        required += REQUIRED_MESH
        bench_json = BENCH_MESH_JSON
    if args.serve:
        required = list(REQUIRED_SERVE)
        bench_json = BENCH_SERVE_JSON
        bench_module = "benchmarks.serve_bench"
    proc = subprocess.run(
        [sys.executable, "-m", bench_module, *bench_flags],
        cwd=repo, env=env, capture_output=True, text=True, timeout=1800,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)

    rows = {}
    for line in proc.stdout.splitlines():
        m = re.match(r"([\w.]+),([^,]*),(.*)", line)
        if m and m.group(1) != "name":
            try:
                seconds = float(m.group(2)) * 1e-6  # column is us_per_call
            except ValueError:
                seconds = 0.0
            rows[m.group(1)] = (seconds, m.group(3))

    failures = []
    print()
    print(f"{'row':32s} {'status':6s} detail")
    for name in sorted(set(rows) | set(required)):
        if name not in rows:
            status, detail = "MISS", "required row absent from bench output"
            failures.append(f"{name}: {detail}")
        else:
            reason = check_row(name, rows[name][1])
            if reason:
                status, detail = "FAIL", reason
                failures.append(f"{name}: {reason}")
            else:
                status, detail = "ok", rows[name][1][:60]
        print(f"{name:32s} {status:6s} {detail}")

    if proc.returncode != 0:
        failures.append(f"{bench_module} exited {proc.returncode}")
    if failures:
        print(f"\nFAIL ({len(failures)}):\n  " + "\n  ".join(failures))
        return 1
    path = write_bench_json(
        repo, rows, bench_json,
        source=f"{bench_module} {' '.join(bench_flags)}",
    )
    print(f"\nOK: {len(rows)} rows, {len(required)} required, all healthy")
    print(f"baseline written to {path}")
    obs_rows = {n: rows[n] for n in rows if n.startswith("obs.")}
    if obs_rows and not args.mesh:
        obs_path = write_obs_json(repo, obs_rows)
        print(f"obs overhead written to {obs_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
