#!/usr/bin/env python
"""CI smoke bench: run kernel_bench --smoke through the generator path.

Executes ``python -m benchmarks.kernel_bench --smoke`` with PYTHONPATH set,
parses the CSV rows, and fails if any generated-kernel row is missing or
reports max_err above tolerance.  Keeps the codegen path exercised on every
push without a TPU.

Usage: python scripts/bench_smoke.py
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

TOL = 1e-3
REQUIRED = [
    "kernel.gen.matmul",
    "kernel.gen.vs_handwritten",
    "kernel.gen.batched",
    "kernel.gen.chain",
    "kernel.gen.transposed",
]


def main() -> int:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.kernel_bench", "--smoke"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=1800,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    if proc.returncode != 0:
        print(f"FAIL: kernel_bench exited {proc.returncode}")
        return 1
    errs = {}
    for line in proc.stdout.splitlines():
        m = re.match(r"([\w.]+),[^,]*,.*max_err=([\d.eE+-]+)", line)
        if m:
            errs[m.group(1)] = float(m.group(2))
    bad = []
    for name in REQUIRED:
        if name not in errs:
            bad.append(f"{name}: missing from bench output")
        elif errs[name] > TOL:
            bad.append(f"{name}: max_err {errs[name]:.3g} > {TOL}")
    if bad:
        print("FAIL:\n  " + "\n  ".join(bad))
        return 1
    print(f"OK: {len(REQUIRED)} generated-kernel benches within {TOL}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
