"""Render the dry-run + roofline tables into EXPERIMENTS.md.

Replaces the <!-- DRYRUN_TABLE --> and <!-- ROOFLINE_TABLE --> markers
(content between marker and the next section heading is regenerated).

  PYTHONPATH=src python scripts/update_experiments.py --results results
"""

import argparse
import json
import re
import sys

sys.path.insert(0, "src")

from repro.roofline.analysis import analyze_all, markdown_table  # noqa: E402


def dryrun_table(rows):
    lines = [
        "| arch | shape | mesh | status | step | compile_s | peak GiB "
        "| HLO flops/dev | collective B/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped "
                f"({r['reason'].split('(')[0].strip()}) | — | — | — | — | — |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | — | "
                f"— | — | — | — |"
            )
            continue
        peak = r["memory"].get("peak_memory_in_bytes", 0) / 2**30
        lines.append(
            "| {arch} | {shape} | {mesh} | ok | {step} | {cs} | {pk:.2f} | "
            "{fl:.3g} | {cb:.3g} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                step=r["step"].replace("_step", ""),
                cs=r.get("compile_s", 0), pk=peak,
                fl=r["parsed"]["dot_flops"] if "parsed" in r else r["flops"],
                cb=r.get("collective_bytes",
                         r.get("parsed", {}).get("collective_bytes", 0)),
            )
        )
    return "\n".join(lines)


def splice(text, marker, content):
    pattern = re.compile(
        rf"(<!-- {marker} -->).*?(?=\n## |\Z)", re.DOTALL
    )
    return pattern.sub(rf"\1\n\n{content}\n", text)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    ap.add_argument("--file", default="EXPERIMENTS.md")
    args = ap.parse_args()

    rows = analyze_all(args.results)
    text = open(args.file).read()
    text = splice(text, "DRYRUN_TABLE", dryrun_table(rows))
    text = splice(text, "ROOFLINE_TABLE", markdown_table(rows))
    open(args.file, "w").write(text)
    print(f"updated {args.file} with {len(rows)} cells")


if __name__ == "__main__":
    main()
