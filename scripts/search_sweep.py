#!/usr/bin/env python
"""Offline variant-search sweep: rewrite rules -> ranked, measured plans.

Runs the full ``repro.search`` pipeline for one or more spec/shape points,
persists the ranked plans, and verifies the winner round-trips through the
plan database (the same lookup ``ops.dense`` performs).

Examples:
  python scripts/search_sweep.py --spec matmul --shapes 512,512,512 \
      --beam 8 --interpret
  python scripts/search_sweep.py --spec chain_matmul \
      --shapes 128,128,128,128 --beam 4 --interpret --dtype float32
  python scripts/search_sweep.py --spec matmul \
      --shapes "256,256,256;512,512,512" --no-measure   # analytic only
  python scripts/search_sweep.py --spec matmul --shapes 512,512,512 \
      --interpret --with-grads   # also sweep the derived dA/dB specs
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  python scripts/search_sweep.py --spec matmul --shapes 256,256,256 \
      --interpret --mesh 2x4     # also sweep the mesh (distributed) tier:
      # sharded ladders persist under mesh-qualified keys and the sharded
      # candidates are measured over the forced 8-device CPU mesh
  python scripts/search_sweep.py --from-model qwen3-8b --model-smoke \
      --model-batch 2 --model-seq 64 --interpret --with-grads
      # whole-model sweep: harvest the config's full GEMM set via
      # repro.capture (train+prefill+decode, abstract trace — no
      # allocation) and sweep every harvested spec, fwd+bwd, in one pass
  python scripts/search_sweep.py --spec attention --shapes 4,64,64,8 \
      --interpret --with-grads
      # fused flash-attention family: shapes = heads,q_seq,kv_seq,head_dim;
      # the KV axis is searched as an in-schedule reduction tier (online
      # softmax) and --with-grads sweeps attention.dQ/.dK/.dV too
  python scripts/search_sweep.py --spec grouped_matmul \
      --shapes 4,16,32,32 --interpret --with-grads
      # ragged grouped GEMM (MoE expert FFNs): shapes =
      # groups,rows_per_group,k,f — one group-offset Pallas grid

Exit code is non-zero if any sweep point fails to produce a plan or the
persisted winner does not round-trip.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_REPO, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def _fmt_sched(sched) -> str:
    return " ".join(f"{l.index}:{l.tier}:{l.extent}" for l in sched.levels)


def main() -> int:
    ap = argparse.ArgumentParser(
        description="cost-guided variant search sweep"
    )
    ap.add_argument(
        "--spec", default=None,
        help="spec family (matmul, matvec, weighted_matmul, "
             "batched_matmul, chain_matmul, transposed_matmul, "
             "attention, grouped_matmul); default matmul.  Incompatible "
             "with --from-model, which harvests its own specs",
    )
    ap.add_argument(
        "--shapes", default=None,
        help="semicolon-separated extent tuples, e.g. '512,512,512' "
             "(required unless --from-model)",
    )
    ap.add_argument(
        "--from-model", default=None, metavar="ARCH",
        help="harvest the sweep points from a model config instead of "
             "--spec/--shapes: repro.capture traces the arch's train, "
             "prefill and decode entry points abstractly and collects "
             "every dispatched dot_general site's ContractionSpec",
    )
    ap.add_argument("--model-smoke", action="store_true",
                    help="with --from-model, use the reduced smoke config")
    ap.add_argument("--model-batch", type=int, default=2,
                    help="batch size for the --from-model trace")
    ap.add_argument("--model-seq", type=int, default=64,
                    help="sequence length for the --from-model trace")
    ap.add_argument(
        "--model-kinds", default="train,prefill,decode",
        help="comma-separated trace points for --from-model",
    )
    ap.add_argument("--beam", type=int, default=8, help="beam width")
    ap.add_argument("--topk", type=int, default=4,
                    help="survivors lowered + measured")
    ap.add_argument("--dtype", default=None,
                    help="sweep dtype (default float32).  Incompatible "
                         "with --from-model, which sweeps under the "
                         "model's own activation dtype so plan keys "
                         "match run-time lookups")
    ap.add_argument("--interpret", action="store_true",
                    help="measure via the Pallas interpreter (CPU)")
    ap.add_argument("--no-measure", action="store_true",
                    help="analytic ranking only, skip lowering/timing")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--plan-db", default=None,
                    help="plan DB path (default: $REPRO_PLAN_DB or "
                         "~/.cache/repro/plans.json)")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore previously stored plans for these keys")
    ap.add_argument("--with-grads", action="store_true",
                    help="also sweep each spec's derived backward specs "
                         "(grad.derive: dA, dB, ...) so training's "
                         "cotangent GEMMs get searched plans too")
    ap.add_argument("--mesh", default=None, metavar="AxB",
                    help="also sweep every point at the mesh tier of the "
                         "given shape ('2x4' = data x model, '2x2x4' adds "
                         "a pod axis): mesh subdivisions x collective "
                         "strategies join the beam under the "
                         "communication-aware cost and the sharded ladder "
                         "persists under the mesh-qualified plan key.  "
                         "Sharded candidates are measured when this "
                         "process can host the mesh (force one with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N), else ranked analytically")
    args = ap.parse_args()

    import numpy as np

    from repro.search import (
        PlanDB,
        default_plan_db,
        search_schedule,
        spec_from_name,
        sweep_specs,
    )

    db = PlanDB(args.plan_db) if args.plan_db else default_plan_db()

    points = []
    if args.from_model:
        # harvested points carry their own specs and dtypes; a user also
        # passing --spec/--dtype/--shapes would silently get something
        # else than they asked for, so refuse loudly
        for flag, val in (("--spec", args.spec), ("--dtype", args.dtype),
                          ("--shapes", args.shapes)):
            if val is not None:
                ap.error(f"{flag} cannot be combined with --from-model "
                         f"(the harvest determines specs and dtypes)")
        from repro.capture import model_gemm_specs
        from repro.configs import get_config

        cfg = get_config(args.from_model)
        if args.model_smoke:
            cfg = cfg.smoke()
        kinds = tuple(
            k.strip() for k in args.model_kinds.split(",") if k.strip()
        )
        harvested = model_gemm_specs(
            cfg, batch=args.model_batch, seq=args.model_seq,
            kinds=kinds, interpret=True,
        )
        if not harvested:
            print(f"--from-model {args.from_model}: no dispatchable "
                  f"GEMM sites harvested")
            return 1
        for hlabel, spec, dtype in harvested:
            shape = tuple(spec.extents[i] for i in spec.indices)
            # sweep under the model's own activation dtype so the plan
            # keys match the lookups ops performs at run time
            points.extend(
                (f"{hlabel}/{label}", sub, shape, dtype)
                for label, sub in sweep_specs(
                    spec, with_grads=args.with_grads
                )
            )
        spec_name = f"{args.from_model}(captured)"
    else:
        if args.spec is None:
            args.spec = "matmul"
        if args.dtype is None:
            args.dtype = "float32"
        if not args.shapes:
            ap.error("--shapes is required unless --from-model is given")
        shapes = [
            tuple(int(x) for x in part.split(","))
            for part in args.shapes.split(";")
            if part.strip()
        ]
        if not shapes:
            ap.error("--shapes is empty")
        for shape in shapes:
            root = spec_from_name(args.spec, shape)
            points.extend(
                (label, spec, shape, args.dtype)
                for label, spec in sweep_specs(
                    root, with_grads=args.with_grads
                )
            )
        spec_name = args.spec

    meshes = [None]
    if args.mesh:
        from repro.search import parse_mesh_shape

        meshes.append(parse_mesh_shape(args.mesh))

    failures = 0
    for label, spec, shape, dtype in points:
      for mesh_shape in meshes:
        at = (f" @mesh={'x'.join(map(str, mesh_shape))}"
              if mesh_shape else "")
        print(f"== {spec_name} {'x'.join(map(str, shape))} [{label}]{at} "
              f"(beam={args.beam}, topk={args.topk}, dtype={dtype}) ==")
        res = search_schedule(
            spec,
            dtype=np.dtype(dtype),
            beam_width=args.beam,
            topk=args.topk,
            measure=not args.no_measure,
            interpret=args.interpret,
            repeats=args.repeats,
            plan_db=db,
            use_cached_plan=not args.fresh,
            mesh_shape=mesh_shape,
        )
        s = res.stats
        print(f"   candidates considered={s.considered} "
              f"deduped={s.deduped} pruned(bound)={s.pruned_bound} "
              f"pruned(beam)={s.pruned_beam} measured={s.measured} "
              f"mesh_variants={s.mesh_variants}")
        for rank, p in enumerate(res.ranked):
            t = ("-" if p.measured_s is None
                 else f"{p.measured_s * 1e3:8.2f}ms")
            coll = f" coll={p.collective}" if p.collective else ""
            print(f"   #{rank} [{p.source:10s}] measured={t} "
                  f"score={p.score:.3e} bound={p.lower_bound:.3e} "
                  f"vmem_ok={p.fits_vmem}{coll}")
            print(f"      {_fmt_sched(p.schedule)}")
        if not res.ranked:
            print("   FAIL: search produced no plan")
            failures += 1
            continue
        if mesh_shape is not None and not any(
            p.sharded for p in res.ranked
        ):
            print("   FAIL: mesh sweep surfaced no mesh:* plan")
            failures += 1
            continue

        # round-trip check: the lookup ops.dense performs must return the
        # winner we just stored
        from repro.codegen.cache import schedule_to_dict

        stored = db.best_schedule(spec, np.dtype(dtype), mesh=res.mesh)
        if stored is None or (
            json.dumps(schedule_to_dict(stored), sort_keys=True)
            != json.dumps(schedule_to_dict(res.best.schedule), sort_keys=True)
        ):
            print("   FAIL: winner did not round-trip through the plan DB")
            failures += 1
            continue
        print(f"   plan persisted & round-tripped (db={db.path})")

    if failures:
        print(f"{failures} sweep point(s) failed")
        return 1
    print("sweep OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
