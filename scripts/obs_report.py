#!/usr/bin/env python
"""Render + schema-check repro.obs artifacts: plan explains, traces, metrics.

Three modes (combinable — each validates its input and exits non-zero on
schema drift, which is what the CI ``obs-smoke`` job gates on):

  --explain SELECTOR [--plan-db PATH]
      Print the ranked why-this-plan table for every plan-DB entry
      matching the selector (``name[@MxKx...][@mesh=AxB][@dtype=NAME]``,
      e.g. ``matmul@512x512x512`` or ``matmul.dA@mesh=2x4``): per-rung
      roofline terms (compute/HBM/collective seconds, penalty) the search
      decided on, plus the sound bound cuts it rejected.  The DB defaults
      to ``$REPRO_PLAN_DB`` / ``~/.cache/repro/plans.json`` — the same
      resolution ``search.default_plan_db`` uses.

  --trace FILE
      Validate a Chrome-trace JSON (``serve --trace-out``, or any
      ``obs.trace_dump``) and print a per-span-name summary (count,
      total/mean/max duration).  The file must parse as
      ``{"traceEvents": [...]}`` with name/cat/ph/ts/pid/tid per event
      and ``dur`` on complete ("X") events.

  --metrics FILE
      Validate a metrics dump (``serve --metrics-out``, or any
      ``obs.metrics_dump``) and pretty-print counters, gauges and
      histogram summaries.  The file must carry the
      counters/gauges/histograms sections with the summary fields
      ``obs.metrics`` writes (count/sum and, when non-empty,
      min/max/p50/p99).

Pure stdlib + ``repro.obs.explain`` (also stdlib-only): usable on a
machine that only holds the artifact files, no jax needed.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(_HERE, "..", "src")
if os.path.isdir(_SRC):
    sys.path.insert(0, os.path.abspath(_SRC))

from repro.obs import explain as _explain  # noqa: E402


def _fail(msg: str) -> "NoReturn":  # noqa: F821
    print(f"obs_report: FAIL: {msg}", file=sys.stderr)
    raise SystemExit(1)


def default_plan_db_path() -> str:
    return os.environ.get("REPRO_PLAN_DB") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "plans.json"
    )


def run_explain(selector: str, db_path: str) -> None:
    if not os.path.exists(db_path):
        _fail(f"plan DB not found at {db_path} (set --plan-db or "
              f"$REPRO_PLAN_DB; populate with scripts/search_sweep.py)")
    try:
        print(_explain.explain(db_path, selector))
    except (LookupError, ValueError) as e:
        _fail(str(e))


_EVENT_REQUIRED = ("name", "cat", "ph", "ts", "pid", "tid")


def run_trace(path: str) -> None:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        _fail(f"{path}: unreadable trace JSON ({e})")
    if not isinstance(doc, dict) or not isinstance(
        doc.get("traceEvents"), list
    ):
        _fail(f"{path}: not a Chrome-trace document "
              f"(want object with a traceEvents list)")
    events = doc["traceEvents"]
    per: dict = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            _fail(f"{path}: traceEvents[{i}] is not an object")
        missing = [k for k in _EVENT_REQUIRED if k not in ev]
        if missing:
            _fail(f"{path}: traceEvents[{i}] missing {missing}")
        if ev["ph"] == "X" and "dur" not in ev:
            _fail(f"{path}: complete event traceEvents[{i}] has no dur")
        if ev["ph"] == "X":
            agg = per.setdefault(ev["name"], [0, 0.0, 0.0])
            agg[0] += 1
            agg[1] += float(ev["dur"])
            agg[2] = max(agg[2], float(ev["dur"]))
    print(f"trace {path}: {len(events)} event(s), "
          f"{len(per)} span name(s)")
    print(f"  {'span':<28} {'count':>6} {'total_ms':>10} "
          f"{'mean_ms':>9} {'max_ms':>9}")
    for name in sorted(per, key=lambda n: -per[n][1]):
        n, tot, mx = per[name]
        print(f"  {name:<28} {n:>6} {tot/1e3:>10.3f} "
              f"{tot/n/1e3:>9.3f} {mx/1e3:>9.3f}")


def run_metrics(path: str) -> None:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        _fail(f"{path}: unreadable metrics JSON ({e})")
    for section in ("counters", "gauges", "histograms"):
        if not isinstance(doc.get(section), dict):
            _fail(f"{path}: missing/invalid {section!r} section")
    for name, h in doc["histograms"].items():
        if not isinstance(h, dict) or "count" not in h or "sum" not in h:
            _fail(f"{path}: histogram {name!r} lacks count/sum")
        if h.get("count", 0) > 0:
            missing = [k for k in ("min", "max", "p50", "p99") if k not in h]
            if missing:
                _fail(f"{path}: non-empty histogram {name!r} "
                      f"missing {missing}")
    print(f"metrics {path}:")
    if doc["counters"]:
        print("  counters:")
        for name, v in sorted(doc["counters"].items()):
            print(f"    {name:<32} {v}")
    if doc["gauges"]:
        print("  gauges:")
        for name, v in sorted(doc["gauges"].items()):
            print(f"    {name:<32} {v:.6g}")
    if doc["histograms"]:
        print("  histograms:")
        for name, h in sorted(doc["histograms"].items()):
            if h["count"]:
                print(f"    {name:<32} count={h['count']} "
                      f"p50={h['p50']:.6g} p99={h['p99']:.6g} "
                      f"max={h['max']:.6g}")
            else:
                print(f"    {name:<32} count=0")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--explain", metavar="SELECTOR",
                    help="plan selector: name[@MxKx...][@mesh=AxB]"
                         "[@dtype=NAME]")
    ap.add_argument("--plan-db", default=None,
                    help="plan-DB JSON (default: $REPRO_PLAN_DB or "
                         "~/.cache/repro/plans.json)")
    ap.add_argument("--trace", metavar="FILE",
                    help="Chrome-trace JSON to validate + summarize")
    ap.add_argument("--metrics", metavar="FILE",
                    help="metrics dump JSON to validate + pretty-print")
    args = ap.parse_args(argv)
    if not (args.explain or args.trace or args.metrics):
        ap.error("pick at least one of --explain / --trace / --metrics")
    if args.explain:
        run_explain(args.explain, args.plan_db or default_plan_db_path())
    if args.trace:
        run_trace(args.trace)
    if args.metrics:
        run_metrics(args.metrics)


if __name__ == "__main__":
    main()
