"""Autotune a GEMM end-to-end: enumerate -> early-cut -> measure -> schedule.

This is the paper's §4 experiment as a tool: given a problem size, the tuner
enumerates HoF orderings (+subdivisions), prunes with the analytic cost
model (the 'early cut' the paper leaves to future work), measures the
survivors on CPU, and emits the full hierarchical Schedule — mesh axes,
Pallas grid blocks, MXU tiles — for the TPU deployment.

Run:  PYTHONPATH=src python examples/autotune_gemm.py [N]
"""

import sys

import numpy as np

from repro.core.autotune import choose_matmul_blocks, tune
from repro.core.enumerate import matmul_spec
from repro.core.schedule import matmul_schedule

n = int(sys.argv[1]) if len(sys.argv) > 1 else 256

rng = np.random.default_rng(0)
arrays = {"A": rng.standard_normal((n, n)), "B": rng.standard_normal((n, n))}
spec = matmul_spec(n, n, n)

print(f"tuning {n}x{n}x{n} matmul (CPU measurement of model-pruned set)...")
tuned = tune(
    spec,
    subdiv_candidates={"j": [16, 32], "i": [32], "k": [32]},
    keep=6,
    measure_with=arrays,
)
print(f"{'nest':40s} {'pred.cost':>12s} {'measured':>10s}")
for tv in tuned:
    print(
        f"{'/'.join(tv.order):40s} {tv.predicted_cost:12.3g} "
        f"{tv.measured_s*1e3:9.2f}ms"
    )

# the TPU deployment schedule for the production mesh: blocks must divide
# the PER-SHARD extents (i is sharded pod*data = 32 ways, k model = 16)
M = N = K = 4096
bm, bn, bk = choose_matmul_blocks(M // 32, N // 16, K, elem_bytes=2)
sch = matmul_schedule(
    M, N, K, block_m=bm, block_n=bn, block_k=bk,
    data_shard=16, model_shard=16, pod_shard=2,
)
print(f"\nTPU schedule for {M}x{N}x{K} on the 2x16x16 mesh:")
for lvl in sch.levels:
    print(f"  {lvl.tier:12s} {lvl.index:6s} extent={lvl.extent}")
print("subdiv chain:", sch.spec.split_chain())

# ...and the generated kernel for the winner, via the persistent cache:
# a second run of this script (or any process on the same host) gets the
# schedule back without re-tuning.
import jax.numpy as jnp

from repro import codegen

tuned_sched = codegen.tune_schedule(spec, dtype=np.float32)
kern = codegen.compile(spec, tuned_sched, interpret=True)
out = np.asarray(kern(jnp.asarray(arrays["A"], jnp.float32),
                      jnp.asarray(arrays["B"], jnp.float32)))
err = np.abs(out - arrays["A"] @ arrays["B"]).max()
cache = codegen.default_cache()
print(f"\ngenerated kernel for the tuned schedule: max_err={err:.2e}")
print(f"autotune cache {cache.path}: {cache.hits} hit(s), "
      f"{cache.misses} miss(es) this run")
