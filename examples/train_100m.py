"""End-to-end training driver: a ~100M-param qwen3-family model on the
synthetic pipeline, with checkpointing + restart.

The full preset (~100M params, 300 steps) is sized for a real accelerator;
on this CPU container use --preset tiny (~10M params) to watch the loss
fall in a few minutes.

  PYTHONPATH=src python examples/train_100m.py --preset tiny --steps 60
  PYTHONPATH=src python examples/train_100m.py --preset full --steps 300
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.train import TrainRun, train
from repro.optim import AdamWConfig

PRESETS = {
    # (layers, d_model, heads, kv, d_ff, vocab, seq, batch)
    "tiny": (4, 256, 4, 2, 1024, 4096, 128, 8),     # ~10M params
    "small": (8, 512, 8, 4, 2048, 8192, 256, 8),    # ~40M params
    "full": (12, 768, 12, 4, 3072, 32_768, 512, 16),  # ~110M params
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    L, D, H, KV, F, V, S, B = PRESETS[args.preset]
    cfg = dataclasses.replace(
        get_config("qwen3-8b"),
        arch_id=f"qwen3-{args.preset}",
        n_layers=L, d_model=D, n_heads=H, n_kv_heads=KV,
        head_dim=D // H, d_ff=F, vocab=V, dtype="float32",
    )
    run = TrainRun(
        cfg=cfg,
        opt_cfg=AdamWConfig(lr=1e-3, weight_decay=0.01),
        data_cfg=DataConfig(vocab=V, seq_len=S, global_batch=B),
        steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=max(10, args.steps // 5),
    )
    _, losses, report = train(run)
    import numpy as np

    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"\nloss: {first:.3f} -> {last:.3f} over {len(losses)} steps")
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
