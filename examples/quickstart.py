"""Quickstart: the paper's pipeline in 60 lines.

  1. write a computation with the HoF DSL (map / nzip / rnz),
  2. fuse it with the rewrite rules (no temporaries),
  3. enumerate loop-order variants (SJT) and rank them with the cost model,
  4. lower the winner to JAX.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import expr as E
from repro.core.expr import MapN, Prim, RNZ, lam, v, zip2
from repro.core.interp import run
from repro.core.rewrite import Trace, fuse
from repro.core.lower import jax_run
from repro.core.enumerate import matmul_spec, variant_orders
from repro.core.cost import rank_variants

# --- 1. the motivating example, paper eq 1:  w = (A + B)(v + u) -------------
expr = MapN(
    lam(
        ("rA", "rB"),
        RNZ(
            Prim("+"), Prim("id"),
            (zip2(
                Prim("*"),
                zip2(Prim("+"), v("rA"), v("rB")),   # row of A+B
                zip2(Prim("+"), v("vv"), v("u")),    # v+u
            ),),
        ),
    ),
    (v("A"), v("B")),
)
print("unfused:", expr)

# --- 2. fuse: zips fold into the rnz zipper (eqs 24-28) ----------------------
trace = Trace()
fused = fuse(expr, trace=trace)
print("\nfused:  ", fused)
print("rules applied:", trace)

rng = np.random.default_rng(0)
A, B = rng.standard_normal((4, 6)), rng.standard_normal((4, 6))
vv, u = rng.standard_normal(6), rng.standard_normal(6)
want = (A + B) @ (vv + u)
assert np.allclose(run(fused, A=A, B=B, vv=vv, u=u), want)
assert np.allclose(np.asarray(jax_run(fused, A=A, B=B, vv=vv, u=u)), want,
                   atol=1e-4)
print("\nsemantics preserved (numpy interp + JAX lowering agree)")

# --- 3. enumerate matmul variants and rank with the cost model ---------------
spec = matmul_spec(1024, 1024, 1024).subdivide("j", 16)
ranked = rank_variants(spec, variant_orders(spec))
print("\nmatmul variants (rnz subdivided, paper Table 2), cheapest first:")
for cost, order in ranked[:4]:
    print(f"  cost={cost:12.3g}  nest={'/'.join(order)}")
print("  ...")
for cost, order in ranked[-2:]:
    print(f"  cost={cost:12.3g}  nest={'/'.join(order)}")
