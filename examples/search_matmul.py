"""Search a matmul end to end: rewrite rules -> ranked, measured kernels.

Where ``examples/autotune_gemm.py`` shows the pieces (enumeration, the
analytic early-cut, block tuning), this drives the closed loop the paper
describes through ``repro.search``:

  1. the SJT walk + per-tier subdivision choices span the candidate space,
  2. the roofline cost model prunes it (sound bound cut + beam trim),
  3. the survivors are lowered through ``repro.codegen`` and *measured*,
  4. the ranked ladder is persisted, and ``ops.dense`` serves the winner.

Run:  PYTHONPATH=src python examples/search_matmul.py [N]
"""

import sys
import tempfile

import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.core.enumerate import matmul_spec
from repro.search import PlanDB, reference_arrays, search_schedule

n = int(sys.argv[1]) if len(sys.argv) > 1 else 256
spec = matmul_spec(n, n, n)
db = PlanDB(tempfile.mktemp(suffix="_plans.json"))

print(f"searching {n}x{n}x{n} matmul "
      f"(beam search + interpret-mode measurement)...")
res = search_schedule(
    spec, beam_width=8, topk=4, interpret=True,
    arrays=reference_arrays(spec, seed=0), plan_db=db,
)

s = res.stats
print(f"\nspace: {s.considered} candidates considered, "
      f"{s.deduped} deduped (exchange-rule equivalent), "
      f"{s.pruned_bound} cut by the roofline bound, "
      f"{s.pruned_beam} trimmed by the beam, {s.measured} measured")

print(f"\n{'rank':4s} {'source':8s} {'measured':>10s} {'analytic':>10s}  schedule")
for rank, p in enumerate(res.ranked):
    sched = " ".join(
        f"{l.index}:{l.tier}:{l.extent}" for l in p.schedule.levels
    )
    print(f"#{rank:3d} {p.source:8s} {p.measured_s*1e3:8.2f}ms "
          f"{p.score*1e6:8.2f}us  {sched}")

base = res.baseline()
if base is not None:
    ratio = base.measured_s / res.best.measured_s
    print(f"\nsearched winner is {ratio:.2f}x the default schedule "
          f"(>= 1.0 by construction: the default is in the measured set)")

# the plan round-trips: ops.dense asks the plan DB before the tuner
import os

os.environ["REPRO_PLAN_DB"] = db.path
x = jnp.asarray(np.random.default_rng(0).standard_normal((n, n)), jnp.float32)
w = jnp.asarray(np.random.default_rng(1).standard_normal((n, n)), jnp.float32)
out = ops.dense(x, w, interpret=True)
err = np.abs(np.asarray(out) - np.asarray(x) @ np.asarray(w)).max()
print(f"ops.dense through the searched plan: max_err={err:.2e} "
      f"(plan db {db.path})")
