"""Batched serving example: prefill + decode with KV caches on a smoke-scale
qwen3, measuring decode throughput.

  PYTHONPATH=src python examples/serve_batch.py --requests 8 --max-new 24
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.launch.serve import BatchServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config("qwen3-8b").smoke()
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(
                np.int32
            ),
            max_new=args.max_new,
        )
        for i in range(args.requests)
    ]
    server = BatchServer(
        cfg,
        batch_size=args.requests,
        max_len=args.prompt_len + args.max_new + 1,
    )
    stats = server.run(reqs)
    print(
        f"prefill {stats['prefill_s']*1e3:.1f} ms | "
        f"{stats['tokens']} tokens | {stats['tok_per_s']:.1f} tok/s"
    )
    for r in reqs[:2]:
        print(f"req {r.rid}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
