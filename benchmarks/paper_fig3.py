"""Paper Fig 3: the six rearrangements of the subdivided matrix-vector
product (1a/1b/1c subdivide the vector; 2a/2b/2c subdivide the map)."""

import numpy as np

from repro.core.cost import cpu_cost
from repro.core.enumerate import paper_fig3_variants
from repro.core.execute import execute_variant

from .common import emit, timeit


def run(n: int = 1024, b: int = 64):
    rng = np.random.default_rng(2)
    A = rng.standard_normal((n, n))
    u = rng.standard_normal(n)
    ref = A @ u
    for label, order, spec in paper_fig3_variants(n, n, b):
        out = execute_variant(spec, order, {"A": A, "u": u})
        assert np.allclose(out, ref, rtol=1e-8), label
        t = timeit(lambda o=order, s=spec: execute_variant(s, o, {"A": A, "u": u}))
        emit(f"fig3.{label}", t, f"model_cost={cpu_cost(spec, order):.3g}")


if __name__ == "__main__":
    run()
