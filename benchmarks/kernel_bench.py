"""Kernel benches: block-shape sweep for the Pallas matmul.

No TPU in this container, so wall-clock is the interpret-mode *correctness*
path only; the reported ``derived`` column is the analytic HBM-traffic model
(core.autotune napkin math) that ranks block shapes for the real chip —
this is the §Perf lever for the kernel level.
"""

import jax.numpy as jnp
import numpy as np

from repro.core.autotune import choose_matmul_blocks
from repro.core.cost import TPU
from repro.kernels.matmul.matmul import matmul_pallas
from repro.kernels.matmul.ref import matmul_ref

from .common import emit, timeit


def traffic(m, n, k, bm, bn, bk):
    return m * k * (n / bn) + k * n * (m / bm) + m * n


def run():
    m = n = k = 4096
    cands = [
        (128, 128, 512), (256, 256, 512), (512, 512, 512),
        (512, 1024, 512), (1024, 512, 512), (256, 512, 1024),
    ]
    budget = TPU["vmem_bytes"] // 2 // 2
    for bm, bn, bk in cands:
        fits = (bm * bk + bk * bn + bm * bn) <= budget
        tr = traffic(m, n, k, bm, bn, bk)
        hbm_s = tr * 2 / TPU["hbm_bw"]
        emit(
            f"kernel.matmul.b{bm}x{bn}x{bk}", hbm_s,
            f"hbm_bytes={tr*2:.3g};fits_vmem={fits}",
        )
    best = choose_matmul_blocks(m, n, k, elem_bytes=2)
    emit("kernel.matmul.autotuned", 0.0, f"blocks={best}")

    # interpret-mode correctness spot-check at a scaled-down shape
    a = jnp.asarray(np.random.default_rng(0).standard_normal((128, 128)),
                    jnp.float32)
    b = jnp.asarray(np.random.default_rng(1).standard_normal((128, 128)),
                    jnp.float32)
    t = timeit(
        lambda: np.asarray(
            matmul_pallas(a, b, block_m=64, block_n=64, block_k=64,
                          interpret=True)
        ),
        repeats=1,
    )
    err = np.abs(
        np.asarray(
            matmul_pallas(a, b, block_m=64, block_n=64, block_k=64,
                          interpret=True)
        ) - np.asarray(matmul_ref(a, b))
    ).max()
    emit("kernel.matmul.interpret_check", t, f"max_err={err:.2e}")


if __name__ == "__main__":
    run()
