"""Kernel benches: block-shape sweep + generated-kernel + search scenarios.

No TPU in this container, so wall-clock is the interpret-mode *correctness*
path only; the reported ``derived`` column is the analytic HBM-traffic model
(core.autotune napkin math) that ranks block shapes for the real chip —
this is the §Perf lever for the kernel level.

The ``gen.*`` rows go through ``repro.codegen``: the schedule-driven
generator compiling plain / batched / chained / transposed contractions
(none of which had kernels before the generator existed), checked against
the hand-written baseline and jnp references.  The ``search.*`` rows run
the full ``repro.search`` pipeline (enumerate -> prune -> measure) and
report how much of the variant space the analytic early-cut removed before
measurement.  The ``grad.*`` rows exercise the training half
(``repro.grad``): forward + backward through the custom_vjp ops, the
epilogue-aware dense_act backward, and the backward GEMMs picking up
searched plans under their derived-spec keys.  The ``capture.*`` rows
cover whole-model capture (``repro.capture``): per demo config, sites
harvested/dispatched/fallback, plus the jitted captured-vs-uncaptured
step-time ratio (the no-op safety bar).  ``--smoke`` (or
``run(smoke=True)``) keeps shapes tiny for CI.

Rows that do arithmetic carry ``flops=`` in the derived column so
``scripts/bench_smoke.py`` can report GFLOP/s in ``BENCH_pr3.json``.

Bench sections are individually guarded: a failing row emits
``error=<type>:<msg>`` in its derived column instead of killing the run,
and ``scripts/bench_smoke.py`` turns any such row into a non-zero exit.
"""

import argparse
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.autotune import choose_matmul_blocks
from repro.core.cost import TPU
from repro.core.enumerate import matmul_spec
from repro.kernels.matmul.matmul import matmul_pallas
from repro.kernels.matmul.ref import matmul_ref

from .common import emit, timeit


def guarded(name):
    """Run a bench section; an exception becomes an ``error=`` row."""

    def deco(fn):
        def wrapper(*a, **k):
            try:
                fn(*a, **k)
            except Exception as e:  # noqa: BLE001 — bench must keep going
                msg = str(e).replace(",", ";").replace("\n", " ")[:120]
                emit(name, 0.0, f"error={type(e).__name__}:{msg}")
        return wrapper

    return deco


def traffic(m, n, k, bm, bn, bk):
    return m * k * (n / bn) + k * n * (m / bm) + m * n


def _rnd(*shape, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(shape), jnp.float32
    )


@guarded("search.matmul")
def _bench_search(smoke: bool):
    """The search pipeline end to end: candidates -> prune -> measure.

    Reports (a) the winner's measured time with the space statistics and
    (b) the winner vs the un-searched ``default_schedule`` — both timed in
    the *same* measurement pass, and the default is always in the measured
    set, so ``not_slower`` holds by construction (the ISSUE-2 acceptance
    bar) rather than by luck of the clock.
    """
    from repro.search import einsum_reference, reference_arrays, search_schedule

    s = 2 if smoke else 1
    m = k = n = 128 // s
    spec = matmul_spec(m, k, n)
    arrays = reference_arrays(spec, seed=42)
    res = search_schedule(
        spec, beam_width=6, topk=3, interpret=True,
        measure=True, arrays=arrays, plan_db=None,
    )
    st = res.stats
    win = res.best
    emit(
        "search.matmul", win.measured_s,
        f"max_err={win.max_err:.2e};candidates={st.considered};"
        f"pruned={st.pruned_bound + st.pruned_beam};measured={st.measured}",
    )
    base = res.baseline()
    if base is None or base.measured_s is None:
        raise RuntimeError("default_schedule missing from measured set")
    emit(
        "search.vs_default", base.measured_s,
        f"not_slower={win.measured_s <= base.measured_s};"
        f"winner_s={win.measured_s:.3g};default_s={base.measured_s:.3g}",
    )


@guarded("kernel.gen")
def _bench_generated(smoke: bool):
    """Generated kernels vs references, interpret mode (CPU container)."""
    from repro import codegen

    s = 2 if smoke else 1
    m, k, n = 128 // s, 128 // s, 128 // s
    a, b = _rnd(m, k, seed=0), _rnd(k, n, seed=1)

    spec = matmul_spec(m, k, n)
    sched = codegen.default_schedule(spec, {"i": 32, "k": 32, "j": 32})
    kern = codegen.compile(spec, sched, interpret=True)
    t = timeit(lambda: np.asarray(kern(a, b)), repeats=1)
    err = np.abs(np.asarray(kern(a, b)) - np.asarray(matmul_ref(a, b))).max()
    emit("kernel.gen.matmul", t, f"max_err={err:.2e};flops={2*m*k*n}")

    base = np.abs(
        np.asarray(
            matmul_pallas(a, b, block_m=32, block_n=32, block_k=32,
                          interpret=True)
        ) - np.asarray(kern(a, b))
    ).max()
    emit("kernel.gen.vs_handwritten", 0.0, f"max_err={base:.2e}")

    bsz = 2 if smoke else 4
    sb = codegen.batched_matmul_schedule(
        bsz, m // 2, k // 2, n // 2, block_m=16, block_n=16, block_k=16
    )
    ab = _rnd(bsz, m // 2, k // 2, seed=2)
    bb = _rnd(bsz, k // 2, n // 2, seed=3)
    kb = codegen.compile(sb.spec, sb, interpret=True)
    t = timeit(lambda: np.asarray(kb(ab, bb)), repeats=1)
    err = np.abs(
        np.asarray(kb(ab, bb))
        - np.einsum("bij,bjk->bik", np.asarray(ab), np.asarray(bb))
    ).max()
    emit("kernel.gen.batched", t,
         f"max_err={err:.2e};flops={2*bsz*(m//2)*(k//2)*(n//2)}")

    sc = codegen.chain_matmul_schedule(
        m // 2, k // 2, k // 2, n // 2,
        block_m=16, block_n=16, block_k1=16, block_k2=16,
    )
    ac, bc = _rnd(m // 2, k // 2, seed=4), _rnd(k // 2, k // 2, seed=5)
    cc = _rnd(k // 2, n // 2, seed=6)
    kc = codegen.compile(sc.spec, sc, interpret=True)
    t = timeit(lambda: np.asarray(kc(ac, bc, cc)), repeats=1)
    err = np.abs(
        np.asarray(kc(ac, bc, cc))
        - np.einsum("ij,jk,kl->il", *(np.asarray(x) for x in (ac, bc, cc)))
    ).max()
    chain_flops = 2 * (m // 2) * (k // 2) * (k // 2 + n // 2)
    emit("kernel.gen.chain", t, f"max_err={err:.2e};flops={chain_flops}")

    st = codegen.transposed_matmul_schedule(
        m // 2, k // 2, n // 2, block_m=16, block_n=16, block_k=16
    )
    at = _rnd(k // 2, m // 2, seed=7)
    bt = _rnd(k // 2, n // 2, seed=8)
    kt = codegen.compile(st.spec, st, interpret=True)
    t = timeit(lambda: np.asarray(kt(at, bt)), repeats=1)
    err = np.abs(
        np.asarray(kt(at, bt))
        - np.einsum("ji,jk->ik", np.asarray(at), np.asarray(bt))
    ).max()
    emit("kernel.gen.transposed", t,
         f"max_err={err:.2e};flops={2*(m//2)*(k//2)*(n//2)}")


@guarded("grad.dense")
def _bench_grad_dense(smoke: bool):
    """Training fwd+bwd through ops.dense's custom_vjp (repro.grad).

    The backward GEMMs are the derived ``matmul.dA``/``matmul.dB`` specs
    compiled through the same generated-kernel pipeline as the forward —
    128-aligned extents so dense's kernel dispatch fires in interpret mode.
    """
    import jax

    from repro import ops

    m = k = n = 128
    x, w = _rnd(m, k, seed=20), _rnd(k, n, seed=21)
    flops = 2 * m * k * n

    t_f = timeit(lambda: np.asarray(ops.dense(x, w, interpret=True)),
                 repeats=1)
    err_f = np.abs(
        np.asarray(ops.dense(x, w, interpret=True))
        - np.asarray(matmul_ref(x, w))
    ).max()
    emit("grad.dense.fwd", t_f, f"max_err={err_f:.2e};flops={flops}")

    grad_fn = jax.grad(
        lambda x_, w_: jnp.sum(ops.dense(x_, w_, interpret=True)),
        argnums=(0, 1),
    )
    t_b = timeit(
        lambda: [np.asarray(v) for v in grad_fn(x, w)], repeats=1
    )
    gx, gw = grad_fn(x, w)
    ones = np.ones((m, n), np.float32)
    err_b = max(
        np.abs(np.asarray(gx) - ones @ np.asarray(w).T).max(),
        np.abs(np.asarray(gw) - np.asarray(x).T @ ones).max(),
    )
    # grad_fn runs fwd + dA + dB: three GEMMs' worth of work
    emit("grad.dense.bwd", t_b, f"max_err={err_b:.2e};flops={3*flops}")


@guarded("grad.dense_act")
def _bench_grad_dense_act(smoke: bool):
    """Epilogue backward: recompute-acc GEMM + elementwise VJP + dA/dB."""
    import jax

    from repro import ops
    from repro.kernels.fused_dense_act.ref import fused_dense_act_ref

    m = d = f = 32 if smoke else 64
    x, w = _rnd(m, d, seed=22), _rnd(d, f, seed=23)
    beta, mean = _rnd(f, seed=24), _rnd(f, seed=25) * 0.1
    var = jnp.abs(_rnd(f, seed=26)) + 0.5

    grad_fn = jax.grad(
        lambda *a: jnp.sum(ops.dense_act(*a, interpret=True)),
        argnums=(0, 1, 2),
    )
    ref_fn = jax.grad(
        lambda *a: jnp.sum(fused_dense_act_ref(*a)), argnums=(0, 1, 2)
    )
    t = timeit(
        lambda: [np.asarray(v) for v in grad_fn(x, w, beta, mean, var)],
        repeats=1,
    )
    err = max(
        np.abs(np.asarray(a) - np.asarray(b)).max()
        for a, b in zip(grad_fn(x, w, beta, mean, var),
                        ref_fn(x, w, beta, mean, var))
    )
    # 4 GEMMs: primal fwd + accumulator recompute + dA + dB
    emit("grad.dense_act.bwd", t, f"max_err={err:.2e};flops={4*2*m*d*f}")


@guarded("grad.plandb")
def _bench_grad_plandb(smoke: bool):
    """Backward GEMMs picking up *searched* plans by derived-spec key.

    Sweeps fwd+dA+dB into a private plan DB (search_schedule_with_grads),
    then runs jax.grad through ops.dense and reports how many plan-DB
    lookups the tape hit — the ISSUE-3 acceptance bar, as a bench row.
    """
    import tempfile

    import jax

    from repro import ops
    from repro.grad import derived_specs
    from repro.search import default_plan_db, search_schedule_with_grads

    m = k = n = 128
    tmp = tempfile.mkdtemp(prefix="repro-grad-bench-")
    saved = {
        v: os.environ.get(v)
        for v in ("REPRO_PLAN_DB", "REPRO_AUTOTUNE_CACHE")
    }
    os.environ["REPRO_PLAN_DB"] = os.path.join(tmp, "plans.json")
    os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(tmp, "autotune.json")
    try:
        spec = matmul_spec(m, k, n)
        db = default_plan_db()
        t0 = time.perf_counter()
        res = search_schedule_with_grads(
            spec, dtype=np.float32, beam_width=4, topk=2,
            interpret=True, repeats=1, plan_db=db,
        )
        sweep_s = time.perf_counter() - t0
        keys_ok = all(
            db.best_schedule(s, np.float32) is not None
            for s in (spec, *derived_specs(spec).values())
        )
        hits0 = db.lookup_hits
        x, w = _rnd(m, k, seed=27), _rnd(k, n, seed=28)
        gx, gw = jax.grad(
            lambda a, b: jnp.sum(ops.dense(a, b, interpret=True)),
            argnums=(0, 1),
        )(x, w)
        hits = db.lookup_hits - hits0
        ones = np.ones((m, n), np.float32)
        err = max(
            np.abs(np.asarray(gx) - ones @ np.asarray(w).T).max(),
            np.abs(np.asarray(gw) - np.asarray(x).T @ ones).max(),
        )
        ok = keys_ok and hits >= 3 and err < 1e-3
        emit(
            "grad.plandb", sweep_s,
            f"ok={ok};plans={len(res)};db_hits={hits};max_err={err:.2e}",
        )
    finally:
        for v, val in saved.items():
            if val is None:
                os.environ.pop(v, None)
            else:
                os.environ[v] = val
        import shutil

        shutil.rmtree(tmp, ignore_errors=True)


def _mesh_shape_for_devices(n: int):
    """Largest conventional (data, model) mesh the process can host."""
    if n >= 8:
        return (2, 4)
    if n >= 4:
        return (2, 2)
    if n >= 2:
        return (1, 2)
    return None


@guarded("mesh.search")
def _bench_mesh_search(smoke: bool):
    """The mesh (distributed) tier of the search, end to end.

    Runs ``search_schedule`` with an active mesh shape over the forced
    device mesh (the mesh-smoke CI job forces 8 CPU devices via
    ``--xla_force_host_platform_device_count``), then reports:

      * ``mesh.search``  — the sharded winner: measured over the real
        mesh via ``codegen.bind_mesh``, differentially checked against
        the einsum oracle in the same pass; ``ok`` requires a ``mesh:*``
        plan in the ladder, measured, with a mesh-qualified DB key.
      * ``mesh.vs_psum`` — searched-sharded vs the naive plain-psum
        lowering of the same subdivision.  The naive baseline is part of
        the measured set (``search_schedule``'s mesh-naive entry), so
        ``not_slower`` holds by construction on this harness.

    Sections emit nothing when the process has fewer than 2 devices —
    the plain bench-smoke job runs single-device and only the mesh-smoke
    job (``scripts/bench_smoke.py --mesh``) gates on these rows.
    """
    import tempfile

    import jax

    from repro.search import PlanDB, reference_arrays, search_schedule

    shape = _mesh_shape_for_devices(jax.device_count())
    if shape is None:
        return
    import shutil

    s = 2 if smoke else 1
    m = k = n = 128 // s
    spec = matmul_spec(m, k, n)
    arrays = reference_arrays(spec, seed=7)
    tmp = tempfile.mkdtemp(prefix="repro-mesh-bench-")
    try:
        db = PlanDB(os.path.join(tmp, "plans.json"))
        res = search_schedule(
            spec, beam_width=6, topk=3, interpret=True, measure=True,
            arrays=arrays, plan_db=db, mesh_shape=shape,
        )
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    st = res.stats
    win = res.best_sharded()
    measured = win is not None and win.measured_s is not None
    ok = (
        measured
        and res.db_key is not None
        and res.mesh == "x".join(map(str, shape))
    )
    err = win.max_err if win is not None else float("nan")
    emit(
        "mesh.search",
        win.measured_s if measured else 0.0,
        f"ok={ok};mesh={res.mesh};max_err={err:.2e};"
        f"candidates={st.considered};mesh_variants={st.mesh_variants};"
        f"pruned={st.pruned_bound + st.pruned_beam};"
        f"measured={st.measured};flops={spec.flops()}",
    )
    naive = res.mesh_baseline()
    if naive is None or naive.measured_s is None or not measured:
        # report the failure as a row rather than crash the section: the
        # --mesh gate fails on ok=False with this diagnostic attached
        emit(
            "mesh.vs_psum", 0.0,
            f"ok=False;not_slower=False;"
            f"sharded_measured={measured};"
            f"naive_measured={naive is not None and naive.measured_s is not None}",
        )
        return
    emit(
        "mesh.vs_psum", naive.measured_s,
        f"ok=True;"
        f"not_slower={win.measured_s <= naive.measured_s};"
        f"sharded_s={win.measured_s:.3g};naive_s={naive.measured_s:.3g}",
    )


@guarded("mesh.ring")
def _bench_mesh_ring(smoke: bool):
    """Ring (ppermute) all-reduce vs lax.psum: equality + relative cost.

    The ring strategy is what a searched plan with ``collective=ring``
    lowers to (``codegen.collectives.ring_psum``); the row pins its
    differential correctness against psum on an odd-sized payload (the
    remainder-shard path) over the largest hostable device ring.
    """
    import jax
    from jax import lax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.codegen.collectives import ring_psum
    from repro.launch.mesh import make_debug_mesh

    p = min(jax.device_count(), 8)
    if p < 2:
        return
    mesh = make_debug_mesh((p,), ("data",))
    rows = 3 if smoke else 5  # odd payload: exercises the padded shard
    x = _rnd(p, rows, 33, seed=11)

    def run_with(fn):
        f = shard_map(
            lambda xs: fn(xs[0]), mesh=mesh,
            in_specs=P("data"), out_specs=P(), check_rep=False,
        )
        return f(x)

    ring_s = timeit(lambda: np.asarray(run_with(
        lambda v: ring_psum(v, "data"))), repeats=2)
    psum_s = timeit(lambda: np.asarray(run_with(
        lambda v: lax.psum(v, "data"))), repeats=2)
    got = np.asarray(run_with(lambda v: ring_psum(v, "data")))
    want = np.asarray(run_with(lambda v: lax.psum(v, "data")))
    err = np.abs(got - want).max() / max(np.abs(want).max(), 1e-30)
    emit(
        "mesh.ring", ring_s,
        f"ok={err < 1e-5};max_err={err:.2e};shards={p};"
        f"psum_s={psum_s:.3g}",
    )


@guarded("capture.sites")
def _bench_capture_sites(smoke: bool):
    """Whole-model capture accounting per demo config (repro.capture).

    Abstract harvest (ShapeDtypeStruct trace — no params allocated, no
    kernels run): one row per config counting dot_general sites
    harvested / dispatched / fallback, the ISSUE-4 acceptance counters.
    The reported time is the trace+harvest cost itself.
    """
    import time as _time

    from repro import capture

    for name, cfg in sorted(capture.demo_configs().items()):
        t0 = _time.perf_counter()
        _, rep = capture.model_capture(
            cfg, batch=capture.DEMO_BATCH, seq=capture.DEMO_SEQ,
            kind="train", interpret=True,
        )
        t = _time.perf_counter() - t0
        emit(
            f"capture.sites.{name}", t,
            f"harvested={rep.harvested};dispatched={rep.dispatched};"
            f"fallback={rep.fallback}",
        )


@guarded("capture.step")
def _bench_capture_step(smoke: bool):
    """End-to-end jitted train-loss step: captured vs uncaptured.

    interpret=False on CPU means every site falls back, so the two jitted
    programs are semantically identical — the row measures the capture
    replay's compile-through overhead, which must stay ~1x (the no-op
    safety bar for turning ``--capture`` on in production).  Dispatch
    counters live in the ``capture.sites.*`` rows above.
    """
    import jax

    from repro import capture
    from repro.models.api import get_api

    cfg = capture.demo_configs()["dense"]
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.key(0))
    B, S = capture.DEMO_BATCH, capture.DEMO_SEQ
    toks = jnp.zeros((B, S), jnp.int32)
    batch = {"tokens": toks, "labels": toks}

    def loss(p, b):
        return api.loss(p, cfg, b)

    captured = capture.optimize(loss, interpret=False)
    base_fn = jax.jit(loss)
    cap_fn = jax.jit(captured)
    base_s = timeit(lambda: float(base_fn(params, batch)), repeats=2)
    cap_s = timeit(lambda: float(cap_fn(params, batch)), repeats=2)
    err = abs(
        float(cap_fn(params, batch)) - float(base_fn(params, batch))
    )
    emit(
        "capture.step", cap_s,
        f"max_err={err:.2e};baseline_s={base_s:.3g};"
        f"ratio={cap_s / max(base_s, 1e-12):.3g}",
    )


@guarded("obs.overhead")
def _bench_obs(smoke: bool):
    """Observability overhead gate: obs-on vs obs-off on a hot kernel call.

    Times the same memoized ``ops.dense`` dispatch (the serving hot path:
    plan-DB consult + kernel-memo lookup + generated kernel) with
    ``REPRO_OBS`` off and on.  The instrumentation on that path is a few
    env reads and counter increments, so the min-over-repeats ratio must
    stay <= 1.02 — ``scripts/bench_smoke.py`` gates on it, which is what
    keeps obs safe to leave on by default in production.
    """
    from repro import ops

    n = 128
    x, w = _rnd(n, n, seed=0), _rnd(n, n, seed=1)

    def call():
        return np.asarray(ops.dense(x, w, interpret=True))

    call()  # tune + compile once: both arms time the memoized path

    prev = os.environ.get("REPRO_OBS")
    try:
        os.environ["REPRO_OBS"] = "0"
        off_s = timeit(call, repeats=5, warmup=1)
        os.environ["REPRO_OBS"] = "1"
        on_s = timeit(call, repeats=5, warmup=1)
    finally:
        if prev is None:
            os.environ.pop("REPRO_OBS", None)
        else:
            os.environ["REPRO_OBS"] = prev
    ratio = on_s / max(off_s, 1e-12)
    emit(
        "obs.overhead", on_s,
        f"baseline_s={off_s:.3g};ratio={ratio:.3g};flops={2 * n**3}",
    )


@guarded("attn.fused")
def _bench_attn_fused(smoke: bool):
    """Fused flash-attention kernel vs the unfused program it replaces.

    The baseline is exactly what capture would otherwise leave in the
    jaxpr: TWO generated interpret-mode GEMM kernels (QK^T and P·V,
    compiled through the same ``codegen`` pipeline) around a jnp softmax.
    Wall-clock here is interpret-mode correctness only (header note) — a
    Python-interpreted softmax inside the fused kernel can never beat an
    XLA-compiled one outside it — so ``not_slower`` is the same analytic
    HBM-traffic claim the ``kernel.matmul.b*`` rows make: the fused
    kernel reads Q/K/V and writes O once, while the unfused program
    additionally round-trips the (h,s,t) score AND probability tensors
    through HBM.  Fused bytes < unfused bytes for every shape, so the
    gate holds by construction and is the statement that matters on the
    real chip; both interpret times are reported alongside for the
    correctness record.
    """
    from repro import codegen, ops
    from repro.core.enumerate import ContractionSpec, attention_spec
    from repro.search import einsum_reference

    s_ = 2 if smoke else 1
    h, s, t, d = 4, 128 // s_, 128 // s_, 8
    spec = attention_spec(h, s, t, d)
    q, k, v = (_rnd(h, n, d, seed=30 + i)
               for i, n in enumerate((s, t, t)))

    def fused():
        return np.asarray(ops.attention(q, k, v, interpret=True,
                                        differentiable=False))

    qk = ContractionSpec(
        name="qk", operands={"Q": ("h", "s", "d"), "K": ("h", "t", "d")},
        output=("h", "s", "t"), extents={"h": h, "s": s, "t": t, "d": d},
    )
    pv = ContractionSpec(
        name="pv", operands={"P": ("h", "s", "t"), "V": ("h", "t", "e")},
        output=("h", "s", "e"), extents={"h": h, "s": s, "t": t, "e": d},
    )
    k1 = codegen.compile(qk, codegen.default_schedule(qk), interpret=True)
    k2 = codegen.compile(pv, codegen.default_schedule(pv), interpret=True)
    import jax

    @jax.jit
    def _softmax(sc):
        return jax.nn.softmax(sc * d ** -0.5, axis=-1)

    def unfused():
        p = _softmax(k1(q, k))
        return np.asarray(k2(p, v))

    fused_s = timeit(fused, repeats=3, warmup=1)
    base_s = timeit(unfused, repeats=3, warmup=1)
    ref = einsum_reference(spec, {"Q": np.asarray(q), "K": np.asarray(k),
                                  "V": np.asarray(v)})
    err = max(
        np.abs(fused() - ref).max(), np.abs(unfused() - ref).max()
    )
    # analytic HBM roofline (f32): fused streams operands + output once;
    # unfused also writes then re-reads scores and probabilities
    io = h * (s * d + t * d + t * d + s * d)
    scores = h * s * t
    fused_hbm_s = io * 4 / TPU["hbm_bw"]
    base_hbm_s = (io + 4 * scores) * 4 / TPU["hbm_bw"]
    emit(
        "attn.fused", fused_s,
        f"not_slower={fused_hbm_s <= base_hbm_s};max_err={err:.2e};"
        f"hbm_s={fused_hbm_s:.3g};baseline_hbm_s={base_hbm_s:.3g};"
        f"interpret_baseline_s={base_s:.3g};flops={spec.flops()}",
    )


@guarded("moe.grouped")
def _bench_moe_grouped(smoke: bool):
    """Ragged grouped GEMM: one group-offset dispatch vs G separate dots.

    The baseline is the semantic definition (per-group dot loop, one
    dispatch per non-empty group); the row's gate is correctness (ok= +
    max_err) on a genuinely ragged partition with an empty group, not a
    speed claim — interpret mode cannot see the dispatch-count win.
    """
    from jax import lax

    from repro import ops
    from repro.core.enumerate import grouped_matmul_spec

    s_ = 2 if smoke else 1
    k_, f = 64 // s_, 64 // s_
    sizes = (24 // s_, 0, 40 // s_, 8 // s_)
    n = sum(sizes)
    spec = grouped_matmul_spec(sizes, k_, f)
    x = _rnd(n, k_, seed=40)
    w = _rnd(len(sizes), k_, f, seed=41)

    def grouped():
        return np.asarray(ops.grouped_dense(x, w, sizes, interpret=True,
                                            differentiable=False))

    def loop():
        parts, off = [], 0
        for g, sz in enumerate(sizes):
            if sz:
                parts.append(lax.dot_general(
                    x[off:off + sz], w[g], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ))
            off += sz
        return np.asarray(jnp.concatenate(parts, axis=0))

    t_g = timeit(grouped, repeats=3, warmup=1)
    t_l = timeit(loop, repeats=3, warmup=1)
    err = np.abs(grouped().astype(np.float64) - loop()).max()
    ok = err < 1e-3
    emit(
        "moe.grouped", t_g,
        f"ok={ok};max_err={err:.2e};loop_s={t_l:.3g};"
        f"groups={len(sizes)};flops={spec.flops()}",
    )


@guarded("quant.gemm")
def _bench_quant(smoke: bool):
    """Searched int8/fp8 quant tier vs the bf16 baseline at matched shapes.

    Wall-clock here is interpret-mode correctness only (header note) — a
    CPU interpreter cannot see the bandwidth win of 1-byte operands — so
    the rows' reported seconds are the analytic one-pass HBM floor
    (``roofline.analysis.quant_hbm_bytes`` / TPU hbm_bw), exactly like
    the ``kernel.matmul.b*`` rows.  With the same ``flops=`` on every
    row, ``BENCH_quant.json`` then reports analytic GFLOP/s, and the
    ISSUE-10 gate "quant GFLOP/s >= bf16 at matched shapes" is the
    ``not_slower`` byte claim: quantized operands stream at 1 B/elem vs
    bf16's 2, and the 4-byte accumulator output cannot eat the saving at
    these shapes.  Correctness is NOT analytic: each quant row runs the
    searched ladder (``search_schedule`` over the quantized spec,
    measure=True) and reports the kernel-vs-dequantized-f64-oracle
    ``max_err`` — exact for int8 (integer products, exact accumulation),
    f32-accumulation-bounded for fp8.  ``quant.dense`` adds the
    end-to-end ``ops.dense(..., quant=)`` path, where dynamic input
    quantization error is charged to the data, hence a relative (not
    max_err) gate.
    """
    from repro import ops
    from repro.core.enumerate import QUANT_FORMATS, quantize_spec
    from repro.roofline.analysis import quant_hbm_bytes
    from repro.search import reference_arrays, search_schedule

    s_ = 2 if smoke else 1
    # reduction-dominant shape: operand traffic (saved by 1-byte storage)
    # must dominate the 4-byte accumulator output, else the byte floors
    # tie exactly — on a cube, 2N²·1 + N²·4 == 2N²·2 + N²·2.  k >> m, n
    # is also the regime the tier serves (weight GEMMs).
    m = n = 128 // s_
    k = 2048 // s_
    base = matmul_spec(m, k, n)
    flops = base.flops()
    # matched-shape bf16 baseline: same one-pass floor at 2 B/elem
    bf16_bytes = quant_hbm_bytes(base, elem_bytes=2)
    bf16_hbm_s = bf16_bytes / TPU["hbm_bw"]
    emit(
        "quant.bf16", bf16_hbm_s,
        f"ok=True;hbm_bytes={bf16_bytes:.0f};flops={flops}",
    )

    for fmt in ("int8", "fp8"):
        spec = quantize_spec(base, fmt=fmt)
        dt = np.dtype(QUANT_FORMATS[fmt].dtype)
        arrays = reference_arrays(spec, dtype=dt, seed=50)
        res = search_schedule(
            spec, dtype=dt, beam_width=4, topk=2, interpret=True,
            measure=True, arrays=arrays, plan_db=None,
        )
        win = res.best
        if win.measured_s is None or win.max_err is None:
            raise RuntimeError(f"quant {fmt} winner was not measured")
        qbytes = quant_hbm_bytes(spec)
        hbm_s = qbytes / TPU["hbm_bw"]
        emit(
            f"quant.{fmt}", hbm_s,
            f"ok=True;not_slower={hbm_s <= bf16_hbm_s};"
            f"max_err={win.max_err:.2e};hbm_bytes={qbytes:.0f};"
            f"bf16_hbm_s={bf16_hbm_s:.3g};"
            f"interpret_s={win.measured_s:.3g};flops={flops}",
        )

    # end-to-end: ops.dense with dynamic input quantization (the capture /
    # serving entry point).  128-aligned so the kernel dispatch fires.
    me = ke = ne = 128
    x, w = _rnd(me, ke, seed=52), _rnd(ke, ne, seed=53)
    ref = np.asarray(ops.dense(x, w, interpret=True), np.float64)
    t_q = timeit(
        lambda: np.asarray(ops.dense(x, w, interpret=True, quant="int8")),
        repeats=1,
    )
    out = np.asarray(
        ops.dense(x, w, interpret=True, quant="int8"), np.float64
    )
    rel = np.abs(out - ref).max() / max(np.abs(ref).max(), 1e-30)
    emit(
        "quant.dense", t_q,
        f"ok={rel < 0.05};rel_err={rel:.2e};flops={2 * me * ke * ne}",
    )


def run_attn(smoke: bool = False):
    """The --attn sections alone (the attn-smoke CI job's bench half)."""
    _bench_attn_fused(smoke)
    _bench_moe_grouped(smoke)


def run_quant(smoke: bool = False):
    """The --quant sections alone (the quant-smoke CI job's bench half)."""
    _bench_quant(smoke)


def run(smoke: bool = False):
    m = n = k = 4096
    cands = [
        (128, 128, 512), (256, 256, 512), (512, 512, 512),
        (512, 1024, 512), (1024, 512, 512), (256, 512, 1024),
    ]
    budget = TPU["vmem_bytes"] // 2 // 2
    for bm, bn, bk in cands:
        fits = (bm * bk + bk * bn + bm * bn) <= budget
        tr = traffic(m, n, k, bm, bn, bk)
        hbm_s = tr * 2 / TPU["hbm_bw"]
        emit(
            f"kernel.matmul.b{bm}x{bn}x{bk}", hbm_s,
            f"hbm_bytes={tr*2:.3g};fits_vmem={fits}",
        )
    best = choose_matmul_blocks(m, n, k, elem_bytes=2)
    emit("kernel.matmul.autotuned", 0.0, f"blocks={best}")

    # interpret-mode correctness spot-check at a scaled-down shape
    a = _rnd(128, 128, seed=0)
    b = _rnd(128, 128, seed=1)
    t = timeit(
        lambda: np.asarray(
            matmul_pallas(a, b, block_m=64, block_n=64, block_k=64,
                          interpret=True)
        ),
        repeats=1,
    )
    err = np.abs(
        np.asarray(
            matmul_pallas(a, b, block_m=64, block_n=64, block_k=64,
                          interpret=True)
        ) - np.asarray(matmul_ref(a, b))
    ).max()
    emit("kernel.matmul.interpret_check", t, f"max_err={err:.2e}")

    _bench_generated(smoke)
    _bench_search(smoke)
    _bench_mesh_search(smoke)
    _bench_mesh_ring(smoke)
    _bench_grad_dense(smoke)
    _bench_grad_dense_act(smoke)
    _bench_grad_plandb(smoke)
    _bench_capture_sites(smoke)
    _bench_capture_step(smoke)
    _bench_obs(smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI")
    ap.add_argument("--attn", action="store_true",
                    help="run only the fused attention + grouped-GEMM "
                         "sections (the attn-smoke CI job)")
    ap.add_argument("--quant", action="store_true",
                    help="run only the int8/fp8 quant-tier sections "
                         "(the quant-smoke CI job)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.attn:
        run_attn(smoke=args.smoke)
    elif args.quant:
        run_quant(smoke=args.smoke)
    else:
        run(smoke=args.smoke)
