"""Serving bench: continuous batching vs the fixed-slot baseline.

Drives the SAME seeded saturated trace (Poisson rate 0 => every request
queued at t=0) through :class:`ContinuousEngine` and :class:`FixedEngine`
and emits:

  * ``serve.continuous.tok_per_s`` / ``serve.fixed.tok_per_s`` — seconds
    column is decode seconds per decode token; derived carries tok_per_s
    plus step/preemption counts,
  * ``serve.p50`` / ``serve.p99`` — end-to-end request latency
    percentiles on the continuous engine,
  * ``serve.vs_fixed`` — the gate row: continuous decode throughput must
    not be slower than fixed-slot (``not_slower=True``),
  * ``serve.differential`` — the gate row: per-request greedy outputs
    identical between the two engines (``ok=True``).

The workload is chosen so the fixed-slot pathology is actually on the
table: more requests than lanes and high ``max_new`` variance, so the
fixed server keeps whole lanes idle while the longest member of each
group finishes.  Both engines are warmed (one full untimed pass over an
identical trace) before the measured pass — the gate compares steady
state, not compile time.
"""

from __future__ import annotations

import math

from .common import emit


def run(smoke: bool = True):
    import numpy as np

    from repro.configs import get_config
    from repro.launch.serving import (
        ContinuousEngine,
        FixedEngine,
        Gateway,
        synthetic_trace,
    )

    cfg = get_config("qwen3-8b")
    if smoke:
        cfg = cfg.smoke()

    lanes, page = 4, 8
    n_requests = 12
    trace_kw = dict(
        vocab=cfg.vocab,
        seed=11,
        rate_hz=0.0,                      # saturated: queueing is the test
        prompt_lens=(4, 8, 16),
        max_news=(1, 24),                 # high variance => fixed-slot waste
    )
    max_ctx = max(trace_kw["prompt_lens"]) + max(trace_kw["max_news"]) + 1
    pages_per_req = math.ceil(max_ctx / page)
    n_pages = 1 + lanes * pages_per_req   # roomy: no preemption in the bench

    cont = ContinuousEngine(
        cfg, lanes=lanes, page_size=page, n_pages=n_pages, max_ctx=max_ctx
    )
    fixed = FixedEngine(cfg, lanes=lanes, max_ctx=max_ctx)

    # warm both engines on an identical trace so the measured pass sees
    # only steady-state dispatches (no compiles)
    cont.run(synthetic_trace(n_requests, **trace_kw))
    fixed.run(synthetic_trace(n_requests, **trace_kw))

    trace_c = synthetic_trace(n_requests, **trace_kw)
    stats_c = Gateway(cont).run(trace_c)
    trace_f = synthetic_trace(n_requests, **trace_kw)
    stats_f = fixed.run(trace_f)

    tps_c = stats_c["tok_per_s"]
    tps_f = stats_f["tok_per_s"]
    emit(
        "serve.continuous.tok_per_s",
        1.0 / max(tps_c, 1e-9),
        f"tok_per_s={tps_c:.1f};decode_tokens={stats_c['decode_tokens']};"
        f"decode_steps={stats_c['decode_steps']};"
        f"preemptions={stats_c['preemptions']}",
    )
    emit(
        "serve.fixed.tok_per_s",
        1.0 / max(tps_f, 1e-9),
        f"tok_per_s={tps_f:.1f};decode_tokens={stats_f['decode_tokens']};"
        f"decode_steps={stats_f['decode_steps']}",
    )
    emit("serve.p50", stats_c["p50_s"], "engine=continuous")
    emit("serve.p99", stats_c["p99_s"], "engine=continuous")
    emit(
        "serve.vs_fixed",
        1.0 / max(tps_c, 1e-9),
        f"not_slower={tps_c >= tps_f};"
        f"continuous={tps_c:.1f};fixed={tps_f:.1f};"
        f"speedup={tps_c / max(tps_f, 1e-9):.2f}x",
    )

    by_rid = {r.rid: r for r in trace_f}
    same = all(
        r.out_tokens == by_rid[r.rid].out_tokens for r in trace_c
    ) and len(trace_c) == len(trace_f)
    n_tok = sum(len(r.out_tokens) for r in trace_c)
    emit(
        "serve.differential",
        0.0,
        f"ok={same};requests={len(trace_c)};tokens={n_tok}",
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config sized for CI CPU runners")
    run(smoke=ap.parse_args().smoke)
