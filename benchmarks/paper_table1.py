"""Paper Table 1: the six permutations of naive matmul.

The paper's C++14 codegen measured (1024x1024 doubles, i5-7300HQ):

    mapA rnz  mapB   0.45 s     <- best: B read row-wise innermost
    rnz  mapA mapB   1.41 s
    mapA mapB rnz    4.67 s     (the textbook form)
    mapB mapA rnz    6.05 s
    rnz  mapB mapA  13.8  s
    mapB rnz  mapA  15.6  s     <- worst: both column-wise

HoF order maps to loop indices: mapA = i (rows of A), mapB = k (cols of B),
rnz = j.  We execute every ordering with the semi-vectorized executor (outer
loops real, innermost two einsum'd over strided views) and check that (a)
all six agree numerically and (b) the measured ordering correlates with the
paper's and with the analytic cost model's ranking.
"""

import numpy as np

from repro.core.cost import cpu_cost, rank_variants
from repro.core.enumerate import matmul_spec, variant_orders
from repro.core.execute import execute_variant

from .common import emit, spearman, timeit

HOF_NAMES = {"i": "mapA", "j": "rnz", "k": "mapB"}

#: the paper's measured ordering, best -> worst
PAPER_ORDER = [
    ("mapA", "rnz", "mapB"),
    ("rnz", "mapA", "mapB"),
    ("mapA", "mapB", "rnz"),
    ("mapB", "mapA", "rnz"),
    ("rnz", "mapB", "mapA"),
    ("mapB", "rnz", "mapA"),
]


def run(n: int = 384):
    spec = matmul_spec(n, n, n)
    rng = np.random.default_rng(0)
    arrays = {
        "A": rng.standard_normal((n, n)),
        "B": rng.standard_normal((n, n)),
    }
    ref = arrays["A"] @ arrays["B"]
    rows = []
    for order in variant_orders(spec, dedup_rnz=False):
        out = execute_variant(spec, order, arrays)
        assert np.allclose(out, ref, rtol=1e-8), order
        t = timeit(lambda o=order: execute_variant(spec, o, arrays))
        label = "/".join(HOF_NAMES[i] for i in order)
        cost = cpu_cost(spec, order)
        rows.append((label, order, t, cost))
        emit(f"table1.{label}", t, f"model_cost={cost:.3g}")

    measured = {r[0]: r[2] for r in rows}
    paper_rank = ["/".join(p) for p in PAPER_ORDER]
    rho_paper = spearman(
        [measured[l] for l in paper_rank], list(range(6))
    )
    rho_model = spearman([r[2] for r in rows], [r[3] for r in rows])
    emit("table1.rank_corr_vs_paper", 0.0, f"spearman={rho_paper:.2f}")
    emit("table1.rank_corr_vs_costmodel", 0.0, f"spearman={rho_model:.2f}")
    return rows


if __name__ == "__main__":
    run()
