"""Paper Table 2: twelve orderings of the rnz-subdivided matmul (b=16).

The paper's best case (186 ms vs 4.9 s naive C) nests
``rnz mapA mapB rnz``: outer reduction blocks, output tile resident,
inner reduction innermost — exactly the blocked GEMM the Pallas kernel
implements on TPU (kernels/matmul).  We reproduce the 12-case enumeration,
verify numerical equality, time each, and report the cost model's pick.
"""

import numpy as np

from repro.core.cost import cpu_cost
from repro.core.enumerate import matmul_spec, variant_orders
from repro.core.execute import execute_variant

from .common import emit, spearman, timeit

HOF = {"i": "mapA", "jo": "rnz", "ji": "rnz", "k": "mapB"}


def run(n: int = 384, b: int = 16):
    spec = matmul_spec(n, n, n).subdivide("j", b)
    rng = np.random.default_rng(1)
    arrays = {
        "A": rng.standard_normal((n, n)),
        "B": rng.standard_normal((n, n)),
    }
    ref = arrays["A"] @ arrays["B"]
    orders = variant_orders(spec)
    assert len(orders) == 12, len(orders)
    rows = []
    for order in orders:
        out = execute_variant(spec, order, arrays)
        assert np.allclose(out, ref, rtol=1e-8), order
        t = timeit(lambda o=order: execute_variant(spec, o, arrays))
        label = "/".join(HOF[i] for i in order)
        cost = cpu_cost(spec, order)
        rows.append((label, order, t, cost))
        emit(f"table2.{label}", t, f"model_cost={cost:.3g}")
    rho = spearman([r[2] for r in rows], [r[3] for r in rows])
    best_measured = min(rows, key=lambda r: r[2])
    best_model = min(rows, key=lambda r: r[3])
    emit("table2.rank_corr_vs_costmodel", 0.0, f"spearman={rho:.2f}")
    emit(
        "table2.best", best_measured[2],
        f"measured={best_measured[0]};model_pick={best_model[0]}",
    )
    return rows


if __name__ == "__main__":
    run()
