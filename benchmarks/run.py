"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Roofline numbers for the assigned
architectures come from the dry-run artifacts (results/) via
``repro.roofline.analysis`` and are appended when available.
"""

from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    from . import (
        fusion_bench, kernel_bench, paper_fig3, paper_table1, paper_table2,
        subdiv_sweep,
    )

    print("name,us_per_call,derived")
    benches = [
        ("table1", paper_table1.run),
        ("table2", paper_table2.run),
        ("fig3", paper_fig3.run),
        ("subdiv_sweep", subdiv_sweep.run),
        ("fusion", fusion_bench.run),
        ("kernel", kernel_bench.run),
    ]
    failures = []
    for name, fn in benches:
        try:
            fn()
        except Exception as e:  # keep the harness going; report at the end
            failures.append((name, e))
            print(f"{name}.ERROR,0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)

    results_dir = os.environ.get("REPRO_RESULTS", "results")
    if os.path.isdir(results_dir):
        try:
            from repro.roofline.analysis import analyze_all

            rows = analyze_all(results_dir)
            ok = [r for r in rows if r["status"] == "ok"]
            for r in ok:
                name = f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}"
                bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
                print(
                    f"{name},{bound*1e6:.1f},"
                    f"dominant={r['dominant']};frac={r.get('roofline_fraction', 0):.2f}"
                )
        except Exception as e:
            print(f"roofline.ERROR,0,{type(e).__name__}:{e}")

    if failures:
        raise SystemExit(f"{len(failures)} bench(es) failed: "
                         f"{[n for n, _ in failures]}")


if __name__ == "__main__":
    main()
