"""Paper Figs 4-6 analogue: which subdivision strategy pays off.

The paper's findings: subdividing the two maps does NOT beat the naive
best; subdividing the rnz (once or twice) does; subdividing everything adds
nothing over rnz-only.  We time the best ordering under each strategy.
"""

import numpy as np

from repro.core.enumerate import matmul_spec, variant_orders
from repro.core.execute import execute_variant
from repro.core.cost import cpu_cost, rank_variants

from .common import emit, timeit


def best_time(spec, arrays, limit=8):
    orders = variant_orders(spec)
    # early-cut with the cost model (paper future-work realized): measure
    # only the model's top candidates
    ranked = rank_variants(spec, orders)[:limit]
    best = float("inf")
    best_order = None
    ref = arrays["A"] @ arrays["B"]
    for _, order in ranked:
        out = execute_variant(spec, order, arrays)
        assert np.allclose(out, ref, rtol=1e-8)
        t = timeit(lambda o=order: execute_variant(spec, o, arrays),
                   repeats=2)
        if t < best:
            best, best_order = t, order
    return best, best_order


def run(n: int = 512, b: int = 16):
    rng = np.random.default_rng(3)
    arrays = {
        "A": rng.standard_normal((n, n)),
        "B": rng.standard_normal((n, n)),
    }
    base = matmul_spec(n, n, n)
    strategies = {
        "naive": base,
        "maps_subdiv": base.subdivide("i", b).subdivide("k", b),
        "rnz_subdiv": base.subdivide("j", b),
        "rnz_subdiv_twice": base.subdivide("j", b * b).subdivide(
            "ji", b
        ),
        "all_subdiv": base.subdivide("j", b).subdivide("i", b).subdivide(
            "k", b
        ),
    }
    results = {}
    for name, spec in strategies.items():
        t, order = best_time(spec, arrays)
        results[name] = t
        emit(f"subdiv.{name}", t, f"best_order={'/'.join(order)}")
    # the paper's qualitative claims, as derived checks:
    emit(
        "subdiv.claim_rnz_beats_maps", 0.0,
        f"ok={results['rnz_subdiv'] < results['maps_subdiv']}",
    )
    return results


if __name__ == "__main__":
    run()
