"""Motivating-example fusion benches (paper eqs 1-5): temporaries vs fused.

* eq 1:  w = (A+B)(v+u) — BLAS-style (materialize A+B, v+u) vs the fused
  rnz produced by the rewrite engine, both lowered to jnp and jitted.
* eqs 3-5: dense + batchnorm + nonlinearity — three-kernel pipeline vs the
  fused epilogue (the Pallas kernel's contract, here timed via its CPU
  lowering).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expr as E
from repro.core.expr import Prim, RNZ, lam, v, zip2
from repro.core.lower import jax_fn
from repro.core.rewrite import fuse

from .common import emit, timeit


def run(n: int = 1024):
    rng = np.random.default_rng(4)
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal(n), jnp.float32)
    u = jnp.asarray(rng.standard_normal(n), jnp.float32)

    # eq 1 unfused: explicit temporaries
    @jax.jit
    def unfused(A, B, vv, u):
        T1 = A + B
        t2 = vv + u
        return T1 @ t2

    # eq 1 fused via the rewrite engine
    expr = E.MapN(
        lam(
            ("rA", "rB"),
            RNZ(
                Prim("+"), Prim("id"),
                (zip2(
                    Prim("*"),
                    zip2(Prim("+"), v("rA"), v("rB")),
                    zip2(Prim("+"), v("vv"), v("u")),
                ),),
            ),
        ),
        (v("A"), v("B")),
    )
    fused_expr = fuse(expr)
    fused = jax.jit(jax_fn(fused_expr, ["A", "B", "vv", "u"]))

    ref = np.asarray(unfused(A, B, vv, u))
    got = np.asarray(fused(A, B, vv, u))
    assert np.allclose(ref, got, rtol=1e-4, atol=1e-4)

    t_un = timeit(lambda: jax.block_until_ready(unfused(A, B, vv, u)))
    t_fu = timeit(lambda: jax.block_until_ready(fused(A, B, vv, u)))
    emit("fusion.eq1_unfused", t_un, "")
    emit("fusion.eq1_fused", t_fu, f"speedup={t_un/t_fu:.2f}x")

    # eqs 3-5: dense + norm + act
    from repro.kernels.fused_dense_act.ref import fused_dense_act_ref

    b, i, k = 256, 1024, 1024
    x = jnp.asarray(rng.standard_normal((b, i)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((i, k)), jnp.float32)
    beta = jnp.asarray(rng.standard_normal(k), jnp.float32)
    mean = jnp.asarray(rng.standard_normal(k), jnp.float32)
    var = jnp.asarray(np.abs(rng.standard_normal(k)) + 0.5, jnp.float32)

    @jax.jit
    def staged(x, w, beta, mean, var):
        y = x @ w + beta[None]
        z = (y - mean[None]) / jnp.sqrt(var[None] + 1e-5)
        return jax.nn.gelu(z)

    fused_k = jax.jit(
        lambda *a: fused_dense_act_ref(*a, act="gelu")
    )
    np.testing.assert_allclose(
        np.asarray(staged(x, w, beta, mean, var)),
        np.asarray(fused_k(x, w, beta, mean, var)),
        rtol=1e-4, atol=1e-4,
    )
    t_st = timeit(lambda: jax.block_until_ready(staged(x, w, beta, mean, var)))
    t_fk = timeit(lambda: jax.block_until_ready(fused_k(x, w, beta, mean, var)))
    emit("fusion.eq345_staged", t_st, "")
    emit("fusion.eq345_fused", t_fk, f"speedup={t_st/t_fk:.2f}x")


if __name__ == "__main__":
    run()
