"""Shared benchmark helpers; every bench prints ``name,us_per_call,derived``."""

from __future__ import annotations

import time
from typing import Callable, Optional

import numpy as np


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds*1e6:.1f},{derived}")


def spearman(a, b) -> float:
    ra = np.argsort(np.argsort(a)).astype(float)
    rb = np.argsort(np.argsort(b)).astype(float)
    ra -= ra.mean(); rb -= rb.mean()
    denom = np.sqrt((ra**2).sum() * (rb**2).sum())
    return float((ra * rb).sum() / denom) if denom else 0.0
