"""Test-suite bootstrap: the property suite runs everywhere, no skips.

Several test modules use ``hypothesis`` for property tests.  CI installs
the real library (see ``.github/workflows/ci.yml`` / ``requirements.txt``);
the container this repo grows in bakes in the jax/Pallas toolchain but not
hypothesis, and tier-1 may not ``pip install``.  The old bootstrap stubbed
``hypothesis`` with a decorator that *skipped* every ``@given`` test (18
permanent skips); that stub-skip path is gone.  When the real library is
absent we install ``tests/_property_engine.py`` — a seeded fallback engine
that actually **executes** each property with deterministically drawn
examples — so the full suite runs with 0 hypothesis skips on bare machines
too.  ``import hypothesis; hypothesis.__is_repro_fallback__`` tells the two
apart; ``REPRO_PROPERTY_EXAMPLES`` caps example counts.

Also puts ``src/`` on sys.path so ``python -m pytest`` works without
PYTHONPATH gymnastics.
"""

from __future__ import annotations

import os
import sys

_HERE = os.path.dirname(__file__)
_SRC = os.path.join(_HERE, "..", "src")
if os.path.isdir(_SRC) and os.path.abspath(_SRC) not in (
    os.path.abspath(p) for p in sys.path
):
    sys.path.insert(0, os.path.abspath(_SRC))

try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    if _HERE not in sys.path:
        sys.path.insert(0, _HERE)
    import _property_engine

    sys.modules["hypothesis"] = _property_engine  # type: ignore[assignment]
    sys.modules["hypothesis.strategies"] = _property_engine.strategies
