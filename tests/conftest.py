"""Test-suite bootstrap: the property suite runs everywhere, no skips.

Several test modules use ``hypothesis`` for property tests.  CI installs
the real library (see ``.github/workflows/ci.yml`` / ``requirements.txt``);
the container this repo grows in bakes in the jax/Pallas toolchain but not
hypothesis, and tier-1 may not ``pip install``.  The old bootstrap stubbed
``hypothesis`` with a decorator that *skipped* every ``@given`` test (18
permanent skips); that stub-skip path is gone.  When the real library is
absent we install ``tests/_property_engine.py`` — a seeded fallback engine
that actually **executes** each property with deterministically drawn
examples — so the full suite runs with 0 hypothesis skips on bare machines
too.  ``import hypothesis; hypothesis.__is_repro_fallback__`` tells the two
apart; ``REPRO_PROPERTY_EXAMPLES`` caps example counts.

Also puts ``src/`` on sys.path so ``python -m pytest`` works without
PYTHONPATH gymnastics, and provides the shared ``forced_devices`` fixture:
device-count-sensitive tests (meshes, shard_map collectives, the mesh-tier
differential matrix) run their payload in a subprocess under
``--xla_force_host_platform_device_count=N`` so the main pytest process
keeps its single-device view (per the dry-run contract: only dryrun.py
forces 512 devices).  Used by ``tests/test_launch.py`` and
``tests/test_mesh_search.py``.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

_HERE = os.path.dirname(__file__)
_SRC = os.path.join(_HERE, "..", "src")
if os.path.isdir(_SRC) and os.path.abspath(_SRC) not in (
    os.path.abspath(p) for p in sys.path
):
    sys.path.insert(0, os.path.abspath(_SRC))

try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    if _HERE not in sys.path:
        sys.path.insert(0, _HERE)
    import _property_engine

    sys.modules["hypothesis"] = _property_engine  # type: ignore[assignment]
    sys.modules["hypothesis.strategies"] = _property_engine.strategies


def run_forced_devices(code: str, devices: int = 8, timeout: int = 600,
                       env_extra: dict = None) -> str:
    """Run ``code`` in a subprocess with ``devices`` forced CPU devices.

    Returns the subprocess stdout; a non-zero exit fails the calling test
    with both streams attached.  ``env_extra`` lets a caller isolate
    caches (``REPRO_PLAN_DB`` etc.) per test.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
    )
    env["PYTHONPATH"] = os.path.abspath(_SRC)
    if env_extra:
        env.update(env_extra)
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert out.returncode == 0, (
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    )
    return out.stdout


@pytest.fixture
def forced_devices():
    """``forced_devices(code, devices=8, timeout=600)`` subprocess runner."""
    return run_forced_devices
