"""Test-suite bootstrap: make collection survive a bare machine.

Several test modules use ``hypothesis`` for property tests.  The container
bakes in the jax/Pallas toolchain but not necessarily hypothesis, and a
missing import must not take down *collection* for the whole suite (the
seed repo failed exactly this way).  When hypothesis is absent we install
a minimal stub into ``sys.modules`` whose ``@given``-decorated tests call
``pytest.skip`` with a clear message, so every non-property test still
runs.  Install the real thing with ``pip install -e .[test]``.

Also puts ``src/`` on sys.path so ``python -m pytest`` works without
PYTHONPATH gymnastics.
"""

from __future__ import annotations

import os
import sys
import types

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.isdir(_SRC) and os.path.abspath(_SRC) not in (
    os.path.abspath(p) for p in sys.path
):
    sys.path.insert(0, os.path.abspath(_SRC))

try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _SKIP_MSG = (
        "hypothesis is not installed — property test skipped "
        "(pip install hypothesis, or pip install -e .[test])"
    )

    class _Strategy:
        """Inert stand-in for any strategy object/expression."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

        def map(self, *a, **k):
            return self

        def filter(self, *a, **k):
            return self

    def _given(*_a, **_k):
        def deco(fn):
            import functools

            import pytest

            @functools.wraps(fn)
            def skipper(*args, **kwargs):
                pytest.skip(_SKIP_MSG)

            # drop hypothesis-injected params so pytest doesn't look for
            # fixtures matching the strategy argument names
            skipper.__wrapped__ = None
            skipper.__signature__ = __import__("inspect").Signature()
            return skipper

        return deco

    def _settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

    _settings.register_profile = lambda *a, **k: None
    _settings.load_profile = lambda *a, **k: None

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):
            return _Strategy()

    stub = types.ModuleType("hypothesis")
    stub.given = _given
    stub.settings = _settings
    stub.assume = lambda *a, **k: True
    stub.note = lambda *a, **k: None
    stub.example = lambda *a, **k: (lambda fn: fn)
    stub.strategies = _Strategies("hypothesis.strategies")
    stub.HealthCheck = _Strategy()
    stub.__is_repro_stub__ = True
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = stub.strategies
