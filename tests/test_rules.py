"""Every rewrite rule preserves the reference-interpreter semantics.

These are the paper's §3 identities, checked as executable properties.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import expr as E
from repro.core import rules as R
from repro.core.expr import (
    App, Flatten, Flip, Lam, Lit, MapN, Prim, Proj, RNZ, Subdiv, Tup, Var,
    dot, lam, map1, reduce1, v, zip2,
)
from repro.core.interp import run
from repro.core.rewrite import Trace, apply_at, find_matches, fuse, normalize

shapes = st.integers(1, 6)
seeds = st.integers(0, 2**16)


def mk(rng, *shape):
    return rng.standard_normal(shape)


def check_rule(rule, e, **arrays):
    """Apply `rule` at its first match and assert semantics are unchanged."""
    paths = find_matches(e, rule)
    assert paths, f"rule {rule.__name__} does not match {e!r}"
    e2 = apply_at(e, paths[0], rule)
    before = run(e, **arrays)
    after = run(e2, **arrays)
    np.testing.assert_allclose(after, before, rtol=1e-10, atol=1e-10)
    return e2


# -- fusion group ------------------------------------------------------------


@given(n=shapes, seed=seeds)
@settings(max_examples=30, deadline=None)
def test_map_map_fusion_eq19(n, seed):
    rng = np.random.default_rng(seed)
    x = mk(rng, n)
    f = lam("a", App(Prim("*"), (v("a"), Lit(3.0))))
    g = lam("a", App(Prim("+"), (v("a"), Lit(1.0))))
    e = map1(f, map1(g, v("x")))
    e2 = check_rule(R.nzip_nzip_fuse, e, x=x)
    # fused: a single MapN remains after normalization
    fused = fuse(e2)
    assert isinstance(fused, MapN)
    assert not any(isinstance(c, MapN) for c in E.children(fused))


@given(n=shapes, seed=seeds)
@settings(max_examples=30, deadline=None)
def test_nzip_nzip_fusion_eq24(n, seed):
    rng = np.random.default_rng(seed)
    x, y, z = mk(rng, n), mk(rng, n), mk(rng, n)
    # zip (+) x (zip (*) y z) — fuses to a ternary nzip
    e = zip2(Prim("+"), v("x"), zip2(Prim("*"), v("y"), v("z")))
    e2 = check_rule(R.nzip_nzip_fuse, e, x=x, y=y, z=z)
    assert isinstance(e2, MapN) and len(e2.args) == 3


@given(n=shapes, seed=seeds)
@settings(max_examples=30, deadline=None)
def test_rnz_nzip_fusion_eq27(n, seed):
    rng = np.random.default_rng(seed)
    u, w = mk(rng, n), mk(rng, n)
    # reduce (+) (zip (*) u w)  ->  rnz (+) (*) u w   (paper eq 29)
    e = reduce1(Prim("+"), zip2(Prim("*"), v("u"), v("w")))
    e2 = check_rule(R.rnz_nzip_fuse, e, u=u, w=w)
    assert isinstance(e2, RNZ) and len(e2.args) == 2
    # and the fused normal form evaluates like a dot product
    np.testing.assert_allclose(run(fuse(e), u=u, w=w), u @ w, rtol=1e-10)


@given(n=shapes, seed=seeds)
@settings(max_examples=20, deadline=None)
def test_tuple_fusion_eq31_34(n, seed):
    rng = np.random.default_rng(seed)
    x, y = mk(rng, n), mk(rng, n)
    f = lam("a", App(Prim("*"), (v("a"), Lit(2.0))))
    g = lam("a", App(Prim("+"), (v("a"), Lit(5.0))))
    e = Tup((map1(f, v("x")), map1(g, v("y"))))
    out1 = run(e, x=x, y=y)
    e2 = R.tup_map_fuse(e)
    assert e2 is not None
    out2 = run(e2, x=x, y=y)
    for a, b in zip(out1, out2):
        np.testing.assert_allclose(a, b, rtol=1e-12)
    # eq 34 for reductions
    er = Tup((reduce1(Prim("+"), v("x")), reduce1(Prim("max"), v("y"))))
    er2 = R.tup_rnz_fuse(er)
    assert er2 is not None
    o1, o2 = run(er, x=x, y=y), run(er2, x=x, y=y)
    np.testing.assert_allclose(o1[0], o2[0], rtol=1e-10)
    np.testing.assert_allclose(o1[1], o2[1], rtol=1e-10)


@given(n=shapes, seed=seeds)
@settings(max_examples=20, deadline=None)
def test_fanout_fusion_eq32(n, seed):
    rng = np.random.default_rng(seed)
    x = mk(rng, n)
    f = lam("a", App(Prim("*"), (v("a"), Lit(2.0))))
    g = lam("a", App(Prim("neg"), (v("a"),)))
    e = Tup((map1(f, v("x")), map1(g, v("x"))))
    e2 = R.fanout_fuse(e)
    assert e2 is not None and isinstance(e2, MapN)
    o1, o2 = run(e, x=x), run(e2, x=x)
    np.testing.assert_allclose(o1[0], o2[0], rtol=1e-12)
    np.testing.assert_allclose(o1[1], o2[1], rtol=1e-12)


# -- exchange group ----------------------------------------------------------


@given(n=shapes, m=shapes, seed=seeds)
@settings(max_examples=40, deadline=None)
def test_map_map_exchange_eq36(n, m, seed):
    rng = np.random.default_rng(seed)
    w, u = mk(rng, n), mk(rng, m)
    e = map1(
        lam("x", map1(lam("y", App(Prim("*"), (v("x"), v("y")))), v("u"))),
        v("w"),
    )
    check_rule(R.map_map_exchange, e, w=w, u=u)


@given(n=shapes, m=shapes, seed=seeds)
@settings(max_examples=40, deadline=None)
def test_map_rnz_exchange_eq42(n, m, seed):
    """THE locality rule: row-wise matvec == column-accumulation matvec."""
    rng = np.random.default_rng(seed)
    A, u = mk(rng, n, m), mk(rng, m)
    e = map1(lam("r", RNZ(Prim("+"), Prim("*"), (v("r"), v("u")))), v("A"))
    e2 = check_rule(R.map_rnz_exchange, e, A=A, u=u)
    # result must be an RNZ at the top with a flipped operand
    assert isinstance(e2, RNZ)
    assert isinstance(e2.args[0], Flip)


@given(n=shapes, m=shapes, seed=seeds)
@settings(max_examples=40, deadline=None)
def test_rnz_map_exchange_roundtrip(n, m, seed):
    """eq 42 applied forwards then backwards returns to a map-of-rnz."""
    rng = np.random.default_rng(seed)
    A, u = mk(rng, n, m), mk(rng, m)
    e = map1(lam("r", RNZ(Prim("+"), Prim("*"), (v("r"), v("u")))), v("A"))
    e2 = apply_at(e, find_matches(e, R.map_rnz_exchange)[0], R.map_rnz_exchange)
    paths = find_matches(e2, R.rnz_map_exchange)
    assert paths, f"inverse rule must match the forward result: {e2!r}"
    e3 = apply_at(e2, paths[0], R.rnz_map_exchange)
    np.testing.assert_allclose(run(e3, A=A, u=u), run(e, A=A, u=u), rtol=1e-10)
    # flip(flip(A)) cancels structurally after normalization
    e3n = normalize(e3, [R.flip_flip])
    assert not find_matches(e3n, lambda x: x if isinstance(x, Flip) else None)


@given(n=shapes, m=shapes, seed=seeds)
@settings(max_examples=40, deadline=None)
def test_rnz_rnz_exchange_eq43(n, m, seed):
    rng = np.random.default_rng(seed)
    A, B = mk(rng, n, m), mk(rng, n)
    # sum_i sum_j A_ij * B_i   — inner rnz consumes rows of A zipped with B
    e = RNZ(
        Prim("+"),
        lam(
            "a",
            RNZ(Prim("+"), Prim("*"), (Var("a"), v("B"))),
        ),
        (v("A"),),
    )
    # inner args = (Var a, B): B's outer extent must equal a's => need m == n
    # use square case for the zipped variant; general case via separate operand
    if n == m:
        check_rule(R.rnz_rnz_exchange, e, A=A, B=B)


@given(n=shapes, seed=seeds)
@settings(max_examples=30, deadline=None)
def test_rnz_rnz_exchange_two_operands(n, seed):
    rng = np.random.default_rng(seed)
    A1, A2, B = mk(rng, n, 4), mk(rng, n, 4), mk(rng, 4)
    e = RNZ(
        Prim("+"),
        lam(
            ("a1", "a2"),
            RNZ(
                Prim("+"),
                lam(
                    ("x", "y", "b"),
                    App(
                        Prim("*"),
                        (App(Prim("*"), (v("x"), v("y"))), v("b")),
                    ),
                ),
                (Var("a1"), Var("a2"), v("B")),
            ),
        ),
        (v("A1"), v("A2")),
    )
    check_rule(R.rnz_rnz_exchange, e, A1=A1, A2=A2, B=B)


# -- subdivision group ---------------------------------------------------------


@given(seed=seeds, nb=st.sampled_from([(6, 2), (6, 3), (8, 4), (12, 3)]))
@settings(max_examples=30, deadline=None)
def test_map_subdiv_eq44(seed, nb):
    n, b = nb
    rng = np.random.default_rng(seed)
    x = mk(rng, n)
    f = lam("a", App(Prim("*"), (v("a"), v("a"))))
    e = map1(f, v("x"))
    rule = R.make_map_subdiv(b)
    e2 = rule(e)
    assert e2 is not None
    np.testing.assert_allclose(run(e2, x=x), run(e, x=x), rtol=1e-12)


@given(seed=seeds, nb=st.sampled_from([(6, 2), (6, 3), (8, 4), (12, 3)]))
@settings(max_examples=30, deadline=None)
def test_rnz_subdiv_regroup(seed, nb):
    n, b = nb
    rng = np.random.default_rng(seed)
    u, w = mk(rng, n), mk(rng, n)
    e = dot(v("u"), v("w"))
    rule = R.make_rnz_subdiv(b)
    e2 = rule(e)
    assert e2 is not None
    np.testing.assert_allclose(run(e2, u=u, w=w), run(e, u=u, w=w), rtol=1e-10)


# -- composed pipelines --------------------------------------------------------


def test_fusion_pipeline_eq1():
    """Motivating example eq 1 fuses to a single rnz with no temporaries."""
    rng = np.random.default_rng(0)
    A, B, vv, u = (
        rng.standard_normal((3, 4)),
        rng.standard_normal((3, 4)),
        rng.standard_normal(4),
        rng.standard_normal(4),
    )
    row_sum = zip2(Prim("+"), v("rA"), v("rB"))
    vec_sum = zip2(Prim("+"), v("vv"), v("u"))
    e = MapN(
        lam(("rA", "rB"), reduce1(Prim("+"), zip2(Prim("*"), row_sum, vec_sum))),
        (v("A"), v("B")),
    )
    trace = Trace()
    fused = fuse(e, trace=trace)
    np.testing.assert_allclose(
        run(fused, A=A, B=B, vv=vv, u=u), (A + B) @ (vv + u), rtol=1e-10
    )

    # after fusion, there must be no nested MapN under the rnz arguments:
    # the zips have been folded into the rnz zipper (no temporaries).
    def count(ty, e):
        n = int(isinstance(e, ty))
        return n + sum(count(ty, c) for c in E.children(e))

    body = fused
    assert isinstance(body, MapN)
    inner = body.f.body if isinstance(body.f, Lam) else None
    assert isinstance(inner, RNZ)
    assert all(not isinstance(a, MapN) for a in inner.args)
    assert len(trace.steps) >= 3


def test_beta_eta():
    e = App(lam("x", App(Prim("+"), (v("x"), Lit(1.0)))), (Lit(2.0),))
    assert run(normalize(e, [R.beta]), ) == 3.0
    f = lam("x", App(Prim("neg"), (v("x"),)))
    assert R.eta(f) == Prim("neg")


# ---------------------------------------------------------------------------
# registry-driven coverage: EVERY rule in rules.RULES, random well-typed
# exprs, applied at every match, checked against core.interp
# ---------------------------------------------------------------------------
#
# Each generator draws a random well-typed expression containing at least
# one redex for its rule (random extents, random values, random scalar
# bodies); ``test_rule_preserves_semantics_at_every_match`` then applies
# the rule at *every* match path and asserts interpreter equivalence.
# With ``lift=True`` the whole expression is additionally embedded in a
# random outer ``map`` context (arrays gain a leading dim), so rules are
# exercised at non-root paths too.  ``test_rule_registry_fully_covered``
# pins the inventory: adding a rule to ``rules.RULES`` without adding a
# generator here fails the suite.

def _scalar_body(rng, names):
    """A random scalar expression over Var(names) (all used at least once)."""
    e = v(names[0])
    for n in names[1:]:
        op = rng.choice(["+", "*", "-"])
        e = App(Prim(op), (e, v(n)))
    if rng.random() < 0.5:
        e = App(Prim("+"), (e, Lit(float(rng.integers(1, 4)))))
    return e


def _unary(rng):
    op = rng.choice(["neg", "sq", "exp", "id"])
    p = f"u{rng.integers(1 << 20)}"
    return lam(p, App(Prim(op), (v(p),)))


def _gen_beta(rng):
    n = int(rng.integers(2, 5))
    x = rng.standard_normal(n)
    p = "bx"
    body = App(Prim("*"), (v(p), App(Prim("+"), (v(p), Lit(2.0)))))
    return App(Lam((p,), body), (v("x"),)), {"x": x}


def _gen_eta(rng):
    n = int(rng.integers(2, 5))
    x = rng.standard_normal(n)
    op = rng.choice(["neg", "sq", "exp"])
    return map1(lam("ex", App(Prim(op), (v("ex"),))), v("x")), {"x": x}


def _gen_app_id(rng):
    n = int(rng.integers(2, 5))
    return App(Prim("id"), (v("x"),)), {"x": rng.standard_normal(n)}


def _gen_proj_tup(rng):
    n = int(rng.integers(2, 5))
    x, y = rng.standard_normal(n), rng.standard_normal(n)
    i = int(rng.integers(0, 2))
    items = (v("x"), App(Prim("neg"), (v("y"),)))
    return Proj(i, Tup(items)), {"x": x, "y": y}


def _gen_nzip_nzip_fuse(rng):
    n = int(rng.integers(2, 6))
    x, y, z = (rng.standard_normal(n) for _ in range(3))
    inner = zip2(Prim(rng.choice(["+", "*"])), v("y"), v("z"))
    if rng.random() < 0.5:
        e = MapN(Prim(rng.choice(["+", "*"])), (v("x"), inner))
    else:
        e = MapN(Prim(rng.choice(["+", "*"])), (inner, v("x")))
    return e, {"x": x, "y": y, "z": z}


def _gen_rnz_nzip_fuse(rng):
    n = int(rng.integers(2, 6))
    u, w, g = (rng.standard_normal(n) for _ in range(3))
    inner = zip2(Prim("*"), v("w"), v("g"))
    e = RNZ(Prim(rng.choice(["+", "max"])), Prim("*"), (v("u"), inner))
    return e, {"u": u, "w": w, "g": g}


def _gen_tup_map_fuse(rng):
    n = int(rng.integers(2, 6))
    x, y = rng.standard_normal(n), rng.standard_normal(n)
    e = Tup((map1(_unary(rng), v("x")), map1(_unary(rng), v("y"))))
    return e, {"x": x, "y": y}


def _gen_tup_rnz_fuse(rng):
    n = int(rng.integers(2, 6))
    x, y = rng.standard_normal(n), rng.standard_normal(n)
    r1, r2 = rng.choice(["+", "max", "min", "*"], size=2)
    e = Tup((reduce1(Prim(r1), v("x")), reduce1(Prim(r2), v("y"))))
    return e, {"x": x, "y": y}


def _gen_fanout_fuse(rng):
    n = int(rng.integers(2, 6))
    x = rng.standard_normal(n)
    e = Tup((map1(_unary(rng), v("x")), map1(_unary(rng), v("x"))))
    return e, {"x": x}


def _gen_map_map_exchange(rng):
    n, m = int(rng.integers(2, 5)), int(rng.integers(2, 5))
    w, u = rng.standard_normal(n), rng.standard_normal(m)
    body = _scalar_body(rng, ["mx", "my"])
    e = map1(
        lam("mx", map1(Lam(("my",), body), v("u"))),
        v("w"),
    )
    return e, {"w": w, "u": u}


def _gen_map_rnz_exchange(rng):
    n, m = int(rng.integers(2, 5)), int(rng.integers(2, 5))
    A, u = rng.standard_normal((n, m)), rng.standard_normal(m)
    r = rng.choice(["+", "max"])
    e = map1(lam("r", RNZ(Prim(r), Prim("*"), (v("r"), v("u")))), v("A"))
    return e, {"A": A, "u": u}


def _gen_rnz_map_exchange(rng):
    # the inverse rule's redexes are exactly the forward rule's images:
    # generate one by applying map_rnz_exchange to a random matvec nest
    e, arrays = _gen_map_rnz_exchange(rng)
    path = find_matches(e, R.map_rnz_exchange)[0]
    return apply_at(e, path, R.map_rnz_exchange), arrays


def _gen_rnz_rnz_exchange(rng):
    n, m = int(rng.integers(2, 5)), int(rng.integers(2, 5))
    A1, A2 = rng.standard_normal((n, m)), rng.standard_normal((n, m))
    B = rng.standard_normal(m)
    e = RNZ(
        Prim("+"),
        lam(
            ("a1", "a2"),
            RNZ(
                Prim("+"),
                lam(
                    ("x", "y", "b"),
                    App(
                        Prim("*"),
                        (App(Prim("*"), (v("x"), v("y"))), v("b")),
                    ),
                ),
                (Var("a1"), Var("a2"), v("B")),
            ),
        ),
        (v("A1"), v("A2")),
    )
    return e, {"A1": A1, "A2": A2, "B": B}


def _gen_flip_flip(rng):
    shape = tuple(int(rng.integers(2, 4)) for _ in range(3))
    A = rng.standard_normal(shape)
    d1 = int(rng.integers(0, 2))
    d2 = int(rng.integers(d1 + 1, 3))
    e = Flip(d1, d2, Flip(d1, d2, v("A")))
    return e, {"A": A}


def _gen_flatten_subdiv(rng):
    n, b = [(6, 2), (6, 3), (8, 4), (4, 2)][int(rng.integers(0, 4))]
    m = 2 * int(rng.integers(1, 3))
    A = rng.standard_normal((m, n))
    d = int(rng.integers(0, 2))  # innermost-first dim being split
    e = Flatten(d, Subdiv(d, b if d == 0 else 2, v("A")))
    return e, {"A": A}


RULE_GENERATORS = {
    "beta": _gen_beta,
    "eta": _gen_eta,
    "app_id": _gen_app_id,
    "proj_tup": _gen_proj_tup,
    "nzip_nzip_fuse": _gen_nzip_nzip_fuse,
    "rnz_nzip_fuse": _gen_rnz_nzip_fuse,
    "tup_map_fuse": _gen_tup_map_fuse,
    "tup_rnz_fuse": _gen_tup_rnz_fuse,
    "fanout_fuse": _gen_fanout_fuse,
    "map_map_exchange": _gen_map_map_exchange,
    "map_rnz_exchange": _gen_map_rnz_exchange,
    "rnz_map_exchange": _gen_rnz_map_exchange,
    "rnz_rnz_exchange": _gen_rnz_rnz_exchange,
    "flip_flip": _gen_flip_flip,
    "flatten_subdiv": _gen_flatten_subdiv,
}

#: rules that by design never produce a match (documented conservatism)
NO_MATCH_RULES = {"subdiv_flatten"}


def test_rule_registry_fully_covered():
    """Every registered rule has a property generator (or is explicitly
    listed as match-free).  A new rule without coverage fails here."""
    assert set(R.RULES) == set(RULE_GENERATORS) | NO_MATCH_RULES, (
        "rules.RULES and the property-test generators drifted apart"
    )


def _lift_into_map(e, arrays, rng):
    """Embed ``e`` in a random outer map context: every array gains a
    leading dim of extent L and the expression is applied per slice."""
    from repro.core.expr import fresh, subst

    L = int(rng.integers(2, 4))
    names = sorted(arrays)
    params = {n: fresh(n.lower()) for n in names}
    body = subst(e, {n: Var(p) for n, p in params.items()})
    lifted = MapN(
        Lam(tuple(params[n] for n in names), body),
        tuple(v(n) for n in names),
    )
    stacked = {
        n: np.stack([
            rng.standard_normal(np.shape(arrays[n])) for _ in range(L)
        ])
        for n in names
    }
    return lifted, stacked


def _assert_same(after, before):
    if isinstance(before, tuple):
        assert isinstance(after, tuple) and len(after) == len(before)
        for a, b in zip(after, before):
            _assert_same(a, b)
        return
    np.testing.assert_allclose(
        np.asarray(after, np.float64), np.asarray(before, np.float64),
        rtol=1e-9, atol=1e-9,
    )


@pytest.mark.parametrize("name", sorted(RULE_GENERATORS))
@given(seed=seeds, lift=st.booleans())
@settings(max_examples=25, deadline=None)
def test_rule_preserves_semantics_at_every_match(name, seed, lift):
    """Random well-typed expr -> apply ``name`` at EVERY match -> interp
    equivalence.  The semantics-preservation contract of rules.py, rule
    by rule, including at non-root paths (``lift``)."""
    rng = np.random.default_rng(seed)
    e, arrays = RULE_GENERATORS[name](rng)
    if lift:
        e, arrays = _lift_into_map(e, arrays, rng)
    rule = R.RULES[name]
    paths = find_matches(e, rule)
    assert paths, f"generator for {name} produced no redex: {e!r}"
    before = run(e, **arrays)
    for path in paths:
        e2 = apply_at(e, path, rule)
        _assert_same(run(e2, **arrays), before)


def test_subdiv_flatten_is_conservative():
    """subdiv_flatten is deliberately match-free: without static extent
    types the cancellation is only safe when the engine tracked the
    subdivision itself (see rules.py)."""
    x = np.arange(12.0).reshape(2, 6)
    e = Subdiv(0, 3, Flatten(0, Subdiv(0, 3, v("x"))))
    assert R.subdiv_flatten(e) is None
    assert not find_matches(e, R.subdiv_flatten)
    # and the engine-tracked pair cancellation it defers to still holds
    np.testing.assert_allclose(
        run(Flatten(0, Subdiv(0, 3, v("x"))), x=x), x
    )
