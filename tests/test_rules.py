"""Every rewrite rule preserves the reference-interpreter semantics.

These are the paper's §3 identities, checked as executable properties.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import expr as E
from repro.core import rules as R
from repro.core.expr import (
    App, Flip, Lam, Lit, MapN, Prim, RNZ, Subdiv, Tup, Var,
    dot, lam, map1, reduce1, v, zip2,
)
from repro.core.interp import run
from repro.core.rewrite import Trace, apply_at, find_matches, fuse, normalize

shapes = st.integers(1, 6)
seeds = st.integers(0, 2**16)


def mk(rng, *shape):
    return rng.standard_normal(shape)


def check_rule(rule, e, **arrays):
    """Apply `rule` at its first match and assert semantics are unchanged."""
    paths = find_matches(e, rule)
    assert paths, f"rule {rule.__name__} does not match {e!r}"
    e2 = apply_at(e, paths[0], rule)
    before = run(e, **arrays)
    after = run(e2, **arrays)
    np.testing.assert_allclose(after, before, rtol=1e-10, atol=1e-10)
    return e2


# -- fusion group ------------------------------------------------------------


@given(n=shapes, seed=seeds)
@settings(max_examples=30, deadline=None)
def test_map_map_fusion_eq19(n, seed):
    rng = np.random.default_rng(seed)
    x = mk(rng, n)
    f = lam("a", App(Prim("*"), (v("a"), Lit(3.0))))
    g = lam("a", App(Prim("+"), (v("a"), Lit(1.0))))
    e = map1(f, map1(g, v("x")))
    e2 = check_rule(R.nzip_nzip_fuse, e, x=x)
    # fused: a single MapN remains after normalization
    fused = fuse(e2)
    assert isinstance(fused, MapN)
    assert not any(isinstance(c, MapN) for c in E.children(fused))


@given(n=shapes, seed=seeds)
@settings(max_examples=30, deadline=None)
def test_nzip_nzip_fusion_eq24(n, seed):
    rng = np.random.default_rng(seed)
    x, y, z = mk(rng, n), mk(rng, n), mk(rng, n)
    # zip (+) x (zip (*) y z) — fuses to a ternary nzip
    e = zip2(Prim("+"), v("x"), zip2(Prim("*"), v("y"), v("z")))
    e2 = check_rule(R.nzip_nzip_fuse, e, x=x, y=y, z=z)
    assert isinstance(e2, MapN) and len(e2.args) == 3


@given(n=shapes, seed=seeds)
@settings(max_examples=30, deadline=None)
def test_rnz_nzip_fusion_eq27(n, seed):
    rng = np.random.default_rng(seed)
    u, w = mk(rng, n), mk(rng, n)
    # reduce (+) (zip (*) u w)  ->  rnz (+) (*) u w   (paper eq 29)
    e = reduce1(Prim("+"), zip2(Prim("*"), v("u"), v("w")))
    e2 = check_rule(R.rnz_nzip_fuse, e, u=u, w=w)
    assert isinstance(e2, RNZ) and len(e2.args) == 2
    # and the fused normal form evaluates like a dot product
    np.testing.assert_allclose(run(fuse(e), u=u, w=w), u @ w, rtol=1e-10)


@given(n=shapes, seed=seeds)
@settings(max_examples=20, deadline=None)
def test_tuple_fusion_eq31_34(n, seed):
    rng = np.random.default_rng(seed)
    x, y = mk(rng, n), mk(rng, n)
    f = lam("a", App(Prim("*"), (v("a"), Lit(2.0))))
    g = lam("a", App(Prim("+"), (v("a"), Lit(5.0))))
    e = Tup((map1(f, v("x")), map1(g, v("y"))))
    out1 = run(e, x=x, y=y)
    e2 = R.tup_map_fuse(e)
    assert e2 is not None
    out2 = run(e2, x=x, y=y)
    for a, b in zip(out1, out2):
        np.testing.assert_allclose(a, b, rtol=1e-12)
    # eq 34 for reductions
    er = Tup((reduce1(Prim("+"), v("x")), reduce1(Prim("max"), v("y"))))
    er2 = R.tup_rnz_fuse(er)
    assert er2 is not None
    o1, o2 = run(er, x=x, y=y), run(er2, x=x, y=y)
    np.testing.assert_allclose(o1[0], o2[0], rtol=1e-10)
    np.testing.assert_allclose(o1[1], o2[1], rtol=1e-10)


@given(n=shapes, seed=seeds)
@settings(max_examples=20, deadline=None)
def test_fanout_fusion_eq32(n, seed):
    rng = np.random.default_rng(seed)
    x = mk(rng, n)
    f = lam("a", App(Prim("*"), (v("a"), Lit(2.0))))
    g = lam("a", App(Prim("neg"), (v("a"),)))
    e = Tup((map1(f, v("x")), map1(g, v("x"))))
    e2 = R.fanout_fuse(e)
    assert e2 is not None and isinstance(e2, MapN)
    o1, o2 = run(e, x=x), run(e2, x=x)
    np.testing.assert_allclose(o1[0], o2[0], rtol=1e-12)
    np.testing.assert_allclose(o1[1], o2[1], rtol=1e-12)


# -- exchange group ----------------------------------------------------------


@given(n=shapes, m=shapes, seed=seeds)
@settings(max_examples=40, deadline=None)
def test_map_map_exchange_eq36(n, m, seed):
    rng = np.random.default_rng(seed)
    w, u = mk(rng, n), mk(rng, m)
    e = map1(
        lam("x", map1(lam("y", App(Prim("*"), (v("x"), v("y")))), v("u"))),
        v("w"),
    )
    check_rule(R.map_map_exchange, e, w=w, u=u)


@given(n=shapes, m=shapes, seed=seeds)
@settings(max_examples=40, deadline=None)
def test_map_rnz_exchange_eq42(n, m, seed):
    """THE locality rule: row-wise matvec == column-accumulation matvec."""
    rng = np.random.default_rng(seed)
    A, u = mk(rng, n, m), mk(rng, m)
    e = map1(lam("r", RNZ(Prim("+"), Prim("*"), (v("r"), v("u")))), v("A"))
    e2 = check_rule(R.map_rnz_exchange, e, A=A, u=u)
    # result must be an RNZ at the top with a flipped operand
    assert isinstance(e2, RNZ)
    assert isinstance(e2.args[0], Flip)


@given(n=shapes, m=shapes, seed=seeds)
@settings(max_examples=40, deadline=None)
def test_rnz_map_exchange_roundtrip(n, m, seed):
    """eq 42 applied forwards then backwards returns to a map-of-rnz."""
    rng = np.random.default_rng(seed)
    A, u = mk(rng, n, m), mk(rng, m)
    e = map1(lam("r", RNZ(Prim("+"), Prim("*"), (v("r"), v("u")))), v("A"))
    e2 = apply_at(e, find_matches(e, R.map_rnz_exchange)[0], R.map_rnz_exchange)
    paths = find_matches(e2, R.rnz_map_exchange)
    assert paths, f"inverse rule must match the forward result: {e2!r}"
    e3 = apply_at(e2, paths[0], R.rnz_map_exchange)
    np.testing.assert_allclose(run(e3, A=A, u=u), run(e, A=A, u=u), rtol=1e-10)
    # flip(flip(A)) cancels structurally after normalization
    e3n = normalize(e3, [R.flip_flip])
    assert not find_matches(e3n, lambda x: x if isinstance(x, Flip) else None)


@given(n=shapes, m=shapes, seed=seeds)
@settings(max_examples=40, deadline=None)
def test_rnz_rnz_exchange_eq43(n, m, seed):
    rng = np.random.default_rng(seed)
    A, B = mk(rng, n, m), mk(rng, n)
    # sum_i sum_j A_ij * B_i   — inner rnz consumes rows of A zipped with B
    e = RNZ(
        Prim("+"),
        lam(
            "a",
            RNZ(Prim("+"), Prim("*"), (Var("a"), v("B"))),
        ),
        (v("A"),),
    )
    # inner args = (Var a, B): B's outer extent must equal a's => need m == n
    # use square case for the zipped variant; general case via separate operand
    if n == m:
        check_rule(R.rnz_rnz_exchange, e, A=A, B=B)


@given(n=shapes, seed=seeds)
@settings(max_examples=30, deadline=None)
def test_rnz_rnz_exchange_two_operands(n, seed):
    rng = np.random.default_rng(seed)
    A1, A2, B = mk(rng, n, 4), mk(rng, n, 4), mk(rng, 4)
    e = RNZ(
        Prim("+"),
        lam(
            ("a1", "a2"),
            RNZ(
                Prim("+"),
                lam(
                    ("x", "y", "b"),
                    App(
                        Prim("*"),
                        (App(Prim("*"), (v("x"), v("y"))), v("b")),
                    ),
                ),
                (Var("a1"), Var("a2"), v("B")),
            ),
        ),
        (v("A1"), v("A2")),
    )
    check_rule(R.rnz_rnz_exchange, e, A1=A1, A2=A2, B=B)


# -- subdivision group ---------------------------------------------------------


@given(seed=seeds, nb=st.sampled_from([(6, 2), (6, 3), (8, 4), (12, 3)]))
@settings(max_examples=30, deadline=None)
def test_map_subdiv_eq44(seed, nb):
    n, b = nb
    rng = np.random.default_rng(seed)
    x = mk(rng, n)
    f = lam("a", App(Prim("*"), (v("a"), v("a"))))
    e = map1(f, v("x"))
    rule = R.make_map_subdiv(b)
    e2 = rule(e)
    assert e2 is not None
    np.testing.assert_allclose(run(e2, x=x), run(e, x=x), rtol=1e-12)


@given(seed=seeds, nb=st.sampled_from([(6, 2), (6, 3), (8, 4), (12, 3)]))
@settings(max_examples=30, deadline=None)
def test_rnz_subdiv_regroup(seed, nb):
    n, b = nb
    rng = np.random.default_rng(seed)
    u, w = mk(rng, n), mk(rng, n)
    e = dot(v("u"), v("w"))
    rule = R.make_rnz_subdiv(b)
    e2 = rule(e)
    assert e2 is not None
    np.testing.assert_allclose(run(e2, u=u, w=w), run(e, u=u, w=w), rtol=1e-10)


# -- composed pipelines --------------------------------------------------------


def test_fusion_pipeline_eq1():
    """Motivating example eq 1 fuses to a single rnz with no temporaries."""
    rng = np.random.default_rng(0)
    A, B, vv, u = (
        rng.standard_normal((3, 4)),
        rng.standard_normal((3, 4)),
        rng.standard_normal(4),
        rng.standard_normal(4),
    )
    row_sum = zip2(Prim("+"), v("rA"), v("rB"))
    vec_sum = zip2(Prim("+"), v("vv"), v("u"))
    e = MapN(
        lam(("rA", "rB"), reduce1(Prim("+"), zip2(Prim("*"), row_sum, vec_sum))),
        (v("A"), v("B")),
    )
    trace = Trace()
    fused = fuse(e, trace=trace)
    np.testing.assert_allclose(
        run(fused, A=A, B=B, vv=vv, u=u), (A + B) @ (vv + u), rtol=1e-10
    )

    # after fusion, there must be no nested MapN under the rnz arguments:
    # the zips have been folded into the rnz zipper (no temporaries).
    def count(ty, e):
        n = int(isinstance(e, ty))
        return n + sum(count(ty, c) for c in E.children(e))

    body = fused
    assert isinstance(body, MapN)
    inner = body.f.body if isinstance(body.f, Lam) else None
    assert isinstance(inner, RNZ)
    assert all(not isinstance(a, MapN) for a in inner.args)
    assert len(trace.steps) >= 3


def test_beta_eta():
    e = App(lam("x", App(Prim("+"), (v("x"), Lit(1.0)))), (Lit(2.0),))
    assert run(normalize(e, [R.beta]), ) == 3.0
    f = lam("x", App(Prim("neg"), (v("x"),)))
    assert R.eta(f) == Prim("neg")
