"""Golden regression: plan-DB on-disk format — grad and mesh keys included.

``tests/data/plan_db_golden.json`` is a committed snapshot of the ranked
plan database ``search_schedule`` writes (PLAN_VERSION 3, hardware
fingerprint pinned to ``golden/fixture-hw``), mirroring
``tests/test_cache_golden.py`` for the PR-2/PR-3 formats.  It covers the
forward ``matmul`` key (f32 + bf16), the derived backward keys
``matmul.dA`` / ``matmul.dB`` (``grad.derive`` names), AND the
mesh-qualified keys of the distributed tier (``matmul@mesh=2x4`` fwd +
``matmul.dA@mesh=2x4`` — the keys ``ops._mesh_plan_kernel`` looks up when
a 2x4 mesh is active), because one fleet DB serves single-device and
sharded plans side by side:

  * key derivation must keep producing the committed hex digests — a
    silent drift would cold-start every fleet's searched plans (the mesh
    keys specifically, which no single-device test would catch);
  * stored ranked entries must keep deserializing, validating and
    round-tripping byte-identically — mesh levels and the ``collective``
    field included;
  * ``PlanDB.best_schedule`` / ``best_sharded_entry`` (the exact lookups
    ``ops._tuned_kernel`` performs) must return the stored winners.

PLAN_VERSION history: v1 = PR-2/PR-3 single-device format; v2 = the mesh
tier — keys gained the ``mesh`` qualifier and rungs the ``collective``
field; v3 = observability (this file's pin) — entries self-describe with
``spec``/``dtype`` and carry a ``cuts`` bound-cut sample, rungs carry the
``explain`` roofline terms (what ``scripts/obs_report.py --explain``
renders); every v1/v2 key went cold deliberately (see the migration note
in ``search/plandb.py``).

ISSUE 8 extends the committed surface to the fused families: the
``attention@HxSxTxDxE`` key (plus its derived ``attention.dQ/.dK/.dV``)
and the ``grouped_matmul@GxKxF+sizes`` key (plus ``.dX/.dW``, GroupedSpecs
themselves).  Their signatures fold ``fused_meta()`` (causal flag, group
sizes) into the digest, so a causal attention plan can never be served to
a full-attention call site — pinned below without fixture entries.

ISSUE 10 adds the quantized tier: the ``matmul@512x512x512@dtype=int8``
and ``@dtype=float8_e4m3fn`` keys (``quantize_spec`` re-taggings; the
signature folds the ``quant`` metadata so a quant plan key can never
collide with the bf16/f32 key at the same geometry), and pins that the
``obs_report --explain`` ``@dtype=`` selector resolves them.

Regenerate only after a deliberate format bump (``PLAN_VERSION``):

    import numpy as np
    import repro.codegen.cache as cache_mod
    cache_mod.hardware_fingerprint = lambda: "golden/fixture-hw"
    from repro.core.enumerate import (
        attention_spec, matmul_spec, quantize_spec, uniform_grouped_spec,
    )
    from repro.grad import derived_specs
    from repro.search import PlanDB, search_schedule
    db = PlanDB("tests/data/plan_db_golden.json")
    fwd = matmul_spec(512, 512, 512); d = derived_specs(fwd)
    attn = attention_spec(4, 64, 64, 8); da = derived_specs(attn)
    grp = uniform_grouped_spec(4, 16, 32, 32); dg = derived_specs(grp)
    f32 = np.dtype(np.float32)
    for spec, dt, mesh in [
        (fwd, f32, None),
        (fwd, np.dtype("bfloat16"), None),
        (d["A"], f32, None),
        (d["B"], f32, None),
        (fwd, f32, (2, 4)),
        (d["A"], f32, (2, 4)),
        (attn, f32, None),
        (da["Q"], f32, None), (da["K"], f32, None), (da["V"], f32, None),
        (grp, f32, None),
        (dg["X"], f32, None), (dg["W"], f32, None),
        (quantize_spec(fwd, fmt="int8"), np.dtype(np.int8), None),
        (quantize_spec(fwd, fmt="fp8"), np.dtype("float8_e4m3fn"), None),
    ]:
        search_schedule(spec, dtype=dt, beam_width=4, topk=3,
                        measure=False, plan_db=db, use_cached_plan=False,
                        mesh_shape=mesh)
"""

from __future__ import annotations

import json
import os
import shutil

import numpy as np
import pytest

import repro.codegen.cache as cache_mod
from repro.codegen.cache import schedule_from_dict, schedule_to_dict
from repro.core.enumerate import (
    attention_spec,
    matmul_spec,
    quantize_spec,
    uniform_grouped_spec,
)
from repro.core.schedule import MESH_TIERS
from repro.grad import derived_specs
from repro.search import PlanDB
from repro.search.plandb import PLAN_VERSION, grad_plan_keys, plan_key

FIXTURE = os.path.join(
    os.path.dirname(__file__), "data", "plan_db_golden.json"
)
GOLDEN_HW = "golden/fixture-hw"

_FWD = matmul_spec(512, 512, 512)
_D = derived_specs(_FWD)
_ATTN = attention_spec(4, 64, 64, 8)
_DA = derived_specs(_ATTN)
_GRP = uniform_grouped_spec(4, 16, 32, 32)
_DG = derived_specs(_GRP)
_F32 = np.dtype(np.float32)

#: (label, spec, dtype, mesh descriptor)
FIXTURE_POINTS = [
    ("matmul-f32", _FWD, _F32, None),
    ("matmul-bf16", _FWD, np.dtype("bfloat16"), None),
    ("matmul.dA", _D["A"], _F32, None),
    ("matmul.dB", _D["B"], _F32, None),
    ("matmul@mesh=2x4", _FWD, _F32, "2x4"),
    ("matmul.dA@mesh=2x4", _D["A"], _F32, "2x4"),
    # ISSUE 8: the fused families and their full backward key fans
    ("attention", _ATTN, _F32, None),
    ("attention.dQ", _DA["Q"], _F32, None),
    ("attention.dK", _DA["K"], _F32, None),
    ("attention.dV", _DA["V"], _F32, None),
    ("grouped_matmul", _GRP, _F32, None),
    ("grouped_matmul.dX", _DG["X"], _F32, None),
    ("grouped_matmul.dW", _DG["W"], _F32, None),
    # ISSUE 10: the quantized tier's dtype-qualified keys
    ("matmul@int8", quantize_spec(_FWD, fmt="int8"),
     np.dtype(np.int8), None),
    ("matmul@fp8", quantize_spec(_FWD, fmt="fp8"),
     np.dtype("float8_e4m3fn"), None),
]


@pytest.fixture()
def fixture_data():
    with open(FIXTURE) as f:
        return json.load(f)


def test_plan_version_is_pinned():
    """Bumping PLAN_VERSION invalidates every key below — this test makes
    sure the bump happens deliberately, fixture regenerated alongside.
    v3 = observability (self-describing spec/dtype, explain terms,
    bound-cut sample)."""
    assert PLAN_VERSION == 3


def test_fixture_is_wellformed(fixture_data):
    assert len(fixture_data) == len(FIXTURE_POINTS)
    mesh_entries = 0
    for entry in fixture_data.values():
        assert set(entry) >= {"v", "ranked", "stats", "spec", "dtype", "cuts"}
        assert entry["v"] == PLAN_VERSION
        assert entry["ranked"], "empty ranked ladder in fixture"
        # v3: entries self-describe so obs_report --explain can find them
        assert entry["spec"].get("name"), "entry spec lacks a name"
        assert "extents" in entry["spec"]
        if entry.get("mesh"):
            mesh_entries += 1
        for rung in entry["ranked"]:
            assert set(rung) >= {
                "schedule", "score", "lower_bound", "fits_vmem",
                "measured_s", "source", "collective", "explain",
            }
            assert set(rung["schedule"]) == {"splits", "levels"}
            if rung["source"] == "search":
                assert {"compute_s", "hbm_s", "comm_s", "penalty"} <= set(
                    rung["explain"]
                ), "search rung missing roofline explain terms"
    assert mesh_entries == 2, "mesh-qualified entries missing from fixture"


@pytest.mark.parametrize(
    "label,spec,dtype,mesh", FIXTURE_POINTS,
    ids=[p[0] for p in FIXTURE_POINTS],
)
def test_plan_key_derivation_is_stable(fixture_data, label, spec, dtype, mesh):
    key = plan_key(spec, dtype, hardware=GOLDEN_HW, mesh=mesh)
    assert key in fixture_data, (
        f"plan-DB key for {label} drifted — every fleet's searched plans "
        f"(mesh-qualified and backward included) would go cold on "
        f"upgrade.  If deliberate, bump PLAN_VERSION and regenerate the "
        f"fixture."
    )


def test_grad_plan_keys_match_derived_fixture_keys(fixture_data):
    """grad_plan_keys (what the custom-VJP backward lookups use) must
    address exactly the committed dA/dB entries — the mesh-qualified dA
    key too (what a backward pass under an active 2x4 mesh consults)."""
    keys = grad_plan_keys(_FWD, np.float32, hardware=GOLDEN_HW)
    assert set(keys) == {"A", "B"}
    for wrt, key in keys.items():
        assert key in fixture_data, f"derived key for d{wrt} drifted"
    mesh_keys = grad_plan_keys(
        _FWD, np.float32, hardware=GOLDEN_HW, mesh="2x4"
    )
    assert mesh_keys["A"] in fixture_data, "mesh-qualified dA key drifted"
    assert mesh_keys["A"] != keys["A"]
    # and they are disjoint from the forward keys
    fwd = plan_key(_FWD, np.float32, hardware=GOLDEN_HW)
    fwd_mesh = plan_key(_FWD, np.float32, hardware=GOLDEN_HW, mesh="2x4")
    assert fwd != fwd_mesh
    assert fwd not in keys.values() and fwd_mesh not in mesh_keys.values()


def test_fused_grad_plan_keys_match_fixture(fixture_data):
    """The fused families' backward lookups address the committed derived
    entries: attention fans to dQ/dK/dV, grouped to dX/dW."""
    akeys = grad_plan_keys(_ATTN, np.float32, hardware=GOLDEN_HW)
    assert set(akeys) == {"Q", "K", "V"}
    gkeys = grad_plan_keys(_GRP, np.float32, hardware=GOLDEN_HW)
    assert set(gkeys) == {"X", "W"}
    for wrt, key in {**akeys, **gkeys}.items():
        assert key in fixture_data, f"fused derived key d{wrt} drifted"
    fused_fwd = {
        plan_key(_ATTN, np.float32, hardware=GOLDEN_HW),
        plan_key(_GRP, np.float32, hardware=GOLDEN_HW),
    }
    assert fused_fwd.isdisjoint({*akeys.values(), *gkeys.values()})


def test_fused_meta_is_part_of_the_key():
    """causal and group_sizes live in fused_meta -> the digest: a causal
    plan must never be served to a full-attention site, nor a plan tuned
    for one partition to a differently-ragged one."""
    full = plan_key(_ATTN, np.float32, hardware=GOLDEN_HW)
    causal = plan_key(
        attention_spec(4, 64, 64, 8, causal=True), np.float32,
        hardware=GOLDEN_HW,
    )
    assert full != causal
    ragged = uniform_grouped_spec(4, 16, 32, 32)
    from repro.core.enumerate import grouped_matmul_spec

    other = grouped_matmul_spec((0, 32, 16, 16), 32, 32)  # same extents
    assert other.extents == ragged.extents
    assert plan_key(ragged, np.float32, hardware=GOLDEN_HW) != plan_key(
        other, np.float32, hardware=GOLDEN_HW
    )


def test_quant_keys_disjoint_from_full_precision(fixture_data):
    """The quant tier's keys can never collide with the bf16/f32 ladders
    at the same geometry: the signature folds the quant metadata AND the
    dtype string differs — either alone would already separate them."""
    qspec = quantize_spec(_FWD, fmt="int8")
    qkey = plan_key(qspec, np.dtype(np.int8), hardware=GOLDEN_HW)
    full_keys = {
        plan_key(_FWD, _F32, hardware=GOLDEN_HW),
        plan_key(_FWD, np.dtype("bfloat16"), hardware=GOLDEN_HW),
        plan_key(_FWD, _F32, hardware=GOLDEN_HW, mesh="2x4"),
    }
    assert qkey not in full_keys
    # belt and braces: even at the SAME dtype string, the re-tagged spec
    # keys apart from the plain one
    assert plan_key(
        qspec, np.dtype("bfloat16"), hardware=GOLDEN_HW
    ) != plan_key(_FWD, np.dtype("bfloat16"), hardware=GOLDEN_HW)
    # and the committed entries self-describe their quant storage
    entry = fixture_data[qkey]
    assert entry["dtype"] == "int8"
    assert entry["spec"]["quant"] == {
        "dtype": "int8", "accum": "int32", "scale": "per_channel",
    }
    fp8 = fixture_data[plan_key(
        quantize_spec(_FWD, fmt="fp8"), np.dtype("float8_e4m3fn"),
        hardware=GOLDEN_HW,
    )]
    assert fp8["spec"]["quant"]["accum"] == "float32"
    # full-precision entries must NOT grow a quant field (signature stays
    # byte-identical for existing keys)
    f32_entry = fixture_data[plan_key(_FWD, _F32, hardware=GOLDEN_HW)]
    assert "quant" not in f32_entry["spec"]


def test_explain_selector_resolves_quant_dtype():
    """``obs_report --explain 'matmul@512x512x512@dtype=int8'`` must find
    exactly the quant entry — the human-facing route to a quant ladder."""
    from repro.obs.explain import explain, match_entries

    with open(FIXTURE) as f:
        data = json.load(f)
    hits = match_entries(data, "matmul@512x512x512@dtype=int8")
    assert len(hits) == 1
    key, entry = hits[0]
    assert key == plan_key(
        quantize_spec(_FWD, fmt="int8"), np.dtype(np.int8),
        hardware=GOLDEN_HW,
    )
    assert entry["spec"]["quant"]["dtype"] == "int8"
    rendered = explain(FIXTURE, "matmul@512x512x512@dtype=int8")
    assert "@dtype=int8" in rendered
    # the unqualified selector must keep resolving to the f32 ladder,
    # not the quant one
    base_hits = match_entries(data, "matmul@512x512x512@dtype=float32")
    assert len(base_hits) == 1 and base_hits[0][0] != key


@pytest.mark.parametrize(
    "label,spec,dtype,mesh", FIXTURE_POINTS,
    ids=[p[0] for p in FIXTURE_POINTS],
)
def test_ranked_schedules_roundtrip(fixture_data, label, spec, dtype, mesh):
    entry = fixture_data[plan_key(spec, dtype, hardware=GOLDEN_HW, mesh=mesh)]
    sharded_rungs = 0
    for rung in entry["ranked"]:
        sched = schedule_from_dict(rung["schedule"], spec.root())
        assert schedule_to_dict(sched) == rung["schedule"], label
        sched.validate()
        if any(l.tier in MESH_TIERS for l in sched.levels):
            sharded_rungs += 1
    if mesh:
        assert sharded_rungs >= 1, f"{label}: mesh ladder has no mesh:* rung"


def test_best_schedule_serves_golden_winner(tmp_path, monkeypatch):
    """End to end: a fleet plan-DB file keeps serving its stored winners
    through the exact lookups ops._tuned_kernel performs — best_schedule
    for single-device keys, best_sharded_entry for mesh keys."""
    monkeypatch.setattr(
        cache_mod, "hardware_fingerprint", lambda: GOLDEN_HW
    )
    path = tmp_path / "plans.json"
    shutil.copy(FIXTURE, path)
    db = PlanDB(str(path))
    with open(FIXTURE) as f:
        data = json.load(f)
    for label, spec, dtype, mesh in FIXTURE_POINTS:
        sched = db.best_schedule(spec, dtype, mesh=mesh)
        assert sched is not None, f"{label}: plan-DB lookup missed"
        want = data[plan_key(spec, dtype, hardware=GOLDEN_HW, mesh=mesh)]
        assert schedule_to_dict(sched) == want["ranked"][0]["schedule"], label
        if mesh:
            sharded, entry = db.best_sharded_entry(spec, dtype, mesh=mesh)
            assert sharded is not None, f"{label}: sharded lookup missed"
            assert any(l.tier in MESH_TIERS for l in sharded.levels)
            assert entry.get("collective") is not None
