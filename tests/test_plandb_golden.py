"""Golden regression: plan-DB on-disk format, derived-grad keys included.

``tests/data/plan_db_golden.json`` is a committed snapshot of the ranked
plan database ``search_schedule`` writes (PLAN_VERSION 1, hardware
fingerprint pinned to ``golden/fixture-hw``), mirroring
``tests/test_cache_golden.py`` for the PR-2/PR-3 formats.  It covers the
forward ``matmul`` key (f32 + bf16) AND the derived backward keys
``matmul.dA`` / ``matmul.dB`` (``grad.derive`` names), because training
fleets share one plan DB for both sides of the tape:

  * key derivation must keep producing the committed hex digests — a
    silent drift would cold-start every fleet's searched plans (and
    training's backward plans specifically, which no forward-only test
    would catch);
  * stored ranked entries must keep deserializing, validating and
    round-tripping byte-identically;
  * ``PlanDB.best_schedule`` (the exact lookup ``ops._tuned_kernel``
    performs) must return the stored winner for every fixture key.

Regenerate only after a deliberate format bump (``PLAN_VERSION``):

    import numpy as np
    import repro.codegen.cache as cache_mod
    cache_mod.hardware_fingerprint = lambda: "golden/fixture-hw"
    from repro.core.enumerate import matmul_spec
    from repro.grad import derived_specs
    from repro.search import PlanDB, search_schedule
    db = PlanDB("tests/data/plan_db_golden.json")
    fwd = matmul_spec(512, 512, 512); d = derived_specs(fwd)
    for spec, dt in [(fwd, np.dtype(np.float32)),
                     (fwd, np.dtype("bfloat16")),
                     (d["A"], np.dtype(np.float32)),
                     (d["B"], np.dtype(np.float32))]:
        search_schedule(spec, dtype=dt, beam_width=4, topk=3,
                        measure=False, plan_db=db, use_cached_plan=False)
"""

from __future__ import annotations

import json
import os
import shutil

import numpy as np
import pytest

import repro.codegen.cache as cache_mod
from repro.codegen.cache import schedule_from_dict, schedule_to_dict
from repro.core.enumerate import matmul_spec
from repro.grad import derived_specs
from repro.search import PlanDB
from repro.search.plandb import PLAN_VERSION, grad_plan_keys, plan_key

FIXTURE = os.path.join(
    os.path.dirname(__file__), "data", "plan_db_golden.json"
)
GOLDEN_HW = "golden/fixture-hw"

_FWD = matmul_spec(512, 512, 512)
_D = derived_specs(_FWD)

FIXTURE_POINTS = [
    ("matmul-f32", _FWD, np.dtype(np.float32)),
    ("matmul-bf16", _FWD, np.dtype("bfloat16")),
    ("matmul.dA", _D["A"], np.dtype(np.float32)),
    ("matmul.dB", _D["B"], np.dtype(np.float32)),
]


@pytest.fixture()
def fixture_data():
    with open(FIXTURE) as f:
        return json.load(f)


def test_plan_version_is_pinned():
    """Bumping PLAN_VERSION invalidates every key below — this test makes
    sure the bump happens deliberately, fixture regenerated alongside."""
    assert PLAN_VERSION == 1


def test_fixture_is_wellformed(fixture_data):
    assert len(fixture_data) == len(FIXTURE_POINTS)
    for entry in fixture_data.values():
        assert set(entry) >= {"v", "ranked", "stats"}
        assert entry["v"] == PLAN_VERSION
        assert entry["ranked"], "empty ranked ladder in fixture"
        for rung in entry["ranked"]:
            assert set(rung) >= {
                "schedule", "score", "lower_bound", "fits_vmem",
                "measured_s", "source",
            }
            assert set(rung["schedule"]) == {"splits", "levels"}


@pytest.mark.parametrize(
    "label,spec,dtype", FIXTURE_POINTS, ids=[p[0] for p in FIXTURE_POINTS],
)
def test_plan_key_derivation_is_stable(fixture_data, label, spec, dtype):
    key = plan_key(spec, dtype, hardware=GOLDEN_HW)
    assert key in fixture_data, (
        f"plan-DB key for {label} drifted — every fleet's searched plans "
        f"(backward included) would go cold on upgrade.  If deliberate, "
        f"bump PLAN_VERSION and regenerate the fixture."
    )


def test_grad_plan_keys_match_derived_fixture_keys(fixture_data):
    """grad_plan_keys (what the custom-VJP backward lookups use) must
    address exactly the committed dA/dB entries."""
    keys = grad_plan_keys(_FWD, np.float32, hardware=GOLDEN_HW)
    assert set(keys) == {"A", "B"}
    for wrt, key in keys.items():
        assert key in fixture_data, f"derived key for d{wrt} drifted"
    # and they are disjoint from the forward key
    assert plan_key(_FWD, np.float32, hardware=GOLDEN_HW) not in keys.values()


@pytest.mark.parametrize(
    "label,spec,dtype", FIXTURE_POINTS, ids=[p[0] for p in FIXTURE_POINTS],
)
def test_ranked_schedules_roundtrip(fixture_data, label, spec, dtype):
    entry = fixture_data[plan_key(spec, dtype, hardware=GOLDEN_HW)]
    for rung in entry["ranked"]:
        sched = schedule_from_dict(rung["schedule"], spec.root())
        assert schedule_to_dict(sched) == rung["schedule"], label
        sched.validate()


def test_best_schedule_serves_golden_winner(tmp_path, monkeypatch):
    """End to end: a fleet plan-DB file keeps serving its stored winners
    through the exact lookup ops._tuned_kernel performs."""
    monkeypatch.setattr(
        cache_mod, "hardware_fingerprint", lambda: GOLDEN_HW
    )
    path = tmp_path / "plans.json"
    shutil.copy(FIXTURE, path)
    db = PlanDB(str(path))
    with open(FIXTURE) as f:
        data = json.load(f)
    for label, spec, dtype in FIXTURE_POINTS:
        sched = db.best_schedule(spec, dtype)
        assert sched is not None, f"{label}: plan-DB lookup missed"
        want = data[plan_key(spec, dtype, hardware=GOLDEN_HW)]
        assert schedule_to_dict(sched) == want["ranked"][0]["schedule"], label
