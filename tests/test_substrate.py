"""Substrate tests: optimizer (f32 + 8-bit), quantization, compression,
checkpointing (atomic/async/elastic), data determinism, fault loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import checkpoint as ckpt
from repro.data.pipeline import DataConfig, batch_at
from repro.optim import (
    AdamWConfig, compress_decompress, dequantize, init as adam_init,
    quantize, update as adam_update, warmup_cosine,
)
from repro.runtime.fault import FaultTolerantLoop, LoopConfig, StepFailure


# -- quantization --------------------------------------------------------------


@given(
    shape=st.sampled_from([(7,), (128,), (3, 130), (16, 16)]),
    seed=st.integers(0, 1000),
)
@settings(max_examples=40, deadline=None)
def test_quantize_roundtrip_error_bound(shape, seed):
    x = jnp.asarray(
        np.random.default_rng(seed).standard_normal(shape), jnp.float32
    )
    q = quantize(x)
    y = dequantize(q)
    assert y.shape == x.shape and y.dtype == x.dtype
    # blockwise absmax int8: error <= absmax/254 per block
    err = np.abs(np.asarray(y - x))
    bound = np.abs(np.asarray(x)).max() / 254 + 1e-7
    assert err.max() <= bound * 1.0001


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(512), jnp.float32) * 1e-3
    residual = jnp.zeros_like(g)
    total_sent = jnp.zeros_like(g)
    for _ in range(50):
        sent, residual = compress_decompress(g, residual)
        total_sent = total_sent + sent
    # with error feedback the time-average converges to the true gradient
    np.testing.assert_allclose(
        np.asarray(total_sent / 50), np.asarray(g), atol=5e-6
    )


# -- optimizer ------------------------------------------------------------------


def _quadratic_params():
    return {"w": jnp.asarray([2.0, -3.0, 1.5]), "b": jnp.asarray([0.5])}


@pytest.mark.parametrize("moments", ["float32", "bfloat16", "int8"])
def test_adamw_optimizes_quadratic(moments):
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, moments_dtype=moments)
    params = _quadratic_params()
    state = adam_init(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, metrics = adam_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-2, moments
    assert jnp.isfinite(metrics["grad_norm"])


def test_adamw_int8_states_are_actually_small():
    cfg = AdamWConfig(moments_dtype="int8")
    params = {"w": jnp.zeros((1024, 1024), jnp.bfloat16)}
    state = adam_init(params, cfg)
    q = state.m["w"]
    nbytes = q.q.size + q.scale.size * 4
    assert nbytes < 1.1 * 1024 * 1024  # ~1.02 B/param vs 4 B/param f32


def test_grad_clip_and_schedule():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.asarray([0.0])}
    state = adam_init(params, cfg)
    g = {"w": jnp.asarray([1e6])}
    new_params, state, metrics = adam_update(g, state, params, cfg)
    assert float(metrics["clip_scale"]) < 1e-5
    assert abs(float(new_params["w"][0])) < 1.1  # clipped step
    sched = warmup_cosine(10, 100)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1.0)
    assert float(sched(100)) == pytest.approx(0.1, abs=1e-6)


# -- checkpointing ---------------------------------------------------------------


def test_checkpoint_roundtrip_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6).reshape(2, 3), "n": {"b": jnp.ones(4)}}
    ckpt.save(d, 10, tree, extra={"loss": 1.5})
    ckpt.save(d, 20, jax.tree.map(lambda x: x * 2, tree))
    assert ckpt.latest_step(d) == 20
    restored, manifest = ckpt.restore(d, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]) * 2)
    restored10, m10 = ckpt.restore(d, tree, step=10)
    assert m10["extra"]["loss"] == 1.5
    np.testing.assert_array_equal(np.asarray(restored10["n"]["b"]),
                                  np.ones(4))


def test_checkpoint_atomicity_tmp_ignored(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.ones(3)}
    ckpt.save(d, 1, tree)
    # simulate a crash mid-write of step 2
    os.makedirs(os.path.join(d, "step_2.tmp"))
    assert ckpt.latest_step(d) == 1


def test_checkpoint_manager_async_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    mgr = ckpt.CheckpointManager(d, keep=2)
    tree = {"a": jnp.ones(3)}
    for s in (1, 2, 3, 4):
        mgr.save_async(s, jax.tree.map(lambda x: x * s, tree))
    mgr.close()
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(d) if n.startswith("step_")
    )
    assert steps == [3, 4]
    restored, _ = ckpt.restore(d, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), 4 * np.ones(3))


def test_checkpoint_elastic_reshard(tmp_path):
    """Save unsharded, restore with an explicit (different) sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(8.0)}
    ckpt.save(d, 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = ckpt.restore(d, tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8.0))


# -- data pipeline -----------------------------------------------------------------


def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8)
    b1, b2 = batch_at(cfg, 5), batch_at(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # two hosts partition the global batch exactly
    h0 = batch_at(DataConfig(1000, 32, 8, n_hosts=2, host_id=0), 5)
    h1 = batch_at(DataConfig(1000, 32, 8, n_hosts=2, host_id=1), 5)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), b1["tokens"]
    )
    assert (batch_at(cfg, 6)["tokens"] != b1["tokens"]).any()


# -- fault-tolerant loop --------------------------------------------------------------


def test_fault_loop_restores_and_replays(tmp_path):
    """Inject a failure; the loop must restore and converge to the same
    final state a failure-free run produces (deterministic replay)."""
    saved = {}

    def make_loop(fail_at=None):
        state0 = 0.0
        calls = {"n": 0}

        def step_fn(step, state):
            if fail_at is not None and step == fail_at and calls["n"] == 0:
                calls["n"] += 1
                raise StepFailure("injected")
            return state + step  # deterministic in step

        def save_fn(step, state):
            saved[step] = state

        def restore_fn():
            s = max(saved)
            return s, saved[s]

        return FaultTolerantLoop(
            step_fn=step_fn, save_fn=save_fn, restore_fn=restore_fn,
            config=LoopConfig(checkpoint_every=3, max_retries=2),
        )

    saved.clear(); saved[0] = 0.0
    clean = make_loop(None).run(0.0, 0, 10)
    saved.clear(); saved[0] = 0.0
    loop = make_loop(fail_at=7)
    faulty = loop.run(0.0, 0, 10)
    assert faulty == clean
    assert loop.report.failures == 1 and loop.report.restores == 1


def test_fault_loop_escalates_after_retries():
    def step_fn(step, state):
        raise StepFailure("always")

    loop = FaultTolerantLoop(
        step_fn=step_fn, save_fn=lambda *a: None,
        restore_fn=lambda: (0, 0.0),
        config=LoopConfig(max_retries=2),
    )
    with pytest.raises(StepFailure):
        loop.run(0.0, 0, 5)
    assert loop.report.failures == 3


def test_straggler_watchdog():
    times = iter([0.0, 1.0,   # step 0: 1s
                  1.0, 2.0,   # step 1
                  2.0, 3.0, 3.0, 4.0, 4.0, 5.0,
                  5.0, 30.0,  # step 5: 25s straggler
                  30.0, 31.0, 31.0, 32.0])
    loop = FaultTolerantLoop(
        step_fn=lambda s, st: st,
        save_fn=lambda *a: None,
        restore_fn=lambda: (0, 0.0),
        config=LoopConfig(checkpoint_every=1000, straggler_factor=3.0),
        clock=lambda: next(times),
    )
    loop.run(0.0, 0, 8)
    assert 5 in loop.report.straggler_events
