"""Layout algebra: strided semantics vs the logical (reshape/transpose) oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.layout import Layout, View


def random_chain_ops(draw, rank_limit=5):
    """Hypothesis helper: a sequence of (op, args) applicable to a layout."""


def test_row_major_example_from_paper():
    # a^((3,1),(2,3),(5,6),(4,30)) — the paper's 120-element 4-D tensor
    lay = Layout.row_major((4, 5, 2, 3))
    assert lay.dims == ((3, 1), (2, 3), (5, 6), (4, 30))
    assert lay.size == 120


def test_subdiv_matches_paper_example():
    # subdividing the (2,15),(5,3) interpretation of the same 120 elements:
    # a 6x10 row-major matrix subdivided into 2x3 blocks in a 3x5 block grid
    base = Layout.row_major((10, 6))  # 10 rows of 6
    sub = base.subdiv(0, 3).subdiv(2, 2)
    # dims: (3,1),(2,3) within-block, then (2,?) ... verify via materialize
    buf = np.arange(60)
    v = View(buf, sub)
    m = v.materialize()
    full = buf.reshape(10, 6)
    # block (i,j) should be full[2i:2i+2? ...] — check one corner block
    # dims innermost-first: (3,1),(2,3) -> block cols 3 wide? Validate algebra:
    assert sub.size == 60
    assert m.size == 60


def test_subdiv_flatten_roundtrip():
    lay = Layout.row_major((8, 6))
    assert lay.subdiv(0, 3).flatten(0) == lay
    assert lay.subdiv(1, 2).flatten(1) == lay


def test_flip_involutive():
    lay = Layout.row_major((4, 5, 6))
    assert lay.flip(0, 2).flip(0, 2) == lay
    assert lay.flip(1).flip(1) == lay


def test_flip_is_transpose():
    buf = np.arange(12, dtype=np.float64)
    lay = Layout.row_major((3, 4))
    v = View(buf, lay)
    flipped = v.flip(0, 1)
    np.testing.assert_array_equal(
        flipped.materialize(), buf.reshape(3, 4).T
    )


def test_subdiv_semantics_against_logical_reshape():
    # strided subdiv on dim d  ==  logical reshape of axis (rank-1-d)
    buf = np.arange(24, dtype=np.float64)
    lay = Layout.row_major((4, 6))  # 4 rows x 6 cols
    v = View(buf, lay)
    sub = v.subdiv(0, 3)  # split cols into blocks of 3
    logical = buf.reshape(4, 6).reshape(4, 2, 3)
    np.testing.assert_array_equal(sub.materialize(), logical)
    sub2 = v.subdiv(1, 2)  # split rows into blocks of 2
    logical2 = buf.reshape(4, 6).reshape(2, 2, 6)
    np.testing.assert_array_equal(sub2.materialize(), logical2)


def test_flatten_requires_contiguity():
    lay = Layout.row_major((4, 6)).flip(0, 1)
    with pytest.raises(ValueError):
        lay.flatten(0)


@st.composite
def layout_and_ops(draw):
    # logical shape, outermost-first
    rank = draw(st.integers(1, 3))
    shape = tuple(
        draw(st.sampled_from([1, 2, 3, 4, 6])) for _ in range(rank)
    )
    lay = Layout.row_major(shape)
    ops = []
    for _ in range(draw(st.integers(0, 4))):
        kind = draw(st.sampled_from(["subdiv", "flip", "flatten"]))
        if kind == "subdiv" and lay.rank < 5:
            d = draw(st.integers(0, lay.rank - 1))
            e = lay.dims[d][0]
            divisors = [b for b in range(1, e + 1) if e % b == 0]
            b = draw(st.sampled_from(divisors))
            ops.append(("subdiv", d, b))
            lay = lay.subdiv(d, b)
        elif kind == "flip" and lay.rank >= 2:
            d1 = draw(st.integers(0, lay.rank - 2))
            d2 = draw(st.integers(d1 + 1, lay.rank - 1))
            ops.append(("flip", d1, d2))
            lay = lay.flip(d1, d2)
        elif kind == "flatten" and lay.rank >= 2:
            cands = [
                d
                for d in range(lay.rank - 1)
                if lay.dims[d + 1][1] == lay.dims[d][0] * lay.dims[d][1]
            ]
            if cands:
                d = draw(st.sampled_from(cands))
                ops.append(("flatten", d))
                lay = lay.flatten(d)
    return shape, ops, lay


@given(layout_and_ops())
@settings(max_examples=200, deadline=None)
def test_strided_equals_logical(case):
    """The strided algebra and the logical reshape/transpose semantics agree.

    This is the bridge between layout.py (paper's strides) and interp.py
    (logical numpy arrays): for any chain of subdiv/flip/flatten, materializing
    the strided view equals applying the logical ops to the logical array.
    """
    shape, ops, final_lay = case
    buf = np.arange(int(np.prod(shape)), dtype=np.float64)
    v = View(buf, Layout.row_major(shape))
    logical = buf.reshape(shape)
    for op in ops:
        if op[0] == "subdiv":
            _, d, b = op
            v = v.subdiv(d, b)
            ax = logical.ndim - 1 - d
            e = logical.shape[ax]
            logical = logical.reshape(
                logical.shape[:ax] + (e // b, b) + logical.shape[ax + 1 :]
            )
        elif op[0] == "flip":
            _, d1, d2 = op
            v = v.flip(d1, d2)
            logical = np.swapaxes(
                logical, logical.ndim - 1 - d1, logical.ndim - 1 - d2
            )
        else:
            _, d = op
            v = v.flatten(d)
            ax = logical.ndim - 2 - d
            logical = np.ascontiguousarray(logical).reshape(
                logical.shape[:ax]
                + (logical.shape[ax] * logical.shape[ax + 1],)
                + logical.shape[ax + 2 :]
            )
    np.testing.assert_array_equal(v.materialize(), logical)
    assert v.layout == final_lay


@given(layout_and_ops())
@settings(max_examples=200, deadline=None)
def test_separable_reshape_transpose_plan(case):
    """Every subdiv/flip/flatten-reachable layout lowers to reshape+transpose."""
    shape, ops, lay = case
    buf = np.arange(int(np.prod(shape)), dtype=np.float64)
    v = View(buf, Layout.row_major(shape))
    for op in ops:
        v = getattr(v, op[0])(*op[1:])
    assert v.layout.is_separable()
    rs, perm = v.layout.reshape_transpose_plan()
    np.testing.assert_array_equal(
        buf.reshape(rs).transpose(perm), v.materialize()
    )
