"""Pallas kernels vs pure-jnp oracles (interpret=True executes the kernel
body on CPU).  Shape/dtype sweeps + hypothesis block-shape property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.matmul.matmul import matmul_pallas
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.fused_rnz.fused_rnz import weighted_matmul_pallas
from repro.kernels.fused_rnz.ref import weighted_matmul_ref
from repro.kernels.fused_dense_act.fused_dense_act import fused_dense_act_pallas
from repro.kernels.fused_dense_act.ref import fused_dense_act_ref


def rnd(*shape, dtype=jnp.float32, seed=0):
    x = np.random.default_rng(seed + sum(shape)).standard_normal(shape)
    return jnp.asarray(x, dtype=dtype)


# bf16 atol covers 1-ulp noise from blocked accumulation order: outputs of
# magnitude ~16 have ulp 0.125, and small outputs inherit absolute error
# from the large intermediate sums they cancel down from.
TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=1e-1)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,n,k,bm,bn,bk",
    [
        (32, 32, 32, 16, 16, 16),
        (64, 48, 80, 16, 16, 16),
        (128, 128, 64, 64, 32, 32),
        (16, 128, 256, 8, 128, 128),
    ],
)
def test_matmul_kernel_sweep(m, n, k, bm, bn, bk, dtype):
    a, b = rnd(m, k, dtype=dtype), rnd(k, n, dtype=dtype, seed=1)
    out = matmul_pallas(a, b, block_m=bm, block_n=bn, block_k=bk, interpret=True)
    ref = matmul_ref(a, b)
    assert out.dtype == ref.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )


@given(
    mi=st.integers(1, 4), ni=st.integers(1, 4), ki=st.integers(1, 4),
    bm=st.sampled_from([8, 16]), bn=st.sampled_from([8, 16]),
    bk=st.sampled_from([8, 16]),
)
@settings(max_examples=12, deadline=None)
def test_matmul_kernel_block_property(mi, ni, ki, bm, bn, bk):
    """For any grid x block combination, kernel == oracle."""
    m, n, k = mi * bm, ni * bn, ki * bk
    a, b = rnd(m, k), rnd(k, n, seed=2)
    out = matmul_pallas(a, b, block_m=bm, block_n=bn, block_k=bk, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(matmul_ref(a, b)), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,n,k,bm,bn,bk",
    [(32, 32, 32, 16, 16, 16), (64, 48, 96, 16, 16, 32)],
)
def test_weighted_matmul_kernel(m, n, k, bm, bn, bk, dtype):
    a, b, g = rnd(m, k, dtype=dtype), rnd(k, n, dtype=dtype, seed=1), rnd(k, dtype=dtype, seed=2)
    out = weighted_matmul_pallas(
        a, b, g, block_m=bm, block_n=bn, block_k=bk, interpret=True
    )
    ref = weighted_matmul_ref(a, b, g)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )
    # the fusion point of paper eq 2: must equal einsum(ij,jk,j->ik)
    if dtype == jnp.float32:
        ein = np.einsum(
            "ij,jk,j->ik",
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            np.asarray(g, np.float32),
        )
        np.testing.assert_allclose(np.asarray(out, np.float32), ein, **TOL[dtype])


@pytest.mark.parametrize("act", ["relu", "gelu", "tanh", "id"])
def test_fused_dense_act_kernel(act):
    b, i, k = 32, 64, 48
    x, w = rnd(b, i), rnd(i, k, seed=1)
    beta, mean = rnd(k, seed=2), rnd(k, seed=3)
    var = jnp.abs(rnd(k, seed=4)) + 0.5
    out = fused_dense_act_pallas(
        x, w, beta, mean, var, act=act,
        block_b=16, block_k=16, block_i=16, interpret=True,
    )
    ref = fused_dense_act_ref(x, w, beta, mean, var, act=act)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
    )


def test_fused_dense_act_matches_unfused_pipeline():
    """Fused kernel == the three-stage pipeline of paper eqs 3-5."""
    b, i, k = 16, 32, 32
    x, w = rnd(b, i), rnd(i, k, seed=5)
    beta, mean = rnd(k, seed=6), rnd(k, seed=7)
    var = jnp.abs(rnd(k, seed=8)) + 0.5
    y = x @ w + beta[None, :]
    z = (y - mean[None, :]) / jnp.sqrt(var[None, :] + 1e-5)
    r = jax.nn.gelu(z)
    out = fused_dense_act_pallas(
        x, w, beta, mean, var, act="gelu",
        block_b=8, block_k=16, block_i=16, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=1e-4, atol=1e-4)


def test_kernel_blocks_are_paper_subdivisions():
    """The kernel's grid/block structure equals the schedule's subdiv chain."""
    from repro.core.schedule import matmul_schedule

    sch = matmul_schedule(
        256, 256, 256, block_m=64, block_n=64, block_k=128
    )
    grid = [l for l in sch.levels if l.tier == "grid"]
    seq = [l for l in sch.levels if l.tier == "seq"]
    mxu = [l for l in sch.levels if l.tier == "mxu"]
    assert [l.extent for l in grid] == [256 // 64, 256 // 64]
    assert [l.extent for l in seq] == [256 // 128]
    assert sorted(l.extent for l in mxu) == [64, 64, 128]
    # and the kernel with exactly those blocks is correct
    a, b = rnd(256, 256), rnd(256, 256, seed=9)
    out = matmul_pallas(a, b, block_m=64, block_n=64, block_k=128, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(matmul_ref(a, b)), rtol=1e-4, atol=1e-4
    )
