"""Mesh-tier search: differential matrix + communication-cost properties.

ISSUE-5 coverage, three layers:

* **In-process properties** (property-engine-driven — hypothesis in CI,
  the seeded fallback engine on bare machines): legality of every mesh
  subdivision ``space.mesh_variants`` proposes, the communication term's
  invariants (psum fully exposed >= ring's overlapped exposure; map-only
  sharding needs no collective; score >= lower bound with the comm term
  enabled), the PR-2-style bound-cut soundness audit on a mesh search,
  and the mesh-qualified plan-key discipline.

* **Differential matrix** (subprocess per forced device count, shared
  ``forced_devices`` fixture): every legal mesh schedule the space
  enumeration proposes for the count's conventional mesh — all mesh
  variants x collective strategies, whole-extent and seeded-random inner
  blockings — lowered through ``codegen.bind_mesh`` and checked against
  the ``np.einsum`` f64 oracle AND the HoF reference interpreter
  (``core.interp`` via ``evaluate_variant``), f32 everywhere and bf16 on
  a stride of the variants.  Seeded like ``test_differential.py``: every
  case reproduces from (family, devices, variant index) alone.

* **Acceptance path**: a swept ``--mesh 2x4`` plan DB serves/trains
  through sharded generated kernels — ``ops.dense`` under an active 2x4
  mesh dispatches a ``MeshBoundKernel`` fwd and bwd (derived-spec mesh
  plans) and a captured model's step matches the unsharded baseline
  within the differential tolerances under 8 forced devices.
"""

from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.enumerate import (  # noqa: E402
    matmul_spec,
    transposed_matmul_spec,
    weighted_matmul_spec,
)
from repro.search import (  # noqa: E402
    PlanDB,
    beam_search,
    estimate,
    plan_key,
    search_schedule,
)
from repro.search.space import (  # noqa: E402
    local_extents,
    mesh_descriptor,
    mesh_variants,
    parse_mesh_shape,
)

#: conventional mesh per forced device count (data x model)
MESH_FOR_DEVICES = {1: (1, 1), 2: (1, 2), 4: (2, 2), 8: (2, 4)}

extent_pool = st.sampled_from([2, 4, 8, 16])
seeds = st.integers(min_value=0, max_value=10_000)


# ---------------------------------------------------------------------------
# space: legality of the mesh enumeration
# ---------------------------------------------------------------------------


@given(m=extent_pool, k=extent_pool, n=extent_pool, seed=seeds)
@settings(max_examples=25, deadline=None)
def test_mesh_variants_are_legal(m, k, n, seed):
    """Every proposed subdivision divides its index's extent, axes shard
    distinct indices, and the collective strategy appears exactly when a
    reduce index is sharded."""
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.choice([1, 2, 4])) for _ in range(2))
    spec = matmul_spec(m, k, n)
    variants = mesh_variants(spec, shape)
    assert variants, "enumeration must at least propose unsharded"
    assert any(not v.assignment for v in variants), "unsharded variant gone"
    seen = set()
    for v in variants:
        key = (v.assignment, v.collective)
        assert key not in seen, f"duplicate variant {key}"
        seen.add(key)
        indices = [i for i, _ in v.assignment]
        assert len(set(indices)) == len(indices)
        for i, (axis, size) in v.assignment:
            assert axis in ("pod", "data", "model")
            assert size > 1
            assert spec.extents[i] % size == 0
        sharded_reduce = any(i not in spec.output for i in indices)
        if sharded_reduce:
            assert v.collective in ("psum", "ring")
        else:
            assert v.collective == ""
        # the denoted schedule must build and validate
        from repro.search.space import make_candidate

        cand = make_candidate(
            spec, spec.indices, {}, mesh=v.as_dict(), collective=v.collective
        )
        sched = cand.to_schedule()
        mesh_levels = [l for l in sched.levels if l.tier.startswith("mesh:")]
        assert len(mesh_levels) == len(v.assignment)


# ---------------------------------------------------------------------------
# cost: the communication term
# ---------------------------------------------------------------------------


@given(m=extent_pool, k=extent_pool, n=extent_pool)
@settings(max_examples=25, deadline=None)
def test_comm_term_invariants(m, k, n):
    """Reduce-sharding pays a collective (psum fully exposed >= ring's
    overlapped exposure >= 0); map-only sharding pays none; the score
    never drops below the lower bound with the comm term enabled."""
    spec = matmul_spec(max(m, 2), max(k, 2), max(n, 2))
    blocks = dict(local_extents(spec, {"j": ("model", 2)}))
    psum = estimate(
        spec, spec.indices, blocks,
        mesh={"j": ("model", 2)}, collective="psum",
    )
    ring = estimate(
        spec, spec.indices, blocks,
        mesh={"j": ("model", 2)}, collective="ring",
    )
    assert psum.comm_s > 0.0
    assert ring.comm_s >= 0.0
    assert psum.comm_s >= ring.comm_s  # overlap can only help
    for est in (psum, ring):
        assert est.score >= est.lower_bound - 1e-18
        assert est.lower_bound >= est.comm_s - 1e-18  # comm is in the bound
        assert est.shards == 2
    map_only = estimate(
        spec, spec.indices, dict(local_extents(spec, {"i": ("data", 2)})),
        mesh={"i": ("data", 2)},
    )
    assert map_only.comm_s == 0.0
    # per-device compute shrinks with the shard count
    whole = estimate(
        spec, spec.indices, {i: spec.extents[i] for i in spec.indices}
    )
    assert map_only.compute_s == pytest.approx(whole.compute_s / 2)


def test_roofline_collective_model():
    """The interconnect model the comm term is built on."""
    from repro.roofline.analysis import (
        collective_seconds,
        sharded_reduce_seconds,
    )

    nbytes, p, bw = 1e6, 4, 50e9
    ar = collective_seconds("all-reduce", nbytes, p, bw)
    rs = collective_seconds("reduce-scatter", nbytes, p, bw)
    ag = collective_seconds("all-gather", nbytes, p, bw)
    assert ar == pytest.approx(rs + ag)
    assert ar == pytest.approx(2 * nbytes * (p - 1) / p / bw)
    assert collective_seconds("psum", nbytes, 1, bw) == 0.0
    # ring: reduce-scatter hides behind compute, all-gather stays exposed
    assert sharded_reduce_seconds(
        nbytes, p, collective="ring", compute_s=1.0, hw_ici_bw=bw
    ) == pytest.approx(ag)
    assert sharded_reduce_seconds(
        nbytes, p, collective="ring", compute_s=0.0, hw_ici_bw=bw
    ) == pytest.approx(rs + ag)
    assert sharded_reduce_seconds(
        nbytes, p, collective="psum", compute_s=123.0, hw_ici_bw=bw
    ) == pytest.approx(ar)  # psum never overlaps


# ---------------------------------------------------------------------------
# beam: mesh plans surface, bound cut stays sound with the comm term
# ---------------------------------------------------------------------------


def test_mesh_search_surfaces_mesh_plan_and_audit_is_sound():
    """The ISSUE-5 acceptance core, analytic half: an active 2x4 mesh
    search returns at least one ``mesh:*`` plan, and every bound cut made
    with the communication term enabled passes the PR-2 soundness audit
    (lower bound >= best complete score at the moment of the cut)."""
    spec = matmul_spec(64, 32, 64)
    survivors, stats = beam_search(
        spec, beam_width=6, topk=4, mesh_shape=(2, 4)
    )
    assert survivors
    assert stats.mesh_variants > 0
    assert any(sc.candidate.mesh for sc in survivors), (
        "mesh search surfaced no sharded plan"
    )
    assert stats.bound_log, "expected bound cuts in a mesh-widened space"
    for key, lower_bound, best_at_prune in stats.bound_log:
        assert lower_bound >= best_at_prune, (
            f"unsound cut with comm term: bound {lower_bound} beat the "
            f"proxy {best_at_prune} for {key}"
        )
    # at least one scored state actually carried a comm term (a sharded
    # reduce variant is in the space for this spec)
    sharded_reduce = [
        sc for sc in survivors
        if any(i not in spec.output for i, _ in sc.candidate.mesh)
    ]
    for sc in sharded_reduce:
        assert sc.cost.comm_s > 0.0


def test_mesh_plan_keys_are_qualified_and_disjoint(tmp_path):
    spec = matmul_spec(64, 64, 64)
    k_plain = plan_key(spec, np.float32)
    k_mesh = plan_key(spec, np.float32, mesh="2x4")
    k_mesh2 = plan_key(spec, np.float32, mesh="2x2")
    assert len({k_plain, k_mesh, k_mesh2}) == 3
    assert mesh_descriptor((2, 4)) == "2x4"
    assert mesh_descriptor((1, 1)) is None
    assert parse_mesh_shape("2x4") == (2, 4)
    with pytest.raises(ValueError):
        parse_mesh_shape("banana")

    db = PlanDB(str(tmp_path / "plans.json"))
    res = search_schedule(
        spec, beam_width=4, topk=2, measure=False, plan_db=db,
        mesh_shape=(2, 4),
    )
    assert res.mesh == "2x4"
    assert any(p.sharded for p in res.ranked)
    # the mesh ladder round-trips only under the mesh-qualified key
    assert db.best_schedule(spec, np.float32, mesh="2x4") is not None
    assert db.best_schedule(spec, np.float32) is None
    sched, entry = db.best_entry(spec, np.float32, mesh="2x4")
    assert sched is not None and "collective" in entry


def test_sharded_plans_rank_behind_measured_without_devices(tmp_path):
    """Single-device process + mesh search: sharded candidates cannot be
    measured, so they keep analytic scores and rank behind the measured
    single-device plans instead of erroring."""
    if jax.device_count() >= 8:
        pytest.skip("process has a real mesh; covered by the matrix test")
    spec = matmul_spec(64, 64, 64)
    res = search_schedule(
        spec, beam_width=4, topk=3, measure=True, interpret=True,
        plan_db=PlanDB(str(tmp_path / "plans.json")), mesh_shape=(2, 4),
    )
    assert any(p.sharded for p in res.ranked)
    for p in res.ranked:
        if p.sharded:
            assert p.measured_s is None
        if p.measured_s is not None:
            assert not p.sharded
    assert res.best.measured_s is not None  # a measured plan still wins


# ---------------------------------------------------------------------------
# the differential matrix (subprocess per forced device count)
# ---------------------------------------------------------------------------

_MATRIX_CODE = """
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.enumerate import (
    evaluate_variant,
    matmul_spec,
    transposed_matmul_spec,
    weighted_matmul_spec,
)
from repro.codegen import cached_compile
from repro.search import (
    einsum_reference,
    mesh_for_schedules,
    reference_arrays,
    schedule_mesh_axes,
)
from repro.search.space import local_extents, make_candidate, mesh_variants

DEVICES = __DEVICES__
SHAPE = __SHAPE__
assert jax.device_count() == DEVICES, jax.device_count()

TOL = {"float32": (1e-4, 1e-4), "bfloat16": (6e-2, 6e-2)}
#: family -> (ctor, extents, seed offset) — offsets keep streams disjoint
#: and stable, mirroring tests/test_differential.py
FAMILIES = [
    ("matmul", matmul_spec, (8, 4, 8), 1000),
    ("weighted_matmul", weighted_matmul_spec, (4, 8, 4), 3000),
    ("transposed_matmul", transposed_matmul_spec, (8, 8, 4), 5000),
]

checked = 0
for fam, ctor, extents, offset in FAMILIES:
    spec = ctor(*extents)
    variants = mesh_variants(spec, SHAPE)
    for vi, v in enumerate(variants):
        rng = np.random.default_rng(offset + 37 * DEVICES + vi)
        mesh_asgn = v.as_dict()
        loc = local_extents(spec, mesh_asgn)
        order = list(spec.indices)
        rng.shuffle(order)
        blocks = {
            i: int(rng.choice(
                [d for d in range(1, loc[i] + 1) if loc[i] % d == 0]
            ))
            for i in spec.indices
        }
        # the primary family runs the whole-extent schedule too; the
        # others keep one random schedule per variant to bound runtime
        cases = [(tuple(order), blocks)]
        if fam == "matmul":
            cases.append((tuple(spec.indices), {}))
        dtypes = ["float32"] if vi % 3 else ["float32", "bfloat16"]
        for ci, (c_order, c_blocks) in enumerate(cases):
            cand = make_candidate(
                spec, c_order, c_blocks,
                mesh=mesh_asgn, collective=v.collective,
            )
            sched = cand.to_schedule()
            sharded = bool(schedule_mesh_axes(sched))
            mesh = mesh_for_schedules([sched]) if sharded else None
            if sharded:
                assert mesh is not None, (fam, vi, sched.levels)
            for dt_name in (dtypes if ci == 0 else ["float32"]):
                dt = jnp.bfloat16 if dt_name == "bfloat16" else np.float32
                rtol, atol = TOL[dt_name]
                arrays = reference_arrays(
                    spec, dtype=np.float32, seed=offset + vi
                )
                ref = einsum_reference(spec, arrays)
                interp = evaluate_variant(spec, c_order, arrays)
                np.testing.assert_allclose(
                    interp, ref, rtol=1e-4, atol=1e-4
                )
                kern = cached_compile(
                    spec, sched, interpret=True,
                    mesh=mesh, collective=v.collective or "psum",
                )
                args = tuple(
                    jnp.asarray(arrays[n], dt) for n in spec.operands
                )
                got = np.asarray(kern(*args), np.float64)
                np.testing.assert_allclose(
                    got, ref, rtol=rtol, atol=atol,
                    err_msg=f"{fam} devices={DEVICES} variant={vi} "
                            f"case={ci} dtype={dt_name} "
                            f"mesh={v.assignment} coll={v.collective} "
                            f"levels={sched.levels}",
                )
                checked += 1
print("CHECKED", checked)
print("OK")
"""


@pytest.mark.parametrize("devices", sorted(MESH_FOR_DEVICES))
def test_mesh_schedule_differential_matrix(forced_devices, devices):
    """Every legal mesh schedule from the space enumeration, lowered under
    the forced device count, matches the einsum oracle and core.interp
    for f32 (all variants) and bf16 (every third variant)."""
    shape = MESH_FOR_DEVICES[devices]
    out = forced_devices(
        _MATRIX_CODE.replace("__DEVICES__", str(devices)).replace(
            "__SHAPE__", repr(shape)),
        devices=devices,
        timeout=1200,
    )
    assert "OK" in out
    checked = int(out.split("CHECKED")[1].split()[0])
    # device counts with a real mesh must cover a non-trivial variant set
    assert checked >= (3 if devices == 1 else 12), out


# ---------------------------------------------------------------------------
# acceptance: swept mesh plans serve/train through sharded kernels
# ---------------------------------------------------------------------------


def test_mesh_swept_model_serves_and_trains_sharded(forced_devices, tmp_path):
    """ISSUE-5 acceptance, executable half: sweep a captured GEMM with
    ``mesh_shape=2x4`` (fwd + derived backward specs), then — under an
    active 2x4 mesh on 8 forced devices — ``ops.dense`` must dispatch a
    ``MeshBoundKernel`` (sharded generated kernel) on the forward AND
    value_and_grad tape, with outputs/gradients matching the unsharded
    baseline within the differential tolerances.  A captured
    (``capture.optimize``) step with a raw dot_general site takes the
    same route."""
    out = forced_devices("""
        import numpy as np
        import jax
        import jax.numpy as jnp

        from repro import capture, ops
        from repro.codegen import MeshBoundKernel
        from repro.core.enumerate import matmul_spec
        from repro.launch.mesh import make_debug_mesh, set_mesh
        from repro.search import default_plan_db, search_schedule_with_grads

        M = D = F = 128  # the dense predicate's 128-alignment floor
        spec = matmul_spec(M, D, F)
        db = default_plan_db()
        res = search_schedule_with_grads(
            spec, beam_width=4, topk=2, interpret=True, repeats=1,
            plan_db=db, mesh_shape=(2, 4),
        )
        assert set(res) == {"fwd", "dA", "dB"}, sorted(res)
        for label, r in res.items():
            assert any(p.sharded for p in r.ranked), label

        mesh = make_debug_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((M, D)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((D, F)), jnp.float32)

        # the lookup ops performs must now return a sharded kernel
        from repro.ops import _mesh_plan_kernel
        with set_mesh(mesh):
            kern = _mesh_plan_kernel(spec, np.float32, interpret=True)
        assert isinstance(kern, MeshBoundKernel), type(kern)
        assert any(
            l.tier.startswith("mesh:") for l in kern.schedule.levels
        )

        def loss(a, b):
            return jnp.mean(ops.dense(a, b, interpret=True) ** 2)

        base_l, (base_gx, base_gw) = jax.value_and_grad(
            loss, argnums=(0, 1))(x, w)
        with set_mesh(mesh):
            mesh_l, (mesh_gx, mesh_gw) = jax.value_and_grad(
                loss, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(
            float(mesh_l), float(base_l), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(mesh_gx), np.asarray(base_gx), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(mesh_gw), np.asarray(base_gw), rtol=1e-4, atol=1e-4)

        # captured step: a raw dot_general site dispatches through the
        # same mesh-qualified plans once capture rewrites it onto ops
        def step(a, b):
            return jnp.tanh(a @ b).sum()

        captured = capture.optimize(step, interpret=True)
        want = float(step(x, w))
        with set_mesh(mesh):
            got = float(captured(x, w))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        print("OK")
    """, devices=8, timeout=1200, env_extra={
        "REPRO_PLAN_DB": str(tmp_path / "plans.json"),
        "REPRO_AUTOTUNE_CACHE": str(tmp_path / "autotune.json"),
    })
    assert "OK" in out
