"""Property suite for ``optim.quant`` — block-wise state quantization and
the GEMM-operand helpers behind the int8/fp8 kernel tier.

Each property is checked over a seeded matrix (no hypothesis dependency):

  * per-block round-trip error is bounded by absmax/127 (half a step of
    the per-block grid, with slack for the f32 divide),
  * the pad path (n % BLOCK != 0) round-trips exactly to the original
    length — padding never leaks into the dequantized values,
  * all-zero blocks take scale exactly 1.0 (no 0/0, and dequantize gives
    exact zeros),
  * shape and dtype restore byte-for-byte through quantize/dequantize,
  * ``quantization_bytes`` is exact arithmetic: payload + 4 bytes per
    block scale,
  * the GEMM-operand helpers (per-tensor / per-channel) obey the same
    absmax/qmax error bound, including empty and all-zero inputs,
  * ``quantize_tree`` / ``dequantize_tree`` / ``tree_quant_bytes`` hold
    the weight-only serving contract (min_size and ndim gating, int8-only
    refusal, jit-compatible Quantized leaves).
"""

from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.optim.quant import (  # noqa: E402
    BLOCK,
    MIN_QUANT_SIZE,
    Quantized,
    dequantize,
    dequantize_tree,
    quantization_bytes,
    quantize,
    quantize_channels,
    quantize_tensor,
    quantize_tree,
    tree_quant_bytes,
)

SEEDS = tuple(range(8))

#: shapes spanning: multiple blocks, the pad path (n % BLOCK != 0),
#: a single partial block, exact one block, and >2-D layouts
SHAPES = (
    (BLOCK * 3,),
    (BLOCK * 2 + 17,),
    (5,),
    (BLOCK,),
    (7, 33),
    (2, 3, 41),
)


def _draw(shape, seed, scale=1.0):
    rng = np.random.default_rng(17000 + seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# block-wise quantize/dequantize (optimizer-state tier)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("seed", SEEDS)
def test_per_block_error_bounded_by_absmax_over_127(shape, seed):
    x = _draw(shape, seed, scale=float(1 + seed))
    qv = quantize(jnp.asarray(x))
    back = np.asarray(dequantize(qv), np.float64)

    flat = x.reshape(-1).astype(np.float64)
    n = flat.size
    pad = (-n) % BLOCK
    blocks = np.pad(flat, (0, pad)).reshape(-1, BLOCK)
    absmax = np.abs(blocks).max(axis=1)
    err = np.abs(back.reshape(-1) - flat)
    err_blocks = np.pad(err, (0, pad)).reshape(-1, BLOCK)
    # rounding to the per-block grid loses at most half a step; 0.51
    # leaves room for the f32 divide's own rounding
    bound = 0.51 * absmax / 127.0
    assert (err_blocks.max(axis=1) <= bound + 1e-12).all(), (
        f"per-block error exceeded absmax/127 bound (shape={shape}, "
        f"seed={seed})"
    )


@pytest.mark.parametrize("n", (1, BLOCK - 1, BLOCK + 1, BLOCK * 2 + 17))
def test_pad_path_roundtrips_to_original_length(n):
    x = _draw((n,), seed=n % 7)
    qv = quantize(jnp.asarray(x))
    assert qv.q.shape == (-(-n // BLOCK), BLOCK)  # padded payload
    back = np.asarray(dequantize(qv))
    assert back.shape == (n,)  # ...but the pad never leaks out
    np.testing.assert_allclose(
        back, x, atol=float(np.abs(x).max()) / 127.0 * 0.51 + 1e-12
    )


def test_all_zero_blocks_take_scale_one():
    # one zero block sandwiched between live ones: its scale must be
    # exactly 1.0 (not 0, which would NaN the dequantize) and its values
    # must come back exactly zero
    x = np.ones((BLOCK * 3,), np.float32)
    x[BLOCK:2 * BLOCK] = 0.0
    qv = quantize(jnp.asarray(x))
    scales = np.asarray(qv.scale).reshape(-1)
    assert scales[1] == 1.0
    back = np.asarray(dequantize(qv))
    assert (back[BLOCK:2 * BLOCK] == 0.0).all()

    all_zero = quantize(jnp.zeros((BLOCK + 3,), jnp.float32))
    assert (np.asarray(all_zero.scale) == 1.0).all()
    assert (np.asarray(dequantize(all_zero)) == 0.0).all()


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "float16"])
@pytest.mark.parametrize("shape", SHAPES[:4])
def test_shape_and_dtype_restoration(shape, dtype):
    dt = jnp.dtype(dtype)
    x = jnp.asarray(_draw(shape, seed=1), dt)
    qv = quantize(x)
    back = dequantize(qv)
    assert back.shape == x.shape
    assert back.dtype == dt


@pytest.mark.parametrize("shape", SHAPES)
def test_quantization_bytes_exact(shape):
    qv = quantize(jnp.asarray(_draw(shape, seed=2)))
    n = int(np.prod(shape))
    nblocks = -(-n // BLOCK)
    # payload: one int8 per padded element; scales: one f32 per block
    assert quantization_bytes(qv) == nblocks * BLOCK + nblocks * 4


# ---------------------------------------------------------------------------
# GEMM-operand helpers (the kernel tier's layouts)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["int8", "fp8"])
@pytest.mark.parametrize("seed", SEEDS[:4])
def test_quantize_tensor_error_bound(fmt, seed):
    if fmt == "fp8" and not hasattr(jnp, "float8_e4m3fn"):
        pytest.skip("jax build lacks float8_e4m3fn")
    qmax = {"int8": 127.0, "fp8": 448.0}[fmt]
    x = _draw((37, 23), seed, scale=3.0)
    q, s = quantize_tensor(jnp.asarray(x), fmt)
    assert np.asarray(s).shape == ()
    back = np.asarray(q, np.float64) * float(s)
    absmax = np.abs(x).max()
    if fmt == "int8":
        bound = 0.51 * absmax / qmax
    else:
        # fp8 e4m3: ~3 mantissa bits, relative grid ~2^-3 near each value
        bound = absmax / qmax + np.abs(x) * 2.0 ** -3
    assert (np.abs(back - x) <= bound + 1e-9).all()


def test_quantize_channels_is_per_last_axis():
    rng = np.random.default_rng(17100)
    w = (rng.standard_normal((24, 6))
         * np.logspace(-2, 2, 6)[None, :]).astype(np.float32)
    q, s = quantize_channels(jnp.asarray(w), "int8")
    assert np.asarray(s).shape == (6,)
    back = np.asarray(q, np.float64) * np.asarray(s, np.float64)[None, :]
    col_absmax = np.abs(w).max(axis=0)
    err = np.abs(back - w).max(axis=0)
    assert (err <= 0.51 * col_absmax / 127.0 + 1e-9).all(), (
        "per-channel error must be bounded by each column's OWN absmax — "
        "a global scale would violate this on the small columns"
    )


def test_gemm_helpers_empty_and_zero_inputs():
    q, s = quantize_tensor(jnp.zeros((0, 8), jnp.float32), "int8")
    assert q.shape == (0, 8) and q.dtype == jnp.int8 and float(s) == 1.0
    q, s = quantize_channels(jnp.zeros((0, 8), jnp.float32), "int8")
    assert q.shape == (0, 8) and np.asarray(s).shape == (8,)
    assert (np.asarray(s) == 1.0).all()
    # all-zero (non-empty): scale 1.0, payload exact zeros
    q, s = quantize_tensor(jnp.zeros((4, 4), jnp.float32), "int8")
    assert float(s) == 1.0 and (np.asarray(q) == 0).all()


def test_gemm_helpers_unknown_format():
    with pytest.raises(KeyError):
        quantize_tensor(jnp.ones((4, 4)), "int3")


# ---------------------------------------------------------------------------
# weight-only serving tree (quantize once at load, dequantize inside jit)
# ---------------------------------------------------------------------------


def _params(rng):
    return {
        "proj": jnp.asarray(rng.standard_normal((96, 64)), jnp.float32),
        "tiny": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32),
        "bias": jnp.asarray(rng.standard_normal(64), jnp.float32),
        "step": jnp.asarray(3, jnp.int32),
    }


def test_quantize_tree_gates_on_size_and_ndim():
    params = _params(np.random.default_rng(17200))
    qt = quantize_tree(params, fmt="int8", min_size=1024)
    assert isinstance(qt["proj"], Quantized)       # 96*64 >= 1024, ndim 2
    assert isinstance(qt["tiny"], jax.Array)       # too small
    assert isinstance(qt["bias"], jax.Array)       # 1-D: precision-critical
    assert qt["step"].dtype == jnp.int32           # non-float passthrough

    # default threshold pins the documented MIN_QUANT_SIZE
    qt_default = quantize_tree(params, fmt="int8")
    assert (96 * 64 >= MIN_QUANT_SIZE) == isinstance(
        qt_default["proj"], Quantized
    )


def test_quantize_tree_rejects_non_int8():
    with pytest.raises(NotImplementedError, match="int8"):
        quantize_tree(_params(np.random.default_rng(0)), fmt="fp8")


def test_dequantize_tree_roundtrip_and_bytes():
    params = _params(np.random.default_rng(17300))
    qt = quantize_tree(params, fmt="int8", min_size=1024)
    back = dequantize_tree(qt)
    assert back["proj"].shape == params["proj"].shape
    assert back["proj"].dtype == params["proj"].dtype
    np.testing.assert_allclose(
        np.asarray(back["proj"]), np.asarray(params["proj"]),
        atol=float(jnp.abs(params["proj"]).max()) / 127.0 * 0.51 + 1e-9,
    )
    # untouched leaves pass through identically
    assert back["tiny"] is qt["tiny"]

    n = 96 * 64
    nblocks = -(-n // BLOCK)
    assert tree_quant_bytes(qt) == nblocks * BLOCK + nblocks * 4
    assert tree_quant_bytes(params) == 0  # nothing quantized yet


def test_quantized_leaves_flow_through_jit():
    params = _params(np.random.default_rng(17400))
    qt = quantize_tree(params, fmt="int8", min_size=1024)

    @jax.jit
    def step(p, x):
        p = dequantize_tree(p)
        return x @ p["proj"] + p["bias"]

    x = jnp.asarray(
        np.random.default_rng(17500).standard_normal((4, 96)), jnp.float32
    )
    out = step(qt, x)
    ref = x @ dequantize_tree(qt)["proj"] + qt["bias"]
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6
    )
