"""Property tests for the cost-guided search pipeline (ISSUE 2).

Covers the two properties the issue names:

  * **cost-model/rewrite consistency** — applying any exchange rule (a loop
    reorder) or subdivision rule to a variant never changes the analytic
    FLOP count, and the roofline compute term agrees;
  * **prune soundness** — the search's bound cut never discards a candidate
    whose cost lower-bound beats the best complete candidate's score (the
    measured proxy); every cut is recorded in ``SearchStats.bound_log`` and
    audited here, and with an unbounded beam the search is exhaustive.

Plus end-to-end pipeline checks: plan DB round-trip, ``ops.dense`` pickup,
and ``candidate_schedule`` vs ``default_schedule`` agreement.
"""

from __future__ import annotations

import itertools
import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.codegen import default_schedule  # noqa: E402
from repro.codegen.cache import schedule_to_dict  # noqa: E402
from repro.core.cost import TPU  # noqa: E402
from repro.core.enumerate import (  # noqa: E402
    chain_matmul_spec,
    matmul_spec,
    matvec_spec,
    variant_orders,
    weighted_matmul_spec,
)
from repro.search import (  # noqa: E402
    PlanDB,
    beam_search,
    block_choices,
    candidate_orders,
    candidate_schedule,
    estimate,
    make_candidate,
    search_schedule,
)

SPECS = [
    matmul_spec(16, 8, 32),
    matvec_spec(24, 16),
    weighted_matmul_spec(8, 16, 8),
    chain_matmul_spec(8, 8, 16, 8),
]


# ---------------------------------------------------------------------------
# cost-model / rewrite consistency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_flops_invariant_under_subdivision(spec):
    """The subdivision rule (paper eq 44) regroups, never adds, work."""
    rng = np.random.default_rng(0)
    base = spec.flops()
    for _ in range(20):
        s = spec
        for _ in range(int(rng.integers(1, 4))):
            idx = str(rng.choice(list(s.indices)))
            divs = [d for d in range(2, s.extents[idx] + 1)
                    if s.extents[idx] % d == 0]
            if not divs:
                continue
            s = s.subdivide(idx, int(rng.choice(divs)))
        assert s.flops() == base, (s.split_chain(), s.extents)


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_flops_invariant_under_exchange(spec):
    """Exchange rules permute the nest; work and the roofline compute term
    must not move.  Orders come from the SJT walk, where each neighbour is
    one exchange-rule application away."""
    blocks = {i: spec.extents[i] for i in spec.indices}
    ref = None
    for order in variant_orders(spec, dedup_rnz=False):
        est = estimate(spec, order, blocks)
        if ref is None:
            ref = est.compute_s
        assert est.compute_s == ref, order
    # specs reached by the subdiv rule keep the same FLOP count too
    for idx in spec.indices:
        divs = [d for d in range(2, spec.extents[idx] + 1)
                if spec.extents[idx] % d == 0]
        for d in divs[:2]:
            assert spec.subdivide(idx, d).flops() == spec.flops()


def test_score_never_below_lower_bound():
    """score = bound x penalties with penalties >= 1 — the invariant the
    sound cut relies on."""
    spec = matmul_spec(64, 32, 128)
    choices = block_choices(spec, TPU)
    for order in candidate_orders(spec):
        for combo in itertools.product(*(choices[i] for i in spec.indices)):
            blocks = dict(zip(spec.indices, combo))
            est = estimate(spec, order, blocks)
            assert est.score >= est.lower_bound - 1e-18, (order, blocks)


# ---------------------------------------------------------------------------
# prune soundness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_bound_cut_never_discards_a_winner(spec):
    """Every bound-cut candidate's lower bound was >= the best complete
    candidate's score at the moment of the cut — so no cut candidate (nor
    any completion of it) could have beaten the proxy."""
    survivors, stats = beam_search(spec, beam_width=4, topk=4)
    assert survivors, "search must always return at least one candidate"
    best_score = survivors[0].cost.score
    for key, lower_bound, best_at_prune in stats.bound_log:
        assert lower_bound >= best_at_prune, (
            f"unsound cut: bound {lower_bound} beat the proxy "
            f"{best_at_prune} for {key}"
        )
        # the proxy only improves over time, so nothing cut could beat the
        # final winner either
        assert lower_bound >= best_score or best_at_prune >= best_score


@pytest.mark.parametrize("spec", SPECS[:3], ids=lambda s: s.name)
def test_unbounded_beam_is_exhaustive(spec):
    """With width >= |space| the beam finds the analytic optimum: the same
    minimum score as brute-force enumeration of every (order, blocks)."""
    choices = block_choices(spec, TPU)
    orders = candidate_orders(spec)
    brute = min(
        estimate(spec, order, dict(zip(spec.indices, combo))).score
        for order in orders
        for combo in itertools.product(*(choices[i] for i in spec.indices))
    )
    survivors, _ = beam_search(spec, beam_width=10_000, topk=1)
    assert survivors[0].cost.score == pytest.approx(brute, rel=1e-12)


def test_beam_width_one_still_returns_a_plan():
    survivors, stats = beam_search(matmul_spec(32, 32, 32), beam_width=1, topk=3)
    assert len(survivors) >= 1
    assert stats.considered > 0


# ---------------------------------------------------------------------------
# schedules and dedup
# ---------------------------------------------------------------------------


def test_candidate_schedule_matches_default_schedule():
    """With loop order == spec.indices the search's schedule builder and
    PR-1's default_schedule emit the identical Schedule."""
    spec = matmul_spec(32, 16, 64)
    blocks = {"i": 8, "j": 8, "k": 16}
    a = candidate_schedule(spec, spec.indices, blocks)
    b = default_schedule(spec, blocks)
    assert schedule_to_dict(a) == schedule_to_dict(b)


def test_canonical_key_collapses_exchange_equivalents():
    """Orders that differ only by a map/rnz exchange lower identically and
    must share a canonical key (the beam's dedup)."""
    spec = matmul_spec(16, 16, 16)
    blocks = {"i": 8, "j": 16, "k": 8}
    a = make_candidate(spec, ("i", "j", "k"), blocks)
    b = make_candidate(spec, ("i", "k", "j"), blocks)
    # j is whole-extent (no seq level) and the grid order (i then k) is the
    # same in both, so these lower to the same kernel:
    assert a.canonical_key() == b.canonical_key()
    # but a genuine grid reorder is a different kernel:
    c = make_candidate(spec, ("k", "i", "j"), blocks)
    assert c.canonical_key() != a.canonical_key()


# ---------------------------------------------------------------------------
# pipeline: plan DB round-trip and ops pickup
# ---------------------------------------------------------------------------


def test_search_pipeline_roundtrip_and_ops_pickup(tmp_path, monkeypatch):
    spec = matmul_spec(128, 128, 128)
    db = PlanDB(str(tmp_path / "plans.json"))
    res = search_schedule(
        spec, beam_width=4, topk=2, measure=False, plan_db=db,
    )
    assert res.ranked and res.db_key
    # default baseline rides along un-measured
    assert any(p.source == "default" for p in res.ranked)

    stored = db.best_schedule(spec, np.float32)
    assert stored is not None
    assert schedule_to_dict(stored) == schedule_to_dict(res.best.schedule)

    # a second search call returns the persisted ladder without re-searching
    res2 = search_schedule(spec, beam_width=4, topk=2, measure=False, plan_db=db)
    assert schedule_to_dict(res2.best.schedule) == schedule_to_dict(
        res.best.schedule
    )

    # ops._tuned_kernel consults the plan DB before the tuner
    monkeypatch.setenv("REPRO_PLAN_DB", str(db.path))
    from repro.ops import _tuned_kernel

    kern = _tuned_kernel(spec, np.float32, interpret=True)
    assert schedule_to_dict(kern.schedule) == schedule_to_dict(
        res.best.schedule
    )


def test_unmeasured_cache_does_not_satisfy_measured_request(tmp_path):
    """An analytic-only (--no-measure) ladder must not mask a later
    measured search for the same spec/dtype."""
    spec = matmul_spec(64, 64, 64)
    db = PlanDB(str(tmp_path / "plans.json"))
    res = search_schedule(spec, beam_width=4, topk=2, measure=False, plan_db=db)
    assert res.best.measured_s is None
    res2 = search_schedule(
        spec, beam_width=4, topk=2, measure=True, interpret=True, plan_db=db
    )
    assert res2.best.measured_s is not None
    # and the measured ladder overwrote the analytic one
    res3 = search_schedule(spec, beam_width=4, topk=2, measure=False, plan_db=db)
    assert res3.best.measured_s is not None


def test_plan_db_corrupt_entry_degrades_to_miss(tmp_path):
    spec = matmul_spec(64, 64, 64)
    db = PlanDB(str(tmp_path / "plans.json"))
    from repro.search.plandb import plan_key

    db._cache.put(
        plan_key(spec, np.float32),
        {"v": 1, "ranked": [{"schedule": {"splits": [["zz", 7]], "levels": []}}]},
    )
    assert db.best_schedule(spec, np.float32) is None


def test_measured_search_winner_not_slower_than_default(tmp_path):
    """The ISSUE-2 acceptance bar, enforced structurally: the default
    schedule is part of the measured set, so the measured winner can never
    be slower than it on the same harness."""
    from repro.search import reference_arrays

    spec = matmul_spec(64, 64, 64)
    res = search_schedule(
        spec, beam_width=4, topk=2, interpret=True, measure=True,
        arrays=reference_arrays(spec, seed=3),
        plan_db=PlanDB(str(tmp_path / "plans.json")),
    )
    base = res.baseline()
    assert base is not None and base.measured_s is not None
    assert res.best.measured_s <= base.measured_s
    assert res.stats.measured == len(res.ranked)
