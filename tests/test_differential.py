"""Differential fuzz suite: random spec x random legal schedule, three ways.

For every case the generated Pallas kernel (interpret mode) must agree with

  * ``np.einsum`` over the root contraction (f64 accumulation oracle), and
  * the HoF reference interpreter (``core.interp`` via ``evaluate_variant``)

to dtype-appropriate tolerance.  Cases are drawn from an explicit PRNG seed
matrix — no hypothesis dependency, and any failure reproduces from its
(family, seed) parametrization id alone.

The matrix is 6 spec families x 10 seeds = 60 float32 cases (the CI bar is
>= 50), plus one bfloat16 case per family exercising the low-precision
store path with f32 accumulation.

The backward matrix (``test_derived_backward_specs``) extends this to the
training half: for each sampled forward spec, every derived dX spec
(``repro.grad.derive``) must itself be a valid codegen input — compiled
under a *random* legal schedule, not just the default — and must match
both the einsum oracle over the derived contraction and the true
cotangent from ``jax.vjp`` of the forward einsum.
"""

from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import codegen  # noqa: E402
from repro.core.enumerate import (  # noqa: E402
    batched_matmul_spec,
    chain_matmul_spec,
    evaluate_variant,
    matmul_spec,
    matvec_spec,
    transposed_matmul_spec,
    weighted_matmul_spec,
)
from repro.search import (  # noqa: E402
    candidate_schedule,
    einsum_reference,
    reference_arrays,
)

@pytest.fixture(autouse=True)
def _isolated_caches(tmp_path, monkeypatch):
    """The ops entry-point cases tune through the default plan-DB/autotune
    pipeline; keep their files out of ~/.cache."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    monkeypatch.setenv("REPRO_PLAN_DB", str(tmp_path / "plans.json"))


#: family -> (ctor, arity, seed offset).  Offsets keep streams disjoint and
#: stable — never derive them from hash() (PYTHONHASHSEED would break repro).
FAMILIES = {
    "matmul": (matmul_spec, 3, 1000),
    "matvec": (matvec_spec, 2, 2000),
    "weighted_matmul": (weighted_matmul_spec, 3, 3000),
    "batched_matmul": (batched_matmul_spec, 4, 4000),
    "transposed_matmul": (transposed_matmul_spec, 3, 5000),
    "chain_matmul": (chain_matmul_spec, 4, 6000),
}

EXTENT_POOL = (2, 3, 4, 6, 8)
SEEDS = tuple(range(10))
CASES = [(fam, seed) for fam in sorted(FAMILIES) for seed in SEEDS]
assert len(CASES) >= 50, "CI requires at least 50 differential cases"

TOL = {  # dtype -> (rtol, atol) against the f64 einsum oracle
    np.dtype(np.float32): (1e-4, 1e-4),
    np.dtype(jnp.bfloat16): (6e-2, 6e-2),
}


def _divisors(n: int):
    return [d for d in range(1, n + 1) if n % d == 0]


def _draw_case(family: str, seed: int):
    """(spec, loop order, blocks) — everything from one seeded stream."""
    ctor, arity, offset = FAMILIES[family]
    rng = np.random.default_rng(offset + seed)
    extents = [int(rng.choice(EXTENT_POOL)) for _ in range(arity)]
    spec = ctor(*extents)
    order = list(spec.indices)
    rng.shuffle(order)
    blocks = {
        i: int(rng.choice(_divisors(spec.extents[i])))
        for i in spec.indices
    }
    return spec, tuple(order), blocks


def _run_kernel(spec, schedule, arrays, dtype):
    kern = codegen.compile(spec, schedule, interpret=True)
    args = tuple(
        jnp.asarray(arrays[n], dtype) for n in spec.operands
    )
    return np.asarray(kern(*args), np.float64)


@pytest.mark.parametrize("family,seed", CASES)
def test_generated_kernel_matches_oracles(family, seed):
    spec, order, blocks = _draw_case(family, seed)
    schedule = candidate_schedule(spec, order, blocks)
    arrays = reference_arrays(spec, dtype=np.float32, seed=seed)
    ref = einsum_reference(spec, arrays)
    rtol, atol = TOL[np.dtype(np.float32)]

    out = _run_kernel(spec, schedule, arrays, jnp.float32)
    np.testing.assert_allclose(
        out, ref, rtol=rtol, atol=atol,
        err_msg=f"kernel != einsum for {family} seed={seed} "
                f"order={order} blocks={blocks}",
    )

    interp = evaluate_variant(spec, spec.indices, arrays)
    np.testing.assert_allclose(
        np.asarray(interp, np.float64), ref, rtol=rtol, atol=atol,
        err_msg=f"reference interpreter != einsum for {family} seed={seed}",
    )


BWD_SEEDS = tuple(range(4))
BWD_CASES = [(fam, seed) for fam in sorted(FAMILIES) for seed in BWD_SEEDS]


@pytest.mark.parametrize("family,seed", BWD_CASES)
def test_derived_backward_specs(family, seed):
    """Derived dX specs are valid codegen inputs and true cotangents."""
    from repro.core.enumerate import einsum_formula
    from repro.grad import COTANGENT, derived_specs

    spec, order, blocks = _draw_case(family, seed)
    spec = spec.root()
    arrays = reference_arrays(spec, dtype=np.float32, seed=9000 + seed)
    rng = np.random.default_rng(9500 + seed)
    g = rng.standard_normal(
        tuple(spec.extents[i] for i in spec.output)
    ).astype(np.float32)

    # independent oracle: jax.vjp through the forward einsum
    names = list(spec.operands)
    formula = einsum_formula(spec)

    def fwd(*ops_):
        return jnp.einsum(formula, *ops_, preferred_element_type=jnp.float32)

    _, vjp = jax.vjp(fwd, *(jnp.asarray(arrays[n]) for n in names))
    oracle_cots = dict(zip(names, vjp(jnp.asarray(g))))

    for wrt, dspec in derived_specs(spec).items():
        darrays = {COTANGENT: g}
        darrays.update(
            {n: arrays[n] for n in spec.operands if n != wrt}
        )
        # a random legal schedule over the DERIVED spec's own index space —
        # backward specs are full citizens of the search space
        dorder = list(dspec.indices)
        rng.shuffle(dorder)
        dblocks = {
            i: int(rng.choice(_divisors(dspec.extents[i])))
            for i in dspec.indices
        }
        schedule = candidate_schedule(dspec, tuple(dorder), dblocks)
        out = _run_kernel(dspec, schedule, darrays, jnp.float32)

        rtol, atol = TOL[np.dtype(np.float32)]
        ref = einsum_reference(dspec, darrays)
        np.testing.assert_allclose(
            out, ref, rtol=rtol, atol=atol,
            err_msg=f"kernel != einsum for {dspec.name} seed={seed} "
                    f"order={dorder} blocks={dblocks}",
        )
        cot = np.asarray(oracle_cots[wrt], np.float64)
        scale = max(np.abs(cot).max(), 1.0)
        np.testing.assert_allclose(
            out / scale, cot / scale, rtol=1e-3, atol=1e-3,
            err_msg=f"derived spec {dspec.name} is not the cotangent "
                    f"of {family} wrt {wrt} (seed={seed})",
        )


# ---------------------------------------------------------------------------
# ops entry points the suite did not previously exercise:
# weighted_dense and the dense_act epilogue matrix, kernel path vs
# pure-jnp oracles
# ---------------------------------------------------------------------------

WD_SEEDS = tuple(range(6))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("seed", WD_SEEDS)
def test_ops_weighted_dense_kernel_path(seed, dtype):
    """ops.weighted_dense's generated-kernel path (interpret mode) against
    the f64 einsum oracle, fwd + all three cotangents."""
    from repro import ops

    dt = jnp.dtype(dtype)
    rng = np.random.default_rng(7000 + seed)
    m, d, f = (int(rng.choice(EXTENT_POOL)) for _ in range(3))
    x64 = rng.standard_normal((m, d))
    w64 = rng.standard_normal((d, f))
    g64 = rng.standard_normal(d)
    x, w, g = (jnp.asarray(a, dt) for a in (x64, w64, g64))
    # charge input quantization to the oracle, not the kernel
    q = [np.asarray(a, np.float64) for a in (x, w, g)]
    ref = np.einsum("ij,jk,j->ik", *q)

    rtol, atol = TOL[np.dtype(dt)]
    out = np.asarray(
        ops.weighted_dense(x, w, g, interpret=True), np.float64
    )
    scale = max(np.abs(ref).max(), 1.0)
    np.testing.assert_allclose(
        out / scale, ref / scale, rtol=rtol, atol=atol,
        err_msg=f"weighted_dense kernel path diverged (seed={seed})",
    )

    if dt == jnp.float32:
        def loss_k(x_, w_, g_):
            return jnp.sum(ops.weighted_dense(x_, w_, g_, interpret=True))

        def loss_ref(x_, w_, g_):
            return jnp.sum(jnp.einsum(
                "ij,jk,j->ik", x_, w_, g_,
                preferred_element_type=jnp.float32,
            ))

        got = jax.grad(loss_k, argnums=(0, 1, 2))(x, w, g)
        want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, g)
        for name, a, b in zip(("dx", "dw", "dg"), got, want):
            np.testing.assert_allclose(
                np.asarray(a, np.float64), np.asarray(b, np.float64),
                rtol=1e-3, atol=1e-3,
                err_msg=f"weighted_dense cotangent {name} (seed={seed})",
            )


ACTS = ("relu", "gelu", "tanh", "silu", "id")
EPSES = (1e-5, 1e-3)


def _dense_act_oracle(x, w, beta, mean, var, act, eps):
    """Pure-jnp reference for the fused epilogue, f32 accumulation."""
    acc = jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    y = acc + beta.astype(jnp.float32)[None, :]
    z = (y - mean.astype(jnp.float32)[None, :]) * jax.lax.rsqrt(
        var.astype(jnp.float32)[None, :] + eps
    )
    fns = {
        "relu": lambda t: jnp.maximum(t, 0.0),
        "gelu": jax.nn.gelu,
        "tanh": jnp.tanh,
        "silu": jax.nn.silu,
        "id": lambda t: t,
    }
    return fns[act](z)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("eps", EPSES)
@pytest.mark.parametrize("act", ACTS)
def test_ops_dense_act_epilogue_matrix(act, eps, dtype):
    """Every epilogue variant of ops.dense_act (act x eps x dtype) on the
    generated-kernel path against an independent pure-jnp oracle."""
    from repro import ops

    dt = jnp.dtype(dtype)
    rng = np.random.default_rng(
        8000 + ACTS.index(act) * 10 + EPSES.index(eps)
    )
    m, d, f = 8, 6, 4
    x = jnp.asarray(rng.standard_normal((m, d)), dt)
    w = jnp.asarray(rng.standard_normal((d, f)), dt)
    beta = jnp.asarray(rng.standard_normal(f), dt)
    mean = jnp.asarray(rng.standard_normal(f) * 0.1, dt)
    var = jnp.asarray(np.abs(rng.standard_normal(f)) + 0.5, dt)

    ref = np.asarray(
        _dense_act_oracle(x, w, beta, mean, var, act, eps), np.float64
    )
    out = np.asarray(
        ops.dense_act(
            x, w, beta, mean, var, act=act, eps=eps, interpret=True,
        ),
        np.float64,
    )
    rtol, atol = TOL[np.dtype(dt)]
    scale = max(np.abs(ref).max(), 1.0)
    np.testing.assert_allclose(
        out / scale, ref / scale, rtol=rtol, atol=atol,
        err_msg=f"dense_act({act}, eps={eps}, {dtype}) diverged",
    )

    if dt == jnp.float32:
        got = jax.grad(
            lambda *a: jnp.sum(ops.dense_act(
                *a, act=act, eps=eps, interpret=True
            )),
            argnums=(0, 1, 2),
        )(x, w, beta, mean, var)
        want = jax.grad(
            lambda *a: jnp.sum(_dense_act_oracle(*a, act, eps)),
            argnums=(0, 1, 2),
        )(x, w, beta, mean, var)
        for name, a, b in zip(("dx", "dw", "dbeta"), got, want):
            sc = max(float(jnp.max(jnp.abs(b))), 1.0)
            np.testing.assert_allclose(
                np.asarray(a, np.float64) / sc,
                np.asarray(b, np.float64) / sc,
                rtol=1e-3, atol=1e-3,
                err_msg=f"dense_act({act}) cotangent {name}",
            )


# ---------------------------------------------------------------------------
# quantized rows: the same random-legal-schedule draw, at the int8/fp8
# storage tier, against the dequantize-then-einsum f64 oracle
# ---------------------------------------------------------------------------

QUANT_SEEDS = tuple(range(3))
QUANT_CASES = [
    (fam, seed, fmt)
    for fam in sorted(FAMILIES)
    for seed in QUANT_SEEDS
    for fmt in ("int8", "fp8")
]


@pytest.mark.parametrize("family,seed,fmt", QUANT_CASES)
def test_generated_kernel_quantized(family, seed, fmt):
    """Quantized kernels under random legal schedules: the generated kernel
    over int8/fp8 storage must match the f64 einsum over the *dequantized*
    operand values — exactly for int8 (int32 accumulation of small-int
    products is closed), to f32-accumulation tolerance for fp8."""
    from repro.core.enumerate import QUANT_FORMATS, quantize_spec

    meta = QUANT_FORMATS[fmt]
    store_dt = getattr(jnp, meta.dtype, None)
    if store_dt is None:
        pytest.skip(f"jax build lacks {meta.dtype}")

    base, order, blocks = _draw_case(family, seed)
    spec = quantize_spec(base.root(), fmt=fmt)
    schedule = candidate_schedule(spec, order, blocks)
    # int formats draw small exact integers, fp8 draws normals rounded to
    # the storage grid — either way np.float64(arrays) IS the dequantized
    # oracle operand set
    arrays = reference_arrays(spec, dtype=np.dtype(meta.dtype), seed=seed)
    ref = einsum_reference(spec, arrays)

    out = _run_kernel(spec, schedule, arrays, store_dt)
    if fmt == "int8":
        assert out.dtype == np.float64 and np.all(out == ref), (
            f"int8 kernel != exact oracle for {family} seed={seed} "
            f"order={order} blocks={blocks}"
        )
    else:
        scale = max(np.abs(ref).max(), 1.0)
        np.testing.assert_allclose(
            out / scale, ref / scale, rtol=1e-4, atol=1e-4,
            err_msg=f"fp8 kernel != dequantized oracle for {family} "
                    f"seed={seed} order={order} blocks={blocks}",
        )

    # the quantized spec keeps the reference interpreter semantics on the
    # dequantized values (scale application is an epilogue concern)
    interp = evaluate_variant(
        spec, spec.indices,
        {n: np.asarray(a, np.float64) for n, a in arrays.items()},
    )
    np.testing.assert_allclose(
        np.asarray(interp, np.float64), ref, rtol=1e-6, atol=1e-6,
        err_msg=f"interp != oracle for quantized {family} seed={seed}",
    )


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_generated_kernel_bfloat16(family):
    """Low-precision store path: bf16 in/out, f32 accumulation inside."""
    spec, order, blocks = _draw_case(family, seed=7)
    schedule = candidate_schedule(spec, order, blocks)
    arrays = reference_arrays(spec, dtype=np.float32, seed=7)
    ref = einsum_reference(spec, arrays)
    # quantize the inputs to bf16 before building the oracle so rounding
    # of the *inputs* is not charged against the kernel
    q = {
        n: np.asarray(jnp.asarray(a, jnp.bfloat16), np.float64)
        for n, a in arrays.items()
    }
    ref = einsum_reference(spec, q)
    rtol, atol = TOL[np.dtype(jnp.bfloat16)]
    out = _run_kernel(spec, schedule, arrays, jnp.bfloat16)
    scale = max(np.abs(ref).max(), 1.0)
    np.testing.assert_allclose(
        out / scale, ref / scale, rtol=rtol, atol=atol,
        err_msg=f"bf16 kernel mismatch for {family}",
    )
