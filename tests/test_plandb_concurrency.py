"""Plan-DB concurrency: parallel fwd+bwd sweep writers must not lose data.

The scenario the ``--with-grads`` sweep creates in production: one process
persists the forward plan for a shape while another persists the derived
backward plans for the *same* shape (disjoint keys, same ``$REPRO_PLAN_DB``
file).  ``AutotuneCache.put`` is a read-merge-write; without an
inter-process lock two interleaved writers each load the same snapshot and
the slower ``os.replace`` silently drops the faster writer's keys (the
file stays valid JSON — corruption here means *lost entries*, which ops
would silently re-tune around).  ``codegen.cache._file_lock`` (flock on
``<path>.lock``) makes the merge atomic across processes; this test drives
two real processes through enough interleaved writes that the pre-lock
code loses entries with near-certainty.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core.enumerate import matmul_spec  # noqa: E402
from repro.grad import derived_specs  # noqa: E402
from repro.search.plandb import PlanDB, plan_key  # noqa: E402

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

#: each process writes its half of the (fwd, dA, dB) key family for every
#: shape, interleaved with the other process via a tiny start barrier
_WRITER = """
import os, sys, time
sys.path.insert(0, {src!r})
import numpy as np
from repro.core.enumerate import matmul_spec
from repro.grad import derived_specs
from repro.codegen import default_schedule
from repro.search.plandb import PlanDB, entry_from

which = sys.argv[1]
n_shapes = int(sys.argv[2])
db = PlanDB(os.environ["REPRO_PLAN_DB"])
deadline = float(os.environ["WRITER_START"])
while time.time() < deadline:   # start both processes together
    time.sleep(0.001)
for t in range(n_shapes):
    m = 128 * (t + 1)
    spec = matmul_spec(m, 128, 128)
    points = {{"fwd": spec, **derived_specs(spec)}}
    for label, s in points.items():
        mine = (label == "fwd") == (which == "0")
        if not mine:
            continue
        db.put(
            s, np.float32,
            [entry_from(default_schedule(s), score=1.0,
                        lower_bound=0.0, fits_vmem=True)],
        )
print("writer", which, "done")
"""


def test_two_process_sweep_writers_keep_all_entries(tmp_path):
    import time

    path = str(tmp_path / "plans.json")
    n_shapes = 14
    env = dict(
        os.environ,
        REPRO_PLAN_DB=path,
        WRITER_START=str(time.time() + 2.0),
        JAX_PLATFORMS="cpu",
    )
    script = _WRITER.format(src=os.path.abspath(_SRC))
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", script, which, str(n_shapes)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True,
        )
        for which in ("0", "1")
    ]
    for p in procs:
        out, err = p.communicate(timeout=300)
        assert p.returncode == 0, f"writer failed:\n{out}\n{err}"

    # the file parses (never corrupt) ...
    with open(path) as f:
        raw = json.load(f)
    assert isinstance(raw, dict)

    # ... and holds EVERY key both writers produced (no lost updates)
    expected = set()
    for t in range(n_shapes):
        spec = matmul_spec(128 * (t + 1), 128, 128)
        expected.add(plan_key(spec, np.float32))
        for d in derived_specs(spec).values():
            expected.add(plan_key(d, np.float32))
    missing = expected - set(raw)
    assert not missing, (
        f"{len(missing)}/{len(expected)} plan entries lost to concurrent "
        f"writers — the read-merge-write in AutotuneCache.put is racing"
    )

    # the surviving entries round-trip through the ops-facing lookup
    db = PlanDB(path)
    spec = matmul_spec(128, 128, 128)
    for s in (spec, *derived_specs(spec).values()):
        assert db.best_schedule(s, np.float32) is not None


def test_lock_file_is_reused_not_leaked(tmp_path):
    """put() creates one sibling .lock file and keeps using it."""
    path = str(tmp_path / "plans.json")
    db = PlanDB(path)
    from repro.codegen import default_schedule
    from repro.search.plandb import entry_from

    for m in (128, 256):
        spec = matmul_spec(m, 128, 128)
        db.put(
            spec, np.float32,
            [entry_from(default_schedule(spec), score=1.0,
                        lower_bound=0.0, fits_vmem=True)],
        )
    siblings = sorted(os.listdir(tmp_path))
    assert siblings == ["plans.json", "plans.json.lock"]


def test_clear_removes_stale_lock_sibling(tmp_path):
    """clear() must also remove ``<path>.lock`` — a cleared cache that
    leaves the lock file behind looks half-deleted and re-creating the
    cache at the same path inherits a stale sibling."""
    from repro.codegen.cache import AutotuneCache

    path = str(tmp_path / "cache.json")
    c = AutotuneCache(path)
    c.put("k", {"v": 1})
    assert os.path.exists(path) and os.path.exists(path + ".lock")
    c.clear()
    assert not os.path.exists(path)
    assert not os.path.exists(path + ".lock")
    # still usable afterwards
    c.put("k2", 2)
    assert c.get("k2") == 2


def test_threaded_readers_count_hits_and_misses_exactly(tmp_path):
    """Regression: ``get()`` bumped hits/misses OUTSIDE the cache lock, so
    concurrent readers raced the read-modify-write and lost counts — the
    attributes could disagree with the obs counters and with reality.
    Both accountings must now be exact under contention."""
    import threading

    from repro import obs
    from repro.codegen.cache import AutotuneCache

    obs.metrics_reset()
    try:
        c = AutotuneCache(str(tmp_path / "cache.json"))
        c.metrics_prefix = "cachetest"
        c.put("present", 1)
        c.hits = c.misses = 0          # discount put-time bookkeeping
        obs.metrics_reset()

        n_threads, n_iter = 8, 300
        barrier = threading.Barrier(n_threads)

        def reader():
            barrier.wait()
            for _ in range(n_iter):
                c.get("present")
                c.get("absent")

        threads = [threading.Thread(target=reader) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        expected = n_threads * n_iter
        assert c.hits == expected, f"lost {expected - c.hits} hit counts"
        assert c.misses == expected, (
            f"lost {expected - c.misses} miss counts"
        )
        j = obs.metrics_json()
        assert j["counters"]["cachetest.hit"] == expected
        assert j["counters"]["cachetest.miss"] == expected
    finally:
        obs.metrics_reset()
