"""Quantized generated kernels (int8 / fp8): the tentpole pinning suite.

Every quant path reachable from the public surfaces — quantized specs,
the dequant epilogue, the searched dtype ladder, ``ops.dense(quant=...)``,
capture dispatch and weight-only serving — is pinned against an oracle:

  * the generated kernel over int8/fp8 storage vs the dequantize-then-
    einsum f64 oracle (exact for int8, f32-accumulation tolerance for fp8),
  * the scale-application epilogue legs (per-channel AND per-tensor) vs
    the HoF reference interpreter (``core.interp``) over the dequantized
    operand values,
  * empty / odd-extent / scale-granularity edge cases,
  * golden plan-key pins: quant keys are stable derivations, disjoint
    from the bf16/f32 keys at the same geometry,
  * the fused-family refusal surfaces (no epilogue / no mesh tier / no
    quantized lowering), pinned to their exact messages.

Like the differential suite, every case draws from an explicit PRNG seed
matrix — failures reproduce from the parametrization id alone.
"""

from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import codegen, ops  # noqa: E402
from repro.codegen.cache import cache_key, spec_signature  # noqa: E402
from repro.core.enumerate import (  # noqa: E402
    QUANT_FORMATS,
    QuantMeta,
    attention_spec,
    evaluate_variant,
    matmul_spec,
    quantize_spec,
    quantized_matmul_spec,
)
from repro.optim.quant import (  # noqa: E402
    quantize_channels,
    quantize_tensor,
)
from repro.search import (  # noqa: E402
    QUANT_TIERS,
    best_dtype_tier,
    candidate_schedule,
    dtype_tier_specs,
    einsum_reference,
    reference_arrays,
    search_dtype_ladder,
)


@pytest.fixture(autouse=True)
def _isolated_caches(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    monkeypatch.setenv("REPRO_PLAN_DB", str(tmp_path / "plans.json"))


def _storage_jnp(fmt: str):
    dt = getattr(jnp, QUANT_FORMATS[fmt].dtype, None)
    if dt is None:
        pytest.skip(f"jax build lacks {QUANT_FORMATS[fmt].dtype}")
    return dt


# ---------------------------------------------------------------------------
# spec layer: QuantMeta validation, quantize_spec guards, quant survives
# subdivision via root()
# ---------------------------------------------------------------------------


class TestQuantSpec:
    def test_quant_meta_validates_fields(self):
        QuantMeta(dtype="int8", accum="int32")  # the canonical formats
        QuantMeta(dtype="float8_e4m3fn", accum="float32",
                  scale="per_tensor")
        with pytest.raises(ValueError, match="unsupported quant dtype"):
            QuantMeta(dtype="int4", accum="int32")
        with pytest.raises(ValueError, match="unsupported quant accumulator"):
            QuantMeta(dtype="int8", accum="float16")
        with pytest.raises(ValueError, match="unsupported scale granularity"):
            QuantMeta(dtype="int8", accum="int32", scale="per_row")

    def test_quantize_spec_rejects_non_root(self):
        child = matmul_spec(4, 4, 4).subdivide("i", 2)
        with pytest.raises(ValueError, match="root"):
            quantize_spec(child, fmt="int8")

    def test_quantize_spec_rejects_fused(self):
        with pytest.raises(
            NotImplementedError,
            match="fused family 'attention' has no quantized lowering",
        ):
            quantize_spec(attention_spec(2, 8, 8, 4), fmt="int8")

    def test_quantize_spec_rejects_unknown_format(self):
        with pytest.raises(ValueError, match="unknown quant format 'int4'"):
            quantize_spec(matmul_spec(4, 4, 4), fmt="int4")

    def test_quant_survives_subdivision_via_root(self):
        spec = quantized_matmul_spec(8, 8, 8, fmt="int8")
        child = spec.subdivide("i", 2).subdivide("k", 4)
        # subdivide drops the field on children (like fused_kind); the
        # detection contract is always getattr(spec.root(), "quant", None)
        assert getattr(child, "quant", None) is None
        assert child.root().quant == QUANT_FORMATS["int8"]

    def test_quantized_spec_keeps_family_name(self):
        # quantization is a storage property, not a new family: the name
        # (and therefore the plan-key family prefix) must not change
        assert quantized_matmul_spec(8, 8, 8).name == matmul_spec(8, 8, 8).name


# ---------------------------------------------------------------------------
# golden plan-key pins: quant signatures are stable derivations, disjoint
# from the full-precision keys at the same geometry
# ---------------------------------------------------------------------------


class TestQuantKeys:
    def test_signature_folds_quant_only_when_present(self):
        plain = spec_signature(matmul_spec(64, 64, 64))
        assert "quant" not in plain  # pre-quant signatures stay byte-equal
        q = spec_signature(quantized_matmul_spec(64, 64, 64, fmt="int8"))
        assert q["quant"] == {
            "dtype": "int8", "accum": "int32", "scale": "per_channel",
        }
        base = {k: v for k, v in q.items() if k != "quant"}
        assert base == plain

    @pytest.mark.parametrize("fmt", sorted(QUANT_FORMATS))
    def test_quant_keys_disjoint_from_bf16(self, fmt):
        meta = QUANT_FORMATS[fmt]
        spec = matmul_spec(128, 128, 128)
        qspec = quantize_spec(spec, fmt=fmt)
        keys = {
            cache_key(spec, dtype=np.dtype(np.float32), hardware="pin/hw"),
            cache_key(spec, dtype=jnp.bfloat16, hardware="pin/hw"),
            cache_key(qspec, dtype=np.dtype(meta.dtype), hardware="pin/hw"),
            # even at the SAME dtype string the quant signature separates:
            # a re-tagged spec never collides with the full-precision plan
            cache_key(qspec, dtype=jnp.bfloat16, hardware="pin/hw"),
        }
        assert len(keys) == 4

    def test_quant_key_derivation_is_stable(self):
        a = cache_key(
            quantized_matmul_spec(64, 64, 64, fmt="int8"),
            dtype=np.dtype(np.int8), hardware="pin/hw",
        )
        b = cache_key(
            quantize_spec(matmul_spec(64, 64, 64), fmt="int8"),
            dtype=np.dtype(np.int8), hardware="pin/hw",
        )
        assert a == b  # same logical point -> same key, however constructed
        assert a != cache_key(
            quantized_matmul_spec(64, 64, 64, fmt="int8", scale="per_tensor"),
            dtype=np.dtype(np.int8), hardware="pin/hw",
        )  # scale granularity is part of the key


# ---------------------------------------------------------------------------
# fused-family refusal surfaces, pinned to their exact messages
# ---------------------------------------------------------------------------


class TestFusedRefusals:
    def _fused_schedule(self, spec):
        order = tuple(spec.indices)
        blocks = {i: spec.extents[i] for i in spec.indices}
        return candidate_schedule(spec, order, blocks)

    def test_fused_kernels_take_no_epilogue(self):
        spec = attention_spec(2, 8, 8, 4)
        sched = self._fused_schedule(spec)
        with pytest.raises(
            NotImplementedError, match="^fused kernels take no epilogue$"
        ):
            codegen.compile(
                spec, sched, interpret=True,
                epilogue=codegen.Epilogue(dequant=True),
            )

    def test_fused_families_have_no_mesh_tier(self):
        spec = attention_spec(2, 8, 8, 4)
        sched = self._fused_schedule(spec)
        with pytest.raises(
            NotImplementedError,
            match="^fused families have no mesh tier yet$",
        ):
            codegen.compile(spec, sched, interpret=True, mesh=object())


# ---------------------------------------------------------------------------
# scale-application legs: dequant epilogue vs core.interp over the
# dequantized operands — per-channel AND per-tensor granularity
# ---------------------------------------------------------------------------

SCALE_SEEDS = tuple(range(4))


class TestScaleApplication:
    @pytest.mark.parametrize("seed", SCALE_SEEDS)
    @pytest.mark.parametrize("granularity", ["per_channel", "per_tensor"])
    def test_dequant_epilogue_matches_interp(self, granularity, seed):
        fmt = "int8"
        rng = np.random.default_rng(12000 + seed)
        m, d, f = 8, 6, 4
        x = rng.standard_normal((m, d)).astype(np.float32)
        # wildly different column magnitudes: the case per-channel exists
        # for (and where per-tensor visibly loses precision)
        w = (rng.standard_normal((d, f))
             * np.logspace(-2, 2, f)[None, :]).astype(np.float32)

        qx, sx = quantize_tensor(jnp.asarray(x), fmt)
        if granularity == "per_channel":
            qw, sw = quantize_channels(jnp.asarray(w), fmt)
            qscale = (sx * sw).astype(jnp.float32)
        else:
            qw, sw = quantize_tensor(jnp.asarray(w), fmt)
            qscale = jnp.full((f,), float(sx * sw), jnp.float32)

        spec = quantized_matmul_spec(m, d, f, fmt=fmt, scale=granularity)
        sched = candidate_schedule(
            spec, tuple(spec.indices),
            {i: spec.extents[i] for i in spec.indices},
        )
        kern = codegen.compile(
            spec, sched, interpret=True,
            epilogue=codegen.Epilogue(dequant=True),
        )
        out = np.asarray(kern(qx, qw, qscale=qscale), np.float64)
        assert out.dtype == np.float64 and out.shape == (m, f)

        # oracle: the HoF reference interpreter over the DEQUANTIZED
        # operand values, scales applied the same way the epilogue does
        deq = {
            "A": np.asarray(qx, np.float64) * float(sx),
            "B": np.asarray(qw, np.float64) * (
                np.asarray(sw, np.float64)[None, :]
                if granularity == "per_channel" else float(sw)
            ),
        }
        ref = np.asarray(
            evaluate_variant(spec, spec.indices, deq), np.float64
        )
        scale = max(np.abs(ref).max(), 1.0)
        np.testing.assert_allclose(
            out / scale, ref / scale, rtol=1e-5, atol=1e-5,
            err_msg=f"dequant epilogue ({granularity}) != interp oracle "
                    f"(seed={seed})",
        )

    def test_per_channel_beats_per_tensor_on_skewed_weights(self):
        rng = np.random.default_rng(12100)
        x = rng.standard_normal((16, 12)).astype(np.float32)
        w = (rng.standard_normal((12, 8))
             * np.logspace(-3, 1, 8)[None, :]).astype(np.float32)
        ref = x.astype(np.float64) @ w.astype(np.float64)

        # ops.dense's quant tier IS per-channel on w
        per_channel = np.asarray(
            ops.dense(jnp.asarray(x), jnp.asarray(w), quant="int8"),
            np.float64,
        )
        qx, sx = quantize_tensor(jnp.asarray(x), "int8")
        qw, sw = quantize_tensor(jnp.asarray(w), "int8")
        per_tensor = (np.asarray(qx, np.float64) @
                      np.asarray(qw, np.float64)) * float(sx) * float(sw)

        def worst_col_rel(out):
            # per-COLUMN relative error: max-abs error hides the contrast
            # because both granularities agree on the largest column
            return (np.abs(out - ref).max(axis=0)
                    / np.abs(ref).max(axis=0)).max()

        assert worst_col_rel(per_channel) < 0.05
        assert worst_col_rel(per_channel) < 0.1 * worst_col_rel(per_tensor), (
            "per-channel scales must beat per-tensor on column-skewed "
            "weights — that is the granularity's reason to exist"
        )


# ---------------------------------------------------------------------------
# differential matrix: the searched ladder's quant kernels vs the
# dequantized-oracle, with bounded max_err and disjoint plan keys
# ---------------------------------------------------------------------------

LADDER_SHAPES = ((8, 8, 8), (16, 4, 8))


class TestSearchedLadder:
    @pytest.mark.parametrize("m,k,n", LADDER_SHAPES)
    def test_ladder_tiers_measured_against_dequant_oracle(self, m, k, n):
        from repro.search import default_plan_db

        for fmt in QUANT_FORMATS:
            _storage_jnp(fmt)  # skip early if the build lacks fp8
        results = search_dtype_ladder(
            matmul_spec(m, k, n), dtype=np.float32,
            beam_width=4, topk=2, interpret=True, measure=True,
            plan_db=default_plan_db(),
        )
        assert set(results) == {"baseline", *QUANT_TIERS}
        # measurement ran the kernel against the f64 dequantized oracle
        # (reference_arrays draws exact small ints for int storage):
        # int8 must be exact, fp8 within f32-accumulation tolerance
        assert results["int8"].best.max_err == 0.0
        assert results["fp8"].best.max_err is not None
        assert results["fp8"].best.max_err <= 1e-3
        assert results["baseline"].best.max_err <= 1e-3
        # each tier persisted under its own dtype-qualified plan key
        keys = {t: r.db_key for t, r in results.items()}
        assert all(keys.values()) and len(set(keys.values())) == 3

    def test_quant_tier_wins_the_analytic_roofline(self):
        results = search_dtype_ladder(
            matmul_spec(8, 8, 8), dtype=np.float32,
            beam_width=4, topk=2, interpret=True, measure=False,
        )
        # 1-byte operands cut HBM traffic ~4x at matched shapes; the
        # analytic score must reflect it and best_dtype_tier must pick a
        # quant tier over the f32 baseline
        base = results["baseline"].best.score
        assert results["int8"].best.score < base
        assert results["fp8"].best.score < base
        assert best_dtype_tier(results) in QUANT_TIERS

    def test_dtype_tier_specs_baseline_only_for_fused(self):
        tiers = dtype_tier_specs(attention_spec(2, 8, 8, 4))
        assert [t for t, _, _ in tiers] == ["baseline"]


# ---------------------------------------------------------------------------
# ops.dense quant tier: kernel path, fallback path, edge cases
# ---------------------------------------------------------------------------


class TestOpsDenseQuant:
    @pytest.mark.parametrize("fmt", sorted(QUANT_FORMATS))
    def test_kernel_path_matches_dequant_oracle(self, fmt):
        _storage_jnp(fmt)
        rng = np.random.default_rng(13000)
        m = d = f = 128  # aligned: takes the generated-kernel path
        x = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((d, f)) / 8, jnp.float32)

        out = np.asarray(
            ops.dense(x, w, quant=fmt, interpret=True), np.float64
        )
        assert out.shape == (m, f)

        # oracle: f64 product of the dequantized operands — exactly what
        # the kernel's int32/f32 accumulator + qscale epilogue computes
        qx, sx = quantize_tensor(x, fmt)
        qw, sw = quantize_channels(w, fmt)
        ref = (np.asarray(qx, np.float64) * float(sx)) @ (
            np.asarray(qw, np.float64) * np.asarray(sw, np.float64)[None, :]
        )
        scale = max(np.abs(ref).max(), 1.0)
        np.testing.assert_allclose(
            out / scale, ref / scale, rtol=1e-5, atol=1e-5,
            err_msg=f"ops.dense(quant={fmt!r}) kernel path != dequantized "
                    "oracle",
        )
        # end-to-end quantization error vs the full-precision product
        # stays in the dynamic-quantization regime
        full = np.asarray(x, np.float64) @ np.asarray(w, np.float64)
        rel = np.abs(out - full).max() / max(np.abs(full).max(), 1.0)
        assert rel < (0.05 if fmt == "int8" else 0.1)

    def test_fallback_path_odd_shapes(self):
        # unaligned extents can't take the kernel; the fallback must keep
        # identical quantization semantics
        rng = np.random.default_rng(13100)
        x = jnp.asarray(rng.standard_normal((3, 5, 60)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((60, 7)), jnp.float32)
        out = np.asarray(ops.dense(x, w, quant="int8"), np.float64)
        assert out.shape == (3, 5, 7)
        qx, sx = quantize_tensor(x.reshape(-1, 60), "int8")
        qw, sw = quantize_channels(w, "int8")
        ref = ((np.asarray(qx, np.float64) * float(sx)) @ (
            np.asarray(qw, np.float64) * np.asarray(sw, np.float64)[None, :]
        )).reshape(3, 5, 7)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_empty_batch(self):
        x = jnp.zeros((0, 16), jnp.float32)
        w = jnp.ones((16, 8), jnp.float32)
        out = ops.dense(x, w, quant="int8")
        assert out.shape == (0, 8) and out.dtype == jnp.float32

    def test_unknown_format_raises(self):
        with pytest.raises(ValueError, match="int4"):
            ops.dense(
                jnp.ones((4, 4)), jnp.ones((4, 4)), quant="int4"
            )

    def test_odd_extent_kernel_exact_small_ints(self):
        # odd extents through the raw quant kernel (no epilogue): int32
        # accumulation of small-int products is closed, so equality is
        # exact, padding included
        rng = np.random.default_rng(13200)
        spec = quantized_matmul_spec(3, 7, 5, fmt="int8")
        sched = candidate_schedule(
            spec, tuple(spec.indices), {"i": 3, "j": 7, "k": 5}
        )
        arrays = reference_arrays(spec, dtype=np.int8, seed=5)
        kern = codegen.compile(spec, sched, interpret=True)
        out = np.asarray(kern(*(
            jnp.asarray(arrays[nm], jnp.int8) for nm in spec.operands
        )))
        assert out.dtype == np.int32
        ref = einsum_reference(spec, arrays)
        assert np.array_equal(out, ref.astype(np.int64))


# ---------------------------------------------------------------------------
# capture + serving: the quant policy threads end to end
# ---------------------------------------------------------------------------


class TestQuantIntegration:
    def test_capture_dispatches_quant_dense(self):
        from repro import capture

        def f(x, w1, w2):
            return jnp.dot(jnp.tanh(jnp.dot(x, w1)), w2)

        rng = np.random.default_rng(14000)
        x = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        w1 = jnp.asarray(rng.standard_normal((128, 128)) / 11, jnp.float32)
        w2 = jnp.asarray(rng.standard_normal((128, 128)) / 11, jnp.float32)
        ref = f(x, w1, w2)

        qf = capture.optimize(f, interpret=True, quant="int8")
        out = qf(x, w1, w2)
        assert qf.report_for(x, w1, w2).dispatched == 2
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel < 0.05, f"quantized capture diverged: rel={rel}"

    def test_sweep_captured_persists_quant_leg(self, tmp_path):
        from repro.capture import sweep_captured
        from repro.search import PlanDB

        db = PlanDB(str(tmp_path / "qdb.json"))
        n = sweep_captured(
            [("t", matmul_spec(16, 16, 16), "float32")],
            with_grads=False, measure=False, plan_db=db, quant="int8",
        )
        assert n == 2  # fwd + fwd@int8
        import json

        with open(db.path) as fh:
            entries = list(json.load(fh).values())
        quants = [e for e in entries if e["spec"].get("quant")]
        assert len(quants) == 1
        assert quants[0]["dtype"] == "int8"
        assert quants[0]["spec"]["quant"]["accum"] == "int32"

    def test_sweep_captured_rejects_unknown_quant(self):
        from repro.capture import sweep_captured

        with pytest.raises(ValueError, match="quant must be one of"):
            sweep_captured(
                [("t", matmul_spec(8, 8, 8), "float32")], quant="int4"
            )

    def test_weight_only_serving_dequantizes_inside_jit(self):
        """quantize_tree once at load + dequantize inside a jitted step
        must equal quantize-then-dequantize outside jit — the serving
        contract of ``--quant int8``."""
        from repro.optim.quant import (Quantized, dequantize_tree,
                                       quantize_tree, tree_quant_bytes)

        rng = np.random.default_rng(14100)
        params = {
            "proj": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32),
            "bias": jnp.asarray(rng.standard_normal(64), jnp.float32),
        }
        qtree = quantize_tree(params, fmt="int8", min_size=64)
        assert isinstance(qtree["proj"], Quantized)
        assert isinstance(qtree["bias"], jax.Array)  # 1-D stays f32
        assert tree_quant_bytes(qtree) > 0

        def step(p, x):
            p = dequantize_tree(p)
            return jnp.dot(x, p["proj"]) + p["bias"]

        x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
        jitted = np.asarray(jax.jit(step)(qtree, x))
        eager = np.asarray(
            jnp.dot(x, dequantize_tree(qtree)["proj"]) + qtree["bias"]
        )
        np.testing.assert_allclose(jitted, eager, rtol=1e-6, atol=1e-6)
