"""Trip-count-aware HLO cost parser: exact on scans, sane on grad+remat."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_parse import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_multiplied_by_trip_count():
    n, L = 64, 30
    Ws = jnp.zeros((L, n, n))
    x = jnp.zeros((n, n))

    def f(x, Ws):
        def step(c, W):
            return jnp.dot(c, W, preferred_element_type=jnp.float32), None
        return jax.lax.scan(step, x, Ws)[0]

    comp = _compile(f, x, Ws)
    out = analyze_hlo(comp.as_text())
    expect = L * 2 * n ** 3
    assert out["dot_flops"] == pytest.approx(expect, rel=0.01)
    # XLA's own analysis counts the body once — our reason for existing
    xla_cost = comp.cost_analysis()
    if isinstance(xla_cost, list):  # jax 0.4.x returns [dict]
        xla_cost = xla_cost[0] if xla_cost else {}
    assert xla_cost["flops"] < expect / (L / 2)


def test_grad_of_scan_counts_fwd_plus_bwd():
    n, L = 32, 10
    Ws = jnp.zeros((L, n, n))
    x = jnp.zeros((n, n))

    def f(x, Ws):
        def step(c, W):
            return jnp.tanh(jnp.dot(c, W)), None
        return jnp.sum(jax.lax.scan(step, x, Ws)[0])

    comp = _compile(jax.grad(f), x, Ws)
    out = analyze_hlo(comp.as_text())
    fwd = L * 2 * n ** 3
    # at least the two backward dots per step (XLA may DCE/fuse the forward
    # dot when only the gradient is returned), at most fwd+bwd+remat
    assert 1.9 * fwd <= out["dot_flops"] <= 4.5 * fwd


def test_nested_scan_multiplies():
    n, L1, L2 = 16, 4, 5
    x = jnp.zeros((n, n))
    W = jnp.zeros((L1, L2, n, n))

    def f(x, W):
        def outer(c, Wi):
            def inner(ci, Wj):
                return jnp.dot(ci, Wj, preferred_element_type=jnp.float32), None
            return jax.lax.scan(inner, c, Wi)[0], None
        return jax.lax.scan(outer, x, W)[0]

    comp = _compile(f, x, W)
    out = analyze_hlo(comp.as_text())
    assert out["dot_flops"] == pytest.approx(
        L1 * L2 * 2 * n ** 3, rel=0.01
    )


def test_no_loops_plain_dot():
    a = jnp.zeros((8, 16))
    b = jnp.zeros((16, 24))
    comp = _compile(lambda a, b: a @ b, a, b)
    out = analyze_hlo(comp.as_text())
    assert out["dot_flops"] == pytest.approx(2 * 8 * 16 * 24, rel=0.01)
