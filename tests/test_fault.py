"""Straggler-watchdog metrics: runtime.fault surfaces step walls via obs.

``FaultTolerantLoop._watch`` reports every per-step wall time into the
``repro.obs`` registry — ``fault.step_wall_s`` (histogram),
``fault.last_step_wall_s`` / ``fault.step_median_s`` (gauges) and
``fault.straggler_events`` (counter).  These tests drive the loop with an
injected clock (same pattern as
``test_substrate.py::test_straggler_watchdog``) so the expected values
are exact, and pin the ``REPRO_OBS=0`` contract: the loop runs
identically but the registry stays empty.
"""

from __future__ import annotations

import statistics

import pytest

from repro import obs
from repro.runtime.fault import FaultTolerantLoop, LoopConfig, StepFailure


@pytest.fixture(autouse=True)
def _clean_obs(monkeypatch):
    monkeypatch.delenv("REPRO_OBS", raising=False)
    obs.metrics_reset()
    yield
    obs.metrics_reset()


#: clock readings for 8 steps of 1s each, except step 5 takes 25s
STRAGGLER_TIMES = [0.0, 1.0,   # step 0
                   1.0, 2.0,   # step 1
                   2.0, 3.0, 3.0, 4.0, 4.0, 5.0,
                   5.0, 30.0,  # step 5: 25s straggler
                   30.0, 31.0, 31.0, 32.0]


def _run_loop():
    times = iter(STRAGGLER_TIMES)
    loop = FaultTolerantLoop(
        step_fn=lambda s, st: st,
        save_fn=lambda *a: None,
        restore_fn=lambda: (0, 0.0),
        config=LoopConfig(checkpoint_every=1000, straggler_factor=3.0),
        clock=lambda: next(times),
    )
    loop.run(0.0, 0, 8)
    return loop


def test_straggler_step_walls_reach_metrics_registry():
    loop = _run_loop()
    assert 5 in loop.report.straggler_events  # the pre-obs behaviour holds

    j = obs.metrics_json()
    walls = [STRAGGLER_TIMES[2 * i + 1] - STRAGGLER_TIMES[2 * i]
             for i in range(8)]
    h = j["histograms"]["fault.step_wall_s"]
    assert h["count"] == 8
    assert h["sum"] == pytest.approx(sum(walls))
    assert h["max"] == pytest.approx(25.0)

    assert j["counters"]["fault.straggler_events"] == 1
    assert j["gauges"]["fault.last_step_wall_s"] == pytest.approx(walls[-1])
    # the median gauge holds the last window median the watchdog computed
    # (steps 0..6 at the final step, the 25s outlier included)
    assert j["gauges"]["fault.step_median_s"] == pytest.approx(
        statistics.median(walls[:-1])
    )


def test_histogram_percentiles_over_step_walls():
    import numpy as np

    _run_loop()
    h = obs.registry().histogram("fault.step_wall_s")
    walls = [STRAGGLER_TIMES[2 * i + 1] - STRAGGLER_TIMES[2 * i]
             for i in range(8)]
    assert h.percentile(50) == pytest.approx(float(np.percentile(walls, 50)))
    assert h.percentile(99) == pytest.approx(float(np.percentile(walls, 99)))


def test_watchdog_is_noop_when_disabled(monkeypatch):
    monkeypatch.setenv("REPRO_OBS", "0")
    loop = _run_loop()
    # loop behaviour identical (report still filled)...
    assert 5 in loop.report.straggler_events
    assert loop.report.steps_run == 8
    # ...but nothing reached the registry
    assert obs.registry().names() == []


def test_default_configs_are_per_loop_not_shared():
    """Regression: ``config: LoopConfig = LoopConfig()`` as the dataclass
    default evaluated ONCE at import, so every loop built without an
    explicit config shared one mutable LoopConfig — tuning one loop's
    thresholds silently retuned every other loop in the process."""
    def mk(**kw):
        return FaultTolerantLoop(
            step_fn=lambda s, st: st,
            save_fn=lambda *a: None,
            restore_fn=lambda: (0, 0.0),
            **kw,
        )

    a, b = mk(), mk()
    assert a.cfg is not b.cfg
    a.cfg.straggler_factor = 99.0
    a.cfg.checkpoint_every = 7
    assert b.cfg.straggler_factor == LoopConfig().straggler_factor
    assert b.cfg.checkpoint_every == LoopConfig().checkpoint_every
    # an explicit config is adopted as-is, not copied
    mine = LoopConfig(max_retries=9)
    assert mk(config=mine).cfg is mine


def test_failure_replay_does_not_double_count_steps():
    """A failing step restores + replays; only *completed* steps report
    wall times, so the histogram count equals steps_run exactly."""
    calls = {"n": 0}

    def step_fn(step, state):
        calls["n"] += 1
        if step == 2 and calls["n"] == 3:  # fail on first visit to step 2
            raise StepFailure("injected")
        return state

    t = iter(float(i) for i in range(100))
    loop = FaultTolerantLoop(
        step_fn=step_fn,
        save_fn=lambda *a: None,
        restore_fn=lambda: (2, 0.0),
        config=LoopConfig(checkpoint_every=1000),
        clock=lambda: next(t),
    )
    loop.run(0.0, 0, 4)
    assert loop.report.failures == 1
    j = obs.metrics_json()
    assert (j["histograms"]["fault.step_wall_s"]["count"]
            == loop.report.steps_run)
