"""Serving-tier correctness: paged KV, continuous batching, phase ladders.

The load-bearing guarantees pinned here:

* **paged mapping** — the block-table gather presents pages in list
  order, and the post-step scatter lands the appended KV row on exactly
  page ``pages[len // page_size]``, offset ``len % page_size``.
* **batched == solo** — right-padded prefill with a length mask means a
  short prompt batched with longer ones produces bitwise-identical
  greedy tokens to running it alone (the padding-leak regression).
* **continuous == fixed == solo** — the differential acceptance test:
  all three execution strategies agree per request.
* **preemption is exact** — recompute-style eviction under page pressure
  yields the same tokens as an uninterrupted run.
* **accounting** — tok/s counts decode-produced tokens over decode time
  only, no trailing wasted dispatch, max_new=0 requests still observe
  latency and count as served, EOS finishes both engines early.
* **phase ladders** — plan keys gain a phase qualifier without
  perturbing existing (unphased) keys, and ``ops._tuned_kernel``
  consults the active phase's ladder first.
"""

from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("jax")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import obs  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.launch.serve import BatchServer, Request  # noqa: E402
from repro.launch.serving import (  # noqa: E402
    ContinuousEngine,
    FixedEngine,
    Gateway,
    PagePool,
    Scheduler,
    ServeRequest,
    synthetic_trace,
)
from repro.launch.serving import paged  # noqa: E402


@pytest.fixture(autouse=True)
def _clean_obs(monkeypatch):
    monkeypatch.delenv("REPRO_OBS", raising=False)
    obs.metrics_reset()
    yield
    obs.metrics_reset()


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3-8b").smoke()


@pytest.fixture(scope="module")
def solo_server(cfg):
    return BatchServer(cfg, batch_size=1, max_len=16)


def _prompt(rng, n, vocab):
    return rng.integers(0, vocab, size=n).astype(np.int32)


def _solo_tokens(solo_server, prompt, max_new, eos_id=None):
    req = Request(rid=0, prompt=prompt, max_new=max_new)
    solo_server.run([req], eos_id=eos_id)
    return req.out_tokens


# --------------------------------------------------------------------------
# page pool + scheduler (pure host-side units)
# --------------------------------------------------------------------------


class TestPagePool:
    def test_alloc_free_roundtrip(self):
        pool = PagePool(8, page_size=4)
        assert pool.capacity == 7
        got = pool.alloc(3)
        assert len(got) == 3 and paged.SINK_PAGE not in got
        assert pool.free_count == 4
        # an unsatisfiable alloc takes nothing
        assert pool.alloc(5) is None and pool.free_count == 4
        pool.free(got)
        assert pool.free_count == 7

    def test_double_free_rejected(self):
        pool = PagePool(4, page_size=2)
        got = pool.alloc(1)
        pool.free(got)
        with pytest.raises(ValueError, match="double free"):
            pool.free(got)

    def test_sink_page_never_allocated(self):
        pool = PagePool(4, page_size=2)
        assert paged.SINK_PAGE not in pool.alloc(3)

    def test_pages_for(self):
        pool = PagePool(4, page_size=4)
        assert pool.pages_for(0) == 1       # even an empty ctx owns a page
        assert pool.pages_for(4) == 1
        assert pool.pages_for(5) == 2


def _sreq(rid, plen, max_new):
    return ServeRequest(
        rid=rid, prompt=np.zeros(plen, np.int32), max_new=max_new
    )


class TestScheduler:
    def test_fcfs_admission_respects_watermark(self):
        sched = Scheduler(PagePool(10, 2), lanes=4, watermark=4)
        for i in range(3):
            sched.submit(_sreq(i, plen=4, max_new=2))   # 2 pages each
        admitted = sched.admit()
        # 9 free: req0 -> 7 spare, req1 -> 5 spare, req2 would leave 3 < 4
        assert [r.rid for r in admitted] == [0, 1]
        assert [r.rid for r in sched.queue] == [2]     # head-of-line waits

    def test_progress_guarantee_overrides_watermark_when_idle(self):
        sched = Scheduler(PagePool(4, 2), lanes=1, watermark=100)
        sched.submit(_sreq(0, plen=4, max_new=1))
        assert [r.rid for r in sched.admit()] == [0]

    def test_grow_preempts_newest_and_requeues_at_head(self):
        pool = PagePool(4, 2)                          # 3 usable pages
        sched = Scheduler(pool, lanes=2, watermark=0)
        sched.submit(_sreq(0, plen=2, max_new=4))
        sched.submit(_sreq(1, plen=2, max_new=4))
        old, new = sched.admit()
        # both generated 2 tokens -> both now need a second page
        for r in (old, new):
            r.out_tokens = [1, 2]
        preempted = sched.grow()
        assert preempted == [new]
        assert new.state == "queued" and new.pages == [] and new.lane == -1
        assert new.preemptions == 1
        assert sched.queue[0] is new                   # FCFS head, not tail
        assert len(old.pages) == 2                     # oldest got the page

    def test_finish_releases_lane_and_pages_immediately(self):
        pool = PagePool(4, 2)
        sched = Scheduler(pool, lanes=1, watermark=0)
        sched.submit(_sreq(0, plen=2, max_new=1))
        (req,) = sched.admit()
        before = pool.free_count
        sched.finish(req)
        assert pool.free_count == before + 1
        assert req.state == "finished" and not sched.running

    def test_oversized_request_rejected_at_submit(self):
        sched = Scheduler(PagePool(3, 2), lanes=1)
        with pytest.raises(ValueError, match="pages"):
            sched.submit(_sreq(0, plen=8, max_new=8))


# --------------------------------------------------------------------------
# paged gather/scatter mapping
# --------------------------------------------------------------------------


def test_paged_view_and_scatter_mapping(cfg):
    page_size = 2
    pools = paged.pool_init(cfg, n_pages=5, page_size=page_size)

    def stamp(leaf):
        # value at (page p, slot s) = 100p + s, broadcast over other axes
        L, P, ps, kv, hd = leaf.shape
        vals = (100 * jnp.arange(P)[:, None] + jnp.arange(ps)[None, :])
        return jnp.broadcast_to(
            vals[None, :, :, None, None].astype(leaf.dtype), leaf.shape
        )

    pools = jax.tree.map(stamp, pools)
    bt = jnp.asarray([[3, 1], [2, 0]], jnp.int32)
    lens = jnp.asarray([3, 1], jnp.int32)
    caches = paged.paged_view(pools, bt, lens, page_size)

    seg = next(iter(caches))
    kind = next(iter(caches[seg]))
    k = caches[seg][kind]["k"]
    # lane 0's view is page 3 then page 1, in block-table order
    np.testing.assert_array_equal(
        np.asarray(k[0, 0, :, 0, 0]), [300.0, 301.0, 100.0, 101.0]
    )
    np.testing.assert_array_equal(
        np.asarray(k[0, 1, :, 0, 0]), [200.0, 201.0, 0.0, 1.0]
    )
    assert int(caches[seg][kind]["len"][0, 0]) == 3

    # fake a decode step: the new KV row lands at view position lens
    marked = {}
    for s, kinds in caches.items():
        marked[s] = {}
        for kd, c in kinds.items():
            nk = c["k"].at[:, 0, 3].set(777.0).at[:, 1, 1].set(888.0)
            marked[s][kd] = {"k": nk, "v": nk, "len": c["len"] + 1}
    pools2 = paged.scatter_token(pools, marked, bt, lens, page_size)
    k2 = pools2[seg][kind]["k"]
    # lane 0: position 3 -> page bt[0, 1]=1, offset 1
    assert float(k2[0, 1, 1, 0, 0]) == 777.0
    # lane 1: position 1 -> page bt[1, 0]=2, offset 1
    assert float(k2[0, 2, 1, 0, 0]) == 888.0
    # untouched slots keep their stamp
    assert float(k2[0, 3, 0, 0, 0]) == 300.0


# --------------------------------------------------------------------------
# padding leak: batched mixed lengths == solo (fixed server)
# --------------------------------------------------------------------------


def test_batched_mixed_lengths_equals_solo(cfg, solo_server):
    rng = np.random.default_rng(0)
    prompts = [_prompt(rng, n, cfg.vocab) for n in (3, 9, 5)]
    max_new = 4
    server = BatchServer(cfg, batch_size=3, max_len=16)
    batch = [
        Request(rid=i, prompt=p, max_new=max_new)
        for i, p in enumerate(prompts)
    ]
    server.run(batch)
    for i, p in enumerate(prompts):
        assert batch[i].out_tokens == _solo_tokens(solo_server, p, max_new), (
            f"request {i} (prompt len {len(p)}) decoded differently "
            "batched with longer prompts than solo — padding is leaking "
            "into attention"
        )


def test_prefill_lengths_mask_matches_unpadded(cfg):
    """Model-level: a right-padded prefill with lengths equals the
    unpadded prefill on logits AND on the cache contents it will serve."""
    from repro.models.api import get_api

    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    prompt = _prompt(rng, 5, cfg.vocab)

    lg_solo, _ = api.prefill(
        params, cfg, {"tokens": jnp.asarray(prompt[None, :])}, 12
    )
    padded = np.zeros((1, 9), np.int32)
    padded[0, :5] = prompt
    lg_masked, _ = api.prefill(
        params, cfg,
        {"tokens": jnp.asarray(padded),
         "lengths": jnp.asarray([5], jnp.int32)},
        12,
    )
    np.testing.assert_allclose(
        np.asarray(lg_solo[0, -1]), np.asarray(lg_masked[0, -1]),
        rtol=2e-5, atol=2e-5,
    )


# --------------------------------------------------------------------------
# engine differential: continuous == fixed == solo
# --------------------------------------------------------------------------


def test_continuous_equals_fixed_equals_solo(cfg, solo_server):
    t_cont = synthetic_trace(5, vocab=cfg.vocab, seed=3, rate_hz=0.0,
                             prompt_lens=(3, 5, 9), max_news=(2, 5))
    t_fixed = synthetic_trace(5, vocab=cfg.vocab, seed=3, rate_hz=0.0,
                              prompt_lens=(3, 5, 9), max_news=(2, 5))
    eng = ContinuousEngine(cfg, lanes=2, page_size=4, n_pages=13, max_ctx=16)
    st = Gateway(eng).run(t_cont)
    fst = FixedEngine(cfg, lanes=2, max_ctx=16).run(t_fixed)

    for a, b in zip(t_cont, t_fixed):
        assert a.out_tokens == b.out_tokens, (
            f"request {a.rid}: continuous {a.out_tokens} != fixed "
            f"{b.out_tokens}"
        )
    for r in t_cont:
        assert r.out_tokens == _solo_tokens(
            solo_server, r.prompt, r.max_new
        ), f"request {r.rid} differs from solo execution"

    assert st["tokens"] == sum(r.max_new for r in t_cont)
    assert fst["tokens"] == st["tokens"]
    # every request produced exactly one prefill-credited token
    assert st["prefill_tokens"] == len(t_cont)


def test_preemption_recompute_is_deterministic(cfg):
    def mk():
        rng = np.random.default_rng(7)
        return [
            ServeRequest(rid=i, prompt=_prompt(rng, 4, cfg.vocab), max_new=8)
            for i in range(3)
        ]

    starved, roomy = mk(), mk()
    st = ContinuousEngine(
        cfg, lanes=3, page_size=2, n_pages=10, max_ctx=12, watermark=0
    ).run(starved)
    assert st["preemptions"] > 0, "pool was sized to force preemption"
    ContinuousEngine(cfg, lanes=3, page_size=2, n_pages=40, max_ctx=12).run(
        roomy
    )
    for a, b in zip(starved, roomy):
        assert a.out_tokens == b.out_tokens, (
            f"request {a.rid}: preempted run {a.out_tokens} != "
            f"uninterrupted {b.out_tokens} — recompute is not exact"
        )


# --------------------------------------------------------------------------
# accounting, max_new=0, EOS
# --------------------------------------------------------------------------


def test_throughput_counts_decode_tokens_only(cfg):
    rng = np.random.default_rng(2)
    server = BatchServer(cfg, batch_size=2, max_len=16)
    reqs = [
        Request(rid=i, prompt=_prompt(rng, 4, cfg.vocab), max_new=5)
        for i in range(2)
    ]
    stats = server.run(reqs)
    assert stats["tokens"] == 10
    # the first token per request came from prefill logits
    assert stats["decode_tokens"] == 8
    # 4 decode dispatches produce tokens 2..5; the loop must not run a
    # 5th, wasted, dispatch after the final emit
    assert stats["decode_steps"] == 4
    assert stats["tok_per_s"] == pytest.approx(
        stats["decode_tokens"] / stats["decode_s"]
    )
    j = obs.metrics_json()
    assert j["counters"]["serve.tokens"] == 10
    assert j["counters"]["serve.requests"] == 2
    assert j["gauges"]["serve.tok_per_s"] == pytest.approx(stats["tok_per_s"])
    assert j["histograms"]["serve.request_latency_s"]["count"] == 2


def test_max_new_zero_fixed_server(cfg):
    rng = np.random.default_rng(3)
    server = BatchServer(cfg, batch_size=2, max_len=8)
    reqs = [
        Request(rid=0, prompt=_prompt(rng, 3, cfg.vocab), max_new=0),
        Request(rid=1, prompt=_prompt(rng, 3, cfg.vocab), max_new=2),
    ]
    stats = server.run(reqs)
    assert reqs[0].done and reqs[0].out_tokens == []
    assert reqs[1].done and len(reqs[1].out_tokens) == 2
    j = obs.metrics_json()
    # the zero-budget request is served, counted, and its latency observed
    assert j["counters"]["serve.requests"] == 2
    assert j["histograms"]["serve.request_latency_s"]["count"] == 2
    assert stats["tokens"] == 2

    # all-zero batch: not a single decode dispatch
    obs.metrics_reset()
    reqs = [
        Request(rid=i, prompt=_prompt(rng, 3, cfg.vocab), max_new=0)
        for i in range(2)
    ]
    stats = server.run(reqs)
    assert stats["decode_steps"] == 0 and stats["tokens"] == 0
    assert obs.metrics_json()["counters"]["serve.requests"] == 2


def test_max_new_zero_continuous_engine(cfg):
    rng = np.random.default_rng(4)
    eng = ContinuousEngine(cfg, lanes=2, page_size=4, n_pages=9, max_ctx=16)
    reqs = [
        ServeRequest(rid=0, prompt=_prompt(rng, 3, cfg.vocab), max_new=0),
        ServeRequest(rid=1, prompt=_prompt(rng, 3, cfg.vocab), max_new=2),
    ]
    stats = eng.run(reqs)
    assert reqs[0].state == "finished" and reqs[0].out_tokens == []
    assert len(reqs[1].out_tokens) == 2
    assert stats["requests"] == 2
    j = obs.metrics_json()
    assert j["counters"]["serve.requests"] == 2
    assert j["histograms"]["serve.request_latency_s"]["count"] == 2
    # the zero-budget request never allocated pages
    assert eng.pool.free_count == eng.pool.capacity


def test_eos_finishes_both_engines_early(cfg, solo_server):
    rng = np.random.default_rng(5)
    prompt = _prompt(rng, 4, cfg.vocab)
    free_run = _solo_tokens(solo_server, prompt, 6)
    assert len(free_run) == 6
    eos = free_run[2]
    expected = free_run[: free_run.index(eos) + 1]

    got_fixed = _solo_tokens(solo_server, prompt, 6, eos_id=eos)
    assert got_fixed == expected

    req = ServeRequest(rid=0, prompt=prompt, max_new=6)
    eng = ContinuousEngine(cfg, lanes=1, page_size=4, n_pages=5, max_ctx=16)
    stats = eng.run([req], eos_id=eos)
    assert req.out_tokens == expected
    # EOS finish still observes latency / counts the request
    assert obs.metrics_json()["counters"]["serve.requests"] >= 1
    assert stats["tokens"] == len(expected)


# --------------------------------------------------------------------------
# phase-tagged plan ladders
# --------------------------------------------------------------------------


def test_plan_key_phase_qualifier_is_compat():
    from repro.core.enumerate import matmul_spec
    from repro.search.plandb import plan_key

    spec = matmul_spec(128, 128, 128)
    # phase=None must hash byte-identically to the pre-phase key — the
    # fleet's existing plan DBs stay warm
    assert plan_key(spec, np.float32) == plan_key(spec, np.float32,
                                                  phase=None)
    decode = plan_key(spec, np.float32, phase="decode")
    assert decode != plan_key(spec, np.float32)
    assert decode != plan_key(spec, np.float32, phase="prefill")


def test_plandb_phase_ladders_are_separate(tmp_path):
    from repro.codegen import default_schedule
    from repro.core.enumerate import matmul_spec
    from repro.search.plandb import PlanDB, entry_from

    db = PlanDB(str(tmp_path / "plans.json"))
    spec = matmul_spec(128, 128, 128)
    db.put(
        spec, np.float32,
        [entry_from(default_schedule(spec), score=1.0, lower_bound=0.0,
                    fits_vmem=True)],
        phase="decode",
    )
    assert db.best_schedule(spec, np.float32) is None
    assert db.best_schedule(spec, np.float32, phase="prefill") is None
    assert db.best_schedule(spec, np.float32, phase="decode") is not None


def test_serving_phase_context_nests():
    from repro.search import active_phase, serving_phase

    assert active_phase() is None
    with serving_phase("prefill"):
        assert active_phase() == "prefill"
        with serving_phase("decode"):
            assert active_phase() == "decode"
        assert active_phase() == "prefill"
    assert active_phase() is None


def test_tuned_kernel_consults_active_phase_first(monkeypatch):
    import repro.search as search
    from repro.core.enumerate import matmul_spec
    from repro.ops import _tuned_kernel
    from repro.search import serving_phase

    lookups = []

    class Recording:
        def best_schedule(self, spec, dtype, phase=None):
            lookups.append(phase)
            return None                      # force tuner fallback

    monkeypatch.setattr(search, "default_plan_db", lambda: Recording())
    spec = matmul_spec(128, 128, 128)
    with serving_phase("decode"):
        _tuned_kernel(spec, np.float32, interpret=True)
    # phased lookup first, unphased fallback second
    assert lookups == ["decode", None]

    lookups.clear()
    _tuned_kernel(spec, np.float32, interpret=True)
    assert lookups == [None]


# --------------------------------------------------------------------------
# trace generator
# --------------------------------------------------------------------------


def test_synthetic_trace_is_seeded_and_ordered():
    a = synthetic_trace(8, vocab=50, seed=9, rate_hz=100.0)
    b = synthetic_trace(8, vocab=50, seed=9, rate_hz=100.0)
    for x, y in zip(a, b):
        assert np.array_equal(x.prompt, y.prompt)
        assert (x.max_new, x.arrival_s, x.tenant) == (
            y.max_new, y.arrival_s, y.tenant
        )
    arrivals = [r.arrival_s for r in a]
    assert arrivals == sorted(arrivals)
    assert len({r.tenant for r in a}) >= 2
    saturated = synthetic_trace(4, vocab=50, seed=0, rate_hz=0.0)
    assert all(r.arrival_s == 0.0 for r in saturated)
