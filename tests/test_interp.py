"""Reference interpreter: DSL formulations of paper examples vs numpy."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import expr as E
from repro.core.expr import (
    App, Flip, Lam, MapN, Prim, RNZ, Subdiv, Flatten, Tup, Var,
    dot, lam, map1, reduce1, v, zip2,
)
from repro.core.interp import run


def rnd(*shape):
    rng = np.random.default_rng(sum(shape) + 7)
    return rng.standard_normal(shape)


def test_dot_product_eq29():
    # dot u v = reduce (+) (zip (*) u v) = rnz (+) (*) u v
    u, w = rnd(5), rnd(5)
    expected = float(u @ w)
    as_reduce = reduce1(Prim("+"), zip2(Prim("*"), v("u"), v("w")))
    as_rnz = dot(v("u"), v("w"))
    np.testing.assert_allclose(run(as_reduce, u=u, w=w), expected, rtol=1e-12)
    np.testing.assert_allclose(run(as_rnz, u=u, w=w), expected, rtol=1e-12)


def test_matvec_eq39():
    # map (\r -> rnz (+) (*) r u) A  ==  A @ u
    A, u = rnd(4, 6), rnd(6)
    e = map1(lam("r", dot(v("r"), v("u"))), v("A"))
    np.testing.assert_allclose(run(e, A=A, u=u), A @ u, rtol=1e-12)


def test_matvec_flipped_eq40():
    # rnz (zip (+)) (\c q -> map (\e -> e*q) c) (flip 0 A) u  ==  A @ u
    A, u = rnd(4, 6), rnd(6)
    e = RNZ(
        E.lift(Prim("+")),
        lam(
            ("c", "q"),
            map1(lam("e", App(Prim("*"), (v("e"), v("q")))), v("c")),
        ),
        (Flip(0, 1, v("A")), v("u")),
    )
    np.testing.assert_allclose(run(e, A=A, u=u), A @ u, rtol=1e-12)


def test_dyadic_product_eq36_37():
    # map (\x -> map (\y -> x*y) u) w == outer(w, u); flipped version transposes
    w, u = rnd(3), rnd(5)
    e1 = map1(
        lam("x", map1(lam("y", App(Prim("*"), (v("x"), v("y")))), v("u"))),
        v("w"),
    )
    np.testing.assert_allclose(run(e1, w=w, u=u), np.outer(w, u), rtol=1e-12)


def test_naive_matmul_eq51():
    # C = map (\rA -> map (\cB -> rnz (+) (*) rA cB) B^T) A
    A, B = rnd(4, 5), rnd(5, 3)
    e = map1(
        lam(
            "rA",
            map1(lam("cB", dot(v("rA"), v("cB"))), Flip(0, 1, v("B"))),
        ),
        v("A"),
    )
    np.testing.assert_allclose(run(e, A=A, B=B), A @ B, rtol=1e-12)


def test_fused_matvec_motivating_eq1():
    # w_i = sum_j (A_ij + B_ij) * (v_j + u_j)
    A, B, vv, u = rnd(3, 4), rnd(3, 4), rnd(4), rnd(4)
    row_sum = zip2(Prim("+"), v("rA"), v("rB"))
    vec_sum = zip2(Prim("+"), v("vv"), v("u"))
    e = MapN(
        lam(("rA", "rB"), reduce1(Prim("+"), zip2(Prim("*"), row_sum, vec_sum))),
        (v("A"), v("B")),
    )
    np.testing.assert_allclose(
        run(e, A=A, B=B, vv=vv, u=u), (A + B) @ (vv + u), rtol=1e-12
    )


def test_weighted_matmul_motivating_eq2():
    # C_ik = sum_j A_ij * B_jk * g_j
    A, B, g = rnd(3, 4), rnd(4, 5), rnd(4)
    e = map1(
        lam(
            "rA",
            map1(
                lam(
                    "cB",
                    RNZ(
                        Prim("+"),
                        lam(
                            ("a", "b", "gg"),
                            App(
                                Prim("*"),
                                (
                                    App(Prim("*"), (v("a"), v("b"))),
                                    v("gg"),
                                ),
                            ),
                        ),
                        (v("rA"), v("cB"), v("g")),
                    ),
                ),
                Flip(0, 1, v("B")),
            ),
        ),
        v("A"),
    )
    np.testing.assert_allclose(
        run(e, A=A, B=B, g=g), np.einsum("ij,jk,j->ik", A, B, g), rtol=1e-12
    )


def test_subdiv_map_identity_eq44():
    # map f v = flatten (map (map f) (subdiv v))
    x = rnd(12)
    f = lam("e", App(Prim("*"), (v("e"), v("e"))))
    naive = map1(f, v("x"))
    blocked = Flatten(
        -2, map1(lam("blk", map1(f, v("blk"))), Subdiv(-1, 4, v("x")))
    )
    np.testing.assert_allclose(
        run(blocked, x=x), run(naive, x=x), rtol=1e-12
    )


def test_rnz_regroup_over_blocks():
    x = rnd(12)
    naive = reduce1(Prim("+"), v("x"))
    blocked = RNZ(
        Prim("+"),
        lam("blk", reduce1(Prim("+"), v("blk"))),
        (Subdiv(-1, 3, v("x")),),
    )
    np.testing.assert_allclose(run(blocked, x=x), run(naive, x=x), rtol=1e-12)


def test_soa_product_map():
    # (map f x, map g y) via FnProd over SoA tuples (paper eqs 30-31)
    x, y = rnd(6), rnd(6)
    f = lam("a", App(Prim("*"), (v("a"), E.Lit(2.0))))
    g = lam("a", App(Prim("+"), (v("a"), E.Lit(1.0))))
    fused = MapN(E.FnProd((f, g)), (Tup((v("x"), v("y"))),))
    out = run(fused, x=x, y=y)
    np.testing.assert_allclose(out[0], 2 * x, rtol=1e-12)
    np.testing.assert_allclose(out[1], y + 1, rtol=1e-12)


@given(
    n=st.integers(1, 8),
    m=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=50, deadline=None)
def test_matvec_property(n, m, seed):
    rng = np.random.default_rng(seed)
    A, u = rng.standard_normal((n, m)), rng.standard_normal(m)
    e = map1(lam("r", dot(v("r"), v("u"))), v("A"))
    np.testing.assert_allclose(run(e, A=A, u=u), A @ u, rtol=1e-10, atol=1e-10)
