"""repro.codegen: generated kernels vs oracles + the persistent cache.

Every kernel here runs in Pallas interpreter mode (CPU container).
Equivalence oracles: the hand-written ``kernels/matmul`` baseline and
``jnp.einsum``, per the acceptance criteria — plain, batched, chained,
and transposed contractions across f32/bf16.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codegen
from repro.codegen.plan import build_plan
from repro.core.enumerate import (
    batched_matmul_spec,
    chain_matmul_spec,
    matmul_spec,
    transposed_matmul_spec,
    weighted_matmul_spec,
)
from repro.kernels.matmul.matmul import matmul_pallas
from repro.kernels.matmul.ref import matmul_ref


def rnd(*shape, dtype=jnp.float32, seed=0):
    x = np.random.default_rng(seed + sum(shape)).standard_normal(shape)
    return jnp.asarray(x, dtype=dtype)


# bf16 atol covers 1-ulp noise from blocked accumulation order
TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=1e-1)}


def assert_close(out, ref, dtype):
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **TOL[dtype],
    )


# -- plain matmul -------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (32, 32, 32, 16, 16, 16),
        (64, 80, 48, 16, 16, 16),
        (128, 64, 128, 64, 32, 32),
        (16, 256, 128, 8, 128, 128),
        (32, 32, 32, 32, 32, 32),   # single block, no grid, no seq loop
    ],
)
def test_generated_matmul_matches_einsum_and_baseline(m, k, n, bm, bn, bk, dtype):
    a, b = rnd(m, k, dtype=dtype), rnd(k, n, dtype=dtype, seed=1)
    spec = matmul_spec(m, k, n)
    sched = codegen.default_schedule(spec, {"i": bm, "k": bn, "j": bk})
    kern = codegen.compile(spec, sched, interpret=True)
    out = kern(a, b)
    assert out.dtype == a.dtype
    ein = jnp.einsum(
        "ij,jk->ik", a.astype(jnp.float32), b.astype(jnp.float32)
    )
    assert_close(out, ein, dtype)
    # the hand-written kernel is the verification baseline
    base = matmul_pallas(a, b, block_m=bm, block_n=bn, block_k=bk,
                         interpret=True)
    assert_close(out, base, dtype)


# -- the three scenarios the repo could not express before --------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_generated_batched_matmul(dtype):
    b, m, k, n = 4, 32, 48, 16
    x = rnd(b, m, k, dtype=dtype)
    w = rnd(b, k, n, dtype=dtype, seed=1)
    sched = codegen.batched_matmul_schedule(
        b, m, k, n, block_m=16, block_n=8, block_k=16
    )
    kern = codegen.compile(sched.spec, sched, interpret=True)
    out = kern(x, w)
    ein = jnp.einsum(
        "bij,bjk->bik", x.astype(jnp.float32), w.astype(jnp.float32)
    )
    assert out.shape == (b, m, n)
    assert_close(out, ein, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_generated_chain_matmul(dtype):
    m, k1, k2, n = 32, 48, 24, 16
    a = rnd(m, k1, dtype=dtype)
    b = rnd(k1, k2, dtype=dtype, seed=1)
    c = rnd(k2, n, dtype=dtype, seed=2)
    sched = codegen.chain_matmul_schedule(
        m, k1, k2, n, block_m=16, block_n=8, block_k1=16, block_k2=12
    )
    kern = codegen.compile(sched.spec, sched, interpret=True)
    out = kern(a, b, c)
    ein = jnp.einsum(
        "ij,jk,kl->il",
        a.astype(jnp.float32), b.astype(jnp.float32), c.astype(jnp.float32),
    )
    assert_close(out, ein, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_generated_transposed_matmul(dtype):
    m, k, n = 32, 48, 16
    a = rnd(k, m, dtype=dtype)   # stored transposed
    b = rnd(k, n, dtype=dtype, seed=1)
    sched = codegen.transposed_matmul_schedule(
        m, k, n, block_m=16, block_n=8, block_k=16
    )
    kern = codegen.compile(sched.spec, sched, interpret=True)
    out = kern(a, b)
    ein = jnp.einsum(
        "ji,jk->ik", a.astype(jnp.float32), b.astype(jnp.float32)
    )
    assert_close(out, ein, dtype)


def test_generated_weighted_matmul():
    """A 3-operand contraction with a shared reduce index (paper eq 2)."""
    m, k, n = 32, 48, 16
    a, b, g = rnd(m, k), rnd(k, n, seed=1), rnd(k, seed=2)
    spec = weighted_matmul_spec(m, k, n)
    sched = codegen.default_schedule(spec, {"i": 16, "k": 8, "j": 16})
    kern = codegen.compile(spec, sched, interpret=True)
    out = kern(a, b, g)
    ein = np.einsum(
        "ij,jk,j->ik", *(np.asarray(x, np.float32) for x in (a, b, g))
    )
    assert_close(out, ein, jnp.float32)


def test_generated_epilogue_subsumes_fused_dense_act():
    from repro.kernels.fused_dense_act.ref import fused_dense_act_ref

    m, d, f = 32, 64, 48
    x, w = rnd(m, d), rnd(d, f, seed=1)
    beta, mean = rnd(f, seed=2), rnd(f, seed=3)
    var = jnp.abs(rnd(f, seed=4)) + 0.5
    spec = matmul_spec(m, d, f)
    sched = codegen.default_schedule(spec, {"i": 16, "k": 16, "j": 16})
    epi = codegen.Epilogue(act="gelu", bias=True, norm=True)
    kern = codegen.compile(spec, sched, epilogue=epi, interpret=True)
    out = kern(x, w, bias=beta, mean=mean, var=var)
    ref = fused_dense_act_ref(x, w, beta, mean, var, act="gelu")
    assert_close(out, ref, jnp.float32)


def test_ops_layer_routes_through_generator(tmp_path, monkeypatch):
    monkeypatch.setenv(
        "REPRO_AUTOTUNE_CACHE", str(tmp_path / "cache.json")
    )
    from repro import ops

    x, w = rnd(128, 128), rnd(128, 128, seed=1)
    out = ops.dense(x, w, interpret=True)
    assert_close(out, np.asarray(x) @ np.asarray(w), jnp.float32)

    xb, wb = rnd(2, 32, 48), rnd(2, 48, 16, seed=1)
    outb = ops.batched_dense(xb, wb, interpret=True)
    assert_close(
        outb,
        np.einsum("bij,bjk->bik", np.asarray(xb), np.asarray(wb)),
        jnp.float32,
    )

    a, b, c = rnd(32, 48), rnd(48, 24, seed=1), rnd(24, 16, seed=2)
    outc = ops.chain_dense(a, b, c, interpret=True)
    assert_close(
        outc,
        np.asarray(a) @ np.asarray(b) @ np.asarray(c),
        jnp.float32,
    )

    at, bt = rnd(48, 32), rnd(48, 16, seed=1)
    outt = ops.dense_transposed(at, bt, interpret=True)
    assert_close(outt, np.asarray(at).T @ np.asarray(bt), jnp.float32)


# -- plan derivation ----------------------------------------------------------


def test_plan_respects_schedule_tiers():
    spec = matmul_spec(64, 32, 48)
    sched = codegen.default_schedule(spec, {"i": 16, "k": 8, "j": 16})
    plan = build_plan(sched)
    assert plan.grid == ("i", "k")
    assert plan.seq == ("j",)
    assert plan.grid_shape == (4, 6)
    assert plan.axes["j"].seq_steps == 2 and plan.axes["j"].chunk == 16
    # operand blocks: seq axes resident at full extent
    assert plan.operand_block("A") == (16, 32)
    assert plan.operand_block("B") == (32, 8)
    assert plan.out_block() == (16, 8)


def test_plan_rejects_reduce_on_grid():
    from repro.core.schedule import Level, Schedule

    spec = matmul_spec(32, 32, 32).subdivide("j", 16)
    levels = (
        Level("jo", "grid", 2),   # reduction on the parallel grid: invalid
        Level("i", "mxu", 32),
        Level("ji", "mxu", 16),
        Level("k", "mxu", 32),
    )
    with pytest.raises(ValueError, match="reduce index"):
        build_plan(Schedule(spec, levels))


def test_mesh_partition_specs():
    spec = matmul_spec(64, 32, 64)
    sched = codegen.schedules.sharded_schedule(
        spec,
        blocks={"i": 16, "k": 16, "j": 16},
        mesh_shards={"i": ("data", 2), "k": ("model", 2)},
    )
    plan = build_plan(sched)
    assert codegen.operand_partition_spec(plan, "A") == jax.sharding.PartitionSpec("data", None)
    assert codegen.operand_partition_spec(plan, "B") == jax.sharding.PartitionSpec(None, "model")
    assert codegen.output_partition_spec(plan) == jax.sharding.PartitionSpec("data", "model")


# -- persistent autotune cache ------------------------------------------------


def test_cache_roundtrip_tune_persist_reload(tmp_path):
    spec = matmul_spec(64, 32, 64)
    path = str(tmp_path / "autotune.json")

    cache = codegen.AutotuneCache(path)
    s1 = codegen.tune_schedule(spec, dtype=np.float32, cache=cache)
    assert cache.misses == 1 and cache.hits == 0
    assert os.path.exists(path)

    # same process, same cache object
    s2 = codegen.tune_schedule(spec, dtype=np.float32, cache=cache)
    assert cache.hits == 1

    # "new process": a fresh cache object reloads from disk
    cache2 = codegen.AutotuneCache(path)
    s3 = codegen.tune_schedule(spec, dtype=np.float32, cache=cache2)
    assert cache2.hits == 1 and cache2.misses == 0

    for sa, sb in [(s1, s2), (s1, s3)]:
        assert sa.spec.split_chain() == sb.spec.split_chain()
        assert [(l.index, l.tier, l.extent) for l in sa.levels] == [
            (l.index, l.tier, l.extent) for l in sb.levels
        ]
    # and the reloaded schedule still compiles + is correct
    a, b = rnd(64, 32), rnd(32, 64, seed=1)
    out = codegen.compile(spec, s3, interpret=True)(a, b)
    assert_close(out, np.asarray(a) @ np.asarray(b), jnp.float32)


def test_cache_key_distinguishes_dtype_and_shapes():
    s1 = matmul_spec(64, 32, 64)
    s2 = matmul_spec(64, 32, 128)
    k = codegen.cache_key
    assert k(s1, dtype="float32") != k(s2, dtype="float32")
    assert k(s1, dtype="float32") != k(s1, dtype="bfloat16")
    assert k(s1, dtype="float32") == k(s1, dtype="float32")


def test_cache_survives_corrupt_file(tmp_path):
    path = tmp_path / "autotune.json"
    path.write_text("{not json!!")
    cache = codegen.AutotuneCache(str(path))
    assert cache.get("anything") is None
    cache.put("k", {"v": 1})
    assert codegen.AutotuneCache(str(path)).get("k") == {"v": 1}


def test_core_tune_cache_skips_remeasurement(tmp_path, monkeypatch):
    """Acceptance criterion: repeated tune() hits the cache, no re-measure."""
    import repro.core.autotune as at

    spec = matmul_spec(16, 16, 16)
    arrays = {
        "A": np.random.default_rng(0).standard_normal((16, 16)),
        "B": np.random.default_rng(1).standard_normal((16, 16)),
    }
    calls = {"n": 0}
    orig = at.execute_variant

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(at, "execute_variant", counting)
    cache = codegen.AutotuneCache(str(tmp_path / "t.json"))
    r1 = at.tune(spec, {"j": [8]}, measure_with=arrays, cache=cache)
    measured_once = calls["n"]
    assert measured_once > 0

    r2 = at.tune(spec, {"j": [8]}, measure_with=arrays, cache=cache)
    assert calls["n"] == measured_once, "cache hit must not re-measure"
    assert [tv.order for tv in r1] == [tv.order for tv in r2]
    assert [tv.measured_s for tv in r1] == [tv.measured_s for tv in r2]

    # fresh process simulation
    cache2 = codegen.AutotuneCache(str(tmp_path / "t.json"))
    r3 = at.tune(spec, {"j": [8]}, measure_with=arrays, cache=cache2)
    assert calls["n"] == measured_once
    assert [tv.order for tv in r3] == [tv.order for tv in r1]
