"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness; plus a decode step for decoder archs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_is_applicable, get_config
from repro.models.api import batch_spec, get_api


def make_smoke_batch(cfg, kind: str, batch=2, seq=16):
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32
    )}
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            rng.standard_normal((batch, 8, cfg.d_model)), cfg.param_dtype
        )
    if cfg.family == "vlm":
        from repro.models.vlm import VIT_DIM

        b["patches"] = jnp.asarray(
            rng.standard_normal((batch, 4, VIT_DIM)), cfg.param_dtype
        )
    if kind == "train":
        b["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, b["tokens"].shape), jnp.int32
        )
    return b


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = get_config(arch_id).smoke()
    api = get_api(cfg)
    params, axes = api.init(cfg, jax.random.key(0))
    # axes tree mirrors params tree
    p_leaves = jax.tree.leaves(params)
    a_leaves = jax.tree.leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple) or x is None
    )
    assert len(p_leaves) == len(a_leaves)

    batch = make_smoke_batch(cfg, "train")
    logits = api.forward(params, cfg, batch, q_block=8, k_block=8)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert bool(jnp.isfinite(logits).all()), f"{arch_id}: non-finite logits"

    loss, grads = jax.value_and_grad(
        lambda p: api.loss(p, cfg, batch, q_block=8, k_block=8)
    )(params)
    assert bool(jnp.isfinite(loss)), f"{arch_id}: non-finite loss"
    assert all(
        bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads)
    ), f"{arch_id}: non-finite grads"
    # one SGD step must change the params and keep them finite
    new_params = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss2 = api.loss(new_params, cfg, batch, q_block=8, k_block=8)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_decode(arch_id):
    cfg = get_config(arch_id).smoke()
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.key(0))
    batch = make_smoke_batch(cfg, "prefill")
    logits, caches = api.prefill(params, cfg, batch, max_len=24)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for _ in range(3):
        logits1, caches = api.decode_step(params, cfg, caches, tok)
        assert logits1.shape[1] == 1 and logits1.shape[-1] == cfg.vocab
        assert bool(jnp.isfinite(logits1).all()), arch_id
        tok = jnp.argmax(logits1, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_fields(arch_id):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch_id)
    expected = {
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102_400),
        "qwen3-8b": (36, 4096, 32, 8, 12_288, 151_936),
        "granite-34b": (88, 6144, 48, 1, 24_576, 49_152),
        "qwen2-72b": (80, 8192, 64, 8, 29_568, 152_064),
        "whisper-base": (6, 512, 8, 8, 2048, 51_865),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151_655),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 16_384, 202_048),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 18_432, 163_840),
        "mamba2-130m": (24, 768, 24, 0, 0, 50_280),
        "zamba2-2.7b": (54, 2560, 32, 32, 10_240, 32_000),
    }[arch_id]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected, f"{arch_id}: {got} != {expected}"


def test_moe_param_counts_match_headlines():
    """llama4 ~400B total/~17B active; kimi ~1T total/~32B active."""
    def count(cfg):
        m = cfg.moe
        d = cfg.d_model
        n_moe = (cfg.n_layers - m.first_dense) // m.moe_every
        n_dense = cfg.n_layers - n_moe
        expert = 3 * d * m.expert_ff * m.n_experts
        shared = 3 * d * m.shared_expert_ff if m.shared_expert_ff else 0
        dense_mlp = 3 * d * (m.dense_ff or cfg.d_ff)
        attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd + \
            cfg.n_heads * cfg.hd * d
        total = (n_moe * (expert + shared + attn)
                 + n_dense * (dense_mlp + attn)
                 + 2 * cfg.vocab * d)
        active_expert = 3 * d * m.expert_ff * m.top_k
        active = (n_moe * (active_expert + shared + attn)
                  + n_dense * (dense_mlp + attn) + 2 * cfg.vocab * d)
        return total, active

    t, a = count(get_config("llama4-maverick-400b-a17b"))
    assert 3.5e11 < t < 4.6e11, t
    assert 1.2e10 < a < 2.2e10, a
    t, a = count(get_config("kimi-k2-1t-a32b"))
    assert 0.9e12 < t < 1.2e12, t
    assert 2.4e10 < a < 4.0e10, a


def test_shape_grid_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md skips)."""
    runnable = {
        a for a in ARCH_IDS
        if cell_is_applicable(get_config(a), SHAPES["long_500k"])[0]
    }
    assert runnable == {"mamba2-130m", "zamba2-2.7b"}
    for a in ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            ok, _ = cell_is_applicable(get_config(a), SHAPES[s])
            assert ok


def test_batch_specs_cover_all_cells():
    from repro.models.api import batch_spec

    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, _ = cell_is_applicable(cfg, s)
            if not ok:
                continue
            spec = batch_spec(cfg, s)
            assert "tokens" in spec
            for name, (shape, dtype) in spec.items():
                assert all(d > 0 for d in shape), (a, s.name, name)
