"""Gradcheck suite: jax.grad through the custom_vjp ops, three ways.

Property-style matrix (ops x seeds x dtypes, seed-stable exactly like
``test_differential.py``) comparing ``jax.grad`` of a scalar loss built on
each ``repro.ops`` entry point against ``jax.grad`` of a pure-jnp
reference implementation.  Tolerances are f32-tight / bf16-loose.  On CPU
the small-shape cases exercise the generated-kernel backward path in
Pallas interpret mode for every op whose dispatch admits it (batched,
chain, transposed, dense_act); ``dense`` requires 128-aligned extents and
gets a dedicated kernel-path case.

Also here, per the ISSUE-3 acceptance bar:

  * VJP consistency via ``jax.test_util.check_grads`` where available;
  * backward GEMMs hitting the **plan DB** under their own derived-spec
    keys after a ``search_schedule_with_grads`` sweep;
  * backward GEMMs populating the **autotune cache** under derived-spec
    keys when no plan exists.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import codegen, ops  # noqa: E402
from repro.core.enumerate import matmul_spec  # noqa: E402
from repro.grad import derived_specs  # noqa: E402
from repro.kernels.fused_dense_act.ref import fused_dense_act_ref  # noqa: E402

F32 = jnp.float32
BF16 = jnp.bfloat16

#: name -> (rtol, atol) on grads normalized by the reference grad scale
TOL = {
    np.dtype(np.float32): (2e-4, 2e-4),
    np.dtype(BF16): (6e-2, 6e-2),
}

EXTENT_POOL = (2, 4, 6, 8)
SEEDS = (0, 1, 2)


@pytest.fixture(autouse=True)
def _isolated_caches(tmp_path, monkeypatch):
    """Every test gets private plan-DB/autotune files (no ~/.cache writes)."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    monkeypatch.setenv("REPRO_PLAN_DB", str(tmp_path / "plans.json"))


def _pick(rng, n):
    return tuple(int(rng.choice(EXTENT_POOL)) for _ in range(n))


def _norm(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


# op name -> (make_args(rng), op_fn(args, interpret), ref_fn(args))
# seed offsets keep the streams disjoint and stable, as in the
# differential suite — never derive them from hash().
def _mk_dense(rng):
    m, d, f = _pick(rng, 3)
    return (_norm(rng, m, d), _norm(rng, d, f))


def _mk_batched(rng):
    b, m, d, f = _pick(rng, 4)
    return (_norm(rng, b, m, d), _norm(rng, b, d, f))


def _mk_chain(rng):
    m, j, k, n = _pick(rng, 4)
    return (_norm(rng, m, j), _norm(rng, j, k), _norm(rng, k, n))


def _mk_transposed(rng):
    m, d, f = _pick(rng, 3)
    return (_norm(rng, d, m), _norm(rng, d, f))


def _mk_dense_act(rng):
    m, d, f = _pick(rng, 3)
    return (
        _norm(rng, m, d),
        _norm(rng, d, f),
        _norm(rng, f),                                     # beta
        _norm(rng, f) * 0.1,                               # mean
        jnp.asarray(np.abs(rng.standard_normal(f)) + 0.5,  # var > 0
                    np.float32),
    )


OPS = {
    "dense": (
        _mk_dense, 100,
        lambda a, interp: ops.dense(*a, interpret=interp),
        lambda a: jnp.dot(
            a[0], a[1], preferred_element_type=F32
        ).astype(a[0].dtype),
    ),
    "batched_dense": (
        _mk_batched, 200,
        lambda a, interp: ops.batched_dense(*a, interpret=interp),
        lambda a: jnp.einsum(
            "bmd,bdf->bmf", a[0], a[1], preferred_element_type=F32
        ).astype(a[0].dtype),
    ),
    "chain_dense": (
        _mk_chain, 300,
        lambda a, interp: ops.chain_dense(*a, interpret=interp),
        lambda a: jnp.einsum(
            "ij,jk,kl->il", a[0], a[1], a[2], preferred_element_type=F32
        ).astype(a[0].dtype),
    ),
    "dense_transposed": (
        _mk_transposed, 400,
        lambda a, interp: ops.dense_transposed(*a, interpret=interp),
        lambda a: jnp.einsum(
            "dm,df->mf", a[0], a[1], preferred_element_type=F32
        ).astype(a[0].dtype),
    ),
    "dense_act": (
        _mk_dense_act, 500,
        lambda a, interp: ops.dense_act(*a, interpret=interp),
        lambda a: fused_dense_act_ref(*a),
    ),
}

CASES = [(name, seed) for name in sorted(OPS) for seed in SEEDS]


def _grads(fn, args):
    loss = lambda *a: jnp.sum(fn(a).astype(F32))  # noqa: E731
    return jax.grad(loss, argnums=tuple(range(len(args))))(*args)


def _assert_grads_close(got, want, dtype, ctx):
    rtol, atol = TOL[np.dtype(dtype)]
    for i, (g, r) in enumerate(zip(got, want)):
        g = np.asarray(g, np.float64)
        r = np.asarray(r, np.float64)
        scale = max(np.abs(r).max(), 1.0)
        np.testing.assert_allclose(
            g / scale, r / scale, rtol=rtol, atol=atol,
            err_msg=f"grad wrt arg {i} mismatch for {ctx}",
        )


@pytest.mark.parametrize("name,seed", CASES)
def test_custom_vjp_matches_reference_f32(name, seed):
    make, offset, op, ref = OPS[name]
    args = make(np.random.default_rng(offset + seed))
    got = _grads(lambda a: op(a, True), args)
    want = _grads(lambda a: ref(a), args)
    _assert_grads_close(got, want, np.float32, f"{name} seed={seed}")


@pytest.mark.parametrize("name", sorted(OPS))
def test_custom_vjp_matches_reference_bf16(name):
    """Low-precision path: bf16 operands, f32-accumulated backward GEMMs."""
    make, offset, op, ref = OPS[name]
    args = make(np.random.default_rng(offset + 7))
    if name == "dense_act":
        # stats vectors stay f32 (the kernel casts them itself)
        args = tuple(
            a.astype(BF16) if i < 2 else a for i, a in enumerate(args)
        )
    else:
        args = tuple(a.astype(BF16) for a in args)
    got = _grads(lambda a: op(a, True), args)
    want = _grads(lambda a: ref(a), args)
    _assert_grads_close(got, want, BF16, f"{name} bf16")


def test_dense_kernel_path_grad_128_aligned():
    """dense's generated-kernel dispatch (128-aligned) on both tape sides."""
    rng = np.random.default_rng(42)
    x = _norm(rng, 128, 128)
    w = _norm(rng, 128, 128)
    gx, gw = _grads(lambda a: ops.dense(*a, interpret=True), (x, w))
    # closed form for a sum loss: dx = 1·wᵀ, dw = xᵀ·1
    ones = jnp.ones((128, 128), F32)
    _assert_grads_close(
        (gx, gw), (ones @ w.T, x.T @ ones), np.float32, "dense kernel path"
    )


def test_check_grads_vjp_consistency():
    """Numerical VJP consistency via jax.test_util, where available."""
    try:
        from jax.test_util import check_grads
    except ImportError:
        pytest.skip("jax.test_util.check_grads unavailable")
    rng = np.random.default_rng(3)
    a, b, c = _mk_chain(rng)
    check_grads(
        lambda a_, b_, c_: ops.chain_dense(a_, b_, c_, interpret=True),
        (a, b, c), order=1, modes=["rev"], atol=1e-2, rtol=1e-2,
    )
    x, w, beta, mean, var = _mk_dense_act(rng)
    check_grads(
        lambda x_, w_: ops.dense_act(x_, w_, beta, mean, var,
                                     interpret=True),
        (x, w), order=1, modes=["rev"], atol=1e-2, rtol=1e-2,
    )


# ---------------------------------------------------------------------------
# the acceptance bar: backward GEMMs hit plan DB / autotune cache under
# their own derived-spec keys
# ---------------------------------------------------------------------------


def test_backward_gemms_hit_plan_db():
    from repro.search import (
        default_plan_db,
        grad_plan_keys,
        search_schedule_with_grads,
    )

    spec = matmul_spec(128, 128, 128)
    db = default_plan_db()
    results = search_schedule_with_grads(
        spec, dtype=np.float32, beam_width=4, topk=2,
        interpret=True, repeats=1, plan_db=db,
    )
    assert set(results) == {"fwd", "dA", "dB"}

    # each derived spec owns a persisted plan under its own key
    keys = grad_plan_keys(spec, np.float32)
    with open(db.path) as f:
        raw = json.load(f)
    assert set(keys.values()) <= set(raw), "derived-spec plan keys missing"
    for dspec in derived_specs(spec).values():
        assert db.best_schedule(dspec, np.float32) is not None

    # jax.grad through ops.dense consults the DB for fwd + dA + dB
    hits0 = db.lookup_hits
    rng = np.random.default_rng(0)
    x = _norm(rng, 128, 128)
    w = _norm(rng, 128, 128)
    gx, gw = _grads(lambda a: ops.dense(*a, interpret=True), (x, w))
    assert db.lookup_hits >= hits0 + 3, (
        "backward GEMMs did not consult the plan DB"
    )
    ones = jnp.ones((128, 128), F32)
    _assert_grads_close(
        (gx, gw), (ones @ w.T, x.T @ ones), np.float32,
        "dense grad via searched plans",
    )


def test_backward_gemms_populate_autotune_cache():
    """No plan on record: grads fall back to tune_schedule and persist
    winners under the derived specs' own cache keys."""
    rng = np.random.default_rng(1)
    x = _norm(rng, 128, 128)
    w = _norm(rng, 128, 128)
    _grads(lambda a: ops.dense(*a, interpret=True), (x, w))

    cache = codegen.default_cache()
    spec = matmul_spec(128, 128, 128)
    for wrt, dspec in derived_specs(spec).items():
        hits0 = cache.hits
        codegen.tune_schedule(dspec, dtype=np.float32)
        assert cache.hits == hits0 + 1, (
            f"derived spec {dspec.name} missing from the autotune cache"
        )


def test_forward_mode_preserved_on_fallback_paths():
    """custom_vjp wrapping is gated on the kernel dispatch: paths that
    lower to plain einsum/dot keep native autodiff, forward mode included."""
    rng = np.random.default_rng(5)
    x, w = _mk_dense(rng)  # small, unaligned: the jnp.dot fallback
    primal, tangent = jax.jvp(
        lambda x_: ops.dense(x_, w), (x,), (x,)
    )
    ref_p, ref_t = jax.jvp(
        lambda x_: jnp.dot(x_, w, preferred_element_type=F32).astype(
            x.dtype
        ),
        (x,), (x,),
    )
    np.testing.assert_allclose(np.asarray(primal), np.asarray(ref_p),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(tangent), np.asarray(ref_t),
                               rtol=1e-5, atol=1e-5)


def test_differentiable_false_has_no_vjp():
    """The escape hatch: differentiable=False is the bare primal, so the
    generated-kernel path (128-aligned dispatch) has no VJP to offer."""
    rng = np.random.default_rng(2)
    x = _norm(rng, 128, 128)
    w = _norm(rng, 128, 128)
    with pytest.raises(Exception):
        jax.grad(
            lambda x_: jnp.sum(
                ops.dense(x_, w, interpret=True, differentiable=False)
            )
        )(x)
