"""JAX lowering agrees with the reference interpreter / numpy."""

import numpy as np
import pytest

from repro.core import expr as E
from repro.core.autotune import choose_matmul_blocks, tune
from repro.core.enumerate import (
    matmul_spec, matvec_spec, variant_orders, weighted_matmul_spec,
)
from repro.core.execute import execute_variant
from repro.core.expr import App, Flip, Lam, Prim, RNZ, dot, lam, map1, v, zip2
from repro.core.interp import run
from repro.core.lower import contraction_to_jax, jax_run
from repro.core.rewrite import fuse
from repro.core.schedule import matmul_schedule


def rnd(*shape, seed=0):
    return np.random.default_rng(seed + sum(shape)).standard_normal(shape)


def test_jax_run_matvec():
    A, u = rnd(4, 6), rnd(6)
    e = map1(lam("r", dot(v("r"), v("u"))), v("A"))
    np.testing.assert_allclose(
        np.asarray(jax_run(e, A=A, u=u)), A @ u, rtol=1e-4, atol=1e-5
    )


def test_jax_run_matches_interp_on_fused_pipeline():
    A, B, vv, u = rnd(3, 4), rnd(3, 4, seed=1), rnd(4), rnd(4, seed=2)
    row_sum = zip2(Prim("+"), v("rA"), v("rB"))
    vec_sum = zip2(Prim("+"), v("vv"), v("u"))
    e = E.MapN(
        lam(
            ("rA", "rB"),
            RNZ(Prim("+"), Prim("id"), (zip2(Prim("*"), row_sum, vec_sum),)),
        ),
        (v("A"), v("B")),
    )
    fused = fuse(e)
    ref = run(fused, A=A, B=B, vv=vv, u=u)
    got = np.asarray(jax_run(fused, A=A, B=B, vv=vv, u=u))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)


def test_jax_run_flipped_matvec_eq40():
    A, u = rnd(5, 7), rnd(7)
    e = RNZ(
        E.lift(Prim("+")),
        lam(
            ("c", "q"),
            map1(lam("e", App(Prim("*"), (v("e"), v("q")))), v("c")),
        ),
        (Flip(0, 1, v("A")), v("u")),
    )
    np.testing.assert_allclose(np.asarray(jax_run(e, A=A, u=u)), A @ u, rtol=1e-4, atol=1e-5)


def test_contraction_to_jax_all_table1_orders():
    spec = matmul_spec(8, 6, 10)
    A, B = rnd(8, 6), rnd(6, 10, seed=3)
    for order in variant_orders(spec, dedup_rnz=False):
        fn = contraction_to_jax(spec, order)
        np.testing.assert_allclose(
            np.asarray(fn(A, B)), A @ B, rtol=1e-5, err_msg=str(order)
        )


def test_contraction_to_jax_subdivided():
    spec = matmul_spec(8, 12, 10).subdivide("j", 4)
    A, B = rnd(8, 12), rnd(12, 10, seed=4)
    for order in variant_orders(spec)[:6]:
        fn = contraction_to_jax(spec, order)
        np.testing.assert_allclose(
            np.asarray(fn(A, B)), A @ B, rtol=1e-5, err_msg=str(order)
        )


def test_execute_variant_matches():
    spec = matmul_spec(16, 12, 8).subdivide("j", 4)
    A, B = rnd(16, 12), rnd(12, 8, seed=5)
    for order in variant_orders(spec)[:6]:
        got = execute_variant(spec, order, {"A": A, "B": B})
        np.testing.assert_allclose(got, A @ B, rtol=1e-10, err_msg=str(order))


def test_execute_variant_weighted():
    spec = weighted_matmul_spec(6, 8, 10)
    A, B, g = rnd(6, 8), rnd(8, 10, seed=6), rnd(8, seed=7)
    ref = np.einsum("ij,jk,j->ik", A, B, g)
    for order in variant_orders(spec)[:4]:
        got = execute_variant(spec, order, {"A": A, "B": B, "g": g})
        np.testing.assert_allclose(got, ref, rtol=1e-10, err_msg=str(order))


def test_tune_pipeline_end_to_end():
    spec = matmul_spec(64, 64, 64)
    arrays = {"A": rnd(64, 64), "B": rnd(64, 64, seed=8)}
    tuned = tune(
        spec,
        subdiv_candidates={"j": [16]},
        keep=3,
        measure_with=arrays,
        repeats=1,
    )
    assert len(tuned) == 3
    assert tuned[0].measured_s is not None
    # the winner must still be correct
    got = execute_variant(tuned[0].spec, tuned[0].order, arrays)
    np.testing.assert_allclose(got, arrays["A"] @ arrays["B"], rtol=1e-10)


def test_choose_matmul_blocks_alignment_and_vmem():
    bm, bn, bk = choose_matmul_blocks(4096, 4096, 4096, elem_bytes=2)
    assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0
    assert (bm * bk + bk * bn + bm * bn) * 2 * 2 <= 64 * 1024 * 1024
    # tiny problems degrade gracefully
    assert choose_matmul_blocks(16, 16, 16) == (16, 16, 16)


def test_matmul_schedule_hierarchy():
    sch = matmul_schedule(
        4096, 4096, 4096,
        block_m=128, block_n=128, block_k=512,
        data_shard=16, model_shard=16, pod_shard=2,
    )
    tiers = [l.tier for l in sch.levels]
    assert tiers[0] == "mesh:pod" and "mesh:data" in tiers and "mesh:model" in tiers
    assert tiers[-1] == "mxu"
    # every subdivision is recorded in the spec chain
    assert len(sch.spec.split_chain()) >= 5
