"""core.autotune block selection: alignment + tie-break regressions."""

import math

import pytest

from repro.core.autotune import choose_matmul_blocks
from repro.core.cost import TPU


def traffic(m, n, k, bm, bn, bk):
    return m * k * (n / bn) + k * n * (m / bm) + m * n


def test_blocks_divide_and_fit_vmem():
    for m, n, k in [(4096, 4096, 4096), (512, 2048, 1024), (256, 256, 8192)]:
        bm, bn, bk = choose_matmul_blocks(m, n, k, elem_bytes=2)
        assert m % bm == 0 and n % bn == 0 and k % bk == 0
        budget = TPU["vmem_bytes"] // 2 // 2
        assert bm * bk + bk * bn + bm * bn <= budget


def test_aligned_candidates_honor_alignment():
    """The aligned() helper must produce multiples of its alignment arg:
    bm candidates are sublane (8) multiples even when m < 128."""
    bm, bn, bk = choose_matmul_blocks(32, 4096, 4096, elem_bytes=4)
    assert bm % 8 == 0 and bm <= 32
    assert bn % 128 == 0 and bk % 128 == 0


def test_small_m_gets_sublane_aligned_blocks():
    # before the fix, aligned(8, m) for m=64 fell back to [64] only;
    # now 8/16/32/64 are all candidates and the optimizer can trade bm
    # against bn under the VMEM budget
    bm, _, _ = choose_matmul_blocks(64, 8192, 8192, elem_bytes=4)
    assert bm % 8 == 0


def test_tie_break_prefers_deeper_k_blocks():
    """Equal-traffic candidates must pick the larger block_k (fewer grid
    steps) — the tie-break the seed left as dead code."""
    m = n = 256
    k = 1024
    bm, bn, bk = choose_matmul_blocks(m, n, k, elem_bytes=2)
    # whole-m/whole-n blocks make traffic independent of bk: every bk
    # candidate ties, so the deepest one must win
    assert (bm, bn) == (256, 256)
    best_traffic = traffic(m, n, k, bm, bn, bk)
    budget = TPU["vmem_bytes"] // 2 // 2
    deeper = [
        c for c in (128, 256, 512, 1024)
        if k % c == 0 and c > bk
        and bm * c + c * bn + bm * bn <= budget
        and traffic(m, n, k, bm, bn, c) <= best_traffic
    ]
    assert not deeper, f"deeper tied block_k {deeper} should have won over {bk}"


def test_tiny_problem_single_block():
    assert choose_matmul_blocks(4, 4, 4) == (4, 4, 4)
