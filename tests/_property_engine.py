"""Seeded fallback property-test engine, API-compatible with the slice of
``hypothesis`` this suite uses.

Installed into ``sys.modules`` as ``hypothesis`` by ``conftest.py`` when
the real library is absent (the container bakes in jax/Pallas but not
hypothesis, and tier-1 must not ``pip install``).  Unlike the old stub,
which *skipped* every ``@given`` test, this engine actually **runs** them:
each test executes ``max_examples`` times with values drawn from a PRNG
seeded deterministically from the test's qualified name, so failures
reproduce run-to-run and machine-to-machine.  CI installs real hypothesis
and never touches this module (shrinking, the example database and
adaptive generation are real-hypothesis-only features; this engine trades
them for zero dependencies).

Supported surface: ``given`` (positional + keyword strategies),
``settings`` (``max_examples`` honored, rest accepted), ``assume``,
``note``, ``example`` (no-op), ``HealthCheck``, and
``strategies.integers / booleans / sampled_from / just / tuples /
composite`` with ``.map`` / ``.filter``.

``REPRO_PROPERTY_EXAMPLES`` caps per-test example counts (CI knob).
"""

from __future__ import annotations

import functools
import inspect
import os
import random
import types
import zlib

__is_repro_fallback__ = True


class Unsatisfied(Exception):
    """Raised by ``assume(False)`` / exhausted ``.filter`` — skips the example."""


def assume(condition) -> bool:
    if not condition:
        raise Unsatisfied
    return True


def note(*_a, **_k):
    return None


def example(*_a, **_k):
    def deco(fn):
        return fn

    return deco


class _HealthCheck:
    def __getattr__(self, name):
        return name


HealthCheck = _HealthCheck()


def settings(*_a, **kwargs):
    """Decorator recording kwargs for ``given`` to read (max_examples)."""

    def deco(fn):
        fn._fallback_settings = dict(kwargs)
        return fn

    return deco


settings.register_profile = lambda *a, **k: None
settings.load_profile = lambda *a, **k: None


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------


class SearchStrategy:
    def __init__(self, draw_fn, label="strategy"):
        self._draw = draw_fn
        self._label = label

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def map(self, f):
        return SearchStrategy(
            lambda rng: f(self.draw(rng)), f"{self._label}.map"
        )

    def filter(self, pred):
        def draw(rng):
            for _ in range(200):
                x = self.draw(rng)
                if pred(x):
                    return x
            raise Unsatisfied

        return SearchStrategy(draw, f"{self._label}.filter")

    def __repr__(self):
        return self._label


def integers(min_value, max_value) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: rng.randint(min_value, max_value),
        f"integers({min_value},{max_value})",
    )


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")


def sampled_from(seq) -> SearchStrategy:
    items = list(seq)
    if not items:
        raise ValueError("sampled_from: empty sequence")
    return SearchStrategy(lambda rng: rng.choice(items), "sampled_from(...)")


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, f"just({value!r})")


def tuples(*ss) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.draw(rng) for s in ss), "tuples(...)"
    )


def composite(fn):
    """``@st.composite`` — ``fn(draw, *args)`` becomes a strategy factory."""

    @functools.wraps(fn)
    def factory(*args, **kwargs):
        def draw_fn(rng):
            def draw(strategy):
                return strategy.draw(rng)

            return fn(draw, *args, **kwargs)

        return SearchStrategy(draw_fn, f"composite:{fn.__name__}")

    return factory


strategies = types.ModuleType("hypothesis.strategies")
strategies.SearchStrategy = SearchStrategy
strategies.integers = integers
strategies.booleans = booleans
strategies.sampled_from = sampled_from
strategies.just = just
strategies.tuples = tuples
strategies.composite = composite


# ---------------------------------------------------------------------------
# given
# ---------------------------------------------------------------------------

_DEFAULT_MAX_EXAMPLES = 50


def given(*st_args, **st_kwargs):
    """Run the test once per drawn example, deterministically seeded.

    Positional strategies bind to the test's *last* positional parameters
    (matching hypothesis), keyword strategies by name; remaining leading
    parameters stay visible to pytest as fixtures.
    """

    def deco(fn):
        cfg = getattr(fn, "_fallback_settings", {})
        max_examples = int(cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES))
        cap = int(os.environ.get("REPRO_PROPERTY_EXAMPLES", "0") or 0)
        if cap > 0:
            max_examples = min(max_examples, cap)

        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        bound = set(st_kwargs)
        n_pos = len(st_args)
        pos_names = [
            p.name for p in params if p.name not in bound
        ][-n_pos:] if n_pos else []
        fixture_params = [
            p for p in params
            if p.name not in bound and p.name not in pos_names
        ]
        seed0 = zlib.adler32(
            f"{fn.__module__}.{fn.__qualname__}".encode()
        )

        @functools.wraps(fn)
        def runner(*args, **kwargs):
            ran = 0
            for i in range(max_examples):
                rng = random.Random((seed0 * 100003 + i) & 0x7FFFFFFF)
                try:
                    drawn = {
                        name: s.draw(rng)
                        for name, s in zip(pos_names, st_args)
                    }
                    drawn.update(
                        (name, s.draw(rng))
                        for name, s in st_kwargs.items()
                    )
                except Unsatisfied:
                    continue
                try:
                    fn(*args, **{**kwargs, **drawn})
                    ran += 1
                except Unsatisfied:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"[fallback property engine] falsifying example "
                        f"#{i} of {fn.__qualname__}: "
                        f"{ {k: _short(v) for k, v in drawn.items()} } "
                        f"-> {type(e).__name__}: {e}"
                    ) from e
            if ran == 0:
                raise Unsatisfied(
                    f"{fn.__qualname__}: no example satisfied assume()"
                )

        runner.__signature__ = sig.replace(parameters=fixture_params)
        runner.hypothesis = types.SimpleNamespace(inner_test=fn)
        return runner

    return deco


def _short(v, limit=80):
    s = repr(v)
    return s if len(s) <= limit else s[: limit - 3] + "..."
