"""Variant enumeration: every SJT ordering computes the same contraction."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost import cpu_cost, early_cut, rank_variants
from repro.core.enumerate import (
    ContractionSpec, evaluate_variant, matmul_spec, matvec_spec,
    nest_to_expr, paper_fig3_variants, sjt, variant_orders,
    weighted_matmul_spec, tensor_contraction_spec,
)


def test_sjt_is_all_permutations_by_adjacent_swaps():
    perms = list(sjt(4))
    assert len(perms) == 24
    assert len(set(perms)) == 24
    for a, b in zip(perms, perms[1:]):
        diff = [i for i in range(4) if a[i] != b[i]]
        assert len(diff) == 2 and abs(diff[0] - diff[1]) == 1


def test_matmul_six_permutations_table1():
    """Paper Table 1: the 3 HoFs of naive matmul give 6 orderings, all equal."""
    spec = matmul_spec(4, 5, 3)
    rng = np.random.default_rng(0)
    arrays = {
        "A": rng.standard_normal((4, 5)),
        "B": rng.standard_normal((5, 3)),
    }
    expected = arrays["A"] @ arrays["B"]
    orders = variant_orders(spec, dedup_rnz=False)
    assert len(orders) == 6
    for order in orders:
        got = evaluate_variant(spec, order, arrays)
        np.testing.assert_allclose(got, expected, rtol=1e-10, err_msg=str(order))


def test_matmul_subdivided_rnz_twelve_variants_table2():
    """Paper Table 2: subdividing the rnz gives 12 distinguishable orderings."""
    spec = matmul_spec(4, 6, 3).subdivide("j", 2)
    rng = np.random.default_rng(1)
    arrays = {
        "A": rng.standard_normal((4, 6)),
        "B": rng.standard_normal((6, 3)),
    }
    expected = arrays["A"] @ arrays["B"]
    orders = variant_orders(spec)
    # 4 loops, jo must stay outside ji, two rnz indistinguishable -> 12
    assert len(orders) == 12
    for order in orders:
        got = evaluate_variant(spec, order, arrays)
        np.testing.assert_allclose(got, expected, rtol=1e-10, err_msg=str(order))


def test_fig3_matvec_variants():
    """Paper Fig 3: all six subdivided matvec rearrangements agree."""
    rng = np.random.default_rng(2)
    n, m, b = 6, 8, 2
    A, u = rng.standard_normal((n, m)), rng.standard_normal(m)
    for label, order, spec in paper_fig3_variants(n, m, b):
        got = evaluate_variant(spec, order, {"A": A, "u": u})
        np.testing.assert_allclose(got, A @ u, rtol=1e-10, err_msg=label)


def test_weighted_matmul_eq2_variants():
    spec = weighted_matmul_spec(3, 4, 5)
    rng = np.random.default_rng(3)
    arrays = {
        "A": rng.standard_normal((3, 4)),
        "B": rng.standard_normal((4, 5)),
        "g": rng.standard_normal(4),
    }
    expected = np.einsum("ij,jk,j->ik", arrays["A"], arrays["B"], arrays["g"])
    for order in variant_orders(spec, dedup_rnz=False):
        got = evaluate_variant(spec, order, arrays)
        np.testing.assert_allclose(got, expected, rtol=1e-10, err_msg=str(order))


def test_pde_tensor_contraction_eq7():
    """Paper eq 7: C_ipq = sum_jk A_ijk B_jp C_kq g_j f_k."""
    spec = tensor_contraction_spec(2, 3, 4, 2, 3)
    rng = np.random.default_rng(4)
    arrays = {
        "A": rng.standard_normal((2, 3, 4)),
        "B": rng.standard_normal((3, 2)),
        "C": rng.standard_normal((4, 3)),
        "g": rng.standard_normal(3),
        "f": rng.standard_normal(4),
    }
    expected = np.einsum(
        "ijk,jp,kq,j,k->ipq",
        arrays["A"], arrays["B"], arrays["C"], arrays["g"], arrays["f"],
    )
    # spot-check a handful of orderings (120 perms is slow in the interpreter)
    orders = variant_orders(spec)[:8]
    for order in orders:
        got = evaluate_variant(spec, order, arrays)
        np.testing.assert_allclose(got, expected, rtol=1e-9, err_msg=str(order))


def test_double_subdivision_of_rnz():
    """Paper Fig 5: rnz subdivided twice still agrees everywhere."""
    spec = matmul_spec(4, 8, 3).subdivide("j", 4).subdivide("ji", 2)
    rng = np.random.default_rng(5)
    arrays = {
        "A": rng.standard_normal((4, 8)),
        "B": rng.standard_normal((8, 3)),
    }
    expected = arrays["A"] @ arrays["B"]
    orders = variant_orders(spec)[:10]
    assert orders
    for order in orders:
        got = evaluate_variant(spec, order, arrays)
        np.testing.assert_allclose(got, expected, rtol=1e-10, err_msg=str(order))


# -- cost model ---------------------------------------------------------------


def test_cost_model_prefers_paper_table1_winner():
    """Paper Table 1: best = (mapA, rnz, mapB), worst = (mapB, rnz, mapA).

    mapA = i, mapB = k, rnz = j.  The model must reproduce the ends of the
    measured ordering (B row-wise inner = good; A and B column-wise = bad).
    """
    spec = matmul_spec(1024, 1024, 1024)
    ranked = rank_variants(spec, variant_orders(spec, dedup_rnz=False))
    orders_sorted = [o for _, o in ranked]
    best, worst = ("i", "j", "k"), ("k", "j", "i")
    assert orders_sorted.index(best) <= 1, orders_sorted
    assert orders_sorted.index(worst) >= len(orders_sorted) - 2, orders_sorted


def test_cost_model_blocked_beats_naive():
    spec = matmul_spec(1024, 1024, 1024)
    naive = cpu_cost(spec, ("i", "j", "k"))
    blocked_spec = spec.subdivide("j", 16)
    blocked = cpu_cost(blocked_spec, ("i", "jo", "ji", "k"))
    # paper Table 2: subdividing the reduction improves locality
    assert blocked < naive


def test_early_cut_keeps_cheap_variants():
    spec = matmul_spec(512, 512, 512)
    orders = variant_orders(spec, dedup_rnz=False)
    kept = early_cut(spec, orders, keep=2)
    assert len(kept) == 2
    ranked = rank_variants(spec, orders)
    assert kept[0] == ranked[0][1]
