"""repro.obs — spans, metrics exactness, the no-op contract, plan-explain.

What this suite pins:

* **Spans** nest through the thread-local stack and export valid
  Chrome-trace events (name/cat/ph/ts/dur/pid/tid + depth/parent args);
  ``scripts/obs_report.py --trace`` accepts a ``trace_dump``.
* **Metrics exactness** — counters record exactly what a scripted
  search/plan-DB sweep did: one plandb.miss on a cold DB, one plandb.hit
  on the re-search, a version_miss when the DB holds only a stale-format
  key, and beam counters equal to the search's own reported stats.
* **Histograms** match ``numpy.percentile``'s default linear
  interpolation bit-for-bit.
* **REPRO_OBS=0 is a strict no-op** — handles are the shared do-nothing
  singleton, the registry and the trace buffer stay empty.
* **Explain round-trip** — the roofline terms ``search_schedule``
  persists come back out of ``obs.explain`` as a ranked table for a
  human selector, through the real plan-DB file.
"""

from __future__ import annotations

import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import explain as explain_mod
from repro.obs import log as log_mod
from repro.obs.metrics import Histogram, _NOOP, registry


@pytest.fixture(autouse=True)
def _clean_obs(monkeypatch):
    """Each test starts with an empty registry/trace and obs enabled."""
    monkeypatch.delenv("REPRO_OBS", raising=False)
    obs.metrics_reset()
    obs.trace_reset()
    yield
    obs.metrics_reset()
    obs.trace_reset()


# ---------------------------------------------------------------- spans


def test_span_nesting_records_depth_and_parent():
    with obs.span("outer", spec="matmul"):
        with obs.span("inner"):
            pass
    evs = obs.trace_events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # exit order
    inner, outer = evs
    assert outer["args"]["depth"] == 0 and "parent" not in outer["args"]
    assert inner["args"]["depth"] == 1
    assert inner["args"]["parent"] == "outer"
    assert outer["args"]["spec"] == "matmul"
    # the inner span lies inside the outer one on the timeline
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3


def test_span_chrome_trace_schema(tmp_path):
    with obs.span("a"):
        pass
    doc = obs.trace_json()
    assert isinstance(doc["traceEvents"], list)
    ev = doc["traceEvents"][0]
    for k in ("name", "cat", "ph", "ts", "dur", "pid", "tid", "args"):
        assert k in ev
    assert ev["ph"] == "X"
    # the dump must be loadable and pass the report script's validator
    path = obs.trace_dump(str(tmp_path / "t.json"))
    with open(path) as f:
        assert json.load(f)["traceEvents"]
    import importlib.util
    import os

    spec = importlib.util.spec_from_file_location(
        "obs_report",
        os.path.join(os.path.dirname(__file__), "..", "scripts",
                     "obs_report.py"),
    )
    rep = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(rep)
    rep.run_trace(path)  # SystemExit(1) on schema drift


def test_span_threads_have_independent_stacks():
    def worker():
        with obs.span("thread-span"):
            pass

    with obs.span("main-span"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    evs = {e["name"]: e for e in obs.trace_events()}
    # the thread's span must NOT see main's stack as its parent
    assert evs["thread-span"]["args"]["depth"] == 0
    assert "parent" not in evs["thread-span"]["args"]
    assert evs["thread-span"]["tid"] != evs["main-span"]["tid"]


def test_span_survives_exception():
    with pytest.raises(RuntimeError):
        with obs.span("boom"):
            raise RuntimeError("x")
    assert [e["name"] for e in obs.trace_events()] == ["boom"]
    # and the stack unwound — a following span is top-level again
    with obs.span("after"):
        pass
    assert obs.trace_events()[-1]["args"]["depth"] == 0


# -------------------------------------------------------------- metrics


def test_counter_gauge_exact():
    obs.counter("c").inc()
    obs.counter("c").inc(3)
    obs.gauge("g").set(2.5)
    j = obs.metrics_json()
    assert j["counters"] == {"c": 4}
    assert j["gauges"] == {"g": 2.5}


def test_metric_kind_mismatch_raises():
    obs.counter("x")
    with pytest.raises(TypeError):
        obs.gauge("x")


def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(0)
    for n in (1, 2, 5, 101):
        h = Histogram("h")
        vals = rng.uniform(0, 10, size=n)
        for v in vals:
            h.observe(float(v))
        for p in (0, 25, 50, 90, 99, 100):
            assert h.percentile(p) == pytest.approx(
                float(np.percentile(vals, p)), rel=1e-12, abs=1e-12
            )
        s = h.summary()
        assert s["count"] == n
        assert s["p50"] == h.percentile(50)
        assert s["p99"] == h.percentile(99)


def test_metrics_dump_passes_report_validation(tmp_path):
    obs.counter("plandb.hit").inc(2)
    obs.histogram("lat").observe(0.1)
    obs.histogram("empty")  # zero-observation histogram stays valid
    path = obs.metrics_dump(str(tmp_path / "m.json"))
    with open(path) as f:
        doc = json.load(f)
    assert doc["counters"]["plandb.hit"] == 2
    assert doc["histograms"]["lat"]["count"] == 1
    assert doc["histograms"]["empty"] == {"count": 0, "sum": 0.0}
    import importlib.util
    import os

    spec = importlib.util.spec_from_file_location(
        "obs_report",
        os.path.join(os.path.dirname(__file__), "..", "scripts",
                     "obs_report.py"),
    )
    rep = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(rep)
    rep.run_metrics(path)  # SystemExit(1) on schema drift


# -------------------------------------------------- the no-op contract


def test_disabled_is_strict_noop(monkeypatch):
    monkeypatch.setenv("REPRO_OBS", "0")
    assert not obs.enabled()
    c = obs.counter("never")
    c.inc(10)
    obs.gauge("never.g").set(1.0)
    obs.histogram("never.h").observe(1.0)
    assert c is _NOOP
    assert registry().names() == []
    assert obs.metrics_json() == {
        "counters": {}, "gauges": {}, "histograms": {},
    }
    with obs.span("never.span"):
        pass
    assert obs.trace_events() == []
    # flipping the env back re-enables without any reload
    monkeypatch.delenv("REPRO_OBS")
    obs.counter("now").inc()
    assert obs.metrics_json()["counters"] == {"now": 1}


# ------------------------------------- scripted sweep: counters exact


def _tiny_search(db, **kw):
    from repro.core.enumerate import matmul_spec
    from repro.search import search_schedule

    return search_schedule(
        matmul_spec(128, 128, 128), beam_width=4, topk=2,
        measure=False, plan_db=db, **kw
    )


def test_plandb_hit_miss_counters_match_scripted_sweep(tmp_path):
    from repro.search import PlanDB

    db = PlanDB(str(tmp_path / "plans.json"))
    result = _tiny_search(db)  # cold DB: one lookup, one miss
    j = obs.metrics_json()["counters"]
    assert j["plandb.miss"] == 1
    assert "plandb.hit" not in j
    # beam counters mirror the search's own reported stats exactly
    assert j["search.candidates"] == result.stats.considered
    assert j["search.pruned_bound"] == result.stats.pruned_bound
    assert j["search.pruned_beam"] == result.stats.pruned_beam
    assert result.stats.considered > 0

    _tiny_search(db)  # warm DB: the cached ladder served, no re-search
    j2 = obs.metrics_json()["counters"]
    assert j2["plandb.hit"] == 1
    assert j2["plandb.miss"] == 1
    assert j2["search.candidates"] == result.stats.considered  # unchanged


def test_plandb_version_miss_counter(tmp_path):
    """A DB holding only a stale-format key counts a version_miss, so an
    operator can tell 'plans went cold on upgrade' from 'never swept'."""
    import repro.codegen.cache as cache_mod
    from repro.core.enumerate import matmul_spec
    from repro.search import PlanDB
    from repro.search.plandb import PLAN_VERSION, plan_key

    db = PlanDB(str(tmp_path / "plans.json"))
    spec = matmul_spec(128, 128, 128)
    hw = cache_mod.hardware_fingerprint()
    old_key = plan_key(spec, np.float32, hw, version=PLAN_VERSION - 1)
    db._cache.put(old_key, {"v": PLAN_VERSION - 1, "ranked": []})
    obs.metrics_reset()

    assert db.get(spec, np.float32, hw) is None
    j = obs.metrics_json()["counters"]
    assert j["plandb.version_miss"] == 1
    assert j["plandb.miss"] == 1

    # a truly-cold key is a plain miss, no version_miss
    obs.metrics_reset()
    assert db.get(matmul_spec(256, 128, 128), np.float32, hw) is None
    j = obs.metrics_json()["counters"]
    assert j["plandb.miss"] == 1
    assert "plandb.version_miss" not in j


def test_search_spans_recorded(tmp_path):
    from repro.search import PlanDB

    db = PlanDB(str(tmp_path / "plans.json"))
    _tiny_search(db)
    names = {e["name"] for e in obs.trace_events()}
    assert {"search.enumerate", "search.beam", "search.persist"} <= names
    beam = next(e for e in obs.trace_events() if e["name"] == "search.beam")
    assert beam["args"]["spec"] == "matmul"


def test_capture_dispatch_counters_match_report(tmp_path, monkeypatch):
    """The capture.harvested/dispatched/fallback counters record exactly
    what the capture report says happened — same numbers, one source of
    truth (the report), two surfaces (report JSON and the obs registry)."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    monkeypatch.setenv("REPRO_PLAN_DB", str(tmp_path / "plans.json"))
    import jax
    import jax.numpy as jnp

    from repro import capture
    from repro.models.api import get_api

    cfg = capture.demo_configs()["dense"]
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    toks = jnp.asarray(
        rng.integers(0, cfg.vocab, (capture.DEMO_BATCH, capture.DEMO_SEQ)),
        jnp.int32,
    )
    batch = {"tokens": toks, "labels": toks}
    report = capture.optimize(
        lambda p, b: api.loss(p, cfg, b), interpret=True, label="obs-dense"
    ).report_for(params, batch)

    j = obs.metrics_json()["counters"]
    assert j["capture.harvested"] == report.harvested
    assert j["capture.dispatched"] == report.dispatched
    assert j["capture.fallback"] == report.fallback
    # per-op breakdown sums back to the dispatched total
    per_op = {k: v for k, v in j.items()
              if k.startswith("capture.dispatched.")}
    assert sum(per_op.values()) == report.dispatched
    names = {e["name"] for e in obs.trace_events()}
    assert {"capture.trace", "capture.harvest"} <= names


# ------------------------------------------------- explain round-trip


def test_explain_roundtrip_through_plan_db(tmp_path):
    from repro.search import PlanDB

    db = PlanDB(str(tmp_path / "plans.json"))
    result = _tiny_search(db)
    out = explain_mod.explain(db.path, "matmul@128x128x128")
    assert out.startswith("plan matmul@128x128x128")
    # the winner's roofline terms made it to disk and back
    best = result.best
    assert best.explain, "search did not attach explain terms"
    for term in ("compute_s", "hbm_s", "comm_s", "penalty"):
        assert term in best.explain
    with open(db.path) as f:
        entry = next(
            e for e in json.load(f).values()
            if isinstance(e, dict) and e.get("ranked")
        )
    assert entry["ranked"][0]["explain"] == pytest.approx(best.explain)
    # and the rendered table shows them as columns
    assert "compute_s" in out and "hbm_s" in out


def test_explain_selector_grammar():
    p = explain_mod.parse_selector("matmul@512x512x512@mesh=2x4@dtype=bfloat16")
    assert p == {
        "name": "matmul", "shape": "512x512x512",
        "mesh": "2x4", "dtype": "bfloat16",
    }
    assert explain_mod.parse_selector("matmul.dA")["name"] == "matmul.dA"
    with pytest.raises(ValueError):
        explain_mod.parse_selector("matmul@bogus=1")
    with pytest.raises(ValueError):
        explain_mod.parse_selector("")


def test_explain_unknown_selector_lists_names(tmp_path):
    from repro.search import PlanDB

    db = PlanDB(str(tmp_path / "plans.json"))
    _tiny_search(db)
    with pytest.raises(LookupError, match="matmul"):
        explain_mod.explain(db.path, "nope@1x1x1")


# ------------------------------------------------------------ obs.log


def test_log_levels(capsys, monkeypatch):
    monkeypatch.delenv("REPRO_LOG", raising=False)
    log_mod.info("serve", "hello")
    log_mod.debug("serve", "noisy")
    out = capsys.readouterr().out
    assert out == "[serve] hello\n"  # byte-identical to the old print
    monkeypatch.setenv("REPRO_LOG", "quiet")
    log_mod.info("serve", "hidden")
    assert capsys.readouterr().out == ""
    monkeypatch.setenv("REPRO_LOG", "debug")
    log_mod.debug(None, "bare line")
    assert capsys.readouterr().out == "bare line\n"
