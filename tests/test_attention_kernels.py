"""Differential oracle matrix for the fused attention family (ISSUE 8).

Every case pins the generated flash-style Pallas kernel (interpret mode)
against two independent references:

  * a pure-softmax oracle with f64 accumulation
    (``search.einsum_reference`` branches on ``fused_kind``), and
  * the HoF reference interpreter (``core.interp`` via
    ``evaluate_variant``) composed as QK^T GEMM -> explicit softmax ->
    PV GEMM — the *unfused* three-node program the capture layer matches.

Cases are drawn from an explicit PRNG seed matrix over
head_dim x (q_seq, kv_seq) x causal/full x f32/bf16 — no hypothesis
dependency; any failure reproduces from its parametrization id alone.
The schedule for each case is randomly drawn (loop order + divisor
blocks over the non-whole indices), so the KV reduction tier is
exercised at many chunkings, not just the default.

The backward half: each derived spec (``attention.dQ/.dK/.dV``) must be
a valid codegen input matching its own einsum oracle, and the composed
custom VJP (``ops.attention``) must pass ``check_grads`` and agree with
``jax.vjp`` of the pure-jnp forward.
"""

from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import codegen, ops  # noqa: E402
from repro.core.enumerate import (  # noqa: E402
    ContractionSpec,
    attention_spec,
    evaluate_variant,
)
from repro.grad import COTANGENT, derived_specs  # noqa: E402
from repro.search import (  # noqa: E402
    candidate_schedule,
    einsum_reference,
    reference_arrays,
)


@pytest.fixture(autouse=True)
def _isolated_caches(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    monkeypatch.setenv("REPRO_PLAN_DB", str(tmp_path / "plans.json"))


HEAD_DIMS = (4, 8)
SEQS = ((8, 8), (8, 16), (16, 8))  # (q_seq, kv_seq): square + both ragged
MASKS = ("full", "causal")
TOL = {
    np.dtype(np.float32): (1e-4, 1e-4),
    np.dtype(jnp.bfloat16): (6e-2, 6e-2),
}

CASES = [
    (d, s, t, mask)
    for d in HEAD_DIMS
    for s, t in SEQS
    for mask in MASKS
]


def _divisors(n: int):
    return [d for d in range(1, n + 1) if n % d == 0]


def _draw_schedule(spec, rng):
    """Random legal schedule: shuffled order, divisor blocks, whole
    indices (d/e) kept at full extent as the search space pins them."""
    order = list(spec.indices)
    rng.shuffle(order)
    whole = set(getattr(spec.root(), "whole_indices", ()))
    blocks = {
        i: spec.extents[i]
        if i in whole
        else int(rng.choice(_divisors(spec.extents[i])))
        for i in spec.indices
    }
    return candidate_schedule(spec, tuple(order), blocks), order, blocks


def _softmax_np(s):
    m = s.max(axis=-1, keepdims=True)
    p = np.exp(s - m)
    return p / p.sum(axis=-1, keepdims=True)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("d,s,t,mask", CASES)
def test_attention_kernel_matches_oracles(d, s, t, mask, dtype):
    causal = mask == "causal"
    seed = 11000 + d * 97 + s * 13 + t * 7 + causal
    rng = np.random.default_rng(seed)
    h = int(rng.choice((1, 2, 3)))
    spec = attention_spec(h, s, t, d, causal=causal)
    schedule, order, blocks = _draw_schedule(spec, rng)
    arrays = reference_arrays(spec, dtype=np.float32, seed=seed)
    dt = jnp.dtype(dtype)

    # oracle 1: f64 softmax reference over the QUANTIZED inputs, so input
    # rounding is charged to the oracle, not the kernel
    q_arrays = {
        n: np.asarray(jnp.asarray(a, dt), np.float64)
        for n, a in arrays.items()
    }
    ref = einsum_reference(spec, q_arrays)

    kern = codegen.compile(spec, schedule, interpret=True)
    out = np.asarray(
        kern(*(jnp.asarray(arrays[n], dt) for n in spec.operands)),
        np.float64,
    )
    rtol, atol = TOL[np.dtype(dt)]
    scale = max(np.abs(ref).max(), 1.0)
    np.testing.assert_allclose(
        out / scale, ref / scale, rtol=rtol, atol=atol,
        err_msg=f"attention kernel != softmax oracle "
                f"(h={h} s={s} t={t} d={d} {mask} {dtype} "
                f"order={order} blocks={blocks})",
    )

    if dt != jnp.float32:
        return

    # oracle 2: the reference interpreter, composed as the UNFUSED
    # program — two core.interp GEMMs around an explicit softmax
    qk = ContractionSpec(
        name="qk",
        operands={"Q": ("h", "s", "d"), "K": ("h", "t", "d")},
        output=("h", "s", "t"),
        extents={"h": h, "s": s, "t": t, "d": d},
    )
    scores = np.asarray(
        evaluate_variant(qk, qk.indices, arrays), np.float64
    ) * d ** -0.5
    if causal:
        cols = np.arange(t)[None, None, :]
        rows = np.arange(s)[None, :, None]
        scores = np.where(cols <= rows, scores, -np.inf)
    probs = _softmax_np(scores)
    pv = ContractionSpec(
        name="pv",
        operands={"P": ("h", "s", "t"), "V": ("h", "t", "e")},
        output=("h", "s", "e"),
        extents={"h": h, "s": s, "t": t, "e": d},
    )
    interp = np.asarray(
        evaluate_variant(pv, pv.indices, {"P": probs, "V": arrays["V"]}),
        np.float64,
    )
    np.testing.assert_allclose(
        interp / scale, ref / scale, rtol=rtol, atol=atol,
        err_msg=f"core.interp leg != softmax oracle (h={h} s={s} t={t} "
                f"d={d} {mask})",
    )
    np.testing.assert_allclose(
        out / scale, interp / scale, rtol=rtol, atol=atol,
        err_msg="kernel != core.interp composition",
    )


# ---------------------------------------------------------------------------
# backward: derived specs as codegen inputs + the composed custom VJP
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mask", MASKS)
def test_attention_derived_specs_compile(mask):
    """attention.dQ/.dK/.dV are full citizens of the schedule space: each
    compiles under a random legal schedule and matches its einsum oracle.

    The derived dQ/dK specs consume the score cotangent dS (the chain
    through softmax is composed by ``grad.attention_vjp``, not by one
    contraction), so the oracle here is the derived contraction itself.
    """
    causal = mask == "causal"
    seed = 12000 + causal
    rng = np.random.default_rng(seed)
    h, s, t, d = 2, 8, 8, 4
    spec = attention_spec(h, s, t, d, causal=causal)
    dspecs = derived_specs(spec)
    assert set(dspecs) == {"Q", "K", "V"}
    arrays = reference_arrays(spec, dtype=np.float32, seed=seed)

    shapes = {
        "Q": (h, s, t),  # dS cotangent
        "K": (h, s, t),
        "V": (h, s, d),  # output cotangent
    }
    for wrt, dspec in dspecs.items():
        assert dspec.name == f"attention.d{wrt}"
        darrays = {
            COTANGENT: rng.standard_normal(shapes[wrt]).astype(np.float32)
        }
        if wrt == "V":
            # dV contracts the softmax probabilities against the cotangent
            sc = np.einsum(
                "hsd,htd->hst",
                arrays["Q"].astype(np.float64),
                arrays["K"].astype(np.float64),
            ) * d ** -0.5
            darrays["P"] = _softmax_np(sc).astype(np.float32)
        else:
            other = "K" if wrt == "Q" else "Q"
            darrays[other] = arrays[other]
        schedule, order, blocks = _draw_schedule(dspec, rng)
        kern = codegen.compile(dspec, schedule, interpret=True)
        out = np.asarray(
            kern(*(jnp.asarray(darrays[n]) for n in dspec.operands)),
            np.float64,
        )
        ref = einsum_reference(dspec, darrays)
        np.testing.assert_allclose(
            out, ref, rtol=1e-4, atol=1e-4,
            err_msg=f"{dspec.name} kernel != oracle "
                    f"(order={order} blocks={blocks})",
        )


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("mask", MASKS)
def test_ops_attention_forward(mask, dtype):
    """ops.attention (kernel path, interpret) vs the f64 softmax oracle."""
    causal = mask == "causal"
    dt = jnp.dtype(dtype)
    rng = np.random.default_rng(13000 + causal)
    h, s, t, d = 4, 16, 16, 8
    spec = attention_spec(h, s, t, d, causal=causal)
    arrays = reference_arrays(spec, dtype=np.float32, seed=13100 + causal)
    q, k, v = (jnp.asarray(arrays[n], dt) for n in ("Q", "K", "V"))
    ref = einsum_reference(
        spec, {n: np.asarray(a, np.float64) for n, a in
               zip(("Q", "K", "V"), (q, k, v))}
    )
    out = np.asarray(
        ops.attention(q, k, v, causal=causal, interpret=True), np.float64
    )
    rtol, atol = TOL[np.dtype(dt)]
    scale = max(np.abs(ref).max(), 1.0)
    np.testing.assert_allclose(
        out / scale, ref / scale, rtol=rtol, atol=atol,
        err_msg=f"ops.attention({mask}, {dtype}) diverged",
    )


@pytest.mark.parametrize("mask", MASKS)
def test_ops_attention_check_grads(mask):
    """The composed custom VJP is a true gradient (finite differences)
    and matches jax.vjp of the pure-jnp forward."""
    from jax.test_util import check_grads

    causal = mask == "causal"
    rng = np.random.default_rng(14000 + causal)
    h, s, d = 2, 8, 4
    q, k, v = (
        jnp.asarray(rng.standard_normal((h, s, d)), jnp.float32)
        for _ in range(3)
    )

    def f(q_, k_, v_):
        return ops.attention(q_, k_, v_, causal=causal, interpret=True)

    check_grads(f, (q, k, v), order=1, modes=("rev",), atol=2e-2, rtol=2e-2)

    def ref(q_, k_, v_):
        sc = jnp.einsum(
            "hsd,htd->hst", q_, k_, preferred_element_type=jnp.float32
        ) * d ** -0.5
        if causal:
            cols = jax.lax.broadcasted_iota(jnp.int32, (h, s, s), 2)
            rows = jax.lax.broadcasted_iota(jnp.int32, (h, s, s), 1)
            sc = jnp.where(cols <= rows, sc, -jnp.inf)
        p = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum(
            "hst,hte->hse", p, v_, preferred_element_type=jnp.float32
        )

    g = jnp.asarray(rng.standard_normal((h, s, d)), jnp.float32)
    _, vjp_k = jax.vjp(f, q, k, v)
    _, vjp_r = jax.vjp(ref, q, k, v)
    for name, a, b in zip(("dQ", "dK", "dV"), vjp_k(g), vjp_r(g)):
        np.testing.assert_allclose(
            np.asarray(a, np.float64), np.asarray(b, np.float64),
            rtol=1e-3, atol=1e-3,
            err_msg=f"attention cotangent {name} ({mask})",
        )


def test_ops_attention_kv_lengths():
    """Per-head kv_lengths masking == oracle over truncated KV; rows with
    zero visible keys are exact zeros (the l==0 guard)."""
    rng = np.random.default_rng(15000)
    h, s, t, d = 3, 8, 8, 4
    q, k, v = (
        jnp.asarray(rng.standard_normal((h, s_ if i == 0 else t, d)),
                    jnp.float32)
        for i, s_ in enumerate((s, t, t))
    )
    lengths = jnp.asarray([t, 3, 0], jnp.int32)
    out = np.asarray(
        ops.attention(q, k, v, kv_lengths=lengths, interpret=True),
        np.float64,
    )
    for hh, n in enumerate(lengths.tolist()):
        if n == 0:
            np.testing.assert_array_equal(out[hh], 0.0)
            continue
        sc = (
            np.asarray(q, np.float64)[hh] @ np.asarray(k, np.float64)[hh, :n].T
        ) * d ** -0.5
        ref = _softmax_np(sc) @ np.asarray(v, np.float64)[hh, :n]
        np.testing.assert_allclose(
            out[hh], ref, rtol=1e-4, atol=1e-4,
            err_msg=f"kv_lengths head {hh} (len={n})",
        )


def test_capture_dispatches_attention_site():
    """The dense demo's attention motif harvests and dispatches as one
    fused site (op == "attention"), not three dense fallbacks."""
    from repro import capture
    from repro.models.api import get_api

    cfg = capture.demo_configs()["dense"]
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    toks = jnp.asarray(
        rng.integers(0, cfg.vocab, (capture.DEMO_BATCH, capture.DEMO_SEQ)),
        jnp.int32,
    )
    batch = {"tokens": toks, "labels": toks}

    def loss(p, b):
        return api.loss(p, cfg, b)

    report = capture.optimize(
        loss, interpret=True, label="dense-attn"
    ).report_for(params, batch)
    attn = [s for s in report.sites if s.op == "attention"]
    assert attn, report.to_json()
    assert all(s.dispatched for s in attn), report.to_json()
