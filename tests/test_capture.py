"""Conformance suite for repro.capture — whole-model GEMM capture.

The acceptance bar (ISSUE 4): ``capture.optimize`` on three model configs
(dense transformer, MoE, SSM) must dispatch every *eligible*
``dot_general`` site through the plan-DB pipeline, with captured fwd+bwd
outputs matching the uncaptured model within dtype tolerance.  Runs
entirely on CPU: dispatched sites execute under the Pallas interpreter
(``interpret=True`` — what ``REPRO_INTERPRET=1`` selects in CI).

Also covered here: the jaxpr re-emission of the higher-order primitives
(scan / remat / cond), harvest-only mode replaying byte-identically,
abstract (ShapeDtypeStruct) whole-model harvest with no allocation, the
report JSON artifact, and dispatched sites actually consulting the ranked
plan DB after a ``sweep_captured`` pass.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import capture  # noqa: E402
from repro.models.api import get_api  # noqa: E402

F32 = jnp.float32

#: fwd/bwd agreement vs the uncaptured model (f32 configs; the generated
#: kernels accumulate in f32 exactly like the XLA dots they replace)
TOL = dict(rtol=2e-5, atol=2e-5)

CONFIGS = capture.demo_configs()
B, S = capture.DEMO_BATCH, capture.DEMO_SEQ


@pytest.fixture(autouse=True)
def _isolated_caches(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    monkeypatch.setenv("REPRO_PLAN_DB", str(tmp_path / "plans.json"))


def _model_case(name):
    cfg = CONFIGS[name]
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}

    def loss(p, b):
        return api.loss(p, cfg, b)

    return cfg, loss, params, batch


# ---------------------------------------------------------------------------
# the acceptance matrix: three families, fwd + bwd, full dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_captured_model_matches_uncaptured(name):
    """Captured fwd+bwd == uncaptured fwd+bwd, with every eligible site
    dispatched (no site may be classified dispatchable yet fall back)."""
    cfg, loss, params, batch = _model_case(name)
    cf = capture.optimize(loss, interpret=True, label=name)
    report = cf.report_for(params, batch)

    assert report.harvested > 0, "model traced to zero dot_general sites?"
    assert report.dispatched > 0, (
        f"{name}: no site dispatched — alignment/dtype drift in the "
        f"demo config?\n{report.to_json()}"
    )
    # every site is either dispatched or carries a concrete reason: there
    # is no third state, so "every eligible site dispatched" holds exactly
    # when no fallback site has an empty reason
    for site in report.sites:
        if not site.dispatched:
            assert site.reason, f"undocumented fallback: {site.as_dict()}"
        else:
            assert site.spec is not None and site.op is not None

    ref = loss(params, batch)
    out = cf(params, batch)
    np.testing.assert_allclose(float(out), float(ref), **TOL)

    g_ref = jax.grad(loss)(params, batch)
    g_cap = jax.grad(cf)(params, batch)
    for path_ref, path_cap in zip(
        jax.tree.leaves(g_ref), jax.tree.leaves(g_cap)
    ):
        scale = max(float(jnp.max(jnp.abs(path_ref))), 1.0)
        np.testing.assert_allclose(
            np.asarray(path_cap, np.float64) / scale,
            np.asarray(path_ref, np.float64) / scale,
            **TOL,
            err_msg=f"{name}: captured backward diverges from uncaptured",
        )


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_capture_dispatch_counts(name):
    """The demo configs are built so the aligned projection GEMMs dispatch:
    pin the per-family floor so a predicate regression is loud."""
    cfg, loss, params, batch = _model_case(name)
    report = capture.optimize(
        loss, interpret=True, label=name
    ).report_for(params, batch)
    floors = {"dense": 8, "moe": 10, "ssm": 2}
    assert report.dispatched >= floors[name], report.to_json()
    # any remaining fallback (e.g. SSD einsums with multiple batch dims)
    # must carry a concrete reason — attention/MoE motifs now dispatch as
    # fused sites instead of falling back
    assert all(
        s.reason for s in report.sites if not s.dispatched
    )


def test_jit_through_captured_loss():
    cfg, loss, params, batch = _model_case("dense")
    cf = capture.optimize(loss, interpret=True)
    assert np.isclose(
        float(jax.jit(cf)(params, batch)), float(loss(params, batch)),
        rtol=2e-5, atol=2e-5,
    )


# ---------------------------------------------------------------------------
# plan-DB pipeline pickup
# ---------------------------------------------------------------------------


def test_dispatched_sites_consult_plan_db():
    """After sweep_captured persists ranked plans for the harvested specs,
    a captured call must hit the plan DB (the ops._tuned_kernel lookup)."""
    from repro.search import default_plan_db

    cfg, loss, params, batch = _model_case("dense")
    cf = capture.optimize(loss, interpret=True)
    report = cf.report_for(params, batch)
    specs = report.unique_specs()
    assert specs, "dense demo config must harvest dispatched specs"

    db = default_plan_db()
    n = capture.sweep_captured(
        [("t", spec, dt) for spec, dt in specs[:2]],
        with_grads=False, plan_db=db,
        beam_width=2, topk=1, repeats=1, interpret=True,
    )
    assert n == len(specs[:2])
    hits0 = db.lookup_hits
    cf(params, batch)
    assert db.lookup_hits > hits0, (
        "captured call did not consult the ranked plan DB"
    )


def test_backward_uses_derived_spec_keys(tmp_path, monkeypatch):
    """jax.grad of a captured loss populates the autotune cache under the
    *derived-spec* keys (<spec>.dA / <spec>.dB) of repro.grad: the grad
    cache ends up strictly larger than a forward-only cache, and the
    extra keys are exactly the derived specs' tune keys."""
    from repro.codegen import tune_schedule
    from repro.grad import derived_specs

    cfg, loss, params, batch = _model_case("dense")

    fwd_cache = tmp_path / "fwd.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(fwd_cache))
    capture.optimize(loss, interpret=True)(params, batch)
    fwd_entries = json.loads(fwd_cache.read_text())

    grad_cache = tmp_path / "grad.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(grad_cache))
    jax.grad(capture.optimize(loss, interpret=True))(params, batch)
    grad_entries = json.loads(grad_cache.read_text())

    assert len(grad_entries) > len(fwd_entries), (
        "backward pass produced no derived-spec tune entries"
    )
    # every dA/dB derived spec of a dispatched forward site must have been
    # tuned: re-tuning them now against the grad cache is all hits
    report = capture.optimize(
        loss, interpret=True
    ).report_for(params, batch)
    matmul_specs = [
        spec for spec, dt in report.unique_specs() if spec.name == "matmul"
    ]
    assert matmul_specs
    before = len(json.loads(grad_cache.read_text()))
    for spec in matmul_specs:
        for dspec in derived_specs(spec).values():
            tune_schedule(dspec, dtype=np.dtype(np.float32))
    assert len(json.loads(grad_cache.read_text())) == before, (
        "derived-spec keys were missing from the backward-pass cache"
    )


# ---------------------------------------------------------------------------
# jaxpr re-emission units
# ---------------------------------------------------------------------------


def _aligned(seed, *shape):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(shape), F32
    )


def test_scan_remat_cond_reemission():
    w = _aligned(1, 128, 128)
    x = _aligned(0, 3, 128, 128)

    def fn(x, w):
        def body(c, xs):
            return c + jnp.dot(xs, w), (xs * 2).sum()

        out, ys = jax.lax.scan(body, jnp.zeros((128, 128), F32), x)
        out = jax.checkpoint(lambda o: o @ w)(out)
        return jax.lax.cond(
            ys.sum() > 0, lambda o: o.sum(), lambda o: -o.sum(), out
        )

    cf = capture.optimize(fn, interpret=True)
    report = cf.report_for(x, w)
    assert report.harvested == 2 and report.dispatched == 2
    paths = {s.path for s in report.sites}
    assert any("scan" in p for p in paths)
    assert any("remat" in p for p in paths)
    np.testing.assert_allclose(
        float(cf(x, w)), float(fn(x, w)), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(jax.grad(cf)(x, w)), np.asarray(jax.grad(fn)(x, w)),
        rtol=1e-5, atol=1e-5,
    )


def test_grad_through_existing_ops_custom_vjp_site():
    """A traced function that ALREADY routes through a repro.ops
    custom-VJP kernel site must stay differentiable after capture: the
    replay re-binds the custom_vjp equation unmodified (inlining its
    pallas_call primal would make jax.grad crash).  Regression for the
    TPU `train --capture` path, where every model ops.dense call is such
    a site."""
    from repro import ops

    x, w = _aligned(10, 128, 128), _aligned(11, 128, 128)

    def loss(x_, w_):
        return ops.dense(x_, w_, interpret=True).sum()

    cf = capture.optimize(loss, interpret=True)
    report = cf.report_for(x, w)
    # the GEMM is hidden inside the custom_vjp primal as a pallas_call,
    # so there is nothing to harvest — and nothing must break
    assert report.dispatched == 0
    g_ref = jax.grad(loss)(x, w)
    g_cap = jax.grad(cf)(x, w)
    np.testing.assert_allclose(
        np.asarray(g_cap), np.asarray(g_ref), rtol=1e-5, atol=1e-5
    )


def test_custom_vjp_wrapping_dispatchable_site_is_inlined():
    """The counterpart rule: a custom_vjp whose primal holds a plain
    dispatchable dot_general gets inlined so the site dispatches (the
    user's custom derivative is superseded by the op's own VJP)."""

    @jax.custom_vjp
    def f(a, b):
        return jnp.dot(a, b, preferred_element_type=jnp.float32)

    f.defvjp(
        lambda a, b: (f(a, b), (a, b)),
        lambda res, g: (g @ res[1].T, res[0].T @ g),
    )

    a, b = _aligned(12, 128, 128), _aligned(13, 128, 128)
    cf = capture.optimize(lambda a_, b_: f(a_, b_).sum(), interpret=True)
    report = cf.report_for(a, b)
    assert report.dispatched == 1
    np.testing.assert_allclose(
        float(cf(a, b)), float(f(a, b).sum()), rtol=1e-5, atol=1e-4
    )


def test_transposed_and_batched_sites():
    a = _aligned(2, 16, 8)   # (D, M): contract dim 0 with dim 0
    b = _aligned(3, 16, 12)
    xb = _aligned(4, 4, 8, 16)
    wb = _aligned(5, 4, 16, 8)

    def fn(a, b, xb, wb):
        t = jax.lax.dot_general(a, b, (((0,), (0,)), ((), ())))
        bt = jax.lax.dot_general(xb, wb, (((2,), (1,)), ((0,), (0,))))
        return t.sum() + bt.sum()

    cf = capture.optimize(fn, interpret=True)
    report = cf.report_for(a, b, xb, wb)
    ops_seen = {s.op for s in report.sites if s.dispatched}
    assert ops_seen == {"dense_transposed", "batched_dense"}
    np.testing.assert_allclose(
        float(cf(a, b, xb, wb)), float(fn(a, b, xb, wb)),
        rtol=1e-5, atol=1e-5,
    )


def test_harvest_only_mode_replays_identically():
    cfg, loss, params, batch = _model_case("dense")
    cf = capture.optimize(loss, interpret=True, dispatch=False)
    report = cf.report_for(params, batch)
    assert report.dispatched == 0
    # sites that would have dispatched must carry the harvest-only
    # annotation; genuinely ineligible sites keep their own reason
    annotated = [
        s for s in report.sites if "dispatch disabled" in s.reason
    ]
    dispatchable = capture.optimize(
        loss, interpret=True
    ).report_for(params, batch).dispatched
    assert len(annotated) == dispatchable > 0
    assert all(s.reason for s in report.sites)
    # replay re-binds the original equations: bitwise-equal output
    assert float(cf(params, batch)) == float(loss(params, batch))


def test_cpu_without_interpret_falls_back_entirely():
    """interpret=False on a CPU backend: nothing dispatches, everything
    still runs (production no-op safety)."""
    cfg, loss, params, batch = _model_case("dense")
    cf = capture.optimize(loss, interpret=False)
    report = cf.report_for(params, batch)
    assert report.dispatched == 0
    assert float(cf(params, batch)) == pytest.approx(
        float(loss(params, batch)), rel=1e-6
    )


# ---------------------------------------------------------------------------
# abstract whole-model harvest + report artifact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_abstract_model_capture(kind):
    """ShapeDtypeStruct tracing: harvest without allocating parameters."""
    cfg = CONFIGS["dense"]
    _, report = capture.model_capture(
        cfg, batch=B, seq=S, kind=kind, interpret=True,
    )
    assert report.harvested > 0
    if kind == "train":
        assert report.dispatched > 0


def test_abstract_harvest_matches_concrete():
    cfg, loss, params, batch = _model_case("dense")
    concrete = capture.optimize(
        loss, interpret=True
    ).report_for(params, batch)
    _, abstract = capture.model_capture(
        cfg, batch=B, seq=S, kind="train", interpret=True,
    )
    assert (abstract.harvested, abstract.dispatched, abstract.fallback) == (
        concrete.harvested, concrete.dispatched, concrete.fallback,
    )


def test_model_gemm_specs_dedupes():
    cfg = CONFIGS["dense"]
    points = capture.model_gemm_specs(
        cfg, batch=B, seq=S, kinds=("train",), interpret=True,
    )
    assert points
    keys = [
        (spec.name, tuple(sorted(spec.extents.items())), dt)
        for _, spec, dt in points
    ]
    assert len(keys) == len(set(keys))


def test_report_json_roundtrip():
    cfg, loss, params, batch = _model_case("dense")
    report = capture.optimize(
        loss, interpret=True
    ).report_for(params, batch)
    blob = json.loads(report.to_json())
    assert blob["harvested"] == report.harvested
    assert blob["dispatched"] == report.dispatched
    assert len(blob["sites"]) == report.harvested
    for site in blob["sites"]:
        assert site["status"] in ("dispatched", "fallback")
        if site["status"] == "dispatched":
            assert site["spec"] in (
                "matmul", "transposed_matmul", "batched_matmul",
                "attention", "grouped_matmul",
            )


# ---------------------------------------------------------------------------
# fallback-by-containment blame
# ---------------------------------------------------------------------------


def test_fallback_names_nearest_blocking_ancestor(monkeypatch):
    """A site under nested non-rewritable primitives must blame the
    NEAREST one — the primitive that actually stops the rewrite — not the
    outermost.  Regression: the walk used to latch the first blocker and
    never replace it.  ``while``/``cond`` are removed from the rewritable
    set so both act as blockers; the dot lives inside cond inside while,
    so ``cond`` is the true blocker."""
    from repro.capture import harvest as hmod

    monkeypatch.setattr(
        hmod, "REWRITABLE_HOPS",
        frozenset({"pjit", "closed_call", "core_call"}),
    )
    w = _aligned(6, 128, 128)

    def fn(x):
        def body(c):
            return jax.lax.cond(
                c.sum() > 0,
                lambda a: jnp.dot(a, w, preferred_element_type=F32),
                lambda a: a * 1.0,
                c,
            ) * 0.5

        return jax.lax.while_loop(
            lambda c: c[0, 0] < 1.0, body, x
        ).sum()

    report = capture.optimize(fn, interpret=True).report_for(w)
    sites = [s for s in report.sites if s.op == "dense"]
    assert sites, report.to_json()
    for s in sites:
        assert not s.dispatched
        assert "(cond)" in s.reason, s.reason
        assert "while" not in s.reason, s.reason
