"""Launch-layer tests.

Device-count-sensitive pieces (meshes, shard_map collectives, lower+compile)
run through the shared ``forced_devices`` fixture (``tests/conftest.py``):
a subprocess under ``--xla_force_host_platform_device_count`` so the main
pytest process keeps its single-device view (per the dry-run contract:
only dryrun.py forces 512 devices).
"""

import pytest


# -- pure unit tests (no devices) ---------------------------------------------


def test_spec_for_rules_divisibility():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import spec_for

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    mesh = FakeMesh()
    # FSDP x TP for a weight
    assert spec_for(mesh, ("embed", "mlp"), (4096, 11008)) == P("data", "model")
    # vocab not divisible -> replicated dim
    assert spec_for(mesh, ("vocab", "embed"), (51865, 512)) == P(None, "data")
    # MQA cache: kv=1 cannot shard, seq takes model
    assert spec_for(
        mesh, ("batch", "seq_kv", "kv", None), (128, 32768, 1, 128)
    ) == P("data", "model")
    # deepseek cache: kv=32 takes model, seq falls back to data... but batch
    # already used data -> seq stays unsharded
    assert spec_for(
        mesh, ("batch", "seq_kv", "kv", None), (128, 32768, 32, 128)
    ) == P("data", None, "model")


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
      %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={}
      %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%add
      %rs = f32[8,128]{1,0} reduce-scatter(%z), dimensions={0}
      %cp = (f32[4,4]{1,0}, f32[4,4]{1,0}) collective-permute-start(%w)
      %nothing = f32[2,2]{1,0} add(%a, %b)
    """
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 8 * 128 * 4
    assert out["collective-permute"] == 4 * 4 * 4  # tuple payload counted once
    assert out["count"] == 4


def test_roofline_terms_math():
    from repro.roofline.analysis import analyze_cell

    rec = dict(
        status="ok", arch="x", shape="train_4k", mesh="16x16", chips=256,
        step="train_step", flops=197e12, bytes_accessed=819e9,
        collectives={"all-gather": 50e9, "all-reduce": 0,
                     "reduce-scatter": 0, "all-to-all": 0,
                     "collective-permute": 0, "count": 1},
    )
    out = analyze_cell(rec)
    assert out["compute_s"] == pytest.approx(1.0)
    assert out["memory_s"] == pytest.approx(1.0)
    assert out["collective_s"] == pytest.approx(1.0)


# -- subprocess tests (multi-device) -------------------------------------------


def test_debug_mesh_train_bundle_compiles(forced_devices):
    """A smoke-scale arch lowers+compiles on a 2x2 mesh with the same
    sharding machinery as the production dry-run."""
    out = forced_devices("""
        import jax
        from repro.configs import get_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import train_bundle
        from repro.configs.base import ShapeConfig

        cfg = get_config("qwen3-8b").smoke()
        mesh = make_debug_mesh((2, 2), ("data", "model"))
        shape = ShapeConfig("tiny", 32, 8, "train")
        from repro.launch.mesh import set_mesh
        with set_mesh(mesh):
            b = train_bundle(mesh, cfg, shape)
            compiled = jax.jit(
                b.fn, out_shardings=b.out_shardings
            ).lower(*b.in_shapes).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # jax 0.4.x returns [dict]
            cost = cost[0] if cost else {}
        print("FLOPS", cost.get("flops", 0))
        print("OK")
    """)
    assert "OK" in out


def test_debug_mesh_serve_bundle_compiles(forced_devices):
    out = forced_devices("""
        import jax
        from repro.configs import get_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import serve_bundle
        from repro.configs.base import ShapeConfig

        cfg = get_config("granite-34b").smoke()   # MQA decode path
        mesh = make_debug_mesh((2, 2), ("data", "model"))
        shape = ShapeConfig("tinydecode", 64, 8, "decode")
        from repro.launch.mesh import set_mesh
        with set_mesh(mesh):
            b = serve_bundle(mesh, cfg, shape)
            compiled = jax.jit(
                b.fn, out_shardings=b.out_shardings
            ).lower(*b.in_shapes).compile()
        print("OK")
    """)
    assert "OK" in out


def test_train_step_runs_on_mesh_and_loss_decreases(forced_devices):
    """End-to-end: real data -> sharded train_step on a 4-device mesh; the
    loss must fall (integration of models+optim+sharding+data)."""
    out = forced_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import make_train_step
        from repro.optim import AdamWConfig
        from repro.optim import adamw as optim
        from repro.models.api import get_api
        from repro.data.pipeline import DataConfig, batch_at

        cfg = get_config("deepseek-7b").smoke()
        api = get_api(cfg)
        mesh = make_debug_mesh((2, 2), ("data", "model"))
        from repro.launch.mesh import set_mesh
        with set_mesh(mesh):
            params, _ = api.init(cfg, jax.random.key(0))
            ocfg = AdamWConfig(lr=1e-2, moments_dtype="float32")
            opt = optim.init(params, ocfg)
            step = jax.jit(make_train_step(cfg, ocfg))
            dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
            losses = []
            for i in range(20):
                b = {k: jnp.asarray(v) for k, v in batch_at(dc, i).items()}
                params, opt, m = step(params, opt, b)
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses
        print("LOSS", losses[0], "->", losses[-1])
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_hierarchical_compressed_psum(forced_devices):
    """shard_map int8 cross-pod gradient reduction on a (2,4) pod x data
    mesh: result within quantization error of the exact psum."""
    out = forced_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_debug_mesh
        from repro.optim.compress import hierarchical_psum

        mesh = make_debug_mesh((2, 4), ("pod", "data"))
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal((8, 64)), jnp.float32
        )

        def f(xs):
            return hierarchical_psum(xs, pod_axis="pod", inner_axis="data",
                                     compress=True)

        g = shard_map(
            f, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(),
            check_rep=False,
        )
        got = np.asarray(g(x))
        want = np.asarray(x).sum(axis=0, keepdims=True).repeat(1, 0)
        want = np.asarray(x).reshape(8, 64).sum(0)
        rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        assert rel < 0.02, rel
        print("REL", rel)
        print("OK")
    """, devices=8)
    assert "OK" in out


@pytest.mark.parametrize("devices", [2, 4, 8])
def test_ring_collective_matmul_property(forced_devices, devices):
    """Property: ring_gather_matmul == naive_gather_matmul == unsharded
    oracle for seeded-random shard counts and shapes, plus the
    codegen-integrated ring lowering (``codegen.collectives.ring_psum``,
    what a searched plan with ``collective=ring`` executes): ring psum ==
    lax.psum == the unsharded sum, including the ``p == 1`` cut path and
    payloads that leave a remainder shard (padding path)."""
    out = forced_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax import lax
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_debug_mesh
        from repro.codegen.collectives import ring_psum
        from repro.launch.overlap import naive_gather_matmul, ring_gather_matmul

        P_TOTAL = jax.device_count()
        rng = np.random.default_rng(100 + P_TOTAL)
        checked_hlo = False
        for case in range(4):
            # random shard count dividing the device pool, random shapes
            ps = [p for p in (1, 2, 4, 8) if P_TOTAL % p == 0 and p <= P_TOTAL]
            # case 0 pins the p == 1 cut path; the rest draw randomly
            p = 1 if case == 0 else int(rng.choice(ps))
            m_loc = int(rng.integers(1, 5))
            k = int(rng.integers(1, 9))
            n = int(rng.integers(1, 9))
            m = p * m_loc
            mesh = make_debug_mesh((p,), ("model",))
            x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
            w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

            ring = shard_map(
                lambda xs, ws: ring_gather_matmul(xs, ws, "model"),
                mesh=mesh, in_specs=(P("model", None), P()),
                out_specs=P(), check_rep=False,
            )
            naive = shard_map(
                lambda xs, ws: naive_gather_matmul(xs, ws, "model"),
                mesh=mesh, in_specs=(P("model", None), P()),
                out_specs=P(), check_rep=False,
            )
            got, want = np.asarray(ring(x, w)), np.asarray(naive(x, w))
            ref = np.asarray(x) @ np.asarray(w)
            np.testing.assert_allclose(want, ref, rtol=1e-4, atol=1e-5)
            np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
            if p > 1 and not checked_hlo:
                # the ring variant must collective-permute, not all-gather
                hlo = jax.jit(ring).lower(x, w).compile().as_text()
                assert "collective-permute" in hlo
                checked_hlo = True

            # codegen-integrated ring all-reduce: cut path (p == 1 above
            # when drawn), even split, and remainder payloads
            rows = int(rng.integers(1, 7))   # rows*cols rarely divides p
            cols = int(rng.integers(1, 11))
            y = jnp.asarray(
                rng.standard_normal((p, rows, cols)), jnp.float32
            )
            rp = shard_map(
                lambda v: ring_psum(v[0], "model"), mesh=mesh,
                in_specs=P("model"), out_specs=P(), check_rep=False,
            )
            pp = shard_map(
                lambda v: lax.psum(v[0], "model"), mesh=mesh,
                in_specs=P("model"), out_specs=P(), check_rep=False,
            )
            got_r, want_r = np.asarray(rp(y)), np.asarray(pp(y))
            oracle = np.asarray(y).sum(0)
            np.testing.assert_allclose(want_r, oracle, rtol=1e-4, atol=1e-5)
            np.testing.assert_allclose(got_r, oracle, rtol=1e-4, atol=1e-5)
        print("OK")
    """, devices=devices)
    assert "OK" in out


def test_pipeline_parallelism_over_pod_axis(forced_devices):
    """GPipe schedule over a 4-stage pipe axis == sequential layer stack."""
    out = forced_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.pipeline import bubble_fraction, pipeline_apply

        P_STAGES, M, MB, D = 4, 6, 3, 8
        mesh = make_debug_mesh((4,), ("pipe",))
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.standard_normal((P_STAGES, D, D)) * 0.5,
                         jnp.float32)
        xs = jnp.asarray(rng.standard_normal((M, MB, D)), jnp.float32)

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        piped = shard_map(
            lambda ws, mb: pipeline_apply(stage_fn, ws, mb, "pipe"),
            mesh=mesh,
            in_specs=(P("pipe", None, None), P()),
            out_specs=P(),
            check_rep=False,
        )
        got = np.asarray(piped(Ws, xs))

        ref = np.asarray(xs)
        for s in range(P_STAGES):
            ref = np.tanh(ref @ np.asarray(Ws[s]))
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
        assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
        print("OK")
    """, devices=4)
    assert "OK" in out
