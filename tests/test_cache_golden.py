"""Golden regression: PR-1 autotune-cache entries must keep hitting.

``tests/data/autotune_cache_golden.json`` is a committed snapshot of the
cache file ``codegen.tune_schedule`` writes (CACHE_VERSION 1 /
TUNER_VERSION 2 format, hardware fingerprint pinned to
``golden/fixture-hw``).  These tests guard ``$REPRO_AUTOTUNE_CACHE``
compatibility across releases:

  * the key-derivation function still produces the committed hex digests
    for the same (spec, dtype, tuner, hw) inputs — if this fails, every
    fleet cache goes cold on upgrade; bump ``CACHE_VERSION`` deliberately
    instead of silently changing the hash inputs;
  * the serialized schedules still deserialize, validate, and round-trip
    byte-identically;
  * ``tune_schedule`` against the fixture *hits* (no re-enumeration) and
    returns exactly the stored winner.

Regenerate (only after a deliberate format bump) by deleting the fixture
and re-running the snippet in this file's git history / CHANGES.md — pin
``hardware_fingerprint`` to ``golden/fixture-hw`` first.
"""

from __future__ import annotations

import json
import os
import shutil

import numpy as np
import pytest

import repro.codegen.cache as cache_mod
from repro.codegen.cache import (
    AutotuneCache,
    cache_key,
    schedule_from_dict,
    schedule_to_dict,
)
from repro.codegen.tune import TUNER_VERSION, tune_schedule
from repro.core.cost import TPU
from repro.core.enumerate import chain_matmul_spec, matmul_spec

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "autotune_cache_golden.json")
GOLDEN_HW = "golden/fixture-hw"

#: (spec ctor args are part of the key) — what the fixture was built from
FIXTURE_POINTS = [
    ("matmul", matmul_spec(2048, 4096, 4096), np.dtype(np.float32)),
    ("matmul-bf16", matmul_spec(2048, 4096, 4096), np.dtype("bfloat16")),
    ("chain", chain_matmul_spec(1024, 2048, 2048, 1024), np.dtype(np.float32)),
]


def _golden_key(spec, dtype):
    """The exact key construction tune_schedule used at fixture time."""
    return cache_key(
        spec,
        dtype=dtype,
        hardware=GOLDEN_HW,
        extra={
            "tuner": TUNER_VERSION,
            "keep": 3,
            "hw": sorted(
                (k, v) for k, v in TPU.items()
                if isinstance(v, (int, float))
            ),
            "measured": False,
        },
    )


@pytest.fixture()
def fixture_data():
    with open(FIXTURE) as f:
        return json.load(f)


def test_fixture_exists_and_is_wellformed(fixture_data):
    assert len(fixture_data) == len(FIXTURE_POINTS)
    for entry in fixture_data.values():
        assert set(entry) >= {"schedule", "blocks", "measured"}
        assert set(entry["schedule"]) == {"splits", "levels"}


@pytest.mark.parametrize(
    "label,spec,dtype",
    FIXTURE_POINTS,
    ids=[p[0] for p in FIXTURE_POINTS],
)
def test_key_derivation_is_stable(fixture_data, label, spec, dtype):
    key = _golden_key(spec, dtype)
    assert key in fixture_data, (
        f"cache key for {label} drifted — PR-1 fleet caches would go cold. "
        f"If the format change is deliberate, bump CACHE_VERSION and "
        f"regenerate the fixture."
    )


@pytest.mark.parametrize(
    "label,spec,dtype",
    FIXTURE_POINTS,
    ids=[p[0] for p in FIXTURE_POINTS],
)
def test_schedule_roundtrip(fixture_data, label, spec, dtype):
    entry = fixture_data[_golden_key(spec, dtype)]
    sched = schedule_from_dict(entry["schedule"], spec)
    assert schedule_to_dict(sched) == entry["schedule"]
    # the stored splits/levels must still validate against today's Schedule
    sched.validate()


def test_tune_schedule_hits_golden_cache(tmp_path, monkeypatch):
    """End to end: a fleet cache file from PR 1 still short-circuits the
    tuner after the search-pipeline changes."""
    monkeypatch.setattr(
        cache_mod, "hardware_fingerprint", lambda: GOLDEN_HW
    )
    path = tmp_path / "autotune.json"
    shutil.copy(FIXTURE, path)
    cache = AutotuneCache(str(path))

    for label, spec, dtype in FIXTURE_POINTS:
        before_hits = cache.hits
        sched = tune_schedule(
            spec, dtype=dtype, cache=cache, use_default_cache=False
        )
        assert cache.hits == before_hits + 1, f"{label}: cache missed"
        with open(FIXTURE) as f:
            entry = json.load(f)[_golden_key(spec, dtype)]
        assert schedule_to_dict(sched) == entry["schedule"], label
    assert cache.misses == 0
