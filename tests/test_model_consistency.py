"""Deeper model-correctness tests.

* Mamba2 SSD chunked algorithm == naive sequential recurrence.
* Chunk size must not change SSD results (the paper's subdiv identity,
  applied to the SSD inter/intra-chunk decomposition).
* Prefill + decode_step logits == full forward logits at the same position
  (cache path equivalence) for dense, MoE, SSM, and hybrid families.
* Blockwise (flash-style) attention == naive softmax attention for
  causal/non-causal, GQA/MQA, across block sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.models import hybrid as H
from repro.models import transformer as T
from repro.models.layers import blockwise_attention
from repro.models.ssm import ssd_chunked


def naive_ssd(x, A, B, C):
    """Sequential state-space recurrence (the definition)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    state = np.zeros((b, h, p, n))
    ys = np.zeros_like(np.asarray(x))
    for t in range(s):
        dA = np.exp(np.asarray(A[:, t]))  # (b,h)
        state = state * dA[..., None, None] + (
            np.asarray(x[:, t])[..., None] * np.asarray(B[:, t])[:, None, None, :]
        )
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, np.asarray(C[:, t]))
    return ys


@pytest.mark.parametrize("chunk", [1, 2, 4, 8, 16])
def test_ssd_chunked_equals_recurrence(chunk):
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 16, 3, 4, 5
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    A = jnp.asarray(-np.abs(rng.standard_normal((b, s, h))) * 0.5, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y, _ = ssd_chunked(x, A, B, C, chunk=chunk)
    ref = naive_ssd(x, A, B, C)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


def test_ssd_final_state_supports_streaming():
    """Processing [first half] then [second half with carried state] must
    equal processing the whole sequence (decode-path foundation)."""
    rng = np.random.default_rng(1)
    b, s, h, p, n = 1, 12, 2, 4, 3
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    A = jnp.asarray(-np.abs(rng.standard_normal((b, s, h))) * 0.3, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y_full, _ = ssd_chunked(x, A, B, C, chunk=4)
    half = s // 2
    y1, st1 = ssd_chunked(x[:, :half], A[:, :half], B[:, :half], C[:, :half],
                          chunk=4)
    y2, _ = ssd_chunked(x[:, half:], A[:, half:], B[:, half:], C[:, half:],
                        chunk=4, initial_state=st1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)),
        np.asarray(y_full), rtol=2e-4, atol=2e-4,
    )


@given(
    qb=st.sampled_from([2, 4, 8, 16]),
    kb=st.sampled_from([2, 4, 8, 16]),
    causal=st.booleans(),
    kv=st.sampled_from([1, 2, 4]),
)
@settings(max_examples=20, deadline=None)
def test_blockwise_attention_property(qb, kb, causal, kv):
    rng = np.random.default_rng(qb * 100 + kb)
    B, S, H, hd = 2, 16, 4, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, kv, hd)), jnp.float32)
    out = blockwise_attention(q, k, v, causal=causal, q_block=qb, k_block=kb)
    # naive reference
    G = H // kv
    qg = np.asarray(q).reshape(B, S, kv, G, hd)
    s = np.einsum("bskgh,btkh->bkgst", qg, np.asarray(k)) * hd ** -0.5
    if causal:
        mask = np.tril(np.ones((S, S), bool))
        s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    ref = np.einsum("bkgst,btkh->bskgh", p, np.asarray(v)).reshape(B, S, H, hd)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def _decode_matches_forward(cfg, api_forward, api_prefill, api_decode, batch):
    """Greedy next-token logits from (prefill + decode) must match the
    teacher-forced forward logits at the same positions."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    full = api_forward(tokens)  # (B, S, V)
    _, caches = api_prefill(tokens[:, :-1], S + 4)
    step_logits, _ = api_decode(caches, tokens[:, -1:])
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full[:, -1]),
        rtol=5e-3, atol=5e-3,
    )


def test_decode_matches_forward_dense():
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=97, head_dim=8,
                      dtype="float32")
    params, _ = T.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 97)
    full = T.forward(params, cfg, toks)
    _, caches = T.prefill(params, cfg, toks[:, :-1], max_len=16)
    lg, _ = T.decode_step(params, cfg, caches, toks[:, -1:])
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full[:, -1]), rtol=5e-3, atol=5e-3
    )


def test_decode_matches_forward_ssm():
    cfg = ModelConfig(arch_id="s", family="ssm", n_layers=2, d_model=32,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab=97,
                      dtype="float32",
                      ssm=SSMConfig(d_state=8, expand=2, headdim=8, chunk=4))
    params, _ = H.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 97)
    full = H.forward(params, cfg, toks)
    _, caches = H.prefill(params, cfg, toks[:, :-1], max_len=16)
    lg, _ = H.decode_step(params, cfg, caches, toks[:, -1:])
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full[:, -1]), rtol=5e-3, atol=5e-3
    )


def test_decode_matches_forward_hybrid():
    cfg = ModelConfig(arch_id="h", family="hybrid", n_layers=4, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=97, head_dim=8,
                      dtype="float32", attn_every=2,
                      ssm=SSMConfig(d_state=8, expand=2, headdim=8, chunk=4))
    params, _ = H.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 97)
    full = H.forward(params, cfg, toks)
    _, caches = H.prefill(params, cfg, toks[:, :-1], max_len=16)
    lg, _ = H.decode_step(params, cfg, caches, toks[:, -1:])
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full[:, -1]), rtol=5e-3, atol=5e-3
    )


def test_decode_matches_forward_moe():
    cfg = ModelConfig(arch_id="m", family="moe", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=97, head_dim=8,
                      dtype="float32",
                      moe=MoEConfig(n_experts=4, top_k=2, expert_ff=32,
                                    moe_every=1, shared_expert_ff=16,
                                    capacity_factor=4.0))
    params, _ = T.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 97)
    full = T.forward(params, cfg, toks)
    _, caches = T.prefill(params, cfg, toks[:, :-1], max_len=16)
    lg, _ = T.decode_step(params, cfg, caches, toks[:, -1:])
    # generous tolerance: the capacity factor differs between S=11 prefill
    # and S=1 decode, but with cf=4 nothing drops in this regime
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full[:, -1]), rtol=5e-3, atol=5e-3
    )


def test_moe_routing_capacity_and_combine():
    """Unit test for the sort-based dispatch: with capacity ample and top-1
    routing, the MoE must equal running each token through its argmax
    expert."""
    from repro.models.moe import moe_apply, moe_init

    cfg = ModelConfig(arch_id="m", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=1, d_ff=32, vocab=11, head_dim=8,
                      dtype="float32",
                      moe=MoEConfig(n_experts=4, top_k=1, expert_ff=16,
                                    capacity_factor=8.0))
    params_pa = moe_init(jax.random.key(0), cfg)
    from repro.models.layers import split_params

    params, _ = split_params(params_pa)
    x = jax.random.normal(jax.random.key(1), (2, 6, 16))
    out = moe_apply(params, cfg, x)

    xf = np.asarray(x).reshape(-1, 16)
    router = np.asarray(params["router"])
    eidx = (xf @ router).argmax(-1)
    want = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        e = eidx[t]
        g = xf[t] @ np.asarray(params["w_gate"][e])
        u = xf[t] @ np.asarray(params["w_up"][e])
        act = (g / (1 + np.exp(-g))) * u
        want[t] = act @ np.asarray(params["w_down"][e])
    np.testing.assert_allclose(
        np.asarray(out).reshape(-1, 16), want, rtol=2e-3, atol=2e-3
    )
