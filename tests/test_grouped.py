"""Property + differential suite for the grouped_matmul family (ISSUE 8).

The ragged grouped GEMM is the one family where "close enough" is not
good enough: the MoE gate routes through ``ops.grouped_dense`` under a
flag with the promise of *bitwise* parity against the batched-einsum
path.  The property tests here pin, over random partitions — including
empty and size-1 groups, the raggedness that kills naive group-offset
grids —

  * the reference path (the semantic definition XLA also runs for the
    MoE gate on CPU): **bitwise** equal to the per-group
    ``lax.dot_general`` loop, and
  * the generated group-offset Pallas kernel (interpret mode): equal to
    the same loop up to f32 reduction-order reassociation only (both
    sides accumulate in f32 and store in the matched dtype, so the
    tolerance is ~1 ulp of the accumulator, orders of magnitude below
    any masking/offset bug).

Property tests run under the seeded fallback engine when hypothesis is
absent (tier-1 never installs packages); failures reproduce from the
printed falsifying example.
"""

from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
from jax import lax  # noqa: E402

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import codegen, ops  # noqa: E402
from repro.core.enumerate import grouped_matmul_spec  # noqa: E402
from repro.grad import COTANGENT, derived_specs  # noqa: E402
from repro.search import (  # noqa: E402
    candidate_schedule,
    einsum_reference,
    reference_arrays,
)


@pytest.fixture(autouse=True)
def _isolated_caches(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    monkeypatch.setenv("REPRO_PLAN_DB", str(tmp_path / "plans.json"))


def _partition(rng, g, n):
    """Random composition of n into g parts, empties allowed (and with a
    forced empty + size-1 group when room permits, so the degenerate
    cases are always in-distribution)."""
    cuts = np.sort(rng.integers(0, n + 1, g - 1)) if g > 1 else np.array([], int)
    sizes = np.diff(np.concatenate([[0], cuts, [n]])).astype(int)
    if g >= 3 and n >= 1:
        sizes[rng.integers(0, g)] = 0
        sizes[-1] = n - sizes[:-1].sum()
        if sizes[-1] < 0:  # rebalance if the forced empty overdrew
            sizes = np.maximum(sizes, 0)
            sizes[-1] = n - sizes[:-1].sum()
    assert sizes.sum() == n and (sizes >= 0).all()
    return tuple(int(s) for s in sizes)


def _loop_oracle(x, w, sizes, out_dtype):
    """Per-group dot_general loop — the bitwise reference: same f32
    accumulation and store rounding as the generated kernel."""
    parts, o = [], 0
    for g, s in enumerate(sizes):
        parts.append(
            lax.dot_general(
                x[o : o + s], w[g], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).astype(out_dtype)
        )
        o += s
    return jnp.concatenate(parts, axis=0) if parts else jnp.zeros(
        (0, w.shape[-1]), out_dtype
    )


@given(
    seed=st.integers(0, 10**6),
    g=st.integers(1, 5),
    n=st.integers(0, 24),
    k=st.sampled_from([1, 3, 4, 8]),
    f=st.sampled_from([1, 2, 4, 8]),
)
@settings(max_examples=30, deadline=None)
def test_grouped_matches_loop_both_paths(seed, g, n, k, f):
    """ops.grouped_dense == per-group loop: bitwise on the reference
    path, reduction-order-tight on the generated-kernel path."""
    if n == 0:
        return  # empty-input path covered by its own test below
    rng = np.random.default_rng(seed)
    sizes = _partition(rng, g, n)
    x = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((g, k, f)), jnp.float32)
    ref = _loop_oracle(x, w, sizes, jnp.float32)

    ref_path = ops.grouped_dense(x, w, sizes)  # CPU: semantic definition
    assert ref_path.dtype == ref.dtype
    np.testing.assert_array_equal(
        np.asarray(ref_path), np.asarray(ref),
        err_msg=f"reference path not bitwise (sizes={sizes} k={k} f={f})",
    )

    out = ops.grouped_dense(x, w, sizes, interpret=True)
    assert out.dtype == ref.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float64), np.asarray(ref, np.float64),
        rtol=1e-5, atol=1e-6,
        err_msg=f"grouped kernel diverged (sizes={sizes} k={k} f={f})",
    )


@given(seed=st.integers(0, 10**6), g=st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_grouped_bf16_store_matches_loop(seed, g):
    """bf16 operands: f32 accumulation, bf16 store.  The bf16 rounding at
    the store dominates reassociation noise, so both paths must land on
    values within one bf16 ulp of the loop's."""
    rng = np.random.default_rng(seed)
    n, k, f = 12, 4, 4
    sizes = _partition(rng, g, n)
    x = jnp.asarray(rng.standard_normal((n, k)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((g, k, f)), jnp.bfloat16)
    ref = _loop_oracle(x, w, sizes, jnp.bfloat16)
    np.testing.assert_array_equal(
        np.asarray(ops.grouped_dense(x, w, sizes), np.float32),
        np.asarray(ref, np.float32),
    )
    out = ops.grouped_dense(x, w, sizes, interpret=True)
    assert out.dtype == ref.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=1e-2, atol=1e-2,
    )


def test_grouped_empty_and_singleton_groups():
    """Hand-pinned degenerate partitions: leading/trailing empties,
    all-size-1, and the all-rows-in-one-group extremes."""
    rng = np.random.default_rng(42)
    k, f = 4, 8
    for sizes in [
        (0, 5, 0), (5, 0, 0), (0, 0, 5),
        (1, 1, 1, 1, 1), (5,), (0, 0, 0, 5, 0),
    ]:
        n = sum(sizes)
        x = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
        w = jnp.asarray(
            rng.standard_normal((len(sizes), k, f)), jnp.float32
        )
        ref = _loop_oracle(x, w, sizes, jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(ops.grouped_dense(x, w, sizes)), np.asarray(ref),
            err_msg=f"sizes={sizes} (reference path)",
        )
        out = ops.grouped_dense(x, w, sizes, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float64), np.asarray(ref, np.float64),
            rtol=1e-5, atol=1e-6, err_msg=f"sizes={sizes} (kernel path)",
        )


def test_grouped_zero_rows_total():
    x = jnp.zeros((0, 4), jnp.float32)
    w = jnp.asarray(
        np.random.default_rng(0).standard_normal((3, 4, 8)), jnp.float32
    )
    out = ops.grouped_dense(x, w, (0, 0, 0), interpret=True)
    assert out.shape == (0, 8)


def test_grouped_validation():
    x = jnp.zeros((4, 3), jnp.float32)
    w = jnp.zeros((2, 3, 5), jnp.float32)
    with pytest.raises(ValueError):
        ops.grouped_dense(x, w, (2, 1), interpret=True)  # sum != rows
    with pytest.raises(ValueError):
        ops.grouped_dense(x, w, (2, 1, 1), interpret=True)  # len != g
    with pytest.raises(ValueError):
        ops.grouped_dense(x[0], w, (2, 2), interpret=True)  # x not 2-D


# ---------------------------------------------------------------------------
# the family as a search-space citizen: random schedules + derived specs
# ---------------------------------------------------------------------------


def _divisors(n: int):
    return [d for d in range(1, n + 1) if n % d == 0]


def _draw_schedule(spec, rng):
    order = list(spec.indices)
    rng.shuffle(order)
    whole = set(getattr(spec.root(), "whole_indices", ()))
    blocks = {
        i: spec.extents[i]
        if i in whole or spec.extents[i] == 0
        else int(rng.choice(_divisors(spec.extents[i])))
        for i in spec.indices
    }
    return candidate_schedule(spec, tuple(order), blocks)


@pytest.mark.parametrize("seed", range(4))
def test_grouped_derived_specs_compile(seed):
    """grouped_matmul.dX/.dW compile under random legal schedules and
    match the per-group f64 oracle AND jax.vjp of the loop forward."""
    rng = np.random.default_rng(16000 + seed)
    g, k, f = 3, 4, 4
    sizes = _partition(rng, g, 10)
    spec = grouped_matmul_spec(sizes, k, f)
    n = sum(sizes)
    arrays = reference_arrays(spec, dtype=np.float32, seed=seed)
    gcot = rng.standard_normal((n, f)).astype(np.float32)

    dspecs = derived_specs(spec)
    assert set(dspecs) == {"X", "W"}

    x, w = jnp.asarray(arrays["X"]), jnp.asarray(arrays["W"])
    _, vjp = jax.vjp(
        lambda x_, w_: _loop_oracle(x_, w_, sizes, jnp.float32), x, w
    )
    oracle = dict(zip(("X", "W"), vjp(jnp.asarray(gcot))))

    for wrt, dspec in dspecs.items():
        assert dspec.name == f"grouped_matmul.d{wrt}"
        assert dspec.group_sizes == sizes
        darrays = {COTANGENT: gcot}
        darrays.update({m: arrays[m] for m in spec.operands if m != wrt})
        kern = codegen.compile(
            dspec, _draw_schedule(dspec, rng), interpret=True
        )
        out = np.asarray(
            kern(*(jnp.asarray(darrays[m]) for m in dspec.operands)),
            np.float64,
        )
        np.testing.assert_allclose(
            out, einsum_reference(dspec, darrays), rtol=1e-4, atol=1e-4,
            err_msg=f"{dspec.name} != per-group oracle (sizes={sizes})",
        )
        np.testing.assert_allclose(
            out, np.asarray(oracle[wrt], np.float64),
            rtol=1e-3, atol=1e-3,
            err_msg=f"{dspec.name} is not the cotangent (sizes={sizes})",
        )


def test_ops_grouped_dense_check_grads():
    from jax.test_util import check_grads

    rng = np.random.default_rng(17000)
    sizes = (3, 0, 4, 1)
    x = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 4, 4)), jnp.float32)

    def fn(x_, w_):
        return ops.grouped_dense(x_, w_, sizes, interpret=True)

    check_grads(fn, (x, w), order=1, modes=("rev",), atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# capture: the MoE demo dispatches grouped sites without losing the floor
# ---------------------------------------------------------------------------


def test_moe_capture_dispatches_grouped_sites():
    """capture.optimize on the MoE demo config (grouped gate on) emits
    >= 1 grouped_dense site, keeps the dense dispatch floor, and the
    captured loss matches the uncaptured one."""
    from repro import capture
    from repro.models.api import get_api

    cfg = capture.demo_configs()["moe"]
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    toks = jnp.asarray(
        rng.integers(0, cfg.vocab, (capture.DEMO_BATCH, capture.DEMO_SEQ)),
        jnp.int32,
    )
    batch = {"tokens": toks, "labels": toks}

    def loss(p, b):
        return api.loss(p, cfg, b)

    cf = capture.optimize(loss, interpret=True, label="moe-grouped")
    report = cf.report_for(params, batch)
    grouped = [s for s in report.sites if s.op == "grouped_dense"]
    assert grouped, report.to_json()
    assert all(s.dispatched for s in grouped), report.to_json()
    # the grouped sites ride ON TOP of the dense floor, not instead of it
    assert report.dispatched >= 10, report.to_json()

    ref = loss(params, batch)
    out = cf(params, batch)
    np.testing.assert_allclose(float(out), float(ref), rtol=2e-5, atol=2e-5)


def test_moe_grouped_gate_bitwise(monkeypatch):
    """REPRO_MOE_GROUPED=1 routes expert FFNs through grouped_dense with
    bitwise loss AND gradient parity against the einsum path (uniform
    (C,)*E groups, so the ragged kernel must reduce to the batched one)."""
    from repro import capture
    from repro.models.api import get_api

    cfg = capture.demo_configs()["moe"]
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.key(1))
    rng = np.random.default_rng(11)
    toks = jnp.asarray(
        rng.integers(0, cfg.vocab, (capture.DEMO_BATCH, capture.DEMO_SEQ)),
        jnp.int32,
    )
    batch = {"tokens": toks, "labels": toks}

    monkeypatch.delenv("REPRO_MOE_GROUPED", raising=False)
    ref = float(api.loss(params, cfg, batch))
    g_ref = jax.grad(lambda p: api.loss(p, cfg, batch))(params)
    monkeypatch.setenv("REPRO_MOE_GROUPED", "1")
    got = float(api.loss(params, cfg, batch))
    g_got = jax.grad(lambda p: api.loss(p, cfg, batch))(params)
    assert got == ref, f"grouped gate drifted: {got} != {ref}"
    for a, b in zip(jax.tree.leaves(g_got), jax.tree.leaves(g_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
