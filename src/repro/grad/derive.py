"""Backward ContractionSpecs by index calculus — grads as mapping problems.

For a sum-of-products contraction

    out[output] = sum_{reduce} prod_X X[axes_X]

the cotangent of operand ``W`` under upstream gradient ``g = d loss / d out``
is itself a sum-of-products contraction over the *same* index set:

    dW[axes_W] = sum_{indices - axes_W} g[output] * prod_{X != W} X[axes_X]

i.e. differentiation just moves ``W``'s axes to the output side and the
forward output's axes to an operand (the cotangent, named ``dout`` here).
For the canonical matmul this recovers the classical pair

    dA[i,j] = sum_k g[i,k] B[j,k]     (a transposed-operand GEMM — compare
    dB[j,k] = sum_i A[i,j] g[i,k]      ``core.enumerate.transposed_matmul_spec``)

and for ``chain_matmul`` it produces genuine three-operand contractions,
which is exactly the Linnea/LAMP observation that derived expressions are
mapping problems of their own: every derived spec re-enters the same
``search``/``codegen`` pipeline as the primal, with its own plan-DB and
autotune-cache keys (``name`` differs, so ``codegen.cache.spec_signature``
differs).

Consumers: ``grad.vjp`` (the custom_vjp backward passes),
``search.space.sweep_specs`` (``--with-grads`` sweeps) and the differential
test layer (``tests/test_grad.py``, ``tests/test_differential.py``).
"""

from __future__ import annotations

from typing import Dict

from ..core import enumerate as _enum
from ..core.enumerate import ContractionSpec

#: operand name carrying the upstream cotangent in every derived spec
COTANGENT = "dout"


def _check_differentiable(root: ContractionSpec) -> None:
    if root.reducer != "+":
        raise NotImplementedError(
            f"cannot derive gradients for reducer {root.reducer!r}; "
            "only '+' contractions are sum-of-products"
        )
    if root.scalar is not _enum._product_scalar:
        raise NotImplementedError(
            f"spec {root.name!r} has a custom scalar body; gradient "
            "derivation assumes the default product scalar"
        )
    if COTANGENT in root.operands:
        raise ValueError(
            f"operand name {COTANGENT!r} is reserved for the cotangent"
        )


def derived_spec(spec: ContractionSpec, wrt: str) -> ContractionSpec:
    """The backward contraction for ``d loss / d wrt`` of a forward spec.

    The result is a ROOT spec named ``<name>.d<wrt>`` whose operands are
    the cotangent (``dout``, carrying the forward output axes) followed by
    every forward operand except ``wrt`` in their original order, and whose
    output axes are ``wrt``'s axes in *storage* order — so the kernel's
    result drops straight into the cotangent slot with no transpose.
    """
    root = spec.root()
    _check_differentiable(root)
    if wrt not in root.operands:
        raise ValueError(
            f"unknown operand {wrt!r}; spec has {tuple(root.operands)}"
        )
    operands = {COTANGENT: root.output}
    for name, axes in root.operands.items():
        if name != wrt:
            operands[name] = axes
    covered = {i for axes in operands.values() for i in axes}
    missing = [i for i in root.operands[wrt] if i not in covered]
    if missing:
        # an index living only in `wrt` and reduced away forward would need
        # a broadcast (ones-expansion) backward; no current spec family
        # does this, so refuse loudly instead of silently mis-deriving
        raise NotImplementedError(
            f"index {missing} of {wrt!r} appears in no other operand nor "
            f"the output; its cotangent is a broadcast, not a contraction"
        )
    return ContractionSpec(
        name=f"{root.name}.d{wrt}",
        operands=operands,
        output=root.operands[wrt],
        extents=dict(root.extents),
        reducer=root.reducer,
    )


def derived_specs(spec: ContractionSpec) -> Dict[str, ContractionSpec]:
    """Backward specs for every operand: {operand name -> dX spec}."""
    root = spec.root()
    return {name: derived_spec(root, name) for name in root.operands}
