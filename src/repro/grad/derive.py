"""Backward ContractionSpecs by index calculus — grads as mapping problems.

For a sum-of-products contraction

    out[output] = sum_{reduce} prod_X X[axes_X]

the cotangent of operand ``W`` under upstream gradient ``g = d loss / d out``
is itself a sum-of-products contraction over the *same* index set:

    dW[axes_W] = sum_{indices - axes_W} g[output] * prod_{X != W} X[axes_X]

i.e. differentiation just moves ``W``'s axes to the output side and the
forward output's axes to an operand (the cotangent, named ``dout`` here).
For the canonical matmul this recovers the classical pair

    dA[i,j] = sum_k g[i,k] B[j,k]     (a transposed-operand GEMM — compare
    dB[j,k] = sum_i A[i,j] g[i,k]      ``core.enumerate.transposed_matmul_spec``)

and for ``chain_matmul`` it produces genuine three-operand contractions,
which is exactly the Linnea/LAMP observation that derived expressions are
mapping problems of their own: every derived spec re-enters the same
``search``/``codegen`` pipeline as the primal, with its own plan-DB and
autotune-cache keys (``name`` differs, so ``codegen.cache.spec_signature``
differs).

Consumers: ``grad.vjp`` (the custom_vjp backward passes),
``search.space.sweep_specs`` (``--with-grads`` sweeps) and the differential
test layer (``tests/test_grad.py``, ``tests/test_differential.py``).
"""

from __future__ import annotations

from typing import Dict

from ..core import enumerate as _enum
from ..core.enumerate import ContractionSpec

#: operand name carrying the upstream cotangent in every derived spec
COTANGENT = "dout"


def _check_differentiable(root: ContractionSpec) -> None:
    if root.reducer != "+":
        raise NotImplementedError(
            f"cannot derive gradients for reducer {root.reducer!r}; "
            "only '+' contractions are sum-of-products"
        )
    if root.scalar is not _enum._product_scalar:
        raise NotImplementedError(
            f"spec {root.name!r} has a custom scalar body; gradient "
            "derivation assumes the default product scalar"
        )
    if COTANGENT in root.operands:
        raise ValueError(
            f"operand name {COTANGENT!r} is reserved for the cotangent"
        )


def _fused_derived(root: ContractionSpec) -> Dict[str, ContractionSpec]:
    """Backward specs of the fused families.

    A fused forward is not a sum-of-products, so the generic index
    calculus does not apply; instead these are the GEMMs the fused
    custom VJPs (``grad.vjp.attention_vjp`` / ``grouped_vjp``) actually
    execute, each a first-class spec with its own plan-DB/autotune key:

    attention (dS = P∘(dP − D) computed elementwise in the VJP):
        dQ[h,s,d] = Σ_t dS[h,s,t] K[h,t,d]   (``dout`` carries dS)
        dK[h,t,d] = Σ_s dS[h,s,t] Q[h,s,d]
        dV[h,t,e] = Σ_s  P[h,s,t] g[h,s,e]   (``dout`` carries g)
    grouped_matmul (both still ragged — GroupedSpecs with the same
    ``group_sizes``, lowered by the same group-offset kernel modes):
        dX[n,k]   = Σ_f g[n,f] W[group(n),k,f]
        dW[g,k,f] = Σ_{n∈group g} X[n,k] g[n,f]
    """
    kind = root.fused_kind
    ex = root.extents
    if kind == "attention":
        h, s, t = ex["h"], ex["s"], ex["t"]
        d, e = ex["d"], ex["e"]
        return {
            "Q": ContractionSpec(
                name="attention.dQ",
                operands={COTANGENT: ("h", "s", "t"), "K": ("h", "t", "d")},
                output=("h", "s", "d"),
                extents={"h": h, "s": s, "t": t, "d": d},
            ),
            "K": ContractionSpec(
                name="attention.dK",
                operands={COTANGENT: ("h", "s", "t"), "Q": ("h", "s", "d")},
                output=("h", "t", "d"),
                extents={"h": h, "s": s, "t": t, "d": d},
            ),
            "V": ContractionSpec(
                name="attention.dV",
                operands={COTANGENT: ("h", "s", "e"), "P": ("h", "s", "t")},
                output=("h", "t", "e"),
                extents={"h": h, "s": s, "t": t, "e": e},
            ),
        }
    if kind == "grouped_matmul":
        from ..core.enumerate import GroupedSpec

        sizes = root.group_sizes
        return {
            "X": GroupedSpec(
                name="grouped_matmul.dX",
                operands={COTANGENT: ("n", "f"), "W": ("g", "k", "f")},
                output=("n", "k"),
                extents=dict(ex),
                group_sizes=sizes,
            ),
            "W": GroupedSpec(
                name="grouped_matmul.dW",
                operands={COTANGENT: ("n", "f"), "X": ("n", "k")},
                output=("g", "k", "f"),
                extents=dict(ex),
                group_sizes=sizes,
            ),
        }
    raise NotImplementedError(f"no derived specs for fused kind {kind!r}")


def derived_spec(spec: ContractionSpec, wrt: str) -> ContractionSpec:
    """The backward contraction for ``d loss / d wrt`` of a forward spec.

    The result is a ROOT spec named ``<name>.d<wrt>`` whose operands are
    the cotangent (``dout``, carrying the forward output axes) followed by
    every forward operand except ``wrt`` in their original order, and whose
    output axes are ``wrt``'s axes in *storage* order — so the kernel's
    result drops straight into the cotangent slot with no transpose.

    Fused families (``fused_kind`` set) branch to ``_fused_derived`` —
    their backward contractions are hand-derived, not index calculus.
    """
    root = spec.root()
    if getattr(root, "fused_kind", ""):
        fused = _fused_derived(root)
        if wrt not in fused:
            raise ValueError(
                f"unknown operand {wrt!r}; spec has {tuple(root.operands)}"
            )
        return fused[wrt]
    _check_differentiable(root)
    if wrt not in root.operands:
        raise ValueError(
            f"unknown operand {wrt!r}; spec has {tuple(root.operands)}"
        )
    operands = {COTANGENT: root.output}
    for name, axes in root.operands.items():
        if name != wrt:
            operands[name] = axes
    covered = {i for axes in operands.values() for i in axes}
    missing = [i for i in root.operands[wrt] if i not in covered]
    if missing:
        # an index living only in `wrt` and reduced away forward would need
        # a broadcast (ones-expansion) backward; no current spec family
        # does this, so refuse loudly instead of silently mis-deriving
        raise NotImplementedError(
            f"index {missing} of {wrt!r} appears in no other operand nor "
            f"the output; its cotangent is a broadcast, not a contraction"
        )
    return ContractionSpec(
        name=f"{root.name}.d{wrt}",
        operands=operands,
        output=root.operands[wrt],
        extents=dict(root.extents),
        reducer=root.reducer,
    )


def derived_specs(spec: ContractionSpec) -> Dict[str, ContractionSpec]:
    """Backward specs for every operand: {operand name -> dX spec}."""
    root = spec.root()
    return {name: derived_spec(root, name) for name in root.operands}
