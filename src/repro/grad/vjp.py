"""custom_vjp wrappers: both primal and cotangent GEMMs through codegen.

``jax.value_and_grad`` cannot differentiate ``pallas_call``, so before this
module existed training either broke on TPU or silently bypassed the
searched/tuned kernels for the backward GEMMs — the majority of training
FLOPs.  Each wrapper here pairs an ``ops`` primal with a hand-derived VJP
whose GEMMs are the *derived ContractionSpecs* of ``grad.derive``, lowered
through the very same pipeline as the forward pass
(``ops._tuned_kernel``: ranked plan DB first, persistent autotune cache
second).  A ``scripts/search_sweep.py --with-grads`` run therefore upgrades
forward and backward kernels together.

Wrappers are built by memoized factories keyed on the static call
parameters (dtype, interpret, epilogue config); the array-shape dispatch
(kernel vs ``lax``/einsum fallback) happens at trace time inside fwd/bwd,
mirroring the corresponding ``ops`` entry point exactly.  Cotangents are
cast to their primal operand's dtype, so mixed-precision training sees
bf16 backward GEMMs with f32 accumulation, like the forward.

Consumed by ``repro.ops`` (``differentiable=True`` default) and hence by
``launch.steps.make_train_step`` — training needs no dot_general fallback.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..core.enumerate import einsum_formula
from .derive import COTANGENT, derived_specs


def apply_spec(
    spec,
    arrays: Dict[str, jax.Array],
    *,
    out_dtype,
    interpret: bool = False,
    use_kernel: bool = False,
):
    """Evaluate ``spec`` over named arrays — generated kernel or einsum.

    The kernel path is the exact ``ops._tuned_kernel`` pipeline the primal
    uses, keyed by this (possibly derived) spec, so backward GEMMs acquire
    their own plan-DB / autotune-cache entries.  The fallback is an einsum
    with f32 accumulation, matching the non-TPU primal paths.
    """
    if use_kernel:
        from ..ops import _tuned_kernel

        first = next(iter(spec.operands))
        kern = _tuned_kernel(
            spec, arrays[first].dtype, interpret=interpret
        )
        return kern(*(arrays[n] for n in spec.operands)).astype(out_dtype)
    return jnp.einsum(
        einsum_formula(spec),
        *(arrays[n] for n in spec.operands),
        preferred_element_type=jnp.float32,
    ).astype(out_dtype)


def _cotangent_gemms(spec, g, operands, *, interpret, use_kernel):
    """All operand cotangents of ``spec`` via its derived backward specs."""
    out = {}
    for wrt, dspec in derived_specs(spec).items():
        arrays = {COTANGENT: g.astype(operands[wrt].dtype)}
        for name, arr in operands.items():
            if name != wrt:
                arrays[name] = arr
        out[wrt] = apply_spec(
            dspec,
            arrays,
            out_dtype=operands[wrt].dtype,
            interpret=interpret,
            use_kernel=use_kernel,
        )
    return out


# ---------------------------------------------------------------------------
# per-op factories (lru_cache => one custom_vjp object per static config)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def dense_vjp(out_dtype: str, interpret: bool):
    """(..., D) @ (D, F) with backward dA/dB through derived-spec kernels."""
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(x, w):
        from .. import ops

        return ops._dense_raw(x, w, out_dt, interpret)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        if x.ndim != 2:
            # the primal lowered to lax.dot over the flattened batch; keep
            # the classical batched VJP here (still f32-accumulated)
            dx = jnp.einsum(
                "...f,df->...d", g, w, preferred_element_type=jnp.float32
            )
            dw = jnp.einsum(
                "...d,...f->df", x, g, preferred_element_type=jnp.float32
            )
            return dx.astype(x.dtype), dw.astype(w.dtype)
        from .. import ops
        from ..core.enumerate import matmul_spec

        m, d = x.shape
        _, fdim = w.shape
        spec = matmul_spec(m, d, fdim)
        cots = _cotangent_gemms(
            spec, g, {"A": x, "B": w},
            interpret=interpret,
            use_kernel=ops._dense_kernel_ok(x, w, interpret),
        )
        return cots["A"], cots["B"]

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def batched_dense_vjp(out_dtype: str, interpret: bool):
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(x, w):
        from .. import ops

        return ops._batched_dense_raw(x, w, out_dt, interpret)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, g):
        from .. import ops
        from ..core.enumerate import batched_matmul_spec

        x, w = res
        b, m, d = x.shape
        _, _, fdim = w.shape
        spec = batched_matmul_spec(b, m, d, fdim)
        cots = _cotangent_gemms(
            spec, g, {"A": x, "B": w},
            interpret=interpret,
            use_kernel=ops._batched_kernel_ok(x, w, interpret),
        )
        return cots["A"], cots["B"]

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def weighted_dense_vjp(out_dtype: str, interpret: bool):
    """sum_j x_ij w_jk g_j with every cotangent a derived-spec contraction.

    dg is the interesting one: a three-operand contraction over (i, k)
    producing a vector — derived mechanically like every other backward
    spec, and swept/tuned under its own ``weighted_matmul.dg`` key.
    """
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(x, w, g):
        from .. import ops

        return ops._weighted_dense_raw(x, w, g, out_dt, interpret)

    def fwd(x, w, g):
        return f(x, w, g), (x, w, g)

    def bwd(res, grad_out):
        from .. import ops
        from ..core.enumerate import weighted_matmul_spec

        x, w, g = res
        m, d = x.shape
        _, fdim = w.shape
        spec = weighted_matmul_spec(m, d, fdim)
        cots = _cotangent_gemms(
            spec, grad_out, {"A": x, "B": w, "g": g},
            interpret=interpret,
            use_kernel=ops._weighted_kernel_ok(x, interpret),
        )
        return cots["A"], cots["B"], cots["g"]

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def chain_dense_vjp(out_dtype: str, interpret: bool):
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(a, b, c):
        from .. import ops

        return ops._chain_dense_raw(a, b, c, out_dt, interpret)

    def fwd(a, b, c):
        return f(a, b, c), (a, b, c)

    def bwd(res, g):
        from .. import ops
        from ..core.enumerate import chain_matmul_spec

        a, b, c = res
        m, k1 = a.shape
        _, k2 = b.shape
        _, n = c.shape
        spec = chain_matmul_spec(m, k1, k2, n)
        cots = _cotangent_gemms(
            spec, g, {"A": a, "B": b, "C": c},
            interpret=interpret,
            use_kernel=ops._generic_kernel_ok(interpret),
        )
        return cots["A"], cots["B"], cots["C"]

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def dense_transposed_vjp(out_dtype: str, interpret: bool):
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(a, b):
        from .. import ops

        return ops._dense_transposed_raw(a, b, out_dt, interpret)

    def fwd(a, b):
        return f(a, b), (a, b)

    def bwd(res, g):
        from .. import ops
        from ..core.enumerate import transposed_matmul_spec

        a, b = res
        d, m = a.shape
        _, fdim = b.shape
        spec = transposed_matmul_spec(m, d, fdim)
        cots = _cotangent_gemms(
            spec, g, {"A": a, "B": b},
            interpret=interpret,
            use_kernel=ops._generic_kernel_ok(interpret),
        )
        return cots["A"], cots["B"]

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def attention_vjp(causal: bool, out_dtype: str, interpret: bool):
    """Fused attention with a flash-style recompute backward.

    The forward (``ops._attention_raw``) never materializes the (s, t)
    probability matrix; the backward recomputes scores -> P in f32, forms
    dS = P∘(dP − D) elementwise, then routes the three surviving GEMMs
    through the hand-derived fused specs (``attention.dQ/.dK/.dV`` —
    ``grad.derive._fused_derived``), each with its own plan-DB/autotune
    key.  Only the ``kv_lengths=None`` call sites wrap in this vjp; the
    ragged-lengths path stays on the natively-differentiable jnp
    reference (integer lengths make a poor custom_vjp residual).
    """
    import math

    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(q, k, v):
        from .. import ops

        return ops._attention_raw(
            q, k, v, causal=causal, kv_lengths=None,
            out_dtype=out_dt, interpret=interpret,
        )

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        from .. import ops
        from ..core.enumerate import attention_spec

        q, k, v = res
        h, s, d = q.shape
        t = k.shape[1]
        e = v.shape[2]
        spec = attention_spec(h, s, t, d, e=e, causal=causal)
        dsp = derived_specs(spec)
        use_kernel = ops._attention_kernel_ok(q, interpret)
        scale = 1.0 / math.sqrt(d)

        sc = jnp.einsum(
            "hsd,htd->hst", q, k, preferred_element_type=jnp.float32
        ) * scale
        if causal:
            row = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
            col = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 2)
            sc = jnp.where(col <= row, sc, -jnp.inf)
        # every row keeps its diagonal under the causal mask, so the max
        # is finite and the softmax denominator is strictly positive
        m = jnp.max(sc, axis=-1, keepdims=True)
        p = jnp.exp(sc - m)
        big_p = p / jnp.sum(p, axis=-1, keepdims=True)

        gf = g.astype(jnp.float32)
        dv = apply_spec(
            dsp["V"],
            {COTANGENT: g.astype(v.dtype), "P": big_p.astype(v.dtype)},
            out_dtype=v.dtype, interpret=interpret, use_kernel=use_kernel,
        )
        dp = jnp.einsum(
            "hse,hte->hst", gf, v.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        dterm = jnp.sum(dp * big_p, axis=-1, keepdims=True)
        ds = big_p * (dp - dterm) * scale
        dq = apply_spec(
            dsp["Q"], {COTANGENT: ds.astype(q.dtype), "K": k},
            out_dtype=q.dtype, interpret=interpret, use_kernel=use_kernel,
        )
        dk = apply_spec(
            dsp["K"], {COTANGENT: ds.astype(k.dtype), "Q": q},
            out_dtype=k.dtype, interpret=interpret, use_kernel=use_kernel,
        )
        return dq, dk, dv

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def grouped_vjp(group_sizes: tuple, out_dtype: str, interpret: bool):
    """Ragged grouped GEMM: backward stays ragged, never sums over groups.

    Both cotangents are GroupedSpecs with the same ``group_sizes``
    (``grouped_matmul.dX/.dW``), lowered by the same group-offset kernel
    modes as the forward.  The generic einsum fallback of ``apply_spec``
    would be *wrong* here (a plain einsum sums over the group axis), so
    the non-kernel path is an explicit per-group loop.
    """
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(x, w):
        from .. import ops

        return ops._grouped_raw(x, w, group_sizes, out_dt, interpret)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, g):
        from .. import ops
        from ..core.enumerate import grouped_matmul_spec

        x, w = res
        n, kdim = x.shape
        _, _, fdim = w.shape
        if n and ops._grouped_kernel_ok(x, interpret):
            spec = grouped_matmul_spec(group_sizes, kdim, fdim)
            dsp = derived_specs(spec)
            dx = apply_spec(
                dsp["X"], {COTANGENT: g.astype(x.dtype), "W": w},
                out_dtype=x.dtype, interpret=interpret, use_kernel=True,
            )
            dw = apply_spec(
                dsp["W"], {COTANGENT: g.astype(w.dtype), "X": x},
                out_dtype=w.dtype, interpret=interpret, use_kernel=True,
            )
            return dx, dw
        gf = g.astype(jnp.float32)
        xf = x.astype(jnp.float32)
        dx_parts, dw_parts = [], []
        off = 0
        for gi, size in enumerate(group_sizes):
            wg = w[gi].astype(jnp.float32)
            if size:
                dx_parts.append(gf[off:off + size] @ wg.T)
                dw_parts.append(xf[off:off + size].T @ gf[off:off + size])
            else:
                dw_parts.append(jnp.zeros_like(wg))
            off += size
        dx = (
            jnp.concatenate(dx_parts, axis=0)
            if dx_parts else jnp.zeros((n, kdim), jnp.float32)
        )
        dw = jnp.stack(dw_parts, axis=0)
        return dx.astype(x.dtype), dw.astype(w.dtype)

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def dense_act_vjp(act: str, eps: float, out_dtype: str, interpret: bool):
    """Fused dense+bias+norm+act with an epilogue-aware backward.

    The fused forward never materializes the pre-epilogue accumulator, so
    the backward *recomputes* it with one extra GEMM (same spec => same
    plan/cache entry as the primal), runs the elementwise epilogue VJP on
    it via ``jax.vjp`` of ``codegen.Epilogue.apply``, then routes the
    resulting dacc through the derived dA/dB GEMM specs.
    """
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(x, w, beta, mean, var):
        from .. import ops

        return ops._dense_act_raw(
            x, w, beta, mean, var, act=act, eps=eps,
            out_dtype=out_dt, interpret=interpret,
        )

    def fwd(x, w, beta, mean, var):
        return f(x, w, beta, mean, var), (x, w, beta, mean, var)

    def bwd(res, g):
        from .. import ops
        from ..codegen.epilogue import Epilogue
        from ..core.enumerate import matmul_spec

        x, w, beta, mean, var = res
        m, d = x.shape
        _, fdim = w.shape
        spec = matmul_spec(m, d, fdim)
        use_kernel = ops._generic_kernel_ok(interpret)

        acc = apply_spec(
            spec, {"A": x, "B": w},
            out_dtype=jnp.float32, interpret=interpret,
            use_kernel=use_kernel,
        )
        epi = Epilogue(act=act, bias=True, norm=True, eps=eps)

        def epi_fn(acc_, beta_, mean_, var_):
            vectors = {
                "bias": beta_.astype(jnp.float32).reshape(1, -1),
                "mean": mean_.astype(jnp.float32).reshape(1, -1),
                "var": var_.astype(jnp.float32).reshape(1, -1),
            }
            return epi.apply(acc_, vectors)

        _, epi_vjp = jax.vjp(epi_fn, acc, beta, mean, var)
        dacc, dbeta, dmean, dvar = epi_vjp(g.astype(jnp.float32))
        cots = _cotangent_gemms(
            spec, dacc, {"A": x, "B": w},
            interpret=interpret, use_kernel=use_kernel,
        )
        return (
            cots["A"],
            cots["B"],
            dbeta.astype(beta.dtype),
            dmean.astype(mean.dtype),
            dvar.astype(var.dtype),
        )

    f.defvjp(fwd, bwd)
    return f
