"""custom_vjp wrappers: both primal and cotangent GEMMs through codegen.

``jax.value_and_grad`` cannot differentiate ``pallas_call``, so before this
module existed training either broke on TPU or silently bypassed the
searched/tuned kernels for the backward GEMMs — the majority of training
FLOPs.  Each wrapper here pairs an ``ops`` primal with a hand-derived VJP
whose GEMMs are the *derived ContractionSpecs* of ``grad.derive``, lowered
through the very same pipeline as the forward pass
(``ops._tuned_kernel``: ranked plan DB first, persistent autotune cache
second).  A ``scripts/search_sweep.py --with-grads`` run therefore upgrades
forward and backward kernels together.

Wrappers are built by memoized factories keyed on the static call
parameters (dtype, interpret, epilogue config); the array-shape dispatch
(kernel vs ``lax``/einsum fallback) happens at trace time inside fwd/bwd,
mirroring the corresponding ``ops`` entry point exactly.  Cotangents are
cast to their primal operand's dtype, so mixed-precision training sees
bf16 backward GEMMs with f32 accumulation, like the forward.

Consumed by ``repro.ops`` (``differentiable=True`` default) and hence by
``launch.steps.make_train_step`` — training needs no dot_general fallback.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..core.enumerate import einsum_formula
from .derive import COTANGENT, derived_specs


def apply_spec(
    spec,
    arrays: Dict[str, jax.Array],
    *,
    out_dtype,
    interpret: bool = False,
    use_kernel: bool = False,
):
    """Evaluate ``spec`` over named arrays — generated kernel or einsum.

    The kernel path is the exact ``ops._tuned_kernel`` pipeline the primal
    uses, keyed by this (possibly derived) spec, so backward GEMMs acquire
    their own plan-DB / autotune-cache entries.  The fallback is an einsum
    with f32 accumulation, matching the non-TPU primal paths.
    """
    if use_kernel:
        from ..ops import _tuned_kernel

        first = next(iter(spec.operands))
        kern = _tuned_kernel(
            spec, arrays[first].dtype, interpret=interpret
        )
        return kern(*(arrays[n] for n in spec.operands)).astype(out_dtype)
    return jnp.einsum(
        einsum_formula(spec),
        *(arrays[n] for n in spec.operands),
        preferred_element_type=jnp.float32,
    ).astype(out_dtype)


def _cotangent_gemms(spec, g, operands, *, interpret, use_kernel):
    """All operand cotangents of ``spec`` via its derived backward specs."""
    out = {}
    for wrt, dspec in derived_specs(spec).items():
        arrays = {COTANGENT: g.astype(operands[wrt].dtype)}
        for name, arr in operands.items():
            if name != wrt:
                arrays[name] = arr
        out[wrt] = apply_spec(
            dspec,
            arrays,
            out_dtype=operands[wrt].dtype,
            interpret=interpret,
            use_kernel=use_kernel,
        )
    return out


# ---------------------------------------------------------------------------
# per-op factories (lru_cache => one custom_vjp object per static config)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def dense_vjp(out_dtype: str, interpret: bool):
    """(..., D) @ (D, F) with backward dA/dB through derived-spec kernels."""
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(x, w):
        from .. import ops

        return ops._dense_raw(x, w, out_dt, interpret)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        if x.ndim != 2:
            # the primal lowered to lax.dot over the flattened batch; keep
            # the classical batched VJP here (still f32-accumulated)
            dx = jnp.einsum(
                "...f,df->...d", g, w, preferred_element_type=jnp.float32
            )
            dw = jnp.einsum(
                "...d,...f->df", x, g, preferred_element_type=jnp.float32
            )
            return dx.astype(x.dtype), dw.astype(w.dtype)
        from .. import ops
        from ..core.enumerate import matmul_spec

        m, d = x.shape
        _, fdim = w.shape
        spec = matmul_spec(m, d, fdim)
        cots = _cotangent_gemms(
            spec, g, {"A": x, "B": w},
            interpret=interpret,
            use_kernel=ops._dense_kernel_ok(x, w, interpret),
        )
        return cots["A"], cots["B"]

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def batched_dense_vjp(out_dtype: str, interpret: bool):
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(x, w):
        from .. import ops

        return ops._batched_dense_raw(x, w, out_dt, interpret)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, g):
        from .. import ops
        from ..core.enumerate import batched_matmul_spec

        x, w = res
        b, m, d = x.shape
        _, _, fdim = w.shape
        spec = batched_matmul_spec(b, m, d, fdim)
        cots = _cotangent_gemms(
            spec, g, {"A": x, "B": w},
            interpret=interpret,
            use_kernel=ops._batched_kernel_ok(x, w, interpret),
        )
        return cots["A"], cots["B"]

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def weighted_dense_vjp(out_dtype: str, interpret: bool):
    """sum_j x_ij w_jk g_j with every cotangent a derived-spec contraction.

    dg is the interesting one: a three-operand contraction over (i, k)
    producing a vector — derived mechanically like every other backward
    spec, and swept/tuned under its own ``weighted_matmul.dg`` key.
    """
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(x, w, g):
        from .. import ops

        return ops._weighted_dense_raw(x, w, g, out_dt, interpret)

    def fwd(x, w, g):
        return f(x, w, g), (x, w, g)

    def bwd(res, grad_out):
        from .. import ops
        from ..core.enumerate import weighted_matmul_spec

        x, w, g = res
        m, d = x.shape
        _, fdim = w.shape
        spec = weighted_matmul_spec(m, d, fdim)
        cots = _cotangent_gemms(
            spec, grad_out, {"A": x, "B": w, "g": g},
            interpret=interpret,
            use_kernel=ops._weighted_kernel_ok(x, interpret),
        )
        return cots["A"], cots["B"], cots["g"]

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def chain_dense_vjp(out_dtype: str, interpret: bool):
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(a, b, c):
        from .. import ops

        return ops._chain_dense_raw(a, b, c, out_dt, interpret)

    def fwd(a, b, c):
        return f(a, b, c), (a, b, c)

    def bwd(res, g):
        from .. import ops
        from ..core.enumerate import chain_matmul_spec

        a, b, c = res
        m, k1 = a.shape
        _, k2 = b.shape
        _, n = c.shape
        spec = chain_matmul_spec(m, k1, k2, n)
        cots = _cotangent_gemms(
            spec, g, {"A": a, "B": b, "C": c},
            interpret=interpret,
            use_kernel=ops._generic_kernel_ok(interpret),
        )
        return cots["A"], cots["B"], cots["C"]

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def dense_transposed_vjp(out_dtype: str, interpret: bool):
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(a, b):
        from .. import ops

        return ops._dense_transposed_raw(a, b, out_dt, interpret)

    def fwd(a, b):
        return f(a, b), (a, b)

    def bwd(res, g):
        from .. import ops
        from ..core.enumerate import transposed_matmul_spec

        a, b = res
        d, m = a.shape
        _, fdim = b.shape
        spec = transposed_matmul_spec(m, d, fdim)
        cots = _cotangent_gemms(
            spec, g, {"A": a, "B": b},
            interpret=interpret,
            use_kernel=ops._generic_kernel_ok(interpret),
        )
        return cots["A"], cots["B"]

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def dense_act_vjp(act: str, eps: float, out_dtype: str, interpret: bool):
    """Fused dense+bias+norm+act with an epilogue-aware backward.

    The fused forward never materializes the pre-epilogue accumulator, so
    the backward *recomputes* it with one extra GEMM (same spec => same
    plan/cache entry as the primal), runs the elementwise epilogue VJP on
    it via ``jax.vjp`` of ``codegen.Epilogue.apply``, then routes the
    resulting dacc through the derived dA/dB GEMM specs.
    """
    out_dt = np.dtype(out_dtype)

    @jax.custom_vjp
    def f(x, w, beta, mean, var):
        from .. import ops

        return ops._dense_act_raw(
            x, w, beta, mean, var, act=act, eps=eps,
            out_dtype=out_dt, interpret=interpret,
        )

    def fwd(x, w, beta, mean, var):
        return f(x, w, beta, mean, var), (x, w, beta, mean, var)

    def bwd(res, g):
        from .. import ops
        from ..codegen.epilogue import Epilogue
        from ..core.enumerate import matmul_spec

        x, w, beta, mean, var = res
        m, d = x.shape
        _, fdim = w.shape
        spec = matmul_spec(m, d, fdim)
        use_kernel = ops._generic_kernel_ok(interpret)

        acc = apply_spec(
            spec, {"A": x, "B": w},
            out_dtype=jnp.float32, interpret=interpret,
            use_kernel=use_kernel,
        )
        epi = Epilogue(act=act, bias=True, norm=True, eps=eps)

        def epi_fn(acc_, beta_, mean_, var_):
            vectors = {
                "bias": beta_.astype(jnp.float32).reshape(1, -1),
                "mean": mean_.astype(jnp.float32).reshape(1, -1),
                "var": var_.astype(jnp.float32).reshape(1, -1),
            }
            return epi.apply(acc_, vectors)

        _, epi_vjp = jax.vjp(epi_fn, acc, beta, mean, var)
        dacc, dbeta, dmean, dvar = epi_vjp(g.astype(jnp.float32))
        cots = _cotangent_gemms(
            spec, dacc, {"A": x, "B": w},
            interpret=interpret, use_kernel=use_kernel,
        )
        return (
            cots["A"],
            cots["B"],
            dbeta.astype(beta.dtype),
            dmean.astype(mean.dtype),
            dvar.astype(var.dtype),
        )

    f.defvjp(fwd, bwd)
    return f
