"""repro.grad — differentiable generated kernels.

The paper's thesis is that one pattern formalism covers *every* dense
contraction in a workload; before this package only the forward pass did.
``grad`` closes the training half:

  ``derive``   backward ContractionSpecs by index calculus — for each
               operand ``W`` of a forward spec, ``dW`` is itself a
               sum-of-products contraction (dA = g·Bᵀ, dB = Aᵀ·g for the
               matmul; three-operand contractions for the chain), named
               ``<spec>.d<W>`` so it owns plan-DB/autotune-cache keys.
  ``vjp``      ``jax.custom_vjp`` wrappers pairing every ``ops`` primal
               with a backward pass whose cotangent GEMMs compile through
               the same ``ContractionSpec -> search/plan DB -> codegen``
               pipeline as the forward kernels.

``ops`` routes through these wrappers by default (``differentiable=True``),
so ``jax.grad`` of a loss built on ``ops.dense``/``ops.dense_act`` works on
TPU with generated kernels on both sides of the tape — see
``launch.steps.make_train_step``.  Sweeping backward specs alongside the
forward: ``search.search_schedule_with_grads`` /
``scripts/search_sweep.py --with-grads``.
"""

from .derive import COTANGENT, derived_spec, derived_specs
from .vjp import (
    apply_spec,
    attention_vjp,
    batched_dense_vjp,
    chain_dense_vjp,
    dense_act_vjp,
    dense_transposed_vjp,
    dense_vjp,
    grouped_vjp,
    weighted_dense_vjp,
)

__all__ = [
    "COTANGENT",
    "apply_spec",
    "attention_vjp",
    "batched_dense_vjp",
    "chain_dense_vjp",
    "dense_act_vjp",
    "dense_transposed_vjp",
    "dense_vjp",
    "derived_spec",
    "derived_specs",
    "grouped_vjp",
    "weighted_dense_vjp",
]
