"""repro.obs — zero-dependency observability: tracing, metrics, explain.

The search/codegen/serve pipeline makes its decisions (beam cuts, plan-DB
picks, dispatch-vs-fallback, collective choice) from cost terms it used to
throw away after the fact.  This package keeps them visible, in three
layers that the rest of the repo reports through:

* ``obs.trace`` — nestable spans (``with span("search.beam"): ...``) with
  a thread-local stack and Chrome-trace/Perfetto JSON export.  Load the
  dump at ``chrome://tracing`` / https://ui.perfetto.dev, or summarize it
  with ``scripts/obs_report.py --trace out.json``.
* ``obs.metrics`` — a process-global registry of counters, gauges and
  exact-value histograms (p50/p99) wired into the pipeline's previously
  unsurfaced counters: autotune/plan-DB hits and misses, capture dispatch
  per site, beam candidates/cuts, collective picks, per-request serve
  latency, straggler-watchdog step times.  ``dump()``/``to_json()``
  serialize; ``serve --metrics-out FILE`` writes one per run.
* ``obs.explain`` — renders the per-candidate roofline terms the search
  persists into plan-DB entries (``scripts/obs_report.py --explain``).

``obs.log`` is the structured stdout logger the ad-hoc ``print()``s moved
to; it honors ``REPRO_LOG=quiet|info|debug`` and keeps the human-readable
lines byte-identical at the default level.

Everything is a strict no-op when ``REPRO_OBS=0`` (on by default): spans
cost one dict lookup and record nothing, metric handles are a shared
do-nothing singleton, and the registry stays empty.  The bench gate
``obs.overhead`` (``benchmarks/kernel_bench.py``) holds the obs-on/off
ratio of a hot kernel call at <= 1.02.

Stdlib-only by design — ``runtime.fault`` (no jax imports) and the test
harness use it too.
"""

from __future__ import annotations

import os

__all__ = [
    "enabled",
    "span",
    "complete_event",
    "trace_events",
    "trace_json",
    "trace_dump",
    "trace_reset",
    "counter",
    "gauge",
    "histogram",
    "metrics_json",
    "metrics_dump",
    "metrics_reset",
    "registry",
]


def enabled() -> bool:
    """Observability master switch — ``REPRO_OBS=0`` turns it all off.

    Read from the environment on every call (it is one dict lookup) so
    tests can flip it per-case without reloading modules.
    """
    return os.environ.get("REPRO_OBS", "1") != "0"


from .metrics import (  # noqa: E402
    counter,
    gauge,
    histogram,
    metrics_dump,
    metrics_json,
    metrics_reset,
    registry,
)
from .trace import (  # noqa: E402
    complete_event,
    span,
    trace_dump,
    trace_events,
    trace_json,
    trace_reset,
)
