"""Process-global metrics registry — counters, gauges, exact histograms.

Handles are cheap and idempotent::

    from repro import obs

    obs.counter("plandb.hit").inc()
    obs.gauge("serve.tok_per_s").set(123.4)
    obs.histogram("serve.request_latency_s").observe(0.017)

``metrics_json()`` serializes the whole registry (histograms as
count/sum/min/max/p50/p99); ``metrics_dump(path)`` writes it, and
``scripts/obs_report.py --metrics`` pretty-prints + schema-checks a dump.

Histograms store exact values (these are offline/serving-smoke scale, not
per-packet scale), so ``percentile`` matches ``numpy.percentile``'s default
linear interpolation bit-for-bit — asserted in ``tests/test_obs.py``.

With ``REPRO_OBS=0`` the module helpers return one shared do-nothing
handle and never touch the registry, so it stays empty — the no-op
contract ``tests/test_obs.py`` pins.
"""

from __future__ import annotations

import json
import math
import os
import threading
from typing import Any, Dict, List, Optional


class Counter:
    """Monotone integer count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-written float value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Exact-value distribution with numpy-compatible percentiles."""

    __slots__ = ("name", "values")

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def sum(self) -> float:
        return float(sum(self.values))

    def percentile(self, p: float) -> Optional[float]:
        """p-th percentile, numpy default (linear) interpolation; None if
        empty."""
        if not self.values:
            return None
        xs = sorted(self.values)
        if len(xs) == 1:
            return xs[0]
        rank = (p / 100.0) * (len(xs) - 1)
        lo = math.floor(rank)
        hi = min(lo + 1, len(xs) - 1)
        frac = rank - lo
        return xs[lo] + frac * (xs[hi] - xs[lo])

    def summary(self) -> Dict[str, Any]:
        if not self.values:
            return {"count": 0, "sum": 0.0}
        return {
            "count": self.count,
            "sum": self.sum,
            "min": min(self.values),
            "max": max(self.values),
            "p50": self.percentile(50),
            "p99": self.percentile(99),
        }


class _Noop:
    """Shared do-nothing handle for every metric kind when obs is off."""

    __slots__ = ()
    name = "noop"
    value = 0
    values: List[float] = []
    count = 0
    sum = 0.0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def percentile(self, p: float) -> Optional[float]:
        return None

    def summary(self) -> Dict[str, Any]:
        return {"count": 0, "sum": 0.0}


_NOOP = _Noop()


class Registry:
    """Name -> metric map; one per process (module-level ``_REGISTRY``)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def to_json(self) -> Dict[str, Any]:
        """``{"counters": {...}, "gauges": {...}, "histograms": {...}}``."""
        out: Dict[str, Dict[str, Any]] = {
            "counters": {},
            "gauges": {},
            "histograms": {},
        }
        with self._lock:
            items = sorted(self._metrics.items())
        for name, m in items:
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            elif isinstance(m, Histogram):
                out["histograms"][name] = m.summary()
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


_REGISTRY = Registry()


def registry() -> Registry:
    """The process-global registry (mostly for tests / reports)."""
    return _REGISTRY


def counter(name: str):
    """Counter handle, or the shared no-op when ``REPRO_OBS=0``."""
    from . import enabled

    if not enabled():
        return _NOOP
    return _REGISTRY.counter(name)


def gauge(name: str):
    """Gauge handle, or the shared no-op when ``REPRO_OBS=0``."""
    from . import enabled

    if not enabled():
        return _NOOP
    return _REGISTRY.gauge(name)


def histogram(name: str):
    """Histogram handle, or the shared no-op when ``REPRO_OBS=0``."""
    from . import enabled

    if not enabled():
        return _NOOP
    return _REGISTRY.histogram(name)


def metrics_json() -> Dict[str, Any]:
    return _REGISTRY.to_json()


def metrics_dump(path: str) -> str:
    """Write the registry snapshot as JSON to ``path``; returns the path."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(metrics_json(), f, indent=1, sort_keys=True)
    return path


def metrics_reset() -> None:
    _REGISTRY.reset()
