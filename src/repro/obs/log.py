"""Structured stdout logger — where the ad-hoc ``print()``s moved to.

One line per call, ``[component] message`` (or bare ``message`` with no
component), so the human-readable output is byte-identical to the old
prints at the default level — existing smoke greps keep working.  The
level comes from ``REPRO_LOG``:

    quiet   nothing
    info    the default — what the old prints showed
    debug   info + debug() lines (per-step serve timings etc.)

Unknown values fall back to ``info``.  The level is re-read per call so a
test (or an operator mid-run via a wrapper) can flip it without reloads.
This is deliberately not ``logging``: no handlers, no formatters, no
global mutable config a library import could clobber — serving smoke
output must stay exactly what it was.
"""

from __future__ import annotations

import os
from typing import Optional

_LEVELS = {"quiet": 0, "info": 1, "debug": 2}


def level() -> int:
    """Numeric level from ``REPRO_LOG`` (default info)."""
    return _LEVELS.get(os.environ.get("REPRO_LOG", "info"), 1)


def _emit(component: Optional[str], msg: str, **kw) -> None:
    if component:
        print(f"[{component}] {msg}", **kw)
    else:
        print(msg, **kw)


def info(component: Optional[str], msg: str, *, flush: bool = False) -> None:
    """Default-level line; shown unless ``REPRO_LOG=quiet``."""
    if level() >= 1:
        _emit(component, msg, flush=flush)


def debug(component: Optional[str], msg: str, *, flush: bool = False) -> None:
    """Verbose line; shown only under ``REPRO_LOG=debug``."""
    if level() >= 2:
        _emit(component, msg, flush=flush)
