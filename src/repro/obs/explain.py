"""Plan-explain: render WHY the search ranked a plan-DB ladder as it did.

``search_schedule`` persists, per rung, the roofline terms its decision
was made from (``explain``: compute/HBM/collective seconds, penalty,
shards — see ``search.beam.CostEstimate``) plus a sample of the sound
bound cuts (``cuts``: the candidates dropped because their lower bound
already exceeded the best complete score).  Since PLAN_VERSION 3 each
entry also carries its ``spec`` signature and ``dtype``, so a human
selector can find entries without recomputing sha256 keys:

    scripts/obs_report.py --explain 'matmul@512x512x512'
    scripts/obs_report.py --explain 'matmul.dA@mesh=2x4'
    scripts/obs_report.py --explain 'matmul@512x512x512@dtype=bfloat16'

Selector grammar (all parts after the name optional, any order):

    name[@MxKx...][@mesh=AxB][@dtype=NAME]

``MxKx...`` matches the spec's extents in declaration order (the order
``spec_signature`` serializes them).  Everything here is pure formatting
over the DB's JSON — no jax, no search imports — so the report script
stays usable on machines that only hold the DB file.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple


def parse_selector(sel: str) -> Dict[str, Optional[str]]:
    """``'matmul@512x512x512@mesh=2x4@dtype=float32'`` -> parts dict."""
    parts = [p for p in sel.split("@") if p]
    if not parts:
        raise ValueError(f"empty selector {sel!r}")
    out: Dict[str, Optional[str]] = {
        "name": parts[0], "shape": None, "mesh": None, "dtype": None,
    }
    for p in parts[1:]:
        if p.startswith("mesh="):
            out["mesh"] = p[len("mesh="):]
        elif p.startswith("dtype="):
            out["dtype"] = p[len("dtype="):]
        elif all(tok.isdigit() for tok in p.split("x")):
            out["shape"] = p
        else:
            raise ValueError(
                f"unrecognized selector part {p!r} in {sel!r} "
                f"(want MxKx..., mesh=AxB or dtype=NAME)"
            )
    return out


def entry_shape(entry: Dict[str, Any]) -> Optional[str]:
    """'512x512x512'-style extents string of an entry's stored spec."""
    spec = entry.get("spec")
    if not spec or "extents" not in spec:
        return None
    return "x".join(str(v) for v in spec["extents"].values())


def match_entries(
    data: Dict[str, Any], selector: str
) -> List[Tuple[str, Dict[str, Any]]]:
    """All (key, entry) pairs of a plan-DB dict matching ``selector``.

    Entries predating PLAN_VERSION 3 carry no ``spec`` and can never
    match (their keys are opaque hashes) — re-sweep to upgrade them.
    """
    want = parse_selector(selector)
    out = []
    for key, entry in data.items():
        if not isinstance(entry, dict) or "ranked" not in entry:
            continue  # not a plan entry (autotune rows in a merged file)
        spec = entry.get("spec")
        if not spec:
            continue
        if spec.get("name") != want["name"]:
            continue
        if want["shape"] and entry_shape(entry) != want["shape"]:
            continue
        if want["mesh"] and (entry.get("mesh") or "") != want["mesh"]:
            continue
        if want["mesh"] is None and entry.get("mesh"):
            # unqualified selector: prefer the single-device ladder; ask
            # for @mesh=AxB explicitly to see the sharded one
            continue
        if want["dtype"] and entry.get("dtype") != want["dtype"]:
            continue
        out.append((key, entry))
    return sorted(out, key=lambda kv: kv[0])


def _fmt_s(v: Any) -> str:
    if v is None:
        return "-"
    return f"{float(v):.3g}"


def format_entry(key: str, entry: Dict[str, Any]) -> str:
    """The ranked why-this-plan table for one plan-DB entry."""
    lines: List[str] = []
    spec = entry.get("spec") or {}
    head = spec.get("name", "?")
    shape = entry_shape(entry)
    if shape:
        head += f"@{shape}"
    if entry.get("mesh"):
        head += f"@mesh={entry['mesh']}"
    if entry.get("dtype"):
        head += f"@dtype={entry['dtype']}"
    lines.append(f"plan {head}")
    lines.append(f"  key {key}  (v{entry.get('v', '?')})")
    stats = entry.get("stats") or {}
    if stats:
        lines.append(
            "  search: "
            + ", ".join(f"{k}={v}" for k, v in sorted(stats.items()))
        )
    cols = (
        f"  {'#':>2} {'source':<10} {'coll':<5} {'measured_s':>10} "
        f"{'score':>9} {'bound':>9} {'compute_s':>9} {'hbm_s':>9} "
        f"{'comm_s':>9} {'penalty':>7} vmem"
    )
    lines.append(cols)
    for i, rung in enumerate(entry.get("ranked", [])):
        ex = rung.get("explain") or {}
        lines.append(
            f"  {i:>2} {rung.get('source', 'search'):<10} "
            f"{rung.get('collective') or '-':<5} "
            f"{_fmt_s(rung.get('measured_s')):>10} "
            f"{_fmt_s(rung.get('score')):>9} "
            f"{_fmt_s(rung.get('lower_bound')):>9} "
            f"{_fmt_s(ex.get('compute_s')):>9} "
            f"{_fmt_s(ex.get('hbm_s')):>9} "
            f"{_fmt_s(ex.get('comm_s')):>9} "
            f"{_fmt_s(ex.get('penalty')):>7} "
            f"{'ok' if rung.get('fits_vmem', True) else 'SPILL'}"
        )
    cuts = entry.get("cuts") or []
    if cuts:
        lines.append(f"  bound cuts (sample of {len(cuts)}):")
        for c in cuts:
            lines.append(
                f"    bound {_fmt_s(c.get('lower_bound'))} >= best "
                f"{_fmt_s(c.get('best_score'))}  {c.get('key', '?')}"
            )
    return "\n".join(lines)


def explain(db_path: str, selector: str) -> str:
    """Load a plan-DB file and render every entry matching ``selector``."""
    with open(db_path) as f:
        data = json.load(f)
    if not isinstance(data, dict):
        raise ValueError(f"{db_path}: not a plan-DB JSON object")
    matches = match_entries(data, selector)
    if not matches:
        names = sorted(
            {
                e["spec"]["name"]
                for e in data.values()
                if isinstance(e, dict) and e.get("spec")
            }
        )
        raise LookupError(
            f"no plan-DB entry matches {selector!r} in {db_path} "
            f"(spec names present: {names or 'none — pre-v3 DB? re-sweep'})"
        )
    return "\n\n".join(format_entry(k, e) for k, e in matches)
