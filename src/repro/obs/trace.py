"""Nestable spans with Chrome-trace export — the tracing layer of repro.obs.

Usage::

    from repro import obs

    with obs.span("search.beam", spec="matmul"):
        with obs.span("search.enumerate"):
            ...

Spans nest through a thread-local stack; each completed span records one
Chrome-trace *complete* event (``ph: "X"``) with microsecond ``ts``/``dur``
relative to a process epoch, plus ``depth`` and ``parent`` args so tools
that flatten the event list can still reconstruct the nesting.  Export with
``trace_json()`` / ``trace_dump(path)`` — the output loads directly in
``chrome://tracing`` and https://ui.perfetto.dev, and
``scripts/obs_report.py --trace`` renders a per-name summary.

With ``REPRO_OBS=0`` ``span()`` returns a shared no-op context manager and
nothing is recorded (the acquired-lock path is never reached).
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

#: process epoch — all ts values are microseconds since this moment
_EPOCH = time.perf_counter()

_lock = threading.Lock()
_events: List[Dict[str, Any]] = []
_tls = threading.local()


def _stack() -> List[str]:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


class _Span:
    """One timed region; records a Chrome-trace "X" event on exit."""

    __slots__ = ("name", "cat", "args", "_t0", "_depth", "_parent")

    def __init__(self, name: str, cat: str, args: Dict[str, Any]):
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0
        self._depth = 0
        self._parent: Optional[str] = None

    def __enter__(self) -> "_Span":
        st = _stack()
        self._depth = len(st)
        self._parent = st[-1] if st else None
        st.append(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        st = _stack()
        if st and st[-1] == self.name:
            st.pop()
        args = {"depth": self._depth}
        if self._parent is not None:
            args["parent"] = self._parent
        args.update(self.args)
        ev = {
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": (self._t0 - _EPOCH) * 1e6,
            "dur": (t1 - self._t0) * 1e6,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "args": args,
        }
        with _lock:
            _events.append(ev)


class _NoopSpan:
    """Shared do-nothing span — what ``span()`` hands out when disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NOOP = _NoopSpan()


def span(name: str, cat: str = "repro", **args: Any):
    """Context manager timing one region; nests via a thread-local stack.

    ``args`` ride into the Chrome-trace event's ``args`` dict verbatim
    (keep them JSON-serializable).  Free when ``REPRO_OBS=0``.
    """
    from . import enabled

    if not enabled():
        return _NOOP
    return _Span(name, cat, args)


def complete_event(
    name: str,
    start_s: float,
    dur_s: float,
    cat: str = "repro",
    **args: Any,
) -> None:
    """Record a Chrome-trace complete event retroactively.

    For region timings that cannot be a ``with span(...)`` because their
    lifetimes overlap in one thread — e.g. a serving gateway's
    per-request spans, where dozens of requests are in flight at once and
    each spans arrival→finish.  ``start_s`` is a ``time.perf_counter()``
    reading; the event lands on the same process epoch as ``span``.
    Free when ``REPRO_OBS=0``.
    """
    from . import enabled

    if not enabled():
        return
    ev = {
        "name": name,
        "cat": cat,
        "ph": "X",
        "ts": (start_s - _EPOCH) * 1e6,
        "dur": dur_s * 1e6,
        "pid": os.getpid(),
        "tid": threading.get_ident(),
        "args": dict(args),
    }
    with _lock:
        _events.append(ev)


def trace_events() -> List[Dict[str, Any]]:
    """Snapshot of the completed-span events recorded so far."""
    with _lock:
        return list(_events)


def trace_json() -> Dict[str, Any]:
    """The Chrome-trace document: ``{"traceEvents": [...], ...}``."""
    return {
        "traceEvents": trace_events(),
        "displayTimeUnit": "ms",
        "otherData": {"producer": "repro.obs"},
    }


def trace_dump(path: str) -> str:
    """Write the Chrome-trace JSON to ``path``; returns the path."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(trace_json(), f, indent=1)
    return path


def trace_reset() -> None:
    """Drop every recorded event (tests; long-lived servers between dumps)."""
    with _lock:
        _events.clear()
