"""Jaxpr-level GEMM harvest: every ``dot_general`` becomes a ContractionSpec.

The model zoo (``repro.models``) lowers its matmuls through ``jnp.dot`` /
``jnp.einsum``, i.e. through the ``dot_general`` primitive — not through
``repro.ops`` — so before this module only hand-rewired call sites owned
plan-DB/autotune keys.  ``harvest_jaxpr`` walks a traced function (recursing
into ``pjit``/``scan``/``remat``/``cond``/``while``/``custom_*`` sub-jaxprs),
classifies each ``dot_general`` equation against the spec families of
``core.enumerate`` (the single home of contraction naming, so every harvested
site owns the same plan-DB and autotune-cache keys a hand-rewired ``ops``
call would), and reports per site whether the capture rewriter
(``capture.rewrite``) can dispatch it through the generated-kernel pipeline
or must leave it untouched — with the reason.

Classification is the *single source of truth* shared with the rewriter:
``classify_dot_general`` decides, ``rewrite`` obeys.  Eligibility reuses the
``repro.ops`` kernel-dispatch predicates verbatim, so a site is dispatched
exactly when the equivalent ``ops`` entry point would run a generated kernel
for those shapes on this backend.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
from jax import core as jcore

from ..core.enumerate import (
    ContractionSpec,
    batched_matmul_spec,
    matmul_spec,
    transposed_matmul_spec,
)

#: dtypes the generated-kernel pipeline stores/accumulates correctly
SUPPORTED_DTYPES = ("float32", "bfloat16")

#: sub-jaxpr-carrying primitives the rewriter knows how to re-emit; sites
#: inside any *other* jaxpr-carrying primitive are fallback by containment
REWRITABLE_HOPS = frozenset({
    "pjit", "closed_call", "core_call",
    "scan", "while", "cond",
    "remat", "remat2", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
})


@dataclasses.dataclass
class CaptureSite:
    """One ``dot_general`` equation of the traced function."""

    site_id: int
    path: str                  # eqn trail, e.g. "scan/remat2/eqn12"
    lhs_shape: Tuple[int, ...]
    rhs_shape: Tuple[int, ...]
    out_shape: Tuple[int, ...]
    dtype: str
    out_dtype: str
    dimension_numbers: Any
    op: Optional[str] = None           # dense | dense_transposed | batched_dense
    spec: Optional[ContractionSpec] = None
    status: str = "fallback"           # dispatched | fallback
    reason: str = ""                   # why a fallback site fell back

    @property
    def dispatched(self) -> bool:
        return self.status == "dispatched"

    def as_dict(self) -> Dict[str, Any]:
        return {
            "site_id": self.site_id,
            "path": self.path,
            "lhs_shape": list(self.lhs_shape),
            "rhs_shape": list(self.rhs_shape),
            "out_shape": list(self.out_shape),
            "dtype": self.dtype,
            "out_dtype": self.out_dtype,
            "op": self.op,
            "spec": None if self.spec is None else self.spec.name,
            "extents": None if self.spec is None else dict(self.spec.extents),
            "status": self.status,
            "reason": self.reason,
        }


def spec_key(spec: ContractionSpec, dtype: str) -> Tuple:
    """Plan-key granularity for deduplicating harvested GEMM sites — the
    single home of this tuple (report dedup, model sweeps, serve warmup
    all key on it)."""
    return (spec.name, tuple(sorted(spec.extents.items())), str(dtype))


@dataclasses.dataclass
class CaptureReport:
    """Per-site accounting for one captured function."""

    label: str = ""
    sites: List[CaptureSite] = dataclasses.field(default_factory=list)

    @property
    def harvested(self) -> int:
        return len(self.sites)

    @property
    def dispatched(self) -> int:
        return sum(1 for s in self.sites if s.dispatched)

    @property
    def fallback(self) -> int:
        return self.harvested - self.dispatched

    def dispatched_sites(self) -> List[CaptureSite]:
        return [s for s in self.sites if s.dispatched]

    def unique_specs(self) -> List[Tuple[ContractionSpec, str]]:
        """Deduplicated (spec, dtype) pairs of the dispatched sites — the
        sweepable GEMM set of this function (plan-DB key granularity)."""
        seen: Dict[Tuple, Tuple[ContractionSpec, str]] = {}
        for s in self.sites:
            if s.spec is None or not s.dispatched:
                continue
            seen.setdefault(spec_key(s.spec, s.dtype), (s.spec, s.dtype))
        return list(seen.values())

    def summary(self) -> str:
        return (
            f"capture[{self.label or '?'}]: {self.harvested} site(s) "
            f"harvested, {self.dispatched} dispatched, "
            f"{self.fallback} fallback"
        )

    def as_dict(self) -> Dict[str, Any]:
        return {
            "label": self.label,
            "harvested": self.harvested,
            "dispatched": self.dispatched,
            "fallback": self.fallback,
            "sites": [s.as_dict() for s in self.sites],
        }

    def to_json(self, **kwargs) -> str:
        kwargs.setdefault("indent", 2)
        kwargs.setdefault("sort_keys", True)
        return json.dumps(self.as_dict(), **kwargs)


# ---------------------------------------------------------------------------
# classification — shared with capture.rewrite
# ---------------------------------------------------------------------------


class _Shaped:
    """Minimal shape/ndim carrier for the ops dispatch predicates."""

    __slots__ = ("shape", "ndim")

    def __init__(self, shape):
        self.shape = tuple(shape)
        self.ndim = len(self.shape)


def classify_dot_general(
    lhs_aval, rhs_aval, out_aval, params: Dict[str, Any], *,
    interpret: bool, site_id: int = 0, path: str = "",
) -> CaptureSite:
    """Map one ``dot_general`` equation to a ContractionSpec + dispatch verdict.

    Eligible layouts (everything else falls back untouched):

      * ``(..., M, D) @ (D, F)`` contracting the last lhs axis with the
        first rhs axis, no batch dims -> ``matmul`` (leading lhs axes are
        flattened into M, exactly what the models' reshape-then-dense
        call sites do by hand);
      * ``(D, M) @ (D, F)`` contracting axis 0 with axis 0 ->
        ``transposed_matmul`` (the weight-gradient layout);
      * ``(B, M, D) @ (B, D, F)`` batched on axis 0 -> ``batched_matmul``
        (MoE expert FFNs, attention-free batched contractions).

    The dispatch verdict then applies the exact ``repro.ops`` kernel
    predicates, so "dispatched" means "the equivalent ops entry point runs
    a generated kernel here" — alignment, backend and dtype included.
    """
    from .. import ops

    (lc, rc), (lb, rb) = params["dimension_numbers"]
    site = CaptureSite(
        site_id=site_id,
        path=path,
        lhs_shape=tuple(lhs_aval.shape),
        rhs_shape=tuple(rhs_aval.shape),
        out_shape=tuple(out_aval.shape),
        dtype=np.dtype(lhs_aval.dtype).name,
        out_dtype=np.dtype(out_aval.dtype).name,
        dimension_numbers=params["dimension_numbers"],
    )

    if np.dtype(lhs_aval.dtype) != np.dtype(rhs_aval.dtype):
        site.reason = (
            f"mixed operand dtypes {lhs_aval.dtype}/{rhs_aval.dtype}"
        )
        return site
    if site.dtype not in SUPPORTED_DTYPES:
        site.reason = f"unsupported dtype {site.dtype}"
        return site

    ln, rn = len(site.lhs_shape), len(site.rhs_shape)
    lc, rc, lb, rb = tuple(lc), tuple(rc), tuple(lb), tuple(rb)

    if not lb and rn == 2 and rc == (0,) and ln >= 2 and lc == (ln - 1,):
        # (..., M, D) @ (D, F): the workhorse dense layout
        d = site.lhs_shape[-1]
        m = int(np.prod(site.lhs_shape[:-1], dtype=np.int64))
        f = site.rhs_shape[1]
        site.op = "dense"
        site.spec = matmul_spec(m, d, f)
        if ops._dense_kernel_ok(
            _Shaped((m, d)), _Shaped((d, f)), interpret
        ):
            site.status = "dispatched"
        else:
            if not (ops._use_pallas() or interpret):
                site.reason = "cpu backend without interpret mode"
            else:
                site.reason = (
                    f"dense kernel needs 128-aligned (M,D,F)=({m},{d},{f})"
                )
        return site

    if not lb and ln == 2 and rn == 2 and lc == (0,) and rc == (0,):
        # (D, M) @ (D, F) -> (M, F): stored-transposed contraction
        d, m = site.lhs_shape
        f = site.rhs_shape[1]
        site.op = "dense_transposed"
        site.spec = transposed_matmul_spec(m, d, f)
        if ops._generic_kernel_ok(interpret):
            site.status = "dispatched"
        else:
            site.reason = "cpu backend without interpret mode"
        return site

    if (
        lb == (0,) and rb == (0,) and ln == 3 and rn == 3
        and lc == (2,) and rc == (1,)
    ):
        b, m, d = site.lhs_shape
        f = site.rhs_shape[2]
        site.op = "batched_dense"
        site.spec = batched_matmul_spec(b, m, d, f)
        if ops._batched_kernel_ok(
            _Shaped((b, m, d)), _Shaped((b, d, f)), interpret
        ):
            site.status = "dispatched"
        else:
            site.reason = "cpu backend without interpret mode"
        return site

    site.reason = (
        f"unsupported contraction layout ndim=({ln},{rn}) "
        f"contract=({lc},{rc}) batch=({lb},{rb})"
    )
    return site


# ---------------------------------------------------------------------------
# jaxpr walk
# ---------------------------------------------------------------------------


def _sub_jaxprs(eqn) -> List[Tuple[str, jcore.Jaxpr]]:
    """All jaxprs carried in an equation's params (generic, any primitive)."""
    out: List[Tuple[str, jcore.Jaxpr]] = []
    for name, val in eqn.params.items():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, jcore.ClosedJaxpr):
                out.append((name, v.jaxpr))
            elif isinstance(v, jcore.Jaxpr):
                out.append((name, v))
    return out


def harvest_jaxpr(
    closed: jcore.ClosedJaxpr, *, interpret: bool, label: str = "",
) -> CaptureReport:
    """Walk a traced function and classify every ``dot_general`` site.

    Recurses into all jaxpr-carrying params.  Sites nested inside a
    higher-order primitive the rewriter cannot re-emit (anything outside
    ``REWRITABLE_HOPS``) are forced to fallback with the containing
    primitive named in the reason — the report never over-promises what
    ``capture.optimize`` will actually dispatch.
    """
    report = CaptureReport(label=label)

    def walk(
        jaxpr: jcore.Jaxpr, trail: Tuple[str, ...],
        blocked_by: Optional[str],
    ):
        for i, eqn in enumerate(jaxpr.eqns):
            name = eqn.primitive.name
            if name == "dot_general":
                site = classify_dot_general(
                    eqn.invars[0].aval, eqn.invars[1].aval,
                    eqn.outvars[0].aval, eqn.params,
                    interpret=interpret,
                    site_id=len(report.sites),
                    path="/".join(trail + (f"eqn{i}",)),
                )
                if site.dispatched and blocked_by is not None:
                    site.status = "fallback"
                    site.reason = (
                        "inside a higher-order primitive the rewriter "
                        f"does not re-emit ({blocked_by})"
                    )
                report.sites.append(site)
                continue
            subs = _sub_jaxprs(eqn)
            if subs:
                # the first non-rewritable ancestor blocks everything
                # below it; keep naming *that* primitive, not nearer
                # (rewritable) ancestors
                block = blocked_by if blocked_by is not None else (
                    None if name in REWRITABLE_HOPS else name
                )
                for _, sub in subs:
                    walk(sub, trail + (name,), block)

    walk(closed.jaxpr, (), None)
    return report
