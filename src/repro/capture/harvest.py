"""Jaxpr-level GEMM harvest: every ``dot_general`` becomes a ContractionSpec.

The model zoo (``repro.models``) lowers its matmuls through ``jnp.dot`` /
``jnp.einsum``, i.e. through the ``dot_general`` primitive — not through
``repro.ops`` — so before this module only hand-rewired call sites owned
plan-DB/autotune keys.  ``harvest_jaxpr`` walks a traced function (recursing
into ``pjit``/``scan``/``remat``/``cond``/``while``/``custom_*`` sub-jaxprs),
classifies each ``dot_general`` equation against the spec families of
``core.enumerate`` (the single home of contraction naming, so every harvested
site owns the same plan-DB and autotune-cache keys a hand-rewired ``ops``
call would), and reports per site whether the capture rewriter
(``capture.rewrite``) can dispatch it through the generated-kernel pipeline
or must leave it untouched — with the reason.

Classification is the *single source of truth* shared with the rewriter:
``classify_dot_general`` decides, ``rewrite`` obeys.  Eligibility reuses the
``repro.ops`` kernel-dispatch predicates verbatim, so a site is dispatched
exactly when the equivalent ``ops`` entry point would run a generated kernel
for those shapes on this backend.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
from jax import core as jcore

from ..core.enumerate import (
    ContractionSpec,
    attention_spec,
    batched_matmul_spec,
    grouped_matmul_spec,
    matmul_spec,
    transposed_matmul_spec,
)

#: dtypes the generated-kernel pipeline stores/accumulates correctly
SUPPORTED_DTYPES = ("float32", "bfloat16")

#: sub-jaxpr-carrying primitives the rewriter knows how to re-emit; sites
#: inside any *other* jaxpr-carrying primitive are fallback by containment
REWRITABLE_HOPS = frozenset({
    "pjit", "closed_call", "core_call",
    "scan", "while", "cond",
    "remat", "remat2", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
})


@dataclasses.dataclass
class CaptureSite:
    """One ``dot_general`` equation of the traced function."""

    site_id: int
    path: str                  # eqn trail, e.g. "scan/remat2/eqn12"
    lhs_shape: Tuple[int, ...]
    rhs_shape: Tuple[int, ...]
    out_shape: Tuple[int, ...]
    dtype: str
    out_dtype: str
    dimension_numbers: Any
    op: Optional[str] = None           # dense | dense_transposed | batched_dense
    spec: Optional[ContractionSpec] = None
    status: str = "fallback"           # dispatched | fallback
    reason: str = ""                   # why a fallback site fell back

    @property
    def dispatched(self) -> bool:
        return self.status == "dispatched"

    def as_dict(self) -> Dict[str, Any]:
        return {
            "site_id": self.site_id,
            "path": self.path,
            "lhs_shape": list(self.lhs_shape),
            "rhs_shape": list(self.rhs_shape),
            "out_shape": list(self.out_shape),
            "dtype": self.dtype,
            "out_dtype": self.out_dtype,
            "op": self.op,
            "spec": None if self.spec is None else self.spec.name,
            "extents": None if self.spec is None else dict(self.spec.extents),
            "status": self.status,
            "reason": self.reason,
        }


def spec_key(spec: ContractionSpec, dtype: str) -> Tuple:
    """Plan-key granularity for deduplicating harvested GEMM sites — the
    single home of this tuple (report dedup, model sweeps, serve warmup
    all key on it)."""
    return (spec.name, tuple(sorted(spec.extents.items())), str(dtype))


@dataclasses.dataclass
class CaptureReport:
    """Per-site accounting for one captured function."""

    label: str = ""
    sites: List[CaptureSite] = dataclasses.field(default_factory=list)

    @property
    def harvested(self) -> int:
        return len(self.sites)

    @property
    def dispatched(self) -> int:
        return sum(1 for s in self.sites if s.dispatched)

    @property
    def fallback(self) -> int:
        return self.harvested - self.dispatched

    def dispatched_sites(self) -> List[CaptureSite]:
        return [s for s in self.sites if s.dispatched]

    def unique_specs(self) -> List[Tuple[ContractionSpec, str]]:
        """Deduplicated (spec, dtype) pairs of the dispatched sites — the
        sweepable GEMM set of this function (plan-DB key granularity)."""
        seen: Dict[Tuple, Tuple[ContractionSpec, str]] = {}
        for s in self.sites:
            if s.spec is None or not s.dispatched:
                continue
            seen.setdefault(spec_key(s.spec, s.dtype), (s.spec, s.dtype))
        return list(seen.values())

    def summary(self) -> str:
        return (
            f"capture[{self.label or '?'}]: {self.harvested} site(s) "
            f"harvested, {self.dispatched} dispatched, "
            f"{self.fallback} fallback"
        )

    def as_dict(self) -> Dict[str, Any]:
        return {
            "label": self.label,
            "harvested": self.harvested,
            "dispatched": self.dispatched,
            "fallback": self.fallback,
            "sites": [s.as_dict() for s in self.sites],
        }

    def to_json(self, **kwargs) -> str:
        kwargs.setdefault("indent", 2)
        kwargs.setdefault("sort_keys", True)
        return json.dumps(self.as_dict(), **kwargs)


# ---------------------------------------------------------------------------
# classification — shared with capture.rewrite
# ---------------------------------------------------------------------------


class _Shaped:
    """Minimal shape/ndim carrier for the ops dispatch predicates."""

    __slots__ = ("shape", "ndim")

    def __init__(self, shape):
        self.shape = tuple(shape)
        self.ndim = len(self.shape)


def classify_dot_general(
    lhs_aval, rhs_aval, out_aval, params: Dict[str, Any], *,
    interpret: bool, site_id: int = 0, path: str = "",
    grouped_lhs: bool = False,
) -> CaptureSite:
    """Map one ``dot_general`` equation to a ContractionSpec + dispatch verdict.

    Eligible layouts (everything else falls back untouched):

      * ``(..., M, D) @ (D, F)`` contracting the last lhs axis with the
        first rhs axis, no batch dims -> ``matmul`` (leading lhs axes are
        flattened into M, exactly what the models' reshape-then-dense
        call sites do by hand);
      * ``(D, M) @ (D, F)`` contracting axis 0 with axis 0 ->
        ``transposed_matmul`` (the weight-gradient layout);
      * ``(B, M, D) @ (B, D, F)`` batched on axis 0 -> ``batched_matmul``
        (MoE expert FFNs, attention-free batched contractions).

    The dispatch verdict then applies the exact ``repro.ops`` kernel
    predicates, so "dispatched" means "the equivalent ops entry point runs
    a generated kernel here" — alignment, backend and dtype included.
    """
    from .. import ops

    (lc, rc), (lb, rb) = params["dimension_numbers"]
    site = CaptureSite(
        site_id=site_id,
        path=path,
        lhs_shape=tuple(lhs_aval.shape),
        rhs_shape=tuple(rhs_aval.shape),
        out_shape=tuple(out_aval.shape),
        dtype=np.dtype(lhs_aval.dtype).name,
        out_dtype=np.dtype(out_aval.dtype).name,
        dimension_numbers=params["dimension_numbers"],
    )

    if np.dtype(lhs_aval.dtype) != np.dtype(rhs_aval.dtype):
        site.reason = (
            f"mixed operand dtypes {lhs_aval.dtype}/{rhs_aval.dtype}"
        )
        return site
    if site.dtype not in SUPPORTED_DTYPES:
        site.reason = f"unsupported dtype {site.dtype}"
        return site

    ln, rn = len(site.lhs_shape), len(site.rhs_shape)
    lc, rc, lb, rb = tuple(lc), tuple(rc), tuple(lb), tuple(rb)

    if not lb and rn == 2 and rc == (0,) and ln >= 2 and lc == (ln - 1,):
        # (..., M, D) @ (D, F): the workhorse dense layout
        d = site.lhs_shape[-1]
        m = int(np.prod(site.lhs_shape[:-1], dtype=np.int64))
        f = site.rhs_shape[1]
        site.op = "dense"
        site.spec = matmul_spec(m, d, f)
        if ops._dense_kernel_ok(
            _Shaped((m, d)), _Shaped((d, f)), interpret
        ):
            site.status = "dispatched"
        else:
            if not (ops._use_pallas() or interpret):
                site.reason = "cpu backend without interpret mode"
            else:
                site.reason = (
                    f"dense kernel needs 128-aligned (M,D,F)=({m},{d},{f})"
                )
        return site

    if not lb and ln == 2 and rn == 2 and lc == (0,) and rc == (0,):
        # (D, M) @ (D, F) -> (M, F): stored-transposed contraction
        d, m = site.lhs_shape
        f = site.rhs_shape[1]
        site.op = "dense_transposed"
        site.spec = transposed_matmul_spec(m, d, f)
        if ops._generic_kernel_ok(interpret):
            site.status = "dispatched"
        else:
            site.reason = "cpu backend without interpret mode"
        return site

    if (
        lb == (0,) and rb == (0,) and ln == 3 and rn == 3
        and lc == (2,) and rc == (1,)
    ):
        b, m, d = site.lhs_shape
        f = site.rhs_shape[2]
        if grouped_lhs:
            # the lhs rows were routed here by a scatter (MoE dispatch):
            # expert slab b of the rhs multiplies only *its* row block, so
            # this is the uniform-group case of the ragged grouped GEMM —
            # one searched group-offset kernel instead of a batched one
            site.op = "grouped_dense"
            site.spec = grouped_matmul_spec((m,) * b, d, f)
            if ops._grouped_kernel_ok(_Shaped((b * m, d)), interpret):
                site.status = "dispatched"
            else:
                site.reason = "cpu backend without interpret mode"
            return site
        site.op = "batched_dense"
        site.spec = batched_matmul_spec(b, m, d, f)
        if ops._batched_kernel_ok(
            _Shaped((b, m, d)), _Shaped((b, d, f)), interpret
        ):
            site.status = "dispatched"
        else:
            site.reason = "cpu backend without interpret mode"
        return site

    site.reason = (
        f"unsupported contraction layout ndim=({ln},{rn}) "
        f"contract=({lc},{rc}) batch=({lb},{rb})"
    )
    return site


# ---------------------------------------------------------------------------
# fused-pattern analysis: attention motif + scatter-tainted grouped GEMMs
# ---------------------------------------------------------------------------

#: mask fills below this count as "minus infinity" for motif purposes
_MASK_FLOOR = -1e20

#: producers the motif matcher looks through (layout/dtype plumbing)
_TRANSPARENT = frozenset({
    "reshape", "broadcast_in_dim", "convert_element_type",
    "squeeze", "expand_dims",
})


@dataclasses.dataclass
class AttentionMotif:
    """One matched einsum-softmax-einsum chain, rewritable as one fused op.

    ``terminal`` is the closing ``div`` equation (its outvar carries the
    attention output); ``interior`` holds ids of every equation whose
    value exists only to feed the terminal — the rewriter skips them and
    evaluates ``ops.attention(q, k, v)`` at the terminal instead.
    """

    terminal_id: int
    interior: frozenset
    q: Any
    k: Any
    v: Any
    causal: bool
    site: CaptureSite


@dataclasses.dataclass
class JaxprAnalysis:
    """Fused-pattern facts of ONE jaxpr level (sub-jaxprs analyzed apart).

    ``interior`` maps interior-equation id -> owning terminal id, so the
    rewriter can skip an equation only when its motif actually dispatches.
    """

    motifs: Dict[int, AttentionMotif] = dataclasses.field(
        default_factory=dict
    )
    interior: Dict[int, int] = dataclasses.field(default_factory=dict)
    grouped: frozenset = frozenset()


def _peel(atom, producers, visited):
    """Follow layout-only producers back; returns (atom, defining eqn)."""
    while isinstance(atom, jcore.Var) and atom in producers:
        eqn = producers[atom]
        if eqn.primitive.name in _TRANSPARENT:
            visited.append(eqn)
            atom = eqn.invars[0]
        else:
            return atom, eqn
    return atom, None


def _is_causal_pred(pred, producers) -> bool:
    """pred == (col_iota <= row_iota), structurally — no constant masks."""
    if not isinstance(pred, jcore.Var) or pred not in producers:
        return False
    cmp = producers[pred]
    if cmp.primitive.name not in ("le", "ge") or len(cmp.invars) != 2:
        return False
    dims = []
    for v in cmp.invars:
        if not isinstance(v, jcore.Var) or v not in producers:
            return False
        src = producers[v]
        if src.primitive.name != "iota":
            return False
        dims.append(src.params["dimension"])
    want = (2, 1) if cmp.primitive.name == "le" else (1, 2)
    return tuple(dims) == want


def _match_attention(div_eqn, producers, consumers, live_out, *, interpret):
    """Match the plain-path attention chain ending at ``div_eqn``.

    Expected (walking backwards, through layout-only ops):

        div(num, rowsum)  <- num = dot_general(exp_p, V)  b(0,0) c(2,1)
                             rowsum = reduce_sum(exp_p, axes=(2,))
        exp_p = exp(scores_masked - reduce_max(scores_masked, axes=(2,)))
        scores_masked = [where(col<=row, ., -big)] (mul(dot1, d**-0.5))
        dot1 = dot_general(Q, K)  b(0,0) c(2,2)

    Every interior value must be consumed only inside the chain — the
    rewrite replaces the whole region with one ``ops.attention`` call.
    """
    from .. import ops

    chain: List[Any] = []
    _, dot2 = _peel(div_eqn.invars[0], producers, chain)
    if dot2 is None or dot2.primitive.name != "dot_general":
        return None
    (lc, rc), (lb, rb) = dot2.params["dimension_numbers"]
    if (tuple(lb), tuple(rb), tuple(lc), tuple(rc)) != \
            ((0,), (0,), (2,), (1,)):
        return None
    chain.append(dot2)

    _, rsum = _peel(div_eqn.invars[1], producers, chain)
    if (
        rsum is None or rsum.primitive.name != "reduce_sum"
        or tuple(rsum.params["axes"]) != (2,)
    ):
        return None
    chain.append(rsum)

    _, exp_a = _peel(rsum.invars[0], producers, chain)
    _, exp_b = _peel(dot2.invars[0], producers, chain)
    if exp_a is None or exp_a is not exp_b or exp_a.primitive.name != "exp":
        return None
    chain.append(exp_a)

    _, sub = _peel(exp_a.invars[0], producers, chain)
    if sub is None or sub.primitive.name != "sub":
        return None
    chain.append(sub)
    _, rmax = _peel(sub.invars[1], producers, chain)
    if (
        rmax is None or rmax.primitive.name != "reduce_max"
        or tuple(rmax.params["axes"]) != (2,)
    ):
        return None
    chain.append(rmax)
    _, masked = _peel(sub.invars[0], producers, chain)
    _, masked2 = _peel(rmax.invars[0], producers, chain)
    if masked is None or masked is not masked2:
        return None

    causal = False
    if (
        masked.primitive.name == "pjit"
        and str(masked.params.get("name")) in ("_where", "where")
        and len(masked.invars) == 3
    ):
        pred, scores_in, fill = masked.invars
        if not (
            isinstance(fill, jcore.Literal)
            and float(fill.val) <= _MASK_FLOOR
        ):
            return None
        if not _is_causal_pred(pred, producers):
            return None
        causal = True
        chain.append(masked)
        _, mul = _peel(scores_in, producers, chain)
    else:
        mul = masked
    if mul is None or mul.primitive.name != "mul":
        return None
    chain.append(mul)

    scale_lit = dot1 = None
    for a, b in (mul.invars, reversed(mul.invars)):
        if isinstance(b, jcore.Literal) and np.ndim(b.val) == 0:
            _, cand = _peel(a, producers, chain)
            if cand is not None and cand.primitive.name == "dot_general":
                scale_lit, dot1 = float(b.val), cand
            break
    if dot1 is None:
        return None
    (lc, rc), (lb, rb) = dot1.params["dimension_numbers"]
    if (tuple(lb), tuple(rb), tuple(lc), tuple(rc)) != \
            ((0,), (0,), (2,), (2,)):
        return None
    chain.append(dot1)

    q_atom, k_atom = dot1.invars
    v_atom = dot2.invars[1]
    qa, ka, va = (x.aval for x in (q_atom, k_atom, v_atom))
    if qa.ndim != 3 or ka.ndim != 3 or va.ndim != 3:
        return None
    h, s, d = qa.shape
    t = ka.shape[1]
    e = va.shape[2]
    if ka.shape != (h, t, d) or va.shape[:2] != (h, t):
        return None
    if abs(scale_lit - d ** -0.5) > 1e-6 * d ** -0.5:
        return None  # non-standard scaling: not the op we generate

    # the fused call replaces the whole region — nothing outside it may
    # observe an interior value
    interior_ids = {id(c) for c in chain}
    for c in chain:
        for ov in c.outvars:
            if ov in live_out:
                return None
            for user in consumers.get(ov, ()):
                if id(user) not in interior_ids and user is not div_eqn:
                    return None

    site = CaptureSite(
        site_id=0,
        path="",
        lhs_shape=tuple(qa.shape),
        rhs_shape=tuple(ka.shape),
        out_shape=tuple(div_eqn.outvars[0].aval.shape),
        dtype=np.dtype(qa.dtype).name,
        out_dtype=np.dtype(div_eqn.outvars[0].aval.dtype).name,
        dimension_numbers=dot1.params["dimension_numbers"],
        op="attention",
        spec=attention_spec(h, s, t, d, e=e, causal=causal),
    )
    if np.dtype(qa.dtype) != np.dtype(ka.dtype) or \
            np.dtype(qa.dtype) != np.dtype(va.dtype):
        site.reason = "mixed attention operand dtypes"
    elif site.dtype not in SUPPORTED_DTYPES:
        site.reason = f"unsupported dtype {site.dtype}"
    elif ops._attention_kernel_ok(_Shaped((h, s, d)), interpret):
        site.status = "dispatched"
    else:
        site.reason = "cpu backend without interpret mode"
    return AttentionMotif(
        terminal_id=id(div_eqn),
        interior=frozenset(interior_ids),
        q=q_atom, k=k_atom, v=v_atom,
        causal=causal,
        site=site,
    )


def analyze_jaxpr(jaxpr: jcore.Jaxpr, *, interpret: bool) -> JaxprAnalysis:
    """Fused-pattern pass over one jaxpr level.

    * attention motifs: einsum-softmax-einsum chains rewritable as ONE
      ``ops.attention`` call (``_match_attention``);
    * grouped taint: values written by scatter-family primitives (the MoE
      dispatch) taint everything downstream, and a batched ``dot_general``
      whose lhs is tainted classifies as ``grouped_dense`` — its rows
      were *routed* to slabs, so the uniform grouped kernel (numerically
      identical to the batched one) keeps the site in the searched family
      that also covers the ragged case.

    Sub-jaxprs are analyzed separately by their own walk/eval level;
    taint deliberately does not cross higher-order primitive boundaries.
    """
    producers: Dict[Any, Any] = {}
    consumers: Dict[Any, List[Any]] = {}
    for eqn in jaxpr.eqns:
        for v in eqn.invars:
            if isinstance(v, jcore.Var):
                consumers.setdefault(v, []).append(eqn)
        for v in eqn.outvars:
            producers[v] = eqn
    live_out = {v for v in jaxpr.outvars if isinstance(v, jcore.Var)}

    analysis = JaxprAnalysis()
    for eqn in jaxpr.eqns:
        if eqn.primitive.name != "div":
            continue
        motif = _match_attention(
            eqn, producers, consumers, live_out, interpret=interpret
        )
        if motif is None:
            continue
        if any(i in analysis.interior for i in motif.interior):
            continue  # overlapping match: first one wins
        analysis.motifs[id(eqn)] = motif
        for i in motif.interior:
            analysis.interior[i] = id(eqn)

    tainted: set = set()
    grouped: set = set()
    for eqn in jaxpr.eqns:
        hit = any(
            isinstance(v, jcore.Var) and v in tainted for v in eqn.invars
        )
        if eqn.primitive.name == "dot_general" and hit:
            lhs = eqn.invars[0]
            if isinstance(lhs, jcore.Var) and lhs in tainted:
                grouped.add(id(eqn))
        if hit or eqn.primitive.name.startswith("scatter"):
            tainted.update(eqn.outvars)
    analysis.grouped = frozenset(grouped)
    return analysis


# ---------------------------------------------------------------------------
# jaxpr walk
# ---------------------------------------------------------------------------


def _sub_jaxprs(eqn) -> List[Tuple[str, jcore.Jaxpr]]:
    """All jaxprs carried in an equation's params (generic, any primitive)."""
    out: List[Tuple[str, jcore.Jaxpr]] = []
    for name, val in eqn.params.items():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, jcore.ClosedJaxpr):
                out.append((name, v.jaxpr))
            elif isinstance(v, jcore.Jaxpr):
                out.append((name, v))
    return out


def harvest_jaxpr(
    closed: jcore.ClosedJaxpr, *, interpret: bool, label: str = "",
) -> CaptureReport:
    """Walk a traced function and classify every ``dot_general`` site.

    Recurses into all jaxpr-carrying params.  Sites nested inside a
    higher-order primitive the rewriter cannot re-emit (anything outside
    ``REWRITABLE_HOPS``) are forced to fallback with the containing
    primitive named in the reason — the report never over-promises what
    ``capture.optimize`` will actually dispatch.
    """
    report = CaptureReport(label=label)

    def blocked(site: CaptureSite, blocked_by: Optional[str]) -> None:
        if site.dispatched and blocked_by is not None:
            site.status = "fallback"
            site.reason = (
                "inside a higher-order primitive the rewriter "
                f"does not re-emit ({blocked_by})"
            )

    def walk(
        jaxpr: jcore.Jaxpr, trail: Tuple[str, ...],
        blocked_by: Optional[str],
    ):
        analysis = analyze_jaxpr(jaxpr, interpret=interpret)
        for i, eqn in enumerate(jaxpr.eqns):
            name = eqn.primitive.name
            motif = analysis.motifs.get(id(eqn))
            if motif is not None:
                site = motif.site
                site.site_id = len(report.sites)
                site.path = "/".join(trail + (f"eqn{i}",))
                blocked(site, blocked_by)
                report.sites.append(site)
                continue
            if name == "dot_general":
                if id(eqn) in analysis.interior:
                    continue  # folded into an attention site above
                site = classify_dot_general(
                    eqn.invars[0].aval, eqn.invars[1].aval,
                    eqn.outvars[0].aval, eqn.params,
                    interpret=interpret,
                    site_id=len(report.sites),
                    path="/".join(trail + (f"eqn{i}",)),
                    grouped_lhs=id(eqn) in analysis.grouped,
                )
                blocked(site, blocked_by)
                report.sites.append(site)
                continue
            subs = _sub_jaxprs(eqn)
            if subs:
                # a non-rewritable primitive blocks everything below it;
                # report the NEAREST such ancestor (an inner blocker is
                # the one that actually stops the rewrite, even when an
                # outer one exists too)
                block = name if name not in REWRITABLE_HOPS else blocked_by
                for _, sub in subs:
                    walk(sub, trail + (name,), block)

    walk(closed.jaxpr, (), None)
    return report
