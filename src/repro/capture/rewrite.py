"""Re-emit a traced function with eligible GEMMs dispatched through ops.

``optimize(fn)`` is the whole-model counterpart of hand-rewiring a call
site to ``repro.ops``: it traces ``fn`` to a jaxpr (shape-specialized,
cached per input signature, exactly like ``jit``), then evaluates that
jaxpr equation by equation — every ``dot_general`` that
``harvest.classify_dot_general`` marks dispatchable is replaced by the
corresponding ``repro.ops`` entry point (``dense`` / ``dense_transposed``
/ ``batched_dense``), which routes through the ranked plan DB, the
persistent autotune cache and, with ``differentiable=True`` (the
default), the ``repro.grad`` custom-VJP wrappers — so ``jax.grad`` of a
captured loss runs derived-spec generated kernels on the backward tape
too.  Ineligible sites re-bind their original equation untouched.

Higher-order primitives are re-emitted structurally so rewriting reaches
inside them:

  ======================  ==============================================
  primitive               re-emission
  ======================  ==============================================
  ``pjit`` / calls        inlined (the caller's ``jit`` re-fuses)
  ``scan``                rebuilt with ``lax.scan`` over the rewritten body
  ``while``               rebuilt with ``lax.while_loop``
  ``cond``                rebuilt with ``lax.switch``
  ``remat2``              rebuilt with ``jax.checkpoint`` (policy kept)
  ``custom_jvp/vjp_call`` re-bound **unmodified** unless the primal
                          jaxpr contains a dispatchable site.  Unmodified
                          re-bind keeps the custom derivative — crucially
                          including ``repro.ops``'s own custom-VJP sites
                          already present in the traced function, whose
                          primal is a ``pallas_call`` JAX cannot
                          differentiate (inlining those would break
                          ``jax.grad`` of every captured model that
                          already routes through ``ops`` on the kernel
                          path).  When the primal *does* contain a
                          dispatchable GEMM, the primal is inlined so the
                          site dispatches, and JAX re-derives the
                          gradient through the dispatched op's own VJP —
                          a user-supplied custom derivative around such a
                          site is superseded.
  ======================  ==============================================

Anything else that carries a sub-jaxpr is bound unmodified, and the
harvest report marks the sites inside it as fallback-by-containment.
Per-equation classification verdicts are memoized on the traced entry
(they depend only on avals + the interpret flag), so replaying a cached
signature does no re-classification work.

Numerics: a dispatched site accumulates in float32 and casts to the
equation's original output dtype, like every ``ops`` entry point; the
equation's ``precision`` hint is dropped (the generated kernel is always
the highest-precision MXU path).
"""

from __future__ import annotations

import os
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
from jax import core as jcore
from jax import lax

from .harvest import CaptureReport, classify_dot_general, harvest_jaxpr


def _interpret_default() -> bool:
    """Kernel dispatch needs a TPU or the Pallas interpreter; the
    ``REPRO_INTERPRET=1`` switch turns the latter on for CPU CI."""
    return os.environ.get("REPRO_INTERPRET", "") == "1"


class _Ctx:
    __slots__ = (
        "interpret", "dispatch", "quant", "site_memo", "contains_memo",
        "analysis_memo",
    )

    def __init__(self, interpret: bool, dispatch: bool = True,
                 quant: Optional[str] = None,
                 site_memo: Optional[dict] = None,
                 contains_memo: Optional[dict] = None,
                 analysis_memo: Optional[dict] = None):
        self.interpret = interpret
        self.dispatch = dispatch
        self.quant = quant
        # id(eqn) -> CaptureSite, id(jaxpr) -> bool / JaxprAnalysis; keyed
        # by identity, which is stable for the lifetime of the traced
        # _Entry that owns both the jaxpr and these memos
        self.site_memo = {} if site_memo is None else site_memo
        self.contains_memo = {} if contains_memo is None else contains_memo
        self.analysis_memo = {} if analysis_memo is None else analysis_memo

    def analyze(self, jaxpr):
        """Per-level fused-pattern facts (attention motifs, grouped taint),
        the same pass the harvest report is built from."""
        from .harvest import analyze_jaxpr

        hit = self.analysis_memo.get(id(jaxpr))
        if hit is None:
            hit = analyze_jaxpr(jaxpr, interpret=self.interpret)
            self.analysis_memo[id(jaxpr)] = hit
        return hit

    def classify(self, eqn, grouped_lhs: bool = False) -> "object":
        site = self.site_memo.get(id(eqn))
        if site is None:
            site = classify_dot_general(
                eqn.invars[0].aval, eqn.invars[1].aval,
                eqn.outvars[0].aval, eqn.params,
                interpret=self.interpret,
                grouped_lhs=grouped_lhs,
            )
            self.site_memo[id(eqn)] = site
        return site

    def contains_dispatchable(self, closed: jcore.ClosedJaxpr) -> bool:
        """Whether rewriting can reach a dispatchable site inside
        ``closed`` (recursing only through re-emittable primitives, like
        the rewriter itself does)."""
        from .harvest import REWRITABLE_HOPS, _sub_jaxprs

        jaxpr = closed.jaxpr if isinstance(
            closed, jcore.ClosedJaxpr
        ) else closed
        hit = self.contains_memo.get(id(jaxpr))
        if hit is not None:
            return hit
        found = False
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "dot_general":
                if self.classify(eqn).dispatched:
                    found = True
                    break
            elif eqn.primitive.name in REWRITABLE_HOPS:
                if any(
                    self.contains_dispatchable(sub)
                    for _, sub in _sub_jaxprs(eqn)
                ):
                    found = True
                    break
        self.contains_memo[id(jaxpr)] = found
        return found


def _bind(eqn, invals):
    """Re-bind an equation exactly as traced (``core.eval_jaxpr``'s
    mechanism): ``get_bind_params`` reconstructs the callable params of
    custom_jvp/vjp-style primitives, so their custom derivatives — and
    hence differentiability of e.g. ``pallas_call``-backed primals —
    survive the replay."""
    subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
    out = eqn.primitive.bind(*subfuns, *invals, **bind_params)
    return list(out) if eqn.primitive.multiple_results else [out]


def _apply_site(site, lhs, rhs, interpret: bool, quant: Optional[str] = None):
    """Evaluate a dispatched site through its ``repro.ops`` entry point.

    ``quant`` threads the capture-level quantization policy into the
    ``dense`` entry point only — projections are the weight-heavy sites
    the int8/fp8 tier targets; the other entry points stay full-precision
    (the quant tier is inference-oriented and has no custom VJP).
    """
    from .. import ops

    if site.op == "dense":
        x = lhs.reshape(-1, lhs.shape[-1]) if lhs.ndim > 2 else lhs
        out = ops.dense(
            x, rhs, out_dtype=site.out_dtype, interpret=interpret,
            quant=quant,
        )
        return out.reshape(site.out_shape)
    if site.op == "dense_transposed":
        return ops.dense_transposed(
            lhs, rhs, out_dtype=site.out_dtype, interpret=interpret
        )
    if site.op == "batched_dense":
        return ops.batched_dense(
            lhs, rhs, out_dtype=site.out_dtype, interpret=interpret
        )
    if site.op == "grouped_dense":
        b, m, d = site.lhs_shape
        out = ops.grouped_dense(
            lhs.reshape(b * m, d), rhs, (m,) * b,
            out_dtype=site.out_dtype, interpret=interpret,
        )
        return out.reshape(site.out_shape)
    raise AssertionError(f"unhandled capture op {site.op!r}")


def _eval_jaxpr(
    closed: jcore.ClosedJaxpr, args, ctx: _Ctx,
) -> List[Any]:
    jaxpr = closed.jaxpr
    env: Dict[jcore.Var, Any] = {}

    def read(a):
        return a.val if isinstance(a, jcore.Literal) else env[a]

    def write_all(vs, vals):
        for v, val in zip(vs, vals):
            env[v] = val

    write_all(jaxpr.constvars, closed.consts)
    write_all(jaxpr.invars, args)

    analysis = ctx.analyze(jaxpr)

    for i, eqn in enumerate(jaxpr.eqns):
        name = eqn.primitive.name

        if ctx.dispatch:
            motif = analysis.motifs.get(id(eqn))
            if motif is not None and motif.site.dispatched:
                # terminal of a matched attention chain: the whole region
                # collapses into one fused op on the chain's roots
                from .. import ops

                out = ops.attention(
                    read(motif.q), read(motif.k), read(motif.v),
                    causal=motif.causal,
                    out_dtype=motif.site.out_dtype,
                    interpret=ctx.interpret,
                )
                write_all(eqn.outvars, [out])
                continue
            owner = analysis.interior.get(id(eqn))
            if owner is not None and \
                    analysis.motifs[owner].site.dispatched:
                # interior of a dispatching motif: its value is never
                # observed outside the chain (verified at match time)
                continue

        invals = [read(x) for x in eqn.invars]

        if name == "dot_general":
            site = ctx.classify(
                eqn, grouped_lhs=id(eqn) in analysis.grouped
            )
            if ctx.dispatch and site.dispatched:
                outs = [_apply_site(
                    site, invals[0], invals[1], ctx.interpret, ctx.quant
                )]
            else:
                outs = _bind(eqn, invals)

        elif name in ("pjit", "closed_call", "core_call"):
            inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            outs = _eval_jaxpr(inner, invals, ctx)

        elif name in ("remat2", "remat", "checkpoint"):
            inner = eqn.params["jaxpr"]  # open Jaxpr, no consts

            def body(*a, _inner=inner):
                return _eval_jaxpr(jcore.ClosedJaxpr(_inner, ()), a, ctx)

            outs = jax.checkpoint(
                body,
                policy=eqn.params.get("policy"),
                prevent_cse=eqn.params.get("prevent_cse", True),
            )(*invals)

        elif name == "scan":
            p = eqn.params
            nc, ncar = p["num_consts"], p["num_carry"]
            consts = invals[:nc]
            init = tuple(invals[nc:nc + ncar])
            xs = tuple(invals[nc + ncar:])
            body_jaxpr = p["jaxpr"]

            def body(carry, x, _j=body_jaxpr, _c=tuple(consts), _n=ncar):
                res = _eval_jaxpr(_j, [*_c, *carry, *x], ctx)
                return tuple(res[:_n]), tuple(res[_n:])

            carry_out, ys = lax.scan(
                body, init, xs,
                length=p["length"], reverse=p["reverse"],
                unroll=p.get("unroll", 1),
            )
            outs = [*carry_out, *ys]

        elif name == "while":
            p = eqn.params
            cn, bn = p["cond_nconsts"], p["body_nconsts"]
            cconsts, bconsts = invals[:cn], invals[cn:cn + bn]
            init = tuple(invals[cn + bn:])
            cond_j, body_j = p["cond_jaxpr"], p["body_jaxpr"]
            outs = list(lax.while_loop(
                lambda c: _eval_jaxpr(cond_j, [*cconsts, *c], ctx)[0],
                lambda c: tuple(_eval_jaxpr(body_j, [*bconsts, *c], ctx)),
                init,
            ))

        elif name == "cond":
            branches = eqn.params["branches"]
            idx, ops_ = invals[0], invals[1:]
            fns = [
                (lambda *a, _b=b: tuple(_eval_jaxpr(_b, a, ctx)))
                for b in branches
            ]
            outs = list(lax.switch(idx, fns, *ops_))

        elif name in (
            "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
        ):
            inner = eqn.params.get("call_jaxpr") or eqn.params["fun_jaxpr"]
            if ctx.dispatch and ctx.contains_dispatchable(inner):
                # a dispatchable GEMM lives inside: inline the primal so
                # it reaches ops; the dispatched op's own VJP takes over
                outs = _eval_jaxpr(inner, invals, ctx)
            else:
                # keep the custom derivative intact — this is the path
                # repro.ops's own custom-VJP sites take (their primal is
                # a pallas_call, not differentiable if inlined)
                outs = _bind(eqn, invals)

        else:
            outs = _bind(eqn, invals)

        write_all(eqn.outvars, outs)

    return [read(v) for v in jaxpr.outvars]


# ---------------------------------------------------------------------------
# the user-facing wrapper
# ---------------------------------------------------------------------------


class _Entry:
    __slots__ = ("closed", "out_tree", "report", "site_memo", "contains_memo")

    def __init__(self, closed, out_tree, report):
        self.closed, self.out_tree, self.report = closed, out_tree, report
        self.site_memo: dict = {}
        self.contains_memo: dict = {}


class CapturedFunction:
    """``optimize(fn)`` result: trace-once, dispatch-per-call wrapper.

    Shape-specialized like ``jit``: the first call for an input signature
    traces ``fn`` (via ``jax.make_jaxpr``, so abstract
    ``ShapeDtypeStruct`` inputs work too — see ``report_for``) and
    harvests its GEMM sites; subsequent calls replay the rewritten jaxpr.
    Differentiable and jittable: replay just re-binds JAX primitives, and
    dispatched sites carry ``repro.grad`` custom VJPs.
    """

    def __init__(
        self, fn: Callable, *,
        interpret: Optional[bool] = None,
        dispatch: bool = True,
        label: str = "",
        quant: Optional[str] = None,
    ):
        self._fn = fn
        self._interpret = (
            _interpret_default() if interpret is None else bool(interpret)
        )
        self._dispatch = dispatch
        self._quant = quant
        self._label = label or getattr(fn, "__name__", "captured")
        self._entries: Dict[Tuple, _Entry] = {}

    # -- tracing ------------------------------------------------------------

    @staticmethod
    def _signature(flat_args) -> Tuple:
        return tuple(
            (tuple(getattr(a, "shape", ())), str(getattr(a, "dtype", type(a))))
            for a in flat_args
        )

    def _entry_for(self, args, kwargs) -> Tuple[_Entry, List[Any], Any]:
        flat, in_tree = jax.tree.flatten((args, kwargs))
        key = (in_tree, self._signature(flat))
        entry = self._entries.get(key)
        if entry is None:
            out_store: Dict[str, Any] = {}

            def flat_fn(*flat_in):
                a, k = jax.tree.unflatten(in_tree, flat_in)
                out = self._fn(*a, **k)
                out_flat, out_tree = jax.tree.flatten(out)
                out_store["tree"] = out_tree
                return out_flat

            from ..obs import counter, span

            with span("capture.trace", label=self._label):
                closed = jax.make_jaxpr(flat_fn)(*flat)
            with span("capture.harvest", label=self._label):
                report = harvest_jaxpr(
                    closed, interpret=self._interpret, label=self._label,
                )
            if not self._dispatch:
                for s in report.sites:
                    if s.dispatched:
                        s.status = "fallback"
                        s.reason = "dispatch disabled (harvest-only capture)"
            # per-signature dispatch telemetry: aggregate counts plus a
            # per-op breakdown (capture.dispatched.dense etc.) so a fleet
            # dump shows WHICH entry points the model's GEMMs route to
            counter("capture.harvested").inc(report.harvested)
            counter("capture.dispatched").inc(report.dispatched)
            counter("capture.fallback").inc(report.fallback)
            for s in report.sites:
                if s.dispatched:
                    counter(f"capture.dispatched.{s.op}").inc()
            entry = _Entry(closed, out_store["tree"], report)
            self._entries[key] = entry
        return entry, flat, in_tree

    # -- calling ------------------------------------------------------------

    def __call__(self, *args, **kwargs):
        entry, flat, _ = self._entry_for(args, kwargs)
        outs = _eval_jaxpr(
            entry.closed, flat,
            _Ctx(self._interpret, self._dispatch, quant=self._quant,
                 site_memo=entry.site_memo,
                 contains_memo=entry.contains_memo),
        )
        return jax.tree.unflatten(entry.out_tree, outs)

    # -- reporting ----------------------------------------------------------

    def report_for(self, *args, **kwargs) -> CaptureReport:
        """The harvest report for this input signature (traces if needed).

        Accepts concrete arrays or ``jax.ShapeDtypeStruct`` trees — no
        allocation or execution happens for abstract inputs.
        """
        entry, _, _ = self._entry_for(args, kwargs)
        return entry.report

    @property
    def reports(self) -> List[CaptureReport]:
        """Reports of every input signature traced so far."""
        return [e.report for e in self._entries.values()]

    @property
    def interpret(self) -> bool:
        return self._interpret


def optimize(
    fn: Callable, *,
    interpret: Optional[bool] = None,
    dispatch: bool = True,
    label: str = "",
    quant: Optional[str] = None,
) -> CapturedFunction:
    """Capture ``fn`` and dispatch its eligible GEMMs through ``repro.ops``.

    ``interpret=None`` (default) reads ``$REPRO_INTERPRET`` — on a TPU the
    flag is irrelevant (kernels run natively); on CPU set it to run the
    generated kernels under the Pallas interpreter (CI/conformance mode).
    ``dispatch=False`` degrades to a pure harvest: the function replays
    byte-identically (every equation re-bound as traced) but the report
    still says what *would* dispatch.
    ``quant`` ('int8' | 'fp8') routes dispatched ``dense`` sites through
    the dynamic-quantized tier (``ops.dense(..., quant=...)``) — an
    inference-only policy: the quant path has no custom VJP, so don't
    ``jax.grad`` a quantized capture.
    """
    return CapturedFunction(
        fn, interpret=interpret, dispatch=dispatch, label=label, quant=quant
    )


def capture_report(
    fn: Callable, *args, interpret: Optional[bool] = None, label: str = "",
    **kwargs,
) -> CaptureReport:
    """One-shot harvest of ``fn`` at the given (possibly abstract) inputs."""
    return CapturedFunction(
        fn, interpret=interpret, label=label
    ).report_for(*args, **kwargs)
