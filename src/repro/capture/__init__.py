"""repro.capture — whole-model GEMM capture into the plan-DB pipeline.

PRs 1-3 built a per-op pipeline: a call site hand-rewired to ``repro.ops``
gets cost-guided search (``repro.search``), ranked plans, persistent
autotuning (``repro.codegen``) and derived-spec backward kernels
(``repro.grad``).  Everything else — the whole model zoo under
``repro.models`` — still lowered its GEMMs through plain ``dot_general``.
This package closes that gap at the jaxpr level, the move Linnea
(arXiv:1912.12924) and the LAMP survey (arXiv:1911.09421) frame as the
real prize: mapping *whole expressions*, not single kernels, onto
optimized primitives.

    from repro import capture

    loss_c = capture.optimize(loss_fn)        # trace-once wrapper
    loss_c(params, batch)                     # eligible GEMMs -> ops/plan DB
    jax.grad(loss_c)(params, batch)           # bwd GEMMs: derived-spec kernels
    loss_c.report_for(params, batch).summary()
    # "capture[loss_fn]: 18 site(s) harvested, 15 dispatched, 3 fallback"

Layers:

  ``harvest``   walk a jaxpr (recursing through scan/remat/pjit/...),
                classify every ``dot_general`` into a ``ContractionSpec``
                named by ``core.enumerate`` — so each site owns the same
                plan-DB/autotune keys a hand-rewired op would — and report
                dispatched vs fallback per site, with reasons.
  ``rewrite``   ``optimize(fn)``: re-emit the function with eligible sites
                dispatched through ``repro.ops`` (differentiable via
                ``repro.grad``), ineligible sites re-bound untouched.
  ``sweep``     abstract whole-model harvest (ShapeDtypeStruct tracing; no
                allocation) + offline sweep of the harvested GEMM set,
                fwd+bwd, into the ranked plan DB.

Integration points: ``launch.steps.make_train_step(capture=True)`` /
``launch.train --capture`` (training through captured losses),
``launch.serve --capture`` (warm + sweep a serving model's harvested
specs), ``scripts/search_sweep.py --from-model`` (offline fleet sweeps)
and the ``capture.*`` rows of ``benchmarks/kernel_bench.py``.
"""

from .harvest import (
    REWRITABLE_HOPS,
    SUPPORTED_DTYPES,
    CaptureReport,
    CaptureSite,
    classify_dot_general,
    harvest_jaxpr,
    spec_key,
)
from .rewrite import CapturedFunction, capture_report, optimize
from .sweep import (
    DEMO_BATCH,
    DEMO_SEQ,
    demo_configs,
    model_capture,
    model_gemm_specs,
    sweep_captured,
)

__all__ = [
    "CaptureReport",
    "CaptureSite",
    "CapturedFunction",
    "DEMO_BATCH",
    "DEMO_SEQ",
    "REWRITABLE_HOPS",
    "SUPPORTED_DTYPES",
    "capture_report",
    "classify_dot_general",
    "demo_configs",
    "harvest_jaxpr",
    "model_capture",
    "model_gemm_specs",
    "optimize",
    "spec_key",
    "sweep_captured",
]
