"""Model-level harvest + offline sweep: a config's full GEMM set in one pass.

Bridges ``capture`` to the search pipeline: trace a model's train loss /
prefill / decode step **abstractly** (``jax.ShapeDtypeStruct`` inputs — no
parameter allocation, so harvesting a 400B config costs only a trace),
collect the dispatched sites' ContractionSpecs, and run each through
``search.search_schedule`` — with ``with_grads`` the derived backward
specs (``grad.derive``) are swept alongside, so one offline pass readies
ranked plans for the model's forward *and* backward GEMM traffic.

Consumers: ``scripts/search_sweep.py --from-model``, ``serve --capture``
and the CI capture-report artifact (``scripts/capture_report.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..configs.base import ModelConfig
from .harvest import CaptureReport, spec_key
from .rewrite import CapturedFunction

#: trace points a model exposes to the harvester
KINDS = ("train", "prefill", "decode")


def _abstract_params(cfg: ModelConfig, api):
    return jax.eval_shape(lambda key: api.init(cfg, key)[0], jax.random.key(0))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def model_capture(
    cfg: ModelConfig,
    *,
    batch: int,
    seq: int,
    kind: str = "train",
    interpret: Optional[bool] = None,
    dispatch: bool = True,
) -> Tuple[CapturedFunction, CaptureReport]:
    """Capture one model entry point abstractly; returns (fn, report).

    ``kind``: ``train`` traces the loss (the GEMM set training runs
    forward; with ``with_grads`` sweeps, its derived specs cover the
    backward), ``prefill``/``decode`` trace the serving steps.
    """
    from ..configs.base import ShapeConfig
    from ..models.api import batch_spec, get_api

    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
    api = get_api(cfg)
    p = _abstract_params(cfg, api)
    shape = ShapeConfig(f"capture_{kind}", seq, batch,
                        "train" if kind == "train" else "prefill")
    b_sds = {
        name: _sds(shp, dt)
        for name, (shp, dt) in batch_spec(cfg, shape).items()
    }

    if kind == "train":
        fn = lambda params, bt: api.loss(params, cfg, bt)  # noqa: E731
        args = (p, b_sds)
    elif kind == "prefill":
        fn = lambda params, bt: api.prefill(params, cfg, bt, seq)  # noqa: E731
        args = (p, b_sds)
    else:
        caches = jax.eval_shape(lambda: api.cache_init(cfg, batch, seq))
        toks = _sds((batch, 1), np.int32)
        fn = lambda params, c, t: api.decode_step(  # noqa: E731
            params, cfg, c, t
        )
        args = (p, caches, toks)

    captured = CapturedFunction(
        fn, interpret=interpret, dispatch=dispatch,
        label=f"{cfg.arch_id}:{kind}",
    )
    report = captured.report_for(*args)
    return captured, report


def model_gemm_specs(
    cfg: ModelConfig,
    *,
    batch: int,
    seq: int,
    kinds: Sequence[str] = ("train",),
    interpret: Optional[bool] = None,
) -> List[Tuple[str, object, str]]:
    """Deduplicated ``(label, spec, dtype)`` GEMM set across trace points."""
    seen: Dict[Tuple, Tuple[str, object, str]] = {}
    for kind in kinds:
        _, report = model_capture(
            cfg, batch=batch, seq=seq, kind=kind, interpret=interpret,
        )
        for spec, dtype in report.unique_specs():
            seen.setdefault(
                spec_key(spec, dtype), (f"{kind}:{spec.name}", spec, dtype)
            )
    return list(seen.values())


def sweep_captured(
    points: Sequence[Tuple[str, object, str]],
    *,
    with_grads: bool = True,
    plan_db=None,
    beam_width: int = 4,
    topk: int = 2,
    interpret: bool = True,
    measure: bool = True,
    repeats: int = 1,
    verbose: bool = False,
    mesh_shape=None,
    quant=None,
) -> int:
    """Search + persist ranked plans for every harvested GEMM point.

    Each point expands through ``search.space.sweep_specs`` (fwd plus the
    derived dA/dB/... specs when ``with_grads``), so the plan DB ends up
    covering the captured model's full fwd+bwd GEMM traffic.  With
    ``mesh_shape`` ('2x4') every sweep point is *additionally* swept at
    the mesh tier, persisting sharded ladders under the mesh-qualified
    keys — the whole-model analogue of ``scripts/search_sweep.py --mesh``:
    a captured model then serves/trains through sharded generated kernels
    whenever a matching mesh is active (``ops._mesh_plan_kernel``).
    With ``quant`` ('int8' | 'fp8') every *forward* sweep point also gets
    a quantized leg — the spec re-searched at the low-precision tier under
    its dtype-qualified plan key — so a quantized capture/serve run finds
    its ranked plans warm.  Quant legs run at mesh=None only (the quant
    tier, like the fused families, has no mesh lowering yet) and skip
    fused/derived specs that refuse quantization.
    Returns the number of (spec, dtype, mesh) sweep points persisted.
    """
    from ..core.enumerate import QUANT_FORMATS, quantize_spec
    from ..search import default_plan_db, search_schedule, sweep_specs

    db = plan_db if plan_db is not None else default_plan_db()
    if quant is not None and quant not in QUANT_FORMATS:
        raise ValueError(
            f"quant must be one of {sorted(QUANT_FORMATS)}, got {quant!r}"
        )
    n = 0
    meshes = [None] + ([mesh_shape] if mesh_shape is not None else [])
    for label, spec, dtype in points:
        for sub_label, sub in sweep_specs(spec, with_grads=with_grads):
            legs = [(sub_label, sub, np.dtype(dtype), meshes)]
            if quant is not None and sub_label == "fwd":
                try:
                    qspec = quantize_spec(sub, fmt=quant)
                    qdt = np.dtype(QUANT_FORMATS[quant].dtype)
                except (NotImplementedError, ValueError, TypeError):
                    qspec = None  # fused family / unregistered fp8 dtype
                if qspec is not None:
                    legs.append(
                        (f"{sub_label}@{quant}", qspec, qdt, [None])
                    )
            for leg_label, leg_spec, leg_dt, leg_meshes in legs:
                for ms in leg_meshes:
                    res = search_schedule(
                        leg_spec,
                        dtype=leg_dt,
                        beam_width=beam_width,
                        topk=topk,
                        interpret=interpret,
                        measure=measure,
                        repeats=repeats,
                        plan_db=db,
                        mesh_shape=ms,
                    )
                    n += 1
                    if verbose:
                        from ..obs import log

                        best = res.best
                        t = ("-" if best.measured_s is None
                             else f"{best.measured_s * 1e3:.2f}ms")
                        at = f"@mesh={res.mesh}" if res.mesh else ""
                        log.info("capture-sweep",
                                 f"{label}/{leg_label}{at} "
                                 f"dtype={leg_dt} best={t} (db={db.path})")
    return n


# ---------------------------------------------------------------------------
# demo configs — the capture conformance trio
# ---------------------------------------------------------------------------


def demo_configs() -> Dict[str, ModelConfig]:
    """Three tiny, 128-aligned configs (dense / MoE / SSM) used by
    ``tests/test_capture.py``, the ``capture.*`` bench rows and the CI
    capture-report artifact.

    Derived from the real arch smokes but with extents snapped to the
    dense kernel's 128-alignment so the 2-D projection sites actually
    dispatch in interpret mode (the point of the conformance run);
    ``float32`` keeps the fwd/bwd comparison tolerances tight.
    """
    from ..configs import get_config

    dense = dataclasses.replace(
        get_config("qwen3-8b").smoke(),
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, head_dim=64,
        d_ff=128, vocab=256, dtype="float32",
    )
    moe_base = get_config("kimi-k2-1t-a32b").smoke()
    moe = dataclasses.replace(
        moe_base,
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, head_dim=64,
        d_ff=128, vocab=256, dtype="float32",
        moe=dataclasses.replace(
            moe_base.moe, n_experts=4, top_k=2, expert_ff=64,
            first_dense=1, dense_ff=128, shared_expert_ff=0,
        ),
    )
    ssm_base = get_config("mamba2-130m").smoke()
    ssm = dataclasses.replace(
        ssm_base,
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=0, head_dim=64,
        d_ff=128, vocab=256, dtype="float32",
    )
    return {"dense": dense, "moe": moe, "ssm": ssm}


#: (batch, seq) used with the demo configs: batch*seq = 128 keeps the
#: flattened token dim aligned for the dense-kernel dispatch predicate
DEMO_BATCH, DEMO_SEQ = 2, 64
