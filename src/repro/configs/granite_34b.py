"""Granite-34B-Code — dense, MQA (kv=1) [arXiv:2405.04324; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24_576, vocab=49_152, rope_theta=10_000.0,
)
