"""Mamba2-130M — attention-free SSD (state-space duality)
[arXiv:2405.21060]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=24, n_kv_heads=0,
    d_ff=0, vocab=50_280, rope_theta=0.0,
    ssm=SSMConfig(d_state=128, expand=2, headdim=64, d_conv=4, chunk=256),
)
