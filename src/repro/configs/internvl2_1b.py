"""InternVL2-1B — ViT frontend STUB + InternLM2-like 1B LM backbone
[arXiv:2404.16821; hf].  input_specs feeds precomputed patch embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    head_dim=64, d_ff=4864, vocab=151_655, rope_theta=1_000_000.0,
)
