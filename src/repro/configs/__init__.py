"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

import importlib
from typing import Dict, List

from .base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES,
    cell_is_applicable,
)

_MODULES = {
    "deepseek-7b": "deepseek_7b",
    "qwen3-8b": "qwen3_8b",
    "granite-34b": "granite_34b",
    "qwen2-72b": "qwen2_72b",
    "whisper-base": "whisper_base",
    "internvl2-1b": "internvl2_1b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "mamba2-130m": "mamba2_130m",
    "zamba2-2.7b": "zamba2_2p7b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {', '.join(ARCH_IDS)}"
        )
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
