"""Model/arch configuration schema.

One ``ModelConfig`` covers all ten assigned families; family-specific fields
are simply unused elsewhere.  ``smoke()`` produces the reduced-config variant
used by the per-arch CPU smoke tests (same family/topology, tiny extents).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_ff: int
    moe_every: int = 1          # MoE layer every Nth layer (1 = all)
    shared_expert_ff: int = 0   # 0 = no shared expert
    first_dense: int = 0        # first N layers stay dense
    dense_ff: int = 0           # d_ff of the dense layers (if any)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    expand: int = 2
    headdim: int = 64
    d_conv: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    act: str = "silu"            # MLP activation (silu = SwiGLU, gelu = GLU-free)
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0          # hybrid: shared attn block every N ssm layers
    enc_layers: int = 0          # encdec: encoder depth
    max_seq: int = 1 << 20
    dtype: str = "bfloat16"
    remat: bool = True           # activation checkpointing around each layer
    # attention flavour: "full" (quadratic, blockwise-computed) only for now;
    # ssm/hybrid archs are sub-quadratic by construction
    sliding_window: int = 0

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                expert_ff=32,
                shared_expert_ff=min(self.moe.shared_expert_ff, 32),
                dense_ff=min(self.moe.dense_ff, 64) if self.moe.dense_ff else 0,
            )
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(
                self.ssm, d_state=16, headdim=8, chunk=8
            )
        return dataclasses.replace(
            self,
            n_layers=max(2, self.attn_every or 2),
            d_model=32,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            head_dim=8,
            d_ff=64,
            vocab=97,
            enc_layers=2 if self.enc_layers else 0,
            moe=moe,
            ssm=ssm,
            dtype="float32",
        )


# --------------------------------------------------------------------------
# the assigned input-shape grid (LM transformer shapes)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch x shape) runs, with the skip reason per DESIGN.md."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (skip: full-attention arch)"
    return True, ""
