"""Zamba2-2.7B — Mamba2 backbone + weight-shared attention block every 6
SSM layers [arXiv:2411.15242; hf]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    head_dim=80, d_ff=10_240, vocab=32_000, attn_every=6,
    rope_theta=10_000.0,
    ssm=SSMConfig(d_state=64, expand=2, headdim=64, d_conv=4, chunk=256),
)
