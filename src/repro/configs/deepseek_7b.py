"""DeepSeek-7B — dense llama-arch [arXiv:2401.02954; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=102_400, rope_theta=10_000.0,
)
