"""Llama-4-Maverick-400B-A17B — MoE, 128 experts top-1 + shared expert,
MoE every other layer (matching ~400B total / ~17B active)
[hf:meta-llama/Llama-4-*]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=16_384, vocab=202_048, rope_theta=500_000.0,
    moe=MoEConfig(
        n_experts=128, top_k=1, expert_ff=8192,
        moe_every=2, shared_expert_ff=8192, dense_ff=16_384,
    ),
)
