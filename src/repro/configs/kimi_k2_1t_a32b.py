"""Kimi-K2-1T-A32B — trillion-parameter MoE: 384 experts top-8 + shared
expert, first layer dense (DeepSeek-V3-style) [arXiv:2501.kimi2].
Requires 8-bit optimizer moments to fit 512 x 16 GB (see optim/)."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    head_dim=112, d_ff=18_432, vocab=163_840, rope_theta=50_000.0,
    moe=MoEConfig(
        n_experts=384, top_k=8, expert_ff=2048,
        moe_every=1, first_dense=1, dense_ff=18_432,
        shared_expert_ff=2048,
    ),
)
