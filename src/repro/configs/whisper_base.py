"""Whisper-base — enc-dec audio backbone; conv frontend STUB
[arXiv:2212.04356].  6 encoder + 6 decoder layers, d=512, LN + GELU,
sinusoidal positions (rope disabled), tied embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-base", family="encdec",
    n_layers=6, enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51_865,
    rope_theta=0.0, act="gelu", qkv_bias=True, tie_embeddings=True,
)
