"""Fault-tolerant step loop: checkpoint/restart, straggler watchdog, elastic
restore.

``FaultTolerantLoop`` wraps any step function.  Behaviour under failure:
  * a step raising ``StepFailure`` (or any exception matching
    ``recoverable``) triggers restore-from-latest-checkpoint and replay —
    the data pipeline is deterministic in the step number, so replay is
    exact;
  * repeated failures at the same step escalate after ``max_retries``;
  * a straggler watchdog tracks per-step wall time and reports hosts/steps
    exceeding ``straggler_factor`` x the rolling median (on a real cluster
    this feeds the controller that re-schedules the slow host; here it is
    surfaced through the ``repro.obs`` metrics registry —
    ``fault.step_wall_s`` histogram, ``fault.last_step_wall_s`` /
    ``fault.step_median_s`` gauges, ``fault.straggler_events`` counter —
    and tested by clock injection in tests/test_fault.py).

Elasticity: checkpoints are layout-free (see checkpoint/), so a loop
restarted with a different mesh simply passes the new shardings to
``restore`` — exercised in tests/test_fault.py.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional


class StepFailure(RuntimeError):
    """Raised by a step function to simulate/flag a recoverable failure."""


@dataclasses.dataclass
class LoopConfig:
    checkpoint_every: int = 50
    max_retries: int = 3
    straggler_factor: float = 3.0
    straggler_window: int = 20


@dataclasses.dataclass
class LoopReport:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0
    straggler_events: List[int] = dataclasses.field(default_factory=list)
    step_times: List[float] = dataclasses.field(default_factory=list)


class FaultTolerantLoop:
    def __init__(
        self,
        *,
        step_fn: Callable[[int, Any], Any],       # (step, state) -> state
        save_fn: Callable[[int, Any], None],      # checkpoint writer
        restore_fn: Callable[[], tuple],          # () -> (step, state)
        config: Optional[LoopConfig] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        # None sentinel: a dataclass default instance here would be shared
        # by every loop ever constructed, so mutating one loop's config
        # (e.g. a test tightening straggler_factor) leaks into all others
        self.cfg = config if config is not None else LoopConfig()
        self.clock = clock
        self.report = LoopReport()

    def _watch(self, step: int, dt: float):
        from ..obs import counter, gauge, histogram

        times = self.report.step_times
        times.append(dt)
        gauge("fault.last_step_wall_s").set(dt)
        histogram("fault.step_wall_s").observe(dt)
        window = times[-self.cfg.straggler_window:]
        if len(window) >= 5:
            med = statistics.median(window[:-1])
            gauge("fault.step_median_s").set(med)
            if dt > self.cfg.straggler_factor * med:
                self.report.straggler_events.append(step)
                counter("fault.straggler_events").inc()

    def run(self, state: Any, start_step: int, num_steps: int) -> Any:
        step = start_step
        retries = 0
        end = start_step + num_steps
        while step < end:
            t0 = self.clock()
            try:
                state = self.step_fn(step, state)
            except StepFailure:
                self.report.failures += 1
                retries += 1
                if retries > self.cfg.max_retries:
                    raise
                step, state = self.restore_fn()
                self.report.restores += 1
                continue
            retries = 0
            self._watch(step, self.clock() - t0)
            self.report.steps_run += 1
            step += 1
            if step % self.cfg.checkpoint_every == 0:
                self.save_fn(step, state)
        self.save_fn(step, state)
        return state
