"""Framework-level dense ops.

``dense`` is the single entry point every model matmul goes through.  On TPU
backends it dispatches 2-D contractions to the Pallas blocked-matmul kernel
whose block shapes are the cost-model-chosen ``subdiv`` factors (see
``core.autotune`` / ``core.schedule``); on CPU and in the dry-run it lowers
to ``lax.dot_general`` so GSPMD can partition it.  This is where the paper's
technique meets the model zoo.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _use_pallas() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


def dense(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    """x: (..., D) @ w: (D, F) -> (..., F), f32 accumulation."""
    out_dtype = out_dtype or x.dtype
    if _use_pallas() and x.ndim == 2 and all(
        s % 128 == 0 for s in (*x.shape, w.shape[1])
    ):
        from ..kernels.matmul.ops import matmul

        return matmul(x, w).astype(out_dtype)
    return jnp.dot(
        x, w, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def weighted_dense(x, w, g, out_dtype=None):
    """sum_j x_.j w_jk g_j — paper eq 2, fused (kernel on TPU)."""
    out_dtype = out_dtype or x.dtype
    if _use_pallas() and x.ndim == 2:
        from ..kernels.fused_rnz.ops import weighted_matmul

        return weighted_matmul(x, w, g).astype(out_dtype)
    return jnp.dot(
        x * g[None, :], w, preferred_element_type=jnp.float32
    ).astype(out_dtype)
