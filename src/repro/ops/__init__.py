"""Framework-level dense ops, routed through the kernel generator.

``dense`` is the single entry point every model matmul goes through.  On
TPU backends (or with ``interpret=True``) 2-D contractions compile through
``repro.codegen``: the Schedule comes from the ranked plan database
(``repro.search`` — measured winners of the cost-guided variant search)
when a sweep has run for the shape, else from the persistent autotune
cache (``codegen.tune_schedule``), so a serving replica reuses the fleet's
searched/tuned block shapes instead of re-tuning at import time.  On CPU
and in the dry-run everything lowers to ``lax.dot_general`` so GSPMD can
partition it.  This is where the paper's technique meets the model zoo.

New scenario entry points (all generated — the repo had no kernels for
these before ``codegen`` existed):

  ``batched_dense``   out[b,i,k] = sum_j x[b,i,j] w[b,j,k]
  ``chain_dense``     out[i,l]   = sum_jk a[i,j] b[j,k] c[k,l]
  ``dense_transposed``out[i,k]   = sum_j a[j,i] b[j,k]
  ``weighted_dense``  out[i,k]   = sum_j x[i,j] w[j,k] g[j]  (paper eq 2;
                      generated replacement for kernels/fused_rnz)
  ``dense_act``       epilogue-fused dense+bias+norm+activation
                      (the generated replacement for kernels/fused_dense_act)

Whole-model entry: ``repro.capture.optimize(fn)`` harvests a traced
function's plain ``dot_general`` sites and dispatches the eligible ones
through these entry points — the predicates below (``_dense_kernel_ok``
etc.) are the shared single source of truth for what "eligible" means.

All entry points are **differentiable by default**: whenever the call
would dispatch to a generated kernel, ``differentiable=True`` routes
through the ``repro.grad`` custom_vjp wrappers, whose backward GEMMs are
derived ContractionSpecs (``grad.derive``) compiled through this same
plan-DB/autotune pipeline — so ``jax.grad`` of a loss built on these ops
runs generated kernels on both sides of the tape (``launch.steps``).  On
the non-kernel paths (CPU, unaligned shapes) the op stays a plain
einsum/dot, so JAX's native autodiff — forward mode included — applies
unchanged.  Pass ``differentiable=False`` to get the bare primal (no VJP
registered; ``jax.grad`` through a raw Pallas kernel raises).

Caveat: ``jax.custom_vjp`` supports reverse mode only, so forward-mode
autodiff (``jax.jvp`` / ``jax.jacfwd`` / ``jax.linearize``) raises
exactly where the generated-kernel dispatch fires (the raw Pallas kernel
has no JVP either way); everywhere else it works as before.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _use_pallas() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


def _mesh_plan_kernel(spec, dtype, *, epilogue=None, interpret=False):
    """Sharded generated kernel from a mesh-qualified plan, or None.

    When the calling context runs under a device mesh (``launch.mesh
    .set_mesh`` / ``with mesh:`` — checked at trace time), the plan DB is
    consulted under the mesh-shape-qualified key ('2x4'-style,
    ``search.plandb.plan_key(mesh=...)``) for the best rung that actually
    distributes (``best_sharded_entry`` — under a live mesh the operands
    are sharded, so a mesh ladder's single-device reference rungs do not
    apply).  A sharded plan whose mesh axes match the active mesh
    compiles through ``codegen.bind_mesh`` with the plan's measured
    collective strategy.  Any mismatch (axis names/sizes, no plan)
    returns None and the caller falls back to the unqualified lookup — a
    replica without mesh sweeps behaves exactly as before.
    """
    from .. import codegen
    from ..launch.mesh import active_mesh, mesh_shape_descriptor

    mesh = active_mesh()
    if mesh is None or getattr(mesh, "size", 1) <= 1:
        return None
    from ..search import default_plan_db, schedule_mesh_axes

    sched, entry = default_plan_db().best_sharded_entry(
        spec, np.dtype(dtype), mesh=mesh_shape_descriptor(mesh)
    )
    if sched is None:
        return None
    axes = schedule_mesh_axes(sched)
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    if any(shape.get(a) != n for a, n in axes.items()):
        return None
    return codegen.cached_compile(
        spec, sched, epilogue=epilogue, interpret=interpret,
        mesh=mesh, collective=entry.get("collective") or "psum",
    )


def _tuned_kernel(spec, dtype, *, epilogue=None, out_dtype=None,
                  interpret=False):
    """Generated kernel for ``spec``: searched plan first, tuned fallback.

    The ranked plan database (``repro.search``) is consulted before the
    analytic tuner: an offline ``scripts/search_sweep.py`` run or a
    ``serve --search-gemms`` warmup leaves a measured-best schedule there,
    and every later call for the same spec/shape/dtype picks it up.  When
    a device mesh is active the mesh-shape-qualified key is consulted
    first (``_mesh_plan_kernel``), so a ``--mesh`` sweep upgrades every
    op under that mesh to sharded generated kernels.  When a serving
    phase is active (``search.serving_phase`` — entered by the
    prefill/decode runners around their jitted steps) the
    phase-qualified ladder is consulted before the unphased one, so the
    decode runner's bandwidth-bound skinny GEMMs serve their own searched
    winner rather than the prefill ladder's.  With no plan on record this
    degrades to PR-1 behaviour (``codegen.tune_schedule`` + persistent
    autotune cache).
    """
    from .. import codegen

    # PlanDB.best_schedule already degrades corrupt/stale entries to a
    # miss; the catch here is for genuine breakage in the search package,
    # which must not take down serving — but must not be silent either.
    schedule = None
    try:
        from ..search import active_phase, default_plan_db

        kern = _mesh_plan_kernel(
            spec, dtype, epilogue=epilogue, interpret=interpret
        )
        if kern is not None:
            return kern
        phase = active_phase()
        if phase is not None:
            schedule = default_plan_db().best_schedule(
                spec, np.dtype(dtype), phase=phase
            )
        if schedule is None:
            schedule = default_plan_db().best_schedule(spec, np.dtype(dtype))
    except Exception as e:
        global _plan_db_warned
        if not _plan_db_warned:
            _plan_db_warned = True
            import warnings

            warnings.warn(
                f"search plan DB unavailable ({type(e).__name__}: {e}); "
                f"falling back to codegen.tune_schedule for all ops",
                RuntimeWarning,
                stacklevel=2,
            )
    if schedule is None:
        schedule = codegen.tune_schedule(spec, dtype=np.dtype(dtype))
    return codegen.cached_compile(
        spec, schedule, epilogue=epilogue, out_dtype=out_dtype,
        interpret=interpret,
    )


_plan_db_warned = False


def warm_dense_cache(shapes, dtype=jnp.bfloat16) -> int:
    """Pre-tune schedules for (m, k, n) GEMMs; returns #schedules readied.

    Called by serving entry points at startup so the first request never
    pays tuning latency; hits the persistent cache when the fleet has
    tuned these shapes before.
    """
    from .. import codegen
    from ..core.enumerate import matmul_spec

    count = 0
    for m, k, n in shapes:
        codegen.tune_schedule(matmul_spec(m, k, n), dtype=np.dtype(dtype))
        count += 1
    return count


def _dt_name(dtype) -> str:
    """Hashable dtype key for the grad factory caches."""
    return np.dtype(dtype).name


# -- kernel-dispatch predicates, shared with the grad.vjp backward passes --
# The custom_vjp wrapping is gated on exactly these: where an op lowers to
# a plain einsum/dot anyway, native JAX autodiff (fwd mode included) stays
# in charge and the wrapper would only subtract capability.


def _dense_kernel_ok(x, w, interpret: bool) -> bool:
    return (_use_pallas() or interpret) and x.ndim == 2 and all(
        s % 128 == 0 for s in (*x.shape, w.shape[1])
    )


def _batched_kernel_ok(x, w, interpret: bool) -> bool:
    return (_use_pallas() or interpret) and x.ndim == 3 and w.ndim == 3


def _generic_kernel_ok(interpret: bool) -> bool:
    return _use_pallas() or interpret


def _dense_raw(x, w, out_dtype, interpret):
    if _dense_kernel_ok(x, w, interpret):
        from ..core.enumerate import matmul_spec

        m, d = x.shape
        _, f = w.shape
        kern = _tuned_kernel(
            matmul_spec(m, d, f), x.dtype, interpret=interpret
        )
        return kern(x, w).astype(out_dtype)
    return jnp.dot(
        x, w, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def _dense_quant(x, w, fmt, out_dtype, interpret):
    """Dynamic-quantized dense: int8/fp8 storage, dequant epilogue.

    ``x`` is quantized per-tensor (one absmax scale), ``w`` per output
    channel (one scale per column of F) — the combined ``qscale = sx * sw``
    row is exactly what the generated kernel's dequant epilogue multiplies
    into the accumulator, so the kernel streams 1-byte operands and writes
    real-valued output in one pass.  Kernel-ineligible shapes take the
    dequantize-then-dot fallback with identical quantization semantics.
    """
    from ..core.enumerate import QUANT_FORMATS, quantized_matmul_spec
    from ..optim.quant import quantize_channels, quantize_tensor

    if fmt not in QUANT_FORMATS:
        raise ValueError(
            f"quant must be one of {sorted(QUANT_FORMATS)}, got {fmt!r}"
        )
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    qx, sx = quantize_tensor(x2, fmt)
    qw, sw = quantize_channels(w, fmt)
    qscale = (sx * sw).astype(jnp.float32)
    if _dense_kernel_ok(x2, w, interpret):
        from .. import codegen

        m, d = x2.shape
        f = w.shape[1]
        kern = _tuned_kernel(
            quantized_matmul_spec(m, d, f, fmt), qx.dtype,
            epilogue=codegen.Epilogue(dequant=True),
            out_dtype=jnp.float32, interpret=interpret,
        )
        out = kern(qx, qw, qscale=qscale)
    else:
        out = jnp.dot(
            qx.astype(jnp.float32), qw.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        ) * qscale[None, :]
    return out.reshape(*lead, w.shape[1]).astype(out_dtype)


def dense(x: jax.Array, w: jax.Array, out_dtype=None,
          interpret: bool = False, differentiable: bool = True,
          quant=None) -> jax.Array:
    """x: (..., D) @ w: (D, F) -> (..., F), f32 accumulation.

    With ``differentiable`` (the default), a call dispatching to the
    generated kernel goes through ``grad.dense_vjp``: same primal, plus a
    custom VJP whose dA/dB GEMMs compile through the generated-kernel
    pipeline under their own derived-spec keys (``matmul.dA`` /
    ``matmul.dB``).  Fallback paths stay natively differentiable.

    ``quant`` ('int8' | 'fp8') takes the low-precision tier instead:
    operands are dynamically quantized (x per-tensor, w per-channel), the
    contraction runs on the dtype-qualified searched kernel
    (``matmul@...@dtype=int8`` plans), and the scales are applied by the
    kernel's dequant epilogue.  The quant tier is inference-oriented —
    the quantize ops are differentiable only through the fallback path.
    """
    out_dtype = out_dtype or x.dtype
    if quant is not None:
        return _dense_quant(x, w, quant, out_dtype, interpret)
    if differentiable and _dense_kernel_ok(x, w, interpret):
        from ..grad import dense_vjp

        return dense_vjp(_dt_name(out_dtype), bool(interpret))(x, w)
    return _dense_raw(x, w, out_dtype, interpret)


def _weighted_kernel_ok(x, interpret: bool) -> bool:
    return (_use_pallas() or interpret) and x.ndim == 2


def _weighted_dense_raw(x, w, g, out_dtype, interpret):
    if _weighted_kernel_ok(x, interpret):
        from ..core.enumerate import weighted_matmul_spec

        m, d = x.shape
        _, f = w.shape
        kern = _tuned_kernel(
            weighted_matmul_spec(m, d, f), x.dtype, interpret=interpret
        )
        return kern(x, w, g).astype(out_dtype)
    return jnp.dot(
        x * g[None, :], w, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def weighted_dense(x, w, g, out_dtype=None, interpret: bool = False,
                   differentiable: bool = True):
    """sum_j x_.j w_jk g_j — paper eq 2, through the generator.

    Generated three-operand contraction (``weighted_matmul`` spec) with
    its own plan-DB/autotune keys; the hand-written ``kernels/fused_rnz``
    kernel remains as a verification baseline.  The backward dg spec is a
    genuine three-operand contraction (dg[j] = sum_ik g_out[i,k] A[i,j]
    B[j,k]) — a derived expression treated as a first-class mapping
    problem, per Linnea/LAMP.
    """
    out_dtype = out_dtype or x.dtype
    if differentiable and _weighted_kernel_ok(x, interpret):
        from ..grad import weighted_dense_vjp

        return weighted_dense_vjp(
            _dt_name(out_dtype), bool(interpret)
        )(x, w, g)
    return _weighted_dense_raw(x, w, g, out_dtype, interpret)


def _batched_dense_raw(x, w, out_dtype, interpret):
    if _batched_kernel_ok(x, w, interpret):
        from ..core.enumerate import batched_matmul_spec

        b, m, d = x.shape
        _, _, f = w.shape
        kern = _tuned_kernel(
            batched_matmul_spec(b, m, d, f), x.dtype, interpret=interpret
        )
        return kern(x, w).astype(out_dtype)
    return jnp.einsum(
        "bmd,bdf->bmf", x, w, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def batched_dense(x, w, out_dtype=None, interpret: bool = False,
                  differentiable: bool = True):
    """x: (B, M, D) @ w: (B, D, F) -> (B, M, F) through the generator."""
    out_dtype = out_dtype or x.dtype
    if differentiable and _batched_kernel_ok(x, w, interpret):
        from ..grad import batched_dense_vjp

        return batched_dense_vjp(_dt_name(out_dtype), bool(interpret))(x, w)
    return _batched_dense_raw(x, w, out_dtype, interpret)


def _chain_dense_raw(a, b, c, out_dtype, interpret):
    if _generic_kernel_ok(interpret):
        from ..core.enumerate import chain_matmul_spec

        m, k1 = a.shape
        _, k2 = b.shape
        _, n = c.shape
        kern = _tuned_kernel(
            chain_matmul_spec(m, k1, k2, n), a.dtype, interpret=interpret
        )
        return kern(a, b, c).astype(out_dtype)
    ab = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return jnp.dot(
        ab.astype(a.dtype), c, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def chain_dense(a, b, c, out_dtype=None, interpret: bool = False,
                differentiable: bool = True):
    """a @ b @ c without materializing the intermediate in HBM.

    The backward specs are three-operand contractions (e.g.
    ``chain_matmul.dB``: dB[j,k] = sum_il A[i,j] g[i,l] C[k,l]) — derived
    expressions treated as first-class mapping problems, per Linnea/LAMP.
    """
    out_dtype = out_dtype or a.dtype
    if differentiable and _generic_kernel_ok(interpret):
        from ..grad import chain_dense_vjp

        return chain_dense_vjp(_dt_name(out_dtype), bool(interpret))(a, b, c)
    return _chain_dense_raw(a, b, c, out_dtype, interpret)


def _dense_transposed_raw(a, b, out_dtype, interpret):
    if _generic_kernel_ok(interpret):
        from ..core.enumerate import transposed_matmul_spec

        d, m = a.shape
        _, f = b.shape
        kern = _tuned_kernel(
            transposed_matmul_spec(m, d, f), a.dtype, interpret=interpret
        )
        return kern(a, b).astype(out_dtype)
    return jnp.einsum(
        "dm,df->mf", a, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def dense_transposed(a, b, out_dtype=None, interpret: bool = False,
                     differentiable: bool = True):
    """a: (D, M) (stored transposed) , b: (D, F) -> (M, F) = a.T @ b."""
    out_dtype = out_dtype or a.dtype
    if differentiable and _generic_kernel_ok(interpret):
        from ..grad import dense_transposed_vjp

        return dense_transposed_vjp(
            _dt_name(out_dtype), bool(interpret)
        )(a, b)
    return _dense_transposed_raw(a, b, out_dtype, interpret)


def _dense_act_raw(x, w, beta, mean, var, *, act, eps, out_dtype, interpret):
    if _generic_kernel_ok(interpret):
        from .. import codegen
        from ..core.enumerate import matmul_spec

        m, d = x.shape
        _, f = w.shape
        epi = codegen.Epilogue(act=act, bias=True, norm=True, eps=eps)
        kern = _tuned_kernel(
            matmul_spec(m, d, f), x.dtype, epilogue=epi, interpret=interpret
        )
        return kern(x, w, bias=beta, mean=mean, var=var).astype(out_dtype)
    from ..kernels.fused_dense_act.ref import fused_dense_act_ref

    return fused_dense_act_ref(
        x, w, beta, mean, var, act=act, eps=eps
    ).astype(out_dtype)


def _attention_kernel_ok(q, interpret: bool) -> bool:
    return (_use_pallas() or interpret) and q.ndim == 3


def _attention_ref_jnp(q, k, v, *, causal, kv_lengths, out_dtype):
    """Pure-jnp fused-attention reference: f32 stable softmax + masks.

    Fully-masked rows (possible only under ``kv_lengths``) produce exact
    zeros, matching the generated kernel's ``l == 0`` guard.
    """
    import math

    h, s, d = q.shape
    t = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    sc = jnp.einsum(
        "hsd,htd->hst", q, k, preferred_element_type=jnp.float32
    ) * scale
    valid = jnp.ones((h, s, t), dtype=bool)
    if causal:
        row = jax.lax.broadcasted_iota(jnp.int32, (h, s, t), 1)
        col = jax.lax.broadcasted_iota(jnp.int32, (h, s, t), 2)
        valid &= col <= row
    if kv_lengths is not None:
        col = jax.lax.broadcasted_iota(jnp.int32, (h, s, t), 2)
        valid &= col < kv_lengths.astype(jnp.int32).reshape(h, 1, 1)
    sc = jnp.where(valid, sc, -jnp.inf)
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.exp(sc - jnp.where(jnp.isfinite(m), m, 0.0))
    p = jnp.where(valid, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(denom == 0.0, 1.0, denom)
    return jnp.einsum(
        "hst,hte->hse", p, v, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def _attention_raw(q, k, v, *, causal, kv_lengths, out_dtype, interpret):
    if _attention_kernel_ok(q, interpret):
        from ..core.enumerate import attention_spec

        h, s, d = q.shape
        t = k.shape[1]
        e = v.shape[2]
        kern = _tuned_kernel(
            attention_spec(h, s, t, d, e=e, causal=causal),
            q.dtype, interpret=interpret,
        )
        if kv_lengths is not None:
            return kern(q, k, v, kv_lengths=kv_lengths).astype(out_dtype)
        return kern(q, k, v).astype(out_dtype)
    return _attention_ref_jnp(
        q, k, v, causal=causal, kv_lengths=kv_lengths, out_dtype=out_dtype
    )


def attention(q, k, v, *, causal: bool = False, kv_lengths=None,
              out_dtype=None, interpret: bool = False,
              differentiable: bool = True):
    """Fused QK^T -> online-softmax -> PV through the searched kernel.

    q: (H, S, D), k: (H, T, D), v: (H, T, E) -> (H, S, E).  Scores are
    scaled by D^-0.5 and accumulated in f32; the KV axis runs as an
    in-schedule reduction tier carrying running max/sum in VMEM, so the
    (S, T) probability matrix never exists in HBM
    (``codegen.fused_gen``).  ``kv_lengths`` (per-head int32, PR 7's
    paged-KV convention) masks columns ``>= length``; rows with no valid
    column return exact zeros.

    Differentiable calls without lengths wrap in ``grad.attention_vjp``
    (flash-style recompute backward whose GEMMs are the hand-derived
    ``attention.dQ/.dK/.dV`` specs); ``kv_lengths`` + ``differentiable``
    routes to the natively-differentiable jnp reference instead.
    """
    out_dtype = out_dtype or q.dtype
    if q.ndim != 3 or k.ndim != 3 or v.ndim != 3:
        raise ValueError(
            f"attention expects 3-D (H, S|T, D|E) operands; got "
            f"{q.shape}, {k.shape}, {v.shape}"
        )
    if differentiable and kv_lengths is not None:
        return _attention_ref_jnp(
            q, k, v, causal=causal, kv_lengths=kv_lengths,
            out_dtype=out_dtype,
        )
    if differentiable and _attention_kernel_ok(q, interpret):
        from ..grad import attention_vjp

        return attention_vjp(
            bool(causal), _dt_name(out_dtype), bool(interpret)
        )(q, k, v)
    return _attention_raw(
        q, k, v, causal=causal, kv_lengths=kv_lengths,
        out_dtype=out_dtype, interpret=interpret,
    )


def _grouped_kernel_ok(x, interpret: bool) -> bool:
    return (_use_pallas() or interpret) and x.ndim == 2


def _grouped_ref_jnp(x, w, group_sizes, out_dtype):
    """Static per-group dot loop — the semantic definition of the op."""
    parts = []
    off = 0
    for g, size in enumerate(group_sizes):
        if size:
            parts.append(jax.lax.dot_general(
                x[off:off + size], w[g],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ))
        off += size
    if not parts:
        return jnp.zeros((x.shape[0], w.shape[-1]), out_dtype)
    return jnp.concatenate(parts, axis=0).astype(out_dtype)


def _grouped_raw(x, w, group_sizes, out_dtype, interpret):
    if x.shape[0] and _grouped_kernel_ok(x, interpret):
        from ..core.enumerate import grouped_matmul_spec

        kern = _tuned_kernel(
            grouped_matmul_spec(group_sizes, x.shape[1], w.shape[2]),
            x.dtype, interpret=interpret,
        )
        return kern(x, w).astype(out_dtype)
    return _grouped_ref_jnp(x, w, group_sizes, out_dtype)


def grouped_dense(x, w, group_sizes, *, out_dtype=None,
                  interpret: bool = False, differentiable: bool = True):
    """Ragged grouped GEMM: row block g of ``x`` hits expert matrix w[g].

    x: (N, K) with N = sum(group_sizes), w: (G, K, F) -> (N, F).  One
    searched kernel walks the static group offsets in its Pallas grid
    (``codegen.fused_gen``) instead of G separate dispatches — the MoE
    expert-FFN pattern (``models.moe``).  Empty and size-1 groups are
    legal; empty groups contribute no rows and cost no grid steps.

    The backward specs stay ragged (``grouped_matmul.dX/.dW`` are
    GroupedSpecs with the same sizes) — a plain einsum would wrongly sum
    over the group axis, so even the fallback VJP is a per-group loop.
    """
    out_dtype = out_dtype or x.dtype
    group_sizes = tuple(int(s) for s in group_sizes)
    if x.ndim != 2 or w.ndim != 3:
        raise ValueError(
            f"grouped_dense expects x (N, K) and w (G, K, F); got "
            f"{x.shape}, {w.shape}"
        )
    if len(group_sizes) != w.shape[0]:
        raise ValueError(
            f"{len(group_sizes)} group sizes for {w.shape[0]} expert slabs"
        )
    if sum(group_sizes) != x.shape[0]:
        raise ValueError(
            f"group sizes sum to {sum(group_sizes)} but x has "
            f"{x.shape[0]} rows"
        )
    if differentiable and x.shape[0] and _grouped_kernel_ok(x, interpret):
        from ..grad import grouped_vjp

        return grouped_vjp(
            group_sizes, _dt_name(out_dtype), bool(interpret)
        )(x, w)
    return _grouped_raw(x, w, group_sizes, out_dtype, interpret)


def dense_act(
    x, w, beta, mean, var,
    *, act: str = "gelu", eps: float = 1e-5,
    out_dtype=None, interpret: bool = False, differentiable: bool = True,
):
    """Generated dense + bias + normalization + activation (paper eqs 3-5).

    Subsumes ``kernels/fused_dense_act``: the epilogue runs on the f32
    accumulator tile before the store, so y and z never round-trip HBM.
    The custom backward (``grad.dense_act_vjp``) recomputes the accumulator
    with one extra GEMM, runs the elementwise epilogue VJP on it, and
    routes dacc through the derived dA/dB GEMM specs.
    """
    out_dtype = out_dtype or x.dtype
    if differentiable and _generic_kernel_ok(interpret):
        from ..grad import dense_act_vjp

        return dense_act_vjp(
            act, float(eps), _dt_name(out_dtype), bool(interpret)
        )(x, w, beta, mean, var)
    return _dense_act_raw(
        x, w, beta, mean, var,
        act=act, eps=eps, out_dtype=out_dtype, interpret=interpret,
    )
