"""Pure-jnp oracle for fused dense + norm + activation (paper eqs 3-5)."""

import jax
import jax.numpy as jnp

_ACTIVATIONS = {
    "relu": lambda z: jnp.maximum(z, 0.0),
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "id": lambda z: z,
}


def fused_dense_act_ref(x, w, beta, mean, var, *, act="gelu", eps=1e-5,
                        out_dtype=None):
    out_dtype = out_dtype or x.dtype
    y = jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) + beta.astype(jnp.float32)[None, :]
    z = (y - mean.astype(jnp.float32)[None, :]) * jax.lax.rsqrt(
        var.astype(jnp.float32)[None, :] + eps
    )
    return _ACTIVATIONS[act](z).astype(out_dtype)
