"""Fused dense + normalization + nonlinearity kernel — paper eqs 3-5:

    y_k^b = sum_i W_ik x_i^b + beta_k          (dense)
    z_k   = (y_k^b - E_k) / sqrt(V_k + eps)    (normalization, given stats)
    r_k   = h(z_k)                             (elementwise nonlinearity)

The paper's NN motivating example: the last two stages are low arithmetic
density, so materializing y and z wastes HBM round-trips.  Fusion rules
(eq 19/27) fold them into the matmul epilogue: they run on the accumulator
tile while it is still resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

_ACTIVATIONS = {
    "relu": lambda z: jnp.maximum(z, 0.0),
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "id": lambda z: z,
}


def _fused_dense_kernel(
    x_ref, w_ref, beta_ref, mean_ref, var_ref, o_ref, acc_ref,
    *, k_steps: int, act: str, eps: float,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        y = acc_ref[...] + beta_ref[...]
        z = (y - mean_ref[...]) * jax.lax.rsqrt(var_ref[...] + eps)
        o_ref[...] = _ACTIVATIONS[act](z).astype(o_ref.dtype)


def fused_dense_act_pallas(
    x: jax.Array,      # (B, I)
    w: jax.Array,      # (I, K)
    beta: jax.Array,   # (K,)
    mean: jax.Array,   # (K,)
    var: jax.Array,    # (K,)
    *,
    act: str = "gelu",
    eps: float = 1e-5,
    block_b: int,
    block_k: int,
    block_i: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    b, i = x.shape
    i2, k = w.shape
    assert i == i2 and beta.shape == mean.shape == var.shape == (k,)
    assert b % block_b == 0 and k % block_k == 0 and i % block_i == 0
    out_dtype = out_dtype or x.dtype
    k_steps = i // block_i
    row = lambda v: v.reshape(1, -1).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(
            _fused_dense_kernel, k_steps=k_steps, act=act, eps=eps
        ),
        grid=(b // block_b, k // block_k, k_steps),
        in_specs=[
            pl.BlockSpec((block_b, block_i), lambda bi, ki, ii: (bi, ii)),
            pl.BlockSpec((block_i, block_k), lambda bi, ki, ii: (ii, ki)),
            pl.BlockSpec((1, block_k), lambda bi, ki, ii: (0, ki)),
            pl.BlockSpec((1, block_k), lambda bi, ki, ii: (0, ki)),
            pl.BlockSpec((1, block_k), lambda bi, ki, ii: (0, ki)),
        ],
        out_specs=pl.BlockSpec((block_b, block_k), lambda bi, ki, ii: (bi, ki)),
        out_shape=jax.ShapeDtypeStruct((b, k), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_b, block_k), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w, row(beta), row(mean), row(var))
