"""Jit'd wrapper for fused dense+norm+activation: generated epilogue first.

The schedule-driven generator subsumes this kernel (an ``Epilogue`` on the
generated matmul); the hand-written ``fused_dense_act_pallas`` stays as
the verification baseline, reachable with ``use_generated=False``.
"""

from __future__ import annotations

import functools

import jax

from ...core.autotune import choose_matmul_blocks
from .fused_dense_act import fused_dense_act_pallas
from .ref import fused_dense_act_ref


def _generated(x, w, beta, mean, var, act, eps,
               block_b, block_k, block_i, interpret):
    from ... import codegen
    from ...core.enumerate import matmul_spec

    b, i = x.shape
    _, k = w.shape
    spec = matmul_spec(b, i, k)
    if block_b is None:
        # no caller-pinned blocks: the generator's tuner budgets the
        # resident reduce axis correctly (choose_matmul_blocks does not)
        schedule = codegen.tune_schedule(spec, dtype=x.dtype)
    else:
        schedule = codegen.default_schedule(
            spec, {"i": block_b, "k": block_k, "j": block_i}
        )
    epi = codegen.Epilogue(act=act, bias=True, norm=True, eps=eps)
    kern = codegen.cached_compile(
        spec, schedule, epilogue=epi, interpret=interpret
    )
    return kern(x, w, bias=beta, mean=mean, var=var)


@functools.partial(
    jax.jit,
    static_argnames=(
        "act", "eps", "block_b", "block_k", "block_i", "interpret",
        "use_generated",
    ),
)
def fused_dense_act(
    x, w, beta, mean, var,
    *, act: str = "gelu", eps: float = 1e-5,
    block_b: int | None = None,
    block_k: int | None = None,
    block_i: int | None = None,
    interpret: bool = False,
    use_generated: bool = True,
):
    if not interpret and jax.default_backend() != "tpu":
        return fused_dense_act_ref(x, w, beta, mean, var, act=act, eps=eps)
    b, i = x.shape
    _, k = w.shape
    if use_generated and block_b is None and block_k is None and block_i is None:
        return _generated(
            x, w, beta, mean, var, act, eps, None, None, None, interpret
        )
    if block_b is None or block_k is None or block_i is None:
        bb, bk, bi = choose_matmul_blocks(b, k, i, elem_bytes=x.dtype.itemsize)
        block_b, block_k, block_i = (
            block_b or bb, block_k or bk, block_i or bi
        )
    if use_generated:
        return _generated(
            x, w, beta, mean, var, act, eps,
            block_b, block_k, block_i, interpret,
        )
    return fused_dense_act_pallas(
        x, w, beta, mean, var, act=act, eps=eps,
        block_b=block_b, block_k=block_k, block_i=block_i,
        interpret=interpret,
    )
