"""Jit'd wrapper for the fused dense+norm+activation kernel."""

from __future__ import annotations

import functools

import jax

from ...core.autotune import choose_matmul_blocks
from .fused_dense_act import fused_dense_act_pallas
from .ref import fused_dense_act_ref


@functools.partial(
    jax.jit,
    static_argnames=("act", "eps", "block_b", "block_k", "block_i", "interpret"),
)
def fused_dense_act(
    x, w, beta, mean, var,
    *, act: str = "gelu", eps: float = 1e-5,
    block_b: int | None = None,
    block_k: int | None = None,
    block_i: int | None = None,
    interpret: bool = False,
):
    if not interpret and jax.default_backend() != "tpu":
        return fused_dense_act_ref(x, w, beta, mean, var, act=act, eps=eps)
    b, i = x.shape
    _, k = w.shape
    if block_b is None or block_k is None or block_i is None:
        bb, bk, bi = choose_matmul_blocks(b, k, i, elem_bytes=x.dtype.itemsize)
        block_b, block_k, block_i = (
            block_b or bb, block_k or bk, block_i or bi
        )
    return fused_dense_act_pallas(
        x, w, beta, mean, var, act=act, eps=eps,
        block_b=block_b, block_k=block_k, block_i=block_i,
        interpret=interpret,
    )
