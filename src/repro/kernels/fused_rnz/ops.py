"""Jit'd wrapper for the fused weighted contraction kernel."""

from __future__ import annotations

import functools

import jax

from ...core.autotune import choose_matmul_blocks
from .fused_rnz import weighted_matmul_pallas
from .ref import weighted_matmul_ref


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def weighted_matmul(
    a: jax.Array,
    b: jax.Array,
    g: jax.Array,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    if not interpret and jax.default_backend() != "tpu":
        return weighted_matmul_ref(a, b, g)
    m, k = a.shape
    _, n = b.shape
    if block_m is None or block_n is None or block_k is None:
        bm, bn, bk = choose_matmul_blocks(m, n, k, elem_bytes=a.dtype.itemsize)
        block_m, block_n, block_k = (
            block_m or bm, block_n or bn, block_k or bk
        )
    return weighted_matmul_pallas(
        a, b, g,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
