"""Pure-jnp oracle for the fused weighted contraction (paper eq 2)."""

import jax.numpy as jnp


def weighted_matmul_ref(a, b, g, out_dtype=None):
    out_dtype = out_dtype or a.dtype
    # same contract as the kernel: the zipper (a*g) runs in the input dtype
    # (it rides the VMEM block), accumulation happens in float32 on the MXU.
    scaled = a * g[None, :]
    return jnp.dot(
        scaled, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)
