"""Fused weighted contraction kernel — paper eq 2 / eq 6:

    C_ik = sum_j A_ij * B_jk * g_j

The paper's motivating point: BLAS-style libraries force ``A' = A .* g`` (a
temporary the size of A) before the GEMM.  The rnz-nzip fusion rule (eq 27)
folds the scaling into the reduction zipper; in the kernel that means the
``g`` chunk rides along the k-grid dimension and scales the A block in VMEM —
zero extra HBM traffic beyond g itself.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams


def _fused_rnz_kernel(a_ref, b_ref, g_ref, o_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_scaled = a_ref[...] * g_ref[...]  # (bm, bk) * (1, bk): the fused zipper
    acc_ref[...] += jnp.dot(
        a_scaled, b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def weighted_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    g: jax.Array,
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb and g.shape == (ka,)
    assert m % block_m == 0 and n % block_n == 0 and ka % block_k == 0
    out_dtype = out_dtype or a.dtype
    k_steps = ka // block_k
    g2 = g.reshape(1, ka)
    return pl.pallas_call(
        functools.partial(_fused_rnz_kernel, k_steps=k_steps),
        grid=(m // block_m, n // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_k), lambda i, j, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b, g2)
