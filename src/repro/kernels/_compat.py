"""Pallas API compatibility shims shared by hand-written and generated kernels.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` across
releases; the pinned jax==0.4.37 ships the old name.  Kernels must not
care which one exists.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
