"""TPU Pallas kernels for the paper's compute hot-spots.

Each kernel directory holds:
  <name>.py  -- pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py     -- jit'd public wrapper (TPU: Pallas; CPU: lax fallback)
  ref.py     -- pure-jnp oracle used by the allclose tests
"""
