"""Blocked matmul Pallas kernel — the TPU realization of the paper's §4 winner.

The paper's best matmul variant subdivides the reduction (``rnz``) and nests
``mapA / rnz / mapB / rnz``: stream blocks of the reduction dimension while
holding an output tile resident.  On TPU this is exactly a 3-D-grid Pallas
kernel with a revisited output block and a float32 VMEM accumulator:

  grid = (M/bm, N/bn, K/bk)       # mapA-blocks x mapB-blocks x rnz-blocks
  A block (bm, bk), B block (bk, bn) stream HBM -> VMEM per grid step
  acc (bm, bn) f32 lives in VMEM across the k-steps (the rnz accumulator)

Block shapes come from ``core.autotune.choose_matmul_blocks`` (the paper's
subdiv factors chosen by the cost model) and must be MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B with explicit VMEM tiling.

    A: (M, K), B: (K, N); block sizes must divide the operand extents.
    """
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and ka % block_k == 0, (
        (m, n, ka),
        (block_m, block_n, block_k),
    )
    out_dtype = out_dtype or a.dtype
    k_steps = ka // block_k
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(m // block_m, n // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
