"""Public wrapper for the blocked matmul: generated kernel first.

Routing policy (see DESIGN.md): the schedule-driven generator
(``repro.codegen``) compiles the matmul's Schedule into a Pallas kernel;
block shapes come from the persistent autotune cache when available, else
``choose_matmul_blocks``.  The hand-written ``matmul_pallas`` is kept as
the verification baseline (``use_generated=False`` and the equivalence
tests in tests/test_codegen.py).  On non-TPU backends without
``interpret`` we fall back to ``lax.dot_general`` so the surrounding
program still lowers/compiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.autotune import choose_matmul_blocks
from ...core.enumerate import matmul_spec
from .matmul import matmul_pallas
from .ref import matmul_ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


def _generated_matmul(a, b, block_m, block_n, block_k, interpret):
    from ... import codegen

    m, k = a.shape
    _, n = b.shape
    spec = matmul_spec(m, k, n)  # extents: i=m, j=k, k=n
    if block_m is None:
        # No caller-pinned blocks: let the generator's tuner pick.  Its
        # VMEM budget accounts for the generated kernel's resident reduce
        # axis (choose_matmul_blocks budgets for the k-STREAMED hand-
        # written kernel, which would overflow VMEM here at large K).
        schedule = codegen.tune_schedule(spec, dtype=a.dtype)
    else:
        schedule = codegen.default_schedule(
            spec, {"i": block_m, "k": block_n, "j": block_k}
        )
    kern = codegen.cached_compile(spec, schedule, interpret=interpret)
    return kern(a, b)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m", "block_n", "block_k", "interpret", "force_pallas",
        "use_generated",
    ),
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = False,
    force_pallas: bool = False,
    use_generated: bool = True,
) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    use_pallas = force_pallas or interpret or _on_tpu()
    if not use_pallas:
        return matmul_ref(a, b)
    if use_generated and block_m is None and block_n is None and block_k is None:
        return _generated_matmul(a, b, None, None, None, interpret)
    if block_m is None or block_n is None or block_k is None:
        bm, bn, bk = choose_matmul_blocks(
            m, n, k, elem_bytes=a.dtype.itemsize
        )
        block_m, block_n, block_k = (
            block_m or bm, block_n or bn, block_k or bk
        )
    if use_generated:
        return _generated_matmul(
            a, b, block_m, block_n, block_k, interpret
        )
    return matmul_pallas(
        a, b,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
