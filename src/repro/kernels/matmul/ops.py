"""Jit'd public wrapper for the blocked matmul kernel.

Routing policy (see DESIGN.md): on TPU backends the Pallas kernel runs with
autotuned block shapes; elsewhere (CPU container, dry-run) we fall back to
``lax.dot_general`` so the surrounding program still lowers/compiles, while
tests exercise the kernel body via ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.autotune import choose_matmul_blocks
from .matmul import matmul_pallas
from .ref import matmul_ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "force_pallas"),
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = False,
    force_pallas: bool = False,
) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    use_pallas = force_pallas or interpret or _on_tpu()
    if not use_pallas:
        return matmul_ref(a, b)
    if block_m is None or block_n is None or block_k is None:
        bm, bn, bk = choose_matmul_blocks(
            m, n, k, elem_bytes=a.dtype.itemsize
        )
        block_m, block_n, block_k = (
            block_m or bm, block_n or bn, block_k or bk
        )
    return matmul_pallas(
        a, b,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
