"""Search space: candidate variants for a ContractionSpec.

A *candidate* is a root-index loop order (one element of the rewrite-derived
SJT walk, ``core.enumerate.variant_orders``) plus one block/chunk choice per
root index — exactly the information a ``core.schedule.Schedule`` needs:

  * a map index blocked at ``b < extent``    -> ``grid`` level + ``mxu`` leaf
  * a map index left whole                   -> ``mxu`` level
  * a reduce index chunked at ``b < extent`` -> ``seq`` level + ``mxu`` leaf
  * a reduce index left whole                -> contracted in one dot

Many SJT orders realize the *same* generated kernel: only the relative order
of blocked map indices (the Pallas grid dims) and of chunked reduce indices
(the in-kernel fori_loop nest) survives lowering.  ``canonical_key`` projects
a candidate onto that quotient so the beam search deduplicates variants that
the exchange rules prove equivalent (see ``core.rules`` eq 36-43).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.enumerate import ContractionSpec, variant_orders
from ..core.schedule import Level, Schedule


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search space, in root-index terms.

    ``blocks`` maps every root index to its per-grid-step (map) or
    per-seq-step (reduce) extent; an index mapped to its full extent has no
    grid/seq level.  ``order`` is the loop nest outermost-first.
    """

    spec: ContractionSpec
    order: Tuple[str, ...]
    blocks: Tuple[Tuple[str, int], ...]  # sorted (index, block) pairs

    @property
    def block_dict(self) -> Dict[str, int]:
        return dict(self.blocks)

    def grid_order(self) -> Tuple[str, ...]:
        b = self.block_dict
        return tuple(
            i for i in self.order
            if i in self.spec.output and b.get(i, self.spec.extents[i]) < self.spec.extents[i]
        )

    def seq_order(self) -> Tuple[str, ...]:
        b = self.block_dict
        return tuple(
            i for i in self.order
            if i not in self.spec.output
            and b.get(i, self.spec.extents[i]) < self.spec.extents[i]
        )

    def canonical_key(self) -> str:
        """Identity after lowering: grid order, seq order, block sizes."""
        return json.dumps(
            {
                "grid": list(self.grid_order()),
                "seq": list(self.seq_order()),
                "blocks": sorted(
                    (i, int(b)) for i, b in self.blocks
                ),
            },
            sort_keys=True,
            separators=(",", ":"),
        )

    def to_schedule(self) -> Schedule:
        return candidate_schedule(self.spec, self.order, self.block_dict)


def make_candidate(
    spec: ContractionSpec, order: Sequence[str], blocks: Dict[str, int]
) -> Candidate:
    spec = spec.root()
    full = {i: int(blocks.get(i, spec.extents[i])) for i in spec.indices}
    return Candidate(
        spec=spec,
        order=tuple(order),
        blocks=tuple(sorted(full.items())),
    )


def candidate_schedule(
    spec: ContractionSpec, order: Sequence[str], blocks: Dict[str, int]
) -> Schedule:
    """Build the Schedule a candidate denotes.

    Same leaf structure as ``codegen.schedules.default_schedule`` but the
    grid and seq levels are emitted in loop-``order`` (default_schedule
    always uses ``spec.indices`` order), so the search can rank grid-dim
    and reduction-nest orders, not just block shapes.
    """
    spec = spec.root()
    order = tuple(order)
    if set(order) != set(spec.indices):
        raise ValueError(f"order {order} != indices {spec.indices}")
    s = spec
    grid: List[Level] = []
    seq: List[Level] = []
    mxu: List[Level] = []
    for index in order:
        extent = spec.extents[index]
        b = int(blocks.get(index, extent))
        if not 1 <= b <= extent or extent % b:
            raise ValueError(
                f"block {b} does not divide extent {extent} of {index}"
            )
        if b == extent:
            mxu.append(Level(index, "mxu", extent))
            continue
        s = s.subdivide(index, b)
        outer = Level(
            index + "o",
            "grid" if index in spec.output else "seq",
            extent // b,
        )
        (grid if index in spec.output else seq).append(outer)
        mxu.append(Level(index + "i", "mxu", b))
    return Schedule(s, tuple(grid + seq + mxu)).validate()


def sweep_specs(
    spec: ContractionSpec, with_grads: bool = False
) -> List[Tuple[str, ContractionSpec]]:
    """(label, spec) points a sweep should cover for one forward spec.

    With ``with_grads`` the forward spec is joined by its derived backward
    specs (``grad.derive`` — dA, dB, ... by index calculus), so one sweep
    prepares ranked plans for both the primal and the cotangent GEMMs of
    training.  Every derived spec has its own name (``<spec>.d<op>``) and
    therefore its own plan-DB key.  Consumed by
    ``search.search_schedule_with_grads``, ``scripts/search_sweep.py
    --with-grads`` and ``serve --search-gemms``.
    """
    out: List[Tuple[str, ContractionSpec]] = [("fwd", spec.root())]
    if with_grads:
        from ..grad import derived_specs

        out.extend(
            (f"d{wrt}", d) for wrt, d in derived_specs(spec).items()
        )
    return out


# ---------------------------------------------------------------------------
# choice generators
# ---------------------------------------------------------------------------


def map_block_choices(
    extent: int, hw: dict, per_index: int = 6
) -> List[int]:
    """Pow2 divisor blocks for a map (output) index, largest first.

    Tiny batch-like extents offer {1, extent} so a batched dim can become
    one grid step per element (the ``default_schedule`` convention).
    """
    if extent <= hw["sublane"]:
        return [extent, 1] if extent > 1 else [1]
    out = [extent]
    c = 1
    while c <= min(extent, 1024):
        if extent % c == 0 and c != extent:
            out.append(c)
        c *= 2
    out.sort(reverse=True)
    return out[:per_index]


def seq_chunk_choices(extent: int, hw: dict, cap: int = 512) -> List[int]:
    """Chunk choices for a reduce index: whole axis, or pow2 chunks <= cap.

    Reduce chunking never changes HBM traffic in the generated kernels (the
    axis is VMEM-resident either way, see ``codegen.plan``), it only bounds
    the per-dot depth — so the fan-out here is deliberately small.
    """
    out = [extent]
    if extent > cap:
        best = 0
        c = 1
        while c <= cap:
            if extent % c == 0:
                best = c
            c *= 2
        if best:
            out.append(best)
    elif extent > hw["mxu"][0] and extent % 2 == 0:
        out.append(extent // 2)
    return out


def block_choices(
    spec: ContractionSpec, hw: dict, per_index: int = 6
) -> Dict[str, List[int]]:
    spec = spec.root()
    return {
        i: (
            map_block_choices(spec.extents[i], hw, per_index)
            if i in spec.output
            else seq_chunk_choices(spec.extents[i], hw)
        )
        for i in spec.indices
    }


def candidate_orders(
    spec: ContractionSpec, limit: Optional[int] = None
) -> List[Tuple[str, ...]]:
    """Root loop orders from the SJT walk, deduplicated by lowering identity.

    Uses ``variant_orders`` (every order reachable by the exchange rules),
    then collapses orders whose map-index and reduce-index projections
    agree — those differ only by map/rnz exchanges that the generated
    kernel realizes identically.
    """
    return candidate_orders_counted(spec, limit)[0]


def candidate_orders_counted(
    spec: ContractionSpec, limit: Optional[int] = None
) -> Tuple[List[Tuple[str, ...]], int]:
    """(orders, visited) — one walk; ``visited - len(orders)`` = deduped."""
    spec = spec.root()
    seen = set()
    out: List[Tuple[str, ...]] = []
    visited = 0
    for order in variant_orders(spec, dedup_rnz=False):
        visited += 1
        key = (
            tuple(i for i in order if i in spec.output),
            tuple(i for i in order if i not in spec.output),
        )
        if key in seen:
            continue
        seen.add(key)
        out.append(order)
        if limit is not None and len(out) >= limit:
            break
    return out, visited
