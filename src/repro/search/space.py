"""Search space: candidate variants for a ContractionSpec.

A *candidate* is a root-index loop order (one element of the rewrite-derived
SJT walk, ``core.enumerate.variant_orders``) plus one block/chunk choice per
root index — exactly the information a ``core.schedule.Schedule`` needs:

  * a map index blocked at ``b < extent``    -> ``grid`` level + ``mxu`` leaf
  * a map index left whole                   -> ``mxu`` level
  * a reduce index chunked at ``b < extent`` -> ``seq`` level + ``mxu`` leaf
  * a reduce index left whole                -> contracted in one dot

The **mesh tier** sits above all of that: a ``MeshVariant`` assigns each
axis of the active device mesh to (at most) one root index, sharding it
before the grid/seq/mxu blocking applies — the paper's subdivision rule
bound to "clusters and devices" instead of grid steps.  Sharding a *map*
index partitions operands and output; sharding a *reduce* index makes each
device compute a partial contraction finished by a collective, whose
lowering (``psum`` vs the ring-overlap form) is itself part of the variant
(``Candidate.collective``).  ``mesh_variants`` enumerates the legal
factorizations of a mesh shape over the root indices; block choices then
range over the per-shard *local* extents.

Many SJT orders realize the *same* generated kernel: only the relative order
of blocked map indices (the Pallas grid dims) and of chunked reduce indices
(the in-kernel fori_loop nest) survives lowering.  ``canonical_key`` projects
a candidate onto that quotient so the beam search deduplicates variants that
the exchange rules prove equivalent (see ``core.rules`` eq 36-43).
"""

from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.enumerate import ContractionSpec, variant_orders
from ..core.schedule import MESH_TIERS, Level, Schedule

#: outermost-first mesh axis names, matching ``core.schedule.MESH_TIERS``
MESH_AXIS_ORDER = tuple(t.split(":", 1)[1] for t in MESH_TIERS)

#: collective lowerings a sharded reduction can choose between
#: (``codegen.mesh_gen.bind_mesh(collective=...)``)
COLLECTIVES = ("psum", "ring")

#: assignment: sorted ``(root index, (mesh axis, shards))`` pairs
MeshAssignment = Tuple[Tuple[str, Tuple[str, int]], ...]


def mesh_axis_names(ndim: int) -> Tuple[str, ...]:
    """Axis-name convention for an ``ndim``-dimensional mesh shape.

    Matches ``launch.mesh``: 2-D meshes are (data, model), 3-D adds the
    leading pod axis; a 1-D mesh is a plain data ring.
    """
    if ndim == 1:
        return ("data",)
    if ndim == 2:
        return ("data", "model")
    if ndim == 3:
        return ("pod", "data", "model")
    raise ValueError(f"mesh shapes have 1-3 axes, got {ndim}")


def parse_mesh_shape(text: str) -> Tuple[int, ...]:
    """'2x4' -> (2, 4) — the ``--mesh`` CLI syntax."""
    try:
        shape = tuple(int(p) for p in str(text).lower().split("x"))
    except ValueError:
        raise ValueError(f"mesh shape must look like '2x4', got {text!r}")
    if not shape or any(s < 1 for s in shape):
        raise ValueError(f"mesh shape must be positive, got {text!r}")
    mesh_axis_names(len(shape))  # validates the rank
    return shape


def mesh_descriptor(shape: Optional[Sequence[int]]) -> Optional[str]:
    """Canonical plan-key qualifier: (2, 4) -> '2x4', None/all-1 -> None."""
    if shape is None:
        return None
    shape = tuple(int(s) for s in shape)
    if all(s == 1 for s in shape):
        return None
    return "x".join(str(s) for s in shape)


@dataclasses.dataclass(frozen=True)
class MeshVariant:
    """One legal mesh subdivision: axis->index assignment + collective.

    ``assignment`` is empty for the unsharded variant.  ``collective`` is
    ``""`` unless a reduce index is sharded, in which case it names the
    lowering of the finishing reduction (one of ``COLLECTIVES``).
    """

    assignment: MeshAssignment = ()
    collective: str = ""

    @property
    def shards(self) -> int:
        out = 1
        for _, (_, n) in self.assignment:
            out *= n
        return out

    def as_dict(self) -> Dict[str, Tuple[str, int]]:
        return dict(self.assignment)


def local_extents(
    spec: ContractionSpec, mesh: Optional[Dict[str, Tuple[str, int]]]
) -> Dict[str, int]:
    """Per-shard extents after the mesh subdivision (root extents sans mesh)."""
    spec = spec.root()
    mesh = mesh or {}
    out = {}
    for i in spec.indices:
        n = mesh[i][1] if i in mesh else 1
        out[i] = spec.extents[i] // n
    return out


def mesh_variants(
    spec: ContractionSpec,
    mesh_shape: Optional[Sequence[int]],
    *,
    include_unsharded: bool = True,
) -> List[MeshVariant]:
    """Enumerate legal mesh subdivisions of ``spec`` over ``mesh_shape``.

    Per mesh axis the options are: leave it unused (the computation is
    replicated over that axis) or shard any root index whose extent it
    divides; axes shard *distinct* indices (one mesh level per root index,
    the shape ``codegen.plan`` lowers).  Variants that shard a reduce
    index fan out once per collective lowering (``COLLECTIVES``) — the
    paper's "choose the variant" applied to the finishing collective
    itself.  Deduplication: assignments are canonical (sorted pairs), so
    distinct MeshVariants are distinct subdivisions.
    """
    spec = spec.root()
    if mesh_shape is None:
        return [MeshVariant()] if include_unsharded else []
    axes = [
        (name, int(size))
        for name, size in zip(mesh_axis_names(len(mesh_shape)), mesh_shape)
        if int(size) > 1
    ]
    if not axes:
        return [MeshVariant()] if include_unsharded else []
    per_axis: List[List[Optional[str]]] = [
        [None]
        + [i for i in spec.indices if spec.extents[i] % size == 0]
        for _, size in axes
    ]
    out: List[MeshVariant] = []
    for combo in itertools.product(*per_axis):
        chosen = [c for c in combo if c is not None]
        if len(set(chosen)) != len(chosen):  # two axes on one index
            continue
        if not chosen and not include_unsharded:
            continue
        assignment = tuple(sorted(
            (idx, (axes[a][0], axes[a][1]))
            for a, idx in enumerate(combo)
            if idx is not None
        ))
        if not assignment:
            out.append(MeshVariant())
            continue
        sharded_reduce = any(
            idx not in spec.output for idx, _ in assignment
        )
        if sharded_reduce:
            out.extend(
                MeshVariant(assignment, coll) for coll in COLLECTIVES
            )
        else:
            out.append(MeshVariant(assignment))
    return out


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search space, in root-index terms.

    ``blocks`` maps every root index to its per-grid-step (map) or
    per-seq-step (reduce) extent **within the local shard**; an index
    mapped to its full local extent has no grid/seq level.  ``order`` is
    the loop nest outermost-first.  ``mesh`` is the mesh subdivision
    (empty = single-device) and ``collective`` the lowering of a sharded
    reduction, if any.
    """

    spec: ContractionSpec
    order: Tuple[str, ...]
    blocks: Tuple[Tuple[str, int], ...]  # sorted (index, block) pairs
    mesh: MeshAssignment = ()
    collective: str = ""

    @property
    def block_dict(self) -> Dict[str, int]:
        return dict(self.blocks)

    @property
    def mesh_dict(self) -> Dict[str, Tuple[str, int]]:
        return dict(self.mesh)

    def _local(self) -> Dict[str, int]:
        return local_extents(self.spec, self.mesh_dict)

    def grid_order(self) -> Tuple[str, ...]:
        b, loc = self.block_dict, self._local()
        return tuple(
            i for i in self.order
            if i in self.spec.output and b.get(i, loc[i]) < loc[i]
        )

    def seq_order(self) -> Tuple[str, ...]:
        b, loc = self.block_dict, self._local()
        return tuple(
            i for i in self.order
            if i not in self.spec.output and b.get(i, loc[i]) < loc[i]
        )

    def canonical_key(self) -> str:
        """Identity after lowering: mesh assignment + collective, grid
        order, seq order, block sizes."""
        return json.dumps(
            {
                "grid": list(self.grid_order()),
                "seq": list(self.seq_order()),
                "blocks": sorted(
                    (i, int(b)) for i, b in self.blocks
                ),
                "mesh": sorted(
                    (i, a, int(n)) for i, (a, n) in self.mesh
                ),
                "collective": self.collective,
            },
            sort_keys=True,
            separators=(",", ":"),
        )

    def to_schedule(self) -> Schedule:
        return candidate_schedule(
            self.spec, self.order, self.block_dict, mesh=self.mesh_dict
        )


def make_candidate(
    spec: ContractionSpec,
    order: Sequence[str],
    blocks: Dict[str, int],
    mesh: Optional[Dict[str, Tuple[str, int]]] = None,
    collective: str = "",
) -> Candidate:
    spec = spec.root()
    mesh = dict(mesh or {})
    loc = local_extents(spec, mesh)
    full = {i: int(blocks.get(i, loc[i])) for i in spec.indices}
    return Candidate(
        spec=spec,
        order=tuple(order),
        blocks=tuple(sorted(full.items())),
        mesh=tuple(sorted(mesh.items())),
        collective=collective,
    )


def candidate_schedule(
    spec: ContractionSpec,
    order: Sequence[str],
    blocks: Dict[str, int],
    mesh: Optional[Dict[str, Tuple[str, int]]] = None,
) -> Schedule:
    """Build the Schedule a candidate denotes.

    Same leaf structure as ``codegen.schedules.default_schedule`` but the
    grid and seq levels are emitted in loop-``order`` (default_schedule
    always uses ``spec.indices`` order), so the search can rank grid-dim
    and reduction-nest orders, not just block shapes.  ``mesh`` shards
    root indices over mesh axes *before* the inner blocking (the
    ``sharded_schedule`` shape); ``blocks`` then tile the per-shard local
    extents.
    """
    spec = spec.root()
    order = tuple(order)
    if set(order) != set(spec.indices):
        raise ValueError(f"order {order} != indices {spec.indices}")
    mesh = dict(mesh or {})
    rank = {a: r for r, a in enumerate(MESH_AXIS_ORDER)}
    s = spec
    mesh_levels: List[Level] = []
    renamed: Dict[str, str] = {}
    for index, (axis, n) in sorted(
        mesh.items(), key=lambda kv: rank.get(kv[1][0], len(rank))
    ):
        if axis not in MESH_AXIS_ORDER:
            raise ValueError(
                f"unknown mesh axis {axis!r} (want {MESH_AXIS_ORDER})"
            )
        extent = spec.extents[index]
        if n <= 0 or extent % n:
            raise ValueError(
                f"{n} shards do not divide extent {extent} of {index}"
            )
        if n == 1:
            continue
        s = s.subdivide(index, extent // n)
        mesh_levels.append(Level(index + "o", f"mesh:{axis}", n))
        renamed[index] = index + "i"
    loc = local_extents(spec, mesh)
    grid: List[Level] = []
    seq: List[Level] = []
    mxu: List[Level] = []
    for index in order:
        extent = loc[index]
        name = renamed.get(index, index)
        b = int(blocks.get(index, extent))
        if not 1 <= b <= extent or extent % b:
            raise ValueError(
                f"block {b} does not divide local extent {extent} of {index}"
            )
        if b == extent:
            mxu.append(Level(name, "mxu", extent))
            continue
        s = s.subdivide(name, b)
        outer = Level(
            name + "o",
            "grid" if index in spec.output else "seq",
            extent // b,
        )
        (grid if index in spec.output else seq).append(outer)
        mxu.append(Level(name + "i", "mxu", b))
    return Schedule(s, tuple(mesh_levels + grid + seq + mxu)).validate()


#: quantized precision tiers of the dtype axis (core.enumerate
#: QUANT_FORMATS keys); the baseline tier is whatever dtype the caller
#: searches at (bf16/f32)
QUANT_TIERS = ("int8", "fp8")


def dtype_tier_specs(
    spec: ContractionSpec,
    *,
    dtype="float32",
    tiers: Sequence[str] = QUANT_TIERS,
) -> List[Tuple[str, ContractionSpec, "object"]]:
    """The dtype axis of the search: (tier, spec, dtype) triples.

    The baseline tier keeps the caller's spec and dtype; each quant tier
    re-tags the root spec with its ``QuantMeta`` (so plans land under
    dtype-qualified keys) and searches at the 1-byte storage dtype.  Fused
    and already-quantized specs get only their baseline row — there is no
    quant lowering for them yet.  A tier whose storage dtype is not
    registered in this container (fp8 on old ml_dtypes) is skipped rather
    than crashing the sweep.
    """
    import numpy as np

    from ..core.enumerate import quantize_spec

    root = spec.root()
    out: List[Tuple[str, ContractionSpec, object]] = [
        ("baseline", root, np.dtype(dtype))
    ]
    if getattr(root, "fused_kind", "") or getattr(root, "quant", None):
        return out
    for tier in tiers:
        q = quantize_spec(root, fmt=tier)
        try:
            qdt = np.dtype(q.quant.dtype)
        except TypeError:
            continue
        out.append((tier, q, qdt))
    return out


def sweep_specs(
    spec: ContractionSpec, with_grads: bool = False
) -> List[Tuple[str, ContractionSpec]]:
    """(label, spec) points a sweep should cover for one forward spec.

    With ``with_grads`` the forward spec is joined by its derived backward
    specs (``grad.derive`` — dA, dB, ... by index calculus), so one sweep
    prepares ranked plans for both the primal and the cotangent GEMMs of
    training.  Every derived spec has its own name (``<spec>.d<op>``) and
    therefore its own plan-DB key.  Consumed by
    ``search.search_schedule_with_grads``, ``scripts/search_sweep.py
    --with-grads`` and ``serve --search-gemms``.
    """
    out: List[Tuple[str, ContractionSpec]] = [("fwd", spec.root())]
    if with_grads:
        from ..grad import derived_specs

        out.extend(
            (f"d{wrt}", d) for wrt, d in derived_specs(spec).items()
        )
    return out


# ---------------------------------------------------------------------------
# choice generators
# ---------------------------------------------------------------------------


def map_block_choices(
    extent: int, hw: dict, per_index: int = 6
) -> List[int]:
    """Pow2 divisor blocks for a map (output) index, largest first.

    Tiny batch-like extents offer {1, extent} so a batched dim can become
    one grid step per element (the ``default_schedule`` convention).
    """
    if extent <= hw["sublane"]:
        return [extent, 1] if extent > 1 else [1]
    out = [extent]
    c = 1
    while c <= min(extent, 1024):
        if extent % c == 0 and c != extent:
            out.append(c)
        c *= 2
    out.sort(reverse=True)
    return out[:per_index]


def seq_chunk_choices(extent: int, hw: dict, cap: int = 512) -> List[int]:
    """Chunk choices for a reduce index: whole axis, or pow2 chunks <= cap.

    Reduce chunking never changes HBM traffic in the generated kernels (the
    axis is VMEM-resident either way, see ``codegen.plan``), it only bounds
    the per-dot depth — so the fan-out here is deliberately small.
    """
    out = [extent]
    if extent > cap:
        best = 0
        c = 1
        while c <= cap:
            if extent % c == 0:
                best = c
            c *= 2
        if best:
            out.append(best)
    elif extent > hw["mxu"][0] and extent % 2 == 0:
        out.append(extent // 2)
    return out


def block_choices(
    spec: ContractionSpec,
    hw: dict,
    per_index: int = 6,
    mesh: Optional[Dict[str, Tuple[str, int]]] = None,
) -> Dict[str, List[int]]:
    """Per-root-index block choices; with ``mesh`` the choices range over
    the per-shard *local* extents (the extents the generated kernel sees
    inside ``shard_map``)."""
    spec = spec.root()
    loc = local_extents(spec, mesh)
    # fused families pin some axes whole: attention's head dims live
    # entirely inside one MXU pass, grouped's group/contraction axes are
    # realized by the group-offset grid, not by blocking
    whole = getattr(spec, "whole_indices", ())
    return {
        i: (
            [loc[i]]
            if i in whole
            else map_block_choices(loc[i], hw, per_index)
            if i in spec.output
            else seq_chunk_choices(loc[i], hw)
        )
        for i in spec.indices
    }


def candidate_orders(
    spec: ContractionSpec, limit: Optional[int] = None
) -> List[Tuple[str, ...]]:
    """Root loop orders from the SJT walk, deduplicated by lowering identity.

    Uses ``variant_orders`` (every order reachable by the exchange rules),
    then collapses orders whose map-index and reduce-index projections
    agree — those differ only by map/rnz exchanges that the generated
    kernel realizes identically.
    """
    return candidate_orders_counted(spec, limit)[0]


def candidate_orders_counted(
    spec: ContractionSpec, limit: Optional[int] = None
) -> Tuple[List[Tuple[str, ...]], int]:
    """(orders, visited) — one walk; ``visited - len(orders)`` = deduped."""
    spec = spec.root()
    seen = set()
    out: List[Tuple[str, ...]] = []
    visited = 0
    for order in variant_orders(spec, dedup_rnz=False):
        visited += 1
        key = (
            tuple(i for i in order if i in spec.output),
            tuple(i for i in order if i not in spec.output),
        )
        if key in seen:
            continue
        seen.add(key)
        out.append(order)
        if limit is not None and len(out) >= limit:
            break
    return out, visited
