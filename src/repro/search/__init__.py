"""repro.search — cost-guided variant search, rewrite rules to measured kernels.

This package closes the loop the paper describes but the repo only had in
pieces: ``core.enumerate`` walks the SJT permutation space, ``core.cost``
scores variants analytically, ``codegen`` compiles a hand-picked Schedule.
``search_schedule`` chains them end to end:

    ContractionSpec + shapes
      │  space.candidate_orders      SJT walk, deduped by lowering identity
      │  space.block_choices         subdivision choices per hierarchy tier
      ▼
    beam.beam_search                 analytic roofline prune (sound bound
      │                              cut + configurable-width beam trim)
      ▼
    measure.measure_schedules        top-K lowered via codegen, timed under
      │                              the autotune harness (interpret on CPU)
      ▼
    plandb.PlanDB                    ranked plans persisted next to the
                                     autotune cache; ops.dense asks here
                                     before falling back to tune_schedule

``ops.dense`` & friends consult ``default_plan_db()`` first, so one offline
sweep (``scripts/search_sweep.py``) or one ``serve --search-gemms`` warmup
upgrades every later call for the same spec/shape/dtype — batched, chained
and transposed contractions included.  With ``--with-grads`` (or
``search_schedule_with_grads``) the sweep also covers the derived backward
specs of ``repro.grad``, so training's cotangent GEMMs are searched too.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from ..core.cost import TPU
from ..core.enumerate import (
    ContractionSpec,
    attention_spec,
    batched_matmul_spec,
    chain_matmul_spec,
    matmul_spec,
    matvec_spec,
    transposed_matmul_spec,
    uniform_grouped_spec,
    weighted_matmul_spec,
)
from ..core.schedule import Schedule
from .beam import CostEstimate, ScoredCandidate, SearchStats, beam_search, estimate
from .measure import (
    Measurement,
    einsum_reference,
    measure_schedules,
    mesh_for_schedules,
    reference_arrays,
    schedule_mesh_axes,
)
from .plandb import (
    PlanDB,
    active_phase,
    default_plan_db,
    entry_from,
    grad_plan_keys,
    plan_key,
    serving_phase,
)
from .space import (
    QUANT_TIERS,
    Candidate,
    MeshVariant,
    block_choices,
    candidate_orders,
    candidate_schedule,
    dtype_tier_specs,
    make_candidate,
    mesh_descriptor,
    mesh_variants,
    parse_mesh_shape,
    sweep_specs,
)

#: spec families the sweep CLI / serve warmup can name; value = (ctor, arity)
SPEC_FAMILIES = {
    "matmul": (matmul_spec, 3),
    "matvec": (matvec_spec, 2),
    "weighted_matmul": (weighted_matmul_spec, 3),
    "batched_matmul": (batched_matmul_spec, 4),
    "chain_matmul": (chain_matmul_spec, 4),
    "transposed_matmul": (transposed_matmul_spec, 3),
    # fused families: attention takes (heads, q_seq, kv_seq, head_dim);
    # grouped_matmul takes (groups, rows_per_group, k, f) — the CLI's
    # uniform-partition entry into the ragged GroupedSpec
    "attention": (attention_spec, 4),
    "grouped_matmul": (uniform_grouped_spec, 4),
}


def spec_from_name(name: str, shape: Sequence[int]) -> ContractionSpec:
    if name not in SPEC_FAMILIES:
        raise ValueError(
            f"unknown spec {name!r}; choose from {sorted(SPEC_FAMILIES)}"
        )
    ctor, arity = SPEC_FAMILIES[name]
    if len(shape) != arity:
        raise ValueError(f"{name} takes {arity} extents, got {list(shape)}")
    return ctor(*shape)


@dataclasses.dataclass
class RankedPlan:
    """One rung of the search output ladder."""

    schedule: Schedule
    score: float
    lower_bound: float
    fits_vmem: bool
    measured_s: Optional[float] = None
    max_err: Optional[float] = None
    source: str = "search"  # "default"/"mesh-naive" for baseline entries
    collective: str = ""    # finishing-collective strategy of a mesh plan
    #: roofline terms the rank was decided from (beam.CostEstimate:
    #: compute_s/hbm_s/comm_s/penalty/seq_steps/shards) — persisted into
    #: the plan DB and rendered by ``obs.explain``
    explain: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def sharded(self) -> bool:
        from .measure import schedule_mesh_axes

        return bool(schedule_mesh_axes(self.schedule))


@dataclasses.dataclass
class SearchResult:
    spec: ContractionSpec
    dtype: str
    ranked: List[RankedPlan]  # best first
    stats: SearchStats
    db_key: Optional[str] = None
    mesh: Optional[str] = None  # mesh descriptor ('2x4') of a mesh search

    @property
    def best(self) -> RankedPlan:
        return self.ranked[0]

    def baseline(self) -> Optional[RankedPlan]:
        for p in self.ranked:
            if p.source == "default":
                return p
        return None

    def mesh_baseline(self) -> Optional[RankedPlan]:
        """The naive-psum lowering of the best sharded subdivision."""
        for p in self.ranked:
            if p.source == "mesh-naive":
                return p
        return None

    def best_sharded(self) -> Optional[RankedPlan]:
        for p in self.ranked:
            if p.sharded:
                return p
        return None


def search_schedule(
    spec: ContractionSpec,
    *,
    dtype=np.float32,
    beam_width: int = 8,
    topk: int = 4,
    elem_bytes: Optional[int] = None,
    hw: dict = TPU,
    measure: bool = True,
    interpret: bool = True,
    repeats: int = 2,
    arrays: Optional[Dict[str, np.ndarray]] = None,
    include_default: bool = True,
    plan_db: Optional[PlanDB] = None,
    use_cached_plan: bool = True,
    mesh_shape=None,
    phase: Optional[str] = None,
) -> SearchResult:
    """The end-to-end pipeline: enumerate -> prune -> measure -> persist.

    Returns the ranked ladder best-first.  When ``measure`` is on, the
    ranking is by measured seconds and — because ``include_default`` puts
    the un-searched ``codegen.default_schedule`` into the measured set —
    the winner is by construction never slower than the default on the
    measurement harness used.

    ``mesh_shape`` ('2x4' or (2, 4)) extends the search to the mesh tier:
    legal mesh subdivisions × collective strategies join the beam under
    the communication-aware cost (``beam.estimate``), the ladder always
    surfaces at least one ``mesh:*`` plan, and a "mesh-naive" baseline
    (the plain-psum, unblocked lowering of the best sharded subdivision)
    rides through measurement so the searched sharded winner is by
    construction never slower than it.  Sharded candidates are measured
    through ``codegen.bind_mesh`` over the visible devices (force a CPU
    mesh with ``--xla_force_host_platform_device_count``); when the
    process cannot host the mesh they keep their analytic rank behind the
    measured single-device plans.  The ladder persists under the
    mesh-qualified plan key (``matmul@mesh=2x4``-style).

    ``plan_db`` (or pass ``default_plan_db()``) persists the ladder;
    ``use_cached_plan`` short-circuits a repeated search of the same
    spec/dtype/hardware/mesh from the DB.

    ``phase`` ('prefill'/'decode') persists the ladder under the
    serving-phase-qualified key (``plandb.plan_key(phase=...)``) — the
    ladder the serving runners consult via ``plandb.serving_phase`` while
    an unphased sweep of the same shape stays untouched.
    """
    spec = spec.root()
    dt = np.dtype(dtype)
    if elem_bytes is None:
        elem_bytes = dt.itemsize
    if isinstance(mesh_shape, str):
        mesh_shape = parse_mesh_shape(mesh_shape)
    mesh_desc = mesh_descriptor(mesh_shape)
    if mesh_desc is None:
        mesh_shape = None

    if plan_db is not None and use_cached_plan:
        cached = plan_db.get(spec, dt, mesh=mesh_desc, phase=phase)
        if (
            cached
            and cached.get("ranked")
            and measure
            and cached["ranked"][0].get("measured_s") is None
        ):
            # an analytic-only (--no-measure) ladder must not satisfy a
            # measured request: fall through and run the full pipeline
            cached = None
        if cached and cached.get("ranked"):
            ranked = []
            for e in cached["ranked"]:
                try:
                    sched = _sched_from(e["schedule"], spec)
                except Exception:
                    continue
                ranked.append(
                    RankedPlan(
                        schedule=sched,
                        score=e.get("score", float("inf")),
                        lower_bound=e.get("lower_bound", 0.0),
                        fits_vmem=e.get("fits_vmem", True),
                        measured_s=e.get("measured_s"),
                        source=e.get("source", "search"),
                        collective=e.get("collective", ""),
                        explain=dict(e.get("explain") or {}),
                    )
                )
            if ranked:
                stats = SearchStats()
                for k, v in (cached.get("stats") or {}).items():
                    if hasattr(stats, k):
                        setattr(stats, k, v)
                return SearchResult(
                    spec=spec, dtype=str(dt), ranked=ranked, stats=stats,
                    db_key=plan_key(spec, dt, mesh=mesh_desc, phase=phase),
                    mesh=mesh_desc,
                )

    with obs.span("search.beam", spec=spec.name, mesh=mesh_desc):
        survivors, stats = beam_search(
            spec, beam_width=beam_width, topk=topk,
            elem_bytes=elem_bytes, hw=hw, mesh_shape=mesh_shape,
        )
    obs.counter("search.candidates").inc(stats.considered)
    obs.counter("search.pruned_bound").inc(stats.pruned_bound)
    obs.counter("search.pruned_beam").inc(stats.pruned_beam)
    obs.counter("search.mesh_variants").inc(stats.mesh_variants)
    plans: List[RankedPlan] = [
        RankedPlan(
            schedule=sc.candidate.to_schedule(),
            score=sc.cost.score,
            lower_bound=sc.cost.lower_bound,
            fits_vmem=sc.cost.fits_vmem,
            collective=sc.candidate.collective,
            explain=_explain_of(sc.cost),
        )
        for sc in survivors
    ]
    if include_default:
        from ..codegen import default_schedule

        base_sched = default_schedule(spec)
        base_dict = _sched_dict(base_sched)
        if not any(_sched_dict(p.schedule) == base_dict for p in plans):
            est = estimate(
                spec, spec.indices,
                {i: spec.extents[i] for i in spec.indices},
                elem_bytes=elem_bytes, hw=hw,
            )
            plans.append(
                RankedPlan(
                    schedule=base_sched,
                    score=est.score,
                    lower_bound=est.lower_bound,
                    fits_vmem=est.fits_vmem,
                    source="default",
                    explain=_explain_of(est),
                )
            )
        else:
            for p in plans:
                if _sched_dict(p.schedule) == base_dict:
                    p.source = "default"

    # mesh searches also measure the NAIVE lowering of the best sharded
    # subdivision — same mesh assignment, plain psum, no inner blocking —
    # so "searched-sharded never slower than naive psum" holds by
    # construction on the measurement harness (the mesh analogue of the
    # include_default guarantee)
    if mesh_shape is not None:
        best_sharded_sc = next(
            (sc for sc in survivors if sc.candidate.mesh), None
        )
        if best_sharded_sc is not None:
            naive_sched = candidate_schedule(
                spec, spec.indices, {},
                mesh=best_sharded_sc.candidate.mesh_dict,
            )
            naive_dict = _sched_dict(naive_sched)
            naive_hit = [
                p for p in plans
                if _sched_dict(p.schedule) == naive_dict
                and (p.collective or "psum") == "psum"
            ]
            if naive_hit:
                for p in naive_hit:
                    p.source = "mesh-naive"
            else:
                from .space import local_extents

                naive_mesh = best_sharded_sc.candidate.mesh_dict
                est = estimate(
                    spec, spec.indices,
                    local_extents(spec, naive_mesh),
                    elem_bytes=elem_bytes, hw=hw,
                    mesh=naive_mesh, collective="psum",
                )
                plans.append(
                    RankedPlan(
                        schedule=naive_sched,
                        score=est.score,
                        lower_bound=est.lower_bound,
                        fits_vmem=est.fits_vmem,
                        source="mesh-naive",
                        collective="psum",
                        explain=_explain_of(est),
                    )
                )

    measured_plans: List[RankedPlan] = []
    if measure and plans:
        sharded = [p for p in plans if p.sharded]
        mesh = mesh_for_schedules([p.schedule for p in sharded])
        if mesh is None and sharded:
            # process cannot host the mesh: measure the single-device
            # candidates, keep sharded ones on their analytic rank
            measured_plans = [p for p in plans if not p.sharded]
        else:
            measured_plans = list(plans)
        if measured_plans:
            with obs.span(
                "search.measure", spec=spec.name, n=len(measured_plans)
            ):
                ms = measure_schedules(
                    spec, [p.schedule for p in measured_plans],
                    arrays=arrays, dtype=dt, interpret=interpret,
                    repeats=repeats, mesh=mesh,
                    collectives=[p.collective for p in measured_plans],
                )
            for p, m in zip(measured_plans, ms):
                p.measured_s = m.seconds
                p.max_err = m.max_err
            stats.measured += len(ms)
            obs.counter("search.measured").inc(len(ms))
        plans.sort(
            key=lambda p: (
                p.measured_s is None,
                p.measured_s if p.measured_s is not None else p.score,
                p.score,
            )
        )
    else:
        plans.sort(key=lambda p: (not p.fits_vmem, p.score))

    result = SearchResult(
        spec=spec, dtype=str(dt), ranked=plans, stats=stats,
        mesh=mesh_desc,
    )
    if mesh_desc is not None:
        sharded_best = result.best_sharded()
        if sharded_best is not None:
            # which finishing collective won the mesh tier — the
            # ring-vs-psum pick, surfaced fleet-wide through obs
            obs.counter(
                f"search.collective.{sharded_best.collective or 'psum'}"
            ).inc()
    if plan_db is not None and plans:
        with obs.span("search.persist", spec=spec.name, mesh=mesh_desc):
            result.db_key = plan_db.put(
                spec, dt,
                [
                    entry_from(
                        p.schedule,
                        score=p.score,
                        lower_bound=p.lower_bound,
                        fits_vmem=p.fits_vmem,
                        measured_s=p.measured_s,
                        source=p.source,
                        collective=p.collective,
                        explain=p.explain,
                    )
                    for p in plans
                ],
                stats=stats.as_dict(),
                mesh=mesh_desc,
                cuts=[
                    {"key": k, "lower_bound": lb, "best_score": bs}
                    for k, lb, bs in stats.bound_log[:_MAX_CUTS]
                ],
                phase=phase,
            )
    return result


#: bound-cut sample size persisted per entry — enough for the explain
#: table's why-not side without bloating the fleet DB on big sweeps
_MAX_CUTS = 12


def _explain_of(est: CostEstimate) -> Dict[str, float]:
    """The CostEstimate terms a plan-DB rung keeps (``explain`` field)."""
    return {
        "compute_s": float(est.compute_s),
        "hbm_s": float(est.hbm_s),
        "comm_s": float(est.comm_s),
        "penalty": float(est.penalty),
        "seq_steps": int(est.seq_steps),
        "shards": int(est.shards),
    }


def _sched_dict(s: Schedule) -> str:
    import json

    from ..codegen.cache import schedule_to_dict

    return json.dumps(schedule_to_dict(s), sort_keys=True)


def _sched_from(d, root: ContractionSpec) -> Schedule:
    from ..codegen.cache import schedule_from_dict

    return schedule_from_dict(d, root)


def search_schedule_with_grads(
    spec: ContractionSpec, **kwargs
) -> Dict[str, SearchResult]:
    """Sweep a forward spec together with its derived backward specs.

    Runs the full ``search_schedule`` pipeline once per point of
    ``space.sweep_specs(spec, with_grads=True)`` — the forward contraction
    plus every cotangent GEMM from ``grad.derive`` (dA = g·Bᵀ etc.), each
    persisted under its own plan key.  Returns ``{label -> SearchResult}``
    with labels ``fwd``, ``dA``, ``dB``, ...  This is how training's
    backward GEMMs pick up *searched* (not just analytically tuned)
    schedules: ``ops``'s custom VJPs consult the plan DB by derived-spec
    key on every backward pass.
    """
    return {
        label: search_schedule(s, **kwargs)
        for label, s in sweep_specs(spec, with_grads=True)
    }


def search_dtype_ladder(
    spec: ContractionSpec,
    *,
    dtype=np.float32,
    tiers: Sequence[str] = QUANT_TIERS,
    **kwargs,
) -> Dict[str, SearchResult]:
    """Search the dtype axis: the baseline tier plus each quant tier.

    Runs the full ``search_schedule`` pipeline once per point of
    ``space.dtype_tier_specs`` — the caller's spec at its full/half
    precision, then the int8 and fp8 re-taggings at their 1-byte storage
    dtypes.  Every tier persists under its own dtype-qualified plan key
    (``matmul@...@dtype=int8`` in ``obs.explain`` selector terms), so
    ``ops.dense(quant=...)`` and the quantized serving path pick up the
    matching ladder at trace time.  Returns ``{tier -> SearchResult}``
    with ``"baseline"`` always present; rank tiers against each other
    with ``best_dtype_tier``.
    """
    return {
        tier: search_schedule(s, dtype=dt, **kwargs)
        for tier, s, dt in dtype_tier_specs(spec, dtype=dtype, tiers=tiers)
    }


def best_dtype_tier(results: Dict[str, SearchResult]) -> str:
    """The precision tier the roofline ranks fastest for this shape.

    Compared on the *analytic* score of each tier's best plan — the
    quant-aware byte model is exactly what distinguishes tiers (operand
    traffic shrinks 4x at matched shapes), whereas interpreter wall-clock
    cannot see memory bandwidth.  Accuracy policy stays with the caller;
    this only says what the hardware model prefers.
    """
    if not results:
        raise ValueError("no tiers searched")
    return min(
        results,
        key=lambda t: (
            not results[t].best.fits_vmem,
            results[t].best.score,
            t,
        ),
    )


def search_gemm_plans(
    shapes: Sequence[Tuple[int, int, int]],
    *,
    dtype=np.float32,
    beam_width: int = 8,
    topk: int = 3,
    interpret: bool = True,
    measure: bool = True,
    plan_db: Optional[PlanDB] = None,
    with_grads: bool = False,
    mesh_shape=None,
    phase: Optional[str] = None,
) -> int:
    """Search + persist plans for (m, k, n) GEMMs; returns #plans readied.

    The serving analogue of ``ops.warm_dense_cache``: where warmup fills
    the autotune cache with the analytic pick, this runs the full
    enumerate->prune->measure pipeline and stores the ranked ladder, so
    ``ops.dense`` serves the *searched* schedule from then on.  With
    ``with_grads`` each GEMM's derived backward specs are swept too (the
    count then includes them), preparing the training fleet's cotangent
    GEMMs from the same warmup.  With ``mesh_shape`` ('2x4') every point
    is additionally swept at the mesh tier, persisting sharded ladders
    under the mesh-qualified keys that ``ops._tuned_kernel`` consults
    when a matching mesh is active (the count includes those sweeps).
    With ``phase`` the ladders persist under the serving-phase-qualified
    keys — how the prefill/decode runners each sweep their own ladder for
    the same shape family.
    """
    db = plan_db if plan_db is not None else default_plan_db()
    n = 0
    for m, k, nn in shapes:
        spec = matmul_spec(m, k, nn)
        kw = dict(
            dtype=dtype, beam_width=beam_width, topk=topk,
            interpret=interpret, measure=measure, plan_db=db,
            phase=phase,
        )
        meshes = [None] + ([mesh_shape] if mesh_shape is not None else [])
        for ms in meshes:
            if with_grads:
                n += len(
                    search_schedule_with_grads(spec, mesh_shape=ms, **kw)
                )
            else:
                search_schedule(spec, mesh_shape=ms, **kw)
                n += 1
    return n


__all__ = [
    "Candidate",
    "CostEstimate",
    "Measurement",
    "MeshVariant",
    "PlanDB",
    "RankedPlan",
    "ScoredCandidate",
    "SearchResult",
    "SearchStats",
    "SPEC_FAMILIES",
    "QUANT_TIERS",
    "active_phase",
    "beam_search",
    "best_dtype_tier",
    "block_choices",
    "candidate_orders",
    "candidate_schedule",
    "default_plan_db",
    "dtype_tier_specs",
    "einsum_reference",
    "entry_from",
    "estimate",
    "grad_plan_keys",
    "make_candidate",
    "measure_schedules",
    "mesh_descriptor",
    "mesh_for_schedules",
    "mesh_variants",
    "parse_mesh_shape",
    "plan_key",
    "reference_arrays",
    "schedule_mesh_axes",
    "search_dtype_ladder",
    "search_gemm_plans",
    "search_schedule",
    "search_schedule_with_grads",
    "serving_phase",
    "spec_from_name",
    "sweep_specs",
]
