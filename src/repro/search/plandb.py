"""Ranked plan database — the search pipeline's persistent output.

Where ``codegen.cache`` stores *one* tuned schedule per key, the plan DB
stores the search's whole ranked ladder (schedule + analytic score + roofline
bound + measured time + search stats), so ops can take the winner today and
an operator can inspect or re-rank the runners-up tomorrow without
re-searching.  Storage reuses ``codegen.cache.AutotuneCache`` (atomic JSON,
concurrent-writer safe) in a *separate* file so search-format changes can
never corrupt the PR-1 autotune cache:

    $REPRO_PLAN_DB if set, else ~/.cache/repro/plans.json

Keys come from ``codegen.cache.cache_key`` with a ``search.plan`` marker, so
they are disjoint from autotune keys even if the files are merged by hand.
"""

from __future__ import annotations

import contextlib
import contextvars
import os
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..codegen.cache import (
    AutotuneCache,
    cache_key,
    schedule_from_dict,
    schedule_to_dict,
)
from ..core.enumerate import ContractionSpec
from ..core.schedule import Schedule

#: bump when the ranked-entry layout changes.
#: v2 (mesh tier): keys gained a ``mesh`` qualifier (None for
#: single-device plans, '2x4'-style for sharded ones) and ranked entries
#: an optional ``collective`` field naming the finishing-reduction
#: lowering.  Every v1 key goes cold on upgrade — deliberate: v1 ladders
#: carry no mesh provenance, so a sharded fleet could have picked up a
#: single-device plan for a mesh-qualified lookup (or vice versa).
#: v3 (observability / plan-explain): entries carry their own identity
#: (``spec`` = ``spec_signature``, ``dtype``) so ``obs.explain`` can find
#: them by human selector instead of sha256 key, each rung an ``explain``
#: dict of the roofline terms the ranking was decided from (compute/HBM/
#: collective seconds, penalty, shards — ``beam.CostEstimate``), and the
#: entry a ``cuts`` sample of the sound bound cuts.  v2 keys go cold
#: (their ladders lack the provenance v3 readers expose); ``PlanDB.get``
#: counts such upgrades as ``plandb.version_miss`` in ``repro.obs``.
#: Re-sweeping (``scripts/search_sweep.py``) rebuilds the DB; the golden
#: fixture ``tests/data/plan_db_golden.json`` was regenerated alongside.
PLAN_VERSION = 3


def plan_key(
    spec: ContractionSpec,
    dtype: Any,
    hardware: Optional[str] = None,
    mesh: Optional[str] = None,
    version: int = PLAN_VERSION,
    phase: Optional[str] = None,
) -> str:
    """Plan-DB key; ``mesh`` is a ``search.space.mesh_descriptor`` string
    ('2x4') qualifying sharded ladders — conceptually ``matmul@mesh=2x4``
    — so one fleet DB serves single-device and mesh plans side by side.
    ``phase`` ('prefill'/'decode') qualifies serving-phase ladders the
    same way — conceptually ``matmul@phase=decode`` — so the decode
    runner's skinny ``M=batch`` GEMMs rank their own ladder instead of
    inheriting the compute-bound prefill winner for the same shape.  A
    ``None`` phase is omitted from the hashed payload entirely, keeping
    every pre-phase key byte-identical (the golden fixtures pin this).
    ``version`` is overridable only so ``PlanDB.get`` can probe whether a
    miss is really a stale-format entry (a *version* miss)."""
    extra: Dict[str, Any] = {"what": "search.plan", "v": version, "mesh": mesh}
    if phase is not None:
        extra["phase"] = phase
    return cache_key(
        spec,
        dtype=np.dtype(dtype),
        hardware=hardware,
        extra=extra,
    )


#: the serving phase the *calling context* is executing under — consulted
#: by ``ops._tuned_kernel`` at trace time so the same GEMM shape resolves
#: to its phase-qualified ladder inside a prefill vs a decode runner.
#: contextvars (not a bare global) so threaded gateways and nested jit
#: traces each see their own phase.
_ACTIVE_PHASE: contextvars.ContextVar[Optional[str]] = contextvars.ContextVar(
    "repro_serving_phase", default=None
)


def active_phase() -> Optional[str]:
    """The serving phase tag of the current context, or None."""
    return _ACTIVE_PHASE.get()


@contextlib.contextmanager
def serving_phase(phase: Optional[str]) -> Iterator[None]:
    """Scope a serving phase ('prefill'/'decode') over kernel dispatch.

    Entered by the serving runners around their jitted steps; while
    active, ``ops._tuned_kernel`` consults the phase-qualified plan key
    first and falls back to the unphased ladder on a miss.
    """
    tok = _ACTIVE_PHASE.set(phase)
    try:
        yield
    finally:
        _ACTIVE_PHASE.reset(tok)


def grad_plan_keys(
    spec: ContractionSpec,
    dtype: Any,
    hardware: Optional[str] = None,
    mesh: Optional[str] = None,
) -> Dict[str, str]:
    """Plan keys of a forward spec's derived backward specs.

    ``{operand -> key}`` for each cotangent GEMM (``grad.derive``): the
    keys ``ops``'s custom VJPs look up at training time, and the ones a
    ``--with-grads`` sweep fills.  Disjoint from the forward key because
    ``spec_signature`` includes the derived spec's name and structure.
    """
    from ..grad import derived_specs

    return {
        wrt: plan_key(d, dtype, hardware, mesh=mesh)
        for wrt, d in derived_specs(spec).items()
    }


class PlanDB:
    """Ranked schedules per (spec, dtype, hardware)."""

    def __init__(self, path: str):
        self._cache = AutotuneCache(path)
        self._cache.metrics_prefix = "plandb"  # obs: plandb.hit/.miss

    @property
    def path(self) -> str:
        return self._cache.path

    @property
    def lookup_hits(self) -> int:
        """Successful plan lookups so far — the supported counter for
        benches/tests asserting that ops consulted the DB."""
        return self._cache.hits

    def put(
        self,
        spec: ContractionSpec,
        dtype: Any,
        ranked: List[Dict[str, Any]],
        stats: Optional[Dict[str, int]] = None,
        hardware: Optional[str] = None,
        mesh: Optional[str] = None,
        cuts: Optional[List[Dict[str, Any]]] = None,
        phase: Optional[str] = None,
    ) -> str:
        """Store ranked entries (best first). Each entry must carry a
        ``schedule`` dict from ``schedule_to_dict``; score/measured_s/
        lower_bound/collective/source/explain ride along verbatim.
        ``mesh`` is the shape descriptor ('2x4') for a mesh-tier sweep,
        None for single-device ladders; ``phase`` tags a serving-phase
        ladder ('prefill'/'decode').  ``cuts`` is the bound-cut sample
        ``obs.explain`` shows as the why-not side of the table.  The
        entry records its own ``spec`` signature + ``dtype`` (since v3)
        so explain selectors can find it without recomputing keys."""
        from ..codegen.cache import spec_signature

        key = plan_key(spec, dtype, hardware, mesh=mesh, phase=phase)
        payload = {
            "v": PLAN_VERSION,
            "mesh": mesh,
            "spec": spec_signature(spec),
            "dtype": str(np.dtype(dtype)),
            "ranked": ranked,
            "stats": stats or {},
            "cuts": cuts or [],
        }
        if phase is not None:
            payload["phase"] = phase
        self._cache.put(key, payload)
        return key

    def get(
        self, spec: ContractionSpec, dtype: Any,
        hardware: Optional[str] = None,
        mesh: Optional[str] = None,
        phase: Optional[str] = None,
    ) -> Optional[Dict[str, Any]]:
        entry = self._cache.get(
            plan_key(spec, dtype, hardware, mesh=mesh, phase=phase)
        )
        if entry is None and phase is None:
            # classify the miss: an entry under an older PLAN_VERSION key
            # means the fleet DB predates a format bump (plans went cold
            # deliberately) rather than never having been swept — an
            # operator reading the metrics dump re-sweeps instead of
            # hunting a phantom sweep gap
            for old_v in range(1, PLAN_VERSION):
                if self._cache.contains(
                    plan_key(spec, dtype, hardware, mesh=mesh, version=old_v)
                ):
                    from ..obs import counter

                    counter("plandb.version_miss").inc()
                    break
        return entry

    def best_schedule(
        self, spec: ContractionSpec, dtype: Any,
        hardware: Optional[str] = None,
        mesh: Optional[str] = None,
        phase: Optional[str] = None,
    ) -> Optional[Schedule]:
        """The stored winner, deserialized and validated — or None.

        A corrupt or stale entry (e.g. an extent mismatch after a spec
        change) degrades to a miss, never an error: callers fall back to
        ``codegen.tune_schedule``.
        """
        sched, _ = self.best_entry(spec, dtype, hardware, mesh=mesh,
                                   phase=phase)
        return sched

    def best_entry(
        self, spec: ContractionSpec, dtype: Any,
        hardware: Optional[str] = None,
        mesh: Optional[str] = None,
        phase: Optional[str] = None,
    ) -> Tuple[Optional[Schedule], Dict[str, Any]]:
        """(winner schedule, its raw entry dict) — or (None, {}).

        The entry dict carries the plan metadata the schedule alone cannot
        (notably ``collective`` — the finishing-reduction strategy a
        mesh-sharded plan was measured with, which ``ops._tuned_kernel``
        forwards to ``bind_mesh``).
        """
        entry = self.get(spec, dtype, hardware, mesh=mesh, phase=phase)
        if not entry or not entry.get("ranked"):
            return None, {}
        try:
            rung = entry["ranked"][0]
            return schedule_from_dict(rung["schedule"], spec.root()), rung
        except Exception:
            return None, {}

    def best_sharded_entry(
        self, spec: ContractionSpec, dtype: Any,
        hardware: Optional[str] = None,
        mesh: Optional[str] = None,
    ) -> Tuple[Optional[Schedule], Dict[str, Any]]:
        """The best rung with ``mesh:*`` levels, or (None, {}).

        A mesh-qualified ladder keeps the single-device plans as
        reference rungs (they often out-measure shard_map on the CPU
        harness), but a caller running *under a live mesh* wants the best
        plan that actually distributes — its operands are sharded and a
        single-device kernel would force a gather.  This is the lookup
        ``ops._mesh_plan_kernel`` performs.
        """
        entry = self.get(spec, dtype, hardware, mesh=mesh)
        if not entry or not entry.get("ranked"):
            return None, {}
        from ..core.schedule import MESH_TIERS

        for rung in entry["ranked"]:
            try:
                sched = schedule_from_dict(rung["schedule"], spec.root())
            except Exception:
                continue
            if any(l.tier in MESH_TIERS for l in sched.levels):
                return sched, rung
        return None, {}

    def clear(self) -> None:
        self._cache.clear()


_default: Optional[PlanDB] = None


def default_plan_db() -> PlanDB:
    """Process-wide DB at $REPRO_PLAN_DB or ~/.cache/repro/plans.json."""
    global _default
    path = os.environ.get("REPRO_PLAN_DB") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "plans.json"
    )
    if _default is None or _default.path != path:
        _default = PlanDB(path)
    return _default


def entry_from(
    schedule: Schedule,
    *,
    score: float,
    lower_bound: float,
    fits_vmem: bool,
    measured_s: Optional[float] = None,
    source: str = "search",
    collective: str = "",
    explain: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """One ranked rung.  ``explain`` carries the roofline terms the rank
    was decided from (``beam.CostEstimate``: compute_s/hbm_s/comm_s/
    penalty/seq_steps/shards) — rendered by ``obs.explain``."""
    return {
        "schedule": schedule_to_dict(schedule),
        "score": float(score),
        "lower_bound": float(lower_bound),
        "fits_vmem": bool(fits_vmem),
        "measured_s": None if measured_s is None else float(measured_s),
        "source": source,
        "collective": collective,
        "explain": dict(explain or {}),
    }
