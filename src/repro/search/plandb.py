"""Ranked plan database — the search pipeline's persistent output.

Where ``codegen.cache`` stores *one* tuned schedule per key, the plan DB
stores the search's whole ranked ladder (schedule + analytic score + roofline
bound + measured time + search stats), so ops can take the winner today and
an operator can inspect or re-rank the runners-up tomorrow without
re-searching.  Storage reuses ``codegen.cache.AutotuneCache`` (atomic JSON,
concurrent-writer safe) in a *separate* file so search-format changes can
never corrupt the PR-1 autotune cache:

    $REPRO_PLAN_DB if set, else ~/.cache/repro/plans.json

Keys come from ``codegen.cache.cache_key`` with a ``search.plan`` marker, so
they are disjoint from autotune keys even if the files are merged by hand.
"""

from __future__ import annotations

import os
from typing import Any, Dict, List, Optional

import numpy as np

from ..codegen.cache import (
    AutotuneCache,
    cache_key,
    schedule_from_dict,
    schedule_to_dict,
)
from ..core.enumerate import ContractionSpec
from ..core.schedule import Schedule

#: bump when the ranked-entry layout changes
PLAN_VERSION = 1


def plan_key(
    spec: ContractionSpec, dtype: Any, hardware: Optional[str] = None
) -> str:
    return cache_key(
        spec,
        dtype=np.dtype(dtype),
        hardware=hardware,
        extra={"what": "search.plan", "v": PLAN_VERSION},
    )


def grad_plan_keys(
    spec: ContractionSpec, dtype: Any, hardware: Optional[str] = None
) -> Dict[str, str]:
    """Plan keys of a forward spec's derived backward specs.

    ``{operand -> key}`` for each cotangent GEMM (``grad.derive``): the
    keys ``ops``'s custom VJPs look up at training time, and the ones a
    ``--with-grads`` sweep fills.  Disjoint from the forward key because
    ``spec_signature`` includes the derived spec's name and structure.
    """
    from ..grad import derived_specs

    return {
        wrt: plan_key(d, dtype, hardware)
        for wrt, d in derived_specs(spec).items()
    }


class PlanDB:
    """Ranked schedules per (spec, dtype, hardware)."""

    def __init__(self, path: str):
        self._cache = AutotuneCache(path)

    @property
    def path(self) -> str:
        return self._cache.path

    @property
    def lookup_hits(self) -> int:
        """Successful plan lookups so far — the supported counter for
        benches/tests asserting that ops consulted the DB."""
        return self._cache.hits

    def put(
        self,
        spec: ContractionSpec,
        dtype: Any,
        ranked: List[Dict[str, Any]],
        stats: Optional[Dict[str, int]] = None,
        hardware: Optional[str] = None,
    ) -> str:
        """Store ranked entries (best first). Each entry must carry a
        ``schedule`` dict from ``schedule_to_dict``; score/measured_s/
        lower_bound/source ride along verbatim."""
        key = plan_key(spec, dtype, hardware)
        self._cache.put(
            key,
            {
                "v": PLAN_VERSION,
                "ranked": ranked,
                "stats": stats or {},
            },
        )
        return key

    def get(
        self, spec: ContractionSpec, dtype: Any,
        hardware: Optional[str] = None,
    ) -> Optional[Dict[str, Any]]:
        return self._cache.get(plan_key(spec, dtype, hardware))

    def best_schedule(
        self, spec: ContractionSpec, dtype: Any,
        hardware: Optional[str] = None,
    ) -> Optional[Schedule]:
        """The stored winner, deserialized and validated — or None.

        A corrupt or stale entry (e.g. an extent mismatch after a spec
        change) degrades to a miss, never an error: callers fall back to
        ``codegen.tune_schedule``.
        """
        entry = self.get(spec, dtype, hardware)
        if not entry or not entry.get("ranked"):
            return None
        try:
            return schedule_from_dict(
                entry["ranked"][0]["schedule"], spec.root()
            )
        except Exception:
            return None

    def clear(self) -> None:
        self._cache.clear()


_default: Optional[PlanDB] = None


def default_plan_db() -> PlanDB:
    """Process-wide DB at $REPRO_PLAN_DB or ~/.cache/repro/plans.json."""
    global _default
    path = os.environ.get("REPRO_PLAN_DB") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "plans.json"
    )
    if _default is None or _default.path != path:
        _default = PlanDB(path)
    return _default


def entry_from(
    schedule: Schedule,
    *,
    score: float,
    lower_bound: float,
    fits_vmem: bool,
    measured_s: Optional[float] = None,
    source: str = "search",
) -> Dict[str, Any]:
    return {
        "schedule": schedule_to_dict(schedule),
        "score": float(score),
        "lower_bound": float(lower_bound),
        "fits_vmem": bool(fits_vmem),
        "measured_s": None if measured_s is None else float(measured_s),
        "source": source,
    }
