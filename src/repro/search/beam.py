"""Cost-guided beam search over the candidate space — the analytic early-cut.

The paper measures every enumerated variant and its Future Work asks for an
analytic rule that cuts the space before measurement.  This module is that
rule, structured as a beam search:

  state     = (loop order, block choice for a prefix of the root indices)
  extension = pick the next index's block/chunk from ``space.block_choices``
  score     = pessimistic analytic step time (roofline max(compute, HBM
              traffic) x alignment/VMEM penalties) with unassigned indices
              defaulted to whole-extent blocks
  bound     = the same roofline WITHOUT penalties — a true lower bound on
              the score of every completion of the state, because leaving
              an index whole minimizes trips for every operand

Two prune mechanisms, kept separate because they have different guarantees:

  * **bound cut** (sound): a state is dropped when its lower bound already
    exceeds the best *complete* candidate's score — no completion can win.
    Every such cut is recorded in ``SearchStats.bound_log`` and the
    invariant (bound >= best-at-prune) is property-tested in
    ``tests/test_search.py``.
  * **beam trim** (heuristic): surviving states are ranked by score and only
    the best ``beam_width`` continue.  This is the configurable-width knob;
    with width >= |space| the search is exhaustive.

States are deduplicated by ``Candidate.canonical_key`` — SJT neighbours that
the exchange rules map to the same generated kernel collapse to one state.

Observability (``repro.obs``): ``search_schedule`` wraps the phases in
``search.enumerate``/``search.beam``/``search.measure`` trace spans and
surfaces ``SearchStats`` through the metrics registry
(``search.candidates``/``search.pruned_bound``/``search.pruned_beam``...);
each ``CostEstimate``'s terms are persisted per plan-DB rung and rendered
by ``scripts/obs_report.py --explain`` — the cost model's working is part
of the search's output, not a side effect.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.cost import TPU
from ..core.enumerate import ContractionSpec
from .space import (
    Candidate,
    MeshVariant,
    block_choices,
    local_extents,
    make_candidate,
)
from .space import mesh_variants as enumerate_mesh_variants


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Analytic roofline estimate for one candidate (seconds, per device)."""

    score: float          # pessimistic proxy for measurement: bound * penalty
    lower_bound: float    # max(compute, HBM, comm) — no penalties
    compute_s: float
    hbm_s: float
    fits_vmem: bool
    penalty: float
    seq_steps: int        # tie-break: fewer fori_loop steps win
    comm_s: float = 0.0   # exposed collective time (mesh-sharded reductions)
    shards: int = 1       # devices the candidate spreads over


def estimate(
    spec: ContractionSpec,
    order: Sequence[str],
    blocks: Dict[str, int],
    *,
    elem_bytes: int = 4,
    hw: dict = TPU,
    assigned: Optional[frozenset] = None,
    mesh: Optional[Dict[str, Tuple[str, int]]] = None,
    collective: str = "",
) -> CostEstimate:
    """Roofline cost of a (possibly partial) candidate, per device.

    ``blocks`` must cover every index (callers default unassigned indices to
    their whole *local* extent — the traffic-minimal choice, which is what
    makes ``lower_bound`` sound for partial states).  ``assigned`` restricts
    the alignment penalties to decided indices so a partial state is never
    penalized for a choice it has not made yet.

    With ``mesh`` the estimate is the per-device roofline: compute and HBM
    terms shrink by the shard counts (each device owns a local slice), and
    a sharded *reduce* index adds the communication term — the exposed
    link time of the finishing collective under the interconnect model of
    ``roofline.analysis`` (``psum`` = fully exposed all-reduce; ``ring`` =
    reduce-scatter pipelined behind compute + exposed all-gather).  The
    mesh assignment and collective are decided before any block choice, so
    the comm term is constant across a state's completions and the bound
    cut stays sound.
    """
    spec = spec.root()
    mesh = dict(mesh or {})
    extents = local_extents(spec, mesh)  # per-shard view
    shards = 1
    for _, n in mesh.values():
        shards *= n
    n_blocks = {i: extents[i] // blocks[i] for i in spec.output}
    vmem = 0
    traffic = 0.0
    for name, axes in spec.operands.items():
        block_elems = 1
        for a in axes:
            # reduce axes are VMEM-resident at full extent in generated
            # kernels (codegen.plan); only map blocking shrinks the block
            block_elems *= blocks[a] if a in spec.output else extents[a]
        vmem += block_elems
        elems = math.prod(extents[a] for a in axes)
        trips = math.prod(
            n_blocks[i] for i in spec.output if i not in axes
        )
        traffic += elems * trips
    out_block = math.prod(blocks[i] for i in spec.output)
    out_elems = math.prod(extents[i] for i in spec.output)

    # quantized specs stream operands at storage precision (1 byte) but
    # write the 4-byte accumulator/dequantized output — the whole point of
    # the precision tier.  Non-quant keeps the caller's elem_bytes on both
    # sides (expressions unchanged so existing scores stay bit-identical).
    quant = getattr(spec, "quant", None)
    if quant is None:
        out_elem_bytes = elem_bytes
        vmem_bytes = (vmem + 2 * out_block) * elem_bytes
        hbm_s = (traffic + out_elems) * elem_bytes / hw["hbm_bw"]
    else:
        from ..roofline.analysis import quant_byte_model

        op_b, out_elem_bytes = quant_byte_model(quant, elem_bytes)
        vmem_bytes = vmem * op_b + 2 * out_block * out_elem_bytes
        hbm_s = (
            traffic * op_b + out_elems * out_elem_bytes
        ) / hw["hbm_bw"]
    compute_s = spec.flops() / shards / hw["peak_flops"]

    # fused-family terms.  Both stay sound for the bound cut: unassigned
    # indices default to whole extents, which minimizes the attention
    # rescale term (t_steps = 1), and the grouped ragged-tail factor only
    # applies once the row-tile choice is actually decided.
    kind = getattr(spec, "fused_kind", "")
    if kind == "attention":
        from ..roofline.analysis import attention_rescale_seconds

        compute_s += attention_rescale_seconds(
            extents["h"], extents["s"], extents["e"],
            extents["t"] // blocks["t"],
            peak=hw["peak_flops"],
        )
    elif kind == "grouped_matmul" and "n" in (
        assigned if assigned is not None else frozenset(spec.indices)
    ):
        from ..roofline.analysis import grouped_tail_factor

        compute_s *= grouped_tail_factor(spec.group_sizes, blocks["n"])

    # communication: a mesh-sharded reduce index leaves every device with a
    # partial local output that a collective must finish
    comm_s = 0.0
    reduce_shards = 1
    for i, (_, n) in mesh.items():
        if i not in spec.output:
            reduce_shards *= n
    if reduce_shards > 1:
        from ..roofline.analysis import sharded_reduce_seconds

        out_bytes = out_elems * out_elem_bytes
        comm_s = sharded_reduce_seconds(
            out_bytes,
            reduce_shards,
            collective=collective or "psum",
            compute_s=compute_s,
            hw_ici_bw=hw.get("ici_bw", 50e9),
        )

    lower = max(hbm_s, compute_s, comm_s)
    fits = vmem_bytes <= hw["vmem_bytes"]

    decided = assigned if assigned is not None else frozenset(spec.indices)
    penalty = 1.0
    last = spec.output[-1]
    if last in decided and blocks[last] % hw["mxu"][1] and blocks[last] != extents[last]:
        penalty *= 1.25
    if len(spec.output) >= 2:
        sub = spec.output[-2]
        if sub in decided and blocks[sub] % hw["sublane"] and blocks[sub] != extents[sub]:
            penalty *= 1.1
    # grid-dim order: the fastest-varying grid dim should be the output's
    # contiguous axis so successive blocks write adjacent HBM lines
    grid = [
        i for i in order
        if i in spec.output and i in decided and blocks[i] < extents[i]
    ]
    if grid and blocks.get(last, extents[last]) < extents[last] and grid[-1] != last:
        penalty *= 1.05
    if not fits and decided == frozenset(spec.indices):
        penalty *= 8.0  # would spill on real hardware
    seq_steps = sum(
        extents[i] // blocks[i] for i in spec.indices if i not in spec.output
    )
    return CostEstimate(
        score=lower * penalty,
        lower_bound=lower,
        compute_s=compute_s,
        hbm_s=hbm_s,
        fits_vmem=fits,
        penalty=penalty,
        seq_steps=seq_steps,
        comm_s=comm_s,
        shards=shards,
    )


@dataclasses.dataclass
class SearchStats:
    """What the search did — surfaced in benches and the sweep CLI."""

    considered: int = 0     # states scored (after dedup)
    deduped: int = 0        # states collapsed by canonical_key
    pruned_bound: int = 0   # sound roofline cuts
    pruned_beam: int = 0    # heuristic width trims
    measured: int = 0       # candidates actually lowered + timed
    mesh_variants: int = 0  # mesh subdivisions enumerated (0 = no mesh)
    #: (canonical_key, lower_bound, best_complete_score_at_prune)
    bound_log: List[Tuple[str, float, float]] = dataclasses.field(
        default_factory=list
    )

    def as_dict(self) -> Dict[str, int]:
        return {
            "considered": self.considered,
            "deduped": self.deduped,
            "pruned_bound": self.pruned_bound,
            "pruned_beam": self.pruned_beam,
            "measured": self.measured,
            "mesh_variants": self.mesh_variants,
        }


@dataclasses.dataclass(frozen=True)
class ScoredCandidate:
    candidate: Candidate
    cost: CostEstimate

    def sort_key(self):
        c = self.cost
        return (not c.fits_vmem, c.score, c.seq_steps, self.candidate.canonical_key())


def _greedy_complete(
    spec: ContractionSpec,
    order: Tuple[str, ...],
    choices: Dict[str, List[int]],
    elem_bytes: int,
    hw: dict,
    variant: MeshVariant = MeshVariant(),
) -> ScoredCandidate:
    """Cheapest single-path completion — seeds the bound cut with a real
    complete candidate before the beam has finished any."""
    mesh = variant.as_dict()
    blocks: Dict[str, int] = {}
    defaults = local_extents(spec, mesh)
    for index in spec.indices:
        best_b, best_s = None, None
        for b in choices[index]:
            trial = {**defaults, **blocks, index: b}
            est = estimate(
                spec, order, trial, elem_bytes=elem_bytes, hw=hw,
                assigned=frozenset(blocks) | {index},
                mesh=mesh, collective=variant.collective,
            )
            key = (not est.fits_vmem, est.score, est.seq_steps, b)
            if best_s is None or key < best_s:
                best_b, best_s = b, key
        blocks[index] = best_b
    cand = make_candidate(
        spec, order, blocks, mesh=mesh, collective=variant.collective
    )
    return ScoredCandidate(
        cand,
        estimate(
            spec, order, blocks, elem_bytes=elem_bytes, hw=hw,
            mesh=mesh, collective=variant.collective,
        ),
    )


def beam_search(
    spec: ContractionSpec,
    *,
    beam_width: int = 8,
    topk: int = 4,
    elem_bytes: int = 4,
    hw: dict = TPU,
    orders: Optional[Sequence[Sequence[str]]] = None,
    choices: Optional[Dict[str, List[int]]] = None,
    max_orders: int = 24,
    bound_slack: float = 1.25,
    stats: Optional[SearchStats] = None,
    mesh_shape: Optional[Sequence[int]] = None,
    mesh_variants: Optional[Sequence[MeshVariant]] = None,
) -> Tuple[List[ScoredCandidate], SearchStats]:
    """Enumerate-and-cut: returns the analytic top-``topk`` candidates.

    The survivors are ranked best-first by (fits-VMEM, score, seq steps);
    measurement of the survivors is ``measure.measure_schedules``'s job.

    ``bound_slack`` widens the sound cut: a state is dropped only when its
    lower bound exceeds ``slack x`` the best complete score, so candidates
    the analytic model ranks within ``slack`` of the proxy still reach
    measurement — the model is a napkin, the clock is the judge.

    With ``mesh_shape`` (or an explicit ``mesh_variants`` list) the search
    is joint over the mesh tier: every legal mesh subdivision ×collective
    (``space.mesh_variants``) seeds its own states, all competing in the
    same beam under the communication-aware per-device roofline.  The
    unsharded variant stays in the space, so a mesh that does not pay for
    its collectives loses to single-device on merit, not by fiat.
    """
    spec = spec.root()
    stats = stats if stats is not None else SearchStats()
    if orders is None:
        from .. import obs
        from .space import candidate_orders_counted

        with obs.span("search.enumerate", spec=spec.name):
            orders, visited = candidate_orders_counted(spec, max_orders)
        stats.deduped += max(visited - len(orders), 0)
    orders = [tuple(o) for o in orders]
    if mesh_variants is None:
        mesh_variants = enumerate_mesh_variants(spec, mesh_shape)
    variants: List[MeshVariant] = list(mesh_variants) or [MeshVariant()]
    stats.mesh_variants += sum(1 for v in variants if v.assignment)
    # per-variant block choices (and whole-extent defaults) range over the
    # per-shard local extents
    var_choices: List[Dict[str, List[int]]] = []
    var_defaults: List[Dict[str, int]] = []
    for v in variants:
        if v.assignment:
            var_choices.append(
                block_choices(spec, hw, mesh=v.as_dict())
            )
            var_defaults.append(local_extents(spec, v.as_dict()))
        else:
            var_choices.append(choices or block_choices(spec, hw))
            var_defaults.append({i: spec.extents[i] for i in spec.indices})

    best_complete: Optional[ScoredCandidate] = None
    best_sharded: Optional[ScoredCandidate] = None
    for vi, v in enumerate(variants):
        for order in orders[: max(1, min(2, len(orders)))]:
            g = _greedy_complete(
                spec, order, var_choices[vi], elem_bytes, hw, v
            )
            if best_complete is None or g.sort_key() < best_complete.sort_key():
                best_complete = g
            if v.assignment and (
                best_sharded is None or g.sort_key() < best_sharded.sort_key()
            ):
                best_sharded = g

    # state = (order, blocks-so-far, variant); one decision stage per root
    # index.  States never need mid-stage dedup: initial (order, variant)
    # pairs are distinct and blocks-so-far distinguish the rest; states
    # that converge (an index left whole) collapse at the final dedup below.
    states: List[Tuple[Tuple[str, ...], Dict[str, int], int]] = [
        (o, {}, vi) for vi in range(len(variants)) for o in orders
    ]
    decision_seq = spec.indices
    final: List[ScoredCandidate] = []
    for stage, index in enumerate(decision_seq):
        extended: List[
            Tuple[ScoredCandidate, Tuple[str, ...], Dict[str, int], int]
        ] = []
        complete_stage = stage == len(decision_seq) - 1
        for order, blocks, vi in states:
            v = variants[vi]
            mesh = v.as_dict()
            for b in var_choices[vi][index]:
                nb = {**blocks, index: b}
                assigned = frozenset(nb)
                full = {**var_defaults[vi], **nb}
                cand = make_candidate(
                    spec, order, full, mesh=mesh, collective=v.collective
                )
                est = estimate(
                    spec, order, full,
                    elem_bytes=elem_bytes, hw=hw, assigned=assigned,
                    mesh=mesh, collective=v.collective,
                )
                stats.considered += 1
                sc = ScoredCandidate(cand, est)
                if (
                    best_complete is not None
                    and not complete_stage
                    and est.lower_bound >= best_complete.cost.score * bound_slack
                ):
                    # sound cut: no completion can beat the best proxy
                    stats.pruned_bound += 1
                    stats.bound_log.append(
                        (cand.canonical_key(), est.lower_bound,
                         best_complete.cost.score)
                    )
                    continue
                if complete_stage:
                    if (
                        best_complete is None
                        or sc.sort_key() < best_complete.sort_key()
                    ):
                        best_complete = sc
                    if v.assignment and (
                        best_sharded is None
                        or sc.sort_key() < best_sharded.sort_key()
                    ):
                        best_sharded = sc
                extended.append((sc, order, nb, vi))
        extended.sort(key=lambda t: t[0].sort_key())
        if len(extended) > beam_width:
            stats.pruned_beam += len(extended) - beam_width
            extended = extended[:beam_width]
        states = [(order, blocks, vi) for _, order, blocks, vi in extended]
        if complete_stage:
            final = [sc for sc, _, _, _ in extended]

    if best_complete is not None:
        # the greedy seed (or a completion the trim later dropped) is a real
        # candidate — keep it in the ranking; dedup collapses repeats
        final = list(final) + [best_complete]

    ranked: List[ScoredCandidate] = sorted(final, key=lambda s: s.sort_key())
    # dedup complete candidates by canonical key (orders can converge)
    out: List[ScoredCandidate] = []
    seen_keys = set()
    for sc in ranked:
        k = sc.candidate.canonical_key()
        if k in seen_keys:
            stats.deduped += 1
            continue
        seen_keys.add(k)
        out.append(sc)
        if len(out) >= topk:
            break
    # a mesh search must surface at least one sharded plan: if the beam's
    # topk is all-unsharded (tiny problems on the analytic model), the best
    # sharded complete candidate rides along so measurement and the plan DB
    # still cover the mesh tier
    if best_sharded is not None and not any(
        sc.candidate.mesh for sc in out
    ):
        key = best_sharded.candidate.canonical_key()
        if key not in seen_keys:
            out.append(best_sharded)
    return out, stats
