"""Measure surviving candidates through the kernel generator.

The paper measures *every* variant; after the analytic cut only the beam's
top-K reach this stage.  Each survivor is lowered with ``codegen.compile``
(the same path ``ops.dense`` uses) and timed; ``interpret=True`` runs the
Pallas interpreter so the loop closes on CPU-only machines — on a TPU the
same call times the real kernel.

Schedules with ``mesh:*`` levels are lowered through ``codegen.bind_mesh``
over a real device mesh: on a multi-chip host that is the hardware mesh,
in CI it is the ``--xla_force_host_platform_device_count``-forced CPU mesh
(``tests/test_mesh_search.py`` and the mesh-smoke job force 8).
``mesh_for_schedules`` builds the smallest mesh the candidate set needs
from the visible devices, or returns None when the process cannot host it
— in which case sharded candidates keep their analytic score and only the
single-device ones are timed.

Timing uses min-over-repeats after a warmup call (compilation is excluded),
mirroring ``benchmarks.common.timeit``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.enumerate import ContractionSpec
from ..core.schedule import MESH_TIERS, Schedule


@dataclasses.dataclass
class Measurement:
    schedule: Schedule
    seconds: float
    max_err: Optional[float]  # vs einsum reference; None when skipped


def schedule_mesh_axes(schedule: Schedule) -> Dict[str, int]:
    """{mesh axis -> size} a schedule's mesh levels require (may be {})."""
    out: Dict[str, int] = {}
    for l in schedule.levels:
        if l.tier in MESH_TIERS:
            axis = l.tier.split(":", 1)[1]
            out[axis] = out.get(axis, 1) * l.extent
    return out


def mesh_for_schedules(schedules: Sequence[Schedule]):
    """The smallest debug mesh hosting every sharded schedule, or None.

    Every schedule that uses a mesh axis must use the whole axis (that is
    what ``space.mesh_variants`` emits — an axis is either assigned to an
    index at its full size or left unused/replicated), so conflicting
    sizes for one axis are a caller bug and raise.  Returns None when no
    schedule has mesh levels or the process has too few devices (run
    under ``--xla_force_host_platform_device_count`` to force more).
    """
    need: Dict[str, int] = {}
    for s in schedules:
        for axis, size in schedule_mesh_axes(s).items():
            if need.setdefault(axis, size) != size:
                raise ValueError(
                    f"schedules disagree on mesh axis {axis!r} size: "
                    f"{need[axis]} vs {size}"
                )
    if not need:
        return None
    import math as _math

    import jax

    from ..launch.mesh import make_debug_mesh

    # canonical axis order (pod, data, model) per core.schedule.MESH_TIERS
    order = [t.split(":", 1)[1] for t in MESH_TIERS]
    axes = tuple(a for a in order if a in need)
    shape = tuple(need[a] for a in axes)
    if _math.prod(shape) > jax.device_count():
        return None
    return make_debug_mesh(shape, axes)


def reference_arrays(
    spec: ContractionSpec, dtype=np.float32, seed: int = 0
) -> Dict[str, np.ndarray]:
    """Standard-normal operand arrays in ``spec.operands`` order.

    Integer dtypes (the int8 quant tier) draw small ints instead — every
    product and partial sum is then exactly representable, so the f64
    einsum oracle doubles as the *dequantized* oracle.  fp8 callers pass
    an fp8 ``dtype``: the normal draw rounds through storage precision
    here, which charges input quantization to the data (where it belongs),
    not to the kernel under test.
    """
    rng = np.random.default_rng(seed)
    spec = spec.root()
    dt = np.dtype(dtype)

    def draw(shape):
        if dt.kind in ("i", "u"):
            return rng.integers(-4, 5, size=shape).astype(dt)
        return rng.standard_normal(shape).astype(dt)

    return {
        name: draw(tuple(spec.extents[i] for i in axes))
        for name, axes in spec.operands.items()
    }


def einsum_reference(
    spec: ContractionSpec, arrays: Dict[str, np.ndarray]
) -> np.ndarray:
    """np.einsum oracle for a root spec (f64 accumulation).

    Fused families are not single einsums — attention gets a stable f64
    softmax oracle, grouped_matmul a per-group f64 loop.
    """
    from ..core.enumerate import einsum_formula

    spec = spec.root()
    kind = getattr(spec, "fused_kind", "")
    if kind == "attention":
        q, k, v = (
            np.asarray(arrays[n], np.float64) for n in ("Q", "K", "V")
        )
        s = np.einsum("hsd,htd->hst", q, k) * spec.extents["d"] ** -0.5
        if spec.causal:
            t_ids = np.arange(spec.extents["t"])[None, None, :]
            s_ids = np.arange(spec.extents["s"])[None, :, None]
            s = np.where(t_ids <= s_ids, s, -np.inf)
        p = np.exp(s - s.max(axis=-1, keepdims=True))
        p = p / p.sum(axis=-1, keepdims=True)
        return np.einsum("hst,hte->hse", p, v)
    if kind == "grouped_matmul":
        names = tuple(spec.operands)
        vals = {n: np.asarray(arrays[n], np.float64) for n in names}
        sizes = spec.group_sizes
        if "g" in spec.output:  # dW orientation: out[g,o1,o2]
            _, o1, o2 = spec.output
            lhs = next(n for n in names if o1 in spec.operands[n])
            rhs = next(n for n in names if o2 in spec.operands[n])
            out = np.zeros(
                tuple(spec.extents[i] for i in spec.output), np.float64
            )
            o = 0
            for g, s_g in enumerate(sizes):
                out[g] = vals[lhs][o : o + s_g].T @ vals[rhs][o : o + s_g]
                o += s_g
            return out
        # row orientation (fwd / dX): out[n, oc]
        xname, wname = names
        oc = spec.output[1]
        c = spec.operands[xname][1]
        w_axes = spec.operands[wname]
        out = np.zeros(
            tuple(spec.extents[i] for i in spec.output), np.float64
        )
        o = 0
        for g, s_g in enumerate(sizes):
            wg = vals[wname][g]
            if w_axes.index(c) == 2:  # shared axis last -> transpose
                wg = wg.T
            out[o : o + s_g] = vals[xname][o : o + s_g] @ wg
            o += s_g
        return out
    return np.einsum(
        einsum_formula(spec),
        *(np.asarray(arrays[n], np.float64) for n in spec.operands),
    )


def measure_schedules(
    spec: ContractionSpec,
    schedules: Sequence[Schedule],
    *,
    arrays: Optional[Dict[str, np.ndarray]] = None,
    dtype=np.float32,
    interpret: bool = True,
    repeats: int = 2,
    check: bool = True,
    tol: Optional[float] = None,
    mesh=None,
    collectives: Optional[Sequence[str]] = None,
) -> List[Measurement]:
    """Lower + time each schedule; same operand data for every candidate.

    With ``check=True`` every measured kernel is verified against the
    einsum oracle and a mismatch raises — a schedule that computes the
    wrong answer must never win the search.  The default tolerance is
    dtype-appropriate: 1e-3 relative for >= 32-bit floats, 5e-2 for
    half-precision (bf16/f16 round the *stored* output even though the
    generated kernels accumulate in f32).

    Schedules with ``mesh:*`` levels lower through ``codegen.bind_mesh``
    over ``mesh`` (default: ``mesh_for_schedules`` over the visible
    devices; a sharded schedule with no hostable mesh raises).
    ``collectives`` optionally names the finishing-collective lowering per
    schedule (``"psum"``/``"ring"``, ignored for unsharded entries); the
    operands stay global arrays either way, so the oracle check is
    identical for sharded and single-device candidates.
    """
    import jax.numpy as jnp

    from ..codegen import cached_compile

    spec = spec.root()
    quantized = np.dtype(dtype).itemsize == 1
    if tol is None:
        # quantized operands (itemsize 1) are exactly representable by
        # construction (reference_arrays), so the kernel only differs from
        # the f64 oracle by f32 accumulation order — full-precision tol
        tol = (
            1e-3 if np.dtype(dtype).itemsize >= 4 or quantized else 5e-2
        )
    if arrays is None:
        arrays = reference_arrays(spec, dtype=dtype)
    jarrs = tuple(jnp.asarray(arrays[n]) for n in spec.operands)
    ref = einsum_reference(spec, arrays) if check else None
    if mesh is None:
        mesh = mesh_for_schedules(schedules)

    out: List[Measurement] = []
    for pos, sched in enumerate(schedules):
        sharded = bool(schedule_mesh_axes(sched))
        if sharded and mesh is None:
            raise ValueError(
                f"schedule {sched.levels} needs a device mesh but none is "
                f"available (devices visible: see jax.device_count(); force "
                f"more with --xla_force_host_platform_device_count)"
            )
        coll = (collectives[pos] if collectives else "") or "psum"
        kern = cached_compile(
            spec, sched, interpret=interpret,
            # 1-byte operands must not round-trip the accumulator through
            # int8/fp8 storage on the way out — measure the f32 result
            out_dtype=jnp.float32 if quantized else None,
            mesh=mesh if sharded else None,
            collective=coll,
        )
        result = np.asarray(kern(*jarrs))  # warmup (compile + first run)
        err = None
        if check:
            err = float(np.abs(result - ref).max() / max(np.abs(ref).max(), 1e-30))
            if err > tol:
                raise AssertionError(
                    f"schedule {sched.levels} produced wrong output "
                    f"(rel err {err:.3g} > {tol}) — refusing to rank it"
                )
        best = float("inf")
        for _ in range(max(repeats, 1)):
            t0 = time.perf_counter()
            np.asarray(kern(*jarrs))
            best = min(best, time.perf_counter() - t0)
        out.append(Measurement(schedule=sched, seconds=best, max_err=err))
    return out
