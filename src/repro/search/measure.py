"""Measure surviving candidates through the kernel generator.

The paper measures *every* variant; after the analytic cut only the beam's
top-K reach this stage.  Each survivor is lowered with ``codegen.compile``
(the same path ``ops.dense`` uses) and timed; ``interpret=True`` runs the
Pallas interpreter so the loop closes on CPU-only machines — on a TPU the
same call times the real kernel.

Timing uses min-over-repeats after a warmup call (compilation is excluded),
mirroring ``benchmarks.common.timeit``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.enumerate import ContractionSpec
from ..core.schedule import Schedule


@dataclasses.dataclass
class Measurement:
    schedule: Schedule
    seconds: float
    max_err: Optional[float]  # vs einsum reference; None when skipped


def reference_arrays(
    spec: ContractionSpec, dtype=np.float32, seed: int = 0
) -> Dict[str, np.ndarray]:
    """Standard-normal operand arrays in ``spec.operands`` order."""
    rng = np.random.default_rng(seed)
    spec = spec.root()
    return {
        name: rng.standard_normal(
            tuple(spec.extents[i] for i in axes)
        ).astype(dtype)
        for name, axes in spec.operands.items()
    }


def einsum_reference(
    spec: ContractionSpec, arrays: Dict[str, np.ndarray]
) -> np.ndarray:
    """np.einsum oracle for a root spec (f64 accumulation)."""
    from ..core.enumerate import einsum_formula

    spec = spec.root()
    return np.einsum(
        einsum_formula(spec),
        *(np.asarray(arrays[n], np.float64) for n in spec.operands),
    )


def measure_schedules(
    spec: ContractionSpec,
    schedules: Sequence[Schedule],
    *,
    arrays: Optional[Dict[str, np.ndarray]] = None,
    dtype=np.float32,
    interpret: bool = True,
    repeats: int = 2,
    check: bool = True,
    tol: Optional[float] = None,
) -> List[Measurement]:
    """Lower + time each schedule; same operand data for every candidate.

    With ``check=True`` every measured kernel is verified against the
    einsum oracle and a mismatch raises — a schedule that computes the
    wrong answer must never win the search.  The default tolerance is
    dtype-appropriate: 1e-3 relative for >= 32-bit floats, 5e-2 for
    half-precision (bf16/f16 round the *stored* output even though the
    generated kernels accumulate in f32).
    """
    import jax.numpy as jnp

    from ..codegen import cached_compile

    spec = spec.root()
    if tol is None:
        tol = 1e-3 if np.dtype(dtype).itemsize >= 4 else 5e-2
    if arrays is None:
        arrays = reference_arrays(spec, dtype=dtype)
    jarrs = tuple(jnp.asarray(arrays[n]) for n in spec.operands)
    ref = einsum_reference(spec, arrays) if check else None

    out: List[Measurement] = []
    for sched in schedules:
        kern = cached_compile(spec, sched, interpret=interpret)
        result = np.asarray(kern(*jarrs))  # warmup (compile + first run)
        err = None
        if check:
            err = float(np.abs(result - ref).max() / max(np.abs(ref).max(), 1e-30))
            if err > tol:
                raise AssertionError(
                    f"schedule {sched.levels} produced wrong output "
                    f"(rel err {err:.3g} > {tol}) — refusing to rank it"
                )
        best = float("inf")
        for _ in range(max(repeats, 1)):
            t0 = time.perf_counter()
            np.asarray(kern(*jarrs))
            best = min(best, time.perf_counter() - t0)
        out.append(Measurement(schedule=sched, seconds=best, max_err=err))
    return out
