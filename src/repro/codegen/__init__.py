"""repro.codegen — schedule-driven Pallas kernel generation.

The paper's claim is that HoF rewrite rules can distribute a contraction
"over the entire hierarchy of modern hardware".  This package makes the
claim executable for *any* ``ContractionSpec``: a ``Schedule`` (the tier
assignment produced by enumeration + the cost model, ``core.schedule``)
is compiled into a runnable JAX/Pallas kernel instead of being pattern-
matched against a fixed set of hand-written kernels.

Tier -> Pallas mapping (see ``plan.py`` for the derivation):

  =============  ==========================================================
  Schedule tier  Generated realization
  =============  ==========================================================
  ``mesh:*``     ``shard_map`` over the named mesh axis; operand
                 PartitionSpecs from ``Schedule.mesh_axes_for``; reduce
                 indices sharded on a mesh axis get a ``lax.psum`` epilogue
  ``grid``       one parallel Pallas grid dimension per level; BlockSpec
                 index maps route block ``program_id`` to the operand axes
                 (block shapes folded from ``Schedule.block_shape_for``)
  ``seq``        in-kernel ``lax.fori_loop`` over reduction chunks,
                 accumulating into a float32 VMEM scratch tile
  ``mxu``        the innermost tile, contracted with ``lax.dot_general``
                 (f32 ``preferred_element_type``) so the MXU sees a matmul
  =============  ==========================================================

Everything runs (and is tested) on CPU via Pallas interpreter mode.
``tune.py`` chooses schedules with the analytic cost model and persists
winners in a disk-backed cache (``cache.py``) keyed by
spec+shapes+dtype+hardware, so tuning cost is paid once per fleet.

Entry point::

    from repro import codegen
    kernel = codegen.compile(spec, schedule, interpret=True)
    out = kernel(A, B)                      # matches jnp.einsum
"""

from .cache import AutotuneCache, cache_key, default_cache, hardware_fingerprint
from .collectives import (
    all_reduce,
    naive_gather_matmul,
    ring_gather_matmul,
    ring_psum,
)
from .epilogue import Epilogue
from .mesh_gen import (
    MeshBoundKernel,
    bind_mesh,
    operand_partition_spec,
    output_partition_spec,
)
from .pallas_gen import CompiledKernel, cached_compile, compile_kernel
from .plan import KernelPlan, build_plan
from .schedules import (
    batched_matmul_schedule,
    chain_matmul_schedule,
    default_schedule,
    transposed_matmul_schedule,
)
from .tune import tune_schedule

#: public name per the design doc: ``codegen.compile(spec, schedule)``.
compile = compile_kernel

__all__ = [
    "AutotuneCache",
    "CompiledKernel",
    "Epilogue",
    "KernelPlan",
    "MeshBoundKernel",
    "all_reduce",
    "batched_matmul_schedule",
    "bind_mesh",
    "build_plan",
    "cache_key",
    "cached_compile",
    "chain_matmul_schedule",
    "compile",
    "compile_kernel",
    "default_cache",
    "default_schedule",
    "hardware_fingerprint",
    "naive_gather_matmul",
    "operand_partition_spec",
    "output_partition_spec",
    "ring_gather_matmul",
    "ring_psum",
    "transposed_matmul_schedule",
    "tune_schedule",
]
