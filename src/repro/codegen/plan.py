"""Schedule -> KernelPlan: the pure (array-free) half of kernel generation.

A ``Schedule`` assigns every loop level of a (possibly subdivided)
``ContractionSpec`` to a hardware tier.  ``build_plan`` folds that leaf-level
view back onto the *root* indices so the Pallas layer can build BlockSpecs
over the original operand arrays:

  tier       root-axis realization
  ---------  -------------------------------------------------------------
  mesh:*     axis sharded over the mesh axis; everything below is per-shard
  grid       axis blocked; one parallel grid dim, block = product of the
             leaf extents *below* the grid leaf (Schedule.block_shape_for)
  seq        axis resident in VMEM at full (local) extent; the kernel
             fori_loops over chunks = product of leaves below the seq leaf
  mxu        axis fully inside the block, fed to lax.dot_general

Restrictions (checked, with clear errors):
  * every index of the scheduled spec appears in exactly one level;
  * per root index the leaf tiers are ordered mesh* -> (grid|seq)? -> mxu?;
  * grid leaves must be map (output) indices — reductions use seq tiers
    (the generated kernels keep the Pallas grid fully parallel; the
    hand-written ``kernels/matmul`` keeps the grid-streamed reduction as a
    verification baseline);
  * seq leaves must be reduce indices.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from ..core.enumerate import ContractionSpec
from ..core.schedule import MESH_TIERS, Schedule


@dataclasses.dataclass(frozen=True)
class AxisPlan:
    """How one ROOT index is realized across the hierarchy."""

    index: str                      # root index name
    extent: int                     # root extent
    mesh_axes: Tuple[str, ...]      # mesh axis names, outermost first
    shards: int                     # product of mesh shard counts
    grid_dim: Optional[int]         # position in the Pallas grid, or None
    num_blocks: int                 # grid blocks (per shard); 1 if no grid
    seq_steps: int                  # fori_loop steps; 1 if no seq leaf
    block: int                      # per-grid-step block extent (incl. seq)
    chunk: int                      # per-seq-step chunk extent (== block if
                                    # no seq leaf)

    @property
    def local_extent(self) -> int:
        return self.extent // self.shards


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Everything pallas_gen/mesh_gen need, in root-index terms."""

    spec: ContractionSpec                    # the ROOT spec
    axes: Dict[str, AxisPlan]                # root index -> plan
    grid: Tuple[str, ...]                    # root indices, grid order
    seq: Tuple[str, ...]                     # root indices, seq loop order

    @property
    def grid_shape(self) -> Tuple[int, ...]:
        return tuple(self.axes[i].num_blocks for i in self.grid)

    @property
    def seq_shape(self) -> Tuple[int, ...]:
        return tuple(self.axes[i].seq_steps for i in self.seq)

    def operand_block(self, name: str) -> Tuple[int, ...]:
        return tuple(self.axes[i].block for i in self.spec.operands[name])

    def out_block(self) -> Tuple[int, ...]:
        return tuple(self.axes[i].block for i in self.spec.output)

    def out_shape(self) -> Tuple[int, ...]:
        return tuple(self.axes[i].local_extent for i in self.spec.output)


def _leaf_tree(schedule: Schedule) -> Dict[str, List[str]]:
    """root index -> ordered leaf names (outermost split first)."""
    root = schedule.spec.root()
    tree: Dict[str, List[str]] = {i: [i] for i in root.indices}
    for index, _ in schedule.spec.split_chain():
        for leaves in tree.values():
            if index in leaves:
                p = leaves.index(index)
                leaves[p : p + 1] = [index + "o", index + "i"]
                break
        else:
            raise ValueError(f"split index {index} not found in leaf tree")
    return tree


def build_plan(schedule: Schedule) -> KernelPlan:
    spec = schedule.spec
    root = spec.root()
    tiers = {l.index: l for l in schedule.levels}
    missing = set(spec.indices) - set(tiers)
    if missing:
        raise ValueError(f"schedule assigns no tier to indices {sorted(missing)}")

    tree = _leaf_tree(schedule)
    grid_order = [l.index for l in schedule.levels if l.tier == "grid"]
    seq_order = [l.index for l in schedule.levels if l.tier == "seq"]

    axes: Dict[str, AxisPlan] = {}
    grid_roots: List[str] = [None] * len(grid_order)  # type: ignore
    seq_roots: List[str] = [None] * len(seq_order)  # type: ignore
    for r, leaves in tree.items():
        is_map = r in root.output
        seen_rank = -1
        rank = {**{t: 0 for t in MESH_TIERS}, "grid": 1, "seq": 1, "mxu": 2}
        mesh_axes: List[str] = []
        shards = 1
        grid_leaf = seq_leaf = None
        below_grid = below_seq = 1
        for pos, leaf in enumerate(leaves):
            lvl = tiers[leaf]
            if rank[lvl.tier] < seen_rank:
                raise ValueError(
                    f"index {r}: leaf {leaf} tier {lvl.tier} nests outside a "
                    f"deeper tier (leaves {leaves})"
                )
            seen_rank = rank[lvl.tier]
            if lvl.tier in MESH_TIERS:
                mesh_axes.append(lvl.tier.split(":", 1)[1])
                shards *= lvl.extent
            elif lvl.tier == "grid":
                if not is_map:
                    raise ValueError(
                        f"reduce index {r} on the grid tier; generated kernels "
                        f"keep the grid parallel — schedule it as seq"
                    )
                if grid_leaf is not None:
                    raise ValueError(f"index {r} has two grid leaves")
                grid_leaf = leaf
                below_grid = math.prod(
                    tiers[l].extent for l in leaves[pos + 1 :]
                )
            elif lvl.tier == "seq":
                if is_map:
                    raise ValueError(
                        f"map index {r} on the seq tier; only reductions are "
                        f"looped inside the kernel"
                    )
                if seq_leaf is not None:
                    raise ValueError(f"index {r} has two seq leaves")
                seq_leaf = leaf
                below_seq = math.prod(
                    tiers[l].extent for l in leaves[pos + 1 :]
                )
        extent = root.extents[r]
        local = extent // shards
        num_blocks = tiers[grid_leaf].extent if grid_leaf else 1
        seq_steps = tiers[seq_leaf].extent if seq_leaf else 1
        block = below_grid if grid_leaf else local
        chunk = below_seq if seq_leaf else block
        axes[r] = AxisPlan(
            index=r,
            extent=extent,
            mesh_axes=tuple(mesh_axes),
            shards=shards,
            grid_dim=grid_order.index(grid_leaf) if grid_leaf else None,
            num_blocks=num_blocks,
            seq_steps=seq_steps,
            block=block,
            chunk=chunk,
        )
        if grid_leaf:
            grid_roots[grid_order.index(grid_leaf)] = r
        if seq_leaf:
            seq_roots[seq_order.index(seq_leaf)] = r
        assert block * num_blocks == local and chunk * seq_steps == block, (
            r, axes[r],
        )
    return KernelPlan(
        spec=root,
        axes=axes,
        grid=tuple(grid_roots),
        seq=tuple(seq_roots),
    )
