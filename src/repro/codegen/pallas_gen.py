"""KernelPlan -> Pallas kernel: the array half of kernel generation.

Generated kernel shape (one output tile per grid step, fully parallel):

    grid = plan.grid_shape                  # one dim per grid-tier level
    per-operand BlockSpec: block = folded leaf blocks (seq axes resident
      at full local extent), index map routes program_ids to grid axes
    kernel body:
      acc (out_block, f32, VMEM scratch)  = 0
      fori_loop over prod(seq steps):     # the schedule's seq tiers
        slice a chunk of every seq axis (pl.ds)
        acc += dot_general-fold of the operand chunks   # the mxu tier
      store epilogue(acc) -> out block

The dot_general fold (``_contract``) is a minimal einsum: operands are
contracted pairwise left-to-right; indices shared with later operands or
with the output become dot_general *batch* dims, the rest contract.  All
dots accumulate in float32 (``preferred_element_type``), so bf16 inputs
get f32 accumulation exactly like the hand-written kernels.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.enumerate import ContractionSpec
from ..core.schedule import Schedule
from ..kernels._compat import CompilerParams as COMPILER_PARAMS_CLS
from .epilogue import Epilogue
from .plan import KernelPlan, build_plan


def _contract(
    vals: List[jax.Array],
    axlists: List[Tuple[str, ...]],
    out_axes: Tuple[str, ...],
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Contract named-axis blocks down to ``out_axes`` via lax.dot_general.

    Pairs are folded greedily by smallest intermediate: a reduce index
    shared with a *later* operand becomes a dot_general batch dim, so
    naive left-to-right folding of e.g. A_ij B_jk g_j would materialize a
    (j, bm, bn) block; folding (A, g) first keeps every intermediate no
    larger than its inputs' footprint.
    """
    terms = list(zip(vals, [list(a) for a in axlists]))
    while len(terms) > 1:
        best = None
        for x in range(len(terms)):
            for y in range(x + 1, len(terms)):
                (a, ax), (b, bx) = terms[x], terms[y]
                rest = {
                    i
                    for z, (_, axs) in enumerate(terms)
                    if z not in (x, y)
                    for i in axs
                }
                shared = [i for i in ax if i in bx]
                contract = [
                    i for i in shared if i not in out_axes and i not in rest
                ]
                batch = [i for i in shared if i not in contract]
                res_axes = (
                    batch
                    + [i for i in ax if i not in shared]
                    + [i for i in bx if i not in shared]
                )
                sizes = {**dict(zip(bx, b.shape)), **dict(zip(ax, a.shape))}
                elems = math.prod(sizes[i] for i in res_axes) if res_axes else 1
                if best is None or elems < best[0]:
                    best = (elems, x, y, contract, batch, res_axes)
        _, x, y, contract, batch, res_axes = best
        (a, ax), (b, bx) = terms[x], terms[y]
        dn = (
            (
                tuple(ax.index(i) for i in contract),
                tuple(bx.index(i) for i in contract),
            ),
            (
                tuple(ax.index(i) for i in batch),
                tuple(bx.index(i) for i in batch),
            ),
        )
        res = lax.dot_general(a, b, dn, preferred_element_type=acc_dtype)
        terms = [
            t for z, t in enumerate(terms) if z not in (x, y)
        ]
        terms.insert(0, (res, res_axes))
    val, axes = terms[0]
    extra = [i for i in axes if i not in out_axes]
    if extra:  # reduce axes touched by a single operand
        val = jnp.sum(
            val.astype(acc_dtype),
            axis=tuple(axes.index(i) for i in extra),
        )
        axes = [i for i in axes if i not in extra]
    perm = tuple(axes.index(i) for i in out_axes)
    return jnp.transpose(val.astype(acc_dtype), perm)


def _index_map(plan: KernelPlan, axes: Sequence[str]):
    dims = tuple(plan.axes[a].grid_dim for a in axes)

    def imap(*pids):
        return tuple(pids[d] if d is not None else 0 for d in dims)

    return imap


def _make_kernel(
    plan: KernelPlan,
    names: Tuple[str, ...],
    epilogue: Optional[Epilogue],
    acc_dtype=jnp.float32,
):
    spec = plan.spec
    out_axes = spec.output
    seq_roots = plan.seq
    seq_shape = plan.seq_shape
    nsteps = math.prod(seq_shape) if seq_shape else 1
    vec_names = epilogue.vector_names if epilogue else ()
    out_rank = len(out_axes)

    def kernel(*refs):
        op_refs = refs[: len(names)]
        vec_refs = refs[len(names) : len(names) + len(vec_names)]
        o_ref = refs[len(names) + len(vec_names)]
        acc_ref = refs[-1]
        acc_ref[...] = jnp.zeros_like(acc_ref)

        def body(t, carry):
            pos: Dict[str, object] = {}
            rem = t
            for i, r in enumerate(seq_roots):
                below = math.prod(seq_shape[i + 1 :]) if i + 1 < len(
                    seq_shape
                ) else 1
                pos[r] = rem // below
                rem = rem % below
            vals, axlists = [], []
            for name, ref in zip(names, op_refs):
                axes = spec.operands[name]
                idx = tuple(
                    pl.ds(pos[a] * plan.axes[a].chunk, plan.axes[a].chunk)
                    if a in pos
                    else slice(None)
                    for a in axes
                )
                v = ref[idx]
                # quantized operands (int8 / fp8) land in VMEM at storage
                # precision; the MXU-side contraction runs on the upcast
                if v.dtype != acc_dtype and v.dtype.itemsize == 1:
                    v = v.astype(acc_dtype)
                vals.append(v)
                axlists.append(axes)
            acc_ref[...] += _contract(vals, axlists, out_axes, acc_dtype)
            return carry

        if nsteps == 1:
            body(0, 0)
        else:
            lax.fori_loop(0, nsteps, body, 0)

        out = acc_ref[...]
        if epilogue is not None and not epilogue.is_identity:
            vectors = {}
            for vname, vref in zip(vec_names, vec_refs):
                row = vref[...].astype(jnp.float32)  # (1, block_last)
                vectors[vname] = row.reshape(
                    (1,) * (out_rank - 1) + (row.shape[-1],)
                )
            out = epilogue.apply(out, vectors)
        o_ref[...] = out.astype(o_ref.dtype)

    return kernel


@dataclasses.dataclass
class CompiledKernel:
    """A generated kernel bound to one (spec, schedule) pair.

    Call with the operand arrays in ``spec.operands`` order; epilogue
    vectors (bias/mean/var/scale) go by keyword.  Shapes are the *local*
    (per-shard) shapes; use ``codegen.bind_mesh`` / ``mesh=`` for the
    sharded version.
    """

    spec: ContractionSpec
    schedule: Schedule
    plan: KernelPlan
    epilogue: Optional[Epilogue]
    out_dtype: Optional[object]
    interpret: bool
    _fn: object = dataclasses.field(repr=False, default=None)

    def __post_init__(self):
        if self._fn is None:
            self._fn = jax.jit(self._build())

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(self.spec.operands)

    def _build(self):
        plan, spec = self.plan, self.spec
        names = self.names
        epilogue = self.epilogue
        vec_names = epilogue.vector_names if epilogue else ()
        # low-precision specs carry their accumulator: int8 products sum
        # exactly in an int32 VMEM scratch; fp8 accumulates in f32
        quant = getattr(spec.root(), "quant", None)
        acc_dtype = (
            jnp.int32
            if quant is not None and quant.accum == "int32"
            else jnp.float32
        )
        grid = plan.grid_shape or (1,)
        last = spec.output[-1]
        last_dim = plan.axes[last].grid_dim
        block_last = plan.axes[last].block

        in_specs = [
            pl.BlockSpec(plan.operand_block(n), _index_map(plan, spec.operands[n]))
            for n in names
        ]

        def vec_imap(*pids):
            return (0, pids[last_dim] if last_dim is not None else 0)

        in_specs += [
            pl.BlockSpec((1, block_last), vec_imap) for _ in vec_names
        ]
        out_spec = pl.BlockSpec(plan.out_block(), _index_map(plan, spec.output))
        kernel = _make_kernel(plan, names, epilogue, acc_dtype)

        def fn(*arrays):
            ops = arrays[: len(names)]
            vecs = arrays[len(names) :]
            if self.out_dtype is not None:
                out_dtype = self.out_dtype
            elif quant is not None:
                # int8×int8→int32 (fp8→f32): the accumulator IS the
                # result, unless a dequant epilogue already rescaled it
                # back to real values
                out_dtype = (
                    jnp.float32
                    if epilogue is not None and epilogue.dequant
                    else acc_dtype
                )
            else:
                out_dtype = ops[0].dtype
            rows = tuple(v.reshape(1, -1) for v in vecs)
            return pl.pallas_call(
                kernel,
                grid=grid,
                in_specs=in_specs,
                out_specs=out_spec,
                out_shape=jax.ShapeDtypeStruct(plan.out_shape(), out_dtype),
                scratch_shapes=[pltpu.VMEM(plan.out_block(), acc_dtype)],
                compiler_params=COMPILER_PARAMS_CLS(
                    dimension_semantics=("parallel",) * len(grid),
                ),
                interpret=self.interpret,
            )(*ops, *rows)

        return fn

    def __call__(self, *arrays, **vectors):
        names = self.names
        if len(arrays) != len(names):
            raise TypeError(
                f"{self.spec.name} takes {len(names)} operands "
                f"{names}, got {len(arrays)}"
            )
        for name, arr in zip(names, arrays):
            want = tuple(
                self.plan.axes[i].local_extent
                for i in self.spec.operands[name]
            )
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"operand {name}: expected local shape {want}, "
                    f"got {tuple(arr.shape)}"
                )
        vec_names = self.epilogue.vector_names if self.epilogue else ()
        missing = set(vec_names) - set(vectors)
        if missing:
            raise TypeError(f"epilogue vectors missing: {sorted(missing)}")
        vecs = tuple(vectors[v] for v in vec_names)
        return self._fn(*arrays, *vecs)


def compile_kernel(
    spec: ContractionSpec,
    schedule: Schedule,
    *,
    epilogue: Optional[Epilogue] = None,
    out_dtype=None,
    interpret: bool = False,
    mesh=None,
    collective: str = "psum",
):
    """Compile any ContractionSpec + Schedule into a runnable kernel.

    ``spec`` may be the root spec or the schedule's own (subdivided) spec;
    they must share a root.  Returns a ``CompiledKernel`` (local shapes),
    or — when ``mesh`` is given and the schedule has mesh tiers — the
    shard_map-wrapped ``MeshBoundKernel`` over global arrays.
    ``collective`` picks the finishing-reduction strategy for mesh-sharded
    reduce indices (``"psum"`` | ``"ring"``, see ``codegen.collectives``).
    """
    if spec.root() is not schedule.spec.root() and (
        spec.root().operands != schedule.spec.root().operands
        or spec.root().extents != schedule.spec.root().extents
    ):
        raise ValueError("spec and schedule disagree on the root contraction")
    if getattr(spec.root(), "fused_kind", ""):
        from .fused_gen import compile_fused

        return compile_fused(
            spec, schedule,
            epilogue=epilogue, out_dtype=out_dtype, interpret=interpret,
            mesh=mesh, collective=collective,
        )
    from ..obs import span

    with span("codegen.compile", spec=spec.root().name,
              sharded=mesh is not None):
        plan = build_plan(schedule)
        kernel = CompiledKernel(
            spec=plan.spec,
            schedule=schedule,
            plan=plan,
            epilogue=epilogue,
            out_dtype=out_dtype,
            interpret=interpret,
        )
        if mesh is not None:
            from .mesh_gen import bind_mesh

            return bind_mesh(kernel, mesh, collective=collective)
        return kernel


_KERNEL_MEMO: Dict[tuple, CompiledKernel] = {}


def cached_compile(
    spec: ContractionSpec,
    schedule: Schedule,
    *,
    epilogue: Optional[Epilogue] = None,
    out_dtype=None,
    interpret: bool = False,
    mesh=None,
    collective: str = "psum",
):
    """compile_kernel memoized on (spec, schedule, epilogue, dtype, interpret,
    mesh identity, collective).

    Hot-path entry for ``ops``/``launch``: repeated calls with the same
    contraction reuse one jitted kernel instead of re-tracing.  Mesh-bound
    kernels key on the mesh's axes and device ids, so two distinct meshes
    of the same shape get distinct shard_map closures.
    """
    import json

    from .cache import schedule_to_dict, spec_signature

    mesh_key = None
    if mesh is not None:
        mesh_key = (
            tuple(mesh.axis_names),
            tuple(int(s) for s in mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat),
        )
    key = (
        json.dumps(spec_signature(spec), sort_keys=True),
        json.dumps(schedule_to_dict(schedule), sort_keys=True),
        epilogue,
        str(out_dtype) if out_dtype is not None else None,
        interpret,
        mesh_key,
        collective if mesh is not None else None,
    )
    from ..obs import counter

    kern = _KERNEL_MEMO.get(key)
    counter(f"codegen.memo.{'miss' if kern is None else 'hit'}").inc()
    if kern is None:
        kern = compile_kernel(
            spec,
            schedule,
            epilogue=epilogue,
            out_dtype=out_dtype,
            interpret=interpret,
            mesh=mesh,
            collective=collective,
        )
        _KERNEL_MEMO[key] = kern
    return kern
