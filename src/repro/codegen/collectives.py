"""Collective lowerings for mesh-tier schedules (promoted from launch.overlap).

A schedule that shards a *reduce* index over a mesh axis leaves every
device with a partial local output; ``bind_mesh`` finishes it with one of
two strategies, chosen per plan by the search (``search.space.COLLECTIVES``
— the finishing collective is part of the variant, cost-ranked like any
other rewrite choice):

  * ``"psum"`` — plain ``lax.psum``: one blocking all-reduce after the
    kernel; simplest, fully exposed on the interconnect.
  * ``"ring"`` — ``ring_psum``: an explicit ppermute ring (reduce-scatter
    then all-gather).  On TPU each hop's ICI transfer can overlap the
    neighbouring chunk's compute (Wang et al.-style), which is why the
    cost model (``roofline.analysis.sharded_reduce_seconds``) credits the
    reduce-scatter phase against compute; on CPU the two strategies are
    differentially tested equal.

``ring_gather_matmul`` / ``naive_gather_matmul`` — the ppermute-pipelined
TP gather-matmul pair — also live here now; ``launch.overlap`` re-exports
them for its existing callers.  This is the distribution-level analogue of
the paper's pipelined subdivision: the reduction over shards is an ``rnz``
whose blocks arrive one ``flip`` (ring rotation) at a time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

#: strategies ``bind_mesh(collective=...)`` accepts
STRATEGIES = ("psum", "ring")


def _axis_size(axis_name: str) -> int:
    """lax.axis_size where available; psum(1) constant-folds on 0.4.37."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def _ring_perm(p: int):
    return [(i, (i + 1) % p) for i in range(p)]


def ring_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce of ``x`` over ``axis_name`` as an explicit ppermute ring.

    Equivalent to ``lax.psum(x, axis_name)``: a ring reduce-scatter
    (``p - 1`` hops, each accumulating one payload chunk) followed by a
    ring all-gather (``p`` hops).  The payload is flattened and split into
    ``p`` chunks; a payload that does not divide evenly is zero-padded so
    the last chunk is a remainder shard (exercised by the differential
    tests alongside the even fast path).  ``p == 1`` is the cut path: no
    ring to run, the partial *is* the sum.
    """
    p = _axis_size(axis_name)
    if p == 1:
        return x  # cut path: a single shard needs no collective
    idx = lax.axis_index(axis_name)
    flat = x.reshape(-1)
    n = flat.shape[0]
    chunk = -(-n // p)  # ceil division; pad covers the remainder shard
    pad = chunk * p - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(p, chunk)
    perm = _ring_perm(p)

    # reduce-scatter: after p-1 hops device d holds the FULL sum of chunk
    # (d + 1) % p.  Each hop sends the running partial to the neighbour,
    # which folds in its own local copy of that chunk.
    def rs_step(carry, s):
        recv = lax.ppermute(carry, axis_name, perm=perm)
        j = (idx - s - 1) % p
        own = lax.dynamic_index_in_dim(chunks, j, axis=0, keepdims=False)
        return recv + own, None

    init = lax.dynamic_index_in_dim(chunks, idx % p, axis=0, keepdims=False)
    full_chunk, _ = lax.scan(rs_step, init, jnp.arange(p - 1))

    # all-gather: rotate the completed chunks around the ring, recording
    # (owner, value) pairs, then scatter them back into payload order —
    # the same idiom as ring_gather_matmul below.
    def ag_step(carry, _):
        val, j = carry
        nxt = lax.ppermute(val, axis_name, perm=perm)
        return (nxt, (j - 1) % p), (j, val)

    (_, _), (js, vals) = lax.scan(
        ag_step, (full_chunk, (idx + 1) % p), None, length=p
    )
    order = jnp.argsort(js)
    summed = jnp.take(vals, order, axis=0).reshape(p * chunk)[:n]
    return summed.reshape(x.shape)


def all_reduce(x: jax.Array, axis_names, collective: str = "psum") -> jax.Array:
    """Finish a sharded reduction over ``axis_names`` with ``collective``."""
    if collective not in STRATEGIES:
        raise ValueError(
            f"unknown collective {collective!r}; choose from {STRATEGIES}"
        )
    if not axis_names:
        return x
    if collective == "ring":
        for ax in axis_names:
            x = ring_psum(x, ax)
        return x
    return lax.psum(x, tuple(axis_names))


def ring_gather_matmul(x_shard: jax.Array, w: jax.Array, axis_name: str):
    """Inside shard_map: x_shard (m_loc, k), w (k, n) -> y rows for ALL
    shards, (P * m_loc, n), equal to all_gather(x) @ w.

    The explicit ring exposes the overlap to the scheduler; the naive form
    must finish the all-gather before the first flop.
    """
    p = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)

    def step(carry, _):
        x_cur, src = carry
        y_part = jnp.dot(x_cur, w, preferred_element_type=jnp.float32)
        x_nxt = lax.ppermute(x_cur, axis_name, perm=_ring_perm(p))
        src_nxt = (src - 1) % p
        return (x_nxt, src_nxt), (src, y_part)

    (_, _), (srcs, parts) = lax.scan(step, (x_shard, idx), None, length=p)
    # parts[i] are the rows originating from shard srcs[i]; scatter to order
    order = jnp.argsort(srcs)
    parts = jnp.take(parts, order, axis=0)  # (P, m_loc, n)
    m_loc, n = x_shard.shape[0], w.shape[1]
    return parts.reshape(p * m_loc, n).astype(x_shard.dtype)


def naive_gather_matmul(x_shard: jax.Array, w: jax.Array, axis_name: str):
    """Reference: blocking all-gather then one big dot."""
    x_full = lax.all_gather(x_shard, axis_name, axis=0, tiled=True)
    return jnp.dot(
        x_full, w, preferred_element_type=jnp.float32
    ).astype(x_shard.dtype)
