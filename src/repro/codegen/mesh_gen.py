"""Mesh tiers -> shard_map: the cluster/device half of the hierarchy.

A ``mesh:*`` level shards its root index over the named mesh axis:

  * map (output) indices  -> the operand and output axes are partitioned
    with a ``PartitionSpec`` entry naming the mesh axis;
  * reduce indices        -> operands are partitioned, each shard computes
    a partial contraction, and a ``lax.psum`` over the axis completes the
    reduction (the generated analogue of the reduce-scatter the launch
    layer does for gradients).

``bind_mesh`` wraps a ``CompiledKernel`` (which always works on local,
per-shard shapes) into a callable over global arrays.
"""

from __future__ import annotations

from typing import Tuple

from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .plan import KernelPlan


def _axis_entry(plan: KernelPlan, index: str):
    axes = plan.axes[index].mesh_axes
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def operand_partition_spec(plan: KernelPlan, name: str) -> P:
    return P(*(_axis_entry(plan, i) for i in plan.spec.operands[name]))


def output_partition_spec(plan: KernelPlan) -> P:
    return P(*(_axis_entry(plan, i) for i in plan.spec.output))


def reduce_mesh_axes(plan: KernelPlan) -> Tuple[str, ...]:
    """Mesh axes carrying a reduce index (need a psum to finish)."""
    out = []
    for r in plan.spec.reduce_indices:
        out.extend(plan.axes[r].mesh_axes)
    return tuple(out)


def bind_mesh(kernel, mesh):
    """Wrap a CompiledKernel into a shard_map over ``mesh``.

    Returns ``call(*operands, **epilogue_vectors)`` on GLOBAL arrays.
    Epilogue vectors are sharded like the last output axis.

    Ordering with sharded reductions: the epilogue must see the FULL sum,
    not per-shard partials — act(psum(partial) + bias), never
    psum(act(partial + bias)).  When a reduce index is mesh-sharded the
    in-kernel epilogue is disabled and re-applied here after the psum.
    """
    import dataclasses

    import jax.numpy as jnp

    plan = kernel.plan
    names = kernel.names
    epilogue = kernel.epilogue
    vec_names = epilogue.vector_names if epilogue else ()
    in_specs = tuple(operand_partition_spec(plan, n) for n in names)
    vec_spec = P(_axis_entry(plan, plan.spec.output[-1]))
    psum_axes = reduce_mesh_axes(plan)
    out_spec = output_partition_spec(plan)

    defer_epilogue = bool(psum_axes) and epilogue is not None and (
        not epilogue.is_identity
    )
    inner = kernel
    if defer_epilogue:
        inner = dataclasses.replace(
            kernel, epilogue=None, out_dtype=jnp.float32, _fn=None
        )
    out_rank = len(plan.spec.output)

    def local_fn(*args):
        ops = args[: len(names)]
        vecs = args[len(names) :]
        out = inner._fn(*ops) if defer_epilogue else inner._fn(*args)
        if psum_axes:
            out = lax.psum(out, psum_axes)
        if defer_epilogue:
            vectors = {
                nm: v.astype(jnp.float32).reshape(
                    (1,) * (out_rank - 1) + (-1,)
                )
                for nm, v in zip(vec_names, vecs)
            }
            out_dtype = kernel.out_dtype or ops[0].dtype
            out = epilogue.apply(out, vectors).astype(out_dtype)
        return out

    wrapped = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=in_specs + (vec_spec,) * len(vec_names),
        out_specs=out_spec,
        check_rep=False,
    )

    def call(*arrays, **vectors):
        missing = set(vec_names) - set(vectors)
        if missing:
            raise TypeError(f"epilogue vectors missing: {sorted(missing)}")
        return wrapped(*arrays, *(vectors[v] for v in vec_names))

    return call
