"""Mesh tiers -> shard_map: the cluster/device half of the hierarchy.

A ``mesh:*`` level shards its root index over the named mesh axis:

  * map (output) indices  -> the operand and output axes are partitioned
    with a ``PartitionSpec`` entry naming the mesh axis;
  * reduce indices        -> operands are partitioned, each shard computes
    a partial contraction, and a collective over the axis completes the
    reduction.  The lowering of that collective is a per-plan **strategy**
    (``collective=``): plain ``lax.psum``, or the ring-overlap form
    (``collectives.ring_psum``, promoted from ``launch.overlap``) whose
    ppermute hops can hide behind compute on TPU.  The search treats the
    strategy as part of the variant (``search.space.COLLECTIVES``).

``bind_mesh`` wraps a ``CompiledKernel`` (which always works on local,
per-shard shapes) into a ``MeshBoundKernel`` over global arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .collectives import STRATEGIES, all_reduce
from .plan import KernelPlan


def _axis_entry(plan: KernelPlan, index: str):
    axes = plan.axes[index].mesh_axes
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def operand_partition_spec(plan: KernelPlan, name: str) -> P:
    return P(*(_axis_entry(plan, i) for i in plan.spec.operands[name]))


def output_partition_spec(plan: KernelPlan) -> P:
    return P(*(_axis_entry(plan, i) for i in plan.spec.output))


def reduce_mesh_axes(plan: KernelPlan) -> Tuple[str, ...]:
    """Mesh axes carrying a reduce index (need a psum to finish)."""
    out = []
    for r in plan.spec.reduce_indices:
        out.extend(plan.axes[r].mesh_axes)
    return tuple(out)


@dataclasses.dataclass
class MeshBoundKernel:
    """A generated kernel shard_mapped over a device mesh.

    Call with GLOBAL arrays (operands in spec order, epilogue vectors by
    keyword); carries the inner ``CompiledKernel`` so callers that
    introspect ``.schedule``/``.plan`` (tests, ``ops._tuned_kernel``) see
    the same surface as the single-device object.
    """

    kernel: object            # the local-shape CompiledKernel
    mesh: object
    collective: str
    _call: object = dataclasses.field(repr=False, default=None)

    @property
    def spec(self):
        return self.kernel.spec

    @property
    def schedule(self):
        return self.kernel.schedule

    @property
    def plan(self) -> KernelPlan:
        return self.kernel.plan

    def __call__(self, *arrays, **vectors):
        return self._call(*arrays, **vectors)


def bind_mesh(kernel, mesh, collective: str = "psum") -> MeshBoundKernel:
    """Wrap a CompiledKernel into a shard_map over ``mesh``.

    Returns a ``MeshBoundKernel`` called on GLOBAL arrays.  Epilogue
    vectors are sharded like the last output axis.  ``collective`` picks
    the finishing-reduction lowering for mesh-sharded reduce indices
    (``"psum"`` or ``"ring"``, see ``collectives``).

    Ordering with sharded reductions: the epilogue must see the FULL sum,
    not per-shard partials — act(psum(partial) + bias), never
    psum(act(partial + bias)).  When a reduce index is mesh-sharded the
    in-kernel epilogue is disabled and re-applied here after the psum.
    """
    import jax.numpy as jnp

    if collective not in STRATEGIES:
        raise ValueError(
            f"unknown collective {collective!r}; choose from {STRATEGIES}"
        )
    plan = kernel.plan
    names = kernel.names
    epilogue = kernel.epilogue
    vec_names = epilogue.vector_names if epilogue else ()
    in_specs = tuple(operand_partition_spec(plan, n) for n in names)
    vec_spec = P(_axis_entry(plan, plan.spec.output[-1]))
    psum_axes = reduce_mesh_axes(plan)
    out_spec = output_partition_spec(plan)

    defer_epilogue = bool(psum_axes) and epilogue is not None and (
        not epilogue.is_identity
    )
    inner = kernel
    if defer_epilogue:
        inner = dataclasses.replace(
            kernel, epilogue=None, out_dtype=jnp.float32, _fn=None
        )
    out_rank = len(plan.spec.output)

    def local_fn(*args):
        ops = args[: len(names)]
        vecs = args[len(names) :]
        out = inner._fn(*ops) if defer_epilogue else inner._fn(*args)
        if psum_axes:
            out = all_reduce(out, psum_axes, collective)
        if defer_epilogue:
            vectors = {
                nm: v.astype(jnp.float32).reshape(
                    (1,) * (out_rank - 1) + (-1,)
                )
                for nm, v in zip(vec_names, vecs)
            }
            out_dtype = kernel.out_dtype or ops[0].dtype
            out = epilogue.apply(out, vectors).astype(out_dtype)
        return out

    wrapped = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=in_specs + (vec_spec,) * len(vec_names),
        out_specs=out_spec,
        check_rep=False,
    )

    def call(*arrays, **vectors):
        missing = set(vec_names) - set(vectors)
        if missing:
            raise TypeError(f"epilogue vectors missing: {sorted(missing)}")
        return wrapped(*arrays, *(vectors[v] for v in vec_names))

    return MeshBoundKernel(
        kernel=kernel, mesh=mesh, collective=collective, _call=call
    )
