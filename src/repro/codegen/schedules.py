"""Generic schedule construction for arbitrary contractions.

``core.schedule.matmul_schedule`` hand-builds the canonical matmul nest;
``default_schedule`` does the same for ANY ``ContractionSpec``:

  * a map index with a block b < extent  -> subdiv into (grid, mxu) leaves
  * a map index left unblocked           -> whole axis in the block (mxu),
    or, for batch-like dims (``block=1``), one grid step per element
  * a reduce index with a block b        -> subdiv into (seq, mxu) leaves
  * a reduce index left unblocked        -> contracted in one dot (mxu)

``sharded_schedule`` adds outer ``mesh:*`` tiers on top.  Level order is
mesh (pod/data/model) -> grid -> seq -> mxu, which is what
``Schedule.validate`` demands and what ``codegen.plan`` consumes.

The three scenario builders at the bottom are the workloads the repo could
not express before this subsystem existed: batched matmul, the A@B@C
chain, and the transposed-operand GEMM.

``default_schedule`` is the *un-searched* baseline: ``repro.search``
explores loop orders and per-tier blockings around it
(``search.space.candidate_schedule`` generalizes this builder to
arbitrary loop orders) and only keeps a variant if it measures faster —
``ops.dense`` asks the search's plan DB before falling back here.

Fused families reinterpret one tier rather than add new ones.  For
``AttentionSpec`` the ``seq`` tier over the KV axis ``t`` is the
**online-softmax** reduction (``codegen.fused_gen``): each ``t``-block
step computes a score tile, folds it into running row-max ``m`` and
row-sum ``l`` VMEM scratch, and *rescales* the f32 accumulator by
``exp(m_old - m_new)`` before adding the new ``P·V`` contribution — the
flash-attention recurrence, so blocking ``t`` changes arithmetic order
but never semantics.  That is why ``t`` is a legal chunk axis while the
head dims ``d``/``e`` are ``whole_indices`` (a blocked softmax over a
*partial* feature axis has no such rescaling identity, so the search
space pins them to full extent; same for grouped's ``g``/``k``).  A map
index left unblocked lowers exactly as in the plain path, so searched
attention schedules differ only in grid order and ``s``/``t`` blockings.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

from ..core.enumerate import (
    ContractionSpec,
    batched_matmul_spec,
    chain_matmul_spec,
    transposed_matmul_spec,
)
from ..core.schedule import MESH_TIERS, Level, Schedule


def default_schedule(
    spec: ContractionSpec,
    blocks: Optional[Dict[str, int]] = None,
) -> Schedule:
    """Build a Schedule for the ROOT ``spec`` from per-index block sizes.

    ``blocks[i]`` is the per-grid-step (map) or per-seq-step (reduce) tile
    of root index ``i``; omitted indices keep their whole extent in-block.
    For mesh tiers use ``sharded_schedule``.
    """
    spec = spec.root()
    blocks = dict(blocks or {})
    unknown = set(blocks) - set(spec.indices)
    if unknown:
        raise ValueError(f"blocks name unknown indices {sorted(unknown)}")
    s = spec
    grid_levels, seq_levels, mxu_levels = [], [], []
    for index in spec.indices:
        extent = spec.extents[index]
        b = blocks.get(index, extent)
        if not 1 <= b <= extent or extent % b:
            raise ValueError(
                f"block {b} does not divide extent {extent} of {index}"
            )
        is_map = index in spec.output
        if b == extent:
            mxu_levels.append(Level(index, "mxu", extent))
            continue
        s = s.subdivide(index, b)
        outer = Level(index + "o", "grid" if is_map else "seq", extent // b)
        (grid_levels if is_map else seq_levels).append(outer)
        mxu_levels.append(Level(index + "i", "mxu", b))
    levels = tuple(grid_levels + seq_levels + mxu_levels)
    return Schedule(s, levels).validate()


def sharded_schedule(
    spec: ContractionSpec,
    blocks: Optional[Dict[str, int]] = None,
    mesh_shards: Optional[Dict[str, Tuple[str, int]]] = None,
) -> Schedule:
    """default_schedule plus outer mesh tiers.

    ``mesh_shards[i] = (axis, n)`` shards root index ``i`` over mesh axis
    ``axis`` (pod/data/model) in ``n`` pieces before the grid/seq/mxu
    blocking applies; ``blocks[i]`` then tiles the per-shard remainder.
    """
    spec = spec.root()
    mesh_shards = dict(mesh_shards or {})
    blocks = dict(blocks or {})
    s = spec
    mesh_levels = []
    renamed: Dict[str, str] = {}
    for index, (axis, n) in mesh_shards.items():
        tier = f"mesh:{axis}"
        if tier not in MESH_TIERS:
            raise ValueError(f"unknown mesh axis {axis!r} (want pod/data/model)")
        extent = spec.extents[index]
        if n <= 0 or extent % n:
            raise ValueError(f"{n} shards do not divide extent {extent} of {index}")
        if n == 1:
            continue
        s = s.subdivide(index, extent // n)
        mesh_levels.append(Level(index + "o", tier, n))
        renamed[index] = index + "i"
    inner_blocks = {renamed.get(i, i): b for i, b in blocks.items()}
    grid_levels, seq_levels, mxu_levels = [], [], []
    root_out = spec.output
    mesh_names = {l.index for l in mesh_levels}
    for index in s.indices:
        if index in mesh_names:
            continue
        extent = s.extents[index]
        base = index[:-1] if index in renamed.values() else index
        is_map = base in root_out
        b = inner_blocks.get(index, extent)
        if not 1 <= b <= extent or extent % b:
            raise ValueError(
                f"block {b} does not divide local extent {extent} of {index}"
            )
        if b == extent:
            mxu_levels.append(Level(index, "mxu", extent))
            continue
        s = s.subdivide(index, b)
        outer = Level(index + "o", "grid" if is_map else "seq", extent // b)
        (grid_levels if is_map else seq_levels).append(outer)
        mxu_levels.append(Level(index + "i", "mxu", b))
    rank = {t: i for i, t in enumerate(MESH_TIERS)}
    mesh_levels.sort(key=lambda l: rank[l.tier])
    levels = tuple(mesh_levels + grid_levels + seq_levels + mxu_levels)
    return Schedule(s, levels).validate()


# -- the three new scenarios --------------------------------------------------


def batched_matmul_schedule(
    b: int, m: int, k: int, n: int,
    *, block_m: int, block_n: int, block_k: int,
) -> Schedule:
    """out[b,i,k] = sum_j A[b,i,j] B[b,j,k]; batch dim = one grid step each."""
    spec = batched_matmul_spec(b, m, k, n)
    return default_schedule(
        spec,
        blocks={"b": 1, "i": block_m, "k": block_n, "j": block_k},
    )


def chain_matmul_schedule(
    m: int, k1: int, k2: int, n: int,
    *, block_m: int, block_n: int, block_k1: int, block_k2: int,
) -> Schedule:
    """out[i,l] = sum_{j,k} A[i,j] B[j,k] C[k,l] — both reductions seq-tiled."""
    spec = chain_matmul_spec(m, k1, k2, n)
    return default_schedule(
        spec,
        blocks={"i": block_m, "l": block_n, "j": block_k1, "k": block_k2},
    )


def transposed_matmul_schedule(
    m: int, k: int, n: int,
    *, block_m: int, block_n: int, block_k: int,
) -> Schedule:
    """out[i,k] = sum_j A[j,i] B[j,k] (A stored transposed)."""
    spec = transposed_matmul_spec(m, k, n)
    return default_schedule(
        spec, blocks={"i": block_m, "k": block_n, "j": block_k}
    )
