"""Fused-family Pallas builders: flash attention + grouped (MoE) matmul.

``pallas_gen`` lowers any plain product-reduce contraction; the two fused
spec families (``core.enumerate.AttentionSpec`` / ``GroupedSpec``) carry
semantics the generic fold cannot express, so ``compile_kernel`` routes
them here.  Both consume the same ``KernelPlan`` a Schedule produces —
the searched block sizes drive the fused grids:

attention (out = softmax_t(Q·Kᵀ/√d + mask) · V)
    grid = (H/bh, S/bs, T/bt) with the KV axis LAST and ``arbitrary``
    (sequential) semantics: running max / sum / f32 accumulator live in
    VMEM scratch across the T steps (the online-softmax rescale), init
    under ``pl.when(t == 0)`` and the final ``acc / l`` store under
    ``pl.when(t == nt - 1)``.  ``bt`` is the schedule's seq-tier chunk of
    ``t``; bh/bs are the grid blocks of h/s; d and e stay whole
    (``AttentionSpec.whole_indices``).  Causal / kv-length masking uses
    2-D ``broadcasted_iota`` offset by the program ids.  Masked scores
    are set to ``MASK_VALUE`` (not -inf: exp of a -inf difference is NaN)
    and masked probabilities re-zeroed so a fully-masked *block* cannot
    pollute the running sum.

grouped matmul (out[n,:] = x[n,:] @ w[group(n)])
    row mode (fwd / dX): grid = (OC/bn, G) with the group axis last and
    ``arbitrary`` semantics — the (N, bn) output block stays resident in
    a f32 VMEM accumulator while every group adds its row stripe.  Group
    offsets are STATIC (``group_sizes`` lives on the spec), dispatched as
    a ``pl.when(g == const)`` chain; each group walks its rows in
    ``bm``-sized tiles (the schedule's block of ``n``) with the start
    clamped to stay in bounds and a row-mask write so ragged tails and
    size-1/empty groups come out exactly.
    dW mode (output carries ``g``): one (K, bn) tile per (group, column
    block), rows outside the group zeroed before the xᵀ·g dot — blocks
    are disjoint per group so no accumulator is needed.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.enumerate import ContractionSpec
from ..core.schedule import Schedule
from ..kernels._compat import CompilerParams as COMPILER_PARAMS_CLS
from .plan import KernelPlan, build_plan

#: large-but-finite score for masked positions — exp(MASK - m) underflows
#: to 0 while exp(-inf - (-inf)) would be NaN (boom guide §3)
MASK_VALUE = -0.7 * float(np.finfo(np.float32).max)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _attention_fn(
    plan: KernelPlan,
    causal: bool,
    with_lengths: bool,
    out_dtype,
    interpret: bool,
):
    spec = plan.spec
    H, S, T = (spec.extents[i] for i in ("h", "s", "t"))
    D, E = spec.extents["d"], spec.extents["e"]
    bh, bs = plan.axes["h"].block, plan.axes["s"].block
    bt = plan.axes["t"].chunk
    nh, ns, nt = H // bh, S // bs, T // bt
    scale = float(D) ** -0.5

    def kernel(*refs):
        if with_lengths:
            q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref = refs
        else:
            q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
        j = pl.program_id(1)
        kp = pl.program_id(2)

        @pl.when(kp == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        ) * scale
        valid = None
        if causal or with_lengths:
            col = lax.broadcasted_iota(jnp.int32, (bh, bs, bt), 2) + kp * bt
        if causal:
            row = lax.broadcasted_iota(jnp.int32, (bh, bs, bt), 1) + j * bs
            valid = col <= row
        if with_lengths:
            lm = col < len_ref[...][:, :, None]
            valid = lm if valid is None else (valid & lm)
        if valid is not None:
            s = jnp.where(valid, s, MASK_VALUE)
        m_prev = m_ref[...]
        m_next = jnp.maximum(m_prev, jnp.max(s, axis=2))
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next[:, :, None])
        if valid is not None:
            p = jnp.where(valid, p, 0.0)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=2)
        v = v_ref[...].astype(jnp.float32)
        pv = lax.dot_general(
            p, v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha[:, :, None] + pv
        m_ref[...] = m_next

        @pl.when(kp == nt - 1)
        def _done():
            l = l_ref[...]
            l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0, not NaN
            o_ref[...] = (acc_ref[...] / l[:, :, None]).astype(o_ref.dtype)

    in_specs = [
        pl.BlockSpec((bh, bs, D), lambda i, j, kp: (i, j, 0)),
        pl.BlockSpec((bh, bt, D), lambda i, j, kp: (i, kp, 0)),
        pl.BlockSpec((bh, bt, E), lambda i, j, kp: (i, kp, 0)),
    ]
    if with_lengths:
        in_specs.append(pl.BlockSpec((bh, 1), lambda i, j, kp: (i, 0)))

    def fn(*arrays):
        dt = out_dtype or arrays[0].dtype
        return pl.pallas_call(
            kernel,
            grid=(nh, ns, nt),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bh, bs, E), lambda i, j, kp: (i, j, 0)),
            out_shape=jax.ShapeDtypeStruct((H, S, E), dt),
            scratch_shapes=[
                pltpu.VMEM((bh, bs), jnp.float32),
                pltpu.VMEM((bh, bs), jnp.float32),
                pltpu.VMEM((bh, bs, E), jnp.float32),
            ],
            compiler_params=COMPILER_PARAMS_CLS(
                dimension_semantics=("parallel", "parallel", "arbitrary"),
            ),
            interpret=interpret,
        )(*arrays)

    return jax.jit(fn)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------


def _group_offsets(group_sizes: Tuple[int, ...]):
    offs, o = [], 0
    for s in group_sizes:
        offs.append(o)
        o += s
    return offs


def _grouped_row_fn(
    plan: KernelPlan,
    group_sizes: Tuple[int, ...],
    out_dtype,
    interpret: bool,
):
    """fwd (out[n,f] = x@w[g]) and dX (out[n,k] = g@w[g] over f) lowering.

    Introspects the spec so both orientations share one builder: the
    first operand is (n, c); the 3-D operand is (g, ·, ·) with the shared
    axis ``c`` in either trailing slot.
    """
    spec = plan.spec
    xname, wname = tuple(spec.operands)
    n_ax, c_ax = spec.operands[xname]
    w_axes = spec.operands[wname]
    g_ax = w_axes[0]
    oc_ax = spec.output[1]
    N, C, OC = spec.extents[n_ax], spec.extents[c_ax], spec.extents[oc_ax]
    G = len(group_sizes)
    wc = w_axes.index(c_ax) - 1  # contract dim of the squeezed (2-D) w tile
    bm = plan.axes[n_ax].block
    bn = plan.axes[oc_ax].block
    nj = OC // bn
    offsets = _group_offsets(group_sizes)

    w_block = tuple(
        1 if a == g_ax else (C if a == c_ax else bn) for a in w_axes
    )

    def w_imap(j, g):
        return tuple(
            g if a == g_ax else (0 if a == c_ax else j) for a in w_axes
        )

    def kernel(x_ref, w_ref, o_ref, acc_ref):
        g = pl.program_id(1)

        @pl.when(g == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        wm = w_ref[...][0]  # (C, bn) or (bn, C)
        for gg in range(G):
            s_g = group_sizes[gg]
            if s_g == 0:
                continue
            o = offsets[gg]
            ntile = -(-s_g // bm)

            @pl.when(g == gg)
            def _acc(o=o, s_g=s_g, ntile=ntile):
                for i in range(ntile):
                    r0 = min(o + i * bm, N - bm)
                    rows = x_ref[r0 : r0 + bm, :].astype(jnp.float32)
                    part = lax.dot_general(
                        rows, wm, (((1,), (wc,)), ((), ())),
                        preferred_element_type=jnp.float32,
                    )
                    rid = r0 + lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
                    ok = (rid >= o + i * bm) & (rid < o + s_g)
                    cur = acc_ref[r0 : r0 + bm, :]
                    acc_ref[r0 : r0 + bm, :] = jnp.where(ok, cur + part, cur)

        @pl.when(g == G - 1)
        def _done():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    def fn(x, w):
        dt = out_dtype or x.dtype
        return pl.pallas_call(
            kernel,
            grid=(nj, G),
            in_specs=[
                pl.BlockSpec((N, C), lambda j, g: (0, 0)),
                pl.BlockSpec(w_block, w_imap),
            ],
            out_specs=pl.BlockSpec((N, bn), lambda j, g: (0, j)),
            out_shape=jax.ShapeDtypeStruct((N, OC), dt),
            scratch_shapes=[pltpu.VMEM((N, bn), jnp.float32)],
            compiler_params=COMPILER_PARAMS_CLS(
                dimension_semantics=("parallel", "arbitrary"),
            ),
            interpret=interpret,
        )(x, w)

    return jax.jit(fn)


def _grouped_dw_fn(
    plan: KernelPlan,
    group_sizes: Tuple[int, ...],
    out_dtype,
    interpret: bool,
):
    """dW mode: out[g,k,f] = sum_{n in group g} x[n,k] * dout[n,f]."""
    spec = plan.spec
    g_ax, o1, o2 = spec.output
    names = tuple(spec.operands)
    lhs = next(nm for nm in names if o1 in spec.operands[nm])  # (n, o1)
    rhs = next(nm for nm in names if o2 in spec.operands[nm])  # (n, o2)
    n_ax = spec.operands[lhs][0]
    N, K1, K2 = spec.extents[n_ax], spec.extents[o1], spec.extents[o2]
    G = len(group_sizes)
    bn = plan.axes[o2].block
    nj = K2 // bn
    offsets = _group_offsets(group_sizes)
    order = (0, 1) if names[0] == lhs else (1, 0)

    def kernel(*refs):
        l_ref, r_ref = refs[order[0]], refs[order[1]]
        o_ref = refs[2]
        g = pl.program_id(0)
        for gg in range(G):

            @pl.when(g == gg)
            def _emit(o=offsets[gg], s_g=group_sizes[gg]):
                lv = l_ref[...].astype(jnp.float32)
                rid = lax.broadcasted_iota(jnp.int32, (N, 1), 0)
                ok = (rid >= o) & (rid < o + s_g)
                lv = jnp.where(ok, lv, 0.0)  # empty group -> exact zeros
                rv = r_ref[...].astype(jnp.float32)
                res = lax.dot_general(
                    lv, rv, (((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )
                o_ref[...] = res[None].astype(o_ref.dtype)

    lhs_spec = pl.BlockSpec((N, K1), lambda g, j: (0, 0))
    rhs_spec = pl.BlockSpec((N, bn), lambda g, j: (0, j))
    in_specs = (
        [lhs_spec, rhs_spec] if names[0] == lhs else [rhs_spec, lhs_spec]
    )

    def fn(a, b):
        dt = out_dtype or a.dtype
        return pl.pallas_call(
            kernel,
            grid=(G, nj),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, K1, bn), lambda g, j: (g, 0, j)),
            out_shape=jax.ShapeDtypeStruct((G, K1, K2), dt),
            compiler_params=COMPILER_PARAMS_CLS(
                dimension_semantics=("parallel", "parallel"),
            ),
            interpret=interpret,
        )(a, b)

    return jax.jit(fn)


# ---------------------------------------------------------------------------
# wrapper + entry point
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FusedKernel:
    """Generated fused kernel bound to one (spec, schedule) pair.

    Call with the operand arrays in ``spec.operands`` order; attention
    additionally accepts ``kv_lengths=`` (int32 per folded head, the
    PR 7 plumbing) which routes through a lazily-built second variant.
    """

    spec: ContractionSpec
    schedule: Schedule
    plan: KernelPlan
    out_dtype: Optional[object]
    interpret: bool
    kind: str
    epilogue: Optional[object] = None  # parity with CompiledKernel
    _fn: object = dataclasses.field(repr=False, default=None)
    _fn_lengths: object = dataclasses.field(repr=False, default=None)

    def __post_init__(self):
        root = self.spec.root()
        if self._fn is None:
            if self.kind == "attention":
                self._fn = _attention_fn(
                    self.plan, bool(root.causal), False,
                    self.out_dtype, self.interpret,
                )
            elif "g" in self.spec.output:
                self._fn = _grouped_dw_fn(
                    self.plan, tuple(root.group_sizes),
                    self.out_dtype, self.interpret,
                )
            else:
                self._fn = _grouped_row_fn(
                    self.plan, tuple(root.group_sizes),
                    self.out_dtype, self.interpret,
                )

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(self.spec.operands)

    def __call__(self, *arrays, kv_lengths=None):
        names = self.names
        if len(arrays) != len(names):
            raise TypeError(
                f"{self.spec.name} takes {len(names)} operands "
                f"{names}, got {len(arrays)}"
            )
        for name, arr in zip(names, arrays):
            want = tuple(
                self.plan.axes[i].local_extent
                for i in self.spec.operands[name]
            )
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"operand {name}: expected local shape {want}, "
                    f"got {tuple(arr.shape)}"
                )
        if kv_lengths is None:
            return self._fn(*arrays)
        if self.kind != "attention":
            raise TypeError("kv_lengths only applies to attention kernels")
        lengths = jnp.asarray(kv_lengths, jnp.int32).reshape(-1, 1)
        H = self.spec.extents["h"]
        if lengths.shape[0] != H:
            raise ValueError(
                f"kv_lengths: expected {H} entries, got {lengths.shape[0]}"
            )
        if self._fn_lengths is None:
            self._fn_lengths = _attention_fn(
                self.plan, bool(self.spec.root().causal), True,
                self.out_dtype, self.interpret,
            )
        return self._fn_lengths(*arrays, lengths)


def compile_fused(
    spec: ContractionSpec,
    schedule: Schedule,
    *,
    epilogue=None,
    out_dtype=None,
    interpret: bool = False,
    mesh=None,
    collective: str = "psum",
) -> FusedKernel:
    """Lower a fused-family spec + Schedule; ``compile_kernel`` dispatches
    here whenever ``spec.root().fused_kind`` is set."""
    root = spec.root()
    kind = getattr(root, "fused_kind", "")
    if not kind:
        raise ValueError(f"{root.name} is not a fused spec")
    if epilogue is not None and not getattr(epilogue, "is_identity", False):
        raise NotImplementedError("fused kernels take no epilogue")
    if mesh is not None:
        raise NotImplementedError("fused families have no mesh tier yet")
    from ..obs import span

    with span("codegen.compile_fused", spec=root.name, kind=kind):
        plan = build_plan(schedule)
        return FusedKernel(
            spec=plan.spec,
            schedule=schedule,
            plan=plan,
            out_dtype=out_dtype,
            interpret=interpret,
            kind=kind,
        )
