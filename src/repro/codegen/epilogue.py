"""Epilogue hook: fused tail computation on the resident accumulator tile.

The paper's NN motivating example (eqs 3-5) is a dense layer whose
normalization + nonlinearity stages are low arithmetic density — fusing
them into the matmul epilogue saves the HBM round-trips of materializing
``y`` and ``z``.  The generator runs the epilogue on the float32 VMEM
accumulator right before the store, subsuming the hand-written
``kernels/fused_dense_act`` kernel:

    y = acc * scale + bias            (bias/scale broadcast over the last
    z = (y - mean) * rsqrt(var+eps)    output axis, each optional)
    r = act(z)

Vector operands (bias/mean/var/scale) ride along as extra kernel inputs
blocked on the last output axis, so they stream with the output tile.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

ACTIVATIONS = {
    "relu": lambda z: jnp.maximum(z, 0.0),
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "silu": jax.nn.silu,
    "id": lambda z: z,
}


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Which fused tail stages the generated kernel applies."""

    act: str = "id"
    bias: bool = False
    scale: bool = False
    norm: bool = False          # normalize with given (mean, var) stats
    eps: float = 1e-5
    #: dequantize first: cast the (possibly int32) accumulator to f32 and
    #: multiply by the ``qscale`` row (combined input scales, one per
    #: output column — broadcast a constant row for per-tensor scales)
    dequant: bool = False

    def __post_init__(self):
        if self.act not in ACTIVATIONS:
            raise ValueError(
                f"unknown activation {self.act!r}; have {sorted(ACTIVATIONS)}"
            )

    @property
    def vector_names(self) -> Tuple[str, ...]:
        """Extra kernel operands, in argument order."""
        names = []
        if self.dequant:
            names.append("qscale")
        if self.scale:
            names.append("scale")
        if self.bias:
            names.append("bias")
        if self.norm:
            names.extend(["mean", "var"])
        return tuple(names)

    @property
    def is_identity(self) -> bool:
        return not self.vector_names and self.act == "id"

    def apply(self, acc, vectors: Dict[str, jax.Array]):
        """Run the tail on the accumulator tile; vectors are f32 rows
        broadcastable against ``acc`` (the generator reshapes them)."""
        y = acc
        if self.dequant:
            # scales come first: everything downstream (bias/act/norm)
            # sees real-valued activations, same as the bf16/f32 path
            y = y.astype(jnp.float32) * vectors["qscale"]
        if self.scale:
            y = y * vectors["scale"]
        if self.bias:
            y = y + vectors["bias"]
        if self.norm:
            y = (y - vectors["mean"]) * jax.lax.rsqrt(
                vectors["var"] + self.eps
            )
        return ACTIVATIONS[self.act](y)
