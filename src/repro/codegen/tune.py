"""Schedule selection for generated kernels, with the persistent cache.

This generalizes ``core.autotune.choose_matmul_blocks`` from the one
hand-written matmul to any ContractionSpec: enumerate per-index block
candidates (pow2 divisors, MXU-flavoured), rank with the napkin HBM
traffic model under the VMEM budget, optionally measure the analytic
top-k in Pallas interpreter mode, and persist the winner keyed by
spec+shapes+dtype+hardware (``codegen.cache``).  A second process — or a
serving replica warming up — gets the tuned schedule back without paying
enumeration or measurement again.
"""

from __future__ import annotations

import itertools
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cost import TPU
from ..core.enumerate import ContractionSpec
from ..core.schedule import Schedule
from .cache import (
    AutotuneCache,
    cache_key,
    default_cache,
    schedule_from_dict,
    schedule_to_dict,
)
from .schedules import default_schedule

#: bumped when candidate generation or scoring changes
TUNER_VERSION = 2


def _pow2_divisors(extent: int, cap: int = 512, limit: int = 6) -> List[int]:
    """Pow2 divisors of extent up to cap, largest first, plus extent itself."""
    out = [extent]
    c = 1
    while c <= min(extent, cap):
        if extent % c == 0 and c != extent:
            out.append(c)
        c *= 2
    out.sort(reverse=True)
    return out[:limit]


def _score(
    spec: ContractionSpec,
    blocks: Dict[str, int],
    elem_bytes: int,
    hw: dict,
) -> Optional[float]:
    """HBM traffic (elements) of the generated kernel, or None if > VMEM.

    Each operand is re-fetched once per grid block of every *output* index
    it does not carry; reduce (seq) axes are VMEM-resident at full extent
    in generated kernels, so they count fully toward the budget.
    """
    extents = spec.extents
    n_blocks = {
        i: extents[i] // blocks[i] for i in spec.output
    }
    vmem = 0
    traffic = 0.0
    for name, axes in spec.operands.items():
        block_elems = 1
        for a in axes:
            block_elems *= blocks[a] if a in spec.output else extents[a]
        vmem += block_elems
        elems = math.prod(extents[a] for a in axes)
        trips = math.prod(
            n_blocks[i] for i in spec.output if i not in axes
        )
        traffic += elems * trips
    out_block = math.prod(blocks[i] for i in spec.output)
    vmem += 2 * out_block  # out tile + f32 accumulator
    traffic += math.prod(extents[i] for i in spec.output)
    if vmem * elem_bytes > hw["vmem_bytes"]:
        return None
    # MXU alignment nudges: innermost output axis wants multiples of lanes
    penalty = 1.0
    last = spec.output[-1]
    if blocks[last] % hw["mxu"][1] and blocks[last] != extents[last]:
        penalty *= 1.25
    if len(spec.output) >= 2:
        sub = spec.output[-2]
        if blocks[sub] % hw["sublane"] and blocks[sub] != extents[sub]:
            penalty *= 1.1
    return traffic * penalty


def _reduce_chunk(extent: int, cap: int = 512) -> int:
    """Seq-loop chunk for a reduce axis: largest pow2 divisor <= cap.

    Reduce blocks don't change HBM traffic in the generated kernel (the
    axis is VMEM-resident either way), so they are not enumerated — one
    heuristic chunk bounds the per-dot depth; extent itself (no seq
    level) when it is small or has no pow2 divisor under the cap.
    """
    if extent <= cap:
        return extent
    best = 0
    c = 1
    while c <= cap:
        if extent % c == 0:
            best = c
        c *= 2
    return best or extent


def candidate_blocks(
    spec: ContractionSpec, hw: dict = TPU, per_index: int = 6
) -> List[Dict[str, int]]:
    """Cross-product of pow2 MAP-index block candidates; batch-like dims
    pinned near 1, reduce indices fixed to their heuristic chunk."""
    choices: List[Tuple[str, List[int]]] = []
    whole = getattr(spec.root(), "whole_indices", ())
    for i in spec.indices:
        e = spec.extents[i]
        if i in whole:
            cands = [e]  # fused families keep these axes unblocked
        elif i not in spec.output:
            cands = [_reduce_chunk(e)]
        elif e <= hw["sublane"]:
            cands = [1, e] if e > 1 else [1]  # batch-like tiny dims
        else:
            cands = _pow2_divisors(e, limit=per_index)
        choices.append((i, cands))
    out = []
    for combo in itertools.product(*(c for _, c in choices)):
        out.append({i: b for (i, _), b in zip(choices, combo)})
    return out


def tune_schedule(
    spec: ContractionSpec,
    *,
    dtype=np.float32,
    hw: dict = TPU,
    cache: Optional[AutotuneCache] = None,
    measure_with: Optional[Dict[str, np.ndarray]] = None,
    keep: int = 3,
    use_default_cache: bool = True,
) -> Schedule:
    """Pick (and persist) a Schedule for ``spec``.

    Cache hit -> deserialize, no enumeration, no measurement.  Miss ->
    analytic search; if ``measure_with`` provides operand arrays the
    analytic top-``keep`` are timed through the interpreter-mode generated
    kernel before the winner is stored.
    """
    from ..obs import span

    spec = spec.root()
    if cache is None and use_default_cache:
        cache = default_cache()
    elem = np.dtype(dtype).itemsize
    key = cache_key(
        spec,
        dtype=np.dtype(dtype),
        extra={
            "tuner": TUNER_VERSION,
            "keep": keep,
            # custom cost-model dicts must not hit the default's entries
            "hw": sorted(
                (k, v) for k, v in hw.items()
                if isinstance(v, (int, float))
            ),
            # an analytic-only winner must not satisfy a measured request
            "measured": measure_with is not None,
        },
    )
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            return schedule_from_dict(hit["schedule"], spec)

    with span("codegen.tune", spec=spec.name,
              measured=measure_with is not None):
        scored = []
        for blocks in candidate_blocks(spec, hw):
            s = _score(spec, blocks, elem, hw)
            if s is not None:
                steps = sum(  # tie-break: fewer seq steps win
                    spec.extents[i] // blocks[i]
                    for i in spec.indices
                    if i not in spec.output
                )
                scored.append((s, steps, tuple(sorted(blocks.items()))))
        if not scored:  # nothing fits VMEM: fall back to smallest blocks
            blocks = {
                i: (1 if i in spec.output else spec.extents[i])
                for i in spec.indices
            }
            scored = [(math.inf, 0, tuple(sorted(blocks.items())))]
        scored.sort()
        top = [dict(b) for _, _, b in scored[:keep]]

        best = top[0]
        if measure_with is not None and len(top) > 1:
            from .pallas_gen import compile_kernel

            timings = []
            for blocks in top:
                sched = default_schedule(spec, blocks)
                kern = compile_kernel(spec, sched, interpret=True)
                args = tuple(measure_with[n] for n in spec.operands)
                t0 = time.perf_counter()
                np.asarray(kern(*args))
                timings.append((time.perf_counter() - t0, blocks))
            timings.sort(key=lambda t: t[0])
            best = timings[0][1]

    schedule = default_schedule(spec, best)
    if cache is not None:
        cache.put(
            key,
            {
                "schedule": schedule_to_dict(schedule),
                "blocks": {k: int(v) for k, v in best.items()},
                "measured": measure_with is not None,
            },
        )
    return schedule
