"""Disk-backed autotune cache: pay tuning cost once per fleet, not per run.

The ROADMAP "serve heavy traffic" requirement implies tuning cannot happen
per-process: a serving replica must pick up the fleet's tuned schedules at
startup.  This cache is a JSON file (human-inspectable, mergeable) mapping

    key = sha256(spec signature, shapes, dtype, hardware, tuner version)

to a serialized winner — either a full ``Schedule`` (split chain + tier
levels, see ``schedule_to_dict``) or an arbitrary small JSON value such as
``choose_matmul_blocks`` output or measured variant rankings.

Concurrency: reads are lazy; writes are atomic (tmp file + ``os.replace``)
and hold an exclusive inter-process file lock (``<path>.lock``, flock)
around the read-merge-write, so concurrent writers — e.g. two sweep
processes persisting fwd+bwd plans for the same shape — never corrupt the
file *and* never lose each other's entries.  The lock is POSIX-only
(flock); where ``fcntl`` is unavailable writes stay atomic and
thread-safe but a concurrent *process* can still drop another's entry.
A corrupt/alien file degrades to an empty cache rather than an error.

Location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``.

Observability: lookups feed ``repro.obs`` counters (``autotune.hit`` /
``autotune.miss`` for the default cache, ``plandb.*`` for the plan DB —
see ``metrics_prefix``) in addition to the in-process ``hits``/``misses``
attributes, so a fleet dashboard or ``serve --metrics-out`` dump shows
cache effectiveness without poking cache objects.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import os
import tempfile
import threading
from typing import Any, Dict, Optional

try:
    import fcntl
except ImportError:  # non-posix: fall back to thread-lock-only writes
    fcntl = None  # type: ignore[assignment]

from ..core.enumerate import ContractionSpec
from ..core.schedule import Level, Schedule

#: bump when the serialized schedule format or tuner logic changes
CACHE_VERSION = 1


def spec_signature(spec: ContractionSpec) -> Dict[str, Any]:
    """Stable JSON identity of a ROOT contraction (shapes included)."""
    root = spec.root()
    sig = {
        "name": root.name,
        "operands": {k: list(v) for k, v in root.operands.items()},
        "output": list(root.output),
        "extents": {k: int(v) for k, v in root.extents.items()},
        "reducer": root.reducer,
    }
    # fused families (attention/grouped_matmul) carry semantics the plain
    # fields cannot express (causal flag, ragged group sizes) — fold them
    # in ONLY when present so every existing key stays byte-identical
    kind = getattr(root, "fused_kind", None)
    if kind:
        sig["fused"] = {"kind": kind, **root.fused_meta()}
    # low-precision storage (core.enumerate.QuantMeta) changes the lowered
    # kernel (operand dtype, accumulator, dequant epilogue) — same
    # only-when-present rule keeps every existing key byte-identical
    q = getattr(root, "quant", None)
    if q is not None:
        sig["quant"] = {"dtype": q.dtype, "accum": q.accum, "scale": q.scale}
    return sig


def hardware_fingerprint() -> str:
    """backend + device kind; 'cpu/interpret' in the CPU container."""
    try:
        import jax

        dev = jax.devices()[0]
        return f"{jax.default_backend()}/{getattr(dev, 'device_kind', '?')}"
    except Exception:
        return "unknown"


def cache_key(
    spec: ContractionSpec,
    *,
    dtype: Any = None,
    hardware: Optional[str] = None,
    extra: Any = None,
) -> str:
    payload = {
        "v": CACHE_VERSION,
        "spec": spec_signature(spec),
        "dtype": str(dtype) if dtype is not None else None,
        "hw": hardware if hardware is not None else hardware_fingerprint(),
        "extra": extra,
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


@contextlib.contextmanager
def _file_lock(path: str):
    """Exclusive inter-process lock for read-merge-write on ``path``.

    Uses a sibling ``<path>.lock`` file so the lock survives the atomic
    ``os.replace`` of the data file itself (locking the data fd would be
    useless: replace swaps the inode out from under the lock).  The
    thread-level lock in ``AutotuneCache`` still guards in-process use;
    this one makes two *processes* — e.g. concurrent fwd+bwd plan sweeps —
    linearize their writes instead of losing them (tests/test_plandb_concurrency.py).
    """
    if fcntl is None:
        yield
        return
    with open(path + ".lock", "a") as lf:
        fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lf.fileno(), fcntl.LOCK_UN)


def schedule_to_dict(schedule: Schedule) -> Dict[str, Any]:
    return {
        "splits": [[i, int(b)] for i, b in schedule.spec.split_chain()],
        "levels": [
            [l.index, l.tier, int(l.extent)] for l in schedule.levels
        ],
    }


def schedule_from_dict(d: Dict[str, Any], root: ContractionSpec) -> Schedule:
    spec = root.root()
    for index, b in d["splits"]:
        spec = spec.subdivide(index, b)
    levels = tuple(Level(i, t, e) for i, t, e in d["levels"])
    return Schedule(spec, levels).validate()


class AutotuneCache:
    """get/put JSON values keyed by ``cache_key`` strings."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._data: Optional[Dict[str, Any]] = None
        # -- stats, for tests and ops dashboards ----------------------------
        # instance state, updated under self._lock: concurrent readers
        # previously raced the unsynchronized ``self.hits += 1`` (a
        # read-modify-write) and lost counts, so the attributes could
        # disagree with the obs counters
        self.hits: int = 0
        self.misses: int = 0

    #: when set ("autotune"/"plandb"), lookups also feed the repro.obs
    #: counters ``<prefix>.hit`` / ``<prefix>.miss`` — bare instances used
    #: as scratch storage in tests stay silent
    metrics_prefix: Optional[str] = None

    def _load(self) -> Dict[str, Any]:
        if self._data is None:
            try:
                with open(self.path) as f:
                    raw = json.load(f)
                self._data = raw if isinstance(raw, dict) else {}
            except (OSError, ValueError):
                self._data = {}
        return self._data

    def get(self, key: str) -> Optional[Any]:
        with self._lock:
            val = self._load().get(key)
            # accounting stays under the lock: the attribute bump and the
            # obs counter must move together or a concurrent reader can
            # observe them disagreeing (and lose attribute increments)
            if val is None:
                self.misses += 1
            else:
                self.hits += 1
            if self.metrics_prefix:
                from ..obs import counter

                counter(
                    f"{self.metrics_prefix}."
                    f"{'miss' if val is None else 'hit'}"
                ).inc()
        return val

    def contains(self, key: str) -> bool:
        """Presence probe that does NOT count as a hit or a miss — used by
        ``PlanDB`` to classify a miss as a version miss (an entry exists
        under an older PLAN_VERSION key)."""
        with self._lock:
            return key in self._load()

    def put(self, key: str, value: Any) -> None:
        with self._lock:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            # the flock spans reload -> merge -> replace, so a concurrent
            # process's put cannot interleave and drop this write
            with _file_lock(self.path):
                self._data = None  # merge with concurrent writers
                data = dict(self._load())
                data[key] = value
                self._data = data
                fd, tmp = tempfile.mkstemp(dir=d or ".", suffix=".tmp")
                try:
                    with os.fdopen(fd, "w") as f:
                        json.dump(data, f, indent=1, sort_keys=True)
                    os.replace(tmp, self.path)
                except BaseException:
                    try:
                        os.unlink(tmp)
                    except OSError:
                        pass
                    raise

    def clear(self) -> None:
        with self._lock:
            self._data = {}
            for p in (self.path, self.path + ".lock"):
                try:
                    os.unlink(p)
                except OSError:
                    pass


_default: Optional[AutotuneCache] = None


def default_cache() -> AutotuneCache:
    """Process-wide cache at $REPRO_AUTOTUNE_CACHE or ~/.cache/repro."""
    global _default
    path = os.environ.get("REPRO_AUTOTUNE_CACHE") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "autotune.json"
    )
    if _default is None or _default.path != path:
        _default = AutotuneCache(path)
        _default.metrics_prefix = "autotune"
    return _default
