"""Gradient compression for the cross-pod all-reduce, with error feedback.

At 2 pods x 256 chips, the in-pod gradient reduce-scatter rides 50 GB/s ICI
links while the pod-to-pod hop crosses DCI; compressing the cross-pod leg
8-bit cuts that term 4x (vs f32) at <1% relative error with error feedback.

``hierarchical_psum`` is the shard_map building block:
  1. reduce-scatter within the pod (full precision, ICI),
  2. int8 all-reduce across pods (error-feedback residual kept locally),
  3. all-gather within the pod.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .quant import BLOCK, Quantized, dequantize, quantize


def compress_decompress(
    g: jax.Array, residual: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """One error-feedback round: returns (decompressed, new_residual)."""
    corrected = g.astype(jnp.float32) + residual
    q = quantize(corrected)
    deq = dequantize(q).astype(jnp.float32)
    return deq.astype(g.dtype), corrected - deq


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce over ``axis_name`` (inside shard_map).

    Quantize locally, sum the int8 payloads in f32 (scales are averaged
    per-block), dequantize.  Exact for the scale-uniform case and within
    quantization error otherwise.
    """
    q = quantize(x)
    summed = jax.lax.psum(q.q.astype(jnp.float32) * q.scale, axis_name)
    n = 1
    for d in q.shape:
        n *= d
    return summed.reshape(-1)[:n].reshape(q.shape).astype(x.dtype)


def hierarchical_psum(
    x: jax.Array, *, pod_axis: str = "pod", inner_axis: str = "data",
    compress: bool = True,
) -> jax.Array:
    """reduce(in-pod) -> (compressed) reduce(cross-pod), inside shard_map."""
    x = jax.lax.psum(x, inner_axis)
    if compress:
        return compressed_psum(x, pod_axis)
    return jax.lax.psum(x, pod_axis)
