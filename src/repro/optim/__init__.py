from .adamw import AdamWConfig, AdamWState, global_norm, init, update, warmup_cosine  # noqa: F401
from .quant import Quantized, dequantize, quantize  # noqa: F401
from .compress import compress_decompress, compressed_psum, hierarchical_psum  # noqa: F401
