"""AdamW with optional block-quantized 8-bit moments and global-norm clip.

State is a pytree mirroring params; with ``moments_dtype='int8'`` each moment
leaf is a ``Quantized`` (4.25 bits-per-byte effective ~1.03 B/param each vs
4 B/param for f32 — the difference between kimi-k2 fitting 512 chips or not).
Moments are dequantized, updated, and requantized inside the jitted step;
XLA fuses the round-trip so no f32 copy of the full state ever lives in HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .quant import Quantized, dequantize, quantize


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"  # float32 | bfloat16 | int8


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def _encode(x, how: str):
    if how == "int8":
        return quantize(x)
    return x.astype(jnp.dtype(how))


def _decode(x):
    if isinstance(x, Quantized):
        return dequantize(x).astype(jnp.float32)
    return x.astype(jnp.float32)


def init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: _encode(jnp.zeros(p.shape, jnp.float32), cfg.moments_dtype),
        params,
    )
    zeros_v = jax.tree.map(
        lambda p: _encode(jnp.zeros(p.shape, jnp.float32), cfg.moments_dtype),
        params,
    )
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros_v)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree)
        )
    )


def update(
    grads,
    state: AdamWState,
    params,
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_f = _decode(m)
        v_f = _decode(v)
        m_new = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_new = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        upd = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        p_new = (
            p.astype(jnp.float32)
            - lr * (upd + cfg.weight_decay * p.astype(jnp.float32))
        ).astype(p.dtype)
        return p_new, _encode(m_new, cfg.moments_dtype), _encode(
            v_new, cfg.moments_dtype
        )

    # flatten to the params tree's leaf positions: a Quantized moment is one
    # leaf-position subtree there, so flatten_up_to keeps it intact.
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state.m)
    v_leaves = treedef.flatten_up_to(state.v)
    out = [leaf(*t) for t in zip(p_leaves, g_leaves, m_leaves, v_leaves)]
    new_params = treedef.unflatten([t[0] for t in out])
    new_m = treedef.unflatten([t[1] for t in out])
    new_v = treedef.unflatten([t[2] for t in out])
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_params, AdamWState(step, new_m, new_v), metrics


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def warmup_cosine(warmup: int, total: int, min_ratio: float = 0.1) -> Callable:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / max(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return warm * cos

    return fn
