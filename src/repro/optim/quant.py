"""Block-wise 8-bit quantization for optimizer moments and gradient
compression (8-bit-Adam-style dynamic quantization).

Tensors are flattened and quantized in blocks of ``BLOCK`` with a per-block
absmax scale.  Used for:
  * Adam m/v states (`optim.adamw` with ``moments_dtype='int8'``) — required
    to fit kimi-k2's ~1T parameters into 512 x 16 GB (see DESIGN.md),
  * cross-pod gradient all-reduce compression with error feedback
    (`optim.compress`).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Quantized:
    """int8 payload + per-block f32 scales + original shape/dtype."""

    q: jax.Array          # (nblocks, BLOCK) int8
    scale: jax.Array      # (nblocks, 1) f32
    shape: Tuple[int, ...]
    dtype: jnp.dtype

    def tree_flatten(self):
        return (self.q, self.scale), (self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        return cls(q, scale, aux[0], aux[1])


def quantize(x: jax.Array) -> Quantized:
    shape, dtype = x.shape, x.dtype
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return Quantized(q, scale, shape, dtype)


def dequantize(qv: Quantized) -> jax.Array:
    flat = (qv.q.astype(jnp.float32) * qv.scale).reshape(-1)
    n = 1
    for d in qv.shape:
        n *= d
    return flat[:n].reshape(qv.shape).astype(qv.dtype)


def quantization_bytes(qv: Quantized) -> int:
    return qv.q.size + qv.scale.size * 4
