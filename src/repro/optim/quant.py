"""Block-wise 8-bit quantization for optimizer moments and gradient
compression (8-bit-Adam-style dynamic quantization).

Tensors are flattened and quantized in blocks of ``BLOCK`` with a per-block
absmax scale.  Used for:
  * Adam m/v states (`optim.adamw` with ``moments_dtype='int8'``) — required
    to fit kimi-k2's ~1T parameters into 512 x 16 GB (see DESIGN.md),
  * cross-pod gradient all-reduce compression with error feedback
    (`optim.compress`).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Quantized:
    """int8 payload + per-block f32 scales + original shape/dtype."""

    q: jax.Array          # (nblocks, BLOCK) int8
    scale: jax.Array      # (nblocks, 1) f32
    shape: Tuple[int, ...]
    dtype: jnp.dtype

    def tree_flatten(self):
        return (self.q, self.scale), (self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        return cls(q, scale, aux[0], aux[1])


def quantize(x: jax.Array) -> Quantized:
    shape, dtype = x.shape, x.dtype
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return Quantized(q, scale, shape, dtype)


def dequantize(qv: Quantized) -> jax.Array:
    flat = (qv.q.astype(jnp.float32) * qv.scale).reshape(-1)
    n = 1
    for d in qv.shape:
        n *= d
    return flat[:n].reshape(qv.shape).astype(qv.dtype)


def quantization_bytes(qv: Quantized) -> int:
    return qv.q.size + qv.scale.size * 4


# ---------------------------------------------------------------------------
# GEMM-operand quantization (the searched int8/fp8 kernel tier)
#
# The block-wise machinery above serves optimizer state; the helpers below
# produce the *kernel-facing* layout: operands stored at int8/fp8 with a
# per-tensor scalar or per-output-channel scale row that the generated
# kernels' dequant epilogue applies after the accumulator
# (``codegen.Epilogue(dequant=True)``, qscale = sx * sw).
# ---------------------------------------------------------------------------

#: absmax maps to the largest exactly-representable magnitude per format
_QMAX = {"int8": 127.0, "fp8": 448.0, "float8_e4m3fn": 448.0}


def _storage_dtype(fmt: str):
    if fmt in ("fp8", "float8_e4m3fn"):
        dt = getattr(jnp, "float8_e4m3fn", None)
        if dt is None:
            raise NotImplementedError(
                "float8_e4m3fn is not available in this jax build"
            )
        return dt
    if fmt == "int8":
        return jnp.int8
    raise ValueError(f"unknown quant format {fmt!r}; have {sorted(_QMAX)}")


def _cast(x, fmt: str, scale):
    y = x.astype(jnp.float32) / scale
    if fmt == "int8":
        return jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return y.astype(_storage_dtype(fmt))


def quantize_tensor(x: jax.Array, fmt: str = "int8"):
    """(q, scale): whole-tensor absmax quantization; scale is a scalar.

    Empty tensors (any zero extent) quantize with scale 1.0 — there is
    nothing to round, but shape/dtype round-trip must still hold.
    """
    qmax = _QMAX[fmt]
    if x.size == 0:
        scale = jnp.asarray(1.0, jnp.float32)
        return _cast(x, fmt, scale), scale
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    return _cast(x, fmt, scale), scale.astype(jnp.float32)


def quantize_channels(w: jax.Array, fmt: str = "int8"):
    """(q, scales): per-output-channel quantization of a (..., F) weight.

    One scale per slice of the LAST axis — the output-column granularity
    the dequant epilogue broadcasts over the accumulator tile.
    """
    qmax = _QMAX[fmt]
    if any(d == 0 for d in w.shape[:-1]):
        # empty channel slices: nothing to scale, keep scale=1 per channel
        scale = jnp.ones((w.shape[-1],), jnp.float32)
        return _cast(w, fmt, scale), scale
    reduce_axes = tuple(range(w.ndim - 1))
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=reduce_axes)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    return _cast(w, fmt, scale), scale.astype(jnp.float32)


#: weight leaves smaller than this stay full-precision in quantize_tree —
#: biases/norm gains are tiny and precision-critical
MIN_QUANT_SIZE = 4096


def quantize_tree(params, fmt: str = "int8", min_size: int = MIN_QUANT_SIZE):
    """Weight-only quantization of a parameter pytree, once at load.

    Float arrays with >= 2 dims and >= ``min_size`` elements become
    ``Quantized`` leaves (block-wise int8 + scales — a registered pytree
    node, so the tree still flows through jit); everything else passes
    through.  Pair with ``dequantize_tree`` inside the jitted serving step:
    live weights stay 8-bit + scales in device memory and the f32 copies
    are jit temporaries (``launch/serve --quant int8``).
    """
    if fmt != "int8":
        raise NotImplementedError(
            f"weight-only serving quantization supports 'int8', got {fmt!r}"
        )

    def leaf(x):
        if (
            isinstance(x, (jax.Array,)) or hasattr(x, "shape")
        ) and getattr(x, "ndim", 0) >= 2 and jnp.issubdtype(
            jnp.asarray(x).dtype, jnp.floating
        ) and x.size >= min_size:
            return quantize(jnp.asarray(x))
        return x

    return jax.tree_util.tree_map(leaf, params)


def dequantize_tree(params):
    """Inverse of ``quantize_tree``: expand Quantized leaves, pass the rest."""
    return jax.tree_util.tree_map(
        lambda x: dequantize(x) if isinstance(x, Quantized) else x,
        params,
        is_leaf=lambda x: isinstance(x, Quantized),
    )


def tree_quant_bytes(params) -> int:
    """Bytes of the quantized leaves (payload + scales) — the memory the
    weight-only tier actually holds live."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, Quantized)
    ):
        if isinstance(leaf, Quantized):
            total += quantization_bytes(leaf)
    return total
