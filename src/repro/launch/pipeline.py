"""GPipe-style pipeline parallelism over a mesh axis (the multi-pod mesh's
``pod`` axis, switchable from hierarchical DP per DESIGN.md §5).

``pipeline_apply`` runs a stage function over P = |axis| stages and M
microbatches inside shard_map: each of the M + P - 1 ticks every stage
applies its layer block to the activation it holds, then the ring
``ppermute`` shifts activations downstream — the classic bubble schedule
(bubble fraction (P-1)/(M+P-1)).  Stage s's parameters are the s-th slice of
the stacked parameter tree (sharded over the pipe axis, so each device
stores only its stage).

The schedule is the paper's subdiv/flip vocabulary one more time: the layer
stack is ``subdiv``-ed into P stages bound to a mesh axis, and the exchange
that makes it work is a rotation (ppermute) instead of a transposition.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax


def pipeline_apply(
    stage_fn: Callable,   # (stage_params, x) -> y   (same shape)
    stage_params,         # pytree, leaves lead with the LOCAL stage dim (=1)
    microbatches: jax.Array,  # (M, mb, ...) — replicated across the axis
    axis_name: str,
):
    """Run inside shard_map.  Returns (M, mb, ...) outputs (on every member,
    via a final psum-style broadcast)."""
    from .mesh import axis_size

    p = axis_size(axis_name)
    stage = lax.axis_index(axis_name)
    m = microbatches.shape[0]
    ticks = m + p - 1

    params_local = jax.tree.map(lambda w: w[0], stage_params)
    state = jnp.zeros_like(microbatches[0])
    outbuf = jnp.zeros_like(microbatches)

    def tick(t, carry):
        state, outbuf = carry
        # stage 0 ingests microbatch t (while available)
        mb_idx = jnp.clip(t, 0, m - 1)
        fresh = lax.dynamic_index_in_dim(
            microbatches, mb_idx, 0, keepdims=False
        )
        x = lax.select(
            jnp.logical_and(stage == 0, t < m),
            fresh.astype(state.dtype), state,
        )
        y = stage_fn(params_local, x)
        # last stage emits microbatch t - (p - 1)
        out_idx = jnp.clip(t - (p - 1), 0, m - 1)
        emit = jnp.logical_and(stage == p - 1, t >= p - 1)
        outbuf = lax.cond(
            emit,
            lambda ob: lax.dynamic_update_index_in_dim(
                ob, y.astype(ob.dtype), out_idx, 0
            ),
            lambda ob: ob,
            outbuf,
        )
        # shift downstream (ring; stage 0 receives garbage it overwrites)
        state = lax.ppermute(
            y, axis_name, perm=[(i, (i + 1) % p) for i in range(p)]
        )
        return state, outbuf

    _, outbuf = lax.fori_loop(0, ticks, tick, (state, outbuf))
    # broadcast the last stage's buffer to every member so out_specs can be
    # replicated: everyone else holds zeros
    outbuf = lax.psum(
        jnp.where(stage == p - 1, 1.0, 0.0).astype(outbuf.dtype) * outbuf,
        axis_name,
    )
    return outbuf


def bubble_fraction(p: int, m: int) -> float:
    return (p - 1) / (m + p - 1)
