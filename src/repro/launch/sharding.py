"""Logical-axis -> mesh-axis sharding rules (the distributed subdiv level).

A parameter annotated ``('embed', 'mlp')`` becomes, on the production mesh,
``PartitionSpec('data', 'model')`` — i.e. FSDP over the data axis and tensor
parallelism over the model axis.  In the paper's vocabulary this is exactly
``subdiv`` applied at the outermost hierarchy level, with the mesh axis bound
to the new outer dimension (DESIGN.md §2).

Rules are *preference lists*; an axis is taken only if it divides the dim
(e.g. whisper's vocab 51865 is not divisible by 16 -> the unembed stays
replicated; mamba2's in_proj fused dim 3352 likewise).  The chosen spec is
therefore always valid on the target mesh — no silent GSPMD fallbacks.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

#: logical axis -> ordered mesh-axis preferences (the default "tp" profile)
PARAM_RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    "vocab": (("model",),),
    "embed": (("data",),),          # FSDP
    "heads": (("model",),),         # TP over (flattened) attention heads
    "kv": (("model",),),
    "mlp": (("model",),),           # TP over FFN hidden
    "experts": (("model",), ("data",)),  # EP; kimi's 384 also splits on data
    "layers": (),                   # scan axis: never sharded
    "batch": (("pod", "data"), ("data",)),
    "seq": (("model",),),           # SP for sequence-sharded activations
    "seq_kv": (("model",), ("data",)),  # KV-cache sequence dim (long context)
}

#: "dp" profile — no tensor parallelism: the model axis joins data
#: parallelism and weights are FSDP-sharded over both axes.  This is the
#: distribution-level analogue of the paper's flip exchange: instead of
#: subdividing the feature dims across chips (TP), subdivide the batch.
#: Wins for small-d_model archs where per-layer TP all-reduces dwarf compute.
DP_RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    "vocab": (("model",),),
    "embed": (("data",),),
    "heads": (),
    "kv": (),
    "mlp": (),
    "experts": (("model",), ("data",)),  # EP stays: MoE without EP can't fit
    "layers": (),
    "batch": (("pod", "data", "model"), ("data", "model"), ("data",)),
    "seq": (("model",),),
    "seq_kv": (("model",), ("data",)),
}

#: "zero1" profile — params live TP-sharded only (no per-layer FSDP
#: all-gather of the stacked weights inside the scan); the memory cost is
#: paid back by 8-bit optimizer moments whose flat blocks shard over the
#: whole mesh (steps.opt_shardings).  The §Perf lever for the
#: gather-inside-scan pathology visible in the baseline HLO.
ZERO1_RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = dict(
    PARAM_RULES, embed=(), vocab=(("model",), ("data",)),
)

PROFILES = {"tp": PARAM_RULES, "dp": DP_RULES, "zero1": ZERO1_RULES}


def active_rules() -> Dict[str, Tuple[Tuple[str, ...], ...]]:
    """Rules for the profile in $REPRO_SHARDING (default 'tp').

    The env knob exists so the dry-run / §Perf harness can A/B sharding
    variants without touching code (EXPERIMENTS.md §Perf).
    """
    import os

    return PROFILES[os.environ.get("REPRO_SHARDING", "tp")]


def _mesh_size(mesh, axes: Tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def spec_for(
    mesh,
    logical: Optional[Tuple[Optional[str], ...]],
    dims: Tuple[int, ...],
    rules: Optional[Dict] = None,
) -> P:
    """PartitionSpec for one array given its logical axes and shape."""
    if rules is None:
        rules = active_rules()
    if logical is None:
        return P()
    assert len(logical) == len(dims), (logical, dims)
    used: set = set()
    parts: list = [None] * len(dims)

    def try_assign(i, name, dim):
        for pref in rules.get(name, ()) if name else ():
            axes = tuple(a for a in pref if a in mesh.axis_names)
            if not axes or any(a in used for a in axes):
                continue
            if dim % _mesh_size(mesh, axes) == 0:
                parts[i] = axes if len(axes) > 1 else axes[0]
                used.update(axes)
                return

    # §Perf knob (EXPERIMENTS.md): FSDP-sharding the unembed's contraction
    # dim makes GSPMD shard the contraction itself, materializing a
    # replicated-token f32 logits partial plus a giant all-reduce (found by
    # HLO inspection of the baseline).  The fix keeps the unembed sharded
    # over vocab only.
    import os

    if (
        os.environ.get("REPRO_UNEMBED_FIX") == "1"
        and "vocab" in logical
        and "embed" in logical
    ):
        logical = tuple(
            None if name == "embed" else name for name in logical
        )

    # two passes: structural dims (heads/kv/experts/...) get first pick of
    # the mesh axes; sequence dims only take what is left (they are the
    # fallback for long-context cells, not the default)
    fallback = {"seq", "seq_kv"}
    for i, (name, dim) in enumerate(zip(logical, dims)):
        if name not in fallback:
            try_assign(i, name, dim)
    for i, (name, dim) in enumerate(zip(logical, dims)):
        if name in fallback:
            try_assign(i, name, dim)
    # trailing Nones are implicit
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(mesh, shapes_tree, axes_tree, rules: Optional[Dict] = None):
    """NamedSharding tree for a ShapeDtypeStruct tree + logical-axes tree."""
    if rules is None:
        rules = active_rules()

    def one(shape_leaf, ax):
        return NamedSharding(
            mesh, spec_for(mesh, ax, tuple(shape_leaf.shape), rules)
        )

    # axes_tree leaves are tuples (or None); walk the shapes tree structure
    flat_shapes, treedef = jax.tree.flatten(shapes_tree)
    flat_axes = treedef.flatten_up_to(axes_tree)
    return treedef.unflatten(
        [one(s, a) for s, a in zip(flat_shapes, flat_axes)]
    )


def quantized_sharding(mesh, q_shapes):
    """Sharding for a Quantized optimizer moment: shard the flat block axis
    over every mesh axis that divides it (this is what lets kimi-k2's 8-bit
    Adam states spread across all 512 chips)."""
    nblocks = q_shapes.q.shape[0]
    axes = [a for a in ("data", "model") if a in mesh.axis_names]
    good = tuple(
        a for a in axes if nblocks % _mesh_size(mesh, tuple(axes)) == 0
    )
    spec = P(tuple(axes)) if good == tuple(axes) and axes else P()
    return dict(
        q=NamedSharding(mesh, spec),
        scale=NamedSharding(mesh, spec),
    )


def batch_spec_for(mesh, shape: Tuple[int, ...], seq_axis: Optional[int] = None) -> P:
    """Inputs: shard dim0 (batch) per the active profile's batch rule;
    fall back to sequence sharding (long_500k's batch=1)."""
    rules = active_rules()
    for pref in rules["batch"]:
        axes = tuple(a for a in pref if a in mesh.axis_names)
        if not axes:
            continue
        if shape[0] % _mesh_size(mesh, axes) == 0:
            return P(axes if len(axes) > 1 else axes[0])
    if seq_axis is not None and len(shape) > seq_axis:
        if shape[seq_axis] % mesh.shape.get("model", 1) == 0:
            parts: list = [None] * (seq_axis + 1)
            parts[seq_axis] = "model"
            return P(*parts)
    return P()
