"""Serving CLI: continuous-batching paged-KV engine, fixed-slot baseline.

``--engine continuous`` (default) drives the ``launch.serving`` tier: a
block-table paged KV cache, FCFS continuous batching (requests join the
decode batch the step after prefill, free their pages the step they
finish, preempt-newest recompute when the pool runs dry) and separate
phase-tagged prefill/decode plan ladders.  ``--engine fixed`` keeps this
module's original :class:`BatchServer` — requests packed into a fixed
decode batch that rounds every group up to its longest member — as the
differential and throughput baseline.  Both engines emit one token per
step per live request until max_new or ``--eos-id``, and under greedy
decoding produce identical per-request outputs.  On the production mesh
the cache shardings come from launch.steps.serve_bundle.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --requests 8 --prompt-len 32 --max-new 16 --engine continuous

Observability (``repro.obs``): prefill and every decode step run inside
trace spans, each finished request records into the
``serve.request_latency_s`` histogram (p50/p99 in the metrics dump), and
``serve.tokens``/``serve.tok_per_s`` plus the plan-DB/autotune hit
counters quantify how much of the traffic ran searched kernels.
``--metrics-out FILE`` / ``--trace-out FILE`` write the registry snapshot
and the Chrome trace after the run; ``scripts/obs_report.py`` renders
both.  Log lines go through ``obs.log`` (``REPRO_LOG=quiet|info|debug``).
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..configs import get_config
from ..models.api import get_api
from ..obs import log


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (prompt_len,)
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchServer:
    """Fixed-slot batch server (the slot count is the serving batch size)."""

    def __init__(self, cfg, *, batch_size: int, max_len: int,
                 extra_batch=None, warm_gemms=(), search_gemms=(),
                 search_grads: bool = True, capture: bool = False,
                 mesh_shape=None, quant: Optional[str] = None):
        self.cfg = cfg
        self.api = get_api(cfg)
        self.batch_size = batch_size
        self.max_len = max_len
        self.extra_batch = extra_batch or {}
        # --quant int8: weight-only serving quantization.  Params are
        # quantized ONCE at load (optim.quant.quantize_tree — Quantized is
        # a registered pytree node, so the 8-bit tree flows through jit)
        # and expanded INSIDE the jitted step closures: live weights stay
        # int8 + scales in device memory, the f32 copies are jit
        # temporaries.
        self.quant = quant
        # --mesh AxB: sweeps below additionally persist mesh-qualified
        # sharded ladders, and — when this replica can host the mesh —
        # the serving steps trace under it so ops._tuned_kernel dispatches
        # through the sharded generated kernels (codegen.bind_mesh).
        self.mesh = None
        self.mesh_shape = None
        if mesh_shape:
            from ..search import parse_mesh_shape
            from .mesh import make_debug_mesh

            self.mesh_shape = parse_mesh_shape(mesh_shape)
            import math as _math

            from ..search.space import mesh_axis_names

            if _math.prod(self.mesh_shape) <= jax.device_count():
                self.mesh = make_debug_mesh(
                    self.mesh_shape, mesh_axis_names(len(self.mesh_shape))
                )
            else:
                log.info("serve", f"--mesh {mesh_shape}: only "
                         f"{jax.device_count()} device(s) visible — sweeping "
                         f"mesh plans for the fleet, serving single-device")
        # Whole-model capture: harvest the prefill + decode GEMM sets
        # (abstract trace — no allocation), sweep every harvested spec
        # into the ranked plan DB (fwd, plus derived bwd specs unless
        # --no-search-grads so a co-located training fleet benefits from
        # the same warmup), and route serving steps through
        # capture.optimize so the eligible sites dispatch.
        self.capture = capture
        if capture:
            from .. import capture as _capture
            from ..search import default_plan_db

            # One abstract trace per serving entry point covers the
            # report, the sweepable spec set AND the summary.
            # interpret=True classifies eligibility as if kernels can run
            # (what a TPU replica dispatches); the measurement below still
            # uses the interpreter only where there is no TPU.
            points = {}
            for kind in ("prefill", "decode"):
                _, rep = _capture.model_capture(
                    cfg, batch=batch_size, seq=max_len, kind=kind,
                    interpret=True,
                )
                log.info("serve", rep.summary())
                for spec, dt in rep.unique_specs():
                    points.setdefault(
                        _capture.spec_key(spec, dt),
                        (f"{kind}:{spec.name}", spec, dt),
                    )
            db = default_plan_db()
            n = _capture.sweep_captured(
                list(points.values()), with_grads=search_grads, plan_db=db,
                interpret=jax.default_backend() != "tpu",
                mesh_shape=self.mesh_shape,
                quant=self.quant,
            )
            log.info("serve", f"capture swept {n} plan point(s) "
                     f"({len(points)} unique GEMM spec(s)) -> {db.path}")
        # Serving replicas reuse the fleet's tuned kernel schedules: warm
        # the persistent codegen cache before the first request arrives.
        if warm_gemms:
            from ..codegen import default_cache
            from ..ops import warm_dense_cache

            cache = default_cache()
            n = warm_dense_cache(warm_gemms)
            log.info("serve", f"warmed {n} GEMM schedule(s) "
                     f"(cache {cache.path}: {cache.hits} hit, "
                     f"{cache.misses} miss)")
        # The stronger warmup: run the full cost-guided search (enumerate
        # -> prune -> measure) and persist the ranked plans; ops.dense
        # prefers these over the analytic tuner from then on.  Hits the
        # plan DB on repeat shapes, so restarts pay nothing.
        if search_gemms:
            from ..search import default_plan_db, search_gemm_plans

            db = default_plan_db()
            # bfloat16 to match warm_dense_cache: the plan key must equal
            # the one ops.dense derives from the serving activations.
            # On a TPU replica measure the real kernels; the interpreter
            # only stands in for the clock where there is no TPU.
            # search_grads: the plan DB is fleet-shared, so the same
            # warmup also sweeps each GEMM's derived backward specs
            # (repro.grad) and training replicas pick up searched
            # cotangent kernels; --no-search-grads skips the 2 extra
            # sweeps per shape on inference-only replicas.
            n = search_gemm_plans(
                search_gemms,
                dtype=jnp.bfloat16,
                interpret=jax.default_backend() != "tpu",
                plan_db=db,
                with_grads=search_grads,
                mesh_shape=self.mesh_shape,
            )
            what = "fwd + derived bwd" if search_grads else "fwd only"
            at = (f" + mesh={'x'.join(map(str, self.mesh_shape))}"
                  if self.mesh_shape else "")
            log.info("serve", f"searched {n} GEMM plan(s) "
                     f"({what}{at}) -> {db.path}")
        # pre-register the cache-effectiveness counters so a metrics dump
        # always carries plan-DB/autotune hit counts, zero included (a
        # replica whose traffic never consulted the DB should say 0, not
        # omit the row)
        for name in ("plandb.hit", "plandb.miss", "autotune.hit",
                     "autotune.miss"):
            obs.counter(name).inc(0)
        self.params, _ = self.api.init(cfg, jax.random.key(0))
        if self.quant:
            from ..optim.quant import (dequantize_tree, quantize_tree,
                                       tree_quant_bytes)

            self.params = quantize_tree(self.params, fmt=self.quant)
            qb = tree_quant_bytes(self.params)
            obs.gauge("serve.quant_bytes").set(qb)
            log.info("serve", f"weight-only {self.quant}: "
                     f"{qb / 2**20:.2f} MiB held as quantized leaves")
            _deq = dequantize_tree
        else:
            _deq = lambda p: p  # noqa: E731
        decode_fn = lambda p, c, t: self.api.decode_step(  # noqa: E731
            _deq(p), self.cfg, c, t
        )
        prefill_fn = lambda p, b: self.api.prefill(  # noqa: E731
            _deq(p), self.cfg, b, self.max_len
        )
        if self.capture:
            from .. import capture as _capture

            decode_fn = _capture.optimize(
                decode_fn, label=f"{cfg.arch_id}:decode", quant=self.quant
            )
            prefill_fn = _capture.optimize(
                prefill_fn, label=f"{cfg.arch_id}:prefill", quant=self.quant
            )
        self._decode = jax.jit(decode_fn)
        self._prefill_fn = prefill_fn

    def _mesh_ctx(self):
        """Trace/run context: the serving mesh when hosted, else a no-op.

        Entering the mesh at call time is what lets ``ops._tuned_kernel``
        (consulted while jit traces the step) see an active mesh and pick
        the mesh-qualified sharded plans this server swept.
        """
        import contextlib

        if self.mesh is None:
            return contextlib.nullcontext()
        from .mesh import set_mesh

        return set_mesh(self.mesh)

    def _prefill(self, tokens: np.ndarray, lengths=None):
        batch = {"tokens": jnp.asarray(tokens), **self.extra_batch}
        if lengths is not None:
            batch["lengths"] = jnp.asarray(lengths, jnp.int32)
        with self._mesh_ctx():
            return self._prefill_fn(self.params, batch)

    def _pack(self, requests: List[Request]):
        """Pack prompts into the slot matrix; returns (tokens, lengths).

        Attention families right-pad and carry per-row true lengths, so
        prefill masks the pads out and a short prompt decodes identically
        batched or solo.  SSM/hybrid recurrences fold every input token
        into their state — no attention mask can unpollute it — so those
        keep the legacy left-pad (lengths=None) and equal-length prompts.
        """
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.batch_size, plen), np.int32)
        if self.cfg.family in ("ssm", "hybrid"):
            for i, r in enumerate(requests):
                toks[i, plen - len(r.prompt):] = r.prompt
            return toks, None
        lengths = np.ones((self.batch_size,), np.int32)
        for i, r in enumerate(requests):
            toks[i, :len(r.prompt)] = r.prompt
            lengths[i] = len(r.prompt)
        return toks, lengths

    def run(self, requests: List[Request], greedy: bool = True,
            eos_id: Optional[int] = None):
        assert len(requests) <= self.batch_size
        latency = obs.histogram("serve.request_latency_s")
        t0 = time.time()

        def finish(r: Request):
            r.done = True
            # request latency = arrival (run entry) to last token — or to
            # prefill completion for max_new=0, which still counts as a
            # served request
            latency.observe(time.time() - t0)
            obs.counter("serve.requests").inc()

        def emit(next_host: np.ndarray):
            """Append one token per live request; finish on max_new/EOS."""
            for i, r in enumerate(requests):
                if r.done:
                    continue
                tok = int(next_host[i])
                r.out_tokens.append(tok)
                if (len(r.out_tokens) >= r.max_new
                        or (eos_id is not None and tok == eos_id)):
                    finish(r)

        toks, lengths = self._pack(requests)
        with obs.span("serve.prefill", batch=len(requests),
                      prompt_len=toks.shape[1]):
            logits, caches = self._prefill(toks, lengths)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        prefill_s = time.time() - t0

        # max_new=0 requests are complete the moment prefill returns:
        # nothing to emit, but the latency/served-request accounting must
        # still see them (they used to spin the full decode loop and never
        # record).
        for r in requests:
            if not r.done and r.max_new <= 0:
                finish(r)
        # Each request's first token comes from the *prefill* logits —
        # emit it before the decode clock starts so tok/s measures pure
        # decode throughput.
        if not all(r.done for r in requests):
            emit(np.asarray(next_tok))
        n_prefill_tokens = sum(len(r.out_tokens) for r in requests)

        t1 = time.time()
        steps = 0
        with obs.span("serve.decode", batch=len(requests)):
            # while-before-dispatch: when emit() finishes the last
            # request, the loop exits without a wasted trailing decode
            # dispatch
            while not all(r.done for r in requests):
                with obs.span("serve.decode.step", step=steps):
                    with self._mesh_ctx():
                        logits, caches = self._decode(
                            self.params, caches, next_tok[:, None]
                        )
                    next_tok = jnp.argmax(
                        logits[:, -1], axis=-1
                    ).astype(jnp.int32)
                steps += 1
                emit(np.asarray(next_tok))
        decode_s = time.time() - t1
        n_tokens = sum(len(r.out_tokens) for r in requests)
        n_decode_tokens = n_tokens - n_prefill_tokens
        tok_per_s = n_decode_tokens / max(decode_s, 1e-9)
        obs.counter("serve.tokens").inc(n_tokens)
        obs.gauge("serve.tok_per_s").set(tok_per_s)
        return dict(
            prefill_s=prefill_s,
            decode_s=decode_s,
            decode_steps=steps,
            tokens=n_tokens,
            decode_tokens=n_decode_tokens,
            tok_per_s=tok_per_s,
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument(
        "--engine", choices=("continuous", "fixed"), default="continuous",
        help="'continuous': slot-free continuous batching over the paged "
             "KV pool (launch.serving) — requests join decode the step "
             "after their prefill and free pages the step they finish.  "
             "'fixed': the legacy fixed-slot BatchServer, kept as the "
             "differential baseline.  Non-attention families (ssm/hybrid) "
             "always serve fixed",
    )
    ap.add_argument(
        "--lanes", type=int, default=4,
        help="decode batch width: concurrent requests per decode step "
             "(continuous) / slots per group (fixed)",
    )
    ap.add_argument(
        "--page-size", type=int, default=16,
        help="KV page size in tokens (continuous engine)",
    )
    ap.add_argument(
        "--pages", type=int, default=0,
        help="physical KV pages in the pool; 0 sizes it so every lane "
             "can reach max context without preemption",
    )
    ap.add_argument(
        "--eos-id", type=int, default=None,
        help="token id that finishes a request early (default: none — "
             "requests run to max_new)",
    )
    ap.add_argument(
        "--rate-hz", type=float, default=200.0,
        help="Poisson arrival rate of the synthetic trace; 0 = all "
             "requests arrive at t=0 (saturated queue)",
    )
    ap.add_argument("--seed", type=int, default=0,
                    help="trace seed (prompts, lengths, arrivals)")
    ap.add_argument(
        "--warm-gemms", default="",
        help="semicolon-separated M,K,N GEMM shapes to pre-tune "
             "through the codegen cache, e.g. '4096,4096,4096;128,4096,512'",
    )
    ap.add_argument(
        "--search-gemms", default="",
        help="semicolon-separated M,K,N GEMM shapes to run the full "
             "cost-guided variant search on (enumerate -> prune -> "
             "measure) and persist as ranked plans; ops.dense then "
             "serves the measured winner.  Derived backward specs "
             "(repro.grad) are swept alongside each shape unless "
             "--no-search-grads",
    )
    ap.add_argument(
        "--no-search-grads", action="store_true",
        help="with --search-gemms/--capture, sweep only the forward "
             "specs (inference-only replicas skip the backward-plan "
             "cost)",
    )
    ap.add_argument(
        "--mesh", default=None, metavar="AxB",
        help="mesh shape ('2x4' = data x model) for the distributed "
             "schedule tier: --search-gemms/--capture sweeps also persist "
             "mesh-qualified sharded ladders, and when this process can "
             "host the mesh the serving steps trace under it so eligible "
             "GEMMs dispatch through sharded generated kernels",
    )
    ap.add_argument(
        "--metrics-out", default=None, metavar="FILE",
        help="write the repro.obs metrics registry (per-request latency "
             "p50/p99, tokens/sec, plan-DB/autotune hit counts, capture "
             "dispatch counts) as JSON after the run",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="FILE",
        help="write the Chrome-trace/Perfetto span JSON (prefill, decode "
             "steps, search/codegen phases) after the run; load it at "
             "chrome://tracing or summarize with scripts/obs_report.py "
             "--trace",
    )
    ap.add_argument(
        "--quant", choices=("none", "int8"), default="none",
        help="weight-only serving quantization: parameters are quantized "
             "once at load (block-wise int8 + per-block f32 scales, "
             "optim.quant.quantize_tree) and dequantized inside the "
             "jitted serving steps, so live weights stay 8-bit in device "
             "memory.  With --capture the dispatched dense sites also run "
             "the dynamic-quantized kernel tier and the capture sweep "
             "persists quantized plan legs",
    )
    ap.add_argument(
        "--capture", action="store_true",
        help="whole-model capture (repro.capture): harvest the prefill "
             "+ decode GEMM sets, sweep every harvested spec into the "
             "ranked plan DB, and serve through the captured steps so "
             "eligible dot_general sites dispatch through generated "
             "kernels",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    def _parse_shapes(flag: str, raw: str):
        try:
            shapes = tuple(
                tuple(int(x) for x in part.split(","))
                for part in raw.split(";")
                if part.strip()
            )
            if any(len(t) != 3 for t in shapes):
                raise ValueError(shapes)
            return shapes
        except ValueError:
            ap.error(f"{flag} expects 'M,K,N[;M,K,N...]', got {raw!r}")

    warm = _parse_shapes("--warm-gemms", args.warm_gemms)
    search = _parse_shapes("--search-gemms", args.search_gemms)
    quant = None if args.quant == "none" else args.quant

    from .serving import (ContinuousEngine, FixedEngine, Gateway,
                          synthetic_trace)

    trace = synthetic_trace(
        args.requests,
        vocab=cfg.vocab,
        seed=args.seed,
        rate_hz=args.rate_hz,
        prompt_lens=tuple(sorted({
            max(1, args.prompt_len // 4),
            max(1, args.prompt_len // 2),
            args.prompt_len,
        })),
        max_news=tuple(sorted({max(1, args.max_new // 4), args.max_new})),
    )
    max_ctx = args.prompt_len + args.max_new + 1
    engine_kind = args.engine
    if engine_kind == "continuous" and cfg.family not in ("dense", "moe"):
        log.info("serve", f"family {cfg.family!r} has unpageable state — "
                 "serving fixed-slot")
        engine_kind = "fixed"
    if engine_kind == "continuous":
        pages_per_req = -(-max_ctx // args.page_size)
        n_pages = args.pages or (1 + args.lanes * pages_per_req)
        engine = ContinuousEngine(
            cfg,
            lanes=args.lanes,
            page_size=args.page_size,
            n_pages=n_pages,
            max_ctx=max_ctx,
            search_gemms=search,
            search_grads=not args.no_search_grads,
            mesh_shape=args.mesh,
            quant=quant,
        )
    else:
        engine = FixedEngine(
            cfg,
            lanes=args.lanes,
            max_ctx=max_ctx,
            warm_gemms=warm,
            search_gemms=search,
            search_grads=not args.no_search_grads,
            capture=args.capture,
            mesh_shape=args.mesh,
            quant=quant,
        )
    stats = Gateway(engine).run(trace, eos_id=args.eos_id)
    log.info(
        "serve",
        f"[{engine_kind}] prefill {stats['prefill_s']*1e3:.1f} ms, "
        f"decode {stats['decode_s']*1e3:.1f} ms over "
        f"{stats['decode_steps']} step(s), {stats['tokens']} tokens at "
        f"{stats['tok_per_s']:.1f} decode tok/s"
    )
    if args.metrics_out:
        log.info("serve", f"metrics -> {obs.metrics_dump(args.metrics_out)}")
    if args.trace_out:
        log.info("serve", f"trace -> {obs.trace_dump(args.trace_out)}")


if __name__ == "__main__":
    main()
