"""Step builders: sharded train_step / prefill_step / serve_step.

These are what both the real drivers (train.py / serve.py) and the dry-run
lower.  All sharding is expressed as in/out NamedShardings derived from the
logical-axes trees (launch.sharding); GSPMD inserts the collectives.

Training differentiates through the generated kernels directly: every
model matmul is a ``repro.ops`` entry point, which registers a
``jax.custom_vjp`` (``repro.grad``) whose backward GEMMs are derived
ContractionSpecs compiled through the same plan-DB/autotune pipeline as
the forward.  ``jax.value_and_grad`` below therefore needs no
``dot_general`` fallback on TPU — both sides of the tape run searched/
tuned Pallas kernels (sweep them together with
``scripts/search_sweep.py --with-grads``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models.api import ModelAPI, batch_spec, get_api
from ..optim import AdamWConfig, Quantized
from ..optim import adamw as optim
from . import sharding as shd


@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower/run one (arch x shape x mesh) cell."""

    fn: Callable                      # the jittable step function
    in_shapes: Tuple                  # ShapeDtypeStructs (with shardings)
    static_name: str                  # train_step | prefill_step | serve_step
    out_shardings: Any = None


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def eval_params(cfg: ModelConfig, api: ModelAPI):
    """Abstract param shapes + captured logical axes (no allocation)."""
    captured = {}

    def f(key):
        p, a = api.init(cfg, key)
        captured["axes"] = a
        return p

    shapes = jax.eval_shape(f, jax.random.key(0))
    return shapes, captured["axes"]


def param_shardings(mesh, cfg: ModelConfig, api: ModelAPI):
    shapes, axes = eval_params(cfg, api)
    return shapes, axes, shd.tree_shardings(mesh, shapes, axes)


def opt_shardings(mesh, opt_shapes, param_shardings_tree):
    """Moments inherit the param sharding; Quantized moments shard their
    flat block axis across the whole mesh."""

    def like_params(moments):
        flat_p, treedef = jax.tree.flatten(param_shardings_tree)
        flat_m = treedef.flatten_up_to(moments)
        out = []
        for psh, m in zip(flat_p, flat_m):
            if isinstance(m, Quantized) or hasattr(m, "q"):
                qsh = shd.quantized_sharding(mesh, m)
                out.append(Quantized(qsh["q"], qsh["scale"], m.shape, m.dtype))
            else:
                out.append(psh)
        return treedef.unflatten(out)

    return optim.AdamWState(
        step=NamedSharding(mesh, P()),
        m=like_params(opt_shapes.m),
        v=like_params(opt_shapes.v),
    )


def batch_shardings(mesh, cfg: ModelConfig, shape: ShapeConfig):
    spec = batch_spec(cfg, shape)
    out = {}
    for name, (shp, dtype) in spec.items():
        out[name] = _sds(
            shp, dtype,
            NamedSharding(mesh, shd.batch_spec_for(mesh, shp, seq_axis=1)),
        )
    return out


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    lr_schedule: Optional[Callable] = None,
    microbatch: int = 1,
    capture: Optional[bool] = None,
    mesh=None,
):
    """Loss + grad + optimizer update for one (micro)batch.

    ``jax.value_and_grad`` here differentiates straight through the
    generated kernels: the model's ``ops.dense``/``ops.dense_act`` calls
    carry custom VJPs (``repro.grad``) whose cotangent GEMMs
    (dA = g·Bᵀ, dB = Aᵀ·g) compile under their own derived-spec keys —
    the backward pass is generated-kernel traffic, not a dot_general
    fallback.

    ``capture`` (or ``$REPRO_CAPTURE=1``) additionally routes the loss
    through ``repro.capture.optimize``: the model's *remaining* plain
    ``dot_general`` sites — everything not already a ``repro.ops`` call —
    are harvested into ContractionSpecs and, where eligible, dispatched
    through the same plan-DB pipeline, fwd and bwd.  Ineligible sites run
    untouched, so this is a strict superset of the uncaptured step.

    ``mesh`` activates that mesh for the step body at trace time, so
    ``ops._tuned_kernel`` consults the mesh-shape-qualified plan keys a
    ``--mesh`` sweep persisted and eligible GEMMs dispatch through the
    sharded generated kernels (``codegen.bind_mesh``).  Callers that
    already trace under ``with set_mesh(mesh)`` (``train_bundle`` users)
    get the same behaviour without passing it.
    """
    import contextlib
    import os

    api = get_api(cfg)
    if capture is None:
        capture = os.environ.get("REPRO_CAPTURE", "") == "1"
    base_loss = lambda p, b: api.loss(p, cfg, b)  # noqa: E731
    if capture:
        from .. import capture as _capture

        loss_inner = _capture.optimize(
            base_loss, label=f"{cfg.arch_id}:train_step"
        )
    else:
        loss_inner = base_loss

    def _mesh_ctx():
        if mesh is None:
            return contextlib.nullcontext()
        from .mesh import set_mesh

        return set_mesh(mesh)

    def train_step(params, opt_state, batch):
        def loss_fn(p, b):
            with _mesh_ctx():  # nullcontext when no mesh was given
                return loss_inner(p, b)

        if microbatch > 1:
            def split(x):
                return x.reshape(microbatch, x.shape[0] // microbatch,
                                 *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (
                    loss_acc + l / microbatch,
                    jax.tree.map(
                        lambda a, b: a + b.astype(a.dtype) / microbatch,
                        grad_acc, g,
                    ),
                ), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zero), micro
            )
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        lr_scale = lr_schedule(opt_state.step) if lr_schedule else 1.0
        new_params, new_opt, metrics = optim.update(
            grads, opt_state, params, opt_cfg, lr_scale=lr_scale
        )
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def train_bundle(
    mesh,
    cfg: ModelConfig,
    shape: ShapeConfig,
    opt_cfg: Optional[AdamWConfig] = None,
    microbatch: int = 1,
    capture: Optional[bool] = None,
) -> StepBundle:
    api = get_api(cfg)
    if opt_cfg is None:
        import os

        # kimi-class models need 8-bit moments to fit (DESIGN.md); the
        # zero1 §Perf knob forces them for everyone
        big = cfg.moe is not None and cfg.moe.n_experts >= 256
        use_int8 = big or os.environ.get("REPRO_OPT_INT8") == "1"
        opt_cfg = AdamWConfig(moments_dtype="int8" if use_int8 else "float32")
    p_shapes, axes, p_shard = param_shardings(mesh, cfg, api)
    o_shapes = jax.eval_shape(lambda p: optim.init(p, opt_cfg), p_shapes)
    o_shard = opt_shardings(mesh, o_shapes, p_shard)
    b_sds = batch_shardings(mesh, cfg, shape)

    p_sds = jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), p_shapes, p_shard
    )
    # Quantized is a pytree node: its q/scale children align leaf-wise
    o_sds = jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), o_shapes, o_shard
    )

    step = make_train_step(cfg, opt_cfg, microbatch=microbatch,
                           capture=capture, mesh=mesh)
    metrics_shard = {
        "grad_norm": NamedSharding(mesh, P()),
        "clip_scale": NamedSharding(mesh, P()),
        "loss": NamedSharding(mesh, P()),
    }
    return StepBundle(
        fn=step,
        in_shapes=(p_sds, o_sds, b_sds),
        static_name="train_step",
        out_shardings=(p_shard, o_shard, metrics_shard),
    )


# ---------------------------------------------------------------------------
# serve (prefill + decode)
# ---------------------------------------------------------------------------


def cache_shardings(mesh, cfg: ModelConfig, api: ModelAPI, batch, max_len):
    c_shapes = jax.eval_shape(
        lambda: api.cache_init(cfg, batch, max_len)
    )
    c_axes = api.cache_axes(cfg)

    def one(shape_leaf, ax):
        return NamedSharding(
            mesh,
            shd.spec_for(
                mesh, ax, tuple(shape_leaf.shape),
                rules={**shd.PARAM_RULES, "heads": shd.PARAM_RULES["heads"]},
            ),
        )

    # cache axes tree: per-segment {kind: {leaf: axes}} must align with
    # c_shapes structure; flatten up to the axes tree's leaves
    flat_shapes, treedef = jax.tree.flatten(c_shapes)
    # align by broadcasting the axes tree over the shapes tree
    shard_tree = _map_axes_over(c_shapes, c_axes, one)
    return c_shapes, shard_tree


def _map_axes_over(shapes_tree, axes_tree, fn):
    """Walk shapes_tree; at each leaf find the matching axes entry by key
    path suffix (the axes trees omit the stacked-segment nesting)."""

    def walk(s, a):
        if isinstance(s, dict):
            return {
                k: walk(v, a[k] if isinstance(a, dict) and k in a else a)
                for k, v in s.items()
            }
        # s is a leaf; a should be a tuple of logical names (or dict miss)
        ax = a if isinstance(a, (tuple, type(None))) else None
        return fn(s, ax)

    return walk(shapes_tree, axes_tree)


def serve_bundle(
    mesh, cfg: ModelConfig, shape: ShapeConfig
) -> StepBundle:
    """decode_*: one new token against a seq_len-deep cache."""
    api = get_api(cfg)
    B, S = shape.global_batch, shape.seq_len
    p_shapes, axes, p_shard = param_shardings(mesh, cfg, api)
    p_sds = jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), p_shapes, p_shard
    )
    c_shapes, c_shard = cache_shardings(mesh, cfg, api, B, S)
    c_sds = jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), c_shapes, c_shard
    )
    tok_sds = _sds(
        (B, 1), jnp.int32,
        NamedSharding(mesh, shd.batch_spec_for(mesh, (B, 1))),
    )

    def serve_step(params, caches, tokens):
        return api.decode_step(params, cfg, caches, tokens)

    logits_shard = NamedSharding(
        mesh, shd.batch_spec_for(mesh, (B, 1, cfg.vocab))
    )
    return StepBundle(
        fn=serve_step,
        in_shapes=(p_sds, c_sds, tok_sds),
        static_name="serve_step",
        out_shardings=(logits_shard, c_shard),
    )


def prefill_bundle(mesh, cfg: ModelConfig, shape: ShapeConfig) -> StepBundle:
    api = get_api(cfg)
    B, S = shape.global_batch, shape.seq_len
    p_shapes, axes, p_shard = param_shardings(mesh, cfg, api)
    p_sds = jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), p_shapes, p_shard
    )
    b_sds = batch_shardings(mesh, cfg, shape)
    max_len = {"dense": S, "moe": S}.get(cfg.family, S)

    def prefill_step(params, batch):
        return api.prefill(params, cfg, batch, max_len)

    # determine cache output shardings from an eval_shape of the caches
    dec_len = b_sds["tokens"].shape[1]
    _, c_shard = cache_shardings(mesh, cfg, api, B, max_len)
    logits_shard = NamedSharding(
        mesh, shd.batch_spec_for(mesh, (B, dec_len, cfg.vocab))
    )
    return StepBundle(
        fn=prefill_step,
        in_shapes=(p_sds, b_sds),
        static_name="prefill_step",
        out_shardings=(logits_shard, c_shard),
    )
