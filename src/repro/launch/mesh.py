"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Shapes per the deliverable:

  single pod : (data=16, model=16)              -- 256 chips
  multi-pod  : (pod=2, data=16, model=16)       -- 512 chips

The ``pod`` axis is hierarchical data parallelism by default: gradients
reduce-scatter in-pod over ICI and cross pods over DCI (optionally int8-
compressed, see optim.compress); switching it to a pipeline axis is a
config choice in launch.train.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 has explicit axis types; 0.4.37 (pinned) does not
    from jax.sharding import AxisType

    def _mesh(shape, axes):
        return jax.make_mesh(
            shape, axes, axis_types=(AxisType.Auto,) * len(axes)
        )

except ImportError:

    def _mesh(shape, axes):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires >= prod(shape) local devices)."""
    return _mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes that carve the global batch (pod+data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


# -- jax version compat shims -------------------------------------------------


def set_mesh(mesh):
    """Context manager activating ``mesh``: jax.set_mesh when it exists
    (jax >= 0.6), else the Mesh object itself (the 0.4.x context API)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def axis_size(axis_name: str) -> int:
    """lax.axis_size where available; psum(1) constant-folds on 0.4.37.

    Single implementation lives with the collectives (codegen cannot
    import launch without inverting layering); this is the launch-facing
    name.
    """
    from ..codegen.collectives import _axis_size

    return _axis_size(axis_name)


def active_mesh():
    """The mesh the current (trace) context is running under, or None.

    On 0.4.x this is the ``with mesh:`` context (``thread_resources``);
    newer jax exposes ``jax.set_mesh``/abstract meshes — we try the
    thread-resources path first because that is what ``set_mesh`` returns
    on the pinned version.  ``ops._tuned_kernel`` consults this to decide
    whether a mesh-qualified plan lookup applies.
    """
    try:
        from jax.interpreters.pxla import thread_resources

        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:  # jax >= 0.6: an explicitly set global mesh
        m = jax.sharding.get_mesh()  # type: ignore[attr-defined]
        if m is not None and getattr(m, "size", 0) > 1:
            return m
    except Exception:
        pass
    return None


def mesh_shape_descriptor(mesh) -> str:
    """'2x4'-style descriptor of a mesh (the plan-key qualifier)."""
    return "x".join(str(int(s)) for s in mesh.devices.shape)
