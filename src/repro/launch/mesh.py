"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Shapes per the deliverable:

  single pod : (data=16, model=16)              -- 256 chips
  multi-pod  : (pod=2, data=16, model=16)       -- 512 chips

The ``pod`` axis is hierarchical data parallelism by default: gradients
reduce-scatter in-pod over ICI and cross pods over DCI (optionally int8-
compressed, see optim.compress); switching it to a pipeline axis is a
config choice in launch.train.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires >= prod(shape) local devices)."""
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def batch_axes(mesh) -> tuple:
    """Mesh axes that carve the global batch (pod+data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
