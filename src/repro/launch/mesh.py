"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Shapes per the deliverable:

  single pod : (data=16, model=16)              -- 256 chips
  multi-pod  : (pod=2, data=16, model=16)       -- 512 chips

The ``pod`` axis is hierarchical data parallelism by default: gradients
reduce-scatter in-pod over ICI and cross pods over DCI (optionally int8-
compressed, see optim.compress); switching it to a pipeline axis is a
config choice in launch.train.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 has explicit axis types; 0.4.37 (pinned) does not
    from jax.sharding import AxisType

    def _mesh(shape, axes):
        return jax.make_mesh(
            shape, axes, axis_types=(AxisType.Auto,) * len(axes)
        )

except ImportError:

    def _mesh(shape, axes):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires >= prod(shape) local devices)."""
    return _mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes that carve the global batch (pod+data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


# -- jax version compat shims -------------------------------------------------


def set_mesh(mesh):
    """Context manager activating ``mesh``: jax.set_mesh when it exists
    (jax >= 0.6), else the Mesh object itself (the 0.4.x context API)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def axis_size(axis_name: str) -> int:
    """lax.axis_size where available; psum(1) constant-folds on 0.4.37."""
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)
