"""Training driver: data pipeline -> sharded train_step -> checkpoint/restart.

Scales from the CPU container (1 device, smoke config) to the production
mesh (same code path — the mesh and shardings come from launch.mesh /
launch.sharding).  Fault tolerance is the runtime.fault loop: deterministic
data + atomic checkpoints = exact replay after restore.

On TPU the whole step — forward *and* backward — runs generated kernels:
``repro.grad`` gives every ``ops`` matmul a custom VJP whose cotangent
GEMMs go through the same searched/tuned pipeline (see
``launch.steps.make_train_step``); warm their plans with
``scripts/search_sweep.py --with-grads`` before a big run.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import checkpoint as ckpt
from ..configs import SHAPES, get_config
from ..obs import log
from ..configs.base import ShapeConfig
from ..data.pipeline import DataConfig, batch_at
from ..models.api import get_api
from ..optim import AdamWConfig, warmup_cosine
from ..optim import adamw as optim
from ..runtime.fault import FaultTolerantLoop, LoopConfig
from .steps import make_train_step


@dataclasses.dataclass
class TrainRun:
    cfg: object
    opt_cfg: AdamWConfig
    data_cfg: DataConfig
    steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    #: route the loss through repro.capture.optimize so the model's plain
    #: dot_general GEMMs dispatch through the plan-DB pipeline (fwd+bwd);
    #: None = read $REPRO_CAPTURE
    capture: Optional[bool] = None


def train(run: TrainRun, params=None, verbose: bool = True):
    cfg = run.cfg
    api = get_api(cfg)
    if params is None:
        params, _ = api.init(cfg, jax.random.key(0))
    opt_state = optim.init(params, run.opt_cfg)
    schedule = warmup_cosine(
        warmup=min(100, run.steps // 10 + 1), total=run.steps
    )
    step_fn = jax.jit(make_train_step(
        cfg, run.opt_cfg, lr_schedule=schedule, capture=run.capture
    ))

    mgr = (
        ckpt.CheckpointManager(run.ckpt_dir, keep=3) if run.ckpt_dir else None
    )
    start_step = 0
    if run.ckpt_dir and ckpt.latest_step(run.ckpt_dir) is not None:
        (params, opt_state), manifest = ckpt.restore(
            run.ckpt_dir, (params, opt_state)
        )
        start_step = manifest["step"]
        if verbose:
            log.info("restore", f"resuming from step {start_step}")

    losses = []
    state = (params, opt_state)

    def one_step(step, state):
        params, opt_state = state
        batch = {
            k: jnp.asarray(v) for k, v in batch_at(run.data_cfg, step).items()
        }
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if verbose and step % run.log_every == 0:
            log.info(
                None,
                f"step {step:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f}",
                flush=True,
            )
        return (params, opt_state)

    def save_fn(step, state):
        if mgr:
            mgr.save_async(step, state, extra={"step": step})

    def restore_fn():
        (p, o), manifest = ckpt.restore(run.ckpt_dir, state)
        return manifest["step"], (p, o)

    loop = FaultTolerantLoop(
        step_fn=one_step,
        save_fn=save_fn,
        restore_fn=restore_fn,
        config=LoopConfig(checkpoint_every=run.ckpt_every),
    )
    state = loop.run(state, start_step, run.steps - start_step)
    if mgr:
        mgr.close()
    return state, losses, loop.report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--moments", default="float32",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--capture", action="store_true",
                    help="capture the whole model: harvest its plain "
                         "dot_general GEMMs and dispatch the eligible "
                         "ones through the plan-DB pipeline "
                         "(repro.capture; also $REPRO_CAPTURE=1)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    run = TrainRun(
        cfg=cfg,
        opt_cfg=AdamWConfig(lr=args.lr, moments_dtype=args.moments),
        data_cfg=DataConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch
        ),
        steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        capture=args.capture or None,
    )
    t0 = time.time()
    _, losses, report = train(run)
    dt = time.time() - t0
    log.info(
        "train",
        f"{args.steps} steps in {dt:.1f}s; "
        f"loss {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f}; "
        f"stragglers={len(report.straggler_events)}"
    )


if __name__ == "__main__":
    main()
