"""§Perf harness: compile ONE cell under a named knob combination and report
the roofline-term deltas against the baseline record.

Knobs (combinable via --knob a,b):
  baseline        no overrides (the paper-faithful configuration)
  remat_dots      REPRO_REMAT_POLICY=dots  (save matmul outputs in fwd)
  dp              REPRO_SHARDING=dp        (no TP; batch over all axes)
  zero1           REPRO_SHARDING=zero1 + int8 moments (no FSDP param gather)
  moe_constraint  REPRO_MOE_CONSTRAINT=1   (pin dispatch to EP layout)

Usage:
  PYTHONPATH=src python -m repro.launch.perf --arch qwen2-72b \
      --shape train_4k --knob zero1 --out results_perf

Environment: the 512-device ``XLA_FLAGS`` forcing lives in ``main()``
(before the deferred ``dryrun`` import initializes jax) — merely importing
this module must not mutate process state, per the dry-run contract that
only the perf/dryrun *entry points* force devices.
"""

import argparse
import json
import os

_KNOB_ENV = {
    "baseline": {},
    "remat_dots": {"REPRO_REMAT_POLICY": "dots"},
    "dp": {"REPRO_SHARDING": "dp"},
    "zero1": {"REPRO_SHARDING": "zero1", "REPRO_OPT_INT8": "1"},
    "moe_constraint": {"REPRO_MOE_CONSTRAINT": "1"},
    "unembed": {"REPRO_UNEMBED_FIX": "1"},
    "donate": {"REPRO_DONATE": "1"},
    "causal_skip": {"REPRO_CAUSAL_SKIP": "1"},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    ap.add_argument("--knob", default="baseline",
                    help="comma-separated knob names")
    ap.add_argument("--out", default="results_perf")
    ap.add_argument("--baseline-dir", default="results")
    args = ap.parse_args()

    # the dry-run device forcing — set here, not at import time, so that
    # importing repro.launch.perf (tests, docs builds) leaves XLA_FLAGS
    # alone; run_cell is imported after this takes effect
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS_EXTRA", "")
    )

    knobs = args.knob.split(",")
    for k in knobs:
        for env, val in _KNOB_ENV[k].items():
            os.environ[env] = val

    from .dryrun import run_cell  # import AFTER env is set
    from ..roofline.analysis import analyze_cell, param_counts

    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.arch}__{args.shape}__{args.mesh}__{'+'.join(knobs)}"
    rec = run_cell(
        args.arch, args.shape, args.mesh == "multipod",
        hlo_dir=os.path.join(args.out, "hlo"),
    )
    rec["knobs"] = knobs
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)

    counts = param_counts(args.arch)
    row = analyze_cell(rec, counts)
    print(f"\n=== {tag}: {rec['status']} ===")
    if rec["status"] != "ok":
        print(rec.get("error"))
        return
    for k in ("compute_s", "memory_s", "collective_s", "dominant",
              "useful_ratio", "roofline_fraction"):
        print(f"  {k:20s} {row[k]}")
    print(f"  peak_memory_GiB      "
          f"{rec['memory'].get('peak_memory_in_bytes', 0)/2**30:.2f}")

    # delta vs the baseline sweep record
    base_path = os.path.join(
        args.baseline_dir,
        f"{args.arch}__{args.shape}__"
        f"{'mp' if args.mesh == 'multipod' else 'sp'}.json",
    )
    if os.path.exists(base_path):
        with open(base_path) as f:
            base = analyze_cell(json.load(f), counts)
        if base["status"] == "ok":
            print("  --- vs baseline ---")
            for k in ("compute_s", "memory_s", "collective_s"):
                b, n = base[k], row[k]
                print(f"  {k:20s} {b:.4g} -> {n:.4g} "
                      f"({(n/b - 1)*100:+.1f}%)")


if __name__ == "__main__":
    main()
