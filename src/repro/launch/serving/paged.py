"""Block-table paged KV cache — the storage layer of the serving tier.

KV memory is one physical pool per attention-cache leaf, carved into
fixed-size pages:

    pool["segN"][kind]["k"] : (layers, n_pages, page_size, kv_heads, hd)

A request owns an ordered list of page ids (its *block table*); logical
cache position ``p`` lives at page ``pages[p // page_size]``, offset
``p % page_size``.  Allocation and release are O(pages) free-list moves
on the host (:class:`PagePool`), so requests of wildly different lengths
share the pool without fragmentation — the whole point of paging.

The model itself is unchanged: before each decode step the lanes' pages
are gathered into the dense stacked-cache pytree ``models.api`` already
consumes (:func:`paged_view`), and the single KV row the step appends is
scattered back to its physical page (:func:`scatter_token`).  Both are
pure jax functions traced once per (lanes, max_pages) shape — the block
table and lengths are runtime data, so page churn never recompiles.

Physical page 0 is reserved as the *sink*: idle decode lanes point their
block tables at it, and the garbage KV their dispatches produce lands
there instead of in live pages.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax.numpy as jnp
from jax import lax

from ...configs.base import ModelConfig
from ...models import transformer

#: block-table entry for slots past a request's last page (and for every
#: slot of an idle lane) — all of them alias the sink page
SINK_PAGE = 0


class PagePool:
    """Host-side free-list over physical page ids (page 0 = sink)."""

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the sink)")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO so recently-freed (cache-warm) pages are reused first
        self._free = list(range(n_pages - 1, 0, -1))

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        """Usable pages (the sink is never allocatable)."""
        return self.n_pages - 1

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache positions (>= 1)."""
        return max(1, -(-n_tokens // self.page_size))

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages, or None (and no change) if the pool is short."""
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if not 0 < p < self.n_pages:
                raise ValueError(f"bad page id {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)


def pool_init(cfg: ModelConfig, n_pages: int, page_size: int) -> Dict:
    """Physical KV pools mirroring ``transformer.cache_init``'s structure
    (one {"k", "v"} leaf pair per segment x layer-kind, layers stacked)."""
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"paged KV serves attention families only, not {cfg.family!r} "
            "(SSM state is not paged)"
        )
    kv, hd, dt = cfg.n_kv_heads, cfg.hd, cfg.param_dtype
    pools: Dict = {}
    for si, (pattern, count) in enumerate(transformer.segment_plan(cfg)):
        pools[f"seg{si}"] = {
            kind: {
                "k": jnp.zeros((count, n_pages, page_size, kv, hd), dt),
                "v": jnp.zeros((count, n_pages, page_size, kv, hd), dt),
            }
            for kind in pattern
        }
    return pools


def paged_view(pools: Dict, block_table, lens, page_size: int) -> Dict:
    """Gather each lane's pages into the dense stacked-cache pytree.

    block_table (lanes, max_pages) int32, lens (lanes,) int32 = number of
    KV rows present per lane.  The view's tail positions (>= lens) hold
    whatever the sink/unwritten pages contain; ``decode_attention`` masks
    ``t >= cache_len`` to exactly zero weight, so they are never read.
    """
    lanes, max_pages = block_table.shape

    def view(p):
        g = p[:, block_table]  # (L, lanes, max_pages, page, kv, hd)
        return g.reshape(
            p.shape[0], lanes, max_pages * page_size, p.shape[3], p.shape[4]
        )

    caches: Dict = {}
    for seg, kinds in pools.items():
        caches[seg] = {}
        for kind, pv in kinds.items():
            n_layers = pv["k"].shape[0]
            caches[seg][kind] = {
                "k": view(pv["k"]),
                "v": view(pv["v"]),
                "len": jnp.broadcast_to(
                    lens[None, :].astype(jnp.int32), (n_layers, lanes)
                ),
            }
    return caches


def scatter_token(
    pools: Dict, new_caches: Dict, block_table, lens, page_size: int
) -> Dict:
    """Write the KV row each lane's decode step appended back to its page.

    The step wrote at view position ``lens`` (the pre-step cache length),
    which physically lives at page ``block_table[lane, lens // page_size]``
    offset ``lens % page_size``.  Idle lanes (lens=0, all-sink tables)
    scatter their garbage onto the sink page; duplicate sink indices are
    resolved arbitrarily, which is fine — nothing reads the sink.
    """
    lanes = block_table.shape[0]
    lane = jnp.arange(lanes)
    page_of = block_table[lane, lens // page_size]  # (lanes,)
    off = lens % page_size

    def pick(arr):  # (L, lanes, ctx, kv, hd) -> row at lens: (L, lanes, kv, hd)
        idx = jnp.broadcast_to(
            lens[None, :, None, None, None].astype(jnp.int32),
            (arr.shape[0], lanes, 1, arr.shape[3], arr.shape[4]),
        )
        return jnp.take_along_axis(arr, idx, axis=2)[:, :, 0]

    out: Dict = {}
    for seg, kinds in pools.items():
        out[seg] = {}
        for kind, pv in kinds.items():
            nc = new_caches[seg][kind]
            out[seg][kind] = {
                "k": pv["k"].at[:, page_of, off].set(pick(nc["k"])),
                "v": pv["v"].at[:, page_of, off].set(pick(nc["v"])),
            }
    return out


def store_prefill(pools: Dict, caches: Dict, page_ids, page_size: int) -> Dict:
    """Copy a batch-1 prefill cache into physical pages.

    ``caches`` is the dense cache a ``max_len = len(page_ids) * page_size``
    prefill produced; page ``j`` of it (positions ``[j*ps, (j+1)*ps)``)
    lands on physical page ``page_ids[j]``.  Positions past the prompt's
    true length hold pad KV — harmless, because a position is only ever
    attended once ``cache_len`` exceeds it, and decode overwrites it with
    the real token's KV before that happens.
    """

    def body(pl, xs):
        j, pid = xs
        new: Dict = {}
        for seg, kinds in pl.items():
            new[seg] = {}
            for kind, pv in kinds.items():
                c = caches[seg][kind]

                def src(arr):  # (L, 1, max_len, kv, hd) -> (L, page, kv, hd)
                    return lax.dynamic_slice_in_dim(
                        arr[:, 0], j * page_size, page_size, axis=1
                    )

                new[seg][kind] = {
                    "k": pv["k"].at[:, pid].set(src(c["k"])),
                    "v": pv["v"].at[:, pid].set(src(c["v"])),
                }
        return new, None

    n = page_ids.shape[0]
    pools, _ = lax.scan(body, pools, (jnp.arange(n), page_ids))
    return pools
