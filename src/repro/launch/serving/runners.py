"""Prefill and decode runners — the two compute phases of serving, each
with its own phase-tagged plan-DB ladder.

Prefill is compute-bound (square-ish GEMMs over the whole prompt); decode
is bandwidth-bound (skinny M = lanes GEMMs).  The same logical GEMM spec
wants different schedules in each phase, so the runners wrap their
dispatches in ``search.serving_phase(...)``: while jit traces the step,
``ops._tuned_kernel`` sees the active phase and consults the
phase-qualified plan-DB entry first (falling back to the unphased one).
``sweep()`` populates those entries — the decode runner rewrites each
swept shape's M to its lane count, because that is the GEMM it actually
dispatches.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ... import obs
from ...configs.base import ModelConfig
from ...models.api import ModelAPI
from ...obs import log
from ...search import serving_phase
from . import paged


def _sweep(phase: str, shapes, *, with_grads: bool, mesh_shape=None) -> int:
    from ...search import default_plan_db, search_gemm_plans

    db = default_plan_db()
    n = search_gemm_plans(
        shapes,
        dtype=jnp.bfloat16,
        interpret=jax.default_backend() != "tpu",
        plan_db=db,
        with_grads=with_grads,
        mesh_shape=mesh_shape,
        phase=phase,
    )
    log.info("serve", f"searched {n} {phase}-phase GEMM plan(s) -> {db.path}")
    return n


def _deq_fn(quant: Optional[str]):
    """Param expansion hook for the weight-only quant tier: identity when
    full-precision, ``optim.quant.dequantize_tree`` when serving ``--quant``
    — called INSIDE the jitted closures so the f32 weights are jit
    temporaries and only the 8-bit tree stays live."""
    if not quant:
        return lambda p: p
    from ...optim.quant import dequantize_tree

    return dequantize_tree


class PrefillRunner:
    """Batch-1 bucketed prefill: pads the context to a page multiple,
    masks the pads via ``lengths``, and copies the resulting cache pages
    into the physical pool.  Retraces once per padded-length bucket."""

    phase = "prefill"

    def __init__(self, cfg: ModelConfig, api: ModelAPI, page_size: int,
                 quant: Optional[str] = None):
        self.cfg = cfg
        self.page_size = page_size
        deq = _deq_fn(quant)

        def run(params, tokens, lengths):
            logits, caches = api.prefill(
                deq(params), cfg, {"tokens": tokens, "lengths": lengths},
                tokens.shape[1],
            )
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return tok, caches

        self._run = jax.jit(run)
        self._store = jax.jit(
            lambda pools, caches, page_ids: paged.store_prefill(
                pools, caches, page_ids, page_size
            )
        )

    def sweep(self, shapes, *, with_grads: bool = True, mesh_shape=None):
        return _sweep(
            self.phase, shapes, with_grads=with_grads, mesh_shape=mesh_shape
        )

    def __call__(
        self, params, pools: Dict, context, pages
    ) -> Tuple[int, Dict]:
        """Prefill one request's context and store it into ``pages``.
        Returns (first generated token, updated pools)."""
        plen = len(context)
        padded = len(pages) * self.page_size
        assert padded >= plen
        toks = jnp.zeros((1, padded), jnp.int32)
        toks = toks.at[0, :plen].set(jnp.asarray(context, jnp.int32))
        lengths = jnp.full((1,), plen, jnp.int32)
        with serving_phase(self.phase):
            with obs.span("serve.prefill", tokens=plen, padded=padded):
                tok, caches = self._run(params, toks, lengths)
                pools = self._store(
                    pools, caches, jnp.asarray(pages, jnp.int32)
                )
        return int(tok[0]), pools


class DecodeRunner:
    """One continuous-batching decode step over all lanes: gather the
    block-table pages into the dense cache view, run the model's
    ``decode_step``, scatter the appended KV row back.  Fixed
    (lanes, max_pages) shapes — traced exactly once."""

    phase = "decode"

    def __init__(
        self, cfg: ModelConfig, api: ModelAPI, page_size: int,
        lanes: int, max_pages: int, quant: Optional[str] = None,
    ):
        self.cfg = cfg
        self.lanes = lanes
        self.max_pages = max_pages
        deq = _deq_fn(quant)

        def step(params, pools, block_table, lens, tokens):
            caches = paged.paged_view(pools, block_table, lens, page_size)
            logits, new_caches = api.decode_step(
                deq(params), cfg, caches, tokens[:, None]
            )
            pools = paged.scatter_token(
                pools, new_caches, block_table, lens, page_size
            )
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return tok, pools

        self._step = jax.jit(step)

    def sweep(self, shapes, *, with_grads: bool = False, mesh_shape=None):
        # decode dispatches M = lanes activations regardless of what the
        # fleet swept for training/prefill — ladder the shapes it runs
        skinny = tuple((self.lanes, k, n) for (_, k, n) in shapes)
        return _sweep(
            self.phase, skinny, with_grads=with_grads, mesh_shape=mesh_shape
        )

    def __call__(self, params, pools, block_table, lens, tokens):
        """Returns (next_token per lane, updated pools)."""
        with serving_phase(self.phase):
            tok, pools = self._step(
                params, pools,
                jnp.asarray(block_table, jnp.int32),
                jnp.asarray(lens, jnp.int32),
                jnp.asarray(tokens, jnp.int32),
            )
        return tok, pools
