"""Continuous-batching scheduler: FCFS admission, immediate reclaim,
recompute-style preemption.

Policy, in order of application every engine step:

* **finish** — a request that hit max_new/EOS frees its pages the same
  step (the engine calls :meth:`Scheduler.finish` as it emits), so the
  next admission sees the memory immediately.
* **grow** — every running request must own a page for the position its
  next decode writes.  When the pool is dry, the *newest* admitted
  request is preempted: pages freed, generated tokens folded into its
  recompute prefix, requeued at the queue head (FCFS order preserved).
  Under greedy decoding recompute is exact — re-prefilling
  ``prompt + generated`` yields the same continuation it would have
  produced uninterrupted.
* **admit** — FCFS from the queue head into free decode lanes, while the
  pool keeps ``watermark`` pages spare *after* the admission (headroom so
  the requests just admitted can grow a few steps without immediately
  preempting each other).  Head-of-line blocking is deliberate: skipping
  a big request to admit small ones behind it would starve it forever.

The scheduler is pure host-side bookkeeping — it never touches jax.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

import numpy as np

from .paged import PagePool


@dataclasses.dataclass
class ServeRequest:
    """One generation request flowing through the serving tier."""

    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32, immutable
    max_new: int
    arrival_s: float = 0.0             # offset into the trace
    tenant: str = "tenant0"
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    state: str = "queued"              # queued | running | finished
    lane: int = -1
    pages: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0
    # perf_counter stamps the engine fills in (None until they happen)
    t_submit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    _admit_seq: int = -1               # admission order, for preempt-newest

    @property
    def ctx_len(self) -> int:
        """Logical context length: prompt plus everything generated."""
        return len(self.prompt) + len(self.out_tokens)

    @property
    def context_tokens(self) -> np.ndarray:
        """The recompute prefix: prompt + generated-so-far.  Prefilling
        this after a preemption reproduces the uninterrupted state."""
        if not self.out_tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out_tokens, np.int32)]
        )


class Scheduler:
    """FCFS continuous-batching policy over a :class:`PagePool`."""

    def __init__(self, pool: PagePool, lanes: int, watermark: int = 0):
        if lanes < 1:
            raise ValueError("need >= 1 decode lane")
        self.pool = pool
        self.lanes = lanes
        self.watermark = watermark
        self.queue: Deque[ServeRequest] = collections.deque()
        self.running: Dict[int, ServeRequest] = {}   # lane -> request
        self._admit_counter = 0

    # -- queue -------------------------------------------------------------

    def submit(self, req: ServeRequest) -> None:
        need = self.pool.pages_for(len(req.prompt) + req.max_new)
        if need > self.pool.capacity:
            raise ValueError(
                f"request {req.rid} needs {need} pages at full length but "
                f"the pool only has {self.pool.capacity}"
            )
        req.state = "queued"
        self.queue.append(req)

    def _free_lane(self) -> Optional[int]:
        for lane in range(self.lanes):
            if lane not in self.running:
                return lane
        return None

    # -- admission ---------------------------------------------------------

    def admit(self) -> List[ServeRequest]:
        """Admit FCFS from the queue head; returns the newly running
        requests (the engine prefills them).  Pages for the full current
        context (recompute prefix included) are allocated here."""
        admitted: List[ServeRequest] = []
        while self.queue:
            req = self.queue[0]
            lane = self._free_lane()
            if lane is None:
                break
            need = self.pool.pages_for(req.ctx_len)
            below_mark = self.pool.free_count - need < self.watermark
            # progress guarantee: with nothing running the watermark is
            # moot — admit the head as long as the pages physically fit
            if below_mark and (self.running or admitted):
                break
            if below_mark and self.pool.free_count < need:
                raise RuntimeError(
                    f"request {req.rid} needs {need} pages, pool has "
                    f"{self.pool.free_count} free and nothing left to evict"
                )
            pages = self.pool.alloc(need)
            assert pages is not None
            self.queue.popleft()
            req.pages = pages
            req.lane = lane
            req.state = "running"
            req._admit_seq = self._admit_counter
            self._admit_counter += 1
            self.running[lane] = req
            admitted.append(req)
        return admitted

    # -- growth / preemption ----------------------------------------------

    def grow(self) -> List[ServeRequest]:
        """Give every running request the pages its context now needs,
        preempting the newest admissions when the pool runs dry.  Returns
        the preempted requests (already requeued)."""
        preempted: List[ServeRequest] = []
        # oldest admissions grow first, so eviction pressure lands on the
        # newest — the one with the least sunk prefill work
        for req in sorted(self.running.values(), key=lambda r: r._admit_seq):
            if req.lane not in self.running:    # preempted earlier this pass
                continue
            while len(req.pages) < self.pool.pages_for(req.ctx_len):
                got = self.pool.alloc(1)
                if got is not None:
                    req.pages.extend(got)
                    continue
                victim = max(
                    self.running.values(), key=lambda r: r._admit_seq
                )
                self.preempt(victim)
                preempted.append(victim)
                if victim is req:
                    break
        return preempted

    def preempt(self, req: ServeRequest) -> None:
        """Recompute-style eviction: drop the pages, requeue at the head.

        The generated tokens stay on the request (``context_tokens`` folds
        them into the next prefill), so no work is lost beyond the
        recompute itself."""
        self.pool.free(req.pages)
        req.pages = []
        del self.running[req.lane]
        req.lane = -1
        req.preemptions += 1
        req.state = "queued"
        self.queue.appendleft(req)

    # -- completion --------------------------------------------------------

    def finish(self, req: ServeRequest) -> None:
        """Release the request's lane and pages immediately."""
        self.pool.free(req.pages)
        req.pages = []
        del self.running[req.lane]
        req.lane = -1
        req.state = "finished"
