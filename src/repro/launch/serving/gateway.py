"""Gateway: drives a request trace through an engine and reports per-run
serving metrics.

The engine records the per-request observability itself (latency/TTFT
histograms, ``serve.request`` complete-events); the gateway adds the
run-level summary — p50/p99 latency, per-tenant token counts — and the
``--metrics-out`` / ``--trace-out`` artifact writing, so the CLI and the
bench share one code path.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from ... import obs
from ...obs import log
from .scheduler import ServeRequest


class Gateway:
    """Thin front door over a serving engine (continuous or fixed)."""

    def __init__(self, engine):
        self.engine = engine

    def run(
        self, trace: List[ServeRequest], *, eos_id: Optional[int] = None
    ) -> Dict:
        with obs.span("serve.gateway", requests=len(trace)):
            stats = self.engine.run(trace, eos_id=eos_id)
        lat = [
            r.t_done - r.t_submit
            for r in trace
            if r.t_done is not None and r.t_submit is not None
        ]
        stats["p50_s"] = float(np.percentile(lat, 50)) if lat else 0.0
        stats["p99_s"] = float(np.percentile(lat, 99)) if lat else 0.0
        by_tenant: Dict[str, int] = {}
        for r in trace:
            by_tenant[r.tenant] = by_tenant.get(r.tenant, 0) + len(
                r.out_tokens
            )
        for tenant, toks in sorted(by_tenant.items()):
            obs.counter(f"serve.tenant_tokens.{tenant}").inc(toks)
        stats["tenant_tokens"] = by_tenant
        log.info(
            "serve",
            f"{stats['requests']} request(s), {stats['tokens']} token(s) "
            f"at {stats['tok_per_s']:.1f} decode tok/s, "
            f"p50 {stats['p50_s']*1e3:.1f} ms, "
            f"p99 {stats['p99_s']*1e3:.1f} ms, "
            f"{stats['preemptions']} preemption(s)",
        )
        return stats
