"""Continuous-batching paged-KV serving tier.

Layers, bottom to top:

* :mod:`.paged` — physical KV pages: host free-list bookkeeping plus the
  gather/scatter that presents pages to the unchanged model API as a
  dense cache view.
* :mod:`.scheduler` — FCFS admission under a page-budget watermark,
  immediate reclaim on finish, preempt-newest recompute when the pool
  runs dry.
* :mod:`.runners` — the prefill (compute-bound) and decode
  (bandwidth-bound, skinny-M) phases, each consulting and sweeping its
  own phase-tagged plan-DB ladder via ``search.serving_phase``.
* :mod:`.engine` — :class:`ContinuousEngine` (slot-free continuous
  batching) and :class:`FixedEngine` (the legacy fixed-slot server,
  kept as the differential/throughput baseline).
* :mod:`.gateway` / :mod:`.trace` — drive a seeded multi-tenant Poisson
  trace through either engine with per-request observability.

``launch.serve --engine continuous`` is the CLI entry point;
``benchmarks/serve_bench.py`` gates continuous >= fixed throughput.
"""

from .engine import ContinuousEngine, FixedEngine
from .gateway import Gateway
from .paged import PagePool, pool_init
from .scheduler import Scheduler, ServeRequest
from .trace import synthetic_trace

__all__ = [
    "ContinuousEngine",
    "FixedEngine",
    "Gateway",
    "PagePool",
    "pool_init",
    "Scheduler",
    "ServeRequest",
    "synthetic_trace",
]
