"""Serving engines: continuous batching over paged KV, and the legacy
fixed-slot server behind the same ``run()`` interface.

:class:`ContinuousEngine` is slot-free.  Each loop iteration:

1. moves arrived requests into the scheduler (fast-forwarding the clock
   when everything is idle, so a sparse trace doesn't busy-wait),
2. admits FCFS from the queue head into free decode lanes — each
   admission prefills its context batch-1 (phase ``prefill``) straight
   into freshly allocated pages and emits its first token,
3. grows every running request's block table for the position its next
   decode writes, preempting the newest admission when the pool is dry,
4. runs ONE decode step across all lanes (phase ``decode``, fixed
   shapes, compiled once) and emits one token per live request.

Requests therefore join the decode batch the step after their prefill
completes and leave it — freeing pages immediately — the step they
finish; short and long requests share lanes without rounding every batch
up to the longest member, which is where the throughput over the
fixed-slot server comes from.

Both engines return the same stats dict (``tok_per_s`` counts *decode*
tokens over decode seconds only — prefill-produced first tokens are
accounted to prefill) and under greedy decoding produce bitwise-equal
per-request outputs, which the differential tests pin.
"""

from __future__ import annotations

import collections
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from ... import obs
from ...configs.base import ModelConfig
from ...models.api import get_api
from . import paged
from .runners import DecodeRunner, PrefillRunner
from .scheduler import Scheduler, ServeRequest


class ContinuousEngine:
    """Continuous-batching serving engine over a paged KV pool."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        lanes: int = 4,
        page_size: int = 16,
        n_pages: int = 64,
        max_ctx: Optional[int] = None,
        watermark: Optional[int] = None,
        params=None,
        search_gemms=(),
        search_grads: bool = False,
        mesh_shape=None,
        quant: Optional[str] = None,
    ):
        self.cfg = cfg
        self.api = get_api(cfg)
        self.lanes = lanes
        self.page_size = page_size
        if max_ctx is None:
            # default per-request ceiling: an even share of the pool
            max_ctx = page_size * max(1, (n_pages - 1) // max(1, lanes))
        self.max_pages = -(-max_ctx // page_size)
        self.max_ctx = self.max_pages * page_size
        self.pool = paged.PagePool(n_pages, page_size)
        self.sched = Scheduler(
            self.pool, lanes,
            watermark=lanes if watermark is None else watermark,
        )
        if params is None:
            params, _ = self.api.init(cfg, jax.random.key(0))
        # --quant int8: weight-only tier.  Quantize the tree once here
        # (Quantized leaves are registered pytree nodes) and let the
        # runners dequantize inside their jitted closures — live weights
        # stay 8-bit + scales, f32 copies are jit temporaries.
        self.quant = quant
        if quant:
            from ...obs import log
            from ...optim.quant import quantize_tree, tree_quant_bytes

            params = quantize_tree(params, fmt=quant)
            qb = tree_quant_bytes(params)
            obs.gauge("serve.quant_bytes").set(qb)
            log.info("serve", f"weight-only {quant}: "
                     f"{qb / 2**20:.2f} MiB held as quantized leaves")
        self.params = params
        self.pools = paged.pool_init(cfg, n_pages, page_size)
        self.prefill = PrefillRunner(cfg, self.api, page_size, quant=quant)
        self.decode = DecodeRunner(
            cfg, self.api, page_size, lanes, self.max_pages, quant=quant
        )
        if search_gemms:
            self.prefill.sweep(
                search_gemms, with_grads=search_grads, mesh_shape=mesh_shape
            )
            self.decode.sweep(search_gemms, mesh_shape=mesh_shape)
        # pre-register so a metrics dump always carries the cache counters
        for name in ("plandb.hit", "plandb.miss",
                     "autotune.hit", "autotune.miss"):
            obs.counter(name).inc(0)

    def run(
        self, requests: List[ServeRequest], *, eos_id: Optional[int] = None
    ) -> Dict:
        latency = obs.histogram("serve.request_latency_s")
        ttft = obs.histogram("serve.ttft_s")
        pending = collections.deque(
            sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        )
        t0 = time.perf_counter()
        st = dict(prefill_s=0.0, decode_s=0.0, decode_steps=0,
                  prefill_tokens=0, decode_tokens=0, preemptions=0)

        def finish(req: ServeRequest) -> None:
            req.t_done = time.perf_counter()
            if req.state == "running":
                self.sched.finish(req)       # pages freed this very step
            else:
                req.state = "finished"
            latency.observe(req.t_done - req.t_submit)
            obs.counter("serve.requests").inc()
            obs.complete_event(
                "serve.request", req.t_submit, req.t_done - req.t_submit,
                rid=req.rid, tenant=req.tenant, prompt_len=len(req.prompt),
                new_tokens=len(req.out_tokens), preemptions=req.preemptions,
            )

        def emit(req: ServeRequest, tok: int, *, from_prefill: bool) -> None:
            req.out_tokens.append(tok)
            if req.t_first is None:
                req.t_first = time.perf_counter()
                ttft.observe(req.t_first - req.t_submit)
            st["prefill_tokens" if from_prefill else "decode_tokens"] += 1
            obs.counter("serve.tokens").inc()
            if (len(req.out_tokens) >= req.max_new
                    or (eos_id is not None and tok == eos_id)):
                finish(req)

        def submit_next() -> None:
            req = pending.popleft()
            req.t_submit = time.perf_counter()
            if req.max_new <= 0:
                # nothing to generate: complete at admission, but the
                # request still counts and its latency is still observed
                finish(req)
                return
            self.sched.submit(req)

        with obs.span("serve.engine", engine="continuous",
                      requests=len(requests)):
            while pending or self.sched.queue or self.sched.running:
                now = time.perf_counter() - t0
                while pending and pending[0].arrival_s <= now:
                    submit_next()
                if pending and not self.sched.queue and not self.sched.running:
                    submit_next()   # idle: fast-forward to the next arrival

                for req in self.sched.admit():
                    tp = time.perf_counter()
                    tok, self.pools = self.prefill(
                        self.params, self.pools, req.context_tokens,
                        req.pages,
                    )
                    st["prefill_s"] += time.perf_counter() - tp
                    emit(req, tok, from_prefill=True)

                if not self.sched.running:
                    continue
                pre = self.sched.grow()
                st["preemptions"] += len(pre)
                for _ in pre:
                    obs.counter("serve.preempted").inc()
                if not self.sched.running:
                    continue

                bt = np.zeros((self.lanes, self.max_pages), np.int32)
                lens = np.zeros((self.lanes,), np.int32)
                toks = np.zeros((self.lanes,), np.int32)
                for lane, req in self.sched.running.items():
                    bt[lane, :len(req.pages)] = req.pages
                    # the last emitted token's KV is not cached yet — the
                    # step about to run writes it at position ctx_len - 1
                    lens[lane] = req.ctx_len - 1
                    toks[lane] = req.out_tokens[-1]
                td = time.perf_counter()
                with obs.span("serve.decode.step", step=st["decode_steps"],
                              live=len(self.sched.running)):
                    next_tok, self.pools = self.decode(
                        self.params, self.pools, bt, lens, toks
                    )
                    next_host = np.asarray(next_tok)
                st["decode_s"] += time.perf_counter() - td
                st["decode_steps"] += 1
                for lane, req in list(self.sched.running.items()):
                    emit(req, int(next_host[lane]), from_prefill=False)

        st["tokens"] = st["prefill_tokens"] + st["decode_tokens"]
        st["tok_per_s"] = st["decode_tokens"] / max(st["decode_s"], 1e-9)
        st["requests"] = len(requests)
        obs.gauge("serve.tok_per_s").set(st["tok_per_s"])
        return st


class FixedEngine:
    """The legacy fixed-slot ``BatchServer`` behind the continuous
    engine's ``run()`` interface — the differential/throughput baseline.

    Requests are chunked FCFS into slot-sized groups; each group prefills
    together and decodes until its last member finishes (the fixed-slot
    cost model: every batch rounds up to its longest request)."""

    def __init__(self, cfg: ModelConfig, *, lanes: int = 4,
                 max_ctx: int = 128, params=None, **server_kw):
        from ..serve import BatchServer

        self.lanes = lanes
        self.server = BatchServer(
            cfg, batch_size=lanes, max_len=max_ctx, **server_kw
        )
        if params is not None:
            self.server.params = params

    def run(
        self, requests: List[ServeRequest], *, eos_id: Optional[int] = None
    ) -> Dict:
        from ..serve import Request

        ordered = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        st = dict(prefill_s=0.0, decode_s=0.0, decode_steps=0,
                  prefill_tokens=0, decode_tokens=0, preemptions=0)
        with obs.span("serve.engine", engine="fixed",
                      requests=len(requests)):
            for i in range(0, len(ordered), self.lanes):
                group = ordered[i:i + self.lanes]
                batch = [
                    Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                    for r in group
                ]
                t_sub = time.perf_counter()
                for r in group:
                    r.t_submit = t_sub
                s = self.server.run(batch, eos_id=eos_id)
                done = time.perf_counter()
                for r, b in zip(group, batch):
                    r.out_tokens = list(b.out_tokens)
                    r.state = "finished"
                    r.t_done = done
                st["prefill_s"] += s["prefill_s"]
                st["decode_s"] += s["decode_s"]
                st["decode_steps"] += s["decode_steps"]
                st["decode_tokens"] += s["decode_tokens"]
                st["prefill_tokens"] += s["tokens"] - s["decode_tokens"]
        st["tokens"] = st["prefill_tokens"] + st["decode_tokens"]
        st["tok_per_s"] = st["decode_tokens"] / max(st["decode_s"], 1e-9)
        st["requests"] = len(requests)
        return st
