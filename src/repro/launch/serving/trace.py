"""Synthetic multi-tenant request traces for the serving gateway.

A trace is a list of :class:`ServeRequest` with Poisson inter-arrival
times and per-request prompt length / generation budget drawn from small
mixed sets — the shape of real serving traffic (a few tenants, short
chat turns mixed with long completions) at smoke-test scale.  Seeded, so
the differential tests and benches replay identical workloads.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

from .scheduler import ServeRequest


def synthetic_trace(
    n_requests: int,
    *,
    vocab: int,
    seed: int = 0,
    rate_hz: float = 200.0,
    tenants: Sequence[str] = ("tenant0", "tenant1"),
    prompt_lens: Sequence[int] = (4, 8, 16),
    max_news: Sequence[int] = (2, 4, 8),
) -> List[ServeRequest]:
    """Poisson arrivals at ``rate_hz``; lengths/budgets drawn uniformly
    from the given sets.  ``rate_hz=0`` puts every arrival at t=0 (a
    fully saturated queue — what the throughput bench wants)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs: List[ServeRequest] = []
    for i in range(n_requests):
        if rate_hz > 0:
            t += float(rng.exponential(1.0 / rate_hz))
        plen = int(rng.choice(list(prompt_lens)))
        reqs.append(ServeRequest(
            rid=i,
            prompt=rng.integers(0, vocab, size=plen).astype(np.int32),
            max_new=int(rng.choice(list(max_news))),
            arrival_s=t,
            tenant=str(rng.choice(list(tenants))),
        ))
    return reqs
