import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set XLA_FLAGS before any other import (jax locks the device count on
first init) — hence the lines above.

For each cell this:
  1. builds the StepBundle (train/prefill/serve) with full shardings,
  2. ``jax.jit(fn, in_shardings=..., out_shardings=...).lower(...)``,
  3. ``.compile()`` — proving the distribution config is coherent,
  4. records memory_analysis / cost_analysis / per-collective bytes parsed
     from the compiled HLO into a JSON blob for EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k --mesh pod --out results/
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/
"""

import argparse
import json
import re
import time
import traceback
from typing import Dict

import jax

from ..configs import ARCH_IDS, SHAPES, cell_is_applicable, get_config
from .mesh import make_production_mesh
from .steps import prefill_bundle, serve_bundle, train_bundle

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string like 'f32[128,1024]' or a tuple thereof."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in the compiled HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.lstrip()
        # match '%name = TYPE op-name(' with op possibly suffixed (-start)
        for coll in _COLLECTIVES:
            started = f" {coll}-start(" in s
            if not (f" {coll}(" in s or started):
                continue
            eq = s.find("=")
            if eq < 0:
                continue
            op_tok = f" {coll}-start(" if started else f" {coll}("
            idx = s.find(op_tok)
            type_str = s[eq + 1: idx]
            b = _shape_bytes(type_str)
            # async -start ops have tuple types aliasing (operand, result):
            # count the payload once
            if started and type_str.strip().startswith("("):
                b //= 2
            out[coll] += b
            out["count"] += 1
            break
    return out


def run_cell(
    arch: str, shape_name: str, multi_pod: bool,
    hlo_dir: str | None = None,
) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    rec: Dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    from .mesh import set_mesh

    with set_mesh(mesh):
        if shape.kind == "train":
            bundle = train_bundle(mesh, cfg, shape)
        elif shape.kind == "prefill":
            bundle = prefill_bundle(mesh, cfg, shape)
        else:
            bundle = serve_bundle(mesh, cfg, shape)
        # REPRO_DONATE=1 (§Perf knob): donate params/opt-state buffers so the
        # updated trees alias the inputs — halves the peak for the
        # weight-dominated cells
        donate = (
            (0, 1)
            if os.environ.get("REPRO_DONATE") == "1"
            and bundle.static_name == "train_step"
            else ()
        )
        jitted = jax.jit(
            bundle.fn, out_shardings=bundle.out_shardings,
            donate_argnums=donate,
        )
        lowered = jitted.lower(*bundle.in_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)
    # trip-count-aware accounting: XLA's cost_analysis counts while bodies
    # once; the parser multiplies by scan trip counts (roofline/hlo_parse)
    from ..roofline.hlo_parse import analyze_hlo

    parsed = analyze_hlo(hlo)
    if hlo_dir:
        import gzip

        os.makedirs(hlo_dir, exist_ok=True)
        tag = f"{arch}__{shape_name}__{'mp' if multi_pod else 'sp'}"
        with gzip.open(os.path.join(hlo_dir, tag + ".hlo.gz"), "wt") as f:
            f.write(hlo)
    rec.update(
        status="ok",
        step=bundle.static_name,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        flops=cost.get("flops", 0.0),
        bytes_accessed=cost.get("bytes accessed", 0.0),
        memory=_mem_dict(mem),
        collectives=colls,
        parsed=parsed,
        hlo_lines=hlo.count("\n"),
    )
    return rec


def _mem_dict(mem) -> Dict:
    keys = (
        "generated_code_size_in_bytes", "argument_size_in_bytes",
        "output_size_in_bytes", "temp_size_in_bytes", "alias_size_in_bytes",
        "peak_memory_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[
        args.mesh
    ]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip-existing] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            import signal

            def _alarm(sig, frm):
                raise TimeoutError(
                    f"cell exceeded {os.environ.get('DRYRUN_TIMEOUT', '1800')}s"
                )

            signal.signal(signal.SIGALRM, _alarm)
            signal.alarm(int(os.environ.get("DRYRUN_TIMEOUT", "1800")))
            try:
                rec = run_cell(
                    arch, shape, mp,
                    hlo_dir=os.path.join(args.out, "hlo"),
                )
            finally:
                signal.alarm(0)
        except Exception as e:
            rec = {
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if mp else "16x16",
                "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-3000:],
            }
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[done] {tag}: {rec['status']}", flush=True)


if __name__ == "__main__":
    main()
