"""Compute/communication overlap — re-export shim.

The ring (ppermute-pipelined) collective machinery was promoted into
``repro.codegen.collectives`` so generated mesh-tier kernels can choose it
as a per-plan collective strategy (``bind_mesh(collective="ring")``); the
launch layer keeps importing from here.  See ``codegen/collectives.py``
for the implementations and the overlap story.
"""

from __future__ import annotations

from ..codegen.collectives import (  # noqa: F401
    naive_gather_matmul,
    ring_gather_matmul,
    ring_psum,
)

__all__ = ["naive_gather_matmul", "ring_gather_matmul", "ring_psum"]
