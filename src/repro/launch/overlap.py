"""Compute/communication overlap: ring (ppermute-pipelined) collective
matmul, the shard_map building block for TP matmuls whose all-gather would
otherwise serialize before the MXU work (Wang et al.-style).

``ring_gather_matmul`` computes ``y = X @ W`` where X's rows are sharded
over ``axis_name`` and W is replicated per shard-column group: instead of
``all_gather(X) @ W`` (communication then compute), each of the P steps
multiplies the currently-held X shard while ppermuting it to the neighbour —
the collective hides behind the matmul of the previous chunk.  On TPU the
ICI transfer of step i+1 overlaps the MXU work of step i; on CPU
(tests) the result is simply verified equal to the reference.

This is the distribution-level analogue of the paper's pipelined subdivision:
the reduction over shards is an ``rnz`` whose blocks arrive one ``flip``
(ring rotation) at a time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


def ring_gather_matmul(x_shard: jax.Array, w: jax.Array, axis_name: str):
    """Inside shard_map: x_shard (m_loc, k), w (k, n) -> y rows for ALL
    shards, (P * m_loc, n), equal to all_gather(x) @ w.

    The explicit ring exposes the overlap to the scheduler; the naive form
    must finish the all-gather before the first flop.
    """
    from .mesh import axis_size

    p = axis_size(axis_name)
    idx = lax.axis_index(axis_name)

    def step(carry, _):
        x_cur, src = carry
        y_part = jnp.dot(x_cur, w, preferred_element_type=jnp.float32)
        x_nxt = lax.ppermute(
            x_cur, axis_name,
            perm=[(i, (i + 1) % p) for i in range(p)],
        )
        src_nxt = (src - 1) % p
        return (x_nxt, src_nxt), (src, y_part)

    (_, _), (srcs, parts) = lax.scan(step, (x_shard, idx), None, length=p)
    # parts[i] are the rows originating from shard srcs[i]; scatter to order
    order = jnp.argsort(srcs)
    parts = jnp.take(parts, order, axis=0)  # (P, m_loc, n)
    m_loc, n = x_shard.shape[0], w.shape[1]
    return parts.reshape(p * m_loc, n).astype(x_shard.dtype)


def naive_gather_matmul(x_shard: jax.Array, w: jax.Array, axis_name: str):
    """Reference: blocking all-gather then one big dot."""
    x_full = lax.all_gather(x_shard, axis_name, axis=0, tiled=True)
    return jnp.dot(
        x_full, w, preferred_element_type=jnp.float32
    ).astype(x_shard.dtype)
