"""Checkpointing: atomic, async, elastic.

Layout:  <dir>/step_<N>/{manifest.json, arrays.npz}
  * atomic: written to ``step_<N>.tmp`` then renamed — a crash mid-write can
    never corrupt the latest checkpoint (restart picks the previous one);
  * async: ``CheckpointManager.save_async`` hands the host copy to a writer
    thread so the train loop never blocks on disk;
  * elastic: leaves are saved in *logical* form (no device layout); the
    manifest records each leaf's logical axes so ``restore`` can re-shard
    onto any mesh shape — the restore path used after scaling the job up or
    down (see runtime.fault).
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "\x1f"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _unflatten_into(template, flat: Dict[str, Any]):
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl_leaf in paths_leaves:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(directory: str, step: int, tree, extra: Optional[dict] = None):
    """Blocking atomic save of a pytree of arrays."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **host)
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in host.items()
        },
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_", 1)[1]))
    return max(steps) if steps else None


def restore(
    directory: str,
    template,
    step: Optional[int] = None,
    shardings=None,
) -> Tuple[Any, dict]:
    """Restore into the structure of ``template``.

    ``shardings``: optional matching pytree of jax.sharding.Sharding — leaves
    are device_put with them (the elastic re-shard path).  Without it, plain
    host arrays are returned.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda arr, sh: jax.device_put(arr, sh), tree, shardings
        )
    else:
        tree = jax.tree.map(
            lambda arr, t: jax.numpy.asarray(arr, dtype=t.dtype)
            if hasattr(t, "dtype") else arr,
            tree, template,
        )
    return tree, manifest


class CheckpointManager:
    """Async writer with keep-last-K retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._errors: list = []

    def save_async(self, step: int, tree, extra: Optional[dict] = None):
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host, extra))

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, host, extra = item
                save(self.directory, step, host, extra)
                self._gc()
            except Exception as e:  # pragma: no cover
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(n.split("_", 1)[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s}"), ignore_errors=True
            )

    def wait(self):
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self):
        self.wait()
        self._q.put(None)
