"""Rewrite engine: applies the rules of ``rules.py`` over expression trees.

The paper implements pattern-match-and-replace with structured recursion
schemes (catamorphisms / paramorphisms); the Python equivalent is an explicit
bottom-up traversal with path-indexed node replacement.  Two modes:

* **normalization** — apply a rule set to fixpoint (used for fusion: the
  fusion subset is terminating because every rule strictly decreases the
  number of HoF nodes or layout operators);
* **directed derivation** — apply a named rule at an explicit path, recording
  a ``Trace``; this is how ``enumerate.py`` derives each permutation of a HoF
  nest from its neighbour by a single exchange, mirroring the paper's
  Steinhaus–Johnson–Trotter walk.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

from . import expr as E
from .expr import children, rebuild

Path = Tuple[int, ...]
Rule = Callable[[E.Expr], Optional[E.Expr]]


@dataclasses.dataclass
class Step:
    rule: str
    path: Path
    before_size: int
    after_size: int


@dataclasses.dataclass
class Trace:
    steps: List[Step] = dataclasses.field(default_factory=list)

    def record(self, rule: str, path: Path, before: E.Expr, after: E.Expr):
        self.steps.append(Step(rule, path, E.size(before), E.size(after)))

    def __repr__(self):
        return " ; ".join(f"{s.rule}@{list(s.path)}" for s in self.steps)


def get_at(e: E.Expr, path: Path) -> E.Expr:
    for i in path:
        e = children(e)[i]
    return e


def replace_at(e: E.Expr, path: Path, new: E.Expr) -> E.Expr:
    if not path:
        return new
    kids = list(children(e))
    kids[path[0]] = replace_at(kids[path[0]], path[1:], new)
    return rebuild(e, tuple(kids))


def find_matches(e: E.Expr, rule: Rule) -> List[Path]:
    """All paths where ``rule`` fires (pre-order)."""
    out: List[Path] = []

    def go(e: E.Expr, path: Path):
        if rule(e) is not None:
            out.append(path)
        for i, c in enumerate(children(e)):
            go(c, path + (i,))

    go(e, ())
    return out


def apply_at(
    e: E.Expr, path: Path, rule: Rule, trace: Optional[Trace] = None
) -> E.Expr:
    node = get_at(e, path)
    new = rule(node)
    if new is None:
        raise ValueError(
            f"rule {getattr(rule, '__name__', rule)} does not match at {path}: "
            f"{node!r}"
        )
    if trace is not None:
        trace.record(getattr(rule, "__name__", str(rule)), path, node, new)
    return replace_at(e, path, new)


def rewrite_once(
    e: E.Expr, rules: Sequence[Rule], trace: Optional[Trace] = None
) -> Tuple[E.Expr, bool]:
    """One bottom-up pass; apply the first matching rule at each node."""

    changed = False

    def go(e: E.Expr, path: Path) -> E.Expr:
        nonlocal changed
        kids = tuple(
            go(c, path + (i,)) for i, c in enumerate(children(e))
        )
        e2 = rebuild(e, kids)
        for rule in rules:
            new = rule(e2)
            if new is not None:
                changed = True
                if trace is not None:
                    trace.record(
                        getattr(rule, "__name__", str(rule)), path, e2, new
                    )
                return new
        return e2

    return go(e, ()), changed


def normalize(
    e: E.Expr,
    rules: Sequence[Rule],
    max_steps: int = 200,
    trace: Optional[Trace] = None,
) -> E.Expr:
    """Apply ``rules`` bottom-up to fixpoint."""
    for _ in range(max_steps):
        e, changed = rewrite_once(e, rules, trace)
        if not changed:
            return e
    raise RuntimeError(f"normalize: no fixpoint after {max_steps} passes")


def fuse(e: E.Expr, trace: Optional[Trace] = None) -> E.Expr:
    """Normalize with the fusion subset (paper's group-1 rules)."""
    from .rules import FUSION_RULES

    return normalize(e, FUSION_RULES, trace=trace)
