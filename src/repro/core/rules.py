"""Rewrite rules over the HoF DSL — paper §3.

Each rule is a function ``Expr -> Expr | None`` (None = no match at this
node).  Rules are *local*: the engine in ``rewrite.py`` decides where and in
which order to apply them.  Every rule here is property-tested in
``tests/test_rules.py`` to preserve the reference-interpreter semantics.

Rule inventory (paper equation numbers in parens):

fusion group (pipelines)
  beta / eta / app_id          lambda-calculus housekeeping (paper §4)
  nzip_nzip_fuse        (24-25)  nzip closed under ncomp composition
  rnz_nzip_fuse         (27-28)  maps/zips fold into the rnz zipper
  tup_map_fuse          (31,33)  (map f x, map g y) = map (f***g) (x,y)
  tup_rnz_fuse          (34)     (reduce f x, reduce g y) = reduce (f***g) (x,y)
  fanout_fuse           (32)     (map f x, map g x) = map (fanOut f g) x

exchange group (nested structures)
  map_map_exchange      (36-37)  flip nested maps, transposing the result
  map_rnz_exchange      (42)     THE locality rule: map∘rnz → rnz∘map + flip
  rnz_map_exchange      (42⁻¹)   inverse direction
  rnz_rnz_exchange      (43)     flip two reductions (commutative+associative)

subdivision group (hierarchy)
  map_subdiv            (44)     map f = flatten ∘ map (map f) ∘ subdiv
  rnz_subdiv            (44')    reduction regrouping over blocks
  flip_flip / flatten_subdiv / subdiv_flatten   layout-op cancellations
"""

from __future__ import annotations

from typing import Callable, Optional

from . import expr as E
from .expr import (
    App, FanOut, Flatten, Flip, FnProd, Lam, MapN, Prim, Proj, RNZ, Subdiv,
    Tup, Var, fresh, free_vars, subst,
)
from .interp import COMMUTATIVE_ASSOCIATIVE, PRIMS

Rule = Callable[[E.Expr], Optional[E.Expr]]

RULES: dict = {}


def rule(fn: Rule) -> Rule:
    RULES[fn.__name__] = fn
    return fn


# ---------------------------------------------------------------------------
# lambda-calculus housekeeping
# ---------------------------------------------------------------------------


@rule
def beta(e):
    """(\\x -> b) a  =  b[x := a]"""
    if isinstance(e, App) and isinstance(e.fn, Lam) and len(e.fn.params) == len(e.args):
        return subst(e.fn.body, dict(zip(e.fn.params, e.args)))
    return None


@rule
def eta(e):
    """\\x -> f x  =  f   (x not free in f)"""
    if (
        isinstance(e, Lam)
        and isinstance(e.body, App)
        and tuple(e.body.args) == tuple(Var(p) for p in e.params)
        and not (free_vars(e.body.fn) & set(e.params))
    ):
        return e.body.fn
    return None


@rule
def app_id(e):
    """id x = x"""
    if isinstance(e, App) and e.fn == Prim("id") and len(e.args) == 1:
        return e.args[0]
    return None


@rule
def proj_tup(e):
    if isinstance(e, Proj) and isinstance(e.x, Tup):
        return e.x.items[e.i]
    return None


# ---------------------------------------------------------------------------
# fusion group
# ---------------------------------------------------------------------------


def _arity(f: E.Expr) -> Optional[int]:
    if isinstance(f, Lam):
        return len(f.params)
    if isinstance(f, Prim):
        return PRIMS[f.name].arity
    return None


@rule
def nzip_nzip_fuse(e):
    """nzip f xs[..i-1] (nzip g ys) xs[i+1..] = nzip (ncomp i f g) xs++ys (eq 24-25)."""
    if not isinstance(e, MapN):
        return None
    for i, a in enumerate(e.args):
        if isinstance(a, MapN):
            n, m = len(e.args), len(a.args)
            comp = E.ncomp(i, e.f, a.f, n, m)
            new_args = e.args[:i] + a.args + e.args[i + 1 :]
            return MapN(comp, new_args)
    return None


@rule
def rnz_nzip_fuse(e):
    """rnz r f … (nzip g ys) … = rnz r (ncomp i f g) …ys… (eq 27-28)."""
    if not isinstance(e, RNZ):
        return None
    for i, a in enumerate(e.args):
        if isinstance(a, MapN):
            n, m = len(e.args), len(a.args)
            comp = E.ncomp(i, e.f, a.f, n, m)
            new_args = e.args[:i] + a.args + e.args[i + 1 :]
            return RNZ(e.r, comp, new_args)
    return None


@rule
def tup_map_fuse(e):
    """(nzip f xs, nzip g ys) = nzip (f***g) (zip xs ys components) (eq 31/33)."""
    if (
        isinstance(e, Tup)
        and len(e.items) >= 2
        and all(isinstance(it, MapN) for it in e.items)
        and len({len(it.args) for it in e.items}) == 1
    ):
        k = len(e.items[0].args)
        fs = tuple(it.f for it in e.items)
        args = tuple(
            Tup(tuple(it.args[j] for it in e.items)) for j in range(k)
        )
        return MapN(FnProd(fs), args)
    return None


@rule
def tup_rnz_fuse(e):
    """(rnz r f xs, rnz r' f' ys) = rnz (r***r') (f***f') (paired) (eq 34)."""
    if (
        isinstance(e, Tup)
        and len(e.items) >= 2
        and all(isinstance(it, RNZ) for it in e.items)
        and len({len(it.args) for it in e.items}) == 1
    ):
        k = len(e.items[0].args)
        rs = tuple(it.r for it in e.items)
        fs = tuple(it.f for it in e.items)
        args = tuple(
            Tup(tuple(it.args[j] for it in e.items)) for j in range(k)
        )
        return RNZ(FnProd(rs), FnProd(fs), args)
    return None


@rule
def fanout_fuse(e):
    """(map f x, map g x) = map (fanOut f g) x (eq 32)."""
    if (
        isinstance(e, Tup)
        and len(e.items) >= 2
        and all(isinstance(it, MapN) for it in e.items)
        and len({it.args for it in e.items}) == 1
    ):
        return MapN(FanOut(tuple(it.f for it in e.items)), e.items[0].args)
    return None


# ---------------------------------------------------------------------------
# exchange group — operate on nested HoFs, inserting matching flips
# ---------------------------------------------------------------------------


def _single_param_lam(f) -> Optional[Lam]:
    return f if isinstance(f, Lam) and len(f.params) == 1 else None


@rule
def map_map_exchange(e):
    """map (\\x -> map (\\y -> b) u) v  =  flip -2 -1 (map (\\y -> map (\\x -> b) v) u)

    (paper eqs 36-37; the result is 'the same up to a flip in the functor
    structure', which we make explicit so the rule is semantics-preserving.)
    """
    if not (isinstance(e, MapN) and len(e.args) == 1):
        return None
    lam_x = _single_param_lam(e.f)
    if lam_x is None or not isinstance(lam_x.body, MapN):
        return None
    inner = lam_x.body
    if len(inner.args) != 1:
        return None
    x = lam_x.params[0]
    u = inner.args[0]
    if x in free_vars(u):
        return None  # inner operand depends on the outer binder: cannot lift
    v = e.args[0]
    lam_y = inner.f
    if not isinstance(lam_y, Lam) or len(lam_y.params) != 1:
        return None
    y = lam_y.params[0]
    swapped = MapN(
        Lam((y,), MapN(Lam((x,), lam_y.body), (v,))),
        (u,),
    )
    return Flip(-2, -1, swapped)


@rule
def map_rnz_exchange(e):
    """map (\\a -> rnz r m a u) A = rnz (lift r) (\\c q -> map (\\α -> m α q) c) (flip -2 -1 A) u

    (paper eq 42 — the locality-critical exchange.)  Matches when the rnz's
    first argument is exactly the map binder and the second is independent.
    """
    if not (isinstance(e, MapN) and len(e.args) == 1):
        return None
    lam_a = _single_param_lam(e.f)
    if lam_a is None or not isinstance(lam_a.body, RNZ):
        return None
    rnz_ = lam_a.body
    if len(rnz_.args) != 2:
        return None
    a = lam_a.params[0]
    if rnz_.args[0] != Var(a):
        return None
    u = rnz_.args[1]
    if a in free_vars(u) or a in free_vars(rnz_.r) or a in free_vars(rnz_.f):
        return None
    A = e.args[0]
    c, q, al = fresh("c"), fresh("q"), fresh("al")
    zipper = Lam(
        (c, q),
        MapN(Lam((al,), App(rnz_.f, (Var(al), Var(q)))), (Var(c),)),
    )
    return RNZ(E.lift(rnz_.r), zipper, (Flip(-2, -1, A), u))


@rule
def rnz_map_exchange(e):
    """Inverse of eq 42: rnz (lift r) (\\c q -> map (\\α -> m α q) c) A u
    = map (\\a -> rnz r m a u) (flip -2 -1 A)."""
    if not (isinstance(e, RNZ) and len(e.args) == 2):
        return None
    # reducer must be a lift: \la lb -> nzip r (la, lb)
    r = None
    if isinstance(e.r, Lam) and len(e.r.params) == 2:
        b = e.r.body
        if (
            isinstance(b, MapN)
            and b.args == (Var(e.r.params[0]), Var(e.r.params[1]))
            and not (free_vars(b.f) & set(e.r.params))
        ):
            r = b.f
    if r is None:
        return None
    zipper = e.f
    if not isinstance(zipper, Lam) or len(zipper.params) != 2:
        return None
    c, q = zipper.params
    zb = zipper.body
    if not (isinstance(zb, MapN) and len(zb.args) == 1 and zb.args[0] == Var(c)):
        return None
    lam_al = _single_param_lam(zb.f)
    if lam_al is None:
        return None
    al = lam_al.params[0]
    if not (
        isinstance(lam_al.body, App)
        and lam_al.body.args == (Var(al), Var(q))
        and not (free_vars(lam_al.body.fn) & {c, q, al})
    ):
        return None
    m = lam_al.body.fn
    A, u = e.args
    a = fresh("a")
    return MapN(
        Lam((a,), RNZ(r, m, (Var(a), u))),
        (Flip(-2, -1, A),),
    )


@rule
def rnz_rnz_exchange(e):
    """rnz r (\\a… -> rnz r m a… B…) A… =
       rnz r (\\a… b… -> rnz r (\\α… -> m α… b…) a…) (flip A…)… B…

    (paper eq 43; requires r commutative + associative.)
    """
    if not isinstance(e, RNZ):
        return None
    if not (isinstance(e.r, Prim) and e.r.name in COMMUTATIVE_ASSOCIATIVE):
        return None
    outer_lam = e.f
    if not isinstance(outer_lam, Lam) or not isinstance(outer_lam.body, RNZ):
        return None
    inner = outer_lam.body
    if inner.r != e.r:
        return None
    ps = outer_lam.params
    k = len(ps)
    if len(e.args) != k:
        return None
    # inner args must be the outer binders (in order) followed by extras
    if tuple(inner.args[:k]) != tuple(Var(p) for p in ps):
        return None
    extras = inner.args[k:]
    if not extras:
        return None
    bound = set(ps)
    if any(free_vars(x) & bound for x in extras):
        return None
    if free_vars(inner.f) & bound:
        return None
    m = inner.f
    bs = tuple(fresh("b") for _ in extras)
    als = tuple(fresh("al") for _ in ps)
    new_inner = RNZ(
        e.r,
        Lam(als, App(m, tuple(Var(a) for a in als) + tuple(Var(b) for b in bs))),
        tuple(Var(p) for p in ps),
    )
    new_outer_lam = Lam(ps + bs, new_inner)
    new_args = tuple(Flip(-2, -1, A) for A in e.args) + extras
    return RNZ(e.r, new_outer_lam, new_args)


# ---------------------------------------------------------------------------
# subdivision group
# ---------------------------------------------------------------------------


def make_map_subdiv(b: int) -> Rule:
    """map f xs… = flatten -2 (map (\\x… -> map f x…) (subdiv -1 b xs)…)  (eq 44)."""

    def map_subdiv(e):
        if not isinstance(e, MapN):
            return None
        xs = tuple(fresh("blk") for _ in e.args)
        inner = MapN(e.f, tuple(Var(x) for x in xs))
        outer = MapN(
            Lam(xs, inner), tuple(Subdiv(-1, b, a) for a in e.args)
        )
        return Flatten(-2, outer)

    map_subdiv.__name__ = f"map_subdiv[{b}]"
    return map_subdiv


def make_rnz_subdiv(b: int) -> Rule:
    """rnz r f xs… = rnz r (\\x… -> rnz r f x…) (subdiv -1 b xs)…

    Reduction regrouping over blocks — valid because r is associative
    (grouping changes only; order is preserved, so commutativity is NOT
    required, matching the paper's remark below eq 16).
    """

    def rnz_subdiv(e):
        if not isinstance(e, RNZ):
            return None
        xs = tuple(fresh("blk") for _ in e.args)
        inner = RNZ(e.r, e.f, tuple(Var(x) for x in xs))
        return RNZ(
            e.r, Lam(xs, inner), tuple(Subdiv(-1, b, a) for a in e.args)
        )

    rnz_subdiv.__name__ = f"rnz_subdiv[{b}]"
    return rnz_subdiv


# layout-op cancellations -----------------------------------------------------


@rule
def flip_flip(e):
    if (
        isinstance(e, Flip)
        and isinstance(e.x, Flip)
        and {e.d1, e.d2} == {e.x.d1, e.x.d2}
    ):
        return e.x.x
    return None


@rule
def flatten_subdiv(e):
    """flatten d (subdiv d b x) = x"""
    if isinstance(e, Flatten) and isinstance(e.x, Subdiv) and e.d == e.x.d:
        return e.x.x
    return None


@rule
def subdiv_flatten(e):
    """subdiv d b (flatten d x) = x   when the flattened inner extent was b"""
    # only safe when extents match; we keep it conservative: no static types,
    # so this cancellation is applied by the engine only when it tracked the
    # subdivision itself (see rewrite.Normalizer).
    return None


FUSION_RULES = [
    RULES[n]
    for n in [
        "beta", "app_id", "proj_tup",
        "nzip_nzip_fuse", "rnz_nzip_fuse",
        "tup_map_fuse", "tup_rnz_fuse", "fanout_fuse",
        "flip_flip", "flatten_subdiv",
    ]
]
