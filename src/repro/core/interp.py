"""Reference interpreter for the HoF DSL — the semantic oracle.

Array values are plain numpy arrays in *logical* form: axis 0 is the
outermost dimension (the one HoFs consume).  The layout operators act on the
logical form exactly as the strided definitions prescribe (see
``tests/test_layout.py`` for the cross-validation against
``layout.View.materialize``):

* ``subdiv d b``  — reshape logical axis ``rank-1-d`` from ``e`` to ``(e//b, b)``
* ``flatten d``   — merge logical axes of dims ``d+1`` (outer) and ``d`` (inner)
* ``flip d1 d2``  — swap the corresponding logical axes

Every rewrite rule in ``rules.py`` is property-tested to preserve the meaning
assigned by this interpreter.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

from . import expr as E


@dataclasses.dataclass(frozen=True)
class PrimFn:
    name: str
    arity: int
    fn: Callable


PRIMS: Dict[str, PrimFn] = {
    "+": PrimFn("+", 2, lambda a, b: a + b),
    "-": PrimFn("-", 2, lambda a, b: a - b),
    "*": PrimFn("*", 2, lambda a, b: a * b),
    "/": PrimFn("/", 2, lambda a, b: a / b),
    "max": PrimFn("max", 2, np.maximum),
    "min": PrimFn("min", 2, np.minimum),
    "id": PrimFn("id", 1, lambda a: a),
    "neg": PrimFn("neg", 1, lambda a: -a),
    "exp": PrimFn("exp", 1, np.exp),
    "sq": PrimFn("sq", 1, lambda a: a * a),
}

#: reducers that are associative AND commutative — eligible for the
#: rnz/rnz exchange rule (paper eq 43) and reduction regrouping.
COMMUTATIVE_ASSOCIATIVE = frozenset({"+", "*", "max", "min"})


@dataclasses.dataclass(frozen=True)
class Closure:
    lam: E.Lam
    env: dict


@dataclasses.dataclass(frozen=True)
class ProdFn:
    """Evaluated function product (f1, f2, ...) — acts componentwise on tuples."""

    fns: tuple


@dataclasses.dataclass(frozen=True)
class FanFn:
    """Evaluated fanOut — applies every fn to the same args, returns a tuple."""

    fns: tuple


def _norm_dim(rank: int, d: int) -> int:
    return d + rank if d < 0 else d


def _axis(val: np.ndarray, d: int) -> int:
    return val.ndim - 1 - _norm_dim(val.ndim, d)


def _slice(val, k):
    """Index the outermost dim; tuples are SoA products (paper eq 30)."""
    if isinstance(val, tuple):
        return tuple(_slice(c, k) for c in val)
    return val[k]


def _outer_extent(val) -> int:
    if isinstance(val, tuple):
        return _outer_extent(val[0])
    return val.shape[0]


def _stack(vals):
    if isinstance(vals[0], tuple):
        return tuple(
            _stack([v[i] for v in vals]) for i in range(len(vals[0]))
        )
    return np.stack([np.asarray(v) for v in vals])


def apply_fn(fn, args):
    if isinstance(fn, ProdFn):
        # (f *** g) (a, c) = (f a, g c); n-ary, every arg is a tuple
        return tuple(
            apply_fn(f, [a[i] for a in args]) for i, f in enumerate(fn.fns)
        )
    if isinstance(fn, FanFn):
        return tuple(apply_fn(f, args) for f in fn.fns)
    if isinstance(fn, PrimFn):
        if len(args) != fn.arity:
            raise TypeError(f"prim {fn.name} expects {fn.arity} args, got {len(args)}")
        return fn.fn(*args)
    if isinstance(fn, Closure):
        if len(args) != len(fn.lam.params):
            raise TypeError(
                f"closure expects {len(fn.lam.params)} args, got {len(args)}"
            )
        env = dict(fn.env)
        env.update(zip(fn.lam.params, args))
        return evaluate(fn.lam.body, env)
    raise TypeError(f"not applicable: {fn!r}")


def evaluate(e: E.Expr, env: dict):
    if isinstance(e, E.Var):
        try:
            return env[e.name]
        except KeyError:
            raise NameError(f"unbound variable {e.name}") from None
    if isinstance(e, E.Lit):
        return e.value
    if isinstance(e, E.Prim):
        return PRIMS[e.name]
    if isinstance(e, E.Lam):
        return Closure(e, env)
    if isinstance(e, E.App):
        fn = evaluate(e.fn, env)
        args = [evaluate(a, env) for a in e.args]
        return apply_fn(fn, args)
    if isinstance(e, E.FnProd):
        return ProdFn(tuple(evaluate(f, env) for f in e.fs))
    if isinstance(e, E.FanOut):
        return FanFn(tuple(evaluate(f, env) for f in e.fs))
    if isinstance(e, E.MapN):
        fn = evaluate(e.f, env)
        args = [_as_value(evaluate(a, env)) for a in e.args]
        n = _outer_extent(args[0])
        for a in args:
            if _outer_extent(a) != n:
                raise ValueError("nzip extent mismatch")
        out = [apply_fn(fn, [_slice(a, k) for a in args]) for k in range(n)]
        return _stack(out)
    if isinstance(e, E.RNZ):
        r = evaluate(e.r, env)
        fn = evaluate(e.f, env)
        args = [_as_value(evaluate(a, env)) for a in e.args]
        n = _outer_extent(args[0])
        for a in args:
            if _outer_extent(a) != n:
                raise ValueError("rnz extent mismatch")
        if n < 1:
            raise ValueError("rnz needs at least one element (paper: reduce)")
        acc = apply_fn(fn, [_slice(a, 0) for a in args])
        for k in range(1, n):
            acc = apply_fn(r, [acc, apply_fn(fn, [_slice(a, k) for a in args])])
        return acc
    if isinstance(e, E.Subdiv):
        val = np.asarray(evaluate(e.x, env))
        ax = _axis(val, e.d)
        ext = val.shape[ax]
        if ext % e.b:
            raise ValueError(f"subdiv: {e.b} !| {ext}")
        new_shape = val.shape[:ax] + (ext // e.b, e.b) + val.shape[ax + 1 :]
        return val.reshape(new_shape)
    if isinstance(e, E.Flatten):
        val = np.asarray(evaluate(e.x, env))
        d = _norm_dim(val.ndim, e.d)
        ax_outer = val.ndim - 2 - d  # axis of dim d+1
        if ax_outer < 0:
            raise ValueError("flatten: rank too small")
        new_shape = (
            val.shape[:ax_outer]
            + (val.shape[ax_outer] * val.shape[ax_outer + 1],)
            + val.shape[ax_outer + 2 :]
        )
        return np.ascontiguousarray(val).reshape(new_shape)
    if isinstance(e, E.Flip):
        val = np.asarray(evaluate(e.x, env))
        return np.swapaxes(val, _axis(val, e.d1), _axis(val, e.d2))
    if isinstance(e, E.Tup):
        return tuple(evaluate(i, env) for i in e.items)
    if isinstance(e, E.Proj):
        return evaluate(e.x, env)[e.i]
    raise TypeError(type(e))


def _as_value(v):
    """Normalize an evaluated array argument (tuples stay SoA tuples)."""
    if isinstance(v, tuple):
        return tuple(_as_value(c) for c in v)
    return np.asarray(v)


def run(e: E.Expr, **arrays) -> np.ndarray:
    """Evaluate ``e`` with named numpy inputs (logical, outermost-first)."""
    return evaluate(e, {k: np.asarray(v) for k, v in arrays.items()})
