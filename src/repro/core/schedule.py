"""Schedules: binding subdivision levels to the hardware hierarchy.

The paper's closing claim is that its rewrite rules "are potentially capable
of distributing computations over the entire hierarchy of modern hardware,
from vector instructions to entire clusters".  A ``Schedule`` makes that
binding explicit for a contraction variant: every loop level produced by
``subdiv`` is assigned a *tier*:

    mesh:pod / mesh:data / mesh:model   -- GSPMD mesh axes (clusters/devices)
    grid                                -- Pallas grid dimension (HBM->VMEM)
    seq                                 -- sequential loop inside the kernel
    mxu                                 -- innermost tile fed to the MXU

``ops.matmul`` consumes a Schedule end-to-end: the mesh tiers become
PartitionSpecs (pjit in_shardings), the grid tiers become the Pallas
BlockSpec index maps, and the mxu tier fixes the block shapes.  Choosing
between schedules is exactly the paper's variant enumeration with the
TPU cost model as the early-cut (see autotune.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

from .enumerate import ContractionSpec

MESH_TIERS = ("mesh:pod", "mesh:data", "mesh:model")
TIERS = MESH_TIERS + ("grid", "seq", "mxu")


@dataclasses.dataclass(frozen=True)
class Level:
    index: str  # loop index name (possibly a split, e.g. "io")
    tier: str
    extent: int

    def __post_init__(self):
        assert self.tier in TIERS, self.tier


@dataclasses.dataclass(frozen=True)
class Schedule:
    """An ordered (outermost-first) tier assignment for a variant."""

    spec: ContractionSpec
    levels: Tuple[Level, ...]

    @property
    def order(self) -> Tuple[str, ...]:
        return tuple(l.index for l in self.levels)

    def tier_levels(self, tier: str) -> Tuple[Level, ...]:
        return tuple(l for l in self.levels if l.tier == tier)

    def mesh_axes_for(self, operand: str) -> Dict[str, Optional[str]]:
        """index -> mesh axis name for the operand's mesh-tier dims."""
        out: Dict[str, Optional[str]] = {}
        axes = self.spec.operands[operand]
        for l in self.levels:
            if l.tier in MESH_TIERS and l.index in axes:
                out[l.index] = l.tier.split(":", 1)[1]
        return out

    def block_shape_for(self, operand: str) -> Tuple[int, ...]:
        """Pallas block shape: extents of grid/seq dims stay full-block."""
        shape = []
        for idx in self.spec.operands[operand]:
            lvl = next(l for l in self.levels if l.index == idx)
            shape.append(lvl.extent if lvl.tier in ("mxu",) else 1)
        return tuple(shape)

    def validate(self):
        """Tier order must respect the hierarchy (mesh ≥ grid ≥ seq ≥ mxu)."""
        rank = {t: i for i, t in enumerate(TIERS)}
        prev = -1
        for l in self.levels:
            r = rank[l.tier]
            if r < prev and not (l.tier == "seq" and prev == rank["grid"]):
                raise ValueError(
                    f"tier {l.tier} of {l.index} is outside a deeper tier"
                )
            prev = max(prev, r)
        return self


def matmul_schedule(
    m: int,
    n: int,
    k: int,
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    data_shard: int = 1,
    model_shard: int = 1,
    pod_shard: int = 1,
    from_spec: Optional[ContractionSpec] = None,
) -> Schedule:
    """The canonical fully-hierarchical matmul schedule.

    Subdivisions (paper's subdiv, applied level by level):
      i: pods*data shards -> grid blocks of block_m -> mxu rows
      k(N dim): model shards -> grid blocks of block_n -> mxu cols
      j: seq loop of block_k chunks -> mxu depth
    """
    from .enumerate import matmul_spec

    spec = from_spec or matmul_spec(m, k, n)  # extents: i=m, j=k, k=n
    s = spec
    levels = []
    i_rem, n_rem, j_rem = m, n, k
    dp = pod_shard * data_shard
    if pod_shard > 1:
        s = s.subdivide("i", i_rem // pod_shard)
        levels.append(Level("io", "mesh:pod", pod_shard))
        i_name, i_rem = "ii", i_rem // pod_shard
    else:
        i_name = "i"
    if data_shard > 1:
        s = s.subdivide(i_name, i_rem // data_shard)
        levels.append(Level(i_name + "o", "mesh:data", data_shard))
        i_name, i_rem = i_name + "i", i_rem // data_shard
    k_name = "k"
    if model_shard > 1:
        s = s.subdivide(k_name, n_rem // model_shard)
        levels.append(Level(k_name + "o", "mesh:model", model_shard))
        k_name, n_rem = k_name + "i", n_rem // model_shard
    # grid tiers
    s = s.subdivide(i_name, block_m)
    levels.append(Level(i_name + "o", "grid", i_rem // block_m))
    s = s.subdivide(k_name, block_n)
    levels.append(Level(k_name + "o", "grid", n_rem // block_n))
    # sequential k-loop then MXU tile
    s = s.subdivide("j", block_k)
    levels.append(Level("jo", "seq", j_rem // block_k))
    levels.append(Level(i_name + "i", "mxu", block_m))
    levels.append(Level("ji", "mxu", block_k))
    levels.append(Level(k_name + "i", "mxu", block_n))
    return Schedule(s, tuple(levels)).validate()
