"""Strided array layouts — the paper's §2.1 data model.

An array is a flat buffer plus a list of ``(extent, stride)`` pairs.  Dims are
listed *innermost-first* (dim 0 has the smallest stride for a fresh row-major
array), exactly as in the paper's 120-element example::

    a^((3,1),(2,3),(5,6),(4,30))      # flat 4-D row-major tensor
    a^((3,1),(2,15),(5,3),(4,30))     # same buffer viewed as a subdivided matrix

Higher-order functions consume the *outermost* dimension, i.e. ``dims[-1]``.

Three logical (zero-copy) operators re-interpret the buffer:

* ``subdiv(d, b)``  — split dim ``d`` into blocks of ``b`` (paper's tiling)
* ``flatten(d)``    — merge dims ``d`` and ``d+1`` (inverse of subdiv)
* ``flip(d1, d2)``  — swap two dims (logical transposition)

``Layout`` is pure metadata; ``View`` pairs it with a numpy buffer and can
materialize the *logical* array (axes ordered outermost-first) for oracles.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Tuple

import numpy as np

Dim = Tuple[int, int]  # (extent, stride), strides in elements


@dataclasses.dataclass(frozen=True)
class Layout:
    """Immutable (extent, stride) list, innermost-first."""

    dims: Tuple[Dim, ...]

    # -- constructors ------------------------------------------------------
    @staticmethod
    def row_major(shape_outer_first: Tuple[int, ...]) -> "Layout":
        """Row-major layout for a logical shape given outermost-first.

        ``row_major((4, 3))`` is a 4x3 matrix of rows: dims ``((3,1),(4,3))``.
        """
        dims = []
        stride = 1
        for extent in reversed(shape_outer_first):
            dims.append((int(extent), stride))
            stride *= int(extent)
        return Layout(tuple(dims))

    # -- queries -----------------------------------------------------------
    @property
    def rank(self) -> int:
        return len(self.dims)

    @property
    def extents(self) -> Tuple[int, ...]:
        return tuple(e for e, _ in self.dims)

    @property
    def strides(self) -> Tuple[int, ...]:
        return tuple(s for _, s in self.dims)

    @property
    def size(self) -> int:
        return math.prod(self.extents) if self.dims else 1

    def shape_outer_first(self) -> Tuple[int, ...]:
        """Logical shape with the outermost dim first (numpy axis order)."""
        return tuple(reversed(self.extents))

    def offset(self, idx_inner_first: Tuple[int, ...]) -> int:
        assert len(idx_inner_first) == self.rank
        return sum(i * s for i, (_, s) in zip(idx_inner_first, self.dims))

    def indices(self) -> Iterator[Tuple[int, ...]]:
        """All logical indices, innermost-first component order."""

        def rec(d: int, prefix: Tuple[int, ...]):
            if d < 0:
                yield prefix
                return
            for i in range(self.dims[d][0]):
                yield from rec(d - 1, (i,) + prefix)

        yield from rec(self.rank - 1, ())

    # -- the paper's three logical operators --------------------------------
    def subdiv(self, d: int, b: int) -> "Layout":
        """Split dim ``d`` into inner blocks of size ``b`` (paper eq. on subdiv)."""
        d = d + self.rank if d < 0 else d
        e_d, s_d = self.dims[d]
        if e_d % b != 0:
            raise ValueError(f"subdiv: block {b} does not divide extent {e_d}")
        new = (
            self.dims[:d]
            + ((b, s_d), (e_d // b, b * s_d))
            + self.dims[d + 1 :]
        )
        return Layout(new)

    def flatten(self, d: int) -> "Layout":
        """Merge dims ``d`` (inner) and ``d+1`` (outer); inverse of subdiv."""
        d = d + self.rank if d < 0 else d
        if d + 1 >= self.rank:
            raise ValueError("flatten: needs two adjacent dims")
        (e_d, s_d), (e_d1, s_d1) = self.dims[d], self.dims[d + 1]
        if s_d1 != e_d * s_d:
            raise ValueError(
                f"flatten: dims {d},{d+1} are not contiguous "
                f"(stride {s_d1} != {e_d}*{s_d})"
            )
        new = self.dims[:d] + ((e_d * e_d1, s_d),) + self.dims[d + 2 :]
        return Layout(new)

    def flip(self, d1: int, d2: int | None = None) -> "Layout":
        """Swap dims ``d1`` and ``d2`` (default ``d1+1``). Involutive."""
        d1 = d1 + self.rank if d1 < 0 else d1
        if d2 is None:
            d2 = d1 + 1
        d2 = d2 + self.rank if d2 < 0 else d2
        dims = list(self.dims)
        dims[d1], dims[d2] = dims[d2], dims[d1]
        return Layout(tuple(dims))

    # -- relation to reshape/transpose --------------------------------------
    def is_separable(self) -> bool:
        """True if strides are products of extents of smaller-stride dims.

        Every layout reachable from ``row_major`` via subdiv/flatten/flip is
        separable; separable layouts lower to reshape+transpose in JAX.
        """
        nontrivial = [i for i in range(self.rank) if self.dims[i][0] > 1]
        order = sorted(nontrivial, key=lambda i: self.dims[i][1])
        stride = 1
        for i in order:
            e, s = self.dims[i]
            if s != stride:
                return False
            stride *= e
        return True

    def reshape_transpose_plan(self) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
        """Return ``(reshape_shape, transpose_perm)`` lowering this view.

        Given the *flat row-major buffer*, ``buffer.reshape(reshape_shape)
        .transpose(transpose_perm)`` equals the logical array of this layout
        with axes outermost-first.
        """
        if not self.is_separable():
            raise ValueError(f"layout {self.dims} is not separable")
        # buffer reshaped to extents sorted by descending stride (row-major);
        # extent-1 dims carry no stride information — put them first (size-1
        # axes can sit anywhere in a reshape).
        ones = [i for i in range(self.rank) if self.dims[i][0] == 1]
        nontrivial = [i for i in range(self.rank) if self.dims[i][0] > 1]
        by_stride_desc = ones + sorted(
            nontrivial, key=lambda i: -self.dims[i][1]
        )
        reshape_shape = tuple(self.dims[i][0] for i in by_stride_desc)
        # logical axis k (outermost-first) is dim (rank-1-k); find where that
        # dim landed in the reshaped axes.
        pos_of_dim = {dim_i: ax for ax, dim_i in enumerate(by_stride_desc)}
        perm = tuple(pos_of_dim[self.rank - 1 - k] for k in range(self.rank))
        return reshape_shape, perm


@dataclasses.dataclass(frozen=True)
class View:
    """A flat numpy buffer interpreted through a Layout."""

    buffer: np.ndarray  # 1-D
    layout: Layout

    def __post_init__(self):
        assert self.buffer.ndim == 1

    @staticmethod
    def from_logical(arr: np.ndarray) -> "View":
        """Wrap a logical (outermost-first axes) array as a row-major view."""
        a = np.ascontiguousarray(arr)
        return View(a.reshape(-1), Layout.row_major(a.shape))

    def materialize(self) -> np.ndarray:
        """Logical array, axes outermost-first (a copy)."""
        itemsize = self.buffer.itemsize
        shape = self.layout.shape_outer_first()
        strides = tuple(
            s * itemsize for s in reversed(self.layout.strides)
        )
        return np.lib.stride_tricks.as_strided(
            self.buffer, shape=shape, strides=strides
        ).copy()

    # the three operators lift pointwise to views (zero-copy)
    def subdiv(self, d: int, b: int) -> "View":
        return View(self.buffer, self.layout.subdiv(d, b))

    def flatten(self, d: int) -> "View":
        return View(self.buffer, self.layout.flatten(d))

    def flip(self, d1: int, d2: int | None = None) -> "View":
        return View(self.buffer, self.layout.flip(d1, d2))
