"""Analytic cost models for HoF-nest variants — the paper's missing early-cut.

The paper enumerates variants and *measures* them all; its Future Work notes
an early-cut rule is needed for this to scale.  We implement two flavours:

* ``cpu_cost``  — a hierarchical cache-traffic model (classic reuse-level /
  working-set analysis) used to rank the paper's Table-1/2 permutations
  without running them;
* ``tpu_cost``  — a VMEM/HBM/MXU roofline flavour used to pick Pallas block
  shapes and loop orders for the kernels, with explicit penalties for
  MXU-misaligned innermost extents (multiples of (8, 128) wanted).

Both consume a ``ContractionSpec`` + loop order, i.e. they work on the same
objects the rewrite rules produce, so "enumerate -> cut -> lower" is a single
pipeline (see autotune.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

from .enumerate import ContractionSpec


@dataclasses.dataclass(frozen=True)
class CacheLevel:
    name: str
    capacity: int  # elements (we model in elements, not bytes)
    miss_cost: float  # relative cost per line fetched from beyond this level


#: a Core-i5-7300HQ-ish hierarchy, in 8-byte elements
CPU_HIERARCHY = (
    CacheLevel("L1", 32 * 1024 // 8, 1.0),
    CacheLevel("L2", 256 * 1024 // 8, 4.0),
    CacheLevel("L3", 3 * 1024 * 1024 // 8, 20.0),
    CacheLevel("DRAM", 1 << 62, 120.0),
)

LINE_ELEMS = 8  # 64-byte lines of float64


def _operand_views(spec: ContractionSpec) -> Dict[str, Tuple[str, ...]]:
    """Operands plus the output array 'OUT' (store traffic counts too)."""
    views = dict(spec.operands)
    views["OUT"] = spec.output
    return views


def _footprint(
    axes: Tuple[str, ...], resident: set, extents: Dict[str, int]
) -> int:
    return math.prod(extents[a] for a in axes if a in resident) or 1


def _lines(
    name: str,
    axes: Tuple[str, ...],
    resident: set,
    extents: Dict[str, int],
    canonical: Dict[str, Tuple[str, ...]],
    line: int,
) -> float:
    """Footprint in cache lines: contiguous innermost axis amortizes fetches."""
    fp = _footprint(axes, resident, extents)
    if not axes:
        return 1.0
    inner = canonical[name][-1]  # stride-1 axis in canonical storage
    if inner in resident:
        inner_e = min(extents[inner], fp)
        return fp / min(line, inner_e)
    return float(fp)


def cpu_cost(
    spec: ContractionSpec,
    order: Sequence[str],
    hierarchy: Sequence[CacheLevel] = CPU_HIERARCHY,
    line: int = LINE_ELEMS,
) -> float:
    """Total weighted line traffic across the cache hierarchy."""
    views = _operand_views(spec)
    canonical = dict(views)
    extents = spec.extents
    depth = {idx: k for k, idx in enumerate(order)}
    total = 0.0
    for lvl in hierarchy:
        # deepest loop level t such that the working set below t fits
        best_t = len(order)  # innermost only
        for t in range(len(order) + 1):
            resident = set(order[t:])
            ws = sum(
                _footprint(axes, resident, extents) for axes in views.values()
            )
            if ws <= lvl.capacity:
                best_t = t
                break
        resident = set(order[best_t:])
        miss_lines = 0.0
        for name, axes in views.items():
            trips = math.prod(
                extents[i]
                for i in order[:best_t]
                if i in axes
            ) or 1
            miss_lines += trips * _lines(
                name, axes, resident, extents, canonical, line
            )
        total += miss_lines * lvl.miss_cost
    return total


def rank_variants(
    spec: ContractionSpec,
    orders: Sequence[Sequence[str]],
    cost_fn=cpu_cost,
) -> List[Tuple[float, Tuple[str, ...]]]:
    scored = sorted(
        (cost_fn(spec, tuple(o)), tuple(o)) for o in orders
    )
    return scored


def early_cut(
    spec: ContractionSpec,
    orders: Sequence[Sequence[str]],
    keep: int = 4,
    cost_fn=cpu_cost,
) -> List[Tuple[str, ...]]:
    """The paper's future-work pruning rule: keep only the cheapest variants."""
    return [o for _, o in rank_variants(spec, orders, cost_fn)[:keep]]


# ---------------------------------------------------------------------------
# TPU flavour
# ---------------------------------------------------------------------------

#: v5e-like hardware model (see DESIGN.md §6)
TPU = dict(
    peak_flops=197e12,  # bf16
    hbm_bw=819e9,
    vmem_bytes=64 * 1024 * 1024,  # usable VMEM working budget
    ici_bw=50e9,  # per link
    mxu=(128, 128),
    sublane=8,
)


def tpu_cost(
    spec: ContractionSpec,
    order: Sequence[str],
    elem_bytes: int = 2,
    hw: dict = TPU,
) -> float:
    """Estimated step time (s): max(compute, HBM traffic) + alignment penalty.

    The resident set is the deepest loop suffix whose working set fits VMEM
    (the Pallas block); everything outside streams from HBM.
    """
    views = _operand_views(spec)
    extents = spec.extents
    cap = hw["vmem_bytes"] // elem_bytes
    best_t = len(order)
    for t in range(len(order) + 1):
        resident = set(order[t:])
        ws = sum(_footprint(a, resident, extents) for a in views.values())
        if ws <= cap:
            best_t = t
            break
    resident = set(order[best_t:])
    hbm_elems = 0.0
    for name, axes in views.items():
        trips = math.prod(e for i in order[:best_t] if i in axes for e in (extents[i],)) or 1
        hbm_elems += trips * _footprint(axes, resident, extents)
    hbm_time = hbm_elems * elem_bytes / hw["hbm_bw"]
    compute_time = spec.flops() / hw["peak_flops"]

    # alignment: the innermost map/rnz extents feed the MXU; penalize extents
    # that are not multiples of the (sublane, lane) tile.
    penalty = 1.0
    inner = [i for i in order[best_t:]]
    if inner:
        lane = extents[inner[-1]]
        if lane % hw["mxu"][1]:
            penalty *= 1.5
        if len(inner) >= 2 and extents[inner[-2]] % hw["sublane"]:
            penalty *= 1.2
    return max(compute_time, hbm_time) * penalty


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    collective_bytes: float,
    chips: int,
    hw: dict = TPU,
) -> Dict[str, float]:
    """The three §Roofline terms, in seconds (see EXPERIMENTS.md)."""
    return dict(
        compute_s=flops / (chips * hw["peak_flops"]),
        memory_s=hbm_bytes / (chips * hw["hbm_bw"]),
        collective_s=collective_bytes / (chips * hw["ici_bw"]),
    )
