"""Semi-vectorized numpy executor for contraction variants — the wall-clock
half of the paper's Tables 1/2 reproduction.

The paper's C++ codegen turns each HoF ordering into a distinct loop nest and
measures it.  In Python we cannot time scalar loops, so the executor runs the
*outer* loop levels as real Python loops (preserving the traversal order the
variant prescribes) and delegates the innermost ``vector_levels`` dims to one
``np.einsum`` call over the current operand slices.  Slices of
transposed/subdivided operands are numpy *views* with the strides the variant
implies, so the memory-access-pattern differences between variants are real
and measurable — the same signal the paper measures, at block granularity.
"""

from __future__ import annotations

import math
from typing import Dict, Sequence

import numpy as np

from .enumerate import ContractionSpec


def _prepare(spec: ContractionSpec, name: str, arr: np.ndarray):
    root = spec.root()
    axes = list(root.operands[name])
    for index, b in spec.split_chain():
        if index not in axes:
            continue
        p = axes.index(index)
        e = arr.shape[p]
        arr = arr.reshape(arr.shape[:p] + (e // b, b) + arr.shape[p + 1 :])
        axes[p : p + 1] = [index + "o", index + "i"]
    # sort axes into loop order WITHOUT copying (transpose view)
    return arr, axes


def execute_variant(
    spec: ContractionSpec,
    order: Sequence[str],
    arrays: Dict[str, np.ndarray],
    vector_levels: int = 2,
) -> np.ndarray:
    order = tuple(order)
    letters = {idx: chr(ord("a") + i) for i, idx in enumerate(spec.indices)}
    names = list(spec.operands)
    prepped = {}
    for n in names:
        arr, axes = _prepare(spec, n, np.asarray(arrays[n]))
        target = sorted(axes, key=order.index)
        arr = arr.transpose(tuple(axes.index(t) for t in target))  # view
        prepped[n] = (arr, target)

    cut = max(len(order) - vector_levels, 0)
    tail = order[cut:]
    tail_maps = [i for i in tail if spec.kind(i) == "map"]

    def einsum_tail(vals: Dict[str, np.ndarray], axlists) -> np.ndarray:
        subs = ",".join("".join(letters[i] for i in axlists[n]) for n in names)
        out = "".join(letters[i] for i in tail_maps)
        return np.einsum(f"{subs}->{out}", *(vals[n] for n in names))

    def exec_level(k: int, vals, axlists):
        if k == cut:
            return einsum_tail(vals, axlists)
        idx = order[k]
        involved = [n for n in names if axlists[n] and axlists[n][0] == idx]
        if not involved:
            return exec_level(k + 1, vals, axlists)
        sub_ax = {
            n: (axlists[n][1:] if n in involved else axlists[n]) for n in names
        }
        extent = vals[involved[0]].shape[0]
        if spec.kind(idx) == "map":
            parts = []
            for t in range(extent):
                v2 = dict(vals)
                for n in involved:
                    v2[n] = vals[n][t]
                parts.append(exec_level(k + 1, v2, sub_ax))
            return np.stack(parts)
        acc = None
        for t in range(extent):
            v2 = dict(vals)
            for n in involved:
                v2[n] = vals[n][t]
            y = exec_level(k + 1, v2, sub_ax)
            acc = y if acc is None else acc + y
        return acc

    vals = {n: prepped[n][0] for n in names}
    axlists = {n: list(prepped[n][1]) for n in names}
    out = exec_level(0, vals, axlists)

    # canonicalize: produced axes are map dims in loop order
    produced = [i for i in order[:cut] if spec.kind(i) == "map"] + tail_maps
    perm = tuple(produced.index(i) for i in spec.output)
    out = np.transpose(out, perm)
    root = spec.root()
    return out.reshape(tuple(root.extents[i] for i in root.output))


def flops_of(spec: ContractionSpec) -> int:
    return spec.flops()
