"""Lowering the HoF DSL to JAX.

Two layers:

* ``jax_run`` — a structural lowering of any DSL expression to jnp:
  ``MapN -> jax.vmap``, ``RNZ -> vmapped zipper + reduction``, layout ops ->
  reshape/swapaxes.  This is the "generate code for the chosen variant" step
  of the paper, targeting XLA instead of C++14.  Associative prim reducers
  lower to ``jnp.sum``-style monoid reductions (regrouping licensed by the
  paper's associativity requirement).

* ``contraction_to_jax`` — lowers a ``ContractionSpec`` variant to a jitted
  function in which the loop ordering is preserved structurally: map dims
  become vmap axes outer-to-inner, reduce dims become reductions at their
  nesting depth.  The innermost `mxu_levels` dims are delegated to
  ``lax.dot_general`` so the MXU sees a matmul, exactly like the paper
  delegates the innermost blocks to vector instructions.
"""

from __future__ import annotations

import functools
import math
from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import expr as E
from .enumerate import ContractionSpec, output_axis_order
from .interp import COMMUTATIVE_ASSOCIATIVE, PRIMS

_JNP_PRIMS: Dict[str, Callable] = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "id": lambda a: a,
    "neg": lambda a: -a,
    "exp": jnp.exp,
    "sq": lambda a: a * a,
}

_MONOID = {
    "+": jnp.sum,
    "*": jnp.prod,
    "max": jnp.max,
    "min": jnp.min,
}


class _Closure:
    __slots__ = ("lam", "env")

    def __init__(self, lam, env):
        self.lam, self.env = lam, env


def unwrap_lift(r: E.Expr) -> E.Expr | None:
    """Strip ``lift`` wrappers: \\a b -> nzip r (a, b)  ==>  r."""
    while isinstance(r, E.Lam) and len(r.params) == 2:
        b = r.body
        if (
            isinstance(b, E.MapN)
            and b.args == (E.Var(r.params[0]), E.Var(r.params[1]))
            and not (E.free_vars(b.f) & set(r.params))
        ):
            r = b.f
        else:
            break
    return r


def _apply(fn, args):
    if isinstance(fn, _Closure):
        env = dict(fn.env)
        env.update(zip(fn.lam.params, args))
        return _eval(fn.lam.body, env)
    if callable(fn):
        return fn(*args)
    raise TypeError(f"not applicable: {fn}")


def _eval(e: E.Expr, env: dict):
    if isinstance(e, E.Var):
        return env[e.name]
    if isinstance(e, E.Lit):
        return e.value
    if isinstance(e, E.Prim):
        return _JNP_PRIMS[e.name]
    if isinstance(e, E.Lam):
        return _Closure(e, env)
    if isinstance(e, E.App):
        return _apply(_eval(e.fn, env), [_eval(a, env) for a in e.args])
    if isinstance(e, E.MapN):
        fn = _eval(e.f, env)
        args = [jnp.asarray(_eval(a, env)) for a in e.args]
        return jax.vmap(lambda *xs: _apply(fn, list(xs)))(*args)
    if isinstance(e, E.RNZ):
        core = unwrap_lift(e.r)
        fn = _eval(e.f, env)
        args = [jnp.asarray(_eval(a, env)) for a in e.args]
        ys = jax.vmap(lambda *xs: _apply(fn, list(xs)))(*args)
        if isinstance(core, E.Prim) and core.name in _MONOID:
            return _MONOID[core.name](ys, axis=0)
        # general associative reducer: left fold via scan
        r = _eval(e.r, env)
        def step(acc, y):
            return _apply(r, [acc, y]), None
        acc, _ = jax.lax.scan(step, ys[0], ys[1:])
        return acc
    if isinstance(e, E.Subdiv):
        val = jnp.asarray(_eval(e.x, env))
        d = e.d + val.ndim if e.d < 0 else e.d
        ax = val.ndim - 1 - d
        ext = val.shape[ax]
        return val.reshape(
            val.shape[:ax] + (ext // e.b, e.b) + val.shape[ax + 1 :]
        )
    if isinstance(e, E.Flatten):
        val = jnp.asarray(_eval(e.x, env))
        d = e.d + val.ndim if e.d < 0 else e.d
        ax = val.ndim - 2 - d
        return val.reshape(
            val.shape[:ax]
            + (val.shape[ax] * val.shape[ax + 1],)
            + val.shape[ax + 2 :]
        )
    if isinstance(e, E.Flip):
        val = jnp.asarray(_eval(e.x, env))
        d1 = e.d1 + val.ndim if e.d1 < 0 else e.d1
        d2 = e.d2 + val.ndim if e.d2 < 0 else e.d2
        return jnp.swapaxes(val, val.ndim - 1 - d1, val.ndim - 1 - d2)
    if isinstance(e, E.Tup):
        return tuple(_eval(i, env) for i in e.items)
    if isinstance(e, E.Proj):
        return _eval(e.x, env)[e.i]
    if isinstance(e, E.FnProd):
        fns = tuple(_eval(f, env) for f in e.fs)
        return lambda *args: tuple(
            _apply(f, [a[i] for a in args]) for i, f in enumerate(fns)
        )
    if isinstance(e, E.FanOut):
        fns = tuple(_eval(f, env) for f in e.fs)
        return lambda *args: tuple(_apply(f, list(args)) for f in fns)
    raise TypeError(type(e))


def jax_run(e: E.Expr, **arrays):
    """Lower + evaluate a DSL expression with jnp inputs (logical arrays)."""
    env = {k: jnp.asarray(v) for k, v in arrays.items()}
    return _eval(e, env)


def jax_fn(e: E.Expr, names: Sequence[str]) -> Callable:
    """A jittable function (arrays in ``names`` order) computing ``e``."""

    def fn(*arrays):
        return _eval(e, dict(zip(names, arrays)))

    return fn


# ---------------------------------------------------------------------------
# contraction variants -> structured JAX
# ---------------------------------------------------------------------------


def contraction_to_jax(
    spec: ContractionSpec, order: Sequence[str], canonical_output: bool = True
) -> Callable:
    """Lower a contraction variant to JAX preserving the loop structure.

    Map dims become vmap axes (outer first); rnz dims become sums placed at
    their depth.  Operand Subdiv/Flip prefixes are realized as
    reshape/transpose, so the traversal pattern the paper derives is visible
    to XLA verbatim.
    """
    root = spec.root()
    names = list(root.operands)

    def prepare(name: str, arr):
        axes = list(root.operands[name])
        for index, b in spec.split_chain():
            if index not in axes:
                continue
            p = axes.index(index)
            e = arr.shape[p]
            arr = arr.reshape(
                arr.shape[:p] + (e // b, b) + arr.shape[p + 1 :]
            )
            axes[p : p + 1] = [index + "o", index + "i"]
        target = sorted(axes, key=list(order).index)
        arr = jnp.transpose(arr, tuple(axes.index(t) for t in target))
        return arr, target

    def fn(*arrays):
        prepped = dict(zip(names, (prepare(n, a) for n, a in zip(names, arrays))))
        vals = {n: p[0] for n, p in prepped.items()}
        axlists = {n: list(p[1]) for n, p in prepped.items()}

        def build(k: int, vals: Dict[str, jnp.ndarray]):
            if k == len(order):
                out = None
                for n in names:
                    out = vals[n] if out is None else out * vals[n]
                return out
            idx = order[k]
            involved = [
                n for n in names if axlists[n] and axlists[n][0] == idx
            ]
            if not involved:
                return build(k + 1, vals)
            saved = {n: axlists[n] for n in involved}
            for n in involved:
                axlists[n] = axlists[n][1:]

            def inner(*slices):
                v2 = dict(vals)
                v2.update(zip(involved, slices))
                return build(k + 1, v2)

            if spec.kind(idx) == "map":
                in_axes = tuple(0 for _ in involved)
                out = jax.vmap(inner, in_axes=in_axes)(
                    *(vals[n] for n in involved)
                )
            else:
                ys = jax.vmap(inner)(*(vals[n] for n in involved))
                out = jnp.sum(ys, axis=0)
            for n in involved:
                axlists[n] = saved[n]
            return out

        out = build(0, vals)
        if canonical_output:
            produced = output_axis_order(spec, order)
            out = jnp.transpose(
                out, tuple(produced.index(i) for i in spec.output)
            )
            out = out.reshape(
                tuple(root.extents[i] for i in root.output)
            )
        return out

    return fn
