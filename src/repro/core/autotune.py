"""Autotuning: enumerate variants, early-cut with the cost model, pick one.

This is the paper's §4 pipeline made automatic:
  1. enumerate HoF orderings (SJT) and subdivision factors,
  2. rank with the analytic cost model (the early-cut rule the paper's
     Future Work calls for),
  3. (optionally) measure the survivors,
  4. emit the winner as a Schedule for ops/kernels.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost import TPU, cpu_cost, rank_variants, tpu_cost
from .enumerate import ContractionSpec, variant_orders
from .execute import execute_variant


@dataclasses.dataclass
class TunedVariant:
    order: Tuple[str, ...]
    spec: ContractionSpec
    predicted_cost: float
    measured_s: Optional[float] = None


def enumerate_subdivided(
    spec: ContractionSpec,
    subdiv_candidates: Dict[str, Sequence[int]],
) -> List[ContractionSpec]:
    """spec plus every single- and double-index subdivision combination."""
    specs = [spec]
    idxs = list(subdiv_candidates)
    for i, idx in enumerate(idxs):
        for b in subdiv_candidates[idx]:
            if spec.extents[idx] % b:
                continue
            s1 = spec.subdivide(idx, b)
            specs.append(s1)
            for idx2 in idxs[i + 1 :]:
                for b2 in subdiv_candidates[idx2]:
                    if s1.extents[idx2] % b2:
                        continue
                    specs.append(s1.subdivide(idx2, b2))
    return specs


def tune(
    spec: ContractionSpec,
    subdiv_candidates: Optional[Dict[str, Sequence[int]]] = None,
    cost_fn: Callable = cpu_cost,
    keep: int = 4,
    measure_with: Optional[Dict[str, np.ndarray]] = None,
    repeats: int = 3,
) -> List[TunedVariant]:
    """Full enumerate -> cut -> (measure) pipeline; best variant first."""
    specs = (
        enumerate_subdivided(spec, subdiv_candidates)
        if subdiv_candidates
        else [spec]
    )
    pool: List[TunedVariant] = []
    for s in specs:
        for cost, order in rank_variants(s, variant_orders(s), cost_fn):
            pool.append(TunedVariant(order, s, cost))
    pool.sort(key=lambda tv: tv.predicted_cost)
    survivors = pool[:keep]
    if measure_with is not None:
        for tv in survivors:
            best = math.inf
            for _ in range(repeats):
                t0 = time.perf_counter()
                execute_variant(tv.spec, tv.order, measure_with)
                best = min(best, time.perf_counter() - t0)
            tv.measured_s = best
        survivors.sort(key=lambda tv: tv.measured_s)
    return survivors


# ---------------------------------------------------------------------------
# TPU block-shape selection for the Pallas matmul
# ---------------------------------------------------------------------------


def choose_matmul_blocks(
    m: int,
    n: int,
    k: int,
    elem_bytes: int = 2,
    hw: dict = TPU,
    double_buffer: bool = True,
) -> Tuple[int, int, int]:
    """(block_m, block_n, block_k) minimizing HBM traffic under VMEM.

    Napkin model (the TPU analogue of the paper's cache reasoning):
      traffic = M*K * (N/bn)  +  K*N * (M/bm)  +  M*N
    so we maximize bm, bn subject to
      (bm*bk + bk*bn + bm*bn) * elem * (2 if double_buffer) <= VMEM
    with every extent a multiple of the MXU tile where possible.
    """
    budget = hw["vmem_bytes"] // (2 if double_buffer else 1) // elem_bytes

    def aligned(x: int, size: int) -> List[int]:
        outs = [c for c in (128, 256, 512, 1024) if c <= size and size % c == 0]
        return outs or [size]

    best, best_traffic = None, math.inf
    for bm in aligned(8, m):
        for bn in aligned(128, n):
            for bk in aligned(128, k):
                if bm * bk + bk * bn + bm * bn > budget:
                    continue
                traffic = m * k * (n / bn) + k * n * (m / bm) + m * n
                # prefer deeper k-blocks on ties (fewer grid steps)
                score = (traffic, -bk, -(bm * bn))
                if score < (best_traffic, 0, 0) or best is None:
                    if traffic < best_traffic or best is None:
                        best, best_traffic = (bm, bn, bk), traffic
    if best is None:  # tiny problem: single block
        best = (m, n, k)
    return best
