"""Autotuning: enumerate variants, early-cut with the cost model, pick one.

This is the paper's §4 pipeline made automatic:
  1. enumerate HoF orderings (SJT) and subdivision factors,
  2. rank with the analytic cost model (the early-cut rule the paper's
     Future Work calls for),
  3. (optionally) measure the survivors,
  4. emit the winner as a Schedule for ops/kernels.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost import TPU, cpu_cost, rank_variants, tpu_cost
from .enumerate import ContractionSpec, variant_orders
from .execute import execute_variant


@dataclasses.dataclass
class TunedVariant:
    order: Tuple[str, ...]
    spec: ContractionSpec
    predicted_cost: float
    measured_s: Optional[float] = None


def enumerate_subdivided(
    spec: ContractionSpec,
    subdiv_candidates: Dict[str, Sequence[int]],
) -> List[ContractionSpec]:
    """spec plus every single- and double-index subdivision combination."""
    specs = [spec]
    idxs = list(subdiv_candidates)
    for i, idx in enumerate(idxs):
        for b in subdiv_candidates[idx]:
            if spec.extents[idx] % b:
                continue
            s1 = spec.subdivide(idx, b)
            specs.append(s1)
            for idx2 in idxs[i + 1 :]:
                for b2 in subdiv_candidates[idx2]:
                    if s1.extents[idx2] % b2:
                        continue
                    specs.append(s1.subdivide(idx2, b2))
    return specs


def _tune_cache_key(spec, subdiv_candidates, cost_fn, keep, measure_with):
    """NB: cost_fn is identified by module+qualname — pass a NAMED function
    when caching; two lambdas defined at the same spot would collide."""
    from ..codegen.cache import cache_key

    return cache_key(
        spec,
        extra={
            "what": "tune.variants",
            "subdiv": {
                k: sorted(int(b) for b in v)
                for k, v in (subdiv_candidates or {}).items()
            },
            "cost_fn": (
                getattr(cost_fn, "__module__", "")
                + ":"
                + getattr(
                    cost_fn, "__qualname__",
                    getattr(cost_fn, "__name__", repr(cost_fn)),
                )
            ),
            "keep": keep,
            "measured": measure_with is not None
            and {
                k: [list(np.shape(a)), str(np.asarray(a).dtype)]
                for k, a in measure_with.items()
            },
        },
    )


def _variants_to_json(survivors: List[TunedVariant]) -> list:
    return [
        {
            "order": list(tv.order),
            "splits": [[i, int(b)] for i, b in tv.spec.split_chain()],
            "predicted": float(tv.predicted_cost),
            "measured": tv.measured_s,
        }
        for tv in survivors
    ]


def _variants_from_json(data: list, root: ContractionSpec) -> List[TunedVariant]:
    out = []
    for d in data:
        s = root.root()
        for index, b in d["splits"]:
            s = s.subdivide(index, b)
        out.append(
            TunedVariant(
                tuple(d["order"]), s, d["predicted"], d.get("measured")
            )
        )
    return out


def tune(
    spec: ContractionSpec,
    subdiv_candidates: Optional[Dict[str, Sequence[int]]] = None,
    cost_fn: Callable = cpu_cost,
    keep: int = 4,
    measure_with: Optional[Dict[str, np.ndarray]] = None,
    repeats: int = 3,
    cache=None,
) -> List[TunedVariant]:
    """Full enumerate -> cut -> (measure) pipeline; best variant first.

    ``cache`` (a ``codegen.cache.AutotuneCache``) persists the survivor
    list keyed by spec + subdiv candidates + cost model + measurement
    shapes: a repeated call — in this process or any later one — returns
    the stored ranking without re-enumerating or re-measuring.
    """
    if cache is not None:
        key = _tune_cache_key(spec, subdiv_candidates, cost_fn, keep, measure_with)
        hit = cache.get(key)
        if hit is not None:
            return _variants_from_json(hit, spec)
    specs = (
        enumerate_subdivided(spec, subdiv_candidates)
        if subdiv_candidates
        else [spec]
    )
    pool: List[TunedVariant] = []
    for s in specs:
        for cost, order in rank_variants(s, variant_orders(s), cost_fn):
            pool.append(TunedVariant(order, s, cost))
    pool.sort(key=lambda tv: tv.predicted_cost)
    survivors = pool[:keep]
    if measure_with is not None:
        for tv in survivors:
            best = math.inf
            for _ in range(repeats):
                t0 = time.perf_counter()
                execute_variant(tv.spec, tv.order, measure_with)
                best = min(best, time.perf_counter() - t0)
            tv.measured_s = best
        survivors.sort(key=lambda tv: tv.measured_s)
    if cache is not None:
        cache.put(key, _variants_to_json(survivors))
    return survivors


# ---------------------------------------------------------------------------
# TPU block-shape selection for the Pallas matmul
# ---------------------------------------------------------------------------


def choose_matmul_blocks(
    m: int,
    n: int,
    k: int,
    elem_bytes: int = 2,
    hw: dict = TPU,
    double_buffer: bool = True,
) -> Tuple[int, int, int]:
    """(block_m, block_n, block_k) minimizing HBM traffic under VMEM.

    Napkin model (the TPU analogue of the paper's cache reasoning):
      traffic = M*K * (N/bn)  +  K*N * (M/bm)  +  M*N
    so we maximize bm, bn subject to
      (bm*bk + bk*bn + bm*bn) * elem * (2 if double_buffer) <= VMEM
    with every extent a multiple of the MXU tile where possible.
    """
    budget = hw["vmem_bytes"] // (2 if double_buffer else 1) // elem_bytes

    def aligned(align: int, size: int, cap: int = 1024) -> List[int]:
        """Divisors of ``size`` that are pow2 multiples of ``align``."""
        outs, c = [], align
        while c <= min(size, cap):
            if size % c == 0:
                outs.append(c)
            c *= 2
        return outs or [size]

    best, best_score = None, None
    for bm in aligned(8, m):
        for bn in aligned(128, n):
            for bk in aligned(128, k):
                if bm * bk + bk * bn + bm * bn > budget:
                    continue
                traffic = m * k * (n / bn) + k * n * (m / bm) + m * n
                # prefer deeper k-blocks on ties (fewer grid steps)
                score = (traffic, -bk, -(bm * bn))
                if best is None or score < best_score:
                    best, best_score = (bm, bn, bk), score
    if best is None:  # tiny problem: single block
        best = (m, n, k)
    return best
